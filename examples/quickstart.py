"""Quickstart: train a reduced assigned arch with the paper's decentralized
strategy (ring mixing + Adam local updates — transformers need an adaptive
optimizer; the paper's plain-SGD recipe is used in the BLSTM examples),
then serve a few tokens from it.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core import strategies as ST
from repro.data import make_dataset
from repro.models import build_model
from repro.optim.optimizers import adam
from repro.optim.schedules import constant
from repro.sharding import init_spec_tree


def main():
    cfg = get_arch("smollm-360m").reduced()
    model = build_model(cfg)
    L = 4

    # --- train with SD-PSGD (ring mixing, paper Eq. 14) ------------------
    params = ST.stack_for_learners(
        init_spec_tree(model.param_specs(), jax.random.PRNGKey(0)), L)
    strat = ST.get_strategy("sd_psgd")
    state = ST.init_state(strat, params, adam())
    step = jax.jit(ST.make_train_step(strat, model.loss_fn, adam(),
                                      constant(2e-3), n_learners=L,
                                      with_consensus=True))
    ds = make_dataset(cfg, seq_len=64, batch=2 * L, seed=0)
    for k in range(60):
        state, m = step(state, ds.batch_at(k))
        if k % 10 == 0:
            print(f"step {k:3d}  loss {float(m['loss']):.3f}  "
                  f"consensus {float(m['consensus']):.2e}")

    # --- consensus model -> greedy decoding ------------------------------
    params = ST.average_learners(state["params"])
    prompt = jnp.asarray(ds.batch_at(999)["tokens"][:1, :16])
    logits, cache = model.prefill_fn(params, {"tokens": prompt},
                                     cache_len=32)
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    out = [int(tok[0, 0])]
    for i in range(8):
        logits, cache = model.decode_fn(params, cache, tok,
                                        jnp.int32(16 + i))
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        out.append(int(tok[0, 0]))
    print("greedy continuation:", out)


if __name__ == "__main__":
    main()
