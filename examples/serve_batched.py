"""Batched serving example: continuous-batching decode over the model zoo
(wraps repro.launch.serve; see that module for the slot/cache mechanics).

  PYTHONPATH=src python examples/serve_batched.py --arch mamba2-370m
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    main(sys.argv[1:] or ["--arch", "smollm-360m", "--requests", "6",
                          "--slots", "3", "--max-new", "12"])
