"""Fig. 4-style strategy comparison on one model: heldout loss + consensus
trajectories of SC/SD/AD-PSGD + BMUF, same data order and LR — optionally
over a compressed communication substrate (--wire/--topology/...; the
strategy × topology × wire matrix is in docs/strategies.md).

  PYTHONPATH=src python examples/strategy_comparison.py [--arch smollm-360m]
  # compressed wire, e.g. int8 mixing payloads under two strategies:
  PYTHONPATH=src python examples/strategy_comparison.py \
      --strategies ad_psgd,bmuf --wire int8 --steps 50
"""
import argparse

import jax
import numpy as np

from repro.configs import get_arch
from repro.core import strategies as ST
from repro.core.transport import Transport
from repro.data import make_dataset
from repro.models import build_model
from repro.optim.optimizers import sgd
from repro.optim.schedules import constant
from repro.sharding import init_spec_tree

DEFAULT_STRATEGIES = ("sc_psgd_replicated", "sd_psgd", "ad_psgd", "bmuf",
                      "ad_psgd_q8", "ad_psgd_exp")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="swb2000-blstm")
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--learners", type=int, default=4)
    ap.add_argument("--lr", type=float, default=0.3)
    ap.add_argument("--strategies", default=",".join(DEFAULT_STRATEGIES),
                    help="comma-separated subset to run")
    ap.add_argument("--topology", default="",
                    help="substrate topology override (default: each "
                         "strategy's own)")
    ap.add_argument("--wire", default="",
                    choices=["", "f32", "bf16", "int8", "topk"],
                    help="wire codec override for mixing payloads")
    ap.add_argument("--intra-wire", default="",
                    help="hierarchical: intra-pod codec")
    ap.add_argument("--pod-size", type=int, default=1)
    ap.add_argument("--topk-frac", type=float, default=0.01)
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    model = build_model(cfg)
    L = args.learners
    seq = 21 if cfg.family == "lstm" else 64
    ds = make_dataset(cfg, seq_len=seq, batch=4 * L, seed=0)
    heldout = [ds.batch_at(50_000 + i) for i in range(4)]

    print("strategy,step,heldout_loss,consensus,wire_mb")
    for name in args.strategies.split(","):
        strat = ST.get_strategy(name)
        transport = Transport(
            topology=args.topology or strat.topology,
            wire=args.wire or strat.wire,
            intra_wire=args.intra_wire or "f32",
            pod_size=args.pod_size,
            topk_frac=args.topk_frac)
        params = ST.stack_for_learners(
            init_spec_tree(model.param_specs(), jax.random.PRNGKey(0)), L)
        state = ST.init_state(strat, params, sgd(), transport=transport)
        step = jax.jit(ST.make_train_step(strat, model.loss_fn, sgd(),
                                          constant(args.lr), n_learners=L,
                                          with_consensus=True,
                                          transport=transport))
        for k in range(args.steps):
            state, m = step(state, ds.batch_at(k))
            if k % 25 == 0 or k == args.steps - 1:
                avg = ST.average_learners(state["params"])
                hl = float(np.mean([float(model.loss_fn(avg, hb))
                                    for hb in heldout]))
                print(f"{name},{k},{hl:.4f},{float(m['consensus']):.3e},"
                      f"{float(m['wire_bytes']) / 2 ** 20:.3f}",
                      flush=True)


if __name__ == "__main__":
    main()
