"""Fig. 4-style strategy comparison on one model: heldout loss + consensus
trajectories of SC/SD/AD-PSGD + BMUF, same data order and LR.

  PYTHONPATH=src python examples/strategy_comparison.py [--arch smollm-360m]
"""
import argparse

import jax
import numpy as np

from repro.configs import get_arch
from repro.core import strategies as ST
from repro.data import make_dataset
from repro.models import build_model
from repro.optim.optimizers import sgd
from repro.optim.schedules import constant
from repro.sharding import init_spec_tree


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="swb2000-blstm")
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--learners", type=int, default=4)
    ap.add_argument("--lr", type=float, default=0.3)
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    model = build_model(cfg)
    L = args.learners
    seq = 21 if cfg.family == "lstm" else 64
    ds = make_dataset(cfg, seq_len=seq, batch=4 * L, seed=0)
    heldout = [ds.batch_at(50_000 + i) for i in range(4)]

    print("strategy,step,heldout_loss,consensus")
    for name in ("sc_psgd_replicated", "sd_psgd", "ad_psgd", "bmuf",
                 "ad_psgd_q8", "ad_psgd_exp"):
        strat = ST.get_strategy(name)
        params = ST.stack_for_learners(
            init_spec_tree(model.param_specs(), jax.random.PRNGKey(0)), L)
        state = ST.init_state(strat, params, sgd())
        step = jax.jit(ST.make_train_step(strat, model.loss_fn, sgd(),
                                          constant(args.lr), n_learners=L,
                                          with_consensus=True))
        for k in range(args.steps):
            state, m = step(state, ds.batch_at(k))
            if k % 25 == 0 or k == args.steps - 1:
                avg = ST.average_learners(state["params"])
                hl = float(np.mean([float(model.loss_fn(avg, hb))
                                    for hb in heldout]))
                print(f"{name},{k},{hl:.4f},{float(m['consensus']):.3e}",
                      flush=True)


if __name__ == "__main__":
    main()
