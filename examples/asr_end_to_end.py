"""End-to-end driver (deliverable b): the paper's §V experiment at CPU
scale — train the BLSTM DNN-HMM acoustic model on synthetic SWB-style
frames with AD-PSGD, the paper's LR recipe, checkpointing, and heldout
evaluation.

  PYTHONPATH=src python examples/asr_end_to_end.py [--steps 300] [--full]

``--full`` uses the paper's exact architecture (6x1024 BLSTM, 32k CD
states, 260-d input, unroll 21) — slower but runnable on CPU.
"""
import argparse
import time

import jax
import numpy as np

from repro.checkpoint import restore, save
from repro.configs import get_arch
from repro.core import strategies as ST
from repro.data import make_dataset
from repro.data.pipeline import Prefetcher
from repro.models import build_model
from repro.optim.optimizers import sgd
from repro.optim.schedules import paper_recipe
from repro.sharding import init_spec_tree


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--learners", type=int, default=4)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_asr_ckpt")
    args = ap.parse_args()

    cfg = get_arch("swb2000-blstm")
    if not args.full:
        cfg = cfg.reduced()
    model = build_model(cfg)
    L = args.learners
    strat = ST.get_strategy("ad_psgd")

    params = ST.stack_for_learners(
        init_spec_tree(model.param_specs(), jax.random.PRNGKey(0)), L)
    state = ST.init_state(strat, params, sgd())
    spe = max(args.steps // 16, 1)
    step = jax.jit(ST.make_train_step(
        strat, model.loss_fn, sgd(),
        paper_recipe(steps_per_epoch=spe, base_lr=0.05, peak_lr=0.3),
        n_learners=L, with_consensus=True), donate_argnums=(0,))

    batch = 4 * L if not args.full else 16 * L
    ds = make_dataset(cfg, seq_len=21, batch=batch, seed=0)
    heldout = [ds.batch_at(100_000 + i) for i in range(4)]
    pf = Prefetcher(ds)

    start = 0
    try:
        state, start = restore(args.ckpt_dir, state)
        print(f"resumed from step {start}")
    except (FileNotFoundError, AssertionError):
        pass

    t0 = time.time()
    for k in range(start, args.steps):
        state, m = step(state, pf.next())
        if k % 25 == 0:
            avg = ST.average_learners(state["params"])
            hl = float(np.mean([float(model.loss_fn(avg, hb))
                                for hb in heldout]))
            print(f"step {k:5d}  train {float(m['loss']):.3f}  "
                  f"heldout {hl:.3f}  consensus "
                  f"{float(m['consensus']):.2e}  ({time.time()-t0:.0f}s)",
                  flush=True)
        if (k + 1) % 100 == 0:
            save(args.ckpt_dir, k + 1, state)
    pf.close()
    save(args.ckpt_dir, args.steps, state)
    avg = ST.average_learners(state["params"])
    hl = float(np.mean([float(model.loss_fn(avg, hb)) for hb in heldout]))
    print(f"final heldout CE {hl:.4f} "
          f"(uniform = {np.log(cfg.vocab):.2f}); "
          f"checkpoint -> {args.ckpt_dir}")

    # the paper's third axis: recognition quality of the consensus model
    # (masked FER + greedy/beam TER; docs/decoding.md conventions)
    from repro.launch.evaluate import evaluate_params

    m = evaluate_params(cfg, avg, batches=2, batch=batch, seq_len=21,
                        var_len=True)
    print(f"recognition: FER {m['fer']:.3f}  TER greedy "
          f"{m['ter_greedy']:.3f}  beam{m['beam']} {m['ter_beam']:.3f}  "
          f"({m['frames_per_s']:.0f} frames/s, "
          f"{m['decoded_tok_per_s']:.0f} tok/s)")


if __name__ == "__main__":
    main()
