"""Roofline table (deliverable g) from the dry-run artifacts.

Reads experiments/dryrun/*.json and emits the §Roofline markdown table:
per (arch × shape × mesh) the three terms, the dominant bottleneck, the
MODEL_FLOPS/HLO_FLOPS usefulness ratio, and a one-line lever.

Usage: PYTHONPATH=src python -m benchmarks.roofline [--mesh pod_16x16]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")

LEVERS = {
    ("compute",): "raise arithmetic intensity (larger per-chip tiles, less "
                  "remat recompute)",
    ("memory",): "cut HBM round-trips: flash-attention fusion, "
                 "model-axis sequence sharding of attention, bf16 "
                 "intermediates",
    ("collective",): "overlap/shrink collectives: partial (ring) mixing, "
                     "reduce-scatter grads, fewer re-gathers",
}


def lever_for(rec):
    dom = rec["roofline"]["dominant"]
    if dom == "memory" and rec["kind"] in ("train", "prefill") \
            and rec["arch"] != "mamba2-370m":
        return ("attention traffic is replicated over the model axis in "
                "the baseline; shard q-chunks (sequence parallel) and/or "
                "use the Pallas flash kernel")
    if dom == "memory" and "moe" in rec["arch"]:
        return "dispatch one-hot tensors dominate; shrink routing groups"
    return LEVERS[(dom,)]


def load(mesh: str):
    recs = []
    for f in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*__{mesh}.json"))):
        recs.append(json.load(open(f)))
    return recs


def fmt_row(r):
    if r["status"] != "ok":
        return (f"| {r['arch']} | {r['shape']} | — | — | — | "
                f"{r['status'].upper()}: {r.get('reason','')[:40]} | — | — |")
    rf = r["roofline"]
    ratio = r.get("model_flops_ratio", 0.0)
    return ("| {arch} | {shape} | {c:.3e} | {m:.3e} | {n:.3e} | {dom} "
            "| {ratio:.3f} | {lever} |").format(
        arch=r["arch"], shape=r["shape"], c=rf["compute_s"],
        m=rf["memory_s"], n=rf["collective_s"], dom=rf["dominant"],
        ratio=ratio, lever=lever_for(r)[:80])


def table(mesh: str) -> str:
    recs = load(mesh)
    lines = [
        f"### Roofline — mesh `{mesh}` "
        f"({recs[0]['chips'] if recs and recs[0].get('chips') else '?'} chips, "
        "v5e: 197 TF bf16 / 819 GB/s HBM / 50 GB/s ICI)",
        "",
        "| arch | shape | compute (s) | memory (s) | collective (s) | "
        "dominant | MODEL/HLO flops | what would move the dominant term |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        lines.append(fmt_row(r))
    return "\n".join(lines)


def compare(mesh: str = "pod_16x16") -> str:
    """Baseline vs §Perf-optimized bound per pair, sorted by speedup."""
    import math

    base = {(r["arch"], r["shape"]): r for r in load(mesh)
            if r["status"] == "ok"}
    opt = {(r["arch"], r["shape"]): r for r in load(mesh + "_opt")
           if r["status"] == "ok"}
    rows, logs = [], []
    for key in sorted(base):
        if key not in opt:
            continue
        rb = base[key]["roofline"]["bound_s"]
        ro = opt[key]["roofline"]["bound_s"]
        sp = rb / ro
        logs.append(math.log(sp))
        rows.append((sp, key, rb, ro))
    rows.sort(reverse=True)
    lines = ["### Baseline vs optimized (§Perf overlay) — dominant-term "
             f"bound, mesh `{mesh}`", "",
             "| speedup | arch | shape | baseline (s) | optimized (s) |",
             "|---|---|---|---|---|"]
    for sp, (a, s), rb, ro in rows:
        lines.append(f"| {sp:.2f}x | {a} | {s} | {rb:.3f} | {ro:.3f} |")
    gm = math.exp(sum(logs) / len(logs)) if logs else 0.0
    lines.append("")
    lines.append(f"**geomean speedup: {gm:.2f}x over {len(logs)} pairs**")
    return "\n".join(lines)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod_16x16")
    ap.add_argument("--write", default="")
    ap.add_argument("--compare", action="store_true",
                    help="baseline vs *_opt speedup table")
    args = ap.parse_args(argv)
    out = compare(args.mesh) if args.compare else table(args.mesh)
    print(out)
    if args.write:
        with open(args.write, "w") as f:
            f.write(out + "\n")


if __name__ == "__main__":
    main()
