"""Projected roofline with the Pallas flash-attention kernel.

The TPU kernel (kernels/flash_attention.py) cannot be lowered by the CPU
dry-run backend, but its HBM effect is boundable by measurement:

  floor      = memory term of the SAME program with attention ablated
               (o := q — zero score traffic), measured via dryrun.run_one
  flash_adds = one read of Q/K/V + one write of O per layer (the kernel's
               only HBM traffic; VMEM holds the online-softmax state)

  projected  = floor + flash_adds / HBM_bw

Usage: PYTHONPATH=src python -m benchmarks.flash_projection
"""
from __future__ import annotations

import os

os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))


def project(arch: str, shape_name: str):
    import dataclasses

    from repro.analysis.roofline import HW
    from repro.configs import get_arch, get_shape
    from repro.launch.dryrun import run_one

    cfg = get_arch(arch).optimized()
    shape = get_shape(shape_name)
    full = run_one(arch, shape_name, multi_pod=False, opt=True,
                   cfg_override=cfg)

    class _KI(str):
        pass

    # ablated lowering: same program, attention score paths removed
    import repro.launch.dryrun as DR
    orig = DR.build_prefill_dryrun

    def ablated(cfg_, mesh, rules, shp):
        from repro.models import build_model
        from repro.sharding import spec_tree_to_sds
        model = build_model(cfg_)

        def step(params, batch):
            return model.prefill_fn(params, batch, cache_len=shp.seq_len,
                                    kernel_impl="ablate_attn")

        params = spec_tree_to_sds(model.param_specs(), rules)
        batch = spec_tree_to_sds(model.input_specs(shp, "prefill"), rules)
        return step, (params, batch), {"strategy": "serve-ablated"}

    DR.build_prefill_dryrun = ablated
    try:
        floor = run_one(arch, shape_name, multi_pod=False, opt=True,
                        cfg_override=cfg)
    finally:
        DR.build_prefill_dryrun = orig

    # flash kernel's own HBM traffic per device (fwd): q,k,v read + o write
    B_loc = shape.global_batch // 16
    S = shape.seq_len
    qo = 2 * B_loc * (S // 16) * cfg.n_heads * cfg.head_dim * 2  # q + o (seq-sharded)
    kv = 2 * B_loc * S * cfg.n_kv_heads * cfg.head_dim * 2       # k + v
    flash_bytes = (qo + kv) * cfg.n_layers
    proj = floor["roofline"]["memory_s"] + flash_bytes / HW.hbm_bw
    return {
        "arch": arch, "shape": shape_name,
        "optimized_memory_s": full["roofline"]["memory_s"],
        "ablated_floor_s": floor["roofline"]["memory_s"],
        "flash_kernel_traffic_s": flash_bytes / HW.hbm_bw,
        "projected_memory_s": proj,
        "projected_speedup_vs_optimized":
            full["roofline"]["memory_s"] / proj,
    }


def main():
    for arch, shape in (("granite-moe-3b-a800m", "prefill_32k"),
                        ("phi3-medium-14b", "prefill_32k")):
        r = project(arch, shape)
        print(f"{arch} {shape}: optimized {r['optimized_memory_s']:.1f}s -> "
              f"projected-with-flash {r['projected_memory_s']:.1f}s "
              f"(floor {r['ablated_floor_s']:.1f}s + kernel "
              f"{r['flash_kernel_traffic_s']:.3f}s) = "
              f"{r['projected_speedup_vs_optimized']:.1f}x")


if __name__ == "__main__":
    main()
