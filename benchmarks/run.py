"""Benchmark harness entry point — one function per paper table/figure.

Prints ``name,value,derived`` CSV (value is seconds, speedup-x, or the
table's native unit; see each bench's docstring).

  PYTHONPATH=src python -m benchmarks.run [--only fig4]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def all_benches():
    from benchmarks import paper_tables as T

    return [
        ("table1", T.bench_table1),
        ("fig4_convergence", T.bench_fig4_convergence),
        ("fig4_speedup", T.bench_fig4_speedup),
        ("table2_straggler", T.bench_table2_straggler),
        ("table3_hring", T.bench_table3_hring),
        ("fig5_load_balance", T.bench_fig5_load_balance),
        ("compression", T.bench_compression),
        ("comm_matrix", _comm_matrix),
        ("kernel_microbench", _kernel_microbench),
        ("varlen_bucketing", _varlen_bucketing),
        ("faults", _faults),
        ("longseq", _longseq),
        ("decode_microbench", _decode_microbench),
        ("decode_wer", T.bench_decode_wer),
        ("serve_microbench", _serve_microbench),
        ("paged_kv", _paged_microbench),
        ("load_capacity", _load_capacity),
        ("obs_overhead", _obs_overhead),
    ]


def _comm_matrix():
    """Communication/computation tradeoff per (strategy × wire) cell —
    the substrate counterpart of the paper's §IV-D/§V tables.  For each
    strategy's default topology and each wire codec: exact wire MB sent
    per learner per mixing round on the paper's BLSTM param tree
    (Transport.wire_bytes, L=16; hring as 4 pods of 4 with BOTH stages
    coded by the cell's wire), the ratio vs the f32 wire, and the
    perfsim AD-PSGD-style speedup with that payload (calibrated
    compute; bmuf amortizes its sync over the 16-step block)."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from benchmarks.perfsim import (ClusterSpec, calibrate_blstm,
                                    simulate_async, simulate_sync,
                                    wire_payload_bytes)
    from repro.configs import get_arch
    from repro.core import strategies as ST
    from repro.core.transport import Transport
    from repro.models import build_model

    L = 16
    specs = build_model(get_arch("swb2000-blstm")).param_specs()
    stacked = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((L,) + tuple(s.shape), jnp.float32),
        specs)

    t_comp, model_bytes, _ = calibrate_blstm(160)
    n_batches = 4096
    t_single = t_comp * n_batches

    rows = []
    f32_ref = {}
    for strat_name in ("sc_psgd_replicated", "ad_psgd", "bmuf", "hring"):
        strat = ST.get_strategy(strat_name)
        for wire in ("f32", "bf16", "int8", "topk"):
            kw = dict(topology=strat.topology, wire=wire, topk_frac=0.01)
            if strat.topology == "hierarchical":
                # code both stages with the cell's wire (mixed intra/inter
                # wires are a config choice, e.g. bf16 intra + topk inter)
                kw.update(pod_size=4, intra_wire=wire)
            tr = Transport(**kw)
            per_round = tr.wire_bytes(stacked)
            per_step = (per_round / strat.block_size if strat.block_size
                        else per_round)
            rows.append((f"comm/wire_mb_per_step/{strat_name}/{wire}",
                         per_step / 2 ** 20,
                         "MB sent per learner per step"
                         + (f" (sync/{strat.block_size} amortized)"
                            if strat.block_size else "")))
            if wire == "f32":
                f32_ref[strat_name] = per_step
            else:
                rows.append((f"comm/wire_ratio_vs_f32/{strat_name}/{wire}",
                             per_step / f32_ref[strat_name],
                             "acceptance: int8 <= 0.27"))
            # perfsim wall-clock with this payload on the wire
            payload = wire_payload_bytes(model_bytes, wire)
            spec = ClusterSpec(L, np.full(L, t_comp), payload)
            if strat_name == "sc_psgd_replicated":
                t, _ = simulate_sync(spec, n_batches)
            elif strat_name == "bmuf":
                # allreduce every block_size-th step only
                t_sync, _ = simulate_sync(spec, n_batches)
                t = (t_sync - t_comp * n_batches / L) / strat.block_size \
                    + t_comp * n_batches / L
            else:
                t, _ = simulate_async(spec, n_batches)
            rows.append((f"comm/sim_speedup/{strat_name}/{wire}",
                         t_single / t, f"L={L} perfsim"))
    return rows


def _longseq():
    """Long-utterance trajectory across T in {500, 2000, 8000} (paper
    shape B=256, H=512/direction): residual-stash HBM of the training
    forward, unchunked vs --seq-chunk (accounting single-source:
    kernels.lstm_cell.stash_bytes, auto_tile picks (block_b, K) from the
    12MB default budget); masked-BLSTM valid-frames/s through the jitted
    jax-scan grad at a reduced shape; and a chunked-vs-unchunked pallas
    fwd+bwd timing (interpret mode — relative trajectory, not TPU
    numbers)."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.lstm_cell import (auto_tile, blstm_sequence,
                                         stash_bytes)

    rows = []
    B, H, D = 256, 512, 260
    for T in (500, 2000, 8000):
        full = stash_bytes(B, T, H, n_dir=2)
        _, K = auto_tile(B, T, D, H, 2, n_dir=2, seq_chunk=-1)
        chunked = stash_bytes(B, T, H, n_dir=2, seq_chunk=K)
        rows.append((f"longseq/stash_mb_T{T}_full", full / 2 ** 20,
                     "MB fwd residual stash, f32, both directions"))
        rows.append((f"longseq/stash_mb_T{T}_chunked", chunked / 2 ** 20,
                     f"MB boundary carries, seq_chunk={K}"))
        rows.append((f"longseq/stash_ratio_T{T}", chunked / full,
                     "chunked/unchunked (acceptance: <= 0.25)"))

    # valid-frames/s of the masked fwd+bwd at long T (jax scan path; the
    # pallas trajectory below is interpret-mode and not frames/s-meaningful)
    key = jax.random.PRNGKey(0)
    Br, Dr, Hr = 8, 16, 32
    wf = [(jax.random.normal(key, s, jnp.float32) * 0.3).astype(jnp.float32)
          for s in ((Dr, 4 * Hr), (Hr, 4 * Hr), (4 * Hr,))]
    wb = [(jax.random.normal(key, s, jnp.float32) * 0.3).astype(jnp.float32)
          for s in ((Dr, 4 * Hr), (Hr, 4 * Hr), (4 * Hr,))]
    from repro.kernels import ref

    for T in (500, 2000):
        x = jax.random.normal(key, (Br, T, Dr), jnp.float32)
        lens = jnp.clip(jax.random.randint(key, (Br,), T // 2, T), 1, T)

        def loss(wxf, whf, bf, wxb, whb, bb, x):
            y = ref.blstm_ref(wxf, whf, bf, wxb, whb, bb, x, lengths=lens)
            return jnp.mean(jnp.square(y.astype(jnp.float32)))

        g = jax.jit(jax.value_and_grad(loss, argnums=tuple(range(7))))
        args = (*wf, *wb, x)
        jax.block_until_ready(g(*args))       # compile
        t0 = time.perf_counter()
        for _ in range(3):
            jax.block_until_ready(g(*args))
        dt = (time.perf_counter() - t0) / 3
        rows.append((f"longseq/jax_valid_kframes_per_s_T{T}",
                     float(lens.sum()) / dt / 1e3,
                     "masked fwd+bwd, jax scan, cpu"))

    # chunked vs unchunked pallas fwd+bwd (interpret): tracks the relative
    # cost of the extra recompute forward on a small shape
    Bk, Tk, Kk = 4, 64, 16
    x = jax.random.normal(key, (Bk, Tk, Dr), jnp.float32)
    lens = jnp.array([64, 40, 23, 9], jnp.int32)
    for name, chunk in (("unchunked", 0), (f"chunk{Kk}", Kk)):
        def loss(wxf, whf, bf, wxb, whb, bb, x, chunk=chunk):
            y = blstm_sequence(wxf, whf, bf, wxb, whb, bb, x, lens,
                               interpret=True, seq_chunk=chunk)
            return jnp.mean(jnp.square(y.astype(jnp.float32)))

        g = jax.jit(jax.value_and_grad(loss, argnums=tuple(range(7))))
        args = (*wf, *wb, x)
        jax.block_until_ready(g(*args))
        t0 = time.perf_counter()
        for _ in range(2):
            jax.block_until_ready(g(*args))
        rows.append((f"longseq/pallas_interp_fwd_bwd_{name}_ms",
                     (time.perf_counter() - t0) / 2 * 1e3,
                     f"B={Bk} T={Tk} interpret cpu"))
    return rows


def _decode_microbench():
    """Greedy vs CTC prefix-beam decode on synthetic peaky posteriors
    (planted token paths + Gaussian noise, variable lengths): TER of
    best-path vs the max- and sum-semiring beams (the sum beam recovers
    mass spread over alignments that best-path drops), plus decode
    latency/frames-s of the jitted jax path vs the Pallas inner-step
    kernel in interpret mode (relative trajectory, not TPU numbers)."""
    import functools

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.decode import beam_search
    from repro.eval.metrics import (collapse_labels, greedy_ctc_decode,
                                    token_error_rate)

    B, T, V, K = 8, 40, 64, 8
    rng = np.random.default_rng(0)
    path = rng.integers(0, V, size=(B, T)).astype(np.int32)
    path[rng.random((B, T)) < 0.5] = 0            # blank-dominated frames
    lengths = rng.integers(T // 2, T + 1, size=B).astype(np.int32)
    logits = (2.0 * (np.arange(V)[None, None, :] == path[:, :, None])
              + rng.normal(0.0, 1.0, size=(B, T, V))).astype(np.float32)
    refs = collapse_labels(path, lengths, blank=0)

    rows = []
    hyp_g = greedy_ctc_decode(logits, lengths)
    rows.append(("decode/ter_greedy", token_error_rate(refs, hyp_g),
                 "best-path baseline"))
    for semiring in ("max", "sum"):
        toks, lens, _ = beam_search(jnp.asarray(logits),
                                    jnp.asarray(lengths), beam=K,
                                    semiring=semiring)
        toks, lens = np.asarray(toks), np.asarray(lens)
        hyp = [list(map(int, r[:n])) for r, n in zip(toks, lens)]
        rows.append((f"decode/ter_beam{K}_{semiring}",
                     token_error_rate(refs, hyp),
                     "acceptance: sum <= greedy"))

    for impl in ("jax", "pallas"):
        fn = jax.jit(functools.partial(
            beam_search, beam=K, semiring="sum", impl=impl,
            interpret=True))
        args = (jnp.asarray(logits), jnp.asarray(lengths))
        jax.block_until_ready(fn(*args))          # compile
        n = 3 if impl == "jax" else 1
        t0 = time.time()
        for _ in range(n):
            jax.block_until_ready(fn(*args))
        dt = (time.time() - t0) / n
        rows.append((f"decode/beam_ms_{impl}", dt * 1e3,
                     f"B={B} T={T} V={V} K={K}"
                     + (" interpret cpu" if impl == "pallas" else " cpu")))
        if impl == "jax":
            rows.append(("decode/beam_kframes_per_s",
                         float(lengths.sum()) / dt / 1e3,
                         "valid kframes/s, jitted jax beam"))
    return rows


def _varlen_bucketing():
    """Fixed-pad vs length-bucketed batching at the synthetic SWB-like
    length distribution (paper §IV-D loader; Zhang et al. 1907.05701):
    padding efficiency (valid/padded frames) and valid-frames/s through
    the jitted masked BLSTM loss on CPU.  Both modes see the SAME
    utterance stream — only the padding waste differs."""
    import dataclasses

    import jax

    from repro.configs import get_arch
    from repro.data import make_dataset
    from repro.models import build_model
    from repro.sharding import init_spec_tree

    cfg = dataclasses.replace(get_arch("swb2000-blstm").reduced(),
                              n_layers=1, lstm_hidden=32,
                              lstm_bottleneck=16, input_dim=32, vocab=64)
    model = build_model(cfg)
    params = init_spec_tree(model.param_specs(), jax.random.PRNGKey(0))
    loss = jax.jit(lambda p, b: model.loss_fn(p, b))

    rows = []
    for mode, bucket in (("fixed_pad", False), ("bucketed", True)):
        ds = make_dataset(cfg, seq_len=64, batch=8, seed=0,
                          var_len=True, bucket=bucket)
        batches = [ds.batch_at(s) for s in range(16)]   # one shuffle window
        valid = sum(int(b["lengths"].sum()) for b in batches)
        padded = sum(b["features"].shape[0] * b["features"].shape[1]
                     for b in batches)
        for b in batches:                               # compile all shapes
            jax.block_until_ready(loss(params, b))
        t0 = time.perf_counter()
        for b in batches:
            jax.block_until_ready(loss(params, b))
        dt = time.perf_counter() - t0
        rows.append((f"varlen/{mode}_pad_efficiency", valid / padded,
                     "valid/padded frames"))
        rows.append((f"varlen/{mode}_kframes_per_s", valid / dt / 1e3,
                     "valid kframes/s cpu jax"))
    return rows


def _faults():
    """Robustness under one fault description, two views
    (docs/fault_tolerance.md):

    **Convergence** — the reduced BLSTM trained for real at L=8 under
    AD-PSGD with staleness-aware elastic mixing, clean vs the canonical
    fault plan (learner 0 straggling 4×, learner 1 crashing mid-run and
    rejoining): final train loss (mean of the last 10 steps), the
    faulty/clean ratio (acceptance: ≤ 1.10), and the active-set
    consensus distance under faults.

    **Throughput** — the SAME plan through the pod-scale discrete-event
    simulator (perfsim, calibrated BLSTM compute) at N = 8..1024: the
    gang-scheduled sync baseline's slowdown (≥ 2× — every barrier waits
    for the 4× straggler, and the crash halts the gang) vs the elastic
    async ring's, whose survivors keep stepping at their own rate."""
    import dataclasses

    import jax
    import numpy as np

    from benchmarks.perfsim import (calibrate_blstm, simulate_async_faulty,
                                    simulate_sync_faulty, straggler_spec)
    from repro.configs import get_arch
    from repro.core import strategies as ST
    from repro.core.faults import Departure, FaultPlan, Straggler
    from repro.core.transport import Transport
    from repro.data import make_dataset
    from repro.models import build_model
    from repro.optim.optimizers import sgd
    from repro.optim.schedules import constant
    from repro.sharding import init_spec_tree

    rows = []

    # -- convergence: real training, clean vs faulty -------------------
    L, steps, batch = 8, 80, 16
    cfg = dataclasses.replace(get_arch("swb2000-blstm").reduced(),
                              n_layers=1, lstm_hidden=32,
                              lstm_bottleneck=16, input_dim=32, vocab=64)
    model = build_model(cfg)
    strategy = ST.get_strategy("ad_psgd")
    transport = Transport(topology="ring", staleness_lambda=0.2)
    ds = make_dataset(cfg, seq_len=21, batch=batch, seed=0)
    plans = {
        "clean": FaultPlan(L),
        "faulty": FaultPlan(L, stragglers=(Straggler(0, 4),),
                            departures=(Departure(1, 25, 50),)),
    }
    final = {}
    for name, plan in plans.items():
        params = ST.stack_for_learners(
            init_spec_tree(model.param_specs(), jax.random.PRNGKey(0)), L)
        state = ST.init_elastic_state(strategy, params, sgd(), transport)
        step = jax.jit(ST.make_elastic_train_step(
            strategy, model.loss_fn, sgd(), constant(0.05),
            n_learners=L, transport=transport, with_consensus=True))
        losses = []
        for k in range(steps):
            state, m = step(state, ds.batch_at(k), plan.step_inputs(k))
            losses.append(m["loss"])
        final[name] = float(np.mean([float(x) for x in losses[-10:]]))
        rows.append((f"faults/ad_psgd_final_loss/{name}", final[name],
                     f"mean last-10 train loss, L={L}, {plan.describe()}"))
    rows.append(("faults/ad_psgd_loss_ratio/faulty_over_clean",
                 final["faulty"] / final["clean"],
                 "acceptance: <= 1.10 (staleness-aware elastic mixing)"))
    rows.append(("faults/ad_psgd_consensus/faulty",
                 float(m["consensus"]),
                 "active-set consensus distance at the last faulty step"))

    # -- throughput: pod-scale wall-clock under the same plan ----------
    t_comp, model_bytes, _ = calibrate_blstm(160)
    for N in (8, 64, 256, 1024):
        plan = FaultPlan(N, stragglers=(Straggler(0, 4),),
                         departures=(Departure(1, 8, 12),))
        clean = FaultPlan(N)
        spec = straggler_spec(N, t_comp, model_bytes)
        n_batches = 16 * N
        for kind, sim, kw in (
                ("sync", simulate_sync_faulty, {}),
                ("sync_elastic", simulate_sync_faulty, {"elastic": True}),
                ("async", simulate_async_faulty, {})):
            t_clean, _ = sim(spec, n_batches, clean, **kw)
            t_fault, counts = sim(spec, n_batches, plan, **kw)
            slow = t_fault / t_clean
            fps = counts.sum() * 160 * 21 / t_fault
            rows.append((f"faults/{kind}_slowdown/N{N}", slow,
                         "faulty/clean makespan"
                         + (" (acceptance: >= 2.0)"
                            if kind == "sync" else "")))
            rows.append((f"faults/{kind}_frames_per_s/N{N}", fps / 1e6,
                         "effective Mframes/s under the fault plan"))
    return rows


def _kernel_microbench():
    """us/call of the pure-JAX compute paths on CPU (reduced shapes) —
    relative regression tracking, not TPU numbers."""
    import jax
    import jax.numpy as jnp

    from repro.kernels import ref
    from repro.models.attention import attn_seq
    from repro.models.ssm import ssd_chunked

    rows = []
    key = jax.random.PRNGKey(0)

    q = jax.random.normal(key, (2, 512, 8, 64), jnp.float32)
    k = jax.random.normal(key, (2, 512, 2, 64), jnp.float32)
    v = jax.random.normal(key, (2, 512, 2, 64), jnp.float32)
    for name, fn in (
        ("attn_naive_ref", jax.jit(lambda: ref.attention_ref(q, k, v))),
        ("attn_chunked", jax.jit(lambda: attn_seq(q, k, v, causal=True,
                                                  q_chunk=128))),
    ):
        fn()  # compile
        t0 = time.perf_counter()
        for _ in range(5):
            jax.block_until_ready(fn())
        rows.append((f"kernels/{name}", (time.perf_counter() - t0) / 5 * 1e6,
                     "us/call cpu"))

    # fused (B)LSTM kernel: jax scan vs pallas interpret, fwd and fwd+bwd,
    # on reduced shapes — relative trajectory tracking for the training
    # hot path (real TPU numbers come from the compiled kernel).
    from repro.kernels.lstm_cell import blstm_sequence

    B, T, D, H = 4, 8, 16, 16
    wxf, whf = (jax.random.normal(key, (D, 4 * H)) * 0.3,
                jax.random.normal(key, (H, 4 * H)) * 0.3)
    wxb, whb = (jax.random.normal(key, (D, 4 * H)) * 0.3,
                jax.random.normal(key, (H, 4 * H)) * 0.3)
    bf = bb = jnp.zeros((4 * H,), jnp.float32)
    xl = jax.random.normal(key, (B, T, D), jnp.float32)

    def _loss(fn):
        def loss(wxf, whf, bf, wxb, whb, bb, x):
            return jnp.mean(jnp.square(fn(wxf, whf, bf, wxb, whb, bb,
                                          x).astype(jnp.float32)))
        return loss

    pallas_fwd = lambda *a: blstm_sequence(*a, interpret=True)
    grad_ref = jax.value_and_grad(_loss(ref.blstm_ref),
                                  argnums=tuple(range(7)))
    grad_pl = jax.value_and_grad(_loss(pallas_fwd), argnums=tuple(range(7)))
    args = (wxf, whf, bf, wxb, whb, bb, xl)
    # operands passed as jit ARGUMENTS (not closed-over constants) so XLA
    # cannot constant-fold the measured work away at compile time
    for name, fn in (
        ("lstm_fwd_jax", jax.jit(ref.blstm_ref)),
        ("lstm_fwd_pallas_interp", jax.jit(pallas_fwd)),
        ("lstm_fwd_bwd_jax", jax.jit(grad_ref)),
        ("lstm_fwd_bwd_pallas_interp", jax.jit(grad_pl)),
    ):
        fn(*args)  # compile
        t0 = time.perf_counter()
        for _ in range(5):
            jax.block_until_ready(fn(*args))
        rows.append((f"kernels/{name}", (time.perf_counter() - t0) / 5 * 1e6,
                     "us/call cpu"))

    x = jax.random.normal(key, (2, 1024, 8, 64), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(key, (2, 1024, 8)))
    A = -jnp.exp(jax.random.normal(key, (8,)) * 0.5)
    Bm = jax.random.normal(key, (2, 1024, 8, 32), jnp.float32)
    Cm = jax.random.normal(key, (2, 1024, 8, 32), jnp.float32)
    for name, fn in (
        ("ssd_sequential_ref", jax.jit(lambda: ref.ssd_ref(x, dt, A, Bm,
                                                           Cm)[0])),
        ("ssd_chunked", jax.jit(lambda: ssd_chunked(x, dt, A, Bm, Cm,
                                                    256)[0])),
    ):
        fn()
        t0 = time.perf_counter()
        for _ in range(5):
            jax.block_until_ready(fn())
        rows.append((f"kernels/{name}", (time.perf_counter() - t0) / 5 * 1e6,
                     "us/call cpu"))
    return rows


def _serve_microbench():
    """Serving hot-path microbench (``--only serve``), the decode
    counterpart of ``--only decode``: (a) single-token decode-attention
    latency, jax vs the Pallas streaming kernel (interpret mode on CPU —
    relative trajectory, not TPU numbers), across cache lengths S that
    cross many S-tiles; (b) prefix-beam throughput at top-C ∈ {V, 64,
    16} vocab pruning (C=V is the unpruned baseline; the planted-path
    posteriors keep the per-frame support well inside C=16, so all
    three decode identically); (c) the VMEM accounting behind both —
    ``beam_cand_bytes`` shows the beam candidate working set scaling
    with C, not V, and ``decode_attn_vmem_bytes`` shows the attention
    resident set independent of S."""
    import functools

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.decode import beam_search
    from repro.decode.kernel import auto_block_b_decode, beam_cand_bytes
    from repro.kernels.decode_attention import (auto_block_s_decode,
                                                decode_attn_vmem_bytes)
    from repro.models import attention as A

    rows = []

    # (a) decode-attn latency: single-row q vs (B, S, KV, E) cache
    B, H, KV, E = 4, 8, 2, 64
    M = H // KV
    key = jax.random.PRNGKey(0)
    for S in (512, 2048, 8192):
        q = jax.random.normal(key, (B, 1, H, E), jnp.float32)
        kc = jax.random.normal(jax.random.PRNGKey(1), (B, S, KV, E),
                               jnp.float32)
        vc = jax.random.normal(jax.random.PRNGKey(2), (B, S, KV, E),
                               jnp.float32)
        pos = jnp.int32(S - 1)
        for impl in ("jax", "pallas"):
            fn = jax.jit(functools.partial(A.attn_decode, impl=impl,
                                           interpret=True))
            jax.block_until_ready(fn(q, kc, vc, pos))      # compile
            n = 5 if impl == "jax" else 1
            t0 = time.time()
            for _ in range(n):
                jax.block_until_ready(fn(q, kc, vc, pos))
            dt = (time.time() - t0) / n
            bs = auto_block_s_decode(S, M, E)
            rows.append((f"serve/decode_attn_ms_{impl}_S{S}", dt * 1e3,
                         f"B={B} H={H} KV={KV} E={E}"
                         + (f" block_s={bs} interpret cpu"
                            if impl == "pallas" else " cpu")))
    rows.append(("serve/decode_attn_vmem_kb",
                 decode_attn_vmem_bytes(auto_block_s_decode(8192, M, E),
                                        M, E) / 1024,
                 "resident set per grid program — independent of S"))

    # (b) beam throughput at top-C ∈ {V, 64, 16}
    B, T, V, K = 8, 32, 512, 8
    rng = np.random.default_rng(0)
    path = rng.integers(0, 8, size=(B, T)).astype(np.int32)  # tiny support
    path[rng.random((B, T)) < 0.5] = 0
    logits = (4.0 * (np.arange(V)[None, None, :] == path[:, :, None])
              + rng.normal(0.0, 0.5, size=(B, T, V))).astype(np.float32)
    base_toks = None
    for C in (V, 64, 16):
        fn = jax.jit(functools.partial(beam_search, beam=K,
                                       semiring="sum", topc=C))
        toks, lens, _ = jax.block_until_ready(fn(jnp.asarray(logits)))
        t0 = time.time()
        for _ in range(3):
            out = jax.block_until_ready(fn(jnp.asarray(logits)))
        dt = (time.time() - t0) / 3
        label = "V" if C == V else str(C)
        decoded = int(np.asarray(lens).sum())
        if base_toks is None:
            base_toks = np.asarray(toks)
            agree = "unpruned baseline"
        else:
            agree = ("identical to unpruned"
                     if np.array_equal(np.asarray(toks), base_toks)
                     else "DIVERGED from unpruned")
        rows.append((f"serve/beam_tok_per_s_C{label}",
                     decoded / max(dt, 1e-9),
                     f"B={B} T={T} V={V} K={K}, {agree}"))

    # (c) VMEM accounting: candidate working set scales with C, not V
    for C, label in ((0, "V"), (64, "64"), (16, "16")):
        kb = beam_cand_bytes(K, V, C) / 1024
        bb = auto_block_b_decode(1 << 20, K, V, topc=C)
        rows.append((f"serve/beam_cand_kb_C{label}", kb,
                     f"f32 KB per batch row (V={V} K={K}); "
                     f"auto block_b {bb}"))
    ratio = beam_cand_bytes(K, V) / beam_cand_bytes(K, V, 16)
    rows.append(("serve/beam_cand_shrink_C16", ratio,
                 "x smaller candidate VMEM vs unpruned — scales with C, "
                 "not V"))
    return rows


def _paged_microbench():
    """Paged-KV serving bench (``--only paged``): what the page pool
    buys at a FIXED HBM budget (docs/serving.md §KV paging).

    (a) HBM per request — a dense slot pins ``max_len`` cache positions
    regardless of the request; a paged request pins
    ``ceil((plen + max_new) / P)`` pages.  (b) Max concurrent requests
    at equal HBM, measured by admitting short requests into real
    servers until the typed ``pool_full`` — the acceptance bar is >= 4x
    the dense slot count.  (c) A further capacity uplift when prompts
    share a prefix (trie sharing makes the shared pages free).
    (d) Decode tok/s at EQUAL batch, dense vs paged (jax path; wall
    time of real reduced-model decode waves): paged attends only its
    allocated pages, so short requests are not slower despite the
    table indirection.  (e) The paged VMEM accounting row."""
    import time as _time

    import numpy as np

    from repro.configs import get_arch
    from repro.kernels.decode_attention import paged_attn_vmem_bytes
    from repro.launch.serve import PagedServer, Server
    from repro.serving.admission import POOL_FULL
    from repro.serving.kvpool import cdiv

    cfg = get_arch("smollm-360m").reduced()
    MAX_LEN, P, SLOTS_EQ = 64, 8, 2
    POOL_PAGES = SLOTS_EQ * MAX_LEN // P      # dense-equivalent HBM
    L, KV, E = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    PLEN, MAX_NEW = 3, 4                      # short-prompt workload
    kv_bytes = 2 * 2 * L * KV * E             # k+v, bf16, per position
    rows = []

    # (a) HBM per request
    dense_kb = MAX_LEN * kv_bytes / 1024
    paged_kb = cdiv(PLEN + MAX_NEW, P) * P * kv_bytes / 1024
    rows.append(("paged/hbm_kb_per_request_dense", dense_kb,
                 f"max_len={MAX_LEN} row, bf16 k+v, reduced arch"))
    rows.append(("paged/hbm_kb_per_request_paged", paged_kb,
                 f"ceil(({PLEN}+{MAX_NEW})/{P}) pages of {P}"))
    rows.append(("paged/hbm_shrink", dense_kb / paged_kb,
                 "x less HBM pinned per short request"))

    # (b) max concurrent requests at the fixed pool budget
    rng = np.random.default_rng(0)

    def fill(server, prompts):
        n = 0
        for i, prompt in enumerate(prompts):
            if server.admit(i, prompt, MAX_NEW).reason == POOL_FULL:
                break
            n += 1
        return n

    distinct = [rng.integers(0, cfg.vocab, size=PLEN)
                for _ in range(POOL_PAGES + SLOTS_EQ + 2)]
    dense_n = fill(Server(cfg, slots=SLOTS_EQ, max_len=MAX_LEN), distinct)
    paged_n = fill(PagedServer(cfg, pool_pages=POOL_PAGES, page_size=P,
                               max_len=MAX_LEN), distinct)
    rows.append(("paged/max_concurrent_dense", dense_n,
                 f"{SLOTS_EQ} slots x {MAX_LEN} positions"))
    rows.append(("paged/max_concurrent_paged", paged_n,
                 f"{POOL_PAGES} pages x {P} positions (equal HBM)"))
    rows.append(("paged/concurrency_gain", paged_n / max(dense_n, 1),
                 "x more in-flight short requests at equal HBM "
                 "(acceptance: >= 4x)"))

    # (c) shared-prefix capacity uplift (identical prompts, one page-
    # aligned prefix: the trie makes every prompt page after the first
    # request free)
    shared_prompt = rng.integers(0, cfg.vocab, size=2 * P)
    shared = [shared_prompt] * (POOL_PAGES + 2)
    shared_n = fill(PagedServer(cfg, pool_pages=POOL_PAGES, page_size=P,
                                max_len=MAX_LEN), shared)
    unshared_n = fill(PagedServer(cfg, pool_pages=POOL_PAGES, page_size=P,
                                  max_len=MAX_LEN, share=False), shared)
    rows.append(("paged/shared_prefix_capacity_uplift",
                 shared_n / max(unshared_n, 1),
                 f"{shared_n} vs {unshared_n} concurrent at plen={2*P} "
                 f"identical prompts (trie sharing on/off)"))

    # (d) decode tok/s at equal batch (jax path, wall time; dense slots
    # == paged in-flight so the batched wave shapes match)
    B, NT = 4, 12
    prompts = [rng.integers(0, cfg.vocab, size=8) for _ in range(B)]

    def tok_per_s(mk):
        best = 0.0
        for _ in range(3):                    # later runs: everything jitted
            server = mk()
            for i, prompt in enumerate(prompts):
                assert server.admit(i, prompt, NT + 1)
            t0 = _time.time()
            done = []
            while server.active.any():
                done += server.step()
            dt = _time.time() - t0
            toks = sum(len(o) for _, o in done) - B  # first token: prefill
            best = max(best, toks / max(dt, 1e-9))
            server.reset()
        return best

    dense_tps = tok_per_s(lambda: Server(cfg, slots=B, max_len=MAX_LEN))
    paged_tps = tok_per_s(lambda: PagedServer(
        cfg, pool_pages=POOL_PAGES, page_size=P, max_len=MAX_LEN))
    rows.append(("paged/tok_per_s_dense", dense_tps,
                 f"B={B} decode waves, jax path, wall"))
    rows.append(("paged/tok_per_s_paged", paged_tps,
                 f"B={B}, pages streamed per table (wall)"))
    rows.append(("paged/tok_per_s_ratio", paged_tps / max(dense_tps, 1e-9),
                 "paged/dense at equal batch (acceptance: >= 0.9)"))

    # (e) VMEM accounting at page granularity
    M = cfg.n_heads // KV
    rows.append(("paged/paged_attn_vmem_kb",
                 paged_attn_vmem_bytes(P, M, E, B * MAX_LEN // P) / 1024,
                 f"page tile {P} + prefetched (B={B}, W={MAX_LEN//P}) "
                 f"table SMEM"))
    return rows


def _load_capacity():
    """The closed-loop capacity report (``--only load``): for each
    (mode × kernel-impl × beam-topc) serving cell, bisect the max
    sustained QPS whose p99 first-token latency stays under the target
    (``repro.serving.sustained_capacity`` — docs/serving.md §Capacity
    report), and emit the SLO percentiles measured at that rate.

    Each probe replays the SAME seeded workload shape at a candidate
    arrival rate through a real server (real prefill/forward + decode
    compute; reduced shapes) in *virtual time*: per-operation service
    times come from a :class:`CostModel` pinned per cell (nominal
    scenarios — faster nominal decode for the pallas cells — NOT
    measured wall times), so the whole report is a pure function of the
    seed and reruns bit-identically row-for-row.  ``--wall`` runs of
    ``repro.launch.load`` are the measured counterpart."""
    import dataclasses

    from repro.configs import get_arch
    from repro.launch.serve import AsrServer, PagedServer, Server
    from repro.serving import (CostModel, Workload, make_payload,
                               sustained_capacity)

    P99_TARGET_S = 0.25
    SLOTS, MAX_LEN = 2, 24
    lm_cfg = get_arch("smollm-360m").reduced()
    asr_cfg = dataclasses.replace(get_arch("swb2000-blstm").reduced(),
                                  n_layers=1, lstm_hidden=32,
                                  lstm_bottleneck=16, input_dim=16,
                                  vocab=32, beam_width=3)

    def lm_server(impl):
        return Server(lm_cfg, slots=SLOTS, max_len=MAX_LEN,
                      kernel_impl=impl)

    def asr_server(impl, topc):
        return AsrServer(asr_cfg, slots=SLOTS, max_frames=MAX_LEN,
                         chunk=8, beam=3, kernel_impl=impl, topc=topc)

    # (cell, mode, server factory, nominal cost model, bisection iters):
    # pallas cells get a faster nominal decode wave (the kernels' point)
    # and fewer probes — interpret-mode compute is slow on CPU
    cells = [
        ("lm/jax", "lm", lambda: lm_server("jax"),
         CostModel(admit_s=0.080, wave_base_s=0.040, per_work_s=1e-3), 3),
        ("lm/pallas", "lm", lambda: lm_server("pallas"),
         CostModel(admit_s=0.056, wave_base_s=0.024, per_work_s=5e-4), 2),
        # paged page-pool server at the dense-equivalent HBM (SLOTS *
        # MAX_LEN positions = 6 pages of 8); same nominal costs as
        # lm/jax so the capacity delta is purely admission behaviour
        ("lm/paged", "lm",
         lambda: PagedServer(lm_cfg, pool_pages=6, page_size=8,
                             max_len=MAX_LEN),
         CostModel(admit_s=0.080, wave_base_s=0.040, per_work_s=1e-3), 2),
        ("asr/jax/topc0", "asr", lambda: asr_server("jax", 0),
         CostModel(admit_s=0.060, wave_base_s=0.040, per_work_s=1e-3), 3),
        ("asr/jax/topc8", "asr", lambda: asr_server("jax", 8),
         CostModel(admit_s=0.060, wave_base_s=0.024, per_work_s=5e-4), 3),
        ("asr/pallas/topc8", "asr", lambda: asr_server("pallas", 8),
         CostModel(admit_s=0.044, wave_base_s=0.014, per_work_s=2.5e-4), 2),
    ]

    rows = []
    for cell, mode, mk, cost, iters in cells:
        cfg = lm_cfg if mode == "lm" else asr_cfg
        w = Workload(qps=1.0, horizon=6.0, seed=0, len_median=8.0,
                     len_min=2, len_max=MAX_LEN - 1, patience=2.0,
                     deadline=1.0, max_new=6)
        payload_fn = lambda req: make_payload(
            req, mode=mode, vocab=cfg.vocab, input_dim=cfg.input_dim,
            seed=w.seed)
        q, s = sustained_capacity(mk(), w, payload_fn,
                                  p99_target_s=P99_TARGET_S,
                                  qps_lo=0.5, qps_hi=16.0, iters=iters,
                                  cost=cost)
        rows.append((f"load/max_qps/{cell}", q,
                     f"max sustained QPS at p99 first-token <= "
                     f"{P99_TARGET_S}s, virtual time, seed {w.seed}"))
        for metric in ("first_token", "final"):
            for pq, v in s[metric].items():
                rows.append((f"load/{metric}_{pq}/{cell}", v,
                             f"{metric} {pq} at max QPS, virtual s"))
        rows.append((f"load/done/{cell}", s["done"],
                     f"of {s['offered']} offered at max QPS "
                     f"({s['abandoned']} abandoned, "
                     f"{s['preemptions']} preemptions)"))
    return rows


def _obs_overhead():
    """Observability instrumentation overhead on the training step
    (docs/observability.md; acceptance: <= 3%).

    One jitted reduced-BLSTM AD-PSGD step, timed per step (blocked), in
    three arms: **plain** (bare loop), **noop** (the exact per-step
    call sites of launch/train.py — a span plus the ``obs.enabled()``
    guard — against the disabled no-op default), and **live** (the same
    sites with a configured registry + flight recorder: scalar float
    pulls, one event, histogram/counter/gauge updates per step).  Rows
    are medians, so one GC pause cannot fail the gate."""
    import dataclasses

    import jax
    import numpy as np

    from repro import obs
    from repro.configs import get_arch
    from repro.core import strategies as ST
    from repro.core.transport import Transport
    from repro.data import make_dataset
    from repro.models import build_model
    from repro.optim.optimizers import sgd
    from repro.optim.schedules import constant
    from repro.sharding import init_spec_tree

    L, steps, batch = 2, 30, 16
    cfg = dataclasses.replace(get_arch("swb2000-blstm").reduced(),
                              n_layers=1, lstm_hidden=64,
                              lstm_bottleneck=32, input_dim=32, vocab=64)
    model = build_model(cfg)
    strategy = ST.get_strategy("ad_psgd")
    transport = Transport(topology="ring")
    ds = make_dataset(cfg, seq_len=21, batch=batch, seed=0)
    step = jax.jit(ST.make_train_step(
        strategy, model.loss_fn, sgd(), constant(0.05),
        n_learners=L, transport=transport))
    batches = [ds.batch_at(k) for k in range(steps + 1)]

    def run(instrumented: bool):
        params = ST.stack_for_learners(
            init_spec_tree(model.param_specs(), jax.random.PRNGKey(0)), L)
        state = ST.init_state(strategy, params, sgd(), transport)
        state, _ = step(state, batches[0])          # compile outside
        jax.block_until_ready(state)                # the timed loop
        times = []
        for k in range(1, steps + 1):
            t0 = time.perf_counter()
            if instrumented:
                # the per-step call sites of launch/train.py
                with obs.span("bench/step", step=k):
                    state, m = step(state, batches[k])
                    jax.block_until_ready(state)
                if obs.enabled():
                    scal = {k2: float(v) for k2, v in m.items()}
                    obs.event("train/step", step=k, **scal)
                    obs.histogram("train/loss").observe(scal["loss"])
                    obs.counter("train/wire_bytes").inc(
                        scal.get("wire_bytes", 0.0))
                    obs.gauge("train/pad_eff").set(1.0)
            else:
                state, m = step(state, batches[k])
                jax.block_until_ready(state)
            times.append(time.perf_counter() - t0)
        return float(np.median(times))

    obs.reset()
    plain = run(False)
    noop = run(True)                    # no-op default: null span + guard
    obs.configure()
    live = run(True)                    # live registry + flight recorder
    obs.reset()
    return [
        ("obs/step_ms_plain", plain * 1e3,
         "median blocked train step, no instrumentation"),
        ("obs/step_ms_noop", noop * 1e3,
         "instrumentation sites against the disabled no-op default"),
        ("obs/step_ms_live", live * 1e3,
         "live registry + flight-recorder emission per step"),
        ("obs/step_overhead_ratio", live / plain,
         "live/plain (acceptance: <= 1.03)"),
        ("obs/noop_overhead_ratio", noop / plain,
         "noop/plain — the zero-overhead-default contract"),
    ]


def main(argv=None) -> None:
    import json

    from repro.obs import print_csv_rows

    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma-separated substrings; a bench runs if "
                         "ANY matches its name")
    ap.add_argument("--json-out", default="",
                    help="also write every row as machine-readable JSON "
                         "([{name, value, derived}, ...]) to this path "
                         "(the CI artifact format)")
    args = ap.parse_args(argv)
    wanted = [w for w in args.only.split(",") if w]

    # the shared name,value,derived schema (repro.obs)
    print_csv_rows([], header=True)
    failures = 0
    collected = []
    for name, fn in all_benches():
        if wanted and not any(w in name for w in wanted):
            continue
        try:
            rows = fn()
            print_csv_rows(rows)
            collected += [{"name": n, "value": v, "derived": d}
                          for n, v, d in rows]
        except Exception as e:
            failures += 1
            print(f"{name},ERROR,{type(e).__name__}: {e}", flush=True)
            traceback.print_exc(file=sys.stderr)
            collected.append({"name": name, "value": None,
                              "derived": f"ERROR {type(e).__name__}: {e}"})
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(collected, f, indent=1)
        print(f"[bench] wrote {len(collected)} rows to {args.json_out}",
              flush=True)
    if failures:
        raise SystemExit(f"{failures} benchmark failures")


if __name__ == "__main__":
    main()
