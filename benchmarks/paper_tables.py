"""One benchmark per paper table/figure (deliverable d).

Wall-clock phenomena (Tables II/III, Fig. 4-right speedup, Fig. 5) use the
calibrated discrete-event simulator (see perfsim.py docstring); convergence
(Fig. 4-left) runs the REAL strategies at reduced scale on CPU.
"""
from __future__ import annotations

import time

import numpy as np


# ---------------------------------------------------------------------------
# Table I — speech vs vision model profile
# ---------------------------------------------------------------------------

def bench_table1():
    """Model size + per-batch compute of the paper's BLSTM (paper: ~165MB,
    0.07 s/batch-of-32 on P100; we report the v5e roofline projection)."""
    from benchmarks.perfsim import calibrate_blstm

    t_batch160, model_bytes, n_params = calibrate_blstm(160)
    t_batch32, _, _ = calibrate_blstm(32)
    rows = [
        ("table1/blstm_params_M", n_params / 1e6, "paper ~41M (165MB fp32)"),
        ("table1/blstm_model_MB", model_bytes / 1e6, "paper: ~165MB"),
        ("table1/blstm_sec_per_batch32_v5e", t_batch32,
         "paper P100: ~0.07s"),
        ("table1/blstm_sec_per_batch160_v5e", t_batch160, "local batch"),
    ]
    return rows


# ---------------------------------------------------------------------------
# Fig. 4 (left) — heldout-loss convergence of SC/SD/AD-PSGD (REAL training)
# ---------------------------------------------------------------------------

def bench_fig4_convergence(steps: int = 120, L: int = 4):
    import jax

    from repro.configs import get_arch
    from repro.core import strategies as ST
    from repro.data import make_dataset
    from repro.models import build_model
    from repro.optim.optimizers import sgd
    from repro.optim.schedules import constant
    from repro.sharding import init_spec_tree

    cfg = get_arch("swb2000-blstm").reduced()
    model = build_model(cfg)
    ds = make_dataset(cfg, seq_len=21, batch=4 * L, seed=0)
    heldout = [ds.batch_at(10_000 + i) for i in range(4)]
    rows = []
    for name in ("sc_psgd_replicated", "sd_psgd", "ad_psgd"):
        strat = ST.get_strategy(name)
        params = ST.stack_for_learners(
            init_spec_tree(model.param_specs(), jax.random.PRNGKey(0)), L)
        state = ST.init_state(strat, params, sgd())
        step = jax.jit(ST.make_train_step(strat, model.loss_fn, sgd(),
                                          constant(0.3), n_learners=L))
        t0 = time.time()
        for k in range(steps):
            state, m = step(state, ds.batch_at(k))
        avg = ST.average_learners(state["params"])
        hl = float(np.mean([float(model.loss_fn(avg, hb))
                            for hb in heldout]))
        rows.append((f"fig4/heldout_loss/{name}", hl,
                     f"{steps} steps, L={L}, {time.time()-t0:.1f}s wall"))
    return rows


# ---------------------------------------------------------------------------
# Fig. 4 (right) — speedup vs number of learners, per strategy
# ---------------------------------------------------------------------------

def bench_fig4_speedup():
    from benchmarks.perfsim import ClusterSpec, calibrate_blstm, \
        simulate_async, simulate_sync

    t_comp, model_bytes, _ = calibrate_blstm(160)
    rows = []
    n_batches = 4096
    t_single = t_comp * n_batches
    for L in (4, 8, 16):
        comp = np.full(L, t_comp)
        for name, fn, kw in (
            ("sc_psgd_openmpi",
             simulate_sync, dict()),
            ("sc_psgd_nccl", simulate_sync, dict()),
            ("sd_psgd", simulate_sync, dict(neighbor_only=True)),
            ("ad_psgd", simulate_async, dict()),
        ):
            eff = 0.35 if name == "sc_psgd_openmpi" else 1.0
            spec = ClusterSpec(L, comp, model_bytes, allreduce_eff=eff)
            t, _ = fn(spec, n_batches, **kw)
            rows.append((f"fig4/speedup/{name}/L{L}", t_single / t,
                         f"ideal {L}x"))
    return rows


# ---------------------------------------------------------------------------
# Table II — straggler robustness (one learner slowed 2x/10x/100x)
# ---------------------------------------------------------------------------

def bench_table2_straggler():
    from benchmarks.perfsim import ClusterSpec, calibrate_blstm, \
        simulate_async, simulate_sync

    t_comp, model_bytes, _ = calibrate_blstm(160)
    L, n_batches = 16, 4096
    t_single = t_comp * n_batches
    rows = []
    for slow in (1, 2, 10, 100):
        comp = np.full(L, t_comp)
        comp[0] *= slow
        spec = ClusterSpec(L, comp, model_bytes)
        t_sc, _ = simulate_sync(spec, n_batches)
        t_ad, _ = simulate_async(spec, n_batches)
        rows.append((f"table2/sc_psgd_speedup/slow{slow}x",
                     t_single / t_sc, f"paper: collapses ({slow}x)"))
        rows.append((f"table2/ad_psgd_speedup/slow{slow}x",
                     t_single / t_ad, "paper: ~10.4-10.9 stable"))
    return rows


# ---------------------------------------------------------------------------
# Table III — H-ring scaling 16/32/64 learners
# ---------------------------------------------------------------------------

def bench_table3_hring():
    from benchmarks.perfsim import ClusterSpec, calibrate_blstm, \
        simulate_hring

    t_comp, model_bytes, _ = calibrate_blstm(128)
    rows = []
    n_batches = 16 * 4096
    t_single = t_comp * n_batches
    for L in (16, 32, 64):
        spec = ClusterSpec(L, np.full(L, t_comp), model_bytes)
        t, _ = simulate_hring(spec, n_batches, gpus_per_node=8)
        rows.append((f"table3/hring_speedup/L{L}", t_single / t,
                     {16: "paper 9.8x", 32: "paper 19.7x",
                      64: "paper 37.5x"}[L]))
    return rows


# ---------------------------------------------------------------------------
# Fig. 5 — AD-PSGD load balancing across heterogeneous learners
# ---------------------------------------------------------------------------

def bench_fig5_load_balance():
    from benchmarks.perfsim import ClusterSpec, calibrate_blstm, \
        simulate_async

    t_comp, model_bytes, _ = calibrate_blstm(160)
    L = 16
    rng = np.random.default_rng(0)
    comp = np.full(L, t_comp)
    comp[8:] *= rng.uniform(1.5, 3.0, size=8)   # 8 GPUs share other jobs
    spec = ClusterSpec(L, comp, model_bytes)
    _, counts = simulate_async(spec, 4096)
    fast = counts[:8].mean()
    slow = counts[8:].mean()
    return [
        ("fig5/batches_fast_learners_mean", float(fast),
         "faster learners pick up more work"),
        ("fig5/batches_slow_learners_mean", float(slow), ""),
        ("fig5/fast_slow_ratio", float(fast / slow), "paper: ~2-3x"),
    ]


# ---------------------------------------------------------------------------
# Beyond-paper: compressed mixing payloads in the paper's regime (§IV-D)
# ---------------------------------------------------------------------------

def bench_compression():
    """AD-PSGD speedup with fp32 vs bf16 vs int8 vs topk neighbor payloads
    — in the paper's own high-communication/low-compute regime the wire
    format is decisive (measured dry-run note: at phi3-scale on 256 chips
    mixing is <2%% of collective bytes, so this matters for the ASR
    regime, not there — EXPERIMENTS.md §Perf).  Wire scaling comes from
    perfsim.wire_payload_bytes (the Transport codec accounting); the
    exact per-(strategy × wire) byte matrix is the `comm` bench."""
    from benchmarks.perfsim import ClusterSpec, calibrate_blstm, \
        simulate_async, wire_payload_bytes

    t_comp, model_bytes, _ = calibrate_blstm(160)
    L, n_batches = 16, 4096
    t_single = t_comp * n_batches
    rows = []
    for name, wire in (("fp32", "f32"), ("bf16", "bf16"),
                       ("int8_q8", "int8"), ("topk1pct", "topk")):
        payload = wire_payload_bytes(model_bytes, wire)
        spec = ClusterSpec(L, np.full(L, t_comp), payload)
        t, _ = simulate_async(spec, n_batches)
        rows.append((f"compression/ad_psgd_speedup/{name}", t_single / t,
                     f"L={L}, payload x{payload / model_bytes:.3g}"))
    return rows


# ---------------------------------------------------------------------------
# Recognition performance — the paper's third axis (WER tables; the
# companion 1904.04956 reports (A)D-PSGD vs sync SGD as WER deltas)
# ---------------------------------------------------------------------------

def bench_decode_wer(steps: int = 50, L: int = 2):
    """TER per strategy on a held-out synthetic set (REAL training): the
    reduced BLSTM is trained with CTC under sync SC-PSGD and AD-PSGD,
    the learner consensus is decoded with greedy best-path and the
    sum-semiring prefix beam (repro.decode), and the table reports
    per-strategy TER plus the async-vs-sync delta — the synthetic
    analogue of the paper's Hub5'00 WER comparison."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_arch
    from repro.core import strategies as ST
    from repro.data import make_dataset
    from repro.decode import beam_decode
    from repro.eval.metrics import greedy_ctc_decode, token_error_rate
    from repro.models import build_model
    from repro.models.ctc import collapse_frame_labels, ctc_loss
    from repro.models.lstm import forward
    from repro.optim.optimizers import sgd
    from repro.optim.schedules import constant
    from repro.sharding import init_spec_tree

    cfg = get_arch("swb2000-blstm").reduced()
    model = build_model(cfg)
    ds = make_dataset(cfg, seq_len=21, batch=4 * L, seed=0)
    U = 6

    def with_ctc(b):
        seqs, _ = collapse_frame_labels(b["labels"], max_len=U)
        return {"features": b["features"], "ctc": seqs}

    def loss_fn(p, batch):
        return ctc_loss(forward(cfg, p, batch["features"]), batch["ctc"])

    heldout = [ds.batch_at(10_000 + i) for i in range(2)]
    rows, ter = [], {}
    for name in ("sc_psgd_replicated", "ad_psgd"):
        strat = ST.get_strategy(name)
        params = ST.stack_for_learners(
            init_spec_tree(model.param_specs(), jax.random.PRNGKey(0)), L)
        state = ST.init_state(strat, params, sgd())
        step = jax.jit(ST.make_train_step(strat, loss_fn, sgd(),
                                          constant(0.03), n_learners=L))
        t0 = time.time()
        for k in range(steps):
            state, _ = step(state, with_ctc(ds.batch_at(k)))
        avg = ST.average_learners(state["params"])

        refs, hyp_g, hyp_b = [], [], []
        for hb in heldout:
            seqs, lens = collapse_frame_labels(hb["labels"], max_len=U)
            refs += [list(s[:n]) for s, n in zip(seqs, lens)]
            logits = np.asarray(
                forward(cfg, avg, jnp.asarray(hb["features"])), np.float32)
            hyp_g += greedy_ctc_decode(logits)
            hyp_b += beam_decode(jnp.asarray(logits), beam=8,
                                 semiring="sum")
        ter[name] = token_error_rate(refs, hyp_b)
        rows.append((f"decode_wer/ter_greedy/{name}",
                     token_error_rate(refs, hyp_g),
                     f"{steps} CTC steps, L={L}, "
                     f"{time.time() - t0:.1f}s wall"))
        rows.append((f"decode_wer/ter_beam8/{name}", ter[name],
                     "sum-semiring prefix beam, consensus params"))
    rows.append(("decode_wer/ter_delta_ad_vs_sync",
                 ter["ad_psgd"] - ter["sc_psgd_replicated"],
                 "paper framing: async WER - sync WER (~0 is the claim)"))
    return rows
