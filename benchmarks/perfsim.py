"""Discrete-event performance simulator for the paper's wall-clock
experiments (Tables II/III, Fig. 4-right, Fig. 5).

This container is CPU-only, so cluster wall-clock cannot be measured; the
paper's speedup/straggler/load-balance phenomenology is reproduced with an
event simulator whose per-batch compute and communication times are
CALIBRATED from the roofline terms of the compiled dry-run (see
``calibrate_blstm``): compute = dominant roofline term of one learner's
per-batch program on its chips; communication = model bytes over the
link bandwidth with the strategy's collective pattern.

Strategies simulated:
* sync allreduce (SC-PSGD): global barrier + ring allreduce per step
* sync neighbor  (SD-PSGD): global barrier + left/right exchange per step
* async ring     (AD-PSGD): no barrier; each learner loops gradient
  compute and overlaps neighbor averaging (paper §IV-C) — a learner's step
  rate is 1/max(t_comp, t_comm_overlap).
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field

import numpy as np


# Analytic payload scale of each wire codec relative to the f32 wire —
# the perfsim counterpart of repro.core.transport.Transport._payload_bytes
# (int8 carries an f32 scale per tensor, topk ships 8B value+index pairs).
WIRE_FACTORS = {"f32": 1.0, "bf16": 0.5, "int8": 0.2505}


def wire_payload_bytes(model_bytes: float, wire: str,
                       topk_frac: float = 0.01) -> float:
    """Bytes on the wire for one model-sized payload under a codec."""
    if wire == "topk":
        return model_bytes * 2.0 * topk_frac   # 8B/kept of 4B/elem
    return model_bytes * WIRE_FACTORS[wire]


@dataclass
class ClusterSpec:
    n_learners: int
    t_comp: np.ndarray            # per-learner seconds per local batch
    model_bytes: float
    link_bw: float = 50e9         # per the roofline ICI constant
    allreduce_eff: float = 1.0    # NCCL=1.0; 'OpenMPI' ~ 0.35 (paper Fig.4)

    def t_allreduce(self) -> float:
        L = self.n_learners
        return 2 * self.model_bytes * (L - 1) / L / (
            self.link_bw * self.allreduce_eff)

    def t_neighbor(self) -> float:
        # send/recv to both ring neighbors, full model each way
        return 2 * self.model_bytes / self.link_bw


def simulate_sync(spec: ClusterSpec, n_batches: int, *,
                  neighbor_only: bool = False):
    """Barrier per step: straggler-bound (paper Table II)."""
    comm = spec.t_neighbor() if neighbor_only else spec.t_allreduce()
    per_round = max(spec.t_comp) + comm
    rounds = int(np.ceil(n_batches / spec.n_learners))
    counts = np.full(spec.n_learners, rounds)
    return per_round * rounds, counts


def simulate_async(spec: ClusterSpec, n_batches: int):
    """Event loop: each learner independently computes; communication is
    overlapped, so a learner's cycle is max(compute, neighbor exchange).
    Returns (makespan, batches per learner) — Fig. 5's distribution."""
    t_comm = spec.t_neighbor()
    step = np.maximum(spec.t_comp, t_comm)
    heap = [(float(step[i]), i) for i in range(spec.n_learners)]
    heapq.heapify(heap)
    counts = np.zeros(spec.n_learners, np.int64)
    t = 0.0
    for _ in range(n_batches):
        t, i = heapq.heappop(heap)
        counts[i] += 1
        heapq.heappush(heap, (t + float(step[i]), i))
    return t, counts


def simulate_hring(spec: ClusterSpec, n_batches: int, gpus_per_node: int,
                   nvlink_bw: float = 150e9):
    """H-ring (§V Table III): NCCL allreduce inside a node (super-learner),
    AD-PSGD ring across nodes."""
    n_nodes = spec.n_learners // gpus_per_node
    t_local = (2 * spec.model_bytes * (gpus_per_node - 1)
               / gpus_per_node / nvlink_bw)
    node_comp = spec.t_comp.reshape(n_nodes, gpus_per_node).max(1) + t_local
    node_spec = ClusterSpec(n_nodes, node_comp, spec.model_bytes,
                            spec.link_bw)
    # each node-step consumes gpus_per_node local batches
    makespan, counts = simulate_async(node_spec,
                                      n_batches // gpus_per_node)
    return makespan, counts * gpus_per_node


# ---------------------------------------------------------------------------
# Fault-plan driven simulation (pod-scale N; docs/fault_tolerance.md)
# ---------------------------------------------------------------------------
#
# The ``plan`` argument is duck-typed against repro.core.faults.FaultPlan
# (speed_factors / stall_extra / active_at / departures) so perfsim stays
# importable without the repro package on the path — the SAME plan object
# that drives the elastic train step drives the wall-clock simulation,
# making the `--only faults` bench's convergence and throughput columns
# two views of one fault description.


def _nominal_round(spec: ClusterSpec, comm: float) -> float:
    return float(np.median(spec.t_comp)) + comm


def simulate_sync_faulty(spec: ClusterSpec, n_batches: int, plan, *,
                         neighbor_only: bool = False,
                         elastic: bool = False):
    """Barrier-per-step under a fault plan.

    Non-elastic (the gang-scheduled baseline): every round waits for the
    SLOWEST member — a 4× straggler stretches every round 4×, a stall
    blocks the whole job, and a crashed learner halts it outright until
    the rejoin (its downtime, measured in nominal rounds, is charged as
    dead wall-clock).  A departure that never rejoins deadlocks the job:
    makespan = inf.

    Elastic: the barrier spans only the live set — survivors keep
    stepping (each round consumes one batch per live learner), stalls
    and straggler factors only stretch the rounds their victims attend.

    Returns (makespan_seconds, per-learner batch counts).
    """
    L = spec.n_learners
    speed = plan.speed_factors()
    comm = spec.t_neighbor() if neighbor_only else spec.t_allreduce()
    nominal = _nominal_round(spec, comm)

    if not elastic:
        for d in getattr(plan, "departures", ()):
            if d.rejoin < 0:
                return float("inf"), np.zeros(L, np.int64)

    t = 0.0
    counts = np.zeros(L, np.int64)
    done = 0
    r = 0
    charged = set()
    while done < n_batches:
        active = plan.active_at(r)
        members = active if elastic else np.ones(L, bool)
        if not elastic:
            # the gang blocks for every crashed member's downtime (its
            # wall-clock absence, in nominal rounds), charged once
            for d in getattr(plan, "departures", ()):
                if d.step == r and d.learner not in charged:
                    charged.add(d.learner)
                    t += (d.rejoin - d.step) * nominal
        per = [spec.t_comp[i] * speed[i]
               * (1.0 + plan.stall_extra(i, r))
               for i in range(L) if members[i]]
        t += max(per) + comm
        counts[members] += 1
        done += int(members.sum())
        r += 1
    return t, counts


def simulate_async_faulty(spec: ClusterSpec, n_batches: int, plan):
    """AD-PSGD-style event loop under a fault plan: each learner cycles
    at max(its own compute × its speed factor (+ heavy-tailed stalls),
    neighbor exchange); a crashed learner simply produces nothing during
    [crash, rejoin) while the rest keep going — the elastic-membership
    wall-clock model.  Crash/rejoin steps are mapped to wall-clock via
    the nominal round time.  Returns (makespan, per-learner counts)."""
    L = spec.n_learners
    speed = plan.speed_factors()
    t_comm = spec.t_neighbor()
    nominal = _nominal_round(spec, t_comm)
    windows = {}   # learner -> (t_crash, t_rejoin)
    for d in getattr(plan, "departures", ()):
        t_back = d.rejoin * nominal if d.rejoin >= 0 else float("inf")
        windows[d.learner] = (d.step * nominal, t_back)

    def cycle(i: int, k: int) -> float:
        comp = spec.t_comp[i] * speed[i] * (1.0 + plan.stall_extra(i, k))
        return max(comp, t_comm)

    heap = []
    for i in range(L):
        start = 0.0
        if i in windows and windows[i][0] <= 0.0:
            start = windows[i][1]
        if np.isfinite(start):
            heapq.heappush(heap, (start + cycle(i, 0), i, 0))
    counts = np.zeros(L, np.int64)
    t = 0.0
    while counts.sum() < n_batches and heap:
        t, i, k = heapq.heappop(heap)
        if i in windows:
            crash, back = windows[i]
            if crash <= t < back:
                # the batch finished into the crash window: lost; the
                # learner resumes (rejoined, consensus-reseeded) at
                # `back`
                if np.isfinite(back):
                    heapq.heappush(heap, (back + cycle(i, k + 1), i, k + 1))
                continue
        counts[i] += 1
        heapq.heappush(heap, (t + cycle(i, k + 1), i, k + 1))
    return t, counts


def straggler_spec(n: int, t_comp_base: float, model_bytes: float,
                   link_bw: float = 50e9) -> ClusterSpec:
    """ClusterSpec for N learners whose nominal per-batch time is
    ``t_comp_base`` — straggler factors come from the plan at
    simulation time, so the same spec serves clean and faulty runs."""
    return ClusterSpec(n, np.full(n, t_comp_base), model_bytes,
                       link_bw=link_bw)


# ---------------------------------------------------------------------------
# calibration from the repo's own artifacts
# ---------------------------------------------------------------------------

def calibrate_blstm(batch_per_learner: int = 160, unroll: int = 21):
    """Per-batch compute time of the paper's BLSTM on one v5e chip, from
    the model's analytic FLOPs/bytes and roofline constants; model bytes
    from the real ParamSpec tree (≈165MB, matching paper Table I)."""
    from repro.analysis.params import count_params
    from repro.analysis.roofline import HW
    from repro.configs import get_arch
    from repro.models import build_model

    cfg = get_arch("swb2000-blstm")
    n_params = count_params(build_model(cfg).param_specs())
    model_bytes = n_params * 4.0                      # paper stores fp32
    tokens = batch_per_learner * unroll
    flops = 6.0 * n_params * tokens
    t_compute = flops / HW.peak_flops_bf16
    # LSTM steps are latency/memory bound: weights re-read per unroll step
    t_memory = (2 * n_params * 2 * unroll) / HW.hbm_bw
    return max(t_compute, t_memory), model_bytes, n_params
