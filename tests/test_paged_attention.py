"""Paged decode attention (docs/kernels.md §Paged decode): the Pallas
page-table kernel vs the dense kernel and the jax gather reference.

The paged kernel shares ``_attend_tile`` verbatim with the dense one
and page tiles are physically exact (no ragged padding), so paged
output over CONTIGUOUS pages is required to be BIT-exact vs dense at
``block_s = page_size`` — not merely within tolerance — and shuffled
physical pages must be bit-exact vs contiguous ones."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention import (auto_block_s_decode,
                                            decode_attn_vmem_bytes,
                                            decode_attention,
                                            paged_attn_vmem_bytes,
                                            paged_decode_attention)
from repro.models import attention as A

TOL = 2e-5


def _setup(seed, B, S, KV, M, E):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    H = KV * M
    q = jax.random.normal(ks[0], (B, 1, H, E), jnp.float32)
    kc = jax.random.normal(ks[1], (B, S, KV, E), jnp.float32)
    vc = jax.random.normal(ks[2], (B, S, KV, E), jnp.float32)
    kn = jax.random.normal(ks[3], (B, 1, KV, E), jnp.float32)
    vn = jax.random.normal(ks[4], (B, 1, KV, E), jnp.float32)
    return q, kc, vc, kn, vn


def _paginate(kc, P, perm=None):
    """Dense (B, S, KV, E) -> pages (B*W, P, KV, E) + table (B, W),
    optionally placing logical pages at permuted physical slots."""
    B, S, KV, E = kc.shape
    W = S // P
    pages = np.asarray(kc).reshape(B * W, P, KV, E)
    table = np.arange(B * W, dtype=np.int32).reshape(B, W)
    if perm is not None:
        pages = pages[np.argsort(perm)]
        table = np.asarray(perm, np.int32).reshape(B, W)
    return jnp.asarray(pages), jnp.asarray(table)


# ---------------------------------------------------------------------------
# kernel level
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("window", [None, 9])
@pytest.mark.parametrize("M", [1, 2])
def test_paged_contiguous_bitexact_vs_dense(window, M):
    B, S, KV, E, P = 2, 32, 2, 8, 8
    q, kc, vc, _, _ = _setup(0, B, S, KV, M, E)
    kp, tbl = _paginate(kc, P)
    vp, _ = _paginate(vc, P)
    for pos in (0, 13, S - 1):
        dense = decode_attention(q, kc, vc, jnp.int32(pos), window=window,
                                 block_s=P, interpret=True)
        paged = paged_decode_attention(q, kp, vp, tbl, jnp.int32(pos),
                                       window=window, interpret=True)
        assert np.array_equal(np.asarray(paged), np.asarray(dense)), \
            f"paged != dense bit-for-bit at pos={pos}"
        ref = A.attn_decode(q, kc, vc, jnp.int32(pos), window=window)
        err = float(jnp.max(jnp.abs(paged - ref))
                    / jnp.max(jnp.abs(ref)))
        assert err < TOL


def test_paged_shuffled_pages_bitexact_vs_contiguous():
    """The physical placement of pages is invisible: a shuffled pool
    walked through its table equals the contiguous layout exactly."""
    B, S, KV, M, E, P = 2, 32, 2, 2, 8, 8
    q, kc, vc, _, _ = _setup(1, B, S, KV, M, E)
    kp, tbl = _paginate(kc, P)
    vp, _ = _paginate(vc, P)
    perm = np.random.default_rng(3).permutation(B * (S // P))
    kp2, tbl2 = _paginate(kc, P, perm=perm)
    vp2, _ = _paginate(vc, P, perm=perm)
    pos = jnp.int32(21)
    a = paged_decode_attention(q, kp, vp, tbl, pos, interpret=True)
    b = paged_decode_attention(q, kp2, vp2, tbl2, pos, interpret=True)
    assert np.array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("window", [None, 7])
def test_paged_delta_bitexact_vs_dense_delta(window):
    B, S, KV, M, E, P = 2, 32, 2, 2, 8, 8
    q, kc, vc, kn, vn = _setup(2, B, S, KV, M, E)
    kp, tbl = _paginate(kc, P)
    vp, _ = _paginate(vc, P)
    for pos in (0, 13, S - 1):
        dense = decode_attention(q, kc, vc, jnp.int32(pos), window=window,
                                 k_new=kn, v_new=vn, block_s=P,
                                 interpret=True)
        paged = paged_decode_attention(q, kp, vp, tbl, jnp.int32(pos),
                                       window=window, k_new=kn, v_new=vn,
                                       interpret=True)
        assert np.array_equal(np.asarray(paged), np.asarray(dense))
        ref = A.attn_decode_delta(q, kc, vc, kn, vn, jnp.int32(pos),
                                  window=window)
        err = float(jnp.max(jnp.abs(paged - ref))
                    / jnp.max(jnp.abs(ref)))
        assert err < TOL


def test_padded_table_tail_is_ignored():
    """Table entries beyond the request's pages may point anywhere
    valid: tiles starting above pos are skipped, so junk padding does
    not change the output (the masked-tile zero-identity contract that
    also licenses the server's table-width slicing)."""
    B, S, KV, M, E, P = 1, 32, 2, 2, 8, 8
    q, kc, vc, _, _ = _setup(3, B, S, KV, M, E)
    kp, tbl = _paginate(kc, P)
    pos = jnp.int32(P - 1)                   # only page 0 is reachable
    vp, _ = _paginate(vc, P)
    a = paged_decode_attention(q, kp, vp, tbl, pos, interpret=True)
    junk = np.asarray(tbl).copy()
    junk[0, 1:] = [3, 0, 2]                  # garbage (valid ids) tail
    b = paged_decode_attention(q, kp, vp, jnp.asarray(junk), pos,
                               interpret=True)
    assert np.array_equal(np.asarray(a), np.asarray(b))
    # and a 1-wide table (the sliced wave) matches too
    c = paged_decode_attention(q, kp, vp, jnp.asarray(junk[:, :1]), pos,
                               interpret=True)
    assert np.array_equal(np.asarray(a), np.asarray(c))


# ---------------------------------------------------------------------------
# model-level dispatch (attention.attn_decode / write)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("window", [None, 9])
def test_attn_decode_paged_jax_bitexact_vs_dense(window):
    """The jax paged path gathers pages through the table and runs the
    dense math on identical operand values — bit-exact vs dense."""
    B, S, KV, M, E, P = 2, 32, 2, 2, 8, 8
    q, kc, vc, kn, vn = _setup(4, B, S, KV, M, E)
    perm = np.random.default_rng(5).permutation(B * (S // P))
    kp, tbl = _paginate(kc, P, perm=perm)
    vp, _ = _paginate(vc, P, perm=perm)
    pos = jnp.int32(17)
    dense = A.attn_decode(q, kc, vc, pos, window=window)
    paged = A.attn_decode(q, kp, vp, pos, window=window,
                          page_table=tbl, page_size=P)
    assert np.array_equal(np.asarray(paged), np.asarray(dense))
    ddense = A.attn_decode_delta(q, kc, vc, kn, vn, pos, window=window)
    dpaged = A.attn_decode_delta(q, kp, vp, kn, vn, pos, window=window,
                                 page_table=tbl, page_size=P)
    assert np.array_equal(np.asarray(dpaged), np.asarray(ddense))


def test_write_new_token_paged_lands_at_page_offset():
    L, B, S, KV, E, P = 2, 2, 32, 2, 8, 8
    perm = np.random.default_rng(6).permutation(B * (S // P))
    table = np.asarray(perm, np.int32).reshape(B, S // P)
    pages = jnp.zeros((L, B * (S // P), P, KV, E), jnp.float32)
    new = jnp.asarray(np.random.default_rng(7).normal(
        size=(L, B, 1, KV, E)), jnp.float32)
    pos = 13                                  # page 1, offset 5
    out = np.asarray(A.write_new_token_paged(
        pages, new, jnp.asarray(table), jnp.int32(pos), P))
    for b in range(B):
        phys = table[b, pos // P]
        np.testing.assert_array_equal(out[:, phys, pos % P],
                                      np.asarray(new)[:, b, 0])
    # nothing else was touched
    touched = {int(table[b, pos // P]) for b in range(B)}
    for pg in range(out.shape[1]):
        if pg not in touched:
            assert not out[:, pg].any()


# ---------------------------------------------------------------------------
# VMEM accounting
# ---------------------------------------------------------------------------

def test_paged_vmem_accounting_and_page_pinning():
    M, E, P = 2, 8, 8
    assert paged_attn_vmem_bytes(P, M, E, table_elems=16) == \
        decode_attn_vmem_bytes(P, M, E) + 4 * (16 + 2)
    # paged mode pins the tile to the page regardless of S
    assert auto_block_s_decode(4096, M, E, page_size=P) == P
    with pytest.raises(ValueError):
        auto_block_s_decode(4096, M, E, page_size=1 << 20,
                            vmem_budget=1 << 20)
