"""Model-zoo correctness: smoke per arch family + prefill/decode consistency
+ MoE routing equivalence + sliding-window semantics."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_REGISTRY, get_arch
from repro.configs.base import ShapeConfig
from repro.models import build_model
from repro.sharding import ParamSpec, init_spec_tree

RNG = jax.random.PRNGKey(0)
B, S = 2, 64


def synth_inputs(cfg, model, mode, seq=S):
    shape = ShapeConfig("t", seq, B, mode)
    specs = model.input_specs(shape, mode)

    def mk(ps):
        if ps.dtype == "int32":
            if ps.shape == ():
                return jnp.int32(seq // 2)
            return jax.random.randint(RNG, ps.shape, 0,
                                      min(cfg.vocab, 100), jnp.int32)
        return jax.random.normal(RNG, ps.shape, jnp.float32).astype(ps.dtype)

    return jax.tree.map(mk, specs,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


@pytest.fixture(scope="module")
def zoo():
    out = {}
    for name in sorted(ARCH_REGISTRY):
        cfg = get_arch(name).reduced()
        model = build_model(cfg)
        params = init_spec_tree(model.param_specs(), RNG)
        out[name] = (cfg, model, params)
    return out


# ---------------------------------------------------------------------------
# smoke: every arch trains one step with finite loss (deliverable f)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(ARCH_REGISTRY))
def test_arch_smoke_train(zoo, name):
    cfg, model, params = zoo[name]
    batch = synth_inputs(cfg, model, "train")
    loss, g = jax.value_and_grad(model.loss_fn)(params, batch)
    assert np.isfinite(float(loss)), name
    gn = sum(float(jnp.sum(jnp.square(x.astype(jnp.float32))))
             for x in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0, name


@pytest.mark.parametrize("name", [n for n in sorted(ARCH_REGISTRY)
                                  if ARCH_REGISTRY[n].family != "lstm"])
def test_arch_smoke_decode_shapes(zoo, name):
    cfg, model, params = zoo[name]
    pb = synth_inputs(cfg, model, "prefill")
    logits, cache = model.prefill_fn(params, pb, cache_len=S)
    assert logits.shape[-1] == cfg.vocab
    tok = jnp.zeros((B, 1), jnp.int32)
    lg, cache2 = model.decode_fn(params, cache, tok, jnp.int32(S // 2))
    assert lg.shape == (B, 1, cfg.vocab)
    assert bool(jnp.isfinite(lg.astype(jnp.float32)).all()), name


# ---------------------------------------------------------------------------
# prefill -> decode == teacher forcing (the serving path is exact)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["smollm-360m", "granite-moe-3b-a800m",
                                  "mamba2-370m", "hymba-1.5b",
                                  "llama4-scout-17b-a16e"])
def test_prefill_decode_consistency(zoo, name):
    """decode(tokens[:t], then token t) logits == prefill(tokens[:t+1])'s
    last-position logits.

    For capacity-routed MoE the comparison requires no-drop capacity:
    grouped prefill may drop tokens that a solo decode step serves — the
    documented GShard trade-off (see test_moe_capacity_drops_tokens)."""
    cfg, model, params = zoo[name]
    if cfg.moe is not None and cfg.moe.router_impl == "dispatch":
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(
                cfg.moe, capacity_factor=float(cfg.moe.num_experts)))
        model = build_model(cfg)
    T = 32
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, T + 1), 0,
                              cfg.vocab, jnp.int32)
    # ground truth: prefill over t+1 tokens
    full, _ = model.prefill_fn(params, {"tokens": toks}, cache_len=T + 1)
    # serving path: prefill t tokens, decode token t at position t
    part, cache = model.prefill_fn(params, {"tokens": toks[:, :T]},
                                   cache_len=T + 1)
    lg, _ = model.decode_fn(params, cache, toks[:, T:T + 1], jnp.int32(T))
    np.testing.assert_allclose(
        np.asarray(lg[:, 0], np.float32),
        np.asarray(full[:, -1], np.float32), atol=0.11, rtol=0.11)


def test_prefill_decode_consistency_encdec(zoo):
    cfg, model, params = zoo["whisper-large-v3"]
    T = 16
    frames = jax.random.normal(jax.random.PRNGKey(4), (B, T, cfg.d_model),
                               jnp.float32).astype(jnp.bfloat16)
    toks = jax.random.randint(jax.random.PRNGKey(5), (B, T + 1), 0,
                              cfg.vocab, jnp.int32)
    full, _ = model.prefill_fn(
        params, {"frames": frames, "tokens": toks}, cache_len=T + 1)
    part, cache = model.prefill_fn(
        params, {"frames": frames, "tokens": toks[:, :T]}, cache_len=T + 1)
    lg, _ = model.decode_fn(params, cache, toks[:, T:T + 1], jnp.int32(T))
    np.testing.assert_allclose(np.asarray(lg[:, 0], np.float32),
                               np.asarray(full[:, -1], np.float32),
                               atol=0.11, rtol=0.11)


def test_multistep_decode_matches_teacher_forcing(zoo):
    cfg, model, params = zoo["smollm-360m"]
    T, extra = 24, 4
    toks = jax.random.randint(jax.random.PRNGKey(6), (B, T + extra), 0,
                              cfg.vocab, jnp.int32)
    full, _ = model.prefill_fn(params, {"tokens": toks},
                               cache_len=T + extra)
    _, cache = model.prefill_fn(params, {"tokens": toks[:, :T]},
                                cache_len=T + extra)
    for i in range(extra):
        lg, cache = model.decode_fn(params, cache, toks[:, T + i:T + i + 1],
                                    jnp.int32(T + i))
    np.testing.assert_allclose(np.asarray(lg[:, 0], np.float32),
                               np.asarray(full[:, -1], np.float32),
                               atol=0.15, rtol=0.15)


# ---------------------------------------------------------------------------
# MoE: dispatch (capacity) routing == dense routing when nothing drops
# ---------------------------------------------------------------------------

def test_moe_dispatch_matches_dense_at_high_capacity():
    from repro.models.moe import moe_apply, moe_param_specs

    cfg = get_arch("granite-moe-3b-a800m").reduced()
    cfg_disp = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, router_impl="dispatch",
                                     capacity_factor=float(cfg.moe.num_experts)))
    cfg_dense = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, router_impl="dense"))
    p = init_spec_tree(moe_param_specs(cfg), jax.random.PRNGKey(1))
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 64, cfg.d_model),
                          jnp.float32).astype(jnp.bfloat16)
    y1, aux1 = moe_apply(cfg_disp, p, x)
    y2, aux2 = moe_apply(cfg_dense, p, x)
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32), atol=0.06,
                               rtol=0.06)
    np.testing.assert_allclose(float(aux1), float(aux2), rtol=1e-4)


def test_moe_capacity_drops_tokens():
    """At tiny capacity the dispatch path must differ (tokens dropped) but
    stay finite — the documented GShard behaviour."""
    from repro.models.moe import moe_apply, moe_param_specs

    cfg = get_arch("granite-moe-3b-a800m").reduced()
    cfg_tiny = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, router_impl="dispatch",
                                     capacity_factor=0.25))
    p = init_spec_tree(moe_param_specs(cfg), jax.random.PRNGKey(1))
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 64, cfg.d_model),
                          jnp.float32).astype(jnp.bfloat16)
    y, aux = moe_apply(cfg_tiny, p, x)
    assert bool(jnp.isfinite(y.astype(jnp.float32)).all())


# ---------------------------------------------------------------------------
# sliding windows
# ---------------------------------------------------------------------------

def test_window_masks_attention():
    from repro.kernels.ref import attention_ref
    from repro.models.attention import attn_seq

    q = jax.random.normal(jax.random.PRNGKey(1), (1, 128, 2, 16))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 128, 2, 16))
    v = jax.random.normal(jax.random.PRNGKey(3), (1, 128, 2, 16))
    out = attn_seq(q, k, v, causal=True, window=jnp.int32(16), q_chunk=32)
    expect = attention_ref(q, k, v, causal=True, window=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=1e-4, rtol=1e-4)


def test_layer_windows_global_override():
    from repro.models.transformer import layer_windows, GLOBAL_WINDOW

    cfg = get_arch("hymba-1.5b")
    ws = layer_windows(cfg, 1 << 16)
    assert ws[0] == GLOBAL_WINDOW and ws[15] == GLOBAL_WINDOW \
        and ws[31] == GLOBAL_WINDOW
    assert ws[1] == cfg.window


def test_long_context_variant_uses_window_for_long():
    from repro.models.transformer import layer_windows, GLOBAL_WINDOW

    cfg = get_arch("phi3-medium-14b")
    assert layer_windows(cfg, 1 << 16)[0] == GLOBAL_WINDOW
    assert layer_windows(cfg, 1 << 16, long_context=True)[0] == \
        cfg.window_for_long
