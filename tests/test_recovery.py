"""Crash-recovery contract (docs/fault_tolerance.md).

* Atomic saves: no partially-written ``step_<n>`` ever exists under its
  final name; pruning happens only after the new step is durable.
* Validated restores: structure / per-leaf shape / per-leaf dtype
  mismatches raise ValueErrors naming the offending leaf path.
* Bit-exact resume: save→restore round-trips every bit (bf16 params,
  optimizer moments, topk error-feedback residuals), and a killed-and-
  resumed run matches the uninterrupted run step-for-step — for every
  strategy that carries comm state, and through the real CLI under an
  active fault plan.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as CK
from repro.core import strategies as ST
from repro.core.faults import Departure, FaultPlan, Straggler
from repro.core.transport import Transport
from repro.optim.optimizers import momentum, sgd
from repro.optim.schedules import constant

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}

W_TRUE = jax.random.normal(jax.random.PRNGKey(7), (8,))


def loss_fn(params, batch):
    pred = batch["x"] @ params["w"].astype(jnp.float32)
    return jnp.mean((pred - batch["y"]) ** 2)


def data(seed, n=64):
    x = jax.random.normal(jax.random.PRNGKey(seed), (n, 8))
    return {"x": x, "y": x @ W_TRUE}


def _assert_trees_bitwise_equal(a, b):
    for pa, (la, lb) in zip(
            jax.tree_util.tree_flatten_with_path(a)[0],
            zip(jax.tree.leaves(a), jax.tree.leaves(b))):
        name = jax.tree_util.keystr(pa[0])
        xa, xb = np.asarray(la), np.asarray(lb)
        assert xa.dtype == xb.dtype, name
        np.testing.assert_array_equal(
            xa.view(np.uint16) if xa.dtype.name == "bfloat16" else xa,
            xb.view(np.uint16) if xb.dtype.name == "bfloat16" else xb,
            err_msg=name)


# ---------------------------------------------------------------------------
# Atomicity + pruning
# ---------------------------------------------------------------------------

def test_save_layout_atomic_and_prune_after_durable(tmp_path):
    d = str(tmp_path / "ck")
    state = {"w": jnp.arange(4.0), "step": jnp.int32(0)}
    for s in (5, 6, 7, 8):
        path = CK.save(d, s, state, keep=2)
        assert os.path.basename(path) == f"step_{s}"
        assert {"tree.msgpack", "arrays.npz"} <= set(os.listdir(path))
        # no temp staging dir survives a completed save
        assert not [f for f in os.listdir(d) if f.startswith(".tmp_")]
    # keep=2 -> only the two newest remain, pruned after each durable save
    assert sorted(CK.latest_steps(d)) == [7, 8]
    assert CK.latest_step(d) == 8


def test_restore_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError, match="no checkpoints"):
        CK.restore(str(tmp_path / "nothing"), {"w": jnp.zeros(2)})


# ---------------------------------------------------------------------------
# Validated restores: every mismatch class names the leaf
# ---------------------------------------------------------------------------

def test_restore_validates_tree_structure(tmp_path):
    d = str(tmp_path / "ck")
    CK.save(d, 0, {"params": {"w": jnp.zeros(4)},
                   "comm": {"residual": jnp.zeros(4)}})
    with pytest.raises(ValueError, match="tree structure mismatch"):
        CK.restore(d, {"params": {"w": jnp.zeros(4)}})


def test_restore_validates_leaf_shape_names_path(tmp_path):
    d = str(tmp_path / "ck")
    CK.save(d, 0, {"params": {"w": jnp.zeros((4, 8))}})
    with pytest.raises(ValueError) as e:
        CK.restore(d, {"params": {"w": jnp.zeros((8, 8))}})
    msg = str(e.value)
    assert "['params']['w']" in msg
    assert "learner count" in msg


def test_restore_validates_leaf_dtype_names_path(tmp_path):
    d = str(tmp_path / "ck")
    CK.save(d, 0, {"params": {"w": jnp.zeros(4, jnp.bfloat16)}})
    with pytest.raises(ValueError) as e:
        CK.restore(d, {"params": {"w": jnp.zeros(4, jnp.float32)}})
    assert "['params']['w']" in str(e.value)
    assert "dtype" in str(e.value)


# ---------------------------------------------------------------------------
# state['comm'] round-trip: topk error-feedback residuals under bf16
# params are bit-exact and the next 10 steps match an uncheckpointed run
# ---------------------------------------------------------------------------

def test_topk_comm_state_roundtrip_bf16_and_next_10_steps(tmp_path):
    s = ST.get_strategy("ad_psgd")
    tr = Transport(topology="ring", wire="topk", topk_frac=0.25)
    L = 4
    params = ST.stack_for_learners({"w": jnp.zeros((8,), jnp.bfloat16)}, L)
    step = jax.jit(ST.make_train_step(s, loss_fn, sgd(), constant(0.05),
                                      n_learners=L, transport=tr))
    state = ST.init_state(s, params, sgd(), tr)
    for k in range(10):
        state, _ = step(state, data(k))
    assert set(state["comm"]) == {"residual", "estimate"}
    # residuals are non-trivial by now (difference coding has history)
    assert float(jnp.abs(state["comm"]["residual"]["w"]).max()) > 0

    CK.save(str(tmp_path), 10, state)
    like = ST.init_state(s, params, sgd(), tr)
    restored, at = CK.restore(str(tmp_path), like)
    assert at == 10
    _assert_trees_bitwise_equal(restored, state)   # incl. EF residuals

    # the next 10 steps from the restored state match the uncheckpointed
    # continuation bit-for-bit
    for k in range(10, 20):
        state, m_live = step(state, data(k))
        restored, m_ck = step(restored, data(k))
        np.testing.assert_array_equal(np.asarray(m_live["loss"]),
                                      np.asarray(m_ck["loss"]))
    _assert_trees_bitwise_equal(restored, state)


# ---------------------------------------------------------------------------
# Kill-and-resume bit-exactness for every strategy with comm state
# ---------------------------------------------------------------------------

COMM_CASES = [
    ("sd_psgd", Transport(topology="ring", wire="topk", topk_frac=0.25)),
    ("ad_psgd", Transport(topology="ring", wire="topk", topk_frac=0.25)),
    ("bmuf", Transport(topology="uniform", wire="topk", topk_frac=0.25)),
    ("hring", Transport(topology="hierarchical", pod_size=2, wire="topk",
                        topk_frac=0.25)),
]


@pytest.mark.parametrize("name,tr", COMM_CASES,
                         ids=[c[0] for c in COMM_CASES])
def test_kill_and_resume_bit_exact(name, tr, tmp_path):
    """Interrupted at step 10 and resumed from the checkpoint, the run
    matches the uninterrupted one step-for-step (losses AND final state,
    bit-for-bit) — optimizer moments and topk EF residuals included."""
    s = ST.get_strategy(name)
    L = 4
    params = ST.stack_for_learners({"w": jnp.zeros((8,))}, L)
    step = jax.jit(ST.make_train_step(s, loss_fn, momentum(),
                                      constant(0.05), n_learners=L,
                                      transport=tr))

    ref = ST.init_state(s, params, momentum(), tr)
    ref_losses = []
    for k in range(20):
        ref, m = step(ref, data(k))
        ref_losses.append(np.asarray(m["loss"]))

    # "crash" after step 10: persist, rebuild from scratch, resume
    state = ST.init_state(s, params, momentum(), tr)
    for k in range(10):
        state, _ = step(state, data(k))
    CK.save(str(tmp_path), 10, state)
    del state
    like = ST.init_state(s, params, momentum(), tr)
    state, at = CK.restore(str(tmp_path), like)
    res_losses = []
    for k in range(at, 20):
        state, m = step(state, data(k))
        res_losses.append(np.asarray(m["loss"]))

    np.testing.assert_array_equal(np.stack(ref_losses[10:]),
                                  np.stack(res_losses))
    _assert_trees_bitwise_equal(state, ref)


def test_elastic_kill_and_resume_bit_exact(tmp_path):
    """Same contract for the elastic step: the checkpoint crosses a
    crash window and a straggler schedule, and the restored run (incl.
    the staleness counters) matches the uninterrupted one bit-for-bit."""
    L = 4
    plan = FaultPlan(L, stragglers=(Straggler(0, 4),),
                     departures=(Departure(1, 6, 14),))
    s = ST.get_strategy("ad_psgd")
    tr = Transport(topology="ring", wire="bf16", staleness_lambda=0.2)
    params = ST.stack_for_learners({"w": jnp.zeros((8,))}, L)
    step = jax.jit(ST.make_elastic_train_step(
        s, loss_fn, momentum(), constant(0.05), n_learners=L,
        transport=tr))

    def faults(k):
        return {kk: jnp.asarray(v) for kk, v in plan.step_inputs(k).items()}

    ref = ST.init_elastic_state(s, params, momentum(), tr)
    for k in range(20):
        ref, m_ref = step(ref, data(k), faults(k))

    state = ST.init_elastic_state(s, params, momentum(), tr)
    for k in range(10):
        state, _ = step(state, data(k), faults(k))
    CK.save(str(tmp_path), 10, state)
    like = ST.init_elastic_state(s, params, momentum(), tr)
    state, at = CK.restore(str(tmp_path), like)
    for k in range(at, 20):
        state, m_res = step(state, data(k), faults(k))

    _assert_trees_bitwise_equal(state, ref)
    np.testing.assert_array_equal(np.asarray(m_ref["loss"]),
                                  np.asarray(m_res["loss"]))


# ---------------------------------------------------------------------------
# The real CLI under a fault plan: kill-and-resume reproduces the
# uninterrupted run's final loss exactly (data cursor included)
# ---------------------------------------------------------------------------

def _train(extra, timeout=420):
    args = ["repro.launch.train", "--arch", "swb2000-blstm", "--reduced",
            "--learners", "4", "--strategy", "ad_psgd", "--optimizer",
            "momentum", "--log-every", "7",
            "--comm-staleness-lambda", "0.2",
            "--fault-stragglers", "0:4", "--fault-departures", "1:4:9",
            ] + extra
    return subprocess.run([sys.executable, "-m"] + args, cwd=REPO, env=ENV,
                          capture_output=True, text=True, timeout=timeout)


def _final_loss(stdout):
    lines = [l for l in stdout.splitlines() if l.startswith("final loss")]
    assert lines, stdout[-2000:]
    return lines[-1]


def test_cli_kill_and_resume_under_faults(tmp_path):
    full = _train(["--steps", "14"])
    assert full.returncode == 0, full.stderr[-2000:]
    assert "FaultPlan(L=4" in full.stdout         # banner printed
    assert "act 3/4" in full.stdout               # crash window visible

    ck = str(tmp_path / "ck")
    first = _train(["--steps", "7", "--ckpt-dir", ck, "--ckpt-every", "7"])
    assert first.returncode == 0, first.stderr[-2000:]
    second = _train(["--steps", "14", "--ckpt-dir", ck, "--ckpt-every",
                     "14", "--resume"])
    assert second.returncode == 0, second.stderr[-2000:]
    assert _final_loss(second.stdout) == _final_loss(full.stdout)


def test_cli_resume_without_checkpoint_fails():
    r = _train(["--steps", "2", "--resume", "--ckpt-dir",
                "/tmp/definitely-not-a-ckpt-dir"])
    assert r.returncode != 0
    assert "no checkpoint" in (r.stderr + r.stdout)
