"""Fault injection + elastic membership (docs/fault_tolerance.md).

Covers the three layers of the resilience stack: the deterministic
FaultPlan schedule, the elastic mixing matrices (doubly stochastic over
any live set), and the elastic train step (parity with the plain step
when nothing fails; convergence and frozen-dead-learner semantics when
things do)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import mixing
from repro.core import strategies as ST
from repro.core.faults import (Departure, FaultPlan, Straggler,
                               parse_departures, parse_stragglers)
from repro.core.transport import Transport
from repro.optim.optimizers import momentum, sgd
from repro.optim.schedules import constant

W_TRUE = jax.random.normal(jax.random.PRNGKey(7), (8,))


def loss_fn(params, batch):
    pred = batch["x"] @ params["w"]
    return jnp.mean((pred - batch["y"]) ** 2)


def data(seed, n=64):
    x = jax.random.normal(jax.random.PRNGKey(seed), (n, 8))
    return {"x": x, "y": x @ W_TRUE}


# ---------------------------------------------------------------------------
# FaultPlan: deterministic, validated, serializable
# ---------------------------------------------------------------------------

def test_fault_plan_deterministic_and_serializable():
    plan = FaultPlan(8, seed=3, stragglers=(Straggler(0, 4),),
                     departures=(Departure(1, 30, 60),),
                     drop_prob=0.2, stall_prob=0.05,
                     corrupt_prob=0.1, corrupt_scale=0.05)
    twin = FaultPlan.from_dict(plan.to_dict())
    for step in (0, 7, 31, 60, 200):
        a, b = plan.step_inputs(step), twin.step_inputs(step)
        assert set(a) == {"active", "contrib", "rejoin", "edge_ok",
                          "corrupt"}
        for k in a:
            np.testing.assert_array_equal(a[k], b[k])
    # a different seed changes the stochastic parts
    other = FaultPlan.from_dict({**plan.to_dict(), "seed": 4})
    assert any(
        not np.array_equal(plan.step_inputs(s)["edge_ok"],
                           other.step_inputs(s)["edge_ok"])
        for s in range(10))


def test_fault_plan_schedules():
    plan = FaultPlan(8, stragglers=(Straggler(0, 4, phase=0),),
                     departures=(Departure(1, 30, 60), Departure(2, 50)))
    # straggler contributes only every 4th step
    assert plan.step_inputs(4)["contrib"][0] == 1.0
    assert plan.step_inputs(5)["contrib"][0] == 0.0
    # crash window [30, 60); learner 2 never returns
    assert plan.step_inputs(29)["active"][1] == 1.0
    assert plan.step_inputs(30)["active"][1] == 0.0
    assert plan.step_inputs(60)["active"][1] == 1.0
    assert plan.step_inputs(60)["rejoin"][1] == 1.0
    assert plan.step_inputs(59)["rejoin"][1] == 0.0
    assert plan.step_inputs(500)["active"][2] == 0.0
    # perfsim views
    np.testing.assert_array_equal(plan.speed_factors(),
                                  [4, 1, 1, 1, 1, 1, 1, 1])


def test_fault_plan_validation():
    with pytest.raises(ValueError, match="ZERO active"):
        FaultPlan(2, departures=(Departure(0, 5), Departure(1, 5)))
    with pytest.raises(ValueError, match="rejoin"):
        FaultPlan(2, departures=(Departure(0, 5, 5),))
    with pytest.raises(ValueError, match="out of range"):
        FaultPlan(2, stragglers=(Straggler(5, 2),))
    with pytest.raises(ValueError, match="drop_prob"):
        FaultPlan(2, drop_prob=1.5)
    # staggered departures with rejoins are fine
    FaultPlan(2, departures=(Departure(0, 5, 10), Departure(1, 10, 15)))


def test_fault_plan_edge_ok_symmetric():
    plan = FaultPlan(8, drop_prob=0.3)
    eo = plan.step_inputs(3)["edge_ok"]
    np.testing.assert_array_equal(eo, eo.T)
    np.testing.assert_array_equal(np.diag(eo), np.ones(8))
    assert (eo == 0).any()   # at p=0.3 over 28 edges this is near-certain


def test_fault_spec_parsers():
    assert parse_stragglers("0:4, 3:2") == (Straggler(0, 4),
                                            Straggler(3, 2))
    assert parse_departures("1:30:60,2:50") == (Departure(1, 30, 60),
                                                Departure(2, 50, -1))
    assert parse_stragglers("") == ()
    with pytest.raises(ValueError, match="straggler"):
        parse_stragglers("0:4:9")
    with pytest.raises(ValueError, match="departure"):
        parse_departures("1")


# ---------------------------------------------------------------------------
# Elastic mixing matrices
# ---------------------------------------------------------------------------

MASKS = [np.ones(8, np.float32),
         np.array([1, 0, 1, 1, 1, 1, 0, 1], np.float32),
         np.array([1, 1, 0, 0, 0, 0, 0, 0], np.float32),
         np.array([1, 0, 0, 0, 0, 0, 0, 0], np.float32)]


@pytest.mark.parametrize("topology", ["ring", "uniform", "exp",
                                      "hierarchical", "none"])
def test_elastic_matrix_doubly_stochastic_and_freezes_dead(topology):
    for mask in MASKS:
        T = np.asarray(mixing.elastic_matrix(mask, topology, step=3,
                                             pod_size=4))
        assert mixing.is_doubly_stochastic(T, atol=1e-4), (topology, mask)
        for i in np.where(mask == 0)[0]:     # dead learners are identity
            e = np.zeros(8)
            e[i] = 1
            np.testing.assert_allclose(T[i], e, atol=1e-5)
            np.testing.assert_allclose(T[:, i], e, atol=1e-4)


def test_elastic_matrices_match_static_when_all_active():
    ones = np.ones(8, np.float32)
    np.testing.assert_allclose(np.asarray(mixing.elastic_ring_matrix(ones)),
                               mixing.ring_matrix(8), atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(mixing.elastic_uniform_matrix(ones)),
        mixing.uniform_matrix(8), atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(mixing.elastic_hierarchical_matrix(ones, 4)),
        mixing.hierarchical_matrix(8, 4), atol=1e-5)


def test_elastic_ring_two_survivors_degenerate():
    """Two survivors of eight reproduce the L=2 ring [2/3, 1/3] case."""
    T = np.asarray(mixing.elastic_ring_matrix(
        np.array([1, 0, 0, 1, 0, 0, 0, 0], np.float32)))
    assert T[0, 3] == pytest.approx(1 / 3)
    assert T[0, 0] == pytest.approx(2 / 3)


def test_elastic_exp_consensus_over_survivors():
    """4 live of 8: two exp rounds reach exact consensus (hypercube)."""
    mask = np.array([1, 1, 0, 1, 0, 0, 1, 0], np.float32)
    P = (np.asarray(mixing.elastic_exp_matrix(mask, 1))
         @ np.asarray(mixing.elastic_exp_matrix(mask, 0)))
    live = mask == 1
    np.testing.assert_allclose(P[np.ix_(live, live)],
                               np.full((4, 4), 0.25), atol=1e-5)


def test_staleness_damping_downweights_and_stays_ds():
    s = np.array([0, 5, 0, 0, 0, 0, 0, 0], np.float32)
    T = np.asarray(mixing.elastic_matrix(np.ones(8, np.float32), "ring",
                                         staleness=s,
                                         staleness_lambda=0.5))
    base = np.asarray(mixing.ring_matrix(8))
    assert mixing.is_doubly_stochastic(T)
    assert T[0, 1] < base[0, 1]          # stale learner's influence damped
    assert T[2, 1] < base[2, 1]
    assert T[4, 5] == pytest.approx(base[4, 5], abs=1e-6)  # fresh untouched
    # λ = 0 is the identity transform
    T0 = np.asarray(mixing.elastic_matrix(np.ones(8, np.float32), "ring",
                                          staleness=s, staleness_lambda=0.0))
    np.testing.assert_allclose(T0, base, atol=1e-6)


def test_edge_mask_drops_and_stays_ds():
    eo = np.ones((8, 8), np.float32)
    eo[0, 1] = eo[1, 0] = 0
    T = np.asarray(mixing.elastic_matrix(np.ones(8, np.float32), "ring",
                                         edge_ok=eo))
    assert T[0, 1] == 0 and T[1, 0] == 0
    assert mixing.is_doubly_stochastic(T)


# ---------------------------------------------------------------------------
# Elastic train step
# ---------------------------------------------------------------------------

def _no_faults(L):
    return {k: jnp.asarray(v)
            for k, v in FaultPlan(L).no_fault_inputs().items()}


@pytest.mark.parametrize("name", ["sd_psgd", "ad_psgd",
                                  "sc_psgd_replicated", "downpour",
                                  "hring", "bmuf"])
def test_elastic_step_matches_plain_without_faults(name):
    """With everyone active and contributing, the elastic step walks the
    plain step's trajectory (matrix contraction vs rolls: f32 matmul
    tolerance, not bit-exact).  exp is excluded by design — its elastic
    matrix is the symmetrized one-peer graph (transport docstring)."""
    s = ST.get_strategy(name)
    L = 8
    params = ST.stack_for_learners({"w": jnp.zeros((8,))}, L)
    tr = ST.default_transport(s)
    st_p = ST.init_state(s, params, sgd(), tr)
    st_e = ST.init_elastic_state(s, params, sgd(), tr)
    plain = jax.jit(ST.make_train_step(s, loss_fn, sgd(), constant(0.05),
                                       n_learners=L, transport=tr))
    el = jax.jit(ST.make_elastic_train_step(
        s, loss_fn, sgd(), constant(0.05), n_learners=L, transport=tr))
    nf = _no_faults(L)
    for k in range(40):
        st_p, _ = plain(st_p, data(k))
        st_e, m = el(st_e, data(k), nf)
    np.testing.assert_allclose(np.asarray(st_e["params"]["w"]),
                               np.asarray(st_p["params"]["w"]), atol=2e-5)
    assert float(m["n_active"]) == L
    assert int(m["staleness_max"]) == 0


def test_elastic_converges_under_straggler_and_crash():
    """The acceptance-criteria fault plan at test scale: 1 of 8
    straggling 4×, one crash/rejoin — AD-PSGD with staleness-aware
    mixing still reaches the optimum, the dead learner's replica is
    frozen bit-for-bit, and the rejoiner re-enters at the survivors'
    consensus."""
    L = 8
    plan = FaultPlan(L, stragglers=(Straggler(0, 4),),
                     departures=(Departure(1, 30, 60),))
    s = ST.get_strategy("ad_psgd")
    tr = Transport(topology="ring", staleness_lambda=0.2)
    params = ST.stack_for_learners({"w": jnp.zeros((8,))}, L)
    state = ST.init_elastic_state(s, params, sgd(), tr)
    el = jax.jit(ST.make_elastic_train_step(
        s, loss_fn, sgd(), constant(0.05), n_learners=L, transport=tr,
        with_consensus=True))
    for k in range(300):
        before = np.asarray(state["params"]["w"][1])
        state, m = el(state, data(k), {kk: jnp.asarray(v) for kk, v in
                                       plan.step_inputs(k).items()})
        if 31 <= k < 60:                 # dead: frozen bit-for-bit
            np.testing.assert_array_equal(
                np.asarray(state["params"]["w"][1]), before)
        if k == 60:                      # rejoined at incumbents' mean
            assert not np.array_equal(
                np.asarray(state["params"]["w"][1]), before)
    final = ST.average_learners(state["params"])
    assert float(jnp.linalg.norm(final["w"] - W_TRUE)) < 0.05
    assert float(m["consensus"]) < 0.05
    assert np.isfinite(float(m["loss"]))


def test_elastic_converges_with_drops_corruption_bf16():
    """Wire-level weather (bf16 codec + dropped edges + corrupted
    payloads) with a momentum optimizer still converges near the
    optimum — corruption only ever poisons the peer view."""
    L = 8
    plan = FaultPlan(L, seed=3, stragglers=(Straggler(2, 2),),
                     drop_prob=0.1, corrupt_prob=0.1, corrupt_scale=0.05)
    s = ST.get_strategy("ad_psgd")
    tr = Transport(topology="ring", wire="bf16", staleness_lambda=0.1)
    params = ST.stack_for_learners({"w": jnp.zeros((8,))}, L)
    state = ST.init_elastic_state(s, params, momentum(), tr)
    el = jax.jit(ST.make_elastic_train_step(
        s, loss_fn, momentum(), constant(0.02), n_learners=L, transport=tr,
        fault_seed=3, with_corruption=True))
    for k in range(300):
        state, m = el(state, data(k), {kk: jnp.asarray(v) for kk, v in
                                       plan.step_inputs(k).items()})
    final = ST.average_learners(state["params"])
    assert float(jnp.linalg.norm(final["w"] - W_TRUE)) < 0.15


def test_elastic_staleness_counters_track_stragglers():
    L = 4
    plan = FaultPlan(L, stragglers=(Straggler(0, 4),))
    s = ST.get_strategy("sd_psgd")
    state = ST.init_elastic_state(s, ST.stack_for_learners(
        {"w": jnp.zeros((8,))}, L), sgd())
    el = jax.jit(ST.make_elastic_train_step(
        s, loss_fn, sgd(), constant(0.05), n_learners=L))
    for k in range(6):
        state, m = el(state, data(k), {kk: jnp.asarray(v) for kk, v in
                                       plan.step_inputs(k).items()})
    # steps 0..5: learner 0 contributed at 0 and 4 only -> staleness 1
    # after step 5 (k=5 missed); fresh learners at 0
    st = np.asarray(state["staleness"])
    assert st[0] == 1 and (st[1:] == 0).all()
    assert int(m["n_contrib"]) == 3


# ---------------------------------------------------------------------------
# Guards (the all-inactive edge and unsupported configurations)
# ---------------------------------------------------------------------------

def test_check_active_and_split_guard():
    with pytest.raises(ValueError, match="no active learners"):
        ST.check_active(np.zeros(4))
    assert ST.check_active(np.array([0, 1, 0, 1])) == 2
    with pytest.raises(ValueError, match="empty learner set"):
        ST.split_learner_batch({"x": jnp.zeros((8, 2))}, 0)


def test_elastic_rejects_topk_and_non_replicated():
    with pytest.raises(ValueError, match="difference-coded"):
        Transport(topology="ring", wire="topk").make_elastic_mixer(8)
    with pytest.raises(ValueError, match="not replicated"):
        ST.make_elastic_train_step(ST.get_strategy("sc_psgd"), loss_fn,
                                   sgd(), constant(0.1), n_learners=1)
