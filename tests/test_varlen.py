"""Variable-length utterances end-to-end: the ``lengths`` batch contract.

Masked-loss/grad parity against the unpadded per-utterance reference on
both kernel paths, frame-weighted distributed aggregation, the bucketed
loader, CTC input masking, and the Prefetcher lifecycle fixes.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core import strategies as ST
from repro.data import make_dataset
from repro.data.pipeline import Prefetcher, SyntheticASRDataset
from repro.kernels import ref
from repro.kernels.lstm_cell import blstm_sequence, lstm_sequence
from repro.models import build_model
from repro.models import lstm as LS
from repro.models.common import cross_entropy, sequence_mask
from repro.optim.optimizers import sgd
from repro.optim.schedules import constant
from repro.sharding import init_spec_tree

KEY = jax.random.PRNGKey(7)


def _mk(shape, dtype=jnp.float32, i=0, scale=1.0):
    return (jax.random.normal(jax.random.fold_in(KEY, i), shape,
                              jnp.float32) * scale).astype(dtype)


def _norm_close(got, want, tol, name=""):
    scale = float(jnp.abs(jnp.asarray(want, jnp.float32)).max()) + 1e-8
    np.testing.assert_allclose(np.asarray(got, np.float32) / scale,
                               np.asarray(want, np.float32) / scale,
                               atol=tol, err_msg=name)


def _mk_lstm(D, H, dtype, base):
    wx = _mk((D, 4 * H), dtype, base, 0.3)
    wh = _mk((H, 4 * H), dtype, base + 1, 0.3)
    b = _mk((4 * H,), jnp.float32, base + 2, 0.1)
    return wx, wh, b


def _masked_x(B, T, D, lengths, dtype=jnp.float32, i=0):
    x = _mk((B, T, D), dtype, i)
    return x * sequence_mask(lengths, T)[..., None].astype(x.dtype)


# ---------------------------------------------------------------------------
# shared mask utility + masked cross entropy
# ---------------------------------------------------------------------------

def test_sequence_mask():
    m = sequence_mask(jnp.asarray([0, 2, 4]), 4)
    np.testing.assert_array_equal(
        np.asarray(m), [[0, 0, 0, 0], [1, 1, 0, 0], [1, 1, 1, 1]])


def test_masked_cross_entropy_matches_unpadded():
    B, T, V = 3, 6, 11
    logits = _mk((B, T, V), i=1)
    labels = jax.random.randint(KEY, (B, T), 0, V)
    lengths = jnp.asarray([6, 2, 4], jnp.int32)
    got = cross_entropy(logits, labels, mask=sequence_mask(lengths, T))
    # reference: pooled mean over each row's valid prefix
    parts, n = [], 0
    for u in range(B):
        L = int(lengths[u])
        parts.append(float(cross_entropy(logits[u:u + 1, :L],
                                         labels[u:u + 1, :L])) * L)
        n += L
    np.testing.assert_allclose(float(got), sum(parts) / n, rtol=1e-6)
    # all-True mask == plain mean
    full = cross_entropy(logits, labels,
                         mask=jnp.ones((B, T), bool))
    np.testing.assert_allclose(float(full),
                               float(cross_entropy(logits, labels)),
                               rtol=1e-6)


# ---------------------------------------------------------------------------
# variable-length dataset + bucketed batching
# ---------------------------------------------------------------------------

def test_varlen_dataset_contract():
    ds = SyntheticASRDataset(input_dim=12, n_classes=40, seq_len=32,
                             batch=4, seed=3, var_len=True)
    b = ds.batch_at(5)
    assert set(b) == {"features", "labels", "lengths"}
    B, T, D = b["features"].shape
    assert (B, T, D) == (4, 32, 12)
    assert b["lengths"].dtype == np.int32
    assert (b["lengths"] >= ds.min_len).all()
    assert (b["lengths"] <= 32).all()
    for u in range(B):
        L = int(b["lengths"][u])
        assert np.all(b["features"][u, L:] == 0)
        assert np.all(b["labels"][u, L:] == 0)
    # deterministic
    b2 = ds.batch_at(5)
    for k in b:
        np.testing.assert_array_equal(b[k], b2[k])


def test_bucketed_batching_same_workload_less_padding():
    kw = dict(input_dim=8, n_classes=20, seq_len=64, batch=4, seed=1,
              var_len=True, bucket_window=8)
    fixed = SyntheticASRDataset(**kw)
    buck = SyntheticASRDataset(**kw, bucket=True)
    W = kw["bucket_window"]
    lens_f, lens_b, pad_f, pad_b = [], [], 0, 0
    for s in range(W):
        bf, bb = fixed.batch_at(s), buck.batch_at(s)
        lens_f += list(bf["lengths"])
        lens_b += list(bb["lengths"])
        pad_f += bf["features"].shape[0] * bf["features"].shape[1]
        pad_b += bb["features"].shape[0] * bb["features"].shape[1]
        # bucketed batches pad to their own rounded max length
        assert bb["features"].shape[1] >= bb["lengths"].max()
        assert (bb["features"].shape[1] % buck.pad_multiple == 0
                or bb["features"].shape[1] == kw["seq_len"])
    # same utterance-length multiset over the shuffle window...
    assert sorted(lens_f) == sorted(lens_b)
    # ...but strictly less padding
    assert pad_b < pad_f


def test_make_dataset_varlen_dispatch():
    cfg = get_arch("swb2000-blstm").reduced()
    ds = make_dataset(cfg, seq_len=24, batch=4, seed=0, var_len=True,
                      bucket=True)
    assert "lengths" in ds.batch_at(0)
    with pytest.raises(ValueError):
        make_dataset(get_arch("smollm-360m").reduced(), seq_len=8,
                     batch=2, var_len=True)


# ---------------------------------------------------------------------------
# masked recurrence: jax scan vs per-utterance unpadded reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("reverse", [False, True])
def test_masked_scan_matches_per_utterance(reverse):
    B, T, D, H = 4, 9, 8, 16
    wx, wh, b = _mk_lstm(D, H, jnp.float32, 10)
    lengths = jnp.asarray([9, 3, 7, 1], jnp.int32)
    x = _masked_x(B, T, D, lengths, i=13)
    out = ref.lstm_ref(wx, wh, b, x, reverse=reverse, lengths=lengths)
    for u in range(B):
        L = int(lengths[u])
        want = ref.lstm_ref(wx, wh, b, x[u:u + 1, :L], reverse=reverse)
        _norm_close(out[u:u + 1, :L], want, 1e-5, f"utt {u}")
        assert np.all(np.asarray(out[u, L:]) == 0)


# ---------------------------------------------------------------------------
# masked Pallas kernels vs the masked scan oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
@pytest.mark.parametrize("reverse", [False, True])
def test_masked_lstm_kernel_grad_parity(reverse, dtype):
    B, T, D, H = 5, 7, 8, 16
    wx, wh, b = _mk_lstm(D, H, dtype, 20)
    lengths = jnp.asarray([7, 2, 5, 1, 4], jnp.int32)
    x = _masked_x(B, T, D, lengths, dtype, 23)

    def loss_k(wx, wh, b, x):
        y = lstm_sequence(wx, wh, b, x, lengths, reverse=reverse,
                          interpret=True, block_b=2)
        return jnp.mean(jnp.square(y.astype(jnp.float32)))

    def loss_r(wx, wh, b, x):
        y = ref.lstm_ref(wx, wh, b, x, reverse=reverse, lengths=lengths)
        return jnp.mean(jnp.square(y.astype(jnp.float32)))

    v_k, g_k = jax.value_and_grad(loss_k, argnums=(0, 1, 2, 3))(wx, wh, b, x)
    v_r, g_r = jax.value_and_grad(loss_r, argnums=(0, 1, 2, 3))(wx, wh, b, x)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(float(v_k), float(v_r), rtol=tol)
    for got, want, name in zip(g_k, g_r, ("dwx", "dwh", "db", "dx")):
        assert got.dtype == want.dtype
        _norm_close(got, want, tol, name)


def test_masked_blstm_kernel_parity_and_full_length_equivalence():
    B, T, D, H = 4, 6, 8, 16
    wxf, whf, bf = _mk_lstm(D, H, jnp.bfloat16, 30)
    wxb, whb, bb = _mk_lstm(D, H, jnp.bfloat16, 34)
    lengths = jnp.asarray([6, 3, 5, 2], jnp.int32)
    x = _masked_x(B, T, D, lengths, jnp.bfloat16, 38)

    fused = blstm_sequence(wxf, whf, bf, wxb, whb, bb, x, lengths,
                           interpret=True, block_b=2)
    want = ref.blstm_ref(wxf, whf, bf, wxb, whb, bb, x, lengths)
    _norm_close(fused, want, 2e-2)

    # full lengths == the unmasked kernel, bit for bit
    full = jnp.full((B,), T, jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(blstm_sequence(wxf, whf, bf, wxb, whb, bb, x, full,
                                  interpret=True), np.float32),
        np.asarray(blstm_sequence(wxf, whf, bf, wxb, whb, bb, x,
                                  interpret=True), np.float32))

    def loss_k(*w):
        y = blstm_sequence(*w, lengths, interpret=True, block_b=2)
        return jnp.mean(jnp.square(y.astype(jnp.float32)))

    def loss_r(*w):
        return jnp.mean(jnp.square(
            ref.blstm_ref(*w, lengths).astype(jnp.float32)))

    args = (wxf, whf, bf, wxb, whb, bb, x)
    v_k, g_k = jax.value_and_grad(loss_k, argnums=tuple(range(7)))(*args)
    v_r, g_r = jax.value_and_grad(loss_r, argnums=tuple(range(7)))(*args)
    np.testing.assert_allclose(float(v_k), float(v_r), rtol=2e-2)
    for got, want, name in zip(
            g_k, g_r, ("dwxf", "dwhf", "dbf", "dwxb", "dwhb", "dbb", "dx")):
        _norm_close(got, want, 2e-2, name)


def test_bf16_residual_stash_grad_parity():
    """ROADMAP open item: bf16 gate/cell stash halves the residual HBM at
    a relaxed (but bounded) gradient-parity tolerance."""
    B, T, D, H = 4, 8, 8, 16
    wx, wh, b = _mk_lstm(D, H, jnp.float32, 40)
    x = _mk((B, T, D), jnp.float32, 43)

    def loss(stash):
        def f(wx, wh, b, x):
            y = lstm_sequence(wx, wh, b, x, interpret=True,
                              stash_dtype=stash)
            return jnp.mean(jnp.square(y.astype(jnp.float32)))
        return f

    def loss_r(wx, wh, b, x):
        return jnp.mean(jnp.square(
            ref.lstm_ref(wx, wh, b, x).astype(jnp.float32)))

    v16, g16 = jax.value_and_grad(loss("bfloat16"),
                                  argnums=(0, 1, 2, 3))(wx, wh, b, x)
    v_r, g_r = jax.value_and_grad(loss_r, argnums=(0, 1, 2, 3))(wx, wh, b, x)
    # forward output is unaffected (stash only feeds the backward)
    np.testing.assert_allclose(float(v16), float(v_r), rtol=1e-5)
    for got, want, name in zip(g16, g_r, ("dwx", "dwh", "db", "dx")):
        _norm_close(got, want, 2e-2, name)


# ---------------------------------------------------------------------------
# end-to-end masked-loss parity (model + strategy layers)
# ---------------------------------------------------------------------------

def _tiny_cfg():
    return dataclasses.replace(
        get_arch("swb2000-blstm").reduced(), n_layers=1, lstm_hidden=16,
        lstm_bottleneck=8, input_dim=12, vocab=32, lstm_block_b=2)


def _varlen_batch(cfg, B=4, T=10, seed=0):
    ds = SyntheticASRDataset(input_dim=cfg.input_dim, n_classes=cfg.vocab,
                             seq_len=T, batch=B, seed=seed, var_len=True,
                             bucket=True, bucket_window=2, min_len=2)
    return ds.batch_at(1)


@pytest.mark.parametrize("kernel_impl,param_dtype,tol", [
    ("jax", "float32", 1e-4),      # f32 grads: tight
    ("jax", "bfloat16", 2e-2),     # bf16 grad leaves round at ~4e-3
    ("pallas", "bfloat16", 2e-2),
])
def test_masked_loss_matches_per_utterance_reference(kernel_impl,
                                                     param_dtype, tol):
    """Acceptance: padded/bucketed batch loss and grads == the pooled
    per-utterance unpadded reference, on both kernel paths."""
    cfg = dataclasses.replace(_tiny_cfg(), param_dtype=param_dtype)
    model = build_model(cfg)
    params = init_spec_tree(model.param_specs(), jax.random.PRNGKey(0))
    batch = _varlen_batch(cfg)
    lengths = batch["lengths"]

    def padded_loss(p):
        return model.loss_fn(p, batch, kernel_impl=kernel_impl)

    def per_utt_loss(p):
        # sum of per-frame CE over every utterance / total valid frames
        tot, n = jnp.float32(0.0), 0
        for u in range(len(lengths)):
            L = int(lengths[u])
            logits = LS.forward(cfg, p, batch["features"][u:u + 1, :L],
                                kernel_impl=kernel_impl)
            tot = tot + cross_entropy(logits,
                                      batch["labels"][u:u + 1, :L]) * L
            n += L
        return tot / n

    v_m, g_m = jax.value_and_grad(padded_loss)(params)
    v_u, g_u = jax.value_and_grad(per_utt_loss)(params)
    np.testing.assert_allclose(float(v_m), float(v_u), rtol=max(tol, 1e-5))
    flat_m, treedef = jax.tree.flatten(g_m)
    flat_u, _ = jax.tree.flatten(g_u)
    for got, want in zip(flat_m, flat_u):
        _norm_close(got, want, tol, str(treedef))


def test_masked_ad_psgd_step_pallas_matches_jax_under_vmap():
    """Acceptance: the replicated ad_psgd step (vmap over learners) on a
    padded bucketed batch agrees between kernel_impl jax and pallas."""
    cfg = _tiny_cfg()
    model = build_model(cfg)
    params = init_spec_tree(model.param_specs(), jax.random.PRNGKey(1))
    batch = _varlen_batch(cfg, B=4, seed=2)
    strategy = ST.get_strategy("ad_psgd")
    opt = sgd()

    states = {}
    for impl in ("jax", "pallas"):
        step = ST.make_train_step(
            strategy,
            lambda p, bt, impl=impl: model.loss_fn(p, bt, kernel_impl=impl),
            opt, constant(0.05), n_learners=2)
        state = ST.init_state(strategy,
                              ST.stack_for_learners(params, 2), opt)
        jit_step = jax.jit(step)
        for _ in range(2):
            state, metrics = jit_step(state, batch)
        assert np.isfinite(float(metrics["loss"]))
        states[impl] = state
    flat_j = jax.tree.leaves(states["jax"]["params"])
    flat_p = jax.tree.leaves(states["pallas"]["params"])
    for a, b in zip(flat_j, flat_p):
        _norm_close(b, a, 2e-2)


# ---------------------------------------------------------------------------
# frame-weighted distributed aggregation
# ---------------------------------------------------------------------------

def _linear_masked_loss(params, batch):
    pred = jnp.einsum("btd,d->bt", batch["x"], params["w"])
    err = jnp.square(pred - batch["y"])
    m = sequence_mask(batch["lengths"], batch["x"].shape[1])
    return jnp.sum(err * m) / jnp.maximum(jnp.sum(m), 1)


def _linear_batch(B=4, T=6, D=8, seed=0):
    r = np.random.default_rng(seed)
    lengths = np.asarray([6, 1, 3, 2], np.int32)
    x = r.normal(size=(B, T, D)).astype(np.float32)
    y = r.normal(size=(B, T)).astype(np.float32)
    m = np.arange(T)[None, :] < lengths[:, None]
    return {"x": x * m[..., None], "y": y * m, "lengths": lengths}


def test_frame_weighted_aggregation_equals_global_masked_grad():
    """With frame weighting, the uniform combination of per-learner
    masked-mean grads equals the gradient of the GLOBAL masked loss —
    learners holding more valid frames contribute proportionally."""
    L = 2
    batch = _linear_batch()
    params = {"w": jnp.zeros((8,))}
    strat = ST.get_strategy("sc_psgd_replicated")
    state = ST.init_state(strat, ST.stack_for_learners(params, L), sgd())
    lr = 0.1
    step = jax.jit(ST.make_train_step(strat, _linear_masked_loss, sgd(),
                                      constant(lr), n_learners=L))
    new_state, metrics = step(state, batch)
    avg = ST.average_learners(new_state["params"])

    g_global = jax.grad(_linear_masked_loss)(params, batch)
    np.testing.assert_allclose(np.asarray(avg["w"]),
                               np.asarray(params["w"] - lr * g_global["w"]),
                               atol=1e-6)
    # reported loss is the frame-weighted (= global masked) mean
    np.testing.assert_allclose(float(metrics["loss"]),
                               float(_linear_masked_loss(params, batch)),
                               rtol=1e-6)


def test_microbatch_accumulation_frame_weighted():
    """Frame-weighted microbatch accumulation == full-batch masked grad
    (mean-of-means would be wrong when microbatch frame counts differ)."""
    batch = _linear_batch(seed=5)
    params = {"w": jnp.arange(8, dtype=jnp.float32) * 0.1}
    l1, g1 = ST._accumulated_grad(_linear_masked_loss, params, batch, 1)
    l2, g2 = ST._accumulated_grad(_linear_masked_loss, params, batch, 2)
    np.testing.assert_allclose(float(l2), float(l1), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(g2["w"]), np.asarray(g1["w"]),
                               atol=1e-6)


def test_hring_pre_split_frame_weighted_aggregation():
    """hring with ``pre_split=True`` (the multi-pod layout: batch arrives
    already (L, B/L, ...) on the pod axis) + variable-length batches:
    frame-weighted aggregation across the pod axis must match both the
    flat-batch path bit-for-bit and the explicit Eq.-14 reference."""
    from repro.core import mixing

    L, lr = 2, 0.1
    strat = ST.get_strategy("hring")
    batch = _linear_batch()                     # lengths [6, 1, 3, 2]
    pre = ST.split_learner_batch(batch, L)
    params = {"w": jnp.arange(8, dtype=jnp.float32) * 0.1}
    stacked = ST.stack_for_learners(params, L)

    step_flat = jax.jit(ST.make_train_step(
        strat, _linear_masked_loss, sgd(), constant(lr), n_learners=L))
    step_pre = jax.jit(ST.make_train_step(
        strat, _linear_masked_loss, sgd(), constant(lr), n_learners=L,
        pre_split=True))

    s_flat = ST.init_state(strat, stacked, sgd())
    s_pre = ST.init_state(strat, stacked, sgd())
    for k in range(3):                          # staleness kicks in at k>0
        s_flat, m_flat = step_flat(s_flat, batch)
        s_pre, m_pre = step_pre(s_pre, pre)
    np.testing.assert_array_equal(np.asarray(s_flat["params"]["w"]),
                                  np.asarray(s_pre["params"]["w"]))
    np.testing.assert_array_equal(np.asarray(m_flat["loss"]),
                                  np.asarray(m_pre["loss"]))

    # one-step Eq.-14 reference: mixing over the pod axis (hring default
    # pod_size=1 -> T_1 ring) of the CURRENT iterate, frame-weighted
    # stale gradients (hring grads at W_{k-1} = initial params here)
    s0 = ST.init_state(strat, stacked, sgd())
    s1, m1 = step_pre(s0, pre)
    g_l = jax.vmap(jax.grad(_linear_masked_loss))(stacked, pre)
    frames = np.asarray(pre["lengths"].sum(axis=1), np.float32)
    wgt = frames / frames.mean()
    mixed = mixing.mix_ring(stacked)
    ref = np.asarray(mixed["w"]) - lr * wgt[:, None] * np.asarray(g_l["w"])
    np.testing.assert_allclose(np.asarray(s1["params"]["w"]), ref,
                               atol=1e-6)
    # reported loss is the frame-weighted (= global masked) mean
    np.testing.assert_allclose(float(m1["loss"]),
                               float(_linear_masked_loss(params, batch)),
                               rtol=1e-5)


# ---------------------------------------------------------------------------
# CTC input-length masking
# ---------------------------------------------------------------------------

def test_ctc_input_lengths_match_truncated():
    from repro.models.ctc import ctc_loss

    rng = np.random.default_rng(11)
    T, V = 7, 5
    logits = jnp.asarray(rng.normal(size=(2, T, V)), jnp.float32)
    labs = jnp.asarray([[1, 2, -1], [3, 1, 4]], jnp.int32)
    lens = jnp.asarray([4, 7], jnp.int32)
    got = float(ctc_loss(logits, labs, input_lengths=lens))
    want = np.mean([
        float(ctc_loss(logits[0:1, :4], labs[0:1])),
        float(ctc_loss(logits[1:2, :7], labs[1:2])),
    ])
    np.testing.assert_allclose(got, want, rtol=1e-5)


# ---------------------------------------------------------------------------
# Prefetcher lifecycle
# ---------------------------------------------------------------------------

class _FailingDataset:
    def __init__(self, fail_at=2):
        self.fail_at = fail_at

    def batch_at(self, step):
        if step >= self.fail_at:
            raise ValueError(f"synthesis failed at step {step}")
        return {"x": np.full((2,), step, np.float32)}


def test_prefetcher_reraises_worker_exception():
    pf = Prefetcher(_FailingDataset(fail_at=2), depth=2)
    try:
        # already-synthesized batches drain first...
        assert pf.next()["x"][0] == 0
        assert pf.next()["x"][0] == 1
        # ...then the worker's exception surfaces instead of a hang
        with pytest.raises(RuntimeError) as ei:
            pf.next()
        assert isinstance(ei.value.__cause__, ValueError)
    finally:
        pf.close()


def test_prefetcher_close_joins_worker():
    ds = SyntheticASRDataset(input_dim=4, n_classes=8, seq_len=8, batch=2)
    pf = Prefetcher(ds, depth=2)
    pf.next()
    pf.close()
    assert not pf.thread.is_alive()
