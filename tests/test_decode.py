"""Recognition-quality subsystem tests: CTC prefix beam search (jnp +
Pallas) vs the numpy oracle and greedy best-path, streaming/chunked
decode, the eval metrics satellites, and the evaluate/serve loops."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import decode as DC
from repro.decode.beam import NEG, BeamState
from repro.decode.kernel import argmax_tokens, auto_block_b_decode
from repro.decode.ref import prefix_beam_ref
from repro.eval.metrics import (collapse_labels, edit_distance,
                                frame_error_rate, greedy_ctc_decode,
                                token_error_rate)


def _rand_logits(seed, B, T, V, scale=2.0):
    rng = np.random.default_rng(seed)
    return (scale * rng.normal(size=(B, T, V))).astype(np.float32)


def _rand_lengths(seed, B, T):
    rng = np.random.default_rng(seed + 1)
    return rng.integers(1, T + 1, size=B).astype(np.int32)


# ---------------------------------------------------------------------------
# beam=1 == greedy best-path (the acceptance bit-match)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_beam1_max_bitmatches_greedy(seed):
    logits = _rand_logits(seed, B=5, T=16, V=9)
    hyp = DC.beam_decode(jnp.asarray(logits), beam=1, semiring="max")
    assert hyp == greedy_ctc_decode(logits)


@pytest.mark.parametrize("seed", [0, 1])
def test_beam1_max_bitmatches_greedy_varlen(seed):
    logits = _rand_logits(seed, B=5, T=16, V=9)
    lens = _rand_lengths(seed, 5, 16)
    hyp = DC.beam_decode(jnp.asarray(logits), jnp.asarray(lens), beam=1,
                         semiring="max")
    assert hyp == greedy_ctc_decode(logits, lens)


# ---------------------------------------------------------------------------
# vectorized beam vs the dict-of-prefixes numpy oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("semiring", ["max", "sum"])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_beam_matches_oracle(semiring, seed):
    logits = _rand_logits(seed, B=4, T=12, V=7)
    hyp = DC.beam_decode(jnp.asarray(logits), beam=4, semiring=semiring)
    ref, _ = prefix_beam_ref(logits, beam=4, semiring=semiring)
    assert hyp == ref


@pytest.mark.parametrize("semiring", ["max", "sum"])
def test_beam_matches_oracle_varlen(semiring):
    logits = _rand_logits(7, B=5, T=14, V=6)
    lens = np.array([14, 7, 1, 10, 3], np.int32)
    hyp = DC.beam_decode(jnp.asarray(logits), jnp.asarray(lens), beam=4,
                         semiring=semiring)
    ref, _ = prefix_beam_ref(logits, lens, beam=4, semiring=semiring)
    assert hyp == ref


def test_beam_scores_match_oracle():
    logits = _rand_logits(11, B=3, T=10, V=6)
    _, _, scores = DC.beam_search(jnp.asarray(logits), beam=4,
                                  semiring="sum")
    _, ref_scores = prefix_beam_ref(logits, beam=4, semiring="sum")
    np.testing.assert_allclose(np.asarray(scores), ref_scores,
                               rtol=1e-5, atol=1e-5)


def test_len_norm_reranks_final_beams():
    # beam A: 1 token, raw score -1; beam B: 4 tokens, raw score -2.
    # Raw ranking picks A; alpha=1 normalizes to -1 vs -0.5 and picks B.
    tokens = jnp.full((1, 2, 6), -1, jnp.int32)
    tokens = tokens.at[0, 0, 0].set(3)
    tokens = tokens.at[0, 1, :4].set(jnp.array([1, 2, 1, 2]))
    state = BeamState(
        tokens=tokens,
        lens=jnp.array([[1, 4]], jnp.int32),
        last=jnp.array([[3, 2]], jnp.int32),
        phash=jnp.zeros((1, 2), jnp.int32),
        p_b=jnp.array([[-1.0, -2.0]], jnp.float32),
        p_nb=jnp.full((1, 2), NEG, jnp.float32),
        t=jnp.zeros((1,), jnp.int32),
    )
    toks0, lens0, _ = DC.finalize(state, len_norm=0.0)
    toks1, lens1, _ = DC.finalize(state, len_norm=1.0)
    assert int(lens0[0]) == 1 and list(toks0[0][:1]) == [3]
    assert int(lens1[0]) == 4 and list(toks1[0][:4]) == [1, 2, 1, 2]


# ---------------------------------------------------------------------------
# sum semiring > best path (the reason beam search exists)
# ---------------------------------------------------------------------------

def test_sum_beam_recovers_mass_best_path_drops():
    # Per frame: p(blank)=.4, p(a)=.3, p(b)=.3.  Best path is blank,blank
    # (.16) -> [], but prefix [a] sums (a,a)+(a,-)+(-,a) = .33 -> [a].
    p = np.log(np.array([0.4, 0.3, 0.3], np.float32))
    logits = np.broadcast_to(p, (1, 2, 3)).copy()
    assert greedy_ctc_decode(logits) == [[]]
    assert DC.beam_decode(jnp.asarray(logits), beam=3,
                          semiring="sum") in ([[1]], [[2]])
    ref, _ = prefix_beam_ref(logits, beam=3, semiring="sum")
    assert DC.beam_decode(jnp.asarray(logits), beam=3,
                          semiring="sum") == ref


# ---------------------------------------------------------------------------
# edge cases: all-blank and repeat collapse
# ---------------------------------------------------------------------------

def test_all_blank_decodes_empty():
    logits = np.zeros((2, 8, 5), np.float32)
    logits[:, :, 0] = 6.0
    for impl in ("jax", "pallas"):
        assert DC.beam_decode(jnp.asarray(logits), beam=4, impl=impl,
                              interpret=True) == [[], []]


def test_repeat_collapse_and_blank_separated_repeat():
    # path 1,1,blank,1,2,2 -> [1,1,2]: repeats merge, blank splits them
    V = 4
    path = [1, 1, 0, 1, 2, 2]
    logits = np.full((1, len(path), V), -4.0, np.float32)
    for t, c in enumerate(path):
        logits[0, t, c] = 4.0
    for semiring in ("max", "sum"):
        for impl in ("jax", "pallas"):
            hyp = DC.beam_decode(jnp.asarray(logits), beam=4,
                                 semiring=semiring, impl=impl,
                                 interpret=True)
            assert hyp == [[1, 1, 2]], (semiring, impl, hyp)


# ---------------------------------------------------------------------------
# pallas kernel vs jnp path (bit parity) under variable lengths
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("semiring", ["max", "sum"])
def test_pallas_beam_bitmatches_jax_varlen(semiring):
    logits = _rand_logits(3, B=5, T=10, V=8)
    lens = np.array([10, 4, 1, 7, 9], np.int32)
    tj, lj, sj = DC.beam_search(jnp.asarray(logits), jnp.asarray(lens),
                                beam=4, semiring=semiring, impl="jax")
    tp, lp, sp = DC.beam_search(jnp.asarray(logits), jnp.asarray(lens),
                                beam=4, semiring=semiring, impl="pallas",
                                interpret=True)
    np.testing.assert_array_equal(np.asarray(tj), np.asarray(tp))
    np.testing.assert_array_equal(np.asarray(lj), np.asarray(lp))
    np.testing.assert_array_equal(np.asarray(sj), np.asarray(sp))


def test_pallas_beam_batch_tiling_and_padding():
    """block_b that doesn't divide B exercises the pad/slice path."""
    logits = _rand_logits(5, B=5, T=8, V=6)
    tj, lj, _ = DC.beam_search(jnp.asarray(logits), beam=3, impl="jax")
    tp, lp, _ = DC.beam_search(jnp.asarray(logits), beam=3, impl="pallas",
                               interpret=True, block_b=2)
    np.testing.assert_array_equal(np.asarray(tj), np.asarray(tp))
    np.testing.assert_array_equal(np.asarray(lj), np.asarray(lp))


def test_auto_block_b_decode_fits_budget():
    bb = auto_block_b_decode(256, beam=8, vocab=32_000,
                             vmem_budget=12 * 2 ** 20)
    assert 1 <= bb <= 256
    assert (4 * 8 * 32_000 + 32_000) * 4 * bb <= 12 * 2 ** 20
    assert auto_block_b_decode(4, beam=4, vocab=16) == 4   # capped at B


# ---------------------------------------------------------------------------
# top-C vocab pruning: exactness under covering C (docs/decoding.md)
# ---------------------------------------------------------------------------

def _peaky_logits(seed, B, T, V, support):
    """Planted-path posteriors whose per-frame support (tokens with any
    realistic mass) is {0..support-1}: the +12 margin puts every other
    token ~e^-12 below, so any C >= support covers the extend support
    and the pruned search must be bit-identical to the unpruned one."""
    rng = np.random.default_rng(seed)
    path = rng.integers(0, support, size=(B, T)).astype(np.int32)
    path[rng.random((B, T)) < 0.4] = 0
    logits = rng.normal(0.0, 1.0, size=(B, T, V)).astype(np.float32)
    logits[..., support:] -= 12.0
    logits += 4.0 * (np.arange(V)[None, None, :] == path[:, :, None])
    return logits


def test_topc_scores_matches_lax_topk():
    logp = jax.nn.log_softmax(
        jnp.asarray(_rand_logits(3, B=5, T=1, V=33)[:, 0]), -1)
    vals, idx = DC.topc_scores(logp, 7)
    ref_v, ref_i = jax.lax.top_k(logp, 7)
    np.testing.assert_array_equal(np.asarray(vals), np.asarray(ref_v))
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ref_i))


@pytest.mark.parametrize("semiring", ["max", "sum"])
@pytest.mark.parametrize("impl", ["jax", "pallas"])
@pytest.mark.parametrize("topc", [8, 31])
def test_topc_covering_bitmatches_unpruned(semiring, impl, topc):
    logits = _peaky_logits(5, B=4, T=18, V=32, support=6)
    lens = _rand_lengths(5, 4, 18)
    ref = DC.beam_search(jnp.asarray(logits), jnp.asarray(lens), beam=4,
                         semiring=semiring)
    out = DC.beam_search(jnp.asarray(logits), jnp.asarray(lens), beam=4,
                         semiring=semiring, impl=impl, topc=topc)
    for r, o in zip(ref, out):
        np.testing.assert_array_equal(np.asarray(r), np.asarray(o))


@pytest.mark.parametrize("semiring", ["max", "sum"])
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_topc_pruned_matches_oracle(semiring, seed):
    """Property test: the pruned beam with covering C reproduces the
    dict-of-real-prefixes numpy oracle exactly."""
    logits = _peaky_logits(seed, B=4, T=12, V=24, support=5)
    hyp = DC.beam_decode(jnp.asarray(logits), beam=4, semiring=semiring,
                         topc=8)
    ref, _ = prefix_beam_ref(logits, beam=4, semiring=semiring)
    assert hyp == ref


def test_topc_chunked_streaming_bitmatches_oneshot():
    logits = _peaky_logits(7, B=4, T=14, V=20, support=5)
    lens = np.array([14, 6, 2, 11], np.int32)
    ref = DC.beam_search(jnp.asarray(logits), jnp.asarray(lens), beam=4,
                         semiring="sum", topc=8)
    st = DC.init_state(4, 4, 14)
    for t0 in range(0, 14, 5):
        st = DC.decode_chunk(st, jnp.asarray(logits[:, t0:t0 + 5]),
                             jnp.asarray(lens), semiring="sum", topc=8)
    out = DC.finalize(st, semiring="sum")
    for r, o in zip(ref, out):
        np.testing.assert_array_equal(np.asarray(r), np.asarray(o))


def test_topc_at_least_vocab_routes_unpruned():
    """topc >= V is the unpruned path (same object-level step), so the
    bench's C=V row is the true baseline."""
    logits = _rand_logits(11, B=3, T=10, V=16)
    ref = DC.beam_search(jnp.asarray(logits), beam=4, semiring="sum")
    out = DC.beam_search(jnp.asarray(logits), beam=4, semiring="sum",
                         topc=16)
    for r, o in zip(ref, out):
        np.testing.assert_array_equal(np.asarray(r), np.asarray(o))


def test_beam_cand_bytes_scales_with_c_not_v():
    from repro.decode.kernel import beam_cand_bytes

    unpruned = beam_cand_bytes(8, 32_000)
    pruned = beam_cand_bytes(8, 32_000, topc=64)
    assert unpruned == (4 * 8 * 32_000 + 32_000) * 4   # legacy formula
    assert pruned < unpruned / 4
    # doubling vocab barely moves the pruned set (logp block only) ...
    assert beam_cand_bytes(8, 64_000, topc=64) < 2.2 * pruned
    # ... while block_b grows accordingly
    assert (auto_block_b_decode(1 << 20, 8, 32_000, topc=64)
            > 4 * auto_block_b_decode(1 << 20, 8, 32_000))


# ---------------------------------------------------------------------------
# streaming: chunked == one-shot, reset_rows re-arms slots
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chunks", [(5, 5, 4), (1,) * 14, (3, 11)])
def test_chunked_decode_bitmatches_oneshot(chunks):
    assert sum(chunks) == 14
    logits = _rand_logits(9, B=4, T=14, V=6)
    lens = np.array([14, 6, 2, 11], np.int32)
    ref_t, ref_l, ref_s = DC.beam_search(
        jnp.asarray(logits), jnp.asarray(lens), beam=4, semiring="sum")
    st = DC.init_state(4, 4, 14)
    t0 = 0
    for c in chunks:
        st = DC.decode_chunk(st, jnp.asarray(logits[:, t0:t0 + c]),
                             jnp.asarray(lens), semiring="sum")
        t0 += c
    toks, ls, sc = DC.finalize(st, semiring="sum")
    np.testing.assert_array_equal(np.asarray(ref_t), np.asarray(toks))
    np.testing.assert_array_equal(np.asarray(ref_l), np.asarray(ls))
    np.testing.assert_array_equal(np.asarray(ref_s), np.asarray(sc))


def test_reset_rows_rearms_only_masked_rows():
    logits = _rand_logits(2, B=3, T=6, V=5)
    st = DC.init_state(3, 3, 6)
    st = DC.decode_chunk(st, jnp.asarray(logits))
    mask = jnp.array([False, True, False])
    st2 = DC.reset_rows(st, mask)
    fresh = DC.init_state(3, 3, 6)
    np.testing.assert_array_equal(np.asarray(st2.tokens[1]),
                                  np.asarray(fresh.tokens[1]))
    assert int(st2.t[1]) == 0
    np.testing.assert_array_equal(np.asarray(st2.tokens[0]),
                                  np.asarray(st.tokens[0]))
    np.testing.assert_array_equal(np.asarray(st2.p_b[2]),
                                  np.asarray(st.p_b[2]))


def test_beam_occupancy():
    st = DC.init_state(2, 4, 6)
    occ = np.asarray(DC.beam_occupancy(st))
    np.testing.assert_allclose(occ, [0.25, 0.25])   # only the empty root
    logits = _rand_logits(4, B=2, T=6, V=8)
    st = DC.decode_chunk(st, jnp.asarray(logits))
    occ = np.asarray(DC.beam_occupancy(st))
    np.testing.assert_allclose(occ, [1.0, 1.0])     # beams fill (V >= K)


# ---------------------------------------------------------------------------
# serving argmax kernel
# ---------------------------------------------------------------------------

def test_argmax_tokens_matches_jnp():
    rng = np.random.default_rng(0)
    logits = rng.normal(size=(5, 33)).astype(np.float32)
    out = argmax_tokens(jnp.asarray(logits), interpret=True)
    np.testing.assert_array_equal(np.asarray(out),
                                  logits.argmax(-1).astype(np.int32))
    out2 = argmax_tokens(jnp.asarray(logits), interpret=True, block_b=2)
    np.testing.assert_array_equal(np.asarray(out2), np.asarray(out))


# ---------------------------------------------------------------------------
# eval metrics satellites
# ---------------------------------------------------------------------------

def _edit_distance_percell(ref, hyp):
    """The pre-vectorization per-cell DP (frozen here as the parity
    reference for the numpy row-sweep implementation)."""
    ref, hyp = list(ref), list(hyp)
    m, n = len(ref), len(hyp)
    dp = np.arange(n + 1)
    for i in range(1, m + 1):
        prev_diag = dp[0]
        dp[0] = i
        for j in range(1, n + 1):
            cur = dp[j]
            dp[j] = min(dp[j] + 1,
                        dp[j - 1] + 1,
                        prev_diag + (ref[i - 1] != hyp[j - 1]))
            prev_diag = cur
    return int(dp[n])


def test_edit_distance_vectorized_parity():
    rng = np.random.default_rng(0)
    for _ in range(200):
        a = list(rng.integers(0, 5, size=rng.integers(0, 12)))
        b = list(rng.integers(0, 5, size=rng.integers(0, 12)))
        assert edit_distance(a, b) == _edit_distance_percell(a, b), (a, b)


def test_frame_error_rate_masks_padding():
    logits = np.zeros((2, 4, 3), np.float32)
    logits[:, :, 1] = 5.0                       # predicts class 1 always
    labels = np.array([[1, 1, 2, 2], [1, 2, 0, 0]], np.int32)
    # unmasked: errors at (0,2),(0,3),(1,1),(1,2),(1,3) -> 5/8
    assert frame_error_rate(logits, labels) == pytest.approx(5 / 8)
    # lengths (2, 2): only frames t<2 count -> errors at (1,1) -> 1/4
    assert frame_error_rate(logits, labels,
                            np.array([2, 2])) == pytest.approx(1 / 4)


def test_greedy_ctc_decode_respects_lengths():
    logits = np.zeros((1, 4, 3), np.float32)
    for t, c in enumerate([1, 1, 2, 2]):
        logits[0, t, c] = 5.0
    assert greedy_ctc_decode(logits) == [[1, 2]]
    assert greedy_ctc_decode(logits, np.array([2])) == [[1]]


def test_collapse_labels():
    labels = np.array([[0, 1, 1, 2, 0, 2], [3, 3, 3, 0, 0, 0]], np.int32)
    assert collapse_labels(labels) == [[1, 2, 2], [3]]
    assert collapse_labels(labels, np.array([3, 2])) == [[1], [3]]
    assert collapse_labels(np.zeros((1, 4), np.int32)) == [[]]


# ---------------------------------------------------------------------------
# evaluate + ASR serving end-to-end (tiny shapes)
# ---------------------------------------------------------------------------

def _tiny_cfg():
    from repro.configs import get_arch

    return dataclasses.replace(
        get_arch("swb2000-blstm").reduced(), n_layers=1, lstm_hidden=32,
        lstm_bottleneck=16, input_dim=16, vocab=32, beam_width=3)


def test_evaluate_restores_checkpoint_end_to_end(tmp_path):
    """train (2 steps) -> checkpoint -> restore_consensus ->
    evaluate_params reports finite TER/FER rows."""
    from repro.checkpoint import save
    from repro.launch.evaluate import evaluate_params, restore_consensus
    from repro.launch.mesh import make_local_mesh, use_mesh
    from repro.launch.train import setup_training

    cfg = _tiny_cfg()
    mesh = make_local_mesh()
    state, step_fn, meta = setup_training(cfg, mesh, strategy_name="ad_psgd",
                                          n_learners=2)
    from repro.data import make_dataset

    ds = make_dataset(cfg, seq_len=12, batch=4, seed=0)
    with use_mesh(mesh):
        for k in range(2):
            state, _ = step_fn(state, ds.batch_at(k))
    save(str(tmp_path / "ck"), 2, state)

    params, step, meta2 = restore_consensus(
        cfg, ckpt_dir=str(tmp_path / "ck"), strategy_name="ad_psgd",
        n_learners=2)
    assert step == 2
    m = evaluate_params(cfg, params, batches=1, batch=4, seq_len=12,
                        var_len=True, decode_chunk=5)
    assert 0.0 <= m["fer"] <= 1.0
    assert np.isfinite(m["ter_greedy"]) and np.isfinite(m["ter_beam"])
    assert m["frames_per_s"] > 0 and m["decoded_tok_per_s"] >= 0
    assert 0.0 < m["beam_occupancy"] <= 1.0


def test_asr_server_streaming_matches_oneshot_decode():
    """The serving loop's chunked slot decode must equal a one-shot
    beam_search over the same posteriors (carry = beam state)."""
    from repro.launch.serve import AsrServer
    from repro.models import lstm as LS

    cfg = _tiny_cfg()
    server = AsrServer(cfg, slots=2, max_frames=16, chunk=5, beam=3)
    rng = np.random.default_rng(0)
    reqs = [(i, rng.normal(size=(n, cfg.input_dim)).astype(np.float32))
            for i, n in [(0, 13), (1, 7), (2, 16)]]
    pending = list(reqs)
    finished = []
    waves = 0
    while pending or server.active.any():
        while pending and server.admit(*pending[0]):
            pending.pop(0)
        done, occ = server.step()
        finished += done
        waves += 1
        assert 0.0 <= occ <= 1.0
        assert waves < 50
    assert sorted(r for r, _ in finished) == [0, 1, 2]

    hyps = dict(finished)
    for rid, feats in reqs:
        n = len(feats)
        padded = np.zeros((1, 16, cfg.input_dim), np.float32)
        padded[0, :n] = feats
        logits = LS.forward(cfg, server.params, jnp.asarray(padded),
                            jnp.asarray([n], jnp.int32))
        toks, lens, _ = DC.beam_search(
            logits, jnp.asarray([n], jnp.int32), beam=3,
            semiring=server.semiring)
        want = list(map(int, np.asarray(toks)[0][:int(lens[0])]))
        assert hyps[rid] == want, (rid, hyps[rid], want)


def test_ter_drops_after_ctc_training_beam_not_worse_than_greedy():
    """Short CTC training: consensus TER must drop and the sum-semiring
    beam must not be worse than greedy on the heldout set."""
    from repro.models import lstm as LS
    from repro.models.ctc import collapse_frame_labels, ctc_loss
    from repro.sharding import init_spec_tree
    from repro.data import make_dataset
    from repro.models import build_model

    cfg = _tiny_cfg()
    model = build_model(cfg)
    params = init_spec_tree(model.param_specs(), jax.random.PRNGKey(0))
    ds = make_dataset(cfg, seq_len=12, batch=8, seed=0)

    def loss_fn(p, f, s):
        return ctc_loss(LS.forward(cfg, p, f), s)

    @jax.jit
    def step(p, f, s):
        l, g = jax.value_and_grad(loss_fn)(p, f, s)
        gn = jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                          for x in jax.tree.leaves(g)))
        sc = jnp.minimum(1.0, 5.0 / (gn + 1e-6)) * 0.05
        return l, jax.tree.map(
            lambda w, gg: (w.astype(jnp.float32)
                           - sc * gg.astype(jnp.float32)).astype(w.dtype),
            p, g)

    def ters(p):
        b = ds.batch_at(9_999)
        seqs, lens = collapse_frame_labels(b["labels"], max_len=5)
        refs = [list(s[:n]) for s, n in zip(seqs, lens)]
        logits = np.asarray(LS.forward(cfg, p, jnp.asarray(b["features"])),
                            np.float32)
        tg = token_error_rate(refs, greedy_ctc_decode(logits))
        tb = token_error_rate(refs, DC.beam_decode(
            jnp.asarray(logits), beam=4, semiring="sum"))
        return tg, tb

    t0g, _ = ters(params)
    for k in range(60):
        b = ds.batch_at(k)
        seqs, _ = collapse_frame_labels(b["labels"], max_len=5)
        _, params = step(params, jnp.asarray(b["features"]),
                         jnp.asarray(seqs))
    t1g, t1b = ters(params)
    assert t1g < t0g - 0.05, (t0g, t1g)
    assert t1b <= t1g + 1e-9, (t1b, t1g)
