"""Long-utterance Pallas BLSTM paths (sequence-chunked recompute + fused
multi-layer stack): gradient parity vs the unchunked kernels and the
masked-scan oracle, residual-stash accounting, and the joint
(block_b, seq_chunk) VMEM tuner.  All pallas calls run in interpret mode
(CPU CI); tolerances follow tests/test_kernels.py (f32 1e-4 / bf16 2e-2
normalized vs the oracle; the chunked-vs-unchunked comparison is much
tighter because the recompute replays the identical op sequence)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.lstm_cell import (DEFAULT_VMEM_BUDGET, _chunked_usage,
                                     _stack_usage, auto_stack_block_b,
                                     auto_tile, blstm_sequence,
                                     blstm_stack_sequence, lstm_sequence,
                                     stash_bytes)

KEY = jax.random.PRNGKey(7)


def _mk(shape, dtype, i=0, scale=1.0):
    return (jax.random.normal(jax.random.fold_in(KEY, i), shape,
                              jnp.float32) * scale).astype(dtype)


def _mk_lstm(D, H, dtype, base):
    return (_mk((D, 4 * H), dtype, base, 0.3),
            _mk((H, 4 * H), dtype, base + 1, 0.3),
            _mk((4 * H,), jnp.float32, base + 2, 0.1))


def _norm_close(got, want, tol, name=""):
    scale = float(jnp.abs(want.astype(jnp.float32)).max()) + 1e-8
    np.testing.assert_allclose(np.asarray(got, np.float32) / scale,
                               np.asarray(want, np.float32) / scale,
                               atol=tol, err_msg=name)


def _sq_loss(fn):
    def loss(*args):
        return jnp.mean(jnp.square(fn(*args).astype(jnp.float32)))
    return loss


# ---------------------------------------------------------------------------
# sequence-chunked recompute
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
@pytest.mark.parametrize("reverse", [False, True])
@pytest.mark.parametrize("T,K", [
    (12, 4),     # K divides T
    (13, 5),     # non-dividing T -> time padding + synthesized lengths
])
def test_seq_chunk_grad_parity(T, K, reverse, dtype):
    """Chunked-recompute grads match (a) the scan oracle at the standard
    tolerances and (b) the unchunked per-step-stash kernel near-exactly
    (the recompute replays the identical op sequence from the stashed
    f32 chunk-entry carries)."""
    B, D, H = 4, 8, 16
    wx, wh, b = _mk_lstm(D, H, dtype, 10)
    x = _mk((B, T, D), dtype, 13)

    loss_c = _sq_loss(lambda *a: lstm_sequence(
        *a, reverse=reverse, interpret=True, seq_chunk=K))
    loss_u = _sq_loss(lambda *a: lstm_sequence(
        *a, reverse=reverse, interpret=True))
    loss_r = _sq_loss(lambda *a: ref.lstm_ref(*a, reverse=reverse))

    argn = (0, 1, 2, 3)
    v_c, g_c = jax.value_and_grad(loss_c, argnums=argn)(wx, wh, b, x)
    v_u, g_u = jax.value_and_grad(loss_u, argnums=argn)(wx, wh, b, x)
    v_r, g_r = jax.value_and_grad(loss_r, argnums=argn)(wx, wh, b, x)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(float(v_c), float(v_r), rtol=tol)
    for got, exact, want, name in zip(g_c, g_u, g_r,
                                      ("dwx", "dwh", "db", "dx")):
        assert got.dtype == want.dtype
        _norm_close(got, want, tol, name)
        _norm_close(got, exact, 2e-5, name + " vs unchunked")


def test_seq_chunk_varlen_blstm_grad():
    """Chunked recompute composes with the PR-2 masking semantics: a
    fused BLSTM over a variable-length batch (incl. length-1 rows and a
    non-dividing T) with batch tiling matches the masked-scan oracle."""
    B, T, D, H, K = 5, 11, 8, 16, 4
    wf = _mk_lstm(D, H, jnp.bfloat16, 20)
    wb = _mk_lstm(D, H, jnp.bfloat16, 24)
    x = _mk((B, T, D), jnp.bfloat16, 28)
    lens = jnp.array([11, 3, 7, 1, 5], jnp.int32)

    loss_k = _sq_loss(lambda *a: blstm_sequence(
        *a, lens, interpret=True, seq_chunk=K, block_b=2))
    loss_r = _sq_loss(lambda *a: ref.blstm_ref(*a, lengths=lens))
    args = (*wf, *wb, x)
    argn = tuple(range(7))
    v_k, g_k = jax.value_and_grad(loss_k, argnums=argn)(*args)
    v_r, g_r = jax.value_and_grad(loss_r, argnums=argn)(*args)
    np.testing.assert_allclose(float(v_k), float(v_r), rtol=2e-2)
    names = ("dwxf", "dwhf", "dbf", "dwxb", "dwhb", "dbb", "dx")
    for got, want, name in zip(g_k, g_r, names):
        _norm_close(got, want, 2e-2, name)


def test_seq_chunk_auto_end_to_end():
    """seq_chunk=-1 (joint auto-tuning) trains end-to-end and matches the
    oracle."""
    B, T, D, H = 4, 10, 8, 16
    wx, wh, b = _mk_lstm(D, H, jnp.float32, 30)
    x = _mk((B, T, D), jnp.float32, 33)
    loss_c = _sq_loss(lambda *a: lstm_sequence(
        *a, interpret=True, seq_chunk=-1))
    loss_r = _sq_loss(ref.lstm_ref)
    v_c, g_c = jax.value_and_grad(loss_c, argnums=(0, 1, 2, 3))(wx, wh, b, x)
    v_r, g_r = jax.value_and_grad(loss_r, argnums=(0, 1, 2, 3))(wx, wh, b, x)
    np.testing.assert_allclose(float(v_c), float(v_r), rtol=1e-4)
    for got, want in zip(g_c, g_r):
        _norm_close(got, want, 1e-4)


def test_auto_tile_fits_budget():
    """The joint (block_b, seq_chunk) tuner respects the VMEM budget, the
    explicit-K / explicit-bb contracts, and clamps K to T."""
    # paper shape, bf16 weights: the returned pair must fit the budget
    bb, K = auto_tile(256, 8000, 260, 512, 2, n_dir=2, seq_chunk=-1)
    assert _chunked_usage(bb, K, 260, 512, 2, 2, 4) <= DEFAULT_VMEM_BUDGET
    assert bb >= 8 and K >= 16
    # explicit K is respected (clamped to T), bb still tuned
    bb2, K2 = auto_tile(256, 8000, 260, 512, 2, n_dir=2, seq_chunk=64)
    assert K2 == 64 and bb2 >= 8
    _, K3 = auto_tile(256, 8, 260, 512, 2, n_dir=2, seq_chunk=64)
    assert K3 == 8            # clamped to T
    # explicit block_b is passed through untouched
    bb4, _ = auto_tile(256, 8000, 260, 512, 2, n_dir=2, seq_chunk=-1,
                       block_b=16)
    assert bb4 == 16
    # seq_chunk=0 degrades to the unchunked auto_block_b contract
    bb5, K5 = auto_tile(256, 21, 260, 512, 2, n_dir=2, seq_chunk=0)
    assert K5 == 0 and bb5 >= 8
    # auto K bounds the masked time padding: an unlucky T just past a
    # power of two must not pad by ~2x (260 -> 512); waste stays <= T/8
    # (or K has hit its 16-frame floor)
    _, K6 = auto_tile(16, 260, 64, 64, 4, seq_chunk=-1)
    Tp = -(-260 // K6) * K6
    assert (Tp - 260) * 8 <= 260 or K6 == 16


def test_stash_bytes_accounting():
    """Acceptance: at T=8000 the chunked residual stash is <= 1/4 of the
    unchunked one (it is ~2/(5K) of it), and the formulas match the
    stash layouts (5H per step unchunked; 2H per chunk boundary)."""
    B, H = 256, 512
    full = stash_bytes(B, 8000, H, n_dir=2)
    assert full == 2 * B * 8000 * 5 * H * 4
    _, K = auto_tile(B, 8000, 260, H, 2, n_dir=2, seq_chunk=-1)
    chunked = stash_bytes(B, 8000, H, n_dir=2, seq_chunk=K)
    assert chunked == 2 * B * (-(-8000 // K)) * 2 * H * 4
    assert chunked <= full / 4
    # bf16 stash option halves both
    assert stash_bytes(B, 8000, H, n_dir=2, stash_itemsize=2) == full // 2
    # non-dividing T rounds the chunk count up
    assert stash_bytes(1, 13, H, seq_chunk=5) == 3 * 2 * H * 4


# ---------------------------------------------------------------------------
# fused multi-layer stack
# ---------------------------------------------------------------------------

def _mk_stack(L, D0, H, base=40):
    layers = []
    for i in range(L):
        Din = D0 if i == 0 else 2 * H
        layers.append(_mk_lstm(Din, H, jnp.bfloat16, base + 6 * i)
                      + _mk_lstm(Din, H, jnp.bfloat16, base + 6 * i + 3))
    return tuple(layers)


@pytest.mark.parametrize("masked", [False, True])
def test_blstm_stack_bitidentical(masked):
    """Acceptance: the fused multi-layer kernel is bit-identical to the
    per-layer blstm_sequence loop (dense and masked, tiled batch with a
    non-dividing block_b), and tracks the stacked-scan oracle."""
    B, T, D0, H, L = 5, 9, 12, 16, 3
    layers = _mk_stack(L, D0, H)
    x = _mk((B, T, D0), jnp.bfloat16, 60)
    lens = jnp.array([9, 2, 7, 1, 5], jnp.int32) if masked else None

    fused = blstm_stack_sequence(layers, x, lens, interpret=True, block_b=2)
    loop = x
    for lw in layers:
        loop = blstm_sequence(*lw, loop, lens, interpret=True, block_b=2)
    np.testing.assert_array_equal(np.asarray(fused, np.float32),
                                  np.asarray(loop, np.float32))
    _norm_close(fused, ref.blstm_stack_ref(layers, x, lens), 3e-2)


def test_blstm_stack_grad_matches_per_layer():
    """Under jax.vjp the fused stack falls back to the per-layer stashing
    custom VJP — its grads match differentiating the per-layer pallas
    loop, composing with lengths and seq_chunk."""
    B, T, D0, H, L = 4, 10, 12, 16, 2
    layers = _mk_stack(L, D0, H, base=70)
    x = _mk((B, T, D0), jnp.bfloat16, 90)
    lens = jnp.array([10, 3, 8, 5], jnp.int32)

    def loss_stack(ls, x):
        y = blstm_stack_sequence(ls, x, lens, interpret=True, seq_chunk=4)
        return jnp.mean(jnp.square(y.astype(jnp.float32)))

    def loss_loop(ls, x):
        h = x
        for lw in ls:
            h = blstm_sequence(*lw, h, lens, interpret=True)
        return jnp.mean(jnp.square(h.astype(jnp.float32)))

    v_s, g_s = jax.value_and_grad(loss_stack, argnums=(0, 1))(layers, x)
    v_l, g_l = jax.value_and_grad(loss_loop, argnums=(0, 1))(layers, x)
    np.testing.assert_allclose(float(v_s), float(v_l), rtol=1e-2)
    flat_s = jax.tree.leaves(g_s)
    flat_l = jax.tree.leaves(g_l)
    assert len(flat_s) == len(flat_l) == 6 * L + 1
    for got, want in zip(flat_s, flat_l):
        assert got.dtype == want.dtype
        _norm_close(got, want, 2e-2)


def test_auto_stack_block_b_shrinks_with_T():
    """The fused-stack tile accounts for the (bB, T, 2H) ping-pong
    buffers: longer sequences get smaller tiles, floored at 8 rows."""
    bb_short = auto_stack_block_b(256, 21, 260, 512, 2)
    bb_long = auto_stack_block_b(256, 2000, 260, 512, 2)
    assert bb_short >= bb_long >= 8
    assert auto_stack_block_b(4, 8, 12, 16, 2) == 8   # tiny: one tile


def test_stack_fallback_when_buffers_overrun_budget():
    """When even the floor tile cannot hold the ping-pong buffers (very
    long T for the budget), the stack primal silently degrades to the
    per-layer loop — same numbers, T-independent VMEM."""
    B, T, D0, H, L = 4, 16, 12, 16, 2
    layers = _mk_stack(L, D0, H, base=100)
    x = _mk((B, T, D0), jnp.bfloat16, 112)
    # a budget so small the 8-row floor overruns it -> fallback path
    tiny = 4096
    assert _stack_usage(8, T, D0, H, 2) > tiny
    fused = blstm_stack_sequence(layers, x, interpret=True,
                                 vmem_budget=tiny)
    loop = x
    for lw in layers:
        loop = blstm_sequence(*lw, loop, interpret=True, vmem_budget=tiny)
    np.testing.assert_array_equal(np.asarray(fused, np.float32),
                                  np.asarray(loop, np.float32))


# ---------------------------------------------------------------------------
# model integration
# ---------------------------------------------------------------------------

def test_forward_pallas_stack_and_seq_chunk_loss_train():
    """models/lstm.forward's pallas path (now the fused stack) matches the
    jax scan path, and loss_train grads with lstm_seq_chunk set match the
    jax autodiff grads on a var-len batch."""
    import dataclasses

    from repro.configs import get_arch
    from repro.models import build_model
    from repro.sharding import init_spec_tree

    cfg = dataclasses.replace(get_arch("swb2000-blstm").reduced(),
                              n_layers=2, lstm_hidden=16, lstm_bottleneck=8,
                              input_dim=12, vocab=32, lstm_block_b=2,
                              lstm_seq_chunk=4)
    model = build_model(cfg)
    params = init_spec_tree(model.param_specs(), jax.random.PRNGKey(0))
    B, T = 4, 6
    batch = {
        "features": np.asarray(_mk((B, T, cfg.input_dim), jnp.float32, 95)),
        "labels": np.asarray(
            jax.random.randint(KEY, (B, T), 0, cfg.vocab, jnp.int32)),
        "lengths": np.array([6, 2, 5, 3], np.int32),
    }
    v_j, g_j = jax.value_and_grad(
        lambda p: model.loss_fn(p, batch, kernel_impl="jax"))(params)
    v_p, g_p = jax.value_and_grad(
        lambda p: model.loss_fn(p, batch, kernel_impl="pallas"))(params)
    np.testing.assert_allclose(float(v_p), float(v_j), rtol=2e-2)
    flat_j, _ = jax.tree.flatten(g_j)
    flat_p, treedef = jax.tree.flatten(g_p)
    for got, want in zip(flat_p, flat_j):
        _norm_close(got, want, 2e-2, str(treedef))
