"""The HLO analyzer's trip-count attribution vs ground truth: a scanned
program must report the same FLOPs as its fully-unrolled twin."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.hlo import analyze_hlo


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_scan_flops_match_unrolled():
    L, n, d = 12, 64, 32
    w = jnp.ones((L, d, d), jnp.float32)
    x = jnp.ones((n, d), jnp.float32)

    def scanned(w, x):
        return jax.lax.scan(lambda c, wi: (c @ wi, None), x, w)[0]

    def unrolled(w, x):
        for i in range(L):
            x = x @ w[i]
        return x

    s1 = analyze_hlo(_compile(scanned, w, x).as_text())
    s2 = analyze_hlo(_compile(unrolled, w, x).as_text())
    expect = 2.0 * L * n * d * d
    assert s1.flops == pytest.approx(expect, rel=0.01)
    assert s2.flops == pytest.approx(expect, rel=0.01)
    assert s1.n_while >= 1 and s2.n_while == 0


def test_nested_scan_trip_products():
    outer, inner, n, d = 4, 5, 16, 16
    w = jnp.ones((outer, inner, d, d), jnp.float32)
    x = jnp.ones((n, d), jnp.float32)

    def f(w, x):
        def outer_body(c, wo):
            def inner_body(ci, wi):
                return ci @ wi, None
            return jax.lax.scan(inner_body, c, wo)[0], None
        return jax.lax.scan(outer_body, x, w)[0]

    st = analyze_hlo(_compile(f, w, x).as_text())
    expect = 2.0 * outer * inner * n * d * d
    assert st.flops == pytest.approx(expect, rel=0.01)


def test_matmul_flops_exact():
    a = jnp.ones((128, 256), jnp.float32)
    b = jnp.ones((256, 64), jnp.float32)
    st = analyze_hlo(_compile(lambda a, b: a @ b, a, b).as_text())
    assert st.flops == pytest.approx(2 * 128 * 256 * 64, rel=0.01)


def test_cost_analysis_undercounts_scan():
    """Documents WHY the analyzer exists: XLA's cost_analysis counts a while
    body once regardless of trip count."""
    L, n, d = 12, 64, 32
    w = jnp.ones((L, d, d), jnp.float32)
    x = jnp.ones((n, d), jnp.float32)
    compiled = _compile(
        lambda w, x: jax.lax.scan(lambda c, wi: (c @ wi, None), x, w)[0],
        w, x)
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):  # jax 0.4.x: one dict per computation
        ca = ca[0] if ca else {}
    expect = 2.0 * L * n * d * d
    assert ca["flops"] < 0.5 * expect   # undercounted
    st = analyze_hlo(compiled.as_text())
    assert st.flops == pytest.approx(expect, rel=0.01)
