"""Property tests (hypothesis) for the mixing-matrix core (paper Eq. 14)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need the dev extra
from hypothesis import given, settings, strategies as st

from repro.core import mixing

SIZES = st.integers(min_value=1, max_value=12)


@given(SIZES)
@settings(max_examples=25, deadline=None)
def test_ring_matrix_doubly_stochastic(L):
    assert mixing.is_doubly_stochastic(mixing.ring_matrix(L))


@given(SIZES)
@settings(max_examples=25, deadline=None)
def test_uniform_matrix_doubly_stochastic(L):
    assert mixing.is_doubly_stochastic(mixing.uniform_matrix(L))


@given(st.integers(min_value=2, max_value=8))
@settings(max_examples=10, deadline=None)
def test_ring_powers_reach_consensus(L):
    """T^n -> T_u: the Markov chain of T_1 is irreducible+aperiodic (§IV-C)."""
    T = mixing.ring_matrix(L)
    Tn = np.linalg.matrix_power(T, 512)
    assert np.allclose(Tn, mixing.uniform_matrix(L), atol=1e-4)


@given(st.integers(min_value=1, max_value=8),
       st.integers(min_value=1, max_value=5),
       st.sampled_from(["ring", "uniform"]))
@settings(max_examples=20, deadline=None)
def test_mixing_preserves_replica_mean(L, dim, kind):
    """Doubly-stochastic mixing conserves the consensus average — the
    invariant that makes decentralized SGD unbiased."""
    rng = np.random.default_rng(L * 100 + dim)
    w = {"a": jnp.asarray(rng.normal(size=(L, dim)), jnp.float32)}
    mixed = mixing.get_mixer(kind)(w)
    np.testing.assert_allclose(np.mean(np.asarray(mixed["a"]), axis=0),
                               np.mean(np.asarray(w["a"]), axis=0),
                               atol=1e-5)


@given(st.integers(min_value=3, max_value=10))
@settings(max_examples=10, deadline=None)
def test_mix_ring_equals_matrix_form(L):
    """Collective-form ring mixing == explicit W·T_1 (row convention)."""
    rng = np.random.default_rng(L)
    w = {"a": jnp.asarray(rng.normal(size=(L, 7)), jnp.float32)}
    fast = mixing.mix_ring(w)["a"]
    ref = mixing.mix_matrix(w, mixing.ring_matrix(L))["a"]
    np.testing.assert_allclose(np.asarray(fast), np.asarray(ref), atol=1e-5)


def test_mix_uniform_equals_matrix_form():
    rng = np.random.default_rng(0)
    w = {"a": jnp.asarray(rng.normal(size=(6, 4)), jnp.float32)}
    fast = mixing.mix_uniform(w)["a"]
    ref = mixing.mix_matrix(w, mixing.uniform_matrix(6))["a"]
    np.testing.assert_allclose(np.asarray(fast), np.asarray(ref), atol=1e-5)


@given(st.integers(min_value=2, max_value=8))
@settings(max_examples=10, deadline=None)
def test_consensus_contraction(L):
    """One ring-mixing round strictly contracts consensus distance."""
    from repro.core.strategies import consensus_distance

    rng = np.random.default_rng(L)
    w = {"a": jnp.asarray(rng.normal(size=(L, 16)), jnp.float32)}
    before = float(consensus_distance(w))
    after = float(consensus_distance(mixing.mix_ring(w)))
    assert after <= before + 1e-6
