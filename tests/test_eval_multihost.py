"""Eval metrics + multihost scaffolding (single-process degradation)."""
import jax
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need the dev extra
from hypothesis import given, settings, strategies as st

from repro.eval import (edit_distance, frame_error_rate, greedy_ctc_decode,
                        token_error_rate)
from repro.launch.multihost import (host_batch_slice, initialize,
                                    make_global_batch)


def test_edit_distance_basics():
    assert edit_distance([1, 2, 3], [1, 2, 3]) == 0
    assert edit_distance([1, 2, 3], [1, 3]) == 1        # deletion
    assert edit_distance([1, 2], [1, 2, 3]) == 1        # insertion
    assert edit_distance([1, 2, 3], [1, 9, 3]) == 1     # substitution
    assert edit_distance([], [1, 2]) == 2


@given(st.lists(st.integers(0, 5), max_size=8),
       st.lists(st.integers(0, 5), max_size=8))
@settings(max_examples=60, deadline=None)
def test_edit_distance_properties(a, b):
    d = edit_distance(a, b)
    assert d == edit_distance(b, a)                     # symmetry
    assert abs(len(a) - len(b)) <= d <= max(len(a), len(b))
    assert (d == 0) == (a == b)


def test_token_error_rate():
    refs = [[1, 2, 3], [4, 5]]
    hyps = [[1, 2, 3], [4, 6]]
    assert token_error_rate(refs, hyps) == pytest.approx(1 / 5)


def test_frame_error_rate():
    logits = np.zeros((1, 3, 4))
    logits[0, np.arange(3), [1, 2, 3]] = 5.0
    assert frame_error_rate(logits, np.array([[1, 2, 0]])) == \
        pytest.approx(1 / 3)


def test_greedy_ctc_decode_collapses():
    V = 4
    logits = np.zeros((1, 6, V))
    # path: blank,1,1,blank,2,2 -> [1,2]
    for t, c in enumerate([0, 1, 1, 0, 2, 2]):
        logits[0, t, c] = 5.0
    assert greedy_ctc_decode(logits) == [[1, 2]]


def test_ctc_trained_model_beats_chance_ter():
    """Train the reduced BLSTM with CTC a little; TER must drop below the
    ~1.0 of an untrained decoder."""
    import jax.numpy as jnp

    from repro.configs import get_arch
    from repro.data import make_dataset
    from repro.models import build_model
    from repro.models.ctc import collapse_frame_labels, ctc_loss
    from repro.models.lstm import forward
    from repro.sharding import init_spec_tree

    cfg = get_arch("swb2000-blstm").reduced()
    model = build_model(cfg)
    params = init_spec_tree(model.param_specs(), jax.random.PRNGKey(0))
    ds = make_dataset(cfg, seq_len=21, batch=8, seed=0)

    def loss_fn(p, f, s):
        return ctc_loss(forward(cfg, p, f), s)

    @jax.jit
    def step(p, f, s):
        l, g = jax.value_and_grad(loss_fn)(p, f, s)
        gn = jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                          for x in jax.tree.leaves(g)))
        sc = jnp.minimum(1.0, 5.0 / (gn + 1e-6)) * 0.05
        return l, jax.tree.map(
            lambda w, gg: (w.astype(jnp.float32)
                           - sc * gg.astype(jnp.float32)).astype(w.dtype),
            p, g)

    def ter(p):
        b = ds.batch_at(9_999)
        seqs, lens = collapse_frame_labels(b["labels"], max_len=5)
        hyp = greedy_ctc_decode(np.asarray(
            forward(cfg, p, jnp.asarray(b["features"])), np.float32))
        refs = [list(s[:n]) for s, n in zip(seqs, lens)]
        return token_error_rate(refs, hyp)

    t0 = ter(params)
    for k in range(80):
        b = ds.batch_at(k)
        seqs, _ = collapse_frame_labels(b["labels"], max_len=5)
        _, params = step(params, jnp.asarray(b["features"]),
                         jnp.asarray(seqs))
    t1 = ter(params)
    assert t1 < t0 - 0.1, (t0, t1)


# ---------------------------------------------------------------------------
# multihost scaffolding (single-process degradation)
# ---------------------------------------------------------------------------

def test_initialize_noop_single_process():
    assert initialize() is False


def test_host_batch_slice_single():
    start, size = host_batch_slice(32)
    assert (start, size) == (0, 32)


def test_make_global_batch_single_process():
    from repro.launch.mesh import make_local_mesh, rules_for
    from repro.configs import get_arch

    cfg = get_arch("smollm-360m").reduced()
    mesh = make_local_mesh()
    rules = rules_for(cfg, mesh)
    batch = {"tokens": np.zeros((4, 8), np.int32)}
    out = make_global_batch(batch, mesh, rules,
                            {"tokens": ("batch", "seq")})
    assert out["tokens"].shape == (4, 8)
    assert isinstance(out["tokens"], jax.Array)
