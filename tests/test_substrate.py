"""Substrate tests: data pipelines, optimizers, schedules, checkpointing."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.configs import get_arch
from repro.data import make_dataset
from repro.data.pipeline import (Prefetcher, SyntheticASRDataset,
                                 SyntheticLMDataset)
from repro.optim.optimizers import adam, momentum, sgd
from repro.optim.schedules import paper_recipe, warmup_then_anneal


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

def test_lm_dataset_learnable_structure():
    """Markov streams: bigram statistics must beat unigram entropy."""
    ds = SyntheticLMDataset(vocab=512, seq_len=256, batch=32, seed=1)
    b = ds.batch_at(0)
    toks, labels = b["tokens"], b["labels"]
    assert labels.shape == toks.shape
    np.testing.assert_array_equal(toks[:, 1:], labels[:, :-1])
    # empirical transition concentration >> uniform
    counts = np.zeros((ds.k, ds.k))
    np.add.at(counts, (toks[:, :-1].ravel(), toks[:, 1:].ravel()), 1)
    rows = counts.sum(1, keepdims=True).clip(1)
    p = counts / rows
    top = p.max(1)[counts.sum(1) > 10]
    assert top.mean() > 5.0 / ds.k   # far above uniform 1/k


def test_asr_dataset_class_structure():
    ds = SyntheticASRDataset(input_dim=26, n_classes=100, seq_len=21,
                             batch=16, seed=0)
    b = ds.batch_at(3)
    assert b["features"].shape == (16, 21, 26)
    assert b["labels"].max() < 100
    # features of the same class cluster around centroids
    f0 = b["features"][b["labels"] == 0]
    if len(f0) > 2:
        d_own = np.linalg.norm(f0 - ds.centroids[0], axis=-1).mean()
        d_other = np.linalg.norm(f0 - ds.centroids[1], axis=-1).mean()
        assert d_own < d_other


def test_dataset_determinism_and_family_dispatch():
    for arch in ("smollm-360m", "whisper-large-v3", "internvl2-2b",
                 "swb2000-blstm"):
        cfg = get_arch(arch).reduced()
        ds = make_dataset(cfg, seq_len=32, batch=4, seed=7)
        a, b = ds.batch_at(5), ds.batch_at(5)
        for k in a:
            np.testing.assert_array_equal(a[k], b[k])


def test_prefetcher_orders_batches():
    ds = SyntheticLMDataset(vocab=64, seq_len=16, batch=2, seed=0)
    pf = Prefetcher(ds, start_step=0)
    try:
        first = pf.next()
        np.testing.assert_array_equal(first["tokens"],
                                      ds.batch_at(0)["tokens"])
    finally:
        pf.close()


# ---------------------------------------------------------------------------
# optimizers / schedules
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("opt", [sgd(), momentum(), adam()])
def test_optimizers_reduce_quadratic(opt):
    w = {"w": jnp.ones((4,))}
    state = opt.init(w)
    for _ in range(200):
        g = jax.tree.map(lambda x: 2 * x, w)   # grad of ||w||^2
        w, state = opt.update(g, state, w, 0.05)
    assert float(jnp.linalg.norm(w["w"])) < 1e-2


def test_paper_recipe_schedule_shape():
    """§V: warm up 0.1 -> 1.0 over 10 epochs, anneal 1/sqrt(2)/epoch."""
    spe = 100
    sched = paper_recipe(steps_per_epoch=spe)
    assert float(sched(0)) == pytest.approx(0.1)
    assert float(sched(10 * spe)) == pytest.approx(1.0, rel=1e-3)
    assert float(sched(11 * spe)) == pytest.approx(1 / np.sqrt(2), rel=1e-2)
    assert float(sched(12 * spe)) == pytest.approx(0.5, rel=1e-2)


def test_warmup_monotone():
    sched = warmup_then_anneal(0.1, 1.0, 50, 1000, 0.5)
    vals = [float(sched(s)) for s in range(0, 50, 5)]
    assert all(b >= a for a, b in zip(vals, vals[1:]))


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    state = {
        "params": {"w": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3),
                   "b": jnp.zeros((4,), jnp.float32)},
        "step": jnp.int32(17),
    }
    d = str(tmp_path / "ck")
    ckpt.save(d, 17, state)
    restored, step = ckpt.restore(d, state)
    assert step == 17
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(state)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_checkpoint_keep_bound(tmp_path):
    d = str(tmp_path / "ck")
    state = {"w": jnp.zeros((2,))}
    for s in range(6):
        ckpt.save(d, s, state, keep=2)
    assert ckpt.latest_step(d) == 5
    assert len(os.listdir(d)) == 2


def test_checkpoint_shape_mismatch_raises(tmp_path):
    d = str(tmp_path / "ck")
    ckpt.save(d, 0, {"w": jnp.zeros((2,))})
    with pytest.raises(ValueError, match="saved shape"):
        ckpt.restore(d, {"w": jnp.zeros((3,))})


# ---------------------------------------------------------------------------
# end-to-end mini training (integration)
# ---------------------------------------------------------------------------

def test_end_to_end_blstm_training_loss_decreases():
    """The paper's model + AD-PSGD on synthetic ASR frames: loss must drop
    well below uniform ln(vocab)."""
    from repro.core import strategies as ST
    from repro.models import build_model
    from repro.optim.schedules import constant
    from repro.sharding import init_spec_tree

    cfg = get_arch("swb2000-blstm").reduced()
    model = build_model(cfg)
    L = 2
    params = ST.stack_for_learners(
        init_spec_tree(model.param_specs(), jax.random.PRNGKey(0)), L)
    strat = ST.get_strategy("ad_psgd")
    state = ST.init_state(strat, params, sgd())
    step = jax.jit(ST.make_train_step(strat, model.loss_fn, sgd(),
                                      constant(0.3), n_learners=L))
    ds = make_dataset(cfg, seq_len=21, batch=2 * L, seed=0)
    first = None
    for k in range(60):
        state, m = step(state, ds.batch_at(k))
        if first is None:
            first = float(m["loss"])
    assert float(m["loss"]) < first - 0.5
