"""Decode-shaped attention: the Pallas streaming kernel vs the jax
reference (canonical + fused delta variants, windowed masks, GQA
grouping, S-tile-crossing cache lengths) and the delta path's
equivalence to write-then-attend — the serving hot-path contracts of
docs/kernels.md."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention import (auto_block_s_decode,
                                            decode_attn_vmem_bytes,
                                            decode_attention)
from repro.models import attention as A

TOL = 2e-5          # normalized: max|pallas - jax| / max|jax|


def _setup(seed, B, S, KV, M, E):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    H = KV * M
    q = jax.random.normal(ks[0], (B, 1, H, E), jnp.float32)
    kc = jax.random.normal(ks[1], (B, S, KV, E), jnp.float32)
    vc = jax.random.normal(ks[2], (B, S, KV, E), jnp.float32)
    kn = jax.random.normal(ks[3], (B, 1, KV, E), jnp.float32)
    vn = jax.random.normal(ks[4], (B, 1, KV, E), jnp.float32)
    return q, kc, vc, kn, vn


def _norm_err(out, ref):
    return float(jnp.max(jnp.abs(out - ref)) / jnp.max(jnp.abs(ref)))


# ---------------------------------------------------------------------------
# delta == write-then-attend (jax vs jax), windowed + GQA
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("window", [None, 5, 48])
@pytest.mark.parametrize("M", [1, 4])
def test_delta_matches_write_then_attend(window, M):
    """attn_decode_delta(old cache, new column) must equal writing the
    new token first and running attn_decode over the updated cache —
    including the strict t < pos old-position mask under a window."""
    B, S, KV, E = 2, 48, 2, 8
    q, kc, vc, kn, vn = _setup(0, B, S, KV, M, E)
    for pos in (0, 3, S - 1):
        pos = jnp.int32(pos)
        delta = A.attn_decode_delta(q, kc, vc, kn, vn, pos, window=window)
        kc2 = A.update_cache(kc, kn, pos)
        vc2 = A.update_cache(vc, vn, pos)
        ref = A.attn_decode(q, kc2, vc2, pos, window=window)
        np.testing.assert_allclose(np.asarray(delta), np.asarray(ref),
                                   rtol=0, atol=1e-5)


# ---------------------------------------------------------------------------
# pallas kernel vs jax reference (<= 2e-5 normalized)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("S,block_s", [(40, 16), (33, 16), (64, 16),
                                       (16, 16), (136, 64)])
@pytest.mark.parametrize("window", [None, 7])
@pytest.mark.parametrize("M", [1, 4])
def test_pallas_matches_attn_decode(S, block_s, window, M):
    """S values that cross (and raggedly overhang) the S-tile grid."""
    B, KV, E = 2, 2, 8
    q, kc, vc, _, _ = _setup(1, B, S, KV, M, E)
    for pos in (0, S // 2, S - 1):
        pos = jnp.int32(pos)
        ref = A.attn_decode(q, kc, vc, pos, window=window)
        out = decode_attention(q, kc, vc, pos, window=window,
                               block_s=block_s, interpret=True)
        assert _norm_err(out, ref) <= TOL, (S, window, M, int(pos))


@pytest.mark.parametrize("S,block_s", [(40, 16), (33, 16), (136, 64)])
@pytest.mark.parametrize("window", [None, 7])
@pytest.mark.parametrize("M", [1, 4])
def test_pallas_matches_attn_decode_delta(S, block_s, window, M):
    B, KV, E = 2, 2, 8
    q, kc, vc, kn, vn = _setup(2, B, S, KV, M, E)
    for pos in (0, S // 2, S - 1):
        pos = jnp.int32(pos)
        ref = A.attn_decode_delta(q, kc, vc, kn, vn, pos, window=window)
        out = decode_attention(q, kc, vc, pos, window=window, k_new=kn,
                               v_new=vn, block_s=block_s, interpret=True)
        assert _norm_err(out, ref) <= TOL, (S, window, M, int(pos))


def test_impl_dispatch_and_traced_scalars():
    """attn_decode(impl='pallas') under jit with TRACED pos and window
    (the decode_step regime: the per-layer window rides the layer scan
    as data) matches the jax path."""
    B, S, KV, M, E = 2, 40, 2, 4, 8
    q, kc, vc, kn, vn = _setup(3, B, S, KV, M, E)

    @jax.jit
    def pal(pos, win):
        return (A.attn_decode(q, kc, vc, pos, window=win, impl="pallas"),
                A.attn_decode_delta(q, kc, vc, kn, vn, pos, window=win,
                                    impl="pallas"))

    for pos, win in ((20, 6), (39, 2 ** 30), (0, 1)):
        pos, win = jnp.int32(pos), jnp.int32(win)
        out_c, out_d = pal(pos, win)
        ref_c = A.attn_decode(q, kc, vc, pos, window=win)
        ref_d = A.attn_decode_delta(q, kc, vc, kn, vn, pos, window=win)
        assert _norm_err(out_c, ref_c) <= TOL
        assert _norm_err(out_d, ref_d) <= TOL


def test_auto_block_s_and_vmem_accounting():
    """The resident set never depends on S: longer caches only add
    tiles, and auto_block_s_decode keeps the set inside the budget."""
    M, E = 4, 128
    bs_small = auto_block_s_decode(256, M, E)
    bs_huge = auto_block_s_decode(1 << 20, M, E)
    assert bs_huge <= 512
    assert decode_attn_vmem_bytes(bs_huge, M, E) \
        == decode_attn_vmem_bytes(bs_huge, M, E)  # pure in block_s/M/E
    assert decode_attn_vmem_bytes(bs_small, M, E) <= 12 * 2 ** 20
    tight = auto_block_s_decode(1 << 20, M, E, vmem_budget=64 * 1024)
    assert tight < bs_huge
    assert decode_attn_vmem_bytes(tight, M, E) <= 64 * 1024 \
        or tight == 8                              # floor


# ---------------------------------------------------------------------------
# model level: decode_fn(kernel_impl='pallas') on a windowed GQA stack
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["smollm-360m", "hymba-1.5b"])
def test_decode_step_kernel_impl_parity(arch):
    """End-to-end decode_fn: jax vs pallas attention must agree within
    bf16 cache noise (hymba: heterogeneous traced windows + GQA)."""
    from repro.configs import get_arch
    from repro.models import build_model
    from repro.sharding import init_spec_tree

    cfg = get_arch(arch).reduced()
    model = build_model(cfg)
    params = init_spec_tree(model.param_specs(), jax.random.PRNGKey(0))
    B, P = 2, 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0, cfg.vocab)
    _, cache = model.prefill_fn(params, {"tokens": toks}, cache_len=24)
    out = {}
    for impl in ("jax", "pallas"):
        lg, _ = model.decode_fn(params, cache, toks[:, -1:], jnp.int32(P),
                                kernel_impl=impl)
        out[impl] = lg.astype(jnp.float32)
    err = _norm_err(out["pallas"], out["jax"])
    assert err <= 2e-2, err                         # bf16 cache regime
