"""Launcher smoke tests: the real CLIs end-to-end in subprocesses
(train, serve, and one dry-run pair with the 512-device env)."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}


def run(args, timeout=420):
    return subprocess.run([sys.executable, "-m"] + args, cwd=REPO, env=ENV,
                          capture_output=True, text=True, timeout=timeout)


def test_train_cli(tmp_path):
    r = run(["repro.launch.train", "--arch", "swb2000-blstm", "--reduced",
             "--learners", "2", "--strategy", "sd_psgd", "--steps", "12",
             "--log-every", "5", "--ckpt-dir", str(tmp_path / "ck"),
             "--ckpt-every", "10"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "done: 12 steps" in r.stdout
    assert any(d.startswith("step_") for d in os.listdir(tmp_path / "ck"))


def test_serve_cli():
    r = run(["repro.launch.serve", "--arch", "smollm-360m", "--requests",
             "2", "--slots", "1", "--max-new", "4", "--prompt-len", "8",
             "--max-len", "32"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "served 2 requests" in r.stdout


@pytest.mark.slow
def test_dryrun_cli_one_pair(tmp_path):
    """One real multi-pod dry-run in a fresh process (512 host devices)."""
    r = run(["repro.launch.dryrun", "--arch", "smollm-360m", "--shape",
             "decode_32k", "--multipod", "--out-dir", str(tmp_path)],
            timeout=560)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "all dry-runs passed" in r.stdout
    import json
    rec = json.load(open(
        tmp_path / "smollm-360m__decode_32k__multipod_2x16x16.json"))
    assert rec["status"] == "ok"
    assert rec["chips"] == 512
    assert rec["roofline"]["bound_s"] > 0


def test_benchmarks_cli_quick():
    r = run(["benchmarks.run", "--only", "table2"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "table2/ad_psgd_speedup/slow100x" in r.stdout
