"""Docs consistency as tier-1 tests (the CI `docs` job runs the same
checker standalone): no broken intra-repo markdown links, and every
launcher argparse flag documented in the README flag reference."""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "tools"))

import check_docs  # noqa: E402


def test_no_broken_markdown_links():
    assert check_docs.check_links() == []


def test_readme_flag_reference_complete():
    flags = check_docs.declared_flags()
    # sanity: the regex actually sees the launcher surfaces
    assert "--seq-chunk" in flags and "--kernel-impl" in flags
    assert check_docs.check_flag_reference() == []


def test_readme_config_reference_complete():
    knobs = check_docs.declared_config_knobs()
    # sanity: the ast walk actually sees ArchConfig fields
    assert "comm_wire" in knobs and "lstm_seq_chunk" in knobs
    assert check_docs.check_config_reference() == []


def test_checker_detects_missing_flag(tmp_path):
    """The checker is not vacuously green: a README without the flags
    fails, a markdown file with a dangling link fails, an undocumented
    ArchConfig knob fails."""
    (tmp_path / "src/repro/launch").mkdir(parents=True)
    for src in check_docs.FLAG_SOURCES:
        (tmp_path / src).write_text('ap.add_argument("--ghost-flag")\n')
    (tmp_path / "README.md").write_text("no flags here\n")
    assert check_docs.check_flag_reference(tmp_path) != []
    (tmp_path / "doc.md").write_text("[dangling](missing/file.md)\n")
    assert check_docs.check_links(tmp_path) != []
    (tmp_path / "src/repro/configs").mkdir(parents=True)
    (tmp_path / check_docs.CONFIG_SOURCE).write_text(
        "class ArchConfig:\n    ghost_knob: int = 0\n")
    assert check_docs.check_config_reference(tmp_path) != []


def test_readme_docs_index_complete():
    assert check_docs.check_docs_index() == []


def test_checker_detects_unlinked_docs_page(tmp_path):
    (tmp_path / "docs").mkdir()
    (tmp_path / "docs" / "orphan.md").write_text("# orphan\n")
    (tmp_path / "README.md").write_text("[a](docs/linked.md)\n")
    problems = check_docs.check_docs_index(tmp_path)
    assert problems and "orphan.md" in problems[0]
