"""The unified communication substrate (repro.core.transport).

* GOLDEN refactor-equivalence: with the default f32 wire every strategy's
  update trajectory is BIT-IDENTICAL to a frozen re-implementation of the
  pre-substrate ``make_train_step`` (the acceptance gate of the refactor).
* Wire codecs: int8 per-sender bound, topk difference coding, bf16.
* Topology math: hierarchical == kron matrix, pod degenerations.
* Composability: int8/topk converge under sc_psgd / ad_psgd / bmuf to the
  f32 trajectory within tolerance.
* Error-feedback state: f32 residuals under bf16 params, 100-round drift.
* Wire-byte accounting: int8 <= 0.27x f32 on the real BLSTM param tree.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import mixing
from repro.core import strategies as ST
from repro.core.transport import Transport, decode_payload
from repro.optim.optimizers import sgd
from repro.optim.schedules import constant

W_TRUE = jax.random.normal(jax.random.PRNGKey(7), (8,))


def loss_fn(params, batch):
    pred = batch["x"] @ params["w"]
    return jnp.mean((pred - batch["y"]) ** 2)


def data(seed, n=64):
    x = jax.random.normal(jax.random.PRNGKey(seed), (n, 8))
    return {"x": x, "y": x @ W_TRUE}


# ---------------------------------------------------------------------------
# GOLDEN: bit-identical to the pre-substrate step under the default wire
# ---------------------------------------------------------------------------

_LEGACY_MIXERS = {
    "sc_psgd_replicated": mixing.mix_uniform,
    "sd_psgd": mixing.mix_ring,
    "ad_psgd": mixing.mix_ring,
    "downpour": mixing.mix_uniform,
    "hring": mixing.mix_ring,          # pre-substrate hring == plain ring
    "bmuf": mixing.mix_uniform,        # block-sync averaging
}


def _legacy_step_factory(strategy, optimizer, lr_schedule, n_learners):
    """Frozen copy of the PRE-substrate make_train_step (replicated,
    rectangular-batch path) — the oracle for refactor equivalence."""
    legacy_mix = _LEGACY_MIXERS[strategy.name]

    def step(state, batch):
        lr = lr_schedule(state["step"])
        lbatch = ST.split_learner_batch(batch, n_learners)
        grad_at = state["prev_params"] if strategy.stale else state["params"]
        loss_l, g_l = jax.vmap(
            lambda p, b: jax.value_and_grad(loss_fn)(p, b))(grad_at, lbatch)
        metrics = {"loss": jnp.mean(loss_l)}

        if strategy.block_size:
            upd_params, opt = jax.vmap(
                optimizer.update, in_axes=(0, 0, 0, None)
            )(g_l, state["opt"], state["params"], lr)
            step_no = state["step"] + 1
            is_sync = (step_no % strategy.block_size) == 0

            def do_sync(args):
                params, anchor, mom = args
                avg = legacy_mix(params)
                delta = jax.tree.map(
                    lambda a, b: (a.astype(jnp.float32)
                                  - b.astype(jnp.float32)), avg, anchor)
                mom = jax.tree.map(
                    lambda m, d: strategy.block_momentum * m
                    + strategy.block_lr * d, mom, delta)
                new = jax.tree.map(
                    lambda b, m: (b.astype(jnp.float32) + m).astype(b.dtype),
                    anchor, mom)
                return new, new, mom

            def no_sync(args):
                return args

            new_params, anchor, mom = jax.lax.cond(
                is_sync, do_sync, no_sync,
                (upd_params, state["anchor"], state["block_mom"]))
            out = {"params": new_params, "opt": opt, "step": step_no,
                   "anchor": anchor, "block_mom": mom}
        else:
            mixed = legacy_mix(state["params"])
            new_params, opt = jax.vmap(
                optimizer.update, in_axes=(0, 0, 0, None)
            )(g_l, state["opt"], mixed, lr)
            out = {"params": new_params, "opt": opt,
                   "step": state["step"] + 1}

        if strategy.stale:
            out["prev_params"] = state["params"]
        return out, metrics

    return step


@pytest.mark.parametrize("name", ["sc_psgd_replicated", "sd_psgd",
                                  "ad_psgd", "downpour", "bmuf", "hring"])
def test_golden_bit_identical_to_pre_substrate_step(name):
    """wire=f32 / default topology: the refactored step reproduces the
    pre-substrate update trajectory EXACTLY (34 steps crosses two BMUF
    block boundaries)."""
    s = ST.get_strategy(name)
    L = 4
    params = {"w": jax.random.normal(jax.random.PRNGKey(3), (L, 8))}
    state_new = ST.init_state(s, jax.tree.map(jnp.copy, params), sgd())
    state_old = ST.init_state(s, jax.tree.map(jnp.copy, params), sgd())
    step_new = jax.jit(ST.make_train_step(s, loss_fn, sgd(), constant(0.1),
                                          n_learners=L))
    step_old = jax.jit(_legacy_step_factory(s, sgd(), constant(0.1), L))
    for k in range(34):
        b = data(k)
        state_new, m_new = step_new(state_new, b)
        state_old, m_old = step_old(state_old, b)
    for key in state_old:
        got = jax.tree.leaves(state_new[key])
        want = jax.tree.leaves(state_old[key])
        for a, b_ in zip(got, want):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b_),
                                          err_msg=f"{name}/{key}")
    np.testing.assert_array_equal(np.asarray(m_new["loss"]),
                                  np.asarray(m_old["loss"]))


def test_golden_sc_psgd_nonreplicated_unchanged():
    """The GSPMD data-parallel path (Eq. 13) takes no substrate: same
    trajectory as a plain value_and_grad SGD loop."""
    s = ST.get_strategy("sc_psgd")
    params = {"w": jnp.zeros((8,))}
    state = ST.init_state(s, jax.tree.map(jnp.copy, params), sgd())
    step = jax.jit(ST.make_train_step(s, loss_fn, sgd(), constant(0.1)))
    opt = sgd()
    ref_p, ref_o = jax.tree.map(jnp.copy, params), opt.init(params)
    for k in range(10):
        b = data(k)
        state, _ = step(state, b)
        _, g = jax.value_and_grad(loss_fn)(ref_p, b)
        ref_p, ref_o = opt.update(g, ref_o, ref_p, 0.1)
    np.testing.assert_array_equal(np.asarray(state["params"]["w"]),
                                  np.asarray(ref_p["w"]))


# ---------------------------------------------------------------------------
# wire codecs
# ---------------------------------------------------------------------------

def test_int8_codec_per_sender_bound():
    rng = np.random.default_rng(0)
    # wildly different per-sender scales: per-sender coding must bound the
    # error by each sender's own amax/254, not the global one
    x = jnp.asarray(rng.normal(size=(4, 128)), jnp.float32) \
        * jnp.asarray([[0.01], [1.0], [100.0], [0.5]])
    d = decode_payload("int8", x)
    amax = np.abs(np.asarray(x)).max(axis=1, keepdims=True)
    assert np.all(np.abs(np.asarray(d - x)) <= amax / 254.0 + 1e-7)


def test_bf16_codec_is_truncation():
    x = jnp.asarray([[1.0 + 2 ** -10, -3.25]], jnp.float32)
    d = decode_payload("bf16", x)
    np.testing.assert_array_equal(
        np.asarray(d), np.asarray(x.astype(jnp.bfloat16), np.float32))


def test_topk_codec_keeps_largest():
    x = jnp.asarray([[0.1, -5.0, 0.2, 3.0, -0.3, 0.05, 1.0, -0.01]],
                    jnp.float32)
    d = np.asarray(decode_payload("topk", x, topk_frac=0.25))  # k = 2
    assert set(np.nonzero(d[0])[0]) == {1, 3}
    np.testing.assert_array_equal(d[0, [1, 3]], [-5.0, 3.0])


def test_unknown_wire_and_topology_raise():
    with pytest.raises(ValueError, match="unknown wire"):
        Transport(wire="fp8")
    with pytest.raises(ValueError, match="unknown topology"):
        Transport(topology="torus")
    with pytest.raises(ValueError, match="pod_size"):
        Transport(topology="hierarchical", pod_size=3).make_mixer(8)
    with pytest.raises(ValueError, match="power-of-2"):
        Transport(topology="exp").make_mixer(6)


# ---------------------------------------------------------------------------
# topologies
# ---------------------------------------------------------------------------

def test_hierarchical_equals_kron_matrix():
    L, p = 8, 2
    rng = np.random.default_rng(1)
    w = {"a": jnp.asarray(rng.normal(size=(L, 7)), jnp.float32)}
    T = mixing.hierarchical_matrix(L, p)
    assert mixing.is_doubly_stochastic(T)
    ref = mixing.mix_matrix(w, T)["a"]
    fast = mixing.mix_hierarchical(w, pod_size=p)["a"]
    np.testing.assert_allclose(np.asarray(fast), np.asarray(ref), atol=1e-5)
    # the transport's coded path at f32 agrees too
    via_t, _ = Transport(topology="hierarchical", pod_size=p,
                         bucket_bytes=8).make_mixer(L)(w, jnp.int32(0), {})
    np.testing.assert_allclose(np.asarray(via_t["a"]), np.asarray(ref),
                               atol=1e-5)


def test_hierarchical_degenerations():
    rng = np.random.default_rng(2)
    w = {"a": jnp.asarray(rng.normal(size=(6, 5)), jnp.float32)}
    ring = Transport(topology="hierarchical", pod_size=1).make_mixer(6)
    np.testing.assert_array_equal(
        np.asarray(ring(w, jnp.int32(0), {})[0]["a"]),
        np.asarray(mixing.mix_ring(w)["a"]))
    uni = Transport(topology="hierarchical", pod_size=6).make_mixer(6)
    np.testing.assert_array_equal(
        np.asarray(uni(w, jnp.int32(0), {})[0]["a"]),
        np.asarray(mixing.mix_uniform(w)["a"]))


def test_bucketed_collectives_match_fused():
    """Bucketing only chunks the payload; elementwise codecs + combines
    give identical results (f32/bf16 exactly; int8 re-scales per bucket)."""
    rng = np.random.default_rng(3)
    w = {"a": jnp.asarray(rng.normal(size=(4, 1000)), jnp.float32)}
    for wire in ("f32", "bf16"):
        fused, _ = Transport(topology="ring", wire=wire).make_mixer(4)(
            w, jnp.int32(0), {})
        bucketed, _ = Transport(topology="ring", wire=wire,
                                bucket_bytes=256).make_mixer(4)(
            w, jnp.int32(0), {})
        np.testing.assert_array_equal(np.asarray(fused["a"]),
                                      np.asarray(bucketed["a"]))
    exact = mixing.mix_ring(w)["a"]
    q8, _ = Transport(topology="ring", wire="int8",
                      bucket_bytes=256).make_mixer(4)(w, jnp.int32(0), {})
    scale = float(jnp.max(jnp.abs(w["a"])))
    assert float(jnp.max(jnp.abs(q8["a"] - exact))) < scale / 100


def test_mean_preservation_across_wires():
    """Doubly-stochastic mixing preserves the replica mean; coded wires
    must stay within their codec error (exactly, for difference-coded
    topk: the gossip term T·ŵ − ŵ sums to zero)."""
    rng = np.random.default_rng(4)
    w = {"a": jnp.asarray(rng.normal(size=(8, 64)), jnp.float32)}
    mu = np.asarray(w["a"]).mean(axis=0)
    for topo in ("ring", "uniform", "exp"):
        for wire in ("f32", "bf16", "int8", "topk"):
            t = Transport(topology=topo, wire=wire, topk_frac=0.25)
            comm = t.init_comm(w)
            mixed, _ = t.make_mixer(8)(w, jnp.int32(0), comm)
            drift = np.abs(np.asarray(mixed["a"]).mean(axis=0) - mu).max()
            tol = {"f32": 1e-6, "bf16": 2e-2, "int8": 2e-2,
                   "topk": 1e-5}[wire]
            assert drift < tol, (topo, wire, drift)


# ---------------------------------------------------------------------------
# composability: compressed wires under sc/ad_psgd + bmuf (acceptance)
# ---------------------------------------------------------------------------

def _run(name, transport, steps=400, lr=0.04, L=4):
    s = ST.get_strategy(name)
    params = ST.stack_for_learners({"w": jnp.zeros((8,))}, L)
    state = ST.init_state(s, params, sgd(), transport=transport)
    step = jax.jit(ST.make_train_step(s, loss_fn, sgd(), constant(lr),
                                      n_learners=L, transport=transport))
    for k in range(steps):
        state, m = step(state, data(k))
    final = ST.average_learners(state["params"])
    heldout = float(loss_fn(final, data(10_000)))
    return final, heldout, state, m


@pytest.mark.parametrize("strat", ["sc_psgd_replicated", "ad_psgd", "bmuf"])
@pytest.mark.parametrize("wire", ["int8", "topk"])
def test_compressed_wire_matches_f32_final_loss(strat, wire):
    topo = ST.get_strategy(strat).topology
    t_f32 = Transport(topology=topo, wire="f32")
    t_c = Transport(topology=topo, wire=wire, topk_frac=0.25)
    _, held_f32, _, _ = _run(strat, t_f32)
    final, held_c, state, m = _run(strat, t_c)
    # same optimum within tolerance (bmuf converges more slowly on the
    # toy, but identically so across wires)
    assert abs(held_c - held_f32) < 0.05, (held_c, held_f32)
    assert float(m["wire_bytes"]) >= 0.0
    if wire == "topk":
        assert set(state["comm"]) == {"residual", "estimate"}


def test_hring_mixed_intra_inter_wires_converge():
    """The paper's §V setting: cheap bf16 inside the pod, topk-sparse
    across pods."""
    t = Transport(topology="hierarchical", pod_size=2, intra_wire="bf16",
                  wire="topk", topk_frac=0.25)
    final, held, _, m = _run("hring", t)
    assert float(jnp.linalg.norm(final["w"] - W_TRUE)) < 0.1
    assert float(m["wire_bytes"]) > 0


def test_bmuf_wire_bytes_only_on_sync_steps():
    t = Transport(topology="uniform", wire="int8")
    s = ST.get_strategy("bmuf")
    params = ST.stack_for_learners({"w": jnp.zeros((8,))}, 4)
    state = ST.init_state(s, params, sgd(), transport=t)
    step = jax.jit(ST.make_train_step(s, loss_fn, sgd(), constant(0.03),
                                      n_learners=4, transport=t))
    wb = []
    for k in range(2 * s.block_size):
        state, m = step(state, data(k))
        wb.append(float(m["wire_bytes"]))
    assert wb.count(0.0) == len(wb) - 2          # two block boundaries
    assert wb[s.block_size - 1] > 0 and wb[-1] > 0


def test_topk_without_comm_state_raises():
    t = Transport(topology="ring", wire="topk")
    w = {"a": jnp.ones((4, 8))}
    with pytest.raises(ValueError, match="error-feedback state"):
        t.make_mixer(4)(w, jnp.int32(0), {})


# ---------------------------------------------------------------------------
# error-feedback residuals: f32 accumulation + bounded drift (satellite)
# ---------------------------------------------------------------------------

def test_ef_residuals_accumulate_in_f32_under_bf16_params():
    """100 mixing rounds on bf16 replicas: the residual/estimate trees
    stay f32, consensus is reached, and the replica mean drifts only by
    bf16 storage rounding — the compression itself leaks nothing."""
    rng = np.random.default_rng(5)
    w = {"a": jnp.asarray(rng.normal(size=(4, 256)),
                          jnp.float32).astype(jnp.bfloat16)}
    mu0 = np.asarray(w["a"], np.float32).mean(axis=0)
    t = Transport(topology="ring", wire="topk", topk_frac=0.1)
    comm = t.init_comm(w)
    mix = jax.jit(t.make_mixer(4))
    start = float(ST.consensus_distance(w))
    for k in range(100):
        w, comm = mix(w, jnp.int32(k), comm)
    assert comm["residual"]["a"].dtype == jnp.float32
    assert comm["estimate"]["a"].dtype == jnp.float32
    assert w["a"].dtype == jnp.bfloat16
    end = float(ST.consensus_distance(w))
    assert end < 0.05 * start                      # gossip converged
    drift = np.abs(np.asarray(w["a"], np.float32).mean(axis=0) - mu0).max()
    # bf16 ulp-scale storage rounding over 100 rounds, nothing more
    assert drift < 0.05, drift
    # the estimate tracks the (bf16) replicas to codec accuracy
    est_err = np.abs(np.asarray(comm["estimate"]["a"])
                     - np.asarray(w["a"], np.float32)).max()
    assert est_err < 0.1, est_err


def test_ef_residual_shapes_follow_payload_domain():
    """Hierarchical inter-pod residuals live at pod granularity."""
    w = {"a": jnp.ones((8, 16))}
    t = Transport(topology="hierarchical", pod_size=2, wire="topk")
    comm = t.init_comm(w)
    assert comm["residual"]["a"].shape == (4, 16)   # one per pod
    assert comm["estimate"]["a"].shape == (4, 16)


def test_topk_intra_wire_rejected():
    """Difference-coded wires are gossip-only: an allreduce stage cannot
    realize the damped-estimate update (undamped, the first round would
    collapse every pod to ~topk_frac of its mass)."""
    with pytest.raises(ValueError, match="gossip-only"):
        Transport(topology="hierarchical", pod_size=2, intra_wire="topk")


def test_lossy_intra_wire_not_swallowed_by_f32_fast_path():
    """Regression: wire='f32' + intra_wire='bf16' must actually code the
    intra-pod stage (the fast path used to shortcut to the exact mixer
    while wire_bytes still billed the bf16 payload)."""
    rng = np.random.default_rng(6)
    w = {"a": jnp.asarray(rng.normal(size=(8, 64)), jnp.float32)}
    t = Transport(topology="hierarchical", pod_size=2, intra_wire="bf16")
    mixed, _ = t.make_mixer(8)(w, jnp.int32(0), {})
    exact = mixing.mix_hierarchical(w, pod_size=2)["a"]
    diff = float(jnp.max(jnp.abs(mixed["a"] - exact)))
    assert diff > 0.0                      # the codec really ran
    assert diff < 2e-2                     # ...and is only bf16 rounding
    # pod_size=1 has no intra stage: the exact fast path is still taken
    t1 = Transport(topology="hierarchical", pod_size=1, intra_wire="bf16")
    m1, _ = t1.make_mixer(8)(w, jnp.int32(0), {})
    np.testing.assert_array_equal(np.asarray(m1["a"]),
                                  np.asarray(mixing.mix_ring(w)["a"]))


# ---------------------------------------------------------------------------
# wire-byte accounting (acceptance: int8 <= 0.27x f32)
# ---------------------------------------------------------------------------

def test_wire_bytes_ratios_on_blstm_param_tree():
    from repro.configs import get_arch
    from repro.models import build_model

    L = 16
    specs = build_model(get_arch("swb2000-blstm").reduced()).param_specs()
    stacked = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((L,) + tuple(s.shape), jnp.float32),
        specs)
    per = {w: Transport(topology="ring", wire=w).wire_bytes(stacked)
           for w in ("f32", "bf16", "int8", "topk")}
    assert per["int8"] <= 0.27 * per["f32"]
    assert per["topk"] < per["int8"] < per["bf16"] < per["f32"]
    assert per["bf16"] == 0.5 * per["f32"]


def test_wire_bytes_topology_multipliers():
    w = {"a": jnp.ones((8, 100))}
    f32 = 400.0
    assert Transport(topology="ring").wire_bytes(w) == 2 * f32
    assert Transport(topology="uniform").wire_bytes(w) == \
        pytest.approx(2 * 7 / 8 * f32)
    assert Transport(topology="exp").wire_bytes(w) == f32
    assert Transport(topology="none").wire_bytes(w) == 0.0
    # hierarchical: intra 2(p-1)/p + inter ring amortized over the pod
    h = Transport(topology="hierarchical", pod_size=2)
    assert h.wire_bytes(w) == pytest.approx(2 * 0.5 * f32 + 2 * f32 / 2)
    # alone in the ring -> silence
    assert Transport(topology="ring").wire_bytes({"a": jnp.ones((1, 9))}) \
        == 0.0


def test_transport_from_cfg_resolution():
    from repro.configs import get_arch

    cfg = dataclasses.replace(get_arch("swb2000-blstm"),
                              comm_wire="int8", comm_bucket_mb=4,
                              comm_pod_size=2)
    t = ST.transport_from_cfg(cfg, ST.get_strategy("hring"))
    assert t == Transport(topology="hierarchical", wire="int8",
                          bucket_bytes=4 * 2 ** 20, pod_size=2,
                          topk_frac=cfg.comm_topk_frac)
    t2 = ST.transport_from_cfg(get_arch("swb2000-blstm"),
                               ST.get_strategy("ad_psgd_q8"))
    assert (t2.topology, t2.wire) == ("ring", "int8")
