"""Sharding-rule logic (pure; no big meshes needed) + hypothesis sweeps."""
import jax
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need the dev extra
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.sharding import MeshRules, ParamSpec, default_rules, multipod_rules


class FakeMesh:
    """Duck-typed mesh: only .shape is consulted by MeshRules.spec."""

    def __init__(self, shape):
        self.shape = shape


POD = FakeMesh({"data": 16, "model": 16})
MULTI = FakeMesh({"pod": 2, "data": 16, "model": 16})


def rules(mesh=POD, **kw):
    mk = multipod_rules if "pod" in mesh.shape else default_rules
    return MeshRules(mesh, mk(**kw))


def test_mlp_sharded_on_model():
    assert rules().spec((5120, 17920), ("embed", "mlp")) == P(None, "model")


def test_vocab_sharded():
    assert rules().spec((100352, 5120), ("vocab", "embed")) == \
        P("model", None)


def test_attention_weights_replicated():
    # baseline policy: no assigned arch has heads divisible by 16
    assert rules().spec((5120, 40, 128), ("embed", "heads", "head_dim")) == \
        P(None, None, None)


def test_indivisible_dim_falls_through():
    # 40 heads % 16 != 0 -> unsharded even if the rule suggested 'model'
    r = MeshRules(POD, {"heads": ("model",)})
    assert r.spec((40,), ("heads",)) == P(None)
    assert r.spec((64,), ("heads",)) == P("model")


def test_axis_used_once_per_spec():
    r = MeshRules(POD, {"a": ("model",), "b": ("model",)})
    assert r.spec((32, 32), ("a", "b")) == P("model", None)


def test_multi_axis_candidate_cache_seq():
    r = rules()
    # decode_32k: batch takes data, cache_seq falls back to model alone
    spec = r.spec((40, 128, 32768, 8, 128),
                  ("layers", "batch", "cache_seq", "kv_heads", "head_dim"))
    assert spec == P(None, "data", "model", None, None)
    # long_500k: batch=1 unshardable -> cache_seq gets model+data combined
    spec = r.spec((40, 1, 524288, 8, 128),
                  ("layers", "batch", "cache_seq", "kv_heads", "head_dim"))
    assert spec == P(None, None, ("model", "data"), None, None)


def test_learner_axis_single_vs_multipod():
    lead = ((16, "learner"), )
    r1 = rules()
    assert r1.spec((16, 256, 4096), ("learner", "batch", "seq")) == \
        P("data", None, None)
    r2 = rules(MULTI)
    assert r2.spec((2, 128, 4096), ("learner", "batch", "seq")) == \
        P("pod", "data", None)


def test_fsdp_rules_shard_embed_dim():
    r = rules(fsdp=True)
    assert r.spec((5120, 8192), ("embed", "mlp")) == P("data", "model")


def test_expert_axis():
    r = rules(expert_axis="data")
    assert r.spec((16, 5120, 8192), ("experts", "embed", "expert_mlp")) == \
        P("data", None, "model")


@given(st.lists(st.sampled_from([1, 2, 3, 16, 32, 40, 64, 100, 256]),
                min_size=1, max_size=4),
       st.lists(st.sampled_from(["embed", "mlp", "vocab", "heads", "batch",
                                 "cache_seq", "experts", None]),
                min_size=1, max_size=4))
@settings(max_examples=100, deadline=None)
def test_spec_always_valid(dims, axes):
    """Property: any (shape, axes) yields a spec whose sharded dims divide
    evenly and which uses each mesh axis at most once."""
    n = min(len(dims), len(axes))
    dims, axes = tuple(dims[:n]), tuple(axes[:n])
    r = rules()
    spec = r.spec(dims, axes)
    used = []
    for d, s in zip(dims, spec):
        if s is None:
            continue
        group = s if isinstance(s, tuple) else (s,)
        size = int(np.prod([POD.shape[a] for a in group]))
        assert d % size == 0
        used += list(group)
    assert len(used) == len(set(used))


def test_spec_tree_to_sds_with_leading():
    from repro.sharding import spec_tree_to_sds

    from repro.launch.mesh import _make_mesh

    mesh = _make_mesh((1, 1), ("data", "model"))
    r = MeshRules(mesh, default_rules())
    tree = {"w": ParamSpec((8, 4), "float32", ("embed", "mlp"))}
    sds = spec_tree_to_sds(tree, r, extra_leading=((2, "learner"),))
    assert sds["w"].shape == (2, 8, 4)
