"""Multi-tenant serving layer tests (docs/serving.md).

Everything runs in VIRTUAL time — no wall-clock sleeps anywhere; the
only real compute is the reduced-model prefill/decode of the slot-pool
servers.  The controller/loop/capacity tests run on a deterministic
in-memory FakeServer so the scheduling semantics are tested in
milliseconds, and the bit-exactness contracts (batched-vs-sequential
step, preempt-then-resume) run on the real servers.
"""
import dataclasses
import math

import numpy as np
import pytest

from repro.configs import get_arch
from repro.serving import (CostModel, Recorder, ServingLoop, VirtualClock,
                           Workload, generate_trace, make_payload,
                           percentile, rate_at, summarize, summary_rows,
                           sustained_capacity)
from repro.serving.admission import (NO_BUDGET, OK, POOL_FULL,
                                     PROMPT_TOO_LONG, AdmissionController,
                                     AdmitResult)
from repro.serving.capacity import feasible, run_level
from repro.serving.slo import csv_row
from repro.serving.workload import Request


def _lm_cfg():
    return get_arch("smollm-360m").reduced()


def _asr_cfg():
    return dataclasses.replace(
        get_arch("swb2000-blstm").reduced(), n_layers=1, lstm_hidden=32,
        lstm_bottleneck=16, input_dim=16, vocab=32, beam_width=3)


# ---------------------------------------------------------------------------
# workload: seeded determinism, rate, validation
# ---------------------------------------------------------------------------

class TestWorkload:
    def test_trace_deterministic(self):
        w = Workload(qps=3.0, horizon=20.0, seed=11, diurnal_amp=0.4,
                     diurnal_period=10.0)
        a, b = generate_trace(w), generate_trace(w)
        assert a == b
        assert len(a) > 0
        assert all(a[i].arrival <= a[i + 1].arrival
                   for i in range(len(a) - 1))
        assert [r.rid for r in a] == list(range(len(a)))

    def test_seed_sensitivity(self):
        w = Workload(qps=3.0, horizon=20.0, seed=0)
        assert generate_trace(w) != generate_trace(
            dataclasses.replace(w, seed=1))

    def test_empirical_rate_matches_lambda(self):
        qps, horizon = 5.0, 200.0
        n = len(generate_trace(Workload(qps=qps, horizon=horizon, seed=3)))
        mean = qps * horizon
        assert abs(n - mean) < 4 * math.sqrt(mean)   # ~4 sigma

    def test_diurnal_thinning_preserves_mean_rate(self):
        # modulation reshapes arrivals in time but keeps the mean rate
        w = Workload(qps=5.0, horizon=200.0, seed=3, diurnal_amp=0.8,
                     diurnal_period=10.0)
        n = len(generate_trace(w))
        mean = w.qps * w.horizon
        assert abs(n - mean) < 4 * math.sqrt(mean)

    def test_lengths_and_tiers_in_range(self):
        w = Workload(qps=4.0, horizon=30.0, seed=5, len_min=2, len_max=9,
                     tier_probs=(0.5, 0.3, 0.2))
        trace = generate_trace(w)
        assert all(2 <= r.length <= 9 for r in trace)
        assert {r.tier for r in trace} <= {0, 1, 2}
        assert len({r.tier for r in trace}) > 1     # actually mixes tiers

    def test_validation_errors(self):
        with pytest.raises(ValueError, match="diurnal_amp"):
            generate_trace(Workload(qps=1.0, horizon=1.0, diurnal_amp=1.0))
        with pytest.raises(ValueError, match="positive"):
            generate_trace(Workload(qps=0.0, horizon=1.0))
        with pytest.raises(ValueError, match="positive"):
            generate_trace(Workload(qps=1.0, horizon=-1.0))
        with pytest.raises(ValueError, match="tier_probs"):
            generate_trace(Workload(qps=1.0, horizon=1.0,
                                    tier_probs=(-0.5, 1.5)))

    def test_payload_determinism_and_modes(self):
        req = Request(rid=7, arrival=0.0, length=12, tier=0, max_new=4,
                      patience=1.0, deadline=1.0)
        a = make_payload(req, mode="lm", vocab=64, seed=9)
        b = make_payload(req, mode="lm", vocab=64, seed=9)
        np.testing.assert_array_equal(a, b)
        assert a.shape == (12,) and a.dtype == np.int32
        f = make_payload(req, mode="asr", input_dim=8, seed=9)
        assert f.shape == (12, 8) and f.dtype == np.float32
        with pytest.raises(ValueError):
            make_payload(req, mode="lm", vocab=0)
        with pytest.raises(ValueError):
            make_payload(req, mode="nope", vocab=4)


class TestRateAt:
    def test_no_modulation(self):
        w = Workload(qps=3.0, horizon=1.0)
        assert rate_at(w, 12.3) == 3.0

    def test_peak_and_trough_exact(self):
        w = Workload(qps=4.0, horizon=1.0, diurnal_amp=0.5,
                     diurnal_period=8.0)
        assert rate_at(w, 2.0) == pytest.approx(6.0)    # sin peak
        assert rate_at(w, 6.0) == pytest.approx(2.0)    # sin trough

    def test_monotone_in_amplitude(self):
        pytest.importorskip("hypothesis")
        from hypothesis import given, settings
        from hypothesis import strategies as st

        @given(qps=st.floats(0.1, 50.0),
               amp1=st.floats(0.0, 0.98), amp2=st.floats(0.0, 0.98),
               frac=st.floats(0.01, 0.99))
        @settings(max_examples=50, deadline=None)
        def check(qps, amp1, amp2, frac):
            lo, hi = sorted((amp1, amp2))
            period = 10.0
            t = frac * period
            w_lo = Workload(qps=qps, horizon=1.0, diurnal_amp=lo,
                            diurnal_period=period)
            w_hi = Workload(qps=qps, horizon=1.0, diurnal_amp=hi,
                            diurnal_period=period)
            s = math.sin(2.0 * math.pi * t / period)
            if s > 0:        # rising phase: more amplitude, more rate
                assert rate_at(w_hi, t) >= rate_at(w_lo, t)
            elif s < 0:      # falling phase: more amplitude, less rate
                assert rate_at(w_hi, t) <= rate_at(w_lo, t)
            assert rate_at(w_hi, t) >= 0.0

        check()


# ---------------------------------------------------------------------------
# SLO accounting: nearest-rank percentiles, hand-built traces
# ---------------------------------------------------------------------------

class TestPercentile:
    def test_nearest_rank_known_values(self):
        vals = list(range(1, 101))                   # 1..100
        assert percentile(vals, 50) == 50
        assert percentile(vals, 95) == 95
        assert percentile(vals, 99) == 99
        assert percentile(vals, 100) == 100
        assert percentile([7.0], 99) == 7.0
        assert percentile([3.0, 1.0, 2.0, 4.0], 50) == 2.0  # ceil(2)-1
        assert math.isnan(percentile([], 50))
        assert percentile([1.0, float("nan"), 3.0], 100) == 3.0

    def test_nearest_rank_is_an_element(self):
        pytest.importorskip("hypothesis")
        from hypothesis import given, settings
        from hypothesis import strategies as st

        @given(vals=st.lists(st.floats(-1e6, 1e6), min_size=1,
                             max_size=40),
               q=st.floats(0.5, 100.0))
        @settings(max_examples=50, deadline=None)
        def check(vals, q):
            p = percentile(vals, q)
            assert p in vals                          # no interpolation
            # at least ceil(q% n) samples are <= p
            n = len(vals)
            assert sum(v <= p for v in vals) >= math.ceil(q / 100.0 * n)

        check()

    def test_summarize_hand_built_trace(self):
        r = Recorder()
        # 4 done requests with first-token latencies 1, 2, 3, 4
        for i, ft in enumerate([1.0, 2.0, 3.0, 4.0]):
            r.offered(i, tier=i % 2, arrival=10.0 * i, deadline=10.0 * i + 5)
            r.admitted(i, 10.0 * i + ft)
            r.first_token(i, 10.0 * i + ft)
            r.done(i, 10.0 * i + ft + 2.0, n_tokens=3)
        # one abandoned, one rejected
        r.offered(4, tier=0, arrival=100.0)
        r.abandoned(4, 101.0)
        r.offered(5, tier=1, arrival=200.0)
        r.rejected(5, 200.0, PROMPT_TOO_LONG)
        s = summarize(r, n_tiers=2)
        assert s["offered"] == 6 and s["done"] == 4
        assert s["abandoned"] == 1 and s["rejected"] == 1
        assert s["tokens"] == 12
        assert s["first_token"]["p50"] == 2.0        # nearest rank of n=4
        assert s["first_token"]["p95"] == 4.0
        assert s["final"]["p50"] == 4.0
        # request 3: final latency 6 > deadline 5 -> 1 of 4 misses
        assert s["deadline_miss_frac"] == pytest.approx(0.25)
        assert s["per_tier"][0]["done"] == 2
        assert s["per_tier"][1]["offered"] == 3

    def test_first_token_stamped_once(self):
        r = Recorder()
        r.offered(0, 0, 0.0)
        r.first_token(0, 1.0)
        r.first_token(0, 9.0)                        # later stamps ignored
        assert r.events[0].t_first == 1.0

    def test_csv_rows_parse(self):
        r = Recorder()
        r.offered(0, 0, 0.0)
        r.admitted(0, 0.5)
        r.first_token(0, 0.5)
        r.done(0, 1.0, n_tokens=2)
        rows = summary_rows(summarize(r, n_tiers=1), "load", "virtual s")
        assert any(n == "load/done/tier0" for n, _, _ in rows)
        for name, value, derived in rows:
            line = csv_row(name, value, derived)
            parts = line.split(",", 2)
            assert parts[0] == name
            float(parts[1])                          # parseable value


# ---------------------------------------------------------------------------
# a deterministic in-memory server for controller/loop/capacity tests
# ---------------------------------------------------------------------------

class FakeServer:
    """Slot-pool duck contract without any model: each request takes a
    fixed number of waves; payloads longer than ``too_long`` reject."""

    emits_on_admit = False

    def __init__(self, slots, waves=2, too_long=10_000):
        self.slots = slots
        self.waves = waves
        self.too_long = too_long
        self.jobs = {}           # rid -> remaining waves

    def submit(self, req, payload):
        if req.length > self.too_long:
            return AdmitResult(PROMPT_TOO_LONG)
        if req.max_new <= 0:
            return AdmitResult(NO_BUDGET)
        if len(self.jobs) >= self.slots:
            return AdmitResult(POOL_FULL)
        self.jobs[req.rid] = self.waves
        return AdmitResult(OK, 0)

    def step_wave(self):
        progressed = sorted(self.jobs)
        done = []
        for rid in progressed:
            self.jobs[rid] -= 1
            if self.jobs[rid] <= 0:
                done.append((rid, [0] * self.waves))
                del self.jobs[rid]
        return done, progressed, len(progressed)

    def preempt(self, rid):
        return ("snap", rid, self.jobs.pop(rid))

    def restore(self, snap):
        if len(self.jobs) >= self.slots:
            return AdmitResult(POOL_FULL)
        self.jobs[snap[1]] = snap[2]
        return AdmitResult(OK, 0)

    def reset(self):
        self.jobs.clear()


def _req(rid, arrival, tier=0, length=5, max_new=4, patience=30.0,
         deadline=60.0):
    return Request(rid=rid, arrival=arrival, length=length, tier=tier,
                   max_new=max_new, patience=patience, deadline=deadline)


# ---------------------------------------------------------------------------
# admission controller semantics
# ---------------------------------------------------------------------------

class TestAdmissionController:
    def test_typed_terminal_rejections_recorded(self):
        ctl = AdmissionController(FakeServer(2, too_long=10), n_tiers=1)
        ctl.offer(_req(0, 0.0, length=99), None)      # too long
        ctl.offer(_req(1, 0.0, max_new=0), None)      # no budget
        ctl.offer(_req(2, 0.0), None)                 # fine
        ctl.pump(0.0)
        evs = ctl.recorder.events
        assert evs[0].outcome == "rejected"
        assert evs[0].reject_reason == PROMPT_TOO_LONG
        assert evs[1].outcome == "rejected"
        assert evs[1].reject_reason == NO_BUDGET
        assert evs[2].outcome == "running"

    def test_tier_order_and_fifo(self):
        srv = FakeServer(2)
        ctl = AdmissionController(srv, n_tiers=2)
        for rid, tier in [(0, 1), (1, 1), (2, 0)]:
            ctl.offer(_req(rid, 0.0, tier=tier), None)
        ctl.pump(0.0)
        # tier 0 admits first, then tier-1 FIFO: rids 2 and 0 run
        assert set(srv.jobs) == {2, 0}

    def test_preempts_lowest_priority_latest_admitted(self):
        srv = FakeServer(2, waves=10)
        ctl = AdmissionController(srv, n_tiers=3)
        ctl.offer(_req(0, 0.0, tier=2), None)
        ctl.offer(_req(1, 0.0, tier=1), None)
        ctl.pump(0.0)
        assert set(srv.jobs) == {0, 1}
        ctl.offer(_req(2, 1.0, tier=0), None)
        ctl.pump(1.0)
        # rid 0 (tier 2) is the strictly-lowest-priority victim
        assert set(srv.jobs) == {1, 2}
        assert ctl.recorder.events[0].n_preempt == 1
        assert ctl.recorder.n_preemptions == 1
        # the preempted job sits at the FRONT of its tier queue
        assert ctl.queues[2][0].rid == 0

    def test_no_preemption_of_equal_priority(self):
        srv = FakeServer(1, waves=10)
        ctl = AdmissionController(srv, n_tiers=2)
        ctl.offer(_req(0, 0.0, tier=0), None)
        ctl.pump(0.0)
        ctl.offer(_req(1, 1.0, tier=0), None)
        ctl.pump(1.0)
        assert set(srv.jobs) == {0}                  # rid 1 waits
        assert ctl.recorder.n_preemptions == 0

    def test_preempt_disabled(self):
        srv = FakeServer(1, waves=10)
        ctl = AdmissionController(srv, n_tiers=2, preempt=False)
        ctl.offer(_req(0, 0.0, tier=1), None)
        ctl.pump(0.0)
        ctl.offer(_req(1, 1.0, tier=0), None)
        ctl.pump(1.0)
        assert set(srv.jobs) == {0}
        assert ctl.check_inversion() == []           # not tracked when off

    def test_abandonment_unstarted_only(self):
        srv = FakeServer(1, waves=4)
        ctl = AdmissionController(srv, n_tiers=2)
        # rid 0 (tier 1) admitted, then preempted by rid 1 (tier 0);
        # rid 2 never admitted.  Both 0 and 2 have tiny patience.
        ctl.offer(_req(0, 0.0, tier=1, patience=0.1), None)
        ctl.pump(0.0)
        ctl.offer(_req(1, 0.0, tier=0), None)
        ctl.offer(_req(2, 0.0, tier=1, patience=0.1), None)
        ctl.pump(0.0)
        assert ctl.recorder.events[0].n_preempt == 1
        ctl.pump(5.0)                                # way past patience
        evs = ctl.recorder.events
        assert evs[2].outcome == "abandoned"         # never started
        assert evs[0].outcome != "abandoned"         # preempted: kept
        assert ctl.queues[1][0].rid == 0

    def test_invalid_tier_raises(self):
        ctl = AdmissionController(FakeServer(1), n_tiers=2)
        with pytest.raises(ValueError, match="tier"):
            ctl.offer(_req(0, 0.0, tier=5), None)
        with pytest.raises(ValueError, match="n_tiers"):
            AdmissionController(FakeServer(1), n_tiers=0)


# ---------------------------------------------------------------------------
# virtual-time loop: determinism, inversion-freedom, timing
# ---------------------------------------------------------------------------

class TestServingLoop:
    def _overload_trace(self):
        w = Workload(qps=6.0, horizon=5.0, seed=2, tier_probs=(0.3, 0.7),
                     patience=1.0, deadline=2.0)
        return generate_trace(w)

    def _run(self, collect=None):
        loop = ServingLoop(
            FakeServer(2, waves=3), self._overload_trace(),
            lambda req: None, n_tiers=2, clock=VirtualClock(),
            cost=CostModel(admit_s=0.05, wave_base_s=0.03,
                           per_work_s=0.01),
            check_inversion=True, on_event=collect)
        loop.run()
        return loop

    def test_deterministic_timeline(self):
        ev1, ev2 = [], []
        s1 = self._run(lambda *a: ev1.append(a)).summary()
        s2 = self._run(lambda *a: ev2.append(a)).summary()
        assert ev1 == ev2 and len(ev1) > 0
        assert s1 == s2

    def test_no_priority_inversion_over_run(self):
        loop = self._run()
        assert loop.inversions == []
        s = loop.summary()
        assert s["done"] > 0
        # overload at 2 slots: tier 0 preempts tier 1 at some point
        assert s["preemptions"] > 0

    def test_all_requests_reach_terminal_state(self):
        loop = self._run()
        for ev in loop.controller.recorder.events.values():
            assert ev.outcome in ("done", "abandoned", "rejected")

    def test_first_token_includes_admit_cost(self):
        trace = [_req(0, 0.0)]
        server = FakeServer(1, waves=2)
        server.emits_on_admit = True
        cost = CostModel(admit_s=0.5, wave_base_s=0.125, per_work_s=0.0)
        loop = ServingLoop(server, trace, lambda r: None, n_tiers=1,
                           clock=VirtualClock(), cost=cost)
        loop.run()
        ev = loop.controller.recorder.events[0]
        assert ev.first_token == pytest.approx(0.5)   # prefill charged
        assert ev.final == pytest.approx(0.5 + 2 * 0.125)

    def test_streaming_first_token_on_first_wave(self):
        trace = [_req(0, 0.0)]
        cost = CostModel(admit_s=0.5, wave_base_s=0.125, per_work_s=0.0)
        loop = ServingLoop(FakeServer(1, waves=2), trace, lambda r: None,
                           n_tiers=1, clock=VirtualClock(), cost=cost)
        loop.run()
        ev = loop.controller.recorder.events[0]
        assert ev.first_token == pytest.approx(0.5 + 0.125)

    def test_idle_gap_jumps_to_next_arrival(self):
        trace = [_req(0, 0.0), _req(1, 100.0)]
        loop = ServingLoop(FakeServer(1, waves=1), trace, lambda r: None,
                           n_tiers=1, clock=VirtualClock())
        loop.run()
        s = loop.summary()
        assert s["done"] == 2
        assert loop.clock.now() >= 100.0
        # queue-wait percentiles never saw the idle gap
        assert s["queue_wait"]["p99"] < 1.0


# ---------------------------------------------------------------------------
# closed-loop capacity search
# ---------------------------------------------------------------------------

class TestCapacity:
    def _workload(self):
        return Workload(qps=1.0, horizon=20.0, seed=4, patience=2.0,
                        deadline=2.0)

    def test_bisection_brackets_and_reproduces(self):
        cost = CostModel(admit_s=0.2, wave_base_s=0.1, per_work_s=0.0)
        srv = FakeServer(2, waves=3)
        q1, s1 = sustained_capacity(srv, self._workload(),
                                    lambda r: None, p99_target_s=1.0,
                                    qps_lo=0.25, qps_hi=16.0, iters=4,
                                    cost=cost)
        q2, s2 = sustained_capacity(srv, self._workload(),
                                    lambda r: None, p99_target_s=1.0,
                                    qps_lo=0.25, qps_hi=16.0, iters=4,
                                    cost=cost)
        assert q1 == q2 and s1 == s2                 # seeded-reproducible
        assert 0.25 <= q1 < 16.0                     # interior of bracket
        # the returned summary is the feasible run at max QPS
        assert feasible(s1, p99_target_s=1.0)
        # an interior answer means the hi bracket endpoint was infeasible
        above = run_level(srv, self._workload().with_qps(16.0),
                          lambda r: None, cost=cost)
        assert not feasible(above, p99_target_s=1.0)

    def test_infeasible_floor_and_feasible_ceiling(self):
        # impossibly slow cell -> 0.0; impossibly fast -> qps_hi
        w = self._workload()
        slow = CostModel(admit_s=5.0, wave_base_s=5.0)
        q, s = sustained_capacity(FakeServer(1, waves=3), w,
                                  lambda r: None, p99_target_s=0.5,
                                  qps_lo=0.25, qps_hi=4.0, iters=2,
                                  cost=slow)
        assert q == 0.0 and not feasible(s, p99_target_s=0.5)
        fast = CostModel(admit_s=1e-4, wave_base_s=1e-4)
        q, s = sustained_capacity(FakeServer(4, waves=1), w,
                                  lambda r: None, p99_target_s=0.5,
                                  qps_lo=0.25, qps_hi=4.0, iters=2,
                                  cost=fast)
        assert q == 4.0 and feasible(s, p99_target_s=0.5)


# ---------------------------------------------------------------------------
# the real servers: typed admits, batched parity, preempt bit-exactness
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def lm_prompts():
    rng = np.random.default_rng(0)
    vocab = _lm_cfg().vocab
    return [rng.integers(0, vocab, size=int(n)) for n in (5, 9, 3, 7, 6)]


class TestLmServer:
    def test_typed_admit_branches(self):
        from repro.launch.serve import Server

        s = Server(_lm_cfg(), slots=1, max_len=8)
        r = s.admit(0, np.arange(10), 4)
        assert not r and r.reason == PROMPT_TOO_LONG
        r = s.admit(0, np.arange(3), 0)
        assert not r and r.reason == NO_BUDGET
        r = s.admit(0, np.arange(3), 4)
        assert r and r.reason == OK and r.slot == 0
        r = s.admit(1, np.arange(3), 4)
        assert not r and r.reason == POOL_FULL
        # typed events landed in the structured stream
        kinds = [k for k, _, _ in s.events]
        assert kinds.count("reject") == 2 and "admit" in kinds

    def test_batched_step_matches_sequential_bit_for_bit(self, lm_prompts):
        from repro.launch.serve import Server

        def serve_all(batched):
            s = Server(_lm_cfg(), slots=3, max_len=32, batched=batched)
            pending = list(enumerate(lm_prompts))
            fin = []
            while pending or s.active.any():
                while pending:
                    r = s.admit(pending[0][0], pending[0][1], 6)
                    if r.reason == POOL_FULL:
                        break
                    pending.pop(0)
                fin += s.step()
            return dict(fin)

        a, b = serve_all(True), serve_all(False)
        assert a == b                                # token-exact

    def test_preempt_resume_bit_exact(self, lm_prompts):
        from repro.launch.serve import Server

        def run(preempt_at):
            s = Server(_lm_cfg(), slots=2, max_len=32)
            s.admit(0, lm_prompts[0], 8)
            s.admit(1, lm_prompts[1], 8)
            fin = []
            for i in range(30):
                if i == preempt_at:
                    snap = s.preempt(0)
                    fin += s.step()                  # rid 1 alone
                    assert s.restore(snap)
                fin += s.step()
                if not s.active.any():
                    break
            return dict(fin)

        base, pre = run(-1), run(2)
        assert base == pre                           # both requests exact

    def test_restore_pool_full_and_reset(self, lm_prompts):
        from repro.launch.serve import Server

        s = Server(_lm_cfg(), slots=1, max_len=32)
        assert s.admit(0, lm_prompts[0], 8)
        snap = s.preempt(0)
        assert s.admit(1, lm_prompts[1], 8)
        assert s.restore(snap).reason == POOL_FULL
        s.reset()
        assert not s.active.any() and s.events == []
        assert s.restore(snap)                       # resumes after reset

    def test_preempt_unknown_rid_raises(self, lm_prompts):
        from repro.launch.serve import Server

        s = Server(_lm_cfg(), slots=1, max_len=32)
        s.admit(0, lm_prompts[0], 4)
        with pytest.raises(KeyError):
            s.preempt(99)

    def test_step_wave_contract(self, lm_prompts):
        from repro.launch.serve import Server

        s = Server(_lm_cfg(), slots=2, max_len=32)
        assert s.emits_on_admit
        s.admit(0, lm_prompts[0], 2)
        s.admit(1, lm_prompts[1], 2)
        done, progressed, work = s.step_wave()
        assert progressed == [0, 1] and work == 2
        assert [rid for rid, _ in done] == [0, 1]    # budget exhausted


@pytest.fixture(scope="module")
def asr_feats():
    cfg = _asr_cfg()
    rng = np.random.default_rng(1)
    return [rng.standard_normal((n, cfg.input_dim)).astype(np.float32)
            for n in (11, 7, 14)]


class TestAsrServer:
    def test_typed_admit_branches(self, asr_feats):
        from repro.launch.serve import AsrServer

        cfg = _asr_cfg()
        s = AsrServer(cfg, slots=1, max_frames=16, chunk=4, beam=3)
        r = s.admit(0, np.zeros((20, cfg.input_dim), np.float32))
        assert not r and r.reason == PROMPT_TOO_LONG
        r = s.admit(0, np.zeros((0, cfg.input_dim), np.float32))
        assert not r and r.reason == NO_BUDGET
        assert s.admit(0, asr_feats[0])
        assert s.admit(1, asr_feats[1]).reason == POOL_FULL

    def test_preempt_resume_bit_exact(self, asr_feats):
        from repro.launch.serve import AsrServer

        def run(preempt_at):
            s = AsrServer(_asr_cfg(), slots=2, max_frames=16, chunk=4,
                          beam=3)
            s.admit(0, asr_feats[0])
            s.admit(1, asr_feats[1])
            fin = []
            for i in range(20):
                if i == preempt_at:
                    snap = s.preempt(0)
                    d, _ = s.step()
                    fin += d
                    assert s.restore(snap)
                d, _ = s.step()
                fin += d
                if not s.active.any():
                    break
            return dict(fin)

        base, pre = run(-1), run(1)
        assert base == pre                           # hypotheses exact

    def test_streaming_contract(self, asr_feats):
        from repro.launch.serve import AsrServer

        s = AsrServer(_asr_cfg(), slots=2, max_frames=16, chunk=4, beam=3)
        assert not s.emits_on_admit                  # first token on wave
        s.admit(0, asr_feats[0])                     # 11 frames
        s.admit(1, asr_feats[1])                     # 7 frames
        done, progressed, work = s.step_wave()
        assert progressed == [0, 1]
        assert work == 8                             # 4 + 4 valid frames
        _, _, work = s.step_wave()
        assert work == 7                             # 4 + 3 (tail clamp)


class TestBeamRowOps:
    def test_gather_scatter_round_trip(self):
        import jax.numpy as jnp

        from repro.decode import gather_rows, init_state, scatter_rows

        state = init_state(4, 3, 10)
        # make rows distinguishable
        state = state._replace(p_b=state.p_b + jnp.arange(4)[:, None],
                               t=jnp.arange(4, dtype=jnp.int32))
        rows = gather_rows(state, [2])
        assert rows.p_b.shape[0] == 1 and int(rows.t[0]) == 2
        out = scatter_rows(init_state(4, 3, 10), rows, [2])
        np.testing.assert_array_equal(np.asarray(out.p_b[2]),
                                      np.asarray(state.p_b[2]))
        assert int(out.t[2]) == 2
        # other rows untouched
        np.testing.assert_array_equal(
            np.asarray(out.p_b[0]),
            np.asarray(init_state(4, 3, 10).p_b[0]))


# ---------------------------------------------------------------------------
# end-to-end: real server through the virtual loop, seeded twice
# ---------------------------------------------------------------------------

class TestEndToEnd:
    def test_lm_loop_seeded_reproducible(self):
        from repro.launch.serve import Server

        cfg = _lm_cfg()
        w = Workload(qps=3.0, horizon=4.0, seed=7, len_median=6.0,
                     len_min=2, len_max=15, max_new=4, patience=2.0,
                     deadline=2.0)
        payload = lambda req: make_payload(req, mode="lm",
                                           vocab=cfg.vocab, seed=w.seed)

        def run():
            events = []
            loop = ServingLoop(
                Server(cfg, slots=2, max_len=16), generate_trace(w),
                payload, n_tiers=2, clock=VirtualClock(),
                cost=CostModel(), check_inversion=True,
                on_event=lambda *a: events.append(a))
            loop.run()
            return events, loop.summary(), loop.inversions

        (e1, s1, inv1), (e2, s2, inv2) = run(), run()
        assert e1 == e2 and s1 == s2                 # identical timeline
        assert inv1 == [] and inv2 == []
        assert s1["done"] > 0
        assert s1["offered"] == s1["done"] + s1["abandoned"] \
            + s1["rejected"]
