"""Unified observability layer tests (docs/observability.md).

Unit coverage of the metrics registry (deterministic snapshot order,
kind safety, the no-op default), the flight recorder (span nesting,
ring bounding, the JSONL/Chrome exporters and the schema validator),
the compile-vs-steady profiler and the CostModel fit — plus the two
end-to-end contracts: the slo.Recorder-as-view property
(``fold(trace) == live table``) and run-twice JSONL **bit-equality**
of seeded train/serve smokes under ``--trace-deterministic``.
"""
import json
import math
import os
import subprocess
import sys

import pytest

from repro import obs
from repro.obs import (MetricsRegistry, NullRegistry, NOOP, FlightRecorder,
                       NullRecorder, ProfiledFn, chrome_trace,
                       fit_cost_model, nearest_rank, read_jsonl,
                       validate_events, write_jsonl)
from repro.obs.trace import event_to_line

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}


@pytest.fixture(autouse=True)
def _obs_reset():
    """Every test starts and ends on the no-op defaults."""
    obs.reset()
    yield
    obs.reset()


def run(args, timeout=420):
    return subprocess.run([sys.executable, "-m"] + args, cwd=REPO, env=ENV,
                          capture_output=True, text=True, timeout=timeout)


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_instruments_basic():
    reg = MetricsRegistry()
    c = reg.counter("bytes", strategy="hring")
    c.inc(10)
    c.inc(5)
    assert c.value == 15
    g = reg.gauge("occ")
    g.set(3)
    g.set(7)
    assert g.value == 7
    h = reg.histogram("lat")
    for v in (1.0, 2.0, 3.0, 4.0):
        h.observe(v)
    f = h.fields()
    assert f["count"] == 4 and f["total"] == 10.0 and f["mean"] == 2.5
    assert f["min"] == 1.0 and f["max"] == 4.0
    assert f["p50"] == 2.0 and f["p99"] == 4.0


def test_nearest_rank_convention():
    # matches repro.serving.slo.percentile: ceil(q/100 * n) - 1
    vals = list(range(1, 11))
    assert nearest_rank(vals, 50) == 5
    assert nearest_rank(vals, 95) == 10
    assert math.isnan(nearest_rank([], 50))


def test_same_name_same_tags_same_instrument():
    reg = MetricsRegistry()
    assert reg.counter("x", a=1) is reg.counter("x", a=1)
    assert reg.counter("x", a=1) is not reg.counter("x", a=2)
    assert len(reg) == 2


def test_kind_mismatch_raises():
    reg = MetricsRegistry()
    reg.counter("x")
    with pytest.raises(TypeError):
        reg.gauge("x")
    with pytest.raises(TypeError):
        reg.histogram("x")


def test_snapshot_order_independent_of_registration():
    a, b = MetricsRegistry(), MetricsRegistry()
    a.counter("z").inc(1)
    a.gauge("a", k="2").set(5)
    a.gauge("a", k="1").set(4)
    b.gauge("a", k="1").set(4)
    b.counter("z").inc(1)
    b.gauge("a", k="2").set(5)
    sa, sb = a.snapshot(), b.snapshot()
    assert sa == sb
    assert [r["name"] for r in sa] == ["a", "a", "z"]
    assert [r["tags"] for r in sa[:2]] == [{"k": "1"}, {"k": "2"}]


def test_null_registry_noop():
    reg = NullRegistry()
    assert reg.counter("x") is NOOP
    assert reg.gauge("x") is NOOP
    assert reg.histogram("x", wall=True) is NOOP
    NOOP.inc()
    NOOP.set(3)
    NOOP.observe(1)
    assert reg.snapshot() == []


# ---------------------------------------------------------------------------
# flight recorder
# ---------------------------------------------------------------------------

def test_span_nesting_parent_ids():
    rec = FlightRecorder()
    with rec.span("outer", step=1):
        with rec.span("inner"):
            pass
        rec.event("mark", x=2)
    evs = rec.events
    # children land before parents (recorded at exit)
    names = [e["name"] for e in evs]
    assert names == ["inner", "mark", "outer"]
    outer = evs[2]
    inner = evs[0]
    assert outer["parent"] == 0
    assert inner["parent"] == outer["id"]
    assert outer["attrs"] == {"step": 1}
    # seq assigned at ENTRY: outer opened first -> lowest seq
    assert outer["seq"] < inner["seq"] < evs[1]["seq"]
    assert outer["dur"] >= inner["dur"] >= 0


def test_ring_bounding_and_n_dropped():
    rec = FlightRecorder(maxlen=10)
    for k in range(25):
        rec.event("e", k=k)
    assert len(rec) == 10
    assert rec.n_dropped == 15
    assert [e["attrs"]["k"] for e in rec.events] == list(range(15, 25))
    rec.clear()
    assert len(rec) == 0 and rec.n_dropped == 0


def test_metric_record_renames_instrument_kind():
    rec = FlightRecorder()
    rec.metric({"name": "lat", "kind": "histogram", "tags": {},
                "wall": False, "count": 3})
    (ev,) = rec.events
    assert ev["kind"] == "metric"          # the event-schema kind
    assert ev["instrument"] == "histogram"  # the registry kind
    assert validate_events([ev]) == []


def test_null_recorder_noop():
    rec = NullRecorder()
    rec.event("x")
    rec.add_span("y", 0.0, 1.0)
    with rec.span("z"):
        pass
    assert len(rec) == 0


# ---------------------------------------------------------------------------
# JSONL export / validation / chrome
# ---------------------------------------------------------------------------

def _sample_events():
    rec = FlightRecorder()
    with rec.span("step", k=1):
        rec.event("mark", v=2.5)
    rec.add_span("jit", 0.5, 0.25, wall=True, phase="compile")
    rec.metric({"name": "loss", "kind": "histogram", "tags": {},
                "wall": False, "count": 1, "mean": 3.0})
    rec.metric({"name": "svc", "kind": "histogram", "tags": {},
                "wall": True, "count": 1, "mean": 0.1})
    return rec.events


def test_jsonl_roundtrip(tmp_path):
    evs = _sample_events()
    path = tmp_path / "t.jsonl"
    n = write_jsonl(evs, str(path))
    assert n == len(evs)
    assert read_jsonl(str(path)) == json.loads(
        json.dumps(evs))  # tuple-free comparison
    assert validate_events(read_jsonl(str(path))) == []


def test_deterministic_export_strips_wall(tmp_path):
    evs = _sample_events()
    path = tmp_path / "d.jsonl"
    write_jsonl(evs, str(path), deterministic=True)
    out = read_jsonl(str(path))
    # wall-marked span AND wall metric dropped; ts/dur stripped
    assert len(out) == len(evs) - 2
    for ev in out:
        assert "ts" not in ev and "dur" not in ev and not ev.get("wall")
    assert validate_events(out) == []
    # byte-stable: same events -> same lines
    assert [event_to_line(e, True) for e in evs] \
        == [event_to_line(e, True) for e in evs]


def test_validate_events_catches_violations():
    bad = [
        {"kind": "event", "name": "x"},                       # no seq
        {"seq": 1, "kind": "bogus", "name": "x"},             # bad kind
        {"seq": 1, "kind": "event", "name": ""},              # dup seq, no name
        {"seq": 2, "kind": "span", "name": "s", "dur": -1.0,  # negative dur
         "id": "nope"},                                       # non-int id
        {"seq": 3, "kind": "event", "name": "y",
         "attrs": {"a": [1, 2]}},                             # non-scalar attr
    ]
    problems = validate_events(bad)
    for frag in ("seq", "kind", "duplicate", "name", "negative",
                 "id not int", "not a JSON scalar"):
        assert any(frag in p for p in problems), (frag, problems)
    assert validate_events(_sample_events()) == []


def test_chrome_trace_schema():
    evs = _sample_events()
    doc = chrome_trace(evs)
    assert doc["displayTimeUnit"] == "ms"
    phases = [e["ph"] for e in doc["traceEvents"]]
    assert phases.count("X") == 2 and "i" in phases
    assert phases.count("C") == 2
    spans = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    jit = next(e for e in spans if e["name"] == "jit")
    assert jit["ts"] == pytest.approx(0.5e6)      # seconds -> us
    assert jit["dur"] == pytest.approx(0.25e6)
    ts = [e["ts"] for e in doc["traceEvents"]]
    assert ts == sorted(ts)


# ---------------------------------------------------------------------------
# ProfiledFn + fit_cost_model
# ---------------------------------------------------------------------------

def test_profiled_fn_compile_steady_split():
    import numpy as np

    reg, rec = MetricsRegistry(), FlightRecorder()
    calls = []
    fn = ProfiledFn(lambda x: calls.append(1) or x.sum(), "f",
                    metrics=reg, recorder=rec)
    a8, a16 = np.zeros(8), np.zeros(16)
    fn(a8)                   # compile (new shape)
    fn(a8)                   # steady
    fn(a8)                   # steady
    fn(a16)                  # compile again: retrace on a new shape
    assert fn.n_calls == 4 and fn.n_compiles == 2
    assert fn.compile_s >= 0 and fn.steady_s >= 0
    assert fn.steady_mean_s == pytest.approx(fn.steady_s / 2)
    snap = reg.snapshot()
    by_phase = {r["tags"]["phase"]: r for r in snap
                if r["name"] == "profile/call_s"}
    assert by_phase["compile"]["count"] == 2
    assert by_phase["steady"]["count"] == 2
    assert all(r["wall"] for r in by_phase.values())
    spans = [e for e in rec.events if e["kind"] == "span"]
    assert len(spans) == 4 and all(e.get("wall") for e in spans)
    assert obs.profiled(fn, "f") is fn   # idempotent wrapping


def test_profiled_fn_custom_key():
    fn = ProfiledFn(lambda d: 0, "f", key=lambda a, kw: len(a[0]))
    fn({"a": 1})
    fn({"b": 2})             # same key (len 1) -> steady
    assert fn.n_compiles == 1 and fn.n_calls == 2


def test_fit_cost_model_recovers_line():
    base, slope = 0.010, 0.002
    wave = [(w, base + slope * w) for w in (1, 2, 3, 4, 5)] * 3
    fit = fit_cost_model(wave, admit_obs=[0.02, 0.04])
    assert fit["wave_base_s"] == pytest.approx(base, abs=1e-12)
    assert fit["per_work_s"] == pytest.approx(slope, abs=1e-12)
    assert fit["admit_s"] == pytest.approx(0.03)
    assert fit["n_waves"] == 15 and fit["resid_s"] < 1e-12


def test_fit_cost_model_degenerate():
    # one distinct work level: slope unidentifiable -> pinned to 0
    fit = fit_cost_model([(3, 0.02), (3, 0.04)])
    assert fit["per_work_s"] == 0.0
    assert fit["wave_base_s"] == pytest.approx(0.03)
    empty = fit_cost_model([])
    assert math.isnan(empty["wave_base_s"]) and empty["n_waves"] == 0


# ---------------------------------------------------------------------------
# the module-level sinks
# ---------------------------------------------------------------------------

def test_configure_reset_dispatch(tmp_path):
    assert not obs.enabled()
    obs.event("ignored")                 # no-op, no error
    with obs.span("ignored"):
        pass
    assert obs.dump(str(tmp_path / "x.jsonl")) == 0
    assert not (tmp_path / "x.jsonl").exists()

    obs.configure()
    assert obs.enabled()
    obs.counter("c").inc(2)
    obs.event("e", k=1)
    with obs.span("s"):
        pass
    path, chrome = tmp_path / "t.jsonl", tmp_path / "t_chrome.json"
    n = obs.dump(str(path), chrome=str(chrome))
    evs = read_jsonl(str(path))
    assert n == len(evs) == 3            # event + span + metric snapshot
    assert validate_events(evs) == []
    assert json.load(open(chrome))["traceEvents"]
    obs.reset()
    assert not obs.enabled()
    assert obs.counter("c") is NOOP      # dispatch follows current sink


# ---------------------------------------------------------------------------
# slo.Recorder as a view over the event schema
# ---------------------------------------------------------------------------

def test_recorder_fold_equals_live_table():
    from repro.serving.slo import Recorder, fold_request_events, summarize

    obs.configure()
    live = Recorder()
    live.offered(1, 0, 0.0, deadline=5.0)
    live.offered(2, 1, 0.5)
    live.admitted(1, 0.6)
    live.first_token(1, 0.7)
    live.preempted(1)
    live.admitted(1, 0.9)               # re-admit after preempt: t_admit keeps first
    live.done(1, 1.2, n_tokens=4)
    live.rejected(2, 0.8, reason="pool_full")
    folded = fold_request_events(obs.get_recorder().events)
    assert folded.events == live.events
    assert folded.n_preemptions == live.n_preemptions == 1
    assert summarize(folded) == summarize(live)


def test_recorder_unknown_rid_raises():
    from repro.serving.slo import fold_request_events

    evs = [{"seq": 1, "kind": "event", "name": "request/done",
            "attrs": {"rid": 99, "now": 1.0}}]
    with pytest.raises(KeyError):
        fold_request_events(evs)


def test_slo_csv_shims():
    # moved to repro.obs; slo re-exports stay importable
    from repro.serving.slo import CSV_HEADER, csv_row, print_csv_rows
    assert CSV_HEADER is obs.CSV_HEADER
    assert csv_row is obs.csv_row and print_csv_rows is obs.print_csv_rows
    assert obs.csv_row("a", 1.5, "d") == "a,1.5,d"
    assert obs.csv_row("a", "raw") == "a,raw,"


# ---------------------------------------------------------------------------
# obsreport
# ---------------------------------------------------------------------------

def test_obsreport_span_attribution_and_rows():
    from repro.launch.obsreport import compile_steady, report_rows, \
        span_table

    rec = FlightRecorder(clock=iter(range(100)).__next__)
    with rec.span("outer"):      # entry t=0
        with rec.span("inner"):  # entry t=1, exit t=2 -> dur 1
            pass
    # outer exit t=3 -> dur 3, self 3 - 1 = 2
    rows = {name: (n, tot, slf)
            for name, n, tot, slf in span_table(rec.events)}
    assert rows["inner"] == (1, 1.0, 1.0)
    assert rows["outer"] == (1, 3.0, 2.0)

    rec.add_span("train/step", 0.0, 2.0, wall=True, phase="compile")
    rec.add_span("train/step", 2.0, 0.5, wall=True, phase="steady")
    prof = compile_steady(rec.events)
    assert prof["train/step"]["compile"] == [1, 2.0]
    assert prof["train/step"]["steady"] == [1, 0.5]
    # metric-record fallback when wall spans were stripped
    prof2 = compile_steady([
        {"seq": 1, "kind": "metric", "name": "profile/call_s",
         "tags": {"fn": "f", "phase": "steady"}, "count": 4, "total": 2.0}])
    assert prof2["f"]["steady"] == [4, 2.0]

    names = [r[0] for r in report_rows(rec.events)]
    assert "trace/events" in names and "span/outer" in names
    assert "profile/train/step/compile_s" in names


def test_obsreport_cli_rejects_invalid(tmp_path):
    from repro.launch.obsreport import main

    bad = tmp_path / "bad.jsonl"
    bad.write_text('{"seq": 1, "kind": "bogus", "name": "x"}\n')
    assert main([str(bad)]) == 1
    good = tmp_path / "good.jsonl"
    write_jsonl(_sample_events(), str(good))
    assert main([str(good), "--csv"]) == 0
    chrome = tmp_path / "c.json"
    assert main([str(good), "--chrome", str(chrome)]) == 0
    assert json.load(open(chrome))["traceEvents"]


# ---------------------------------------------------------------------------
# run-twice bit-equality of the seeded CLIs (the determinism gate)
# ---------------------------------------------------------------------------

def test_train_trace_run_twice_bit_equal(tmp_path):
    traces = []
    for k in (1, 2):
        out = tmp_path / f"t{k}.jsonl"
        r = run(["repro.launch.train", "--arch", "swb2000-blstm",
                 "--reduced", "--learners", "2", "--strategy", "ad_psgd",
                 "--steps", "3", "--log-every", "2",
                 "--trace-out", str(out), "--trace-deterministic"])
        assert r.returncode == 0, r.stderr[-2000:]
        assert "timing: compile" in r.stdout and "steady" in r.stdout
        traces.append(out.read_bytes())
        evs = read_jsonl(str(out))
        assert evs and validate_events(evs) == []
        assert any(e["kind"] == "event" and e["name"] == "train/step"
                   for e in evs)
    assert traces[0] == traces[1]


def test_serve_trace_run_twice_bit_equal(tmp_path):
    traces = []
    for k in (1, 2):
        out = tmp_path / f"s{k}.jsonl"
        r = run(["repro.launch.serve", "--arch", "smollm-360m",
                 "--requests", "2", "--slots", "1", "--max-new", "4",
                 "--prompt-len", "8", "--max-len", "32",
                 "--trace-out", str(out), "--trace-deterministic"])
        assert r.returncode == 0, r.stderr[-2000:]
        assert "timing: serve/prefill" in r.stdout
        traces.append(out.read_bytes())
        evs = read_jsonl(str(out))
        assert evs and validate_events(evs) == []
        assert any(e["name"].startswith("serve/") for e in evs)
    assert traces[0] == traces[1]
