"""CTC vs brute-force alignment enumeration."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.ctc import collapse_frame_labels, ctc_loss


def brute_force_nll(logits, label, blank=0):
    """Enumerate all V^T alignments; sum prob of those collapsing to label."""
    T, V = logits.shape
    p = jax.nn.softmax(jnp.asarray(logits, jnp.float32), -1)
    p = np.asarray(p)
    total = 0.0
    for path in itertools.product(range(V), repeat=T):
        # collapse: merge repeats, drop blanks
        merged = [k for k, g in itertools.groupby(path)]
        collapsed = [c for c in merged if c != blank]
        if collapsed == list(label):
            prob = 1.0
            for t, c in enumerate(path):
                prob *= p[t, c]
            total += prob
    return -np.log(max(total, 1e-300))


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("T,V,label", [
    (4, 3, [1, 2]),
    (5, 3, [2]),
    (4, 4, [1, 1]),     # repeated label requires the blank between
    (3, 3, []),         # empty label: all-blank paths
])
def test_ctc_matches_brute_force(seed, T, V, label):
    rng = np.random.default_rng(seed)
    logits = rng.normal(size=(T, V)).astype(np.float32)
    U = max(len(label), 1)
    lab = np.full((1, U), -1, np.int32)
    lab[0, :len(label)] = label
    got = float(ctc_loss(jnp.asarray(logits)[None], jnp.asarray(lab)))
    want = brute_force_nll(logits, label)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_ctc_batched_matches_individual():
    rng = np.random.default_rng(3)
    logits = rng.normal(size=(2, 5, 4)).astype(np.float32)
    labs = np.array([[1, 2, -1], [3, -1, -1]], np.int32)
    both = float(ctc_loss(jnp.asarray(logits), jnp.asarray(labs)))
    each = [float(ctc_loss(jnp.asarray(logits[i:i + 1]),
                           jnp.asarray(labs[i:i + 1]))) for i in range(2)]
    np.testing.assert_allclose(both, np.mean(each), rtol=1e-5)


def test_ctc_differentiable_and_improves():
    rng = np.random.default_rng(4)
    logits = jnp.asarray(rng.normal(size=(2, 6, 5)), jnp.float32)
    labs = jnp.asarray([[1, 2, -1], [2, 3, 4]], jnp.int32)
    loss = lambda lg: ctc_loss(lg, labs)
    l0 = float(loss(logits))
    g = jax.grad(loss)(logits)
    assert np.isfinite(np.asarray(g)).all()
    l1 = float(loss(logits - 0.5 * g))
    assert l1 < l0


def test_collapse_frame_labels():
    fl = np.array([[0, 0, 1, 1, 2, 1]], np.int32)
    seq, lens = collapse_frame_labels(fl, max_len=6)
    assert lens[0] == 4
    np.testing.assert_array_equal(seq[0, :4], [1, 2, 3, 2])


def test_blstm_ctc_training_decreases():
    """End-to-end: the paper's acoustic model trained with CTC instead of
    frame-CE (paper §III E2E criteria)."""
    from repro.configs import get_arch
    from repro.data import make_dataset
    from repro.models import build_model
    from repro.models.lstm import forward
    from repro.sharding import init_spec_tree

    cfg = get_arch("swb2000-blstm").reduced()
    model = build_model(cfg)
    params = init_spec_tree(model.param_specs(), jax.random.PRNGKey(0))
    ds = make_dataset(cfg, seq_len=21, batch=4, seed=0)

    def loss_fn(params, feats, seqs):
        logits = forward(cfg, params, feats)
        return ctc_loss(logits, seqs)

    @jax.jit
    def step(params, feats, seqs):
        l, g = jax.value_and_grad(loss_fn)(params, feats, seqs)
        # CTC losses/grads are sequence-summed -> clip + small lr
        gn = jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                          for x in jax.tree.leaves(g)))
        scale = jnp.minimum(1.0, 5.0 / (gn + 1e-6)) * 0.05
        return l, jax.tree.map(
            lambda w, gg: (w.astype(jnp.float32)
                           - scale * gg.astype(jnp.float32)).astype(w.dtype),
            params, g)

    first = last = None
    for k in range(60):
        b = ds.batch_at(k)
        seqs, _ = collapse_frame_labels(b["labels"], max_len=5)
        l, params = step(params, jnp.asarray(b["features"]),
                         jnp.asarray(seqs))
        first = first if first is not None else float(l)
        last = float(l)
    assert last < first - 5.0
