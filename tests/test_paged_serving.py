"""PagedServer contracts (docs/serving.md §KV paging): paged decode
equals the dense Server bit-for-bit, prefix sharing and COW never
change outputs, preempt-then-restore is exact, typed admission fires
``no_budget`` for real page budgets, and ``reset`` drains the pool."""
import numpy as np
import pytest

from repro.configs import get_arch
from repro.serving.admission import (NO_BUDGET, POOL_FULL, PROMPT_TOO_LONG,
                                     AdmissionController, Recorder)
from repro.serving.workload import Request


def _cfg():
    return get_arch("smollm-360m").reduced()


def _mk_paged(cfg, **kw):
    from repro.launch.serve import PagedServer

    kw.setdefault("pool_pages", 12)
    kw.setdefault("page_size", 4)
    kw.setdefault("max_len", 16)
    return PagedServer(cfg, **kw)


def _prompts(n, plen, vocab, shared=0, seed=0):
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, vocab, size=shared)
    return [np.concatenate([prefix,
                            rng.integers(0, vocab, size=plen - shared)])
            for _ in range(n)]


def _serve(server, prompts, max_new):
    for i, p in enumerate(prompts):
        assert server.admit(i, p, max_new), f"admit {i} failed"
    done = []
    while server.active.any():
        done += server.step()
    return dict(done)


# ---------------------------------------------------------------------------
# parity vs the dense server
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", ["jax", "pallas"])
def test_paged_outputs_equal_dense(impl):
    """Same prompts, same budget: the paged server's outputs are
    bit-identical to the dense slot server's on both kernel impls
    (same grouping rule, same finish rule, value-exact attention)."""
    from repro.launch.serve import Server

    cfg = _cfg()
    n, max_new = (2, 3) if impl == "pallas" else (3, 5)
    prompts = _prompts(n, 6, cfg.vocab)
    dense = _serve(Server(cfg, slots=n, max_len=16, kernel_impl=impl),
                   prompts, max_new)
    paged = _serve(_mk_paged(cfg, kernel_impl=impl), prompts, max_new)
    assert paged == dense


@pytest.mark.parametrize("impl", ["jax", "pallas"])
def test_prefix_shared_equals_unshared(impl):
    """Trie sharing + COW are invisible to outputs: a sharing pool and
    a share=False pool produce bit-identical tokens for prompts with a
    common prefix that splits a page (forcing COW on the partial)."""
    cfg = _cfg()
    n, max_new = (2, 3) if impl == "pallas" else (3, 4)
    prompts = _prompts(n, 6, cfg.vocab, shared=6, seed=1)  # identical
    shared_srv = _mk_paged(cfg, kernel_impl=impl)
    got = _serve(shared_srv, prompts, max_new)
    assert shared_srv.peak_sharing > 0, "no sharing detected"
    assert any(k == "cow" for k, _, _ in shared_srv.events), \
        "identical prompts splitting a page must COW on first write"
    unshared = _serve(_mk_paged(cfg, kernel_impl=impl, share=False),
                      prompts, max_new)
    assert got == unshared
    # and identical prompts decode identical continuations
    outs = list(got.values())
    assert all(o == outs[0] for o in outs)


def test_shuffled_pool_seed_equals_default():
    """Physical page placement is invisible: a seed-permuted free list
    (same params) yields bit-identical outputs."""
    from repro.serving.kvpool import PagePool

    cfg = _cfg()
    prompts = _prompts(3, 5, cfg.vocab, seed=2)
    a = _serve(_mk_paged(cfg), prompts, 4)
    shuffled = _mk_paged(cfg)
    shuffled.pool = PagePool(12, 4, seed=11)   # permuted free list only
    b = _serve(shuffled, prompts, 4)
    assert a == b


# ---------------------------------------------------------------------------
# preempt / restore
# ---------------------------------------------------------------------------

def test_preempt_restore_bit_exact():
    """Preempt mid-decode, restore, finish: outputs equal the
    uninterrupted run's — including when the restore re-shares prompt
    pages through the trie."""
    cfg = _cfg()
    prompts = _prompts(2, 6, cfg.vocab, shared=6, seed=3)
    ref = _serve(_mk_paged(cfg), prompts, 5)

    server = _mk_paged(cfg)
    for i, p in enumerate(prompts):
        assert server.admit(i, p, 5)
    done = dict(server.step())           # one wave, then evict rid 1
    snap = server.preempt(1)
    assert 1 not in server.reqs
    done.update(server.step())           # rid 0 advances alone
    assert server.restore(snap)
    while server.active.any():
        done.update(server.step())
    assert done == ref


def test_restore_into_full_pool_is_pool_full():
    cfg = _cfg()
    server = _mk_paged(cfg, pool_pages=4)
    [p0, p1] = _prompts(2, 6, cfg.vocab, seed=4)
    assert server.admit(0, p0, 6)        # 3 pages of 4 (total 12)
    snap = server.preempt(0)
    assert server.admit(1, p1, 6)        # takes 3 of 4 pages
    res = server.restore(snap)
    assert not res and res.reason == POOL_FULL
    # free the blocker; restore now succeeds and finishes cleanly
    server.preempt(1)
    assert server.restore(snap)
    while server.active.any():
        server.step()
    assert server.pool.pages_in_use == 0


# ---------------------------------------------------------------------------
# typed admission on the page budget
# ---------------------------------------------------------------------------

def test_typed_admission_no_budget_and_pool_full():
    cfg = _cfg()
    # max_len 16 needs 4 pages worst-case; a 3-page pool can NEVER fit
    # a full-length request -> terminal no_budget (not pool_full)
    server = _mk_paged(cfg, pool_pages=3)
    long_prompt = _prompts(1, 14, cfg.vocab, seed=5)[0]
    res = server.admit(0, long_prompt, 8)        # total = 16 -> 4 pages
    assert res.reason == NO_BUDGET
    assert server.admit(1, long_prompt, 0).reason == NO_BUDGET
    too_long = _prompts(1, 16, cfg.vocab, seed=5)[0]
    assert server.admit(2, too_long, 1).reason == PROMPT_TOO_LONG
    # a fitting request admits; a second one finds the pool full
    assert server.admit(3, _prompts(1, 9, cfg.vocab, seed=6)[0], 3)
    res = server.admit(4, _prompts(1, 9, cfg.vocab, seed=7)[0], 3)
    assert res.reason == POOL_FULL
    kinds = {(k, kw.get("reason")) for k, _, kw in server.events
             if k == "reject"}
    assert ("reject", NO_BUDGET) in kinds
    assert ("reject", PROMPT_TOO_LONG) in kinds


def test_controller_routes_paged_rejections():
    """Through the AdmissionController: no_budget is terminal (the job
    is dropped and recorded), pool_full keeps the job queued."""
    cfg = _cfg()
    server = _mk_paged(cfg, pool_pages=3)
    rec = Recorder()
    ctl = AdmissionController(server, n_tiers=1, preempt=False,
                              recorder=rec)

    def req(rid, length, max_new=3):
        return Request(rid=rid, arrival=0.0, length=length, tier=0,
                       max_new=max_new, patience=100.0, deadline=1.0)

    ctl.offer(req(0, 14, max_new=8), _prompts(1, 14, cfg.vocab)[0])
    ctl.offer(req(1, 9), _prompts(1, 9, cfg.vocab, seed=8)[0])
    ctl.offer(req(2, 9), _prompts(1, 9, cfg.vocab, seed=9)[0])
    assert ctl.pump(0.0) == 1            # rid 0 rejected, rid 1 admitted
    assert rec.events[0].outcome == "rejected"
    assert rec.events[0].reject_reason == NO_BUDGET
    assert ctl.backlog() == 1            # rid 2 waits on pool_full
    while server.active.any():
        ctl.on_wave(server.step(), [], 0.0)
        ctl.pump(0.0)
    assert ctl.backlog() == 0 and 2 in ctl.running or not ctl.running


def test_reset_drains_pool_and_reuses_server():
    cfg = _cfg()
    server = _mk_paged(cfg)
    prompts = _prompts(2, 6, cfg.vocab, seed=10)
    first = _serve(server, prompts, 4)
    assert server.pool.pages_in_use == 0     # all freed at done
    server.reset()
    assert server.pool.pages_in_use == 0 and not server.reqs
    assert not server.events and server.peak_sharing == 0.0
    assert _serve(server, prompts, 4) == first   # deterministic replay
