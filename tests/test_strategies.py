"""Behavioural tests of the distributed strategies (paper §IV/V)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import mixing
from repro.core import strategies as ST
from repro.optim.optimizers import sgd
from repro.optim.schedules import constant

W_TRUE = jax.random.normal(jax.random.PRNGKey(7), (8,))


def loss_fn(params, batch):
    pred = batch["x"] @ params["w"]
    return jnp.mean((pred - batch["y"]) ** 2)


def data(seed, n=64):
    x = jax.random.normal(jax.random.PRNGKey(seed), (n, 8))
    return {"x": x, "y": x @ W_TRUE}


def run(name, steps=300, lr=0.05, L=4, micro=1):
    s = ST.get_strategy(name)
    L = L if s.replicated else 1
    params = {"w": jnp.zeros((8,))}
    if s.replicated:
        params = ST.stack_for_learners(params, L)
    state = ST.init_state(s, params, sgd())
    step = jax.jit(ST.make_train_step(s, loss_fn, sgd(), constant(lr),
                                      n_learners=L, microbatches=micro))
    for k in range(steps):
        state, m = step(state, data(k))
    final = (ST.average_learners(state["params"]) if s.replicated
             else state["params"])
    return final, m


@pytest.mark.parametrize("name", ["sc_psgd", "sd_psgd", "ad_psgd",
                                  "downpour", "sc_psgd_replicated", "hring"])
def test_strategy_converges(name):
    final, m = run(name)
    assert float(jnp.linalg.norm(final["w"] - W_TRUE)) < 0.05
    assert np.isfinite(float(m["loss"]))


def test_bmuf_converges():
    final, _ = run("bmuf", steps=800, lr=0.03)
    assert float(jnp.linalg.norm(final["w"] - W_TRUE)) < 0.3


def test_sd_psgd_step_matches_eq14():
    """One SD-PSGD step == W·T_1 − α·g(W) exactly (paper Eq. 14)."""
    s = ST.get_strategy("sd_psgd")
    L = 4
    params = {"w": jax.random.normal(jax.random.PRNGKey(1), (L, 8))}
    state = ST.init_state(s, params, sgd())
    batch = data(9)
    step = jax.jit(ST.make_train_step(s, loss_fn, sgd(), constant(0.1),
                                      n_learners=L))
    new_state, _ = step(state, batch)
    lb = ST.split_learner_batch(batch, L)
    g = jax.vmap(jax.grad(loss_fn))(params, lb)
    T = jnp.asarray(mixing.ring_matrix(L), jnp.float32)
    ref = jnp.einsum("ml,lw->mw", T, params["w"]) - 0.1 * g["w"]
    np.testing.assert_allclose(np.asarray(new_state["params"]["w"]),
                               np.asarray(ref), atol=1e-5)


def test_ad_psgd_gradient_is_stale():
    """AD-PSGD evaluates gradients at W_{k-1} (Φ_k per §IV-C)."""
    s = ST.get_strategy("ad_psgd")
    L = 2
    p0 = {"w": jax.random.normal(jax.random.PRNGKey(2), (L, 8))}
    state = ST.init_state(s, p0, sgd())
    step = jax.jit(ST.make_train_step(s, loss_fn, sgd(), constant(0.1),
                                      n_learners=L))
    b1, b2 = data(1), data(2)
    state, _ = step(state, b1)
    state2, _ = step(state, b2)
    # step 2 must have used gradients at the ORIGINAL p0's successor, i.e.
    # prev_params of state — verify manually
    lb = ST.split_learner_batch(b2, L)
    g = jax.vmap(jax.grad(loss_fn))(state["prev_params"], lb)
    mixed = mixing.mix_ring(state["params"])
    ref = jax.tree.map(lambda m, gg: m - 0.1 * gg, mixed, g)
    np.testing.assert_allclose(np.asarray(state2["params"]["w"]),
                               np.asarray(ref["w"]), atol=1e-5)


def test_microbatch_accumulation_matches_full_batch():
    """Grad accumulation over microbatches == one big batch (linear model)."""
    params = {"w": jnp.zeros((8,))}
    batch = data(3, n=64)
    _, g_full = ST._accumulated_grad(loss_fn, params, batch, 1)
    _, g_acc = ST._accumulated_grad(loss_fn, params, batch, 4)
    np.testing.assert_allclose(np.asarray(g_acc["w"]),
                               np.asarray(g_full["w"]), atol=1e-5)


def test_pre_split_batch_equivalent():
    s = ST.get_strategy("sd_psgd")
    L = 4
    params = {"w": jax.random.normal(jax.random.PRNGKey(4), (L, 8))}
    state = ST.init_state(s, params, sgd())
    batch = data(11)
    step_a = jax.jit(ST.make_train_step(s, loss_fn, sgd(), constant(0.1),
                                        n_learners=L))
    step_b = jax.jit(ST.make_train_step(s, loss_fn, sgd(), constant(0.1),
                                        n_learners=L, pre_split=True))
    out_a, _ = step_a(state, batch)
    out_b, _ = step_b(state, ST.split_learner_batch(batch, L))
    np.testing.assert_allclose(np.asarray(out_a["params"]["w"]),
                               np.asarray(out_b["params"]["w"]), atol=1e-6)


def test_consensus_decreases_with_mixing_strategies():
    """Learner replicas stay near consensus under SD-PSGD training."""
    s = ST.get_strategy("sd_psgd")
    L = 8
    params = ST.stack_for_learners({"w": jnp.zeros((8,))}, L)
    state = ST.init_state(s, params, sgd())
    step = jax.jit(ST.make_train_step(s, loss_fn, sgd(), constant(0.05),
                                      n_learners=L, with_consensus=True))
    for k in range(100):
        state, m = step(state, data(k))
    assert float(m["consensus"]) < 0.05


def test_split_learner_batch_indivisible_raises_clear_error():
    """B % L != 0 must fail loudly, naming B, L and the offending key —
    not silently misbehave (regression: was a bare assert tuple)."""
    batch = {"x": jnp.zeros((10, 3)), "y": jnp.zeros((10,))}
    with pytest.raises(ValueError) as ei:
        ST.split_learner_batch(batch, 4)
    msg = str(ei.value)
    assert "B=10" in msg and "n_learners=4" in msg and "'x'" in msg
    # divisible batches still split fine
    out = ST.split_learner_batch({"x": jnp.zeros((12, 3))}, 4)
    assert out["x"].shape == (4, 3, 3)
    # ragged leaves: the first offending KEY is named
    with pytest.raises(ValueError, match="'y'"):
        ST.split_learner_batch({"x": jnp.zeros((12, 3)),
                                "y": jnp.zeros((10,))}, 4)


def test_average_learners_and_stack_roundtrip():
    p = {"w": jnp.arange(8.0)}
    stacked = ST.stack_for_learners(p, 4)
    assert stacked["w"].shape == (4, 8)
    back = ST.average_learners(stacked)
    np.testing.assert_allclose(np.asarray(back["w"]), np.arange(8.0))
