"""Beyond-paper mixers: int8-payload ring mixing + exponential-graph
gossip (anchored in the paper's §IV-D communication-reduction survey)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need the dev extra
from hypothesis import given, settings, strategies as st

from repro.core import strategies as ST
from repro.core.compression import (dequantize_int8, make_exp_mixer,
                                    mix_ring_q8, quantize_int8)
from repro.core.strategies import consensus_distance
from repro.optim.optimizers import sgd
from repro.optim.schedules import constant


@given(st.integers(min_value=0, max_value=1000))
@settings(max_examples=30, deadline=None)
def test_quantize_roundtrip_bounded_error(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(32,)) * rng.uniform(0.01, 100),
                    jnp.float32)
    q, s = quantize_int8(x)
    err = jnp.max(jnp.abs(dequantize_int8(q, s) - x))
    assert float(err) <= float(s) * 0.5 + 1e-7


def test_quantize_zero_tensor():
    q, s = quantize_int8(jnp.zeros((4,)))
    np.testing.assert_array_equal(np.asarray(dequantize_int8(q, s)),
                                  np.zeros(4))


def test_q8_ring_close_to_exact_ring():
    from repro.core.mixing import mix_ring

    rng = np.random.default_rng(0)
    w = {"a": jnp.asarray(rng.normal(size=(8, 64)), jnp.float32)}
    exact = mix_ring(w)["a"]
    q8 = mix_ring_q8(w)["a"]
    scale = float(jnp.max(jnp.abs(w["a"])))
    assert float(jnp.max(jnp.abs(exact - q8))) < scale / 100


def test_exp_mixer_exact_consensus_after_log2_rounds():
    """Hypercube gossip: L=2^m learners reach exact consensus in m rounds."""
    L, m = 8, 3
    rng = np.random.default_rng(1)
    w = {"a": jnp.asarray(rng.normal(size=(L, 16)), jnp.float32)}
    target = np.mean(np.asarray(w["a"]), axis=0)
    mix = make_exp_mixer(L)
    for k in range(m):
        w = mix(w, jnp.int32(k))
    for row in np.asarray(w["a"]):
        np.testing.assert_allclose(row, target, atol=1e-5)
    assert float(consensus_distance(w)) < 1e-6


def test_exp_mixer_doubly_stochastic_rounds():
    """Every per-round T_k preserves the replica mean."""
    L = 4
    rng = np.random.default_rng(2)
    w = {"a": jnp.asarray(rng.normal(size=(L, 5)), jnp.float32)}
    mu = np.mean(np.asarray(w["a"]), axis=0)
    mix = make_exp_mixer(L)
    for k in range(5):
        w = mix(w, jnp.int32(k))
        np.testing.assert_allclose(np.mean(np.asarray(w["a"]), axis=0), mu,
                                   atol=1e-5)


W_TRUE = jax.random.normal(jax.random.PRNGKey(7), (8,))


def _loss(params, batch):
    return jnp.mean((batch["x"] @ params["w"] - batch["y"]) ** 2)


def _data(seed, n=64):
    x = jax.random.normal(jax.random.PRNGKey(seed), (n, 8))
    return {"x": x, "y": x @ W_TRUE}


@pytest.mark.parametrize("name", ["ad_psgd_q8", "ad_psgd_exp"])
def test_compressed_strategies_converge(name):
    s = ST.get_strategy(name)
    L = 4
    params = ST.stack_for_learners({"w": jnp.zeros((8,))}, L)
    state = ST.init_state(s, params, sgd())
    step = jax.jit(ST.make_train_step(s, _loss, sgd(), constant(0.05),
                                      n_learners=L))
    for k in range(400):
        state, m = step(state, _data(k))
    final = ST.average_learners(state["params"])
    assert float(jnp.linalg.norm(final["w"] - W_TRUE)) < 0.05


def test_exp_consensus_faster_than_ring():
    """Pure gossip (no gradients): exponential graph contracts consensus
    faster than the paper's T_1 ring at equal round count."""
    from repro.core.mixing import mix_ring

    L = 16
    rng = np.random.default_rng(3)
    w0 = {"a": jnp.asarray(rng.normal(size=(L, 32)), jnp.float32)}
    w_ring, w_exp = w0, w0
    mix = make_exp_mixer(L)
    for k in range(4):
        w_ring = mix_ring(w_ring)
        w_exp = mix(w_exp, jnp.int32(k))
    assert float(consensus_distance(w_exp)) < \
        float(consensus_distance(w_ring))
