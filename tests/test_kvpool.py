"""Host-side page-pool semantics (repro.serving.kvpool): refcounted
allocation, prompt-prefix trie sharing, COW reservations, trie trimming
on in-place writes, drain, and a randomized property test that hammers
``PagePool.check()`` over arbitrary alloc/share/write/free/preempt
interleavings (a hypothesis variant runs where hypothesis is
installed; the seeded fuzzer below covers the container without it)."""
import numpy as np
import pytest

from repro.serving.kvpool import PageAlloc, PagePool, cdiv, prefix_digests

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _prompt(rng, n, vocab=64):
    return [int(t) for t in rng.integers(0, vocab, size=n)]


# ---------------------------------------------------------------------------
# digests
# ---------------------------------------------------------------------------

def test_prefix_digests_chain():
    """h_n depends on the whole prefix and chaining from h_lo matches
    the from-scratch digest of the same prefix."""
    toks = [3, 1, 4, 1, 5, 9]
    full = prefix_digests(toks)
    assert len(full) == len(toks)
    assert len(set(full)) == len(toks)
    tail = prefix_digests(toks, lo=2, prev=full[1])
    assert tail == full[2:]
    # a different token anywhere changes every later digest
    other = prefix_digests([3, 1, 4, 1, 5, 8])
    assert other[:5] == full[:5] and other[5] != full[5]


# ---------------------------------------------------------------------------
# allocation / sharing / COW
# ---------------------------------------------------------------------------

def test_alloc_basic_and_free():
    pool = PagePool(8, 4)
    a = pool.alloc_request(0, _prompt(np.random.default_rng(0), 6), 10)
    assert isinstance(a, PageAlloc)
    assert a.n_pages == cdiv(10, 4) == 3 and all(a.owned)
    assert pool.pages_in_use == 3 and pool.free_pages == 5
    assert pool.table_of(0) == a.table
    pool.check()
    pool.free_request(0)
    assert pool.pages_in_use == 0 and pool.free_pages == 8
    pool.check()


def test_identical_prompts_share_full_and_partial_pages():
    pool = PagePool(16, 4)
    rng = np.random.default_rng(1)
    prompt = _prompt(rng, 6)          # 1 full page + 1 partial (pos 4..5)
    a0 = pool.alloc_request(0, prompt, 8)
    assert a0.n_shared == 0
    a1 = pool.alloc_request(1, prompt, 8)
    # both prompt pages shared (incl. the partial tail page)
    assert a1.n_shared == 2
    assert a1.table[:2] == a0.table[:2]
    assert a1.owned == [False, False]
    assert pool.sharing_ratio > 0 and pool.n_shared_hits == 2
    # the shared partial page reserved a COW page: admission accounting
    assert pool.reserved_pages == 1
    pool.check()


def test_shorter_prompt_shares_longer_prefix_tail():
    """Digests are registered for every covered prefix length, so a
    4-token prompt shares the page of a 6-token one."""
    pool = PagePool(16, 4)
    long = [7, 7, 7, 7, 5, 5]
    a0 = pool.alloc_request(0, long, 8)
    a1 = pool.alloc_request(1, long[:4], 6)
    assert a1.n_shared == 1 and a1.table[0] == a0.table[0]
    pool.check()


def test_divergent_prompts_do_not_share():
    pool = PagePool(16, 4)
    pool.alloc_request(0, [1, 2, 3, 4, 5], 8)
    a1 = pool.alloc_request(1, [1, 2, 3, 9, 5], 8)  # diverges inside page 0
    assert a1.n_shared == 0
    pool.check()


def test_cow_on_shared_partial_page_uses_reservation():
    pool = PagePool(8, 4)
    prompt = [2, 2, 2, 2, 3, 3]       # page 1 partial at pos 4..5
    pool.alloc_request(0, prompt, 8)
    pool.alloc_request(1, prompt, 8)
    assert pool.reserved_pages == 1
    t0_before = pool.table_of(1)
    moved = pool.ensure_writable(1, 6)      # first write past the prompt
    assert moved is not None
    old, new = moved
    assert old == t0_before[1] and pool.table_of(1)[1] == new
    assert pool.owned_of(1)[1] is True
    assert pool.reserved_pages == 0 and pool.n_cow == 1
    # sole remaining holder of the old page: no further COW
    assert pool.ensure_writable(0, 6) is None
    pool.check()


def test_owner_write_first_consumes_sharers_reservation():
    """The page's original owner never reserves; when it writes FIRST
    into the shared partial page, the COW consumes the sharer's
    reservation (any reservation tied to that physical page covers one
    of its refcount-1 pending copies) — proven here with zero
    unreserved free pages, where the old guard would raise."""
    pool = PagePool(3, 4)
    prompt = [1, 1, 1, 1, 2, 2]
    pool.alloc_request(0, prompt, 8)          # owner: pages 0, 1
    pool.alloc_request(1, prompt, 8)          # shares both, reserves 1
    assert pool.free_pages == 0 and pool.reserved_pages == 1
    moved = pool.ensure_writable(0, 6)        # OWNER writes first
    assert moved is not None and pool.n_cow == 1
    assert pool.reserved_pages == 0
    # the sharer, now sole holder, writes in place
    assert pool.ensure_writable(1, 6) is None
    pool.check()
    pool.free_request(0)
    pool.free_request(1)
    assert pool.pages_in_use == 0
    pool.check()


def test_sole_owner_write_trims_trie():
    """After the owner writes decode output into its partial prompt
    page, a later identical prompt may share only up to the write."""
    pool = PagePool(16, 4)
    prompt = [9, 9, 9, 9, 1, 1]
    pool.alloc_request(0, prompt, 12)
    assert pool.ensure_writable(0, 6) is None   # in-place, trims > 6... no:
    # keep_upto=6 keeps n<=6; the 5..6 prefixes survive, nothing longer
    a1 = pool.alloc_request(1, prompt, 8)
    assert a1.n_shared == 2                     # both pages still shareable
    pool.free_request(1)
    assert pool.ensure_writable(0, 4) is None   # overwrite pos 4
    a2 = pool.alloc_request(2, prompt, 8)
    assert a2.n_shared == 1                     # page-1 prefixes trimmed
    pool.check()


def test_pool_full_and_all_or_nothing():
    pool = PagePool(4, 4)
    assert pool.alloc_request(0, [1] * 4, 12) is not None    # 3 pages
    # 2 pages needed, 1 free -> None, and NOTHING was allocated
    assert pool.alloc_request(1, [2] * 5, 8) is None
    assert pool.pages_in_use == 3 and 1 not in pool._reqs
    # reservation counts against admission: identical partial-page share
    pool.free_request(0)
    prompt = [3, 3, 3, 3, 3, 3]
    pool.alloc_request(2, prompt, 8)            # 2 pages
    pool.alloc_request(3, prompt, 8)            # shares 2, reserves 1
    # free: 4 - 2 owned = 2 minus 1 reserved -> 1 page truly free
    assert pool.free_pages == 1
    assert pool.alloc_request(4, [4] * 3, 8) is None          # needs 2
    pool.check()


def test_restore_path_never_shares_decode_pages():
    """written_upto > plen (restore of a mid-decode request): the
    partial page holds decode output, so only fully-prompt pages may
    share."""
    pool = PagePool(16, 4)
    prompt = [5] * 6
    pool.alloc_request(0, prompt, 12)
    # restore a request already decoded to pos 7: page 1 holds output
    a = pool.alloc_request(1, prompt, 12, written_upto=7)
    assert a.n_shared == 1 and a.owned[1:] == [True, True]
    pool.check()


def test_errors():
    pool = PagePool(4, 4)
    pool.alloc_request(0, [1], 4)
    with pytest.raises(KeyError):
        pool.alloc_request(0, [1], 4)
    with pytest.raises(ValueError):
        pool.alloc_request(1, [], 4)
    with pytest.raises(ValueError):
        pool.alloc_request(1, [1, 2], 1)
    with pytest.raises(IndexError):
        pool.ensure_writable(0, 4)
    with pytest.raises(ValueError):
        PagePool(0, 4)


def test_reset_drains_and_reseeds():
    pool = PagePool(8, 4, seed=3)
    first = pool.alloc_request(0, [1, 2, 3], 6).table
    pool.alloc_request(1, [4, 5, 6], 6)
    pool.reset()
    assert pool.pages_in_use == 0 and pool.free_pages == 8
    assert pool.alloc_request(0, [1, 2, 3], 6).table == first
    pool.check()


def test_seeded_alloc_order_deterministic():
    tables = []
    for _ in range(2):
        pool = PagePool(8, 4, seed=7)
        t = pool.alloc_request(0, [1, 2, 3, 4, 5], 8).table
        t += pool.alloc_request(1, [9, 9], 4).table
        tables.append(t)
    assert tables[0] == tables[1]


# ---------------------------------------------------------------------------
# property test: arbitrary interleavings never leak or double-free
# ---------------------------------------------------------------------------

def _run_ops(ops, n_pages=6, page_size=4):
    """Interpret a flat op list against a pool, asserting invariants
    after every operation.  ops: (kind, a, b) with kind in 0..3."""
    pool = PagePool(n_pages, page_size, seed=1)
    live = {}                    # rid -> (prompt, total, next write pos)
    next_rid = 0
    prompts = [[1, 1, 1, 1, 1, 1], [1, 1, 1, 1, 2, 2], [3, 3, 3],
               [1, 1, 1, 1, 1, 1, 1, 1]]
    for kind, a, b in ops:
        if kind == 0:            # alloc
            prompt = prompts[a % len(prompts)]
            total = min(len(prompt) + 1 + b % 6, 3 * page_size)
            alloc = pool.alloc_request(next_rid, prompt, total)
            if alloc is not None:
                assert len(alloc.table) == cdiv(total, page_size)
                live[next_rid] = [prompt, total, len(prompt)]
                next_rid += 1
        elif kind == 1 and live:  # write the next position (maybe COW)
            rid = sorted(live)[a % len(live)]
            prompt, total, pos = live[rid]
            if pos < total:
                pool.ensure_writable(rid, pos)
                live[rid][2] = pos + 1
        elif kind == 2 and live:  # free
            rid = sorted(live)[a % len(live)]
            pool.free_request(rid)
            del live[rid]
        elif kind == 3 and live:  # preempt + immediate restore attempt
            rid = sorted(live)[a % len(live)]
            prompt, total, pos = live[rid]
            pool.free_request(rid)
            del live[rid]
            alloc = pool.alloc_request(next_rid, prompt, total,
                                       written_upto=pos)
            if alloc is not None:
                live[next_rid] = [prompt, total, pos]
                next_rid += 1
        pool.check()
        assert pool.total_refs == sum(
            len(pool.table_of(r)) for r in live)
    for rid in list(live):
        pool.free_request(rid)
    pool.check()
    assert pool.pages_in_use == 0 and pool.total_refs == 0
    assert pool.free_pages == n_pages and not pool._trie


def test_pool_property_seeded_fuzz():
    """300 random interleavings of alloc/write/free/preempt-restore:
    ``check()`` holds after every op and a full drain leaks nothing."""
    rng = np.random.default_rng(0)
    for _ in range(300):
        ops = [(int(rng.integers(0, 4)), int(rng.integers(0, 8)),
                int(rng.integers(0, 8))) for _ in range(30)]
        _run_ops(ops)


if HAVE_HYPOTHESIS:
    @settings(max_examples=200, deadline=None)
    @given(st.lists(st.tuples(st.integers(0, 3), st.integers(0, 7),
                              st.integers(0, 7)), max_size=40))
    def test_pool_property_hypothesis(ops):
        _run_ops(ops)
