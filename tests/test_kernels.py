"""Per-kernel allclose vs the pure-jnp oracles (interpret mode on CPU),
with shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.lstm_cell import blstm_sequence, lstm_sequence
from repro.kernels.ssd_scan import ssd
from repro.models.ssm import ssd_chunked

KEY = jax.random.PRNGKey(42)


def _mk(shape, dtype, i=0, scale=1.0):
    return (jax.random.normal(jax.random.fold_in(KEY, i), shape,
                              jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
@pytest.mark.parametrize("B,S,H,KV,E", [
    (1, 128, 4, 4, 64),      # MHA
    (2, 256, 6, 2, 64),      # GQA 3:1
    (1, 256, 8, 1, 128),     # MQA, 128 head_dim
])
@pytest.mark.parametrize("window", [0, 64])
def test_flash_attention(B, S, H, KV, E, dtype, window):
    q = _mk((B, S, H, E), dtype, 1)
    k = _mk((B, S, KV, E), dtype, 2)
    v = _mk((B, S, KV, E), dtype, 3)
    out = flash_attention(q, k, v, causal=True, window=window,
                          block_q=64, block_k=64, interpret=True)
    expect = ref.attention_ref(q, k, v, causal=True, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(out.astype(np.float32),
                               expect.astype(np.float32),
                               atol=tol, rtol=tol)


def test_flash_attention_noncausal():
    q = _mk((2, 128, 4, 64), jnp.bfloat16, 4)
    k = _mk((2, 128, 4, 64), jnp.bfloat16, 5)
    v = _mk((2, 128, 4, 64), jnp.bfloat16, 6)
    out = flash_attention(q, k, v, causal=False, block_q=64, block_k=64,
                          interpret=True)
    expect = ref.attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(out.astype(np.float32),
                               expect.astype(np.float32), atol=2e-2,
                               rtol=2e-2)


def test_flash_attention_matches_model_chunked_path():
    """The pure-JAX attn_seq (model path) and the kernel agree."""
    from repro.models.attention import attn_seq

    q = _mk((1, 256, 4, 64), jnp.bfloat16, 7)
    k = _mk((1, 256, 2, 64), jnp.bfloat16, 8)
    v = _mk((1, 256, 2, 64), jnp.bfloat16, 9)
    a = attn_seq(q, k, v, causal=True, q_chunk=64)
    b = flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                        interpret=True)
    np.testing.assert_allclose(a.astype(np.float32), b.astype(np.float32),
                               atol=3e-2, rtol=3e-2)


# ---------------------------------------------------------------------------
# fused LSTM
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
@pytest.mark.parametrize("B,T,D,H", [(4, 21, 26, 32), (2, 33, 16, 16)])
@pytest.mark.parametrize("reverse", [False, True])
def test_lstm_sequence(B, T, D, H, dtype, reverse):
    wx = _mk((D, 4 * H), dtype, 10, 0.3)
    wh = _mk((H, 4 * H), dtype, 11, 0.3)
    b = _mk((4 * H,), jnp.float32, 12, 0.1)
    x = _mk((B, T, D), dtype, 13)
    out = lstm_sequence(wx, wh, b, x, reverse=reverse, interpret=True)
    expect = ref.lstm_ref(wx, wh, b, x, reverse=reverse)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(out.astype(np.float32),
                               expect.astype(np.float32), atol=tol, rtol=tol)


def _norm_close(got, want, tol, name=""):
    """allclose after normalizing by the oracle's scale (grad tensors span
    orders of magnitude; raw atol would be meaningless)."""
    scale = float(jnp.abs(want.astype(jnp.float32)).max()) + 1e-8
    np.testing.assert_allclose(np.asarray(got, np.float32) / scale,
                               np.asarray(want, np.float32) / scale,
                               atol=tol, err_msg=name)


def _mk_lstm(D, H, dtype, base):
    wx = _mk((D, 4 * H), dtype, base, 0.3)
    wh = _mk((H, 4 * H), dtype, base + 1, 0.3)
    b = _mk((4 * H,), jnp.float32, base + 2, 0.1)
    return wx, wh, b


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
@pytest.mark.parametrize("reverse", [False, True])
@pytest.mark.parametrize("B,T,D,H,block_b", [
    (4, 9, 12, 16, None),     # single tile
    (5, 6, 8, 16, 2),         # tiled, B not a multiple of block_b (padding)
])
def test_lstm_sequence_grad(B, T, D, H, block_b, reverse, dtype):
    """value_and_grad parity of the Pallas custom VJP vs jax autodiff
    through the scan oracle, for all four inputs."""
    wx, wh, b = _mk_lstm(D, H, dtype, 70)
    x = _mk((B, T, D), dtype, 73)

    def loss_k(wx, wh, b, x):
        y = lstm_sequence(wx, wh, b, x, reverse=reverse, interpret=True,
                          block_b=block_b)
        return jnp.mean(jnp.square(y.astype(jnp.float32)))

    def loss_r(wx, wh, b, x):
        y = ref.lstm_ref(wx, wh, b, x, reverse=reverse)
        return jnp.mean(jnp.square(y.astype(jnp.float32)))

    v_k, g_k = jax.value_and_grad(loss_k, argnums=(0, 1, 2, 3))(wx, wh, b, x)
    v_r, g_r = jax.value_and_grad(loss_r, argnums=(0, 1, 2, 3))(wx, wh, b, x)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(float(v_k), float(v_r), rtol=tol)
    for got, want, name in zip(g_k, g_r, ("dwx", "dwh", "db", "dx")):
        assert got.dtype == want.dtype
        _norm_close(got, want, tol, name)


def test_blstm_fused_bitidentical_and_tiled():
    """The fused bidirectional kernel is bit-identical to two separate
    direction passes, and batch tiling (incl. a non-dividing block_b)
    is bit-identical to the untiled kernel."""
    B, T, D, H = 5, 7, 12, 16
    wxf, whf, bf = _mk_lstm(D, H, jnp.bfloat16, 80)
    wxb, whb, bb = _mk_lstm(D, H, jnp.bfloat16, 84)
    x = _mk((B, T, D), jnp.bfloat16, 88)

    fused = blstm_sequence(wxf, whf, bf, wxb, whb, bb, x, interpret=True,
                           block_b=8)
    sep = jnp.concatenate(
        [lstm_sequence(wxf, whf, bf, x, interpret=True, block_b=8),
         lstm_sequence(wxb, whb, bb, x, reverse=True, interpret=True,
                       block_b=8)], axis=-1)
    np.testing.assert_array_equal(np.asarray(fused, np.float32),
                                  np.asarray(sep, np.float32))

    tiled = blstm_sequence(wxf, whf, bf, wxb, whb, bb, x, interpret=True,
                           block_b=2)   # 5 % 2 != 0 -> zero-pad path
    np.testing.assert_array_equal(np.asarray(fused, np.float32),
                                  np.asarray(tiled, np.float32))
    _norm_close(fused, ref.blstm_ref(wxf, whf, bf, wxb, whb, bb, x), 2e-2)


@pytest.mark.parametrize("block_b", [None, 2])
def test_blstm_grad(block_b):
    B, T, D, H = 4, 6, 8, 16
    wxf, whf, bf = _mk_lstm(D, H, jnp.bfloat16, 90)
    wxb, whb, bb = _mk_lstm(D, H, jnp.bfloat16, 94)
    x = _mk((B, T, D), jnp.bfloat16, 98)

    def loss_k(*w):
        y = blstm_sequence(*w, interpret=True, block_b=block_b)
        return jnp.mean(jnp.square(y.astype(jnp.float32)))

    def loss_r(*w):
        return jnp.mean(jnp.square(
            ref.blstm_ref(*w).astype(jnp.float32)))

    args = (wxf, whf, bf, wxb, whb, bb, x)
    v_k, g_k = jax.value_and_grad(loss_k, argnums=tuple(range(7)))(*args)
    v_r, g_r = jax.value_and_grad(loss_r, argnums=tuple(range(7)))(*args)
    np.testing.assert_allclose(float(v_k), float(v_r), rtol=2e-2)
    names = ("dwxf", "dwhf", "dbf", "dwxb", "dwhb", "dbb", "dx")
    for got, want, name in zip(g_k, g_r, names):
        assert got.dtype == want.dtype
        _norm_close(got, want, 2e-2, name)


@pytest.mark.parametrize("reverse", [False, True])
def test_lstm_layer_pallas_matches_jax(reverse):
    """models/lstm.lstm_layer's per-direction pallas path (incl. the
    block_b/vmem_budget plumbing) tracks its own jax scan path."""
    from repro.models.lstm import lstm_layer

    D, H = 12, 16
    wx, wh, b = _mk_lstm(D, H, jnp.bfloat16, 104)
    p = {"wx": wx, "wh": wh, "b": b}
    x = _mk((5, 6, D), jnp.bfloat16, 108)
    got = lstm_layer(p, x, reverse=reverse, kernel_impl="pallas", block_b=2)
    want = lstm_layer(p, x, reverse=reverse, kernel_impl="jax")
    _norm_close(got, want, 2e-2)


def test_lstm_pallas_loss_train_and_ad_psgd_step():
    """End-to-end acceptance: jax.value_and_grad through
    models/lstm.loss_train(kernel_impl='pallas') matches the jax path,
    and a replicated ad_psgd train step runs on the pallas kernel."""
    import dataclasses

    from repro.configs import get_arch
    from repro.core import strategies as ST
    from repro.models import build_model
    from repro.optim.optimizers import get_optimizer
    from repro.optim.schedules import constant
    from repro.sharding import init_spec_tree

    cfg = dataclasses.replace(get_arch("swb2000-blstm").reduced(),
                              n_layers=1, lstm_hidden=16, lstm_bottleneck=8,
                              input_dim=12, vocab=32, lstm_block_b=2)
    model = build_model(cfg)
    params = init_spec_tree(model.param_specs(), jax.random.PRNGKey(0))
    B, T = 4, 5
    batch = {
        "features": np.asarray(_mk((B, T, cfg.input_dim), jnp.float32, 100)),
        "labels": np.asarray(
            jax.random.randint(KEY, (B, T), 0, cfg.vocab, jnp.int32)),
    }

    v_j, g_j = jax.value_and_grad(
        lambda p: model.loss_fn(p, batch, kernel_impl="jax"))(params)
    v_p, g_p = jax.value_and_grad(
        lambda p: model.loss_fn(p, batch, kernel_impl="pallas"))(params)
    np.testing.assert_allclose(float(v_p), float(v_j), rtol=2e-2)
    flat_j, _ = jax.tree.flatten(g_j)
    flat_p, treedef = jax.tree.flatten(g_p)
    for got, want in zip(flat_p, flat_j):
        _norm_close(got, want, 2e-2, str(treedef))

    strategy = ST.get_strategy("ad_psgd")
    opt = get_optimizer("sgd")
    step = ST.make_train_step(
        strategy,
        lambda p, bt: model.loss_fn(p, bt, kernel_impl="pallas"),
        opt, constant(0.05), n_learners=2)
    state = ST.init_state(strategy, ST.stack_for_learners(params, 2), opt)
    jit_step = jax.jit(step)
    for _ in range(2):
        state, metrics = jit_step(state, batch)
    assert np.isfinite(float(metrics["loss"]))


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (2, 128, 4, 16, 8, 32),
    (1, 64, 2, 32, 16, 16),
    (1, 256, 8, 64, 64, 64),   # production-like head/state dims
])
def test_ssd_kernel(B, S, H, P, N, chunk):
    x = _mk((B, S, H, P), jnp.bfloat16, 20)
    dt = jax.nn.softplus(_mk((B, S, H), jnp.float32, 21))
    A = -jnp.exp(_mk((H,), jnp.float32, 22, 0.5))
    Bm = _mk((B, S, H, N), jnp.bfloat16, 23)
    Cm = _mk((B, S, H, N), jnp.bfloat16, 24)
    y, hf = ssd(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    y_ref, hf_ref = ref.ssd_ref(x, dt, A, Bm, Cm)
    scale = float(jnp.abs(y_ref.astype(jnp.float32)).max()) + 1e-6
    np.testing.assert_allclose(y.astype(np.float32) / scale,
                               y_ref.astype(np.float32) / scale,
                               atol=5e-3)
    hs = float(jnp.abs(hf_ref).max()) + 1e-6
    np.testing.assert_allclose(hf / hs, hf_ref / hs, atol=5e-3)


def test_ssd_chunked_jnp_matches_ref():
    """The model's pure-jnp chunked path tracks the exact recurrence to
    bf16 accuracy (it intentionally runs bf16 matmuls)."""
    B, S, H, P, N = 2, 128, 4, 16, 8
    x = _mk((B, S, H, P), jnp.bfloat16, 30)
    dt = jax.nn.softplus(_mk((B, S, H), jnp.float32, 31))
    A = -jnp.exp(_mk((H,), jnp.float32, 32, 0.5))
    Bm = _mk((B, S, H, N), jnp.bfloat16, 33)
    Cm = _mk((B, S, H, N), jnp.bfloat16, 34)
    y, hf = ssd_chunked(x, dt, A, Bm, Cm, 32)
    y_ref, hf_ref = ref.ssd_ref(x, dt, A, Bm, Cm)
    scale = float(jnp.abs(y_ref.astype(jnp.float32)).max()) + 1e-6
    np.testing.assert_allclose(y.astype(np.float32) / scale,
                               y_ref.astype(np.float32) / scale, atol=2e-2)


def test_ssd_state_continuation():
    """Chunked scan with h0 from a previous segment == one long sequence."""
    B, S, H, P, N = 1, 128, 2, 16, 8
    x = _mk((B, S, H, P), jnp.float32, 40)
    dt = jax.nn.softplus(_mk((B, S, H), jnp.float32, 41))
    A = -jnp.exp(_mk((H,), jnp.float32, 42, 0.5))
    Bm = _mk((B, S, H, N), jnp.float32, 43)
    Cm = _mk((B, S, H, N), jnp.float32, 44)
    y_full, h_full = ssd_chunked(x, dt, A, Bm, Cm, 32)
    half = S // 2
    y1, h1 = ssd_chunked(x[:, :half], dt[:, :half], A, Bm[:, :half],
                         Cm[:, :half], 32)
    y2, h2 = ssd_chunked(x[:, half:], dt[:, half:], A, Bm[:, half:],
                         Cm[:, half:], 32, h0=h1)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), y_full,
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(h2, h_full, atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# fused dense-MoE
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("act", ["swiglu", "gelu"])
@pytest.mark.parametrize("T,d,E,f,tile", [
    (64, 32, 4, 16, 32),
    (128, 64, 8, 32, 64),
])
def test_moe_dense_kernel(T, d, E, f, tile, act):
    from repro.kernels.moe_dense import moe_dense
    from repro.kernels.ref import moe_dense_ref

    x = _mk((T, d), jnp.bfloat16, 50)
    wi = _mk((E, d, f), jnp.bfloat16, 51, 0.3)
    wg = _mk((E, d, f), jnp.bfloat16, 52, 0.3)
    wo = _mk((E, f, d), jnp.bfloat16, 53, 0.3)
    # top-2-of-E style sparse router weights
    raw = jax.nn.softmax(_mk((T, E), jnp.float32, 54), -1)
    top, idx = jax.lax.top_k(raw, 2)
    w = jnp.zeros((T, E)).at[jnp.arange(T)[:, None], idx].set(
        top / top.sum(-1, keepdims=True))
    y = moe_dense(x, w, wi, wg, wo, act=act, tile_t=tile, interpret=True)
    y_ref = moe_dense_ref(x, w, wi, wg, wo, act=act)
    scale = float(jnp.abs(y_ref.astype(jnp.float32)).max()) + 1e-6
    np.testing.assert_allclose(y.astype(np.float32) / scale,
                               y_ref.astype(np.float32) / scale, atol=2e-2)


def test_moe_dense_kernel_matches_model_moe():
    """Kernel output == models/moe.py dense path on a full block."""
    import dataclasses
    from repro.configs import get_arch
    from repro.kernels.moe_dense import moe_dense
    from repro.models.moe import moe_apply, moe_param_specs
    from repro.sharding import init_spec_tree

    cfg = get_arch("granite-moe-3b-a800m").reduced()
    p = init_spec_tree(moe_param_specs(cfg), jax.random.PRNGKey(1))
    x = _mk((2, 32, cfg.d_model), jnp.bfloat16, 60)
    y_model, _ = moe_apply(cfg, p, x)
    # rebuild the router weights exactly as moe_apply does
    m = cfg.moe
    logits = jnp.einsum("bsd,de->bse",
                        x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, -1)
    top, idx = jax.lax.top_k(probs, m.top_k)
    top = top / top.sum(-1, keepdims=True)
    oh = jax.nn.one_hot(idx, m.num_experts, dtype=jnp.float32)
    w_te = jnp.einsum("bsk,bske->bse", top, oh)
    T = x.shape[0] * x.shape[1]
    y_k = moe_dense(x.reshape(T, -1), w_te.reshape(T, -1),
                    p["wi"], p["wg"], p["wo"], act=cfg.act,
                    tile_t=32).reshape(x.shape)
    scale = float(jnp.abs(y_model.astype(jnp.float32)).max()) + 1e-6
    np.testing.assert_allclose(y_k.astype(np.float32) / scale,
                               y_model.astype(np.float32) / scale,
                               atol=3e-2)
