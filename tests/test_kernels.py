"""Per-kernel allclose vs the pure-jnp oracles (interpret mode on CPU),
with shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.lstm_cell import lstm_sequence
from repro.kernels.ssd_scan import ssd
from repro.models.ssm import ssd_chunked

KEY = jax.random.PRNGKey(42)


def _mk(shape, dtype, i=0, scale=1.0):
    return (jax.random.normal(jax.random.fold_in(KEY, i), shape,
                              jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
@pytest.mark.parametrize("B,S,H,KV,E", [
    (1, 128, 4, 4, 64),      # MHA
    (2, 256, 6, 2, 64),      # GQA 3:1
    (1, 256, 8, 1, 128),     # MQA, 128 head_dim
])
@pytest.mark.parametrize("window", [0, 64])
def test_flash_attention(B, S, H, KV, E, dtype, window):
    q = _mk((B, S, H, E), dtype, 1)
    k = _mk((B, S, KV, E), dtype, 2)
    v = _mk((B, S, KV, E), dtype, 3)
    out = flash_attention(q, k, v, causal=True, window=window,
                          block_q=64, block_k=64, interpret=True)
    expect = ref.attention_ref(q, k, v, causal=True, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(out.astype(np.float32),
                               expect.astype(np.float32),
                               atol=tol, rtol=tol)


def test_flash_attention_noncausal():
    q = _mk((2, 128, 4, 64), jnp.bfloat16, 4)
    k = _mk((2, 128, 4, 64), jnp.bfloat16, 5)
    v = _mk((2, 128, 4, 64), jnp.bfloat16, 6)
    out = flash_attention(q, k, v, causal=False, block_q=64, block_k=64,
                          interpret=True)
    expect = ref.attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(out.astype(np.float32),
                               expect.astype(np.float32), atol=2e-2,
                               rtol=2e-2)


def test_flash_attention_matches_model_chunked_path():
    """The pure-JAX attn_seq (model path) and the kernel agree."""
    from repro.models.attention import attn_seq

    q = _mk((1, 256, 4, 64), jnp.bfloat16, 7)
    k = _mk((1, 256, 2, 64), jnp.bfloat16, 8)
    v = _mk((1, 256, 2, 64), jnp.bfloat16, 9)
    a = attn_seq(q, k, v, causal=True, q_chunk=64)
    b = flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                        interpret=True)
    np.testing.assert_allclose(a.astype(np.float32), b.astype(np.float32),
                               atol=3e-2, rtol=3e-2)


# ---------------------------------------------------------------------------
# fused LSTM
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
@pytest.mark.parametrize("B,T,D,H", [(4, 21, 26, 32), (2, 33, 16, 16)])
@pytest.mark.parametrize("reverse", [False, True])
def test_lstm_sequence(B, T, D, H, dtype, reverse):
    wx = _mk((D, 4 * H), dtype, 10, 0.3)
    wh = _mk((H, 4 * H), dtype, 11, 0.3)
    b = _mk((4 * H,), jnp.float32, 12, 0.1)
    x = _mk((B, T, D), dtype, 13)
    out = lstm_sequence(wx, wh, b, x, reverse=reverse, interpret=True)
    expect = ref.lstm_ref(wx, wh, b, x, reverse=reverse)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(out.astype(np.float32),
                               expect.astype(np.float32), atol=tol, rtol=tol)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (2, 128, 4, 16, 8, 32),
    (1, 64, 2, 32, 16, 16),
    (1, 256, 8, 64, 64, 64),   # production-like head/state dims
])
def test_ssd_kernel(B, S, H, P, N, chunk):
    x = _mk((B, S, H, P), jnp.bfloat16, 20)
    dt = jax.nn.softplus(_mk((B, S, H), jnp.float32, 21))
    A = -jnp.exp(_mk((H,), jnp.float32, 22, 0.5))
    Bm = _mk((B, S, H, N), jnp.bfloat16, 23)
    Cm = _mk((B, S, H, N), jnp.bfloat16, 24)
    y, hf = ssd(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    y_ref, hf_ref = ref.ssd_ref(x, dt, A, Bm, Cm)
    scale = float(jnp.abs(y_ref.astype(jnp.float32)).max()) + 1e-6
    np.testing.assert_allclose(y.astype(np.float32) / scale,
                               y_ref.astype(np.float32) / scale,
                               atol=5e-3)
    hs = float(jnp.abs(hf_ref).max()) + 1e-6
    np.testing.assert_allclose(hf / hs, hf_ref / hs, atol=5e-3)


def test_ssd_chunked_jnp_matches_ref():
    """The model's pure-jnp chunked path tracks the exact recurrence to
    bf16 accuracy (it intentionally runs bf16 matmuls)."""
    B, S, H, P, N = 2, 128, 4, 16, 8
    x = _mk((B, S, H, P), jnp.bfloat16, 30)
    dt = jax.nn.softplus(_mk((B, S, H), jnp.float32, 31))
    A = -jnp.exp(_mk((H,), jnp.float32, 32, 0.5))
    Bm = _mk((B, S, H, N), jnp.bfloat16, 33)
    Cm = _mk((B, S, H, N), jnp.bfloat16, 34)
    y, hf = ssd_chunked(x, dt, A, Bm, Cm, 32)
    y_ref, hf_ref = ref.ssd_ref(x, dt, A, Bm, Cm)
    scale = float(jnp.abs(y_ref.astype(jnp.float32)).max()) + 1e-6
    np.testing.assert_allclose(y.astype(np.float32) / scale,
                               y_ref.astype(np.float32) / scale, atol=2e-2)


def test_ssd_state_continuation():
    """Chunked scan with h0 from a previous segment == one long sequence."""
    B, S, H, P, N = 1, 128, 2, 16, 8
    x = _mk((B, S, H, P), jnp.float32, 40)
    dt = jax.nn.softplus(_mk((B, S, H), jnp.float32, 41))
    A = -jnp.exp(_mk((H,), jnp.float32, 42, 0.5))
    Bm = _mk((B, S, H, N), jnp.float32, 43)
    Cm = _mk((B, S, H, N), jnp.float32, 44)
    y_full, h_full = ssd_chunked(x, dt, A, Bm, Cm, 32)
    half = S // 2
    y1, h1 = ssd_chunked(x[:, :half], dt[:, :half], A, Bm[:, :half],
                         Cm[:, :half], 32)
    y2, h2 = ssd_chunked(x[:, half:], dt[:, half:], A, Bm[:, half:],
                         Cm[:, half:], 32, h0=h1)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), y_full,
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(h2, h_full, atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# fused dense-MoE
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("act", ["swiglu", "gelu"])
@pytest.mark.parametrize("T,d,E,f,tile", [
    (64, 32, 4, 16, 32),
    (128, 64, 8, 32, 64),
])
def test_moe_dense_kernel(T, d, E, f, tile, act):
    from repro.kernels.moe_dense import moe_dense
    from repro.kernels.ref import moe_dense_ref

    x = _mk((T, d), jnp.bfloat16, 50)
    wi = _mk((E, d, f), jnp.bfloat16, 51, 0.3)
    wg = _mk((E, d, f), jnp.bfloat16, 52, 0.3)
    wo = _mk((E, f, d), jnp.bfloat16, 53, 0.3)
    # top-2-of-E style sparse router weights
    raw = jax.nn.softmax(_mk((T, E), jnp.float32, 54), -1)
    top, idx = jax.lax.top_k(raw, 2)
    w = jnp.zeros((T, E)).at[jnp.arange(T)[:, None], idx].set(
        top / top.sum(-1, keepdims=True))
    y = moe_dense(x, w, wi, wg, wo, act=act, tile_t=tile, interpret=True)
    y_ref = moe_dense_ref(x, w, wi, wg, wo, act=act)
    scale = float(jnp.abs(y_ref.astype(jnp.float32)).max()) + 1e-6
    np.testing.assert_allclose(y.astype(np.float32) / scale,
                               y_ref.astype(np.float32) / scale, atol=2e-2)


def test_moe_dense_kernel_matches_model_moe():
    """Kernel output == models/moe.py dense path on a full block."""
    import dataclasses
    from repro.configs import get_arch
    from repro.kernels.moe_dense import moe_dense
    from repro.models.moe import moe_apply, moe_param_specs
    from repro.sharding import init_spec_tree

    cfg = get_arch("granite-moe-3b-a800m").reduced()
    p = init_spec_tree(moe_param_specs(cfg), jax.random.PRNGKey(1))
    x = _mk((2, 32, cfg.d_model), jnp.bfloat16, 60)
    y_model, _ = moe_apply(cfg, p, x)
    # rebuild the router weights exactly as moe_apply does
    m = cfg.moe
    logits = jnp.einsum("bsd,de->bse",
                        x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, -1)
    top, idx = jax.lax.top_k(probs, m.top_k)
    top = top / top.sum(-1, keepdims=True)
    oh = jax.nn.one_hot(idx, m.num_experts, dtype=jnp.float32)
    w_te = jnp.einsum("bsk,bske->bse", top, oh)
    T = x.shape[0] * x.shape[1]
    y_k = moe_dense(x.reshape(T, -1), w_te.reshape(T, -1),
                    p["wi"], p["wg"], p["wo"], act=cfg.act,
                    tile_t=32).reshape(x.shape)
    scale = float(jnp.abs(y_model.astype(jnp.float32)).max()) + 1e-6
    np.testing.assert_allclose(y_k.astype(np.float32) / scale,
                               y_model.astype(np.float32) / scale,
                               atol=3e-2)
