#!/usr/bin/env python
"""Docs consistency checker (the CI `docs` job; also run as a tier-1
test via tests/test_docs.py).

Four checks, all against the working tree:

1. **Intra-repo markdown links** — every relative `[text](target)` link
   in a tracked *.md file must resolve to an existing file/directory
   (anchors are stripped; external schemes are ignored).
2. **README flag reference** — every argparse flag defined in
   `src/repro/launch/train.py`, `src/repro/launch/serve.py` and
   `src/repro/launch/evaluate.py` must appear in README.md, so the CLI
   surface and its documentation cannot drift apart.
3. **README config-knob reference** — every `ArchConfig` field of
   `src/repro/configs/base.py` must be mentioned in README.md (as
   `` `name` ``), so new config knobs cannot land undocumented.
4. **README docs index** — every `docs/*.md` must be linked from
   README.md, so a new docs page cannot land undiscoverable.

Exit status is non-zero with one line per problem.
"""
from __future__ import annotations

import ast
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent

# [text](target) — good enough for our hand-written markdown; skips
# fenced code because our docs never put link syntax inside it.
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_FLAG = re.compile(r"add_argument\(\s*\"(--[A-Za-z0-9-]+)\"")

_EXTERNAL = ("http://", "https://", "mailto:", "ftp://")

FLAG_SOURCES = ("src/repro/launch/train.py", "src/repro/launch/serve.py",
                "src/repro/launch/evaluate.py", "src/repro/launch/load.py",
                "src/repro/launch/obsreport.py")


def iter_markdown(root: Path):
    for path in sorted(root.rglob("*.md")):
        if ".git" in path.parts or ".pytest_cache" in path.parts:
            continue
        yield path


def check_links(root: Path = ROOT) -> list:
    """Broken intra-repo links as 'file: target' strings."""
    problems = []
    for md in iter_markdown(root):
        for target in _LINK.findall(md.read_text(encoding="utf-8")):
            if target.startswith(_EXTERNAL) or target.startswith("#"):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            resolved = (md.parent / rel).resolve()
            if not resolved.exists():
                problems.append(
                    f"{md.relative_to(root)}: broken link -> {target}")
    return problems


def declared_flags(root: Path = ROOT) -> dict:
    """{flag: defining file} over the launcher argparse surfaces."""
    flags = {}
    for src in FLAG_SOURCES:
        text = (root / src).read_text(encoding="utf-8")
        for flag in _FLAG.findall(text):
            flags.setdefault(flag, src)
    return flags


def check_flag_reference(root: Path = ROOT) -> list:
    """Launcher flags missing from the README flag reference."""
    readme = (root / "README.md").read_text(encoding="utf-8")
    return [f"README.md: flag {flag} ({src}) missing from the "
            f"flag reference"
            for flag, src in sorted(declared_flags(root).items())
            if f"`{flag}`" not in readme]


CONFIG_SOURCE = "src/repro/configs/base.py"


def declared_config_knobs(root: Path = ROOT) -> list:
    """ArchConfig field names parsed (ast, no import) from configs/base.py."""
    tree = ast.parse((root / CONFIG_SOURCE).read_text(encoding="utf-8"))
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef) and node.name == "ArchConfig":
            return [stmt.target.id for stmt in node.body
                    if isinstance(stmt, ast.AnnAssign)
                    and isinstance(stmt.target, ast.Name)]
    return []


def check_config_reference(root: Path = ROOT) -> list:
    """ArchConfig knobs missing from the README config reference."""
    readme = (root / "README.md").read_text(encoding="utf-8")
    return [f"README.md: ArchConfig knob `{knob}` ({CONFIG_SOURCE}) "
            f"missing from the config reference"
            for knob in declared_config_knobs(root)
            if f"`{knob}`" not in readme]


def check_docs_index(root: Path = ROOT) -> list:
    """docs/*.md pages not linked from README.md."""
    readme = (root / "README.md").read_text(encoding="utf-8")
    linked = {t.split("#", 1)[0] for t in _LINK.findall(readme)}
    return [f"README.md: docs page docs/{md.name} not linked from the "
            f"docs index"
            for md in sorted((root / "docs").glob("*.md"))
            if f"docs/{md.name}" not in linked]


def main() -> int:
    problems = (check_links() + check_flag_reference()
                + check_config_reference() + check_docs_index())
    for p in problems:
        print(p)
    if problems:
        print(f"{len(problems)} docs problem(s)", file=sys.stderr)
        return 1
    n_md = len(list(iter_markdown(ROOT)))
    print(f"docs OK: {n_md} markdown files, "
          f"{len(declared_flags())} CLI flags + "
          f"{len(declared_config_knobs())} config knobs documented")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
