from repro.analysis.hlo import analyze_hlo, HloStats  # noqa: F401
from repro.analysis.roofline import roofline_terms, HW  # noqa: F401
