"""Roofline terms for the TPU v5e target (EXPERIMENTS.md §Roofline).

    compute term    = HLO_FLOPs        / (chips * peak_FLOP/s)
    memory term     = HLO_bytes        / (chips * HBM_bw)
    collective term = collective_bytes / (chips * link_bw)

HLO quantities come from the per-device HLO analysis (trip-count-correct,
see ``repro.analysis.hlo``); per-device * chips = cluster totals, so the
per-chip time terms below divide out to the per-device numbers directly.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class Hardware:
    name: str = "tpu-v5e"
    peak_flops_bf16: float = 197e12     # per chip
    hbm_bw: float = 819e9               # bytes/s per chip
    ici_bw: float = 50e9                # bytes/s per link
    hbm_per_chip: float = 16e9


HW = Hardware()


def model_flops(cfg, shape, n_params_active: float, mode: str) -> float:
    """Analytic MODEL_FLOPS = 6*N*D (train) / 2*N*D (forward-only), global."""
    if mode == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_params_active * tokens
    if mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        if cfg.family == "encdec":
            tokens = shape.global_batch * shape.seq_len  # enc+dec halves
        return 2.0 * n_params_active * tokens
    # decode: one token per sequence
    return 2.0 * n_params_active * shape.global_batch


def roofline_terms(per_device: dict, *, chips: int, hw: Hardware = HW):
    """per_device: {'flops','bytes','collective_bytes'} from HloStats."""
    compute = per_device["flops"] / hw.peak_flops_bf16
    memory = per_device["bytes"] / hw.hbm_bw
    collective = per_device["collective_bytes"] / hw.ici_bw
    terms = {"compute_s": compute, "memory_s": memory,
             "collective_s": collective}
    dominant = max(terms, key=terms.get)
    terms["dominant"] = dominant.replace("_s", "")
    terms["bound_s"] = terms[dominant]
    return terms
