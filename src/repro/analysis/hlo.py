"""Post-compile HLO analyzer: per-step FLOPs / HBM bytes / collective bytes
with correct while-loop trip-count attribution.

``compiled.cost_analysis()`` counts a ``while`` body ONCE, so any scanned
program (layers, microbatches, q-chunks) is undercounted by the trip
count.  XLA:CPU conveniently records ``backend_config={"known_trip_count"
:{"n": ...}}`` on while ops after optimization, so we walk the call graph
(fusion ``calls=``, while ``body=/condition=``, ``to_apply=``) and multiply
through.  Validated against a fully-unrolled compile of the same program
(tests/test_hlo_analysis.py).

All numbers are PER DEVICE (the SPMD module is per-device); multiply by
chip count for cluster totals.
"""
from __future__ import annotations

import json
import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "opaque": 0,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
def _parse_op_line(line):
    """'%name = TYPE opcode(args), attrs' -> (name, type_str, opcode, rest)
    with balanced-paren handling of tuple types (which may contain '=' in
    /*index=N*/ comments and '{...}' layouts)."""
    s = line.strip()
    if s.startswith("ROOT "):
        s = s[5:]
    eq = s.find(" = ")
    if eq < 0 or not s.startswith("%"):
        return None
    name = s[1:eq]
    rhs = s[eq + 3:]
    if rhs.startswith("("):
        depth, i = 0, 0
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        type_str = rhs[:i + 1]
        rem = rhs[i + 1:].lstrip()
    else:
        sp = rhs.find(" ")
        if sp < 0:
            return None
        type_str = rhs[:sp]
        rem = rhs[sp + 1:]
    par = rem.find("(")
    if par < 0:
        return None
    opcode = rem[:par].strip()
    rest = rem[par + 1:]
    if not re.fullmatch(r"[\w\-]+", opcode):
        return None
    return name, type_str, opcode, rest
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_APPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _parse_shapes(type_str):
    """'(f32[2,3], bf16[4])' or 'f32[2,3]{1,0}' -> [(dtype, [dims])]."""
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        shape = [int(x) for x in dims.split(",")] if dims else []
        out.append((dt, shape))
    return out


def _nbytes(shapes):
    total = 0
    for dt, dims in shapes:
        n = _DTYPE_BYTES.get(dt, 0)
        for d in dims:
            n *= d
        total += n
    return total


def _nelems(dims):
    n = 1
    for d in dims:
        n *= d
    return n


# HBM-traffic model: count operand+result bytes only for ops that would
# stay materialization boundaries under TPU fusion; bare elementwise /
# shape ops are assumed fused into a neighbor (calibration notes in
# DESIGN.md §Roofline-methodology).
_COUNT_BYTES_OPS = {
    "fusion", "dot", "convolution", "dynamic-update-slice", "dynamic-slice",
    "copy", "transpose", "reduce", "reduce-window", "scatter", "gather",
    "sort", "pad", "concatenate", "slice", "reverse", "cholesky",
    "triangular-solve", "rng", "rng-bit-generator",
} | set(COLLECTIVES)


@dataclass
class _Comp:
    name: str
    flops: float = 0.0
    bytes: float = 0.0
    coll: dict = field(default_factory=dict)
    # (kind, callee, trip) edges
    edges: list = field(default_factory=list)


@dataclass
class HloStats:
    """Per-device totals for one compiled module."""

    flops: float
    bytes: float
    collectives: dict          # type -> {"bytes": b, "count": n}
    n_while: int

    @property
    def collective_bytes(self) -> float:
        return sum(v["bytes"] for v in self.collectives.values())

    def to_json(self):
        return {"flops": self.flops, "bytes": self.bytes,
                "collectives": self.collectives,
                "collective_bytes": self.collective_bytes,
                "n_while": self.n_while}


def _split_computations(text: str):
    comps, cur, name = {}, None, None
    for line in text.splitlines():
        if cur is None:
            # computation headers start at column 0 and end with '{'
            if line[:1] not in (" ", "\t", "") and line.rstrip().endswith("{"):
                m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)", line)
                if m and "HloModule" not in line:
                    name = m.group(1)
                    cur = []
                    comps[name] = (cur, line.startswith("ENTRY"))
        else:
            if line.startswith("}") or line.strip() == "}":
                cur = None
            else:
                cur.append(line)
    return comps


def analyze_hlo(text: str) -> HloStats:
    raw = _split_computations(text)
    comps: dict[str, _Comp] = {}
    entry_name = None
    n_while = 0

    for name, (lines, is_entry) in raw.items():
        c = _Comp(name)
        symbols = {}
        if is_entry:
            entry_name = name
        for line in lines:
            m = _parse_op_line(line)
            if not m:
                continue
            res_name, type_str, opcode, rest = m
            shapes = _parse_shapes(type_str)
            symbols[res_name] = shapes
            # ---- flops: dot ops (2 * out_elems * contracted size)
            if opcode == "dot":
                out_elems = sum(_nelems(d) for _, d in shapes)
                cm = _CONTRACT_RE.search(rest)
                contract = 1
                if cm:
                    idxs = [int(x) for x in cm.group(1).split(",") if x]
                    lhs = _OPERAND_RE.search(rest)
                    if lhs and lhs.group(1) in symbols:
                        ldims = symbols[lhs.group(1)][0][1]
                        for i in idxs:
                            if i < len(ldims):
                                contract *= ldims[i]
                c.flops += 2.0 * out_elems * contract
            # ---- collectives
            if opcode in COLLECTIVES:
                ops_bytes = 0
                # operand shapes from local symbol table
                arg_str = rest.split(")", 1)[0]
                for om in _OPERAND_RE.finditer(arg_str):
                    if om.group(1) in symbols and om.group(1) != res_name:
                        ops_bytes += _nbytes(symbols[om.group(1)])
                if ops_bytes == 0:  # fall back to result size
                    ops_bytes = _nbytes(shapes)
                d = c.coll.setdefault(opcode, {"bytes": 0.0, "count": 0})
                d["bytes"] += ops_bytes
                d["count"] += 1
            # ---- HBM-ish bytes: fusion/dot/collective boundaries
            if opcode in _COUNT_BYTES_OPS:
                if opcode == "dynamic-slice":
                    # hardware reads only the slice, not the full operand
                    b = 2 * _nbytes(shapes)
                elif opcode == "dynamic-update-slice":
                    # in-place on TPU: read+write of the UPDATE region only
                    # (update operand = 2nd %ref in the arg list)
                    arg_str = rest.split(")", 1)[0]
                    refs = [om.group(1)
                            for om in _OPERAND_RE.finditer(arg_str)]
                    upd = (_nbytes(symbols[refs[1]])
                           if len(refs) > 1 and refs[1] in symbols
                           else _nbytes(shapes))
                    b = 2 * upd
                else:
                    b = _nbytes(shapes)
                    arg_str = rest.split(")", 1)[0]
                    for om in _OPERAND_RE.finditer(arg_str):
                        if om.group(1) in symbols and om.group(1) != res_name:
                            b += _nbytes(symbols[om.group(1)])
                c.bytes += b
            # ---- call edges
            if opcode == "fusion":
                cm = _CALLS_RE.search(rest)
                if cm:
                    c.edges.append(("call", cm.group(1), 1))
            elif opcode == "while":
                n_while += 1
                trip = 1
                tm = _TRIP_RE.search(rest)
                if tm:
                    trip = int(tm.group(1))
                bm = _BODY_RE.search(rest)
                if bm:
                    c.edges.append(("call", bm.group(1), trip))
                cnd = _COND_RE.search(rest)
                if cnd:
                    c.edges.append(("call", cnd.group(1), trip + 1))
            elif opcode in ("call", "reduce", "reduce-window", "scatter",
                            "select-and-scatter", "sort", "map", "all-reduce",
                            "reduce-scatter"):
                am = _APPLY_RE.search(rest)
                if am:
                    c.edges.append(("call", am.group(1), 1))
            elif opcode == "conditional":
                bm = _BRANCH_RE.search(rest)
                if bm:
                    branches = _OPERAND_RE.findall(bm.group(1))
                    for b in branches:   # upper bound: all branches
                        c.edges.append(("call", b, 1))
        comps[name] = c

    memo = {}

    def total(name):
        if name in memo:
            return memo[name]
        c = comps.get(name)
        if c is None:
            return (0.0, 0.0, {})
        memo[name] = (0.0, 0.0, {})  # cycle guard
        fl, by, co = c.flops, c.bytes, {k: dict(v) for k, v in c.coll.items()}
        for _, callee, trip in c.edges:
            cf, cb, cc = total(callee)
            fl += trip * cf
            by += trip * cb
            for k, v in cc.items():
                d = co.setdefault(k, {"bytes": 0.0, "count": 0})
                d["bytes"] += trip * v["bytes"]
                d["count"] += trip * v["count"]
        memo[name] = (fl, by, co)
        return memo[name]

    fl, by, co = total(entry_name) if entry_name else (0.0, 0.0, {})
    return HloStats(flops=fl, bytes=by, collectives=co, n_while=n_while)
