"""Parameter counting (total / active) from ParamSpec trees."""
from __future__ import annotations

import jax

from repro.sharding import ParamSpec


def _count(ps: ParamSpec) -> int:
    n = 1
    for d in ps.shape:
        n *= d
    return n


def count_params(spec_tree) -> int:
    leaves = jax.tree.leaves(
        spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec))
    return sum(_count(ps) for ps in leaves)


def count_active_params(cfg, spec_tree) -> int:
    """MoE: routed-expert tensors count at top_k/num_experts (6*N_active*D
    convention for the roofline MODEL_FLOPS)."""
    paths = jax.tree_util.tree_flatten_with_path(
        spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec))[0]
    total = 0.0
    frac = (cfg.moe.top_k / cfg.moe.num_experts) if cfg.moe else 1.0
    for path, ps in paths:
        keys = [getattr(k, "key", getattr(k, "name", "")) for k in path]
        n = _count(ps)
        if "embed" in keys or "softmax_w" in keys:
            continue  # 6ND convention: non-embedding params
        if cfg.moe and ps.axes and ps.axes[0] == "experts":
            total += n * frac  # routed expert weight (E, ...)
        else:
            total += n
    return int(total)
