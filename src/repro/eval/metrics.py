"""Recognition-quality metrics (the paper reports WER on Hub5'00; with
synthetic data the analogues are frame error rate for the CE-trained
DNN-HMM and token error rate — the same Levenshtein WER formula over
synthetic token sequences — for CTC/seq2seq models).

All metrics honor the variable-length ``lengths`` batch contract of
``repro.data.pipeline``: frames at ``t >= lengths[b]`` are padding and
are excluded from FER and from the decoded token streams.  Beam decoding
lives in ``repro.decode`` (``beam_decode`` is the drop-in beam
counterpart of :func:`greedy_ctc_decode`).
"""
from __future__ import annotations

import numpy as np


def edit_distance(ref, hyp) -> int:
    """Levenshtein distance between two sequences (the WER numerator).

    Row-sweep DP: each reference row is one vectorized numpy pass — the
    sequential insertion chain ``dp[j] = min(cand[j], dp[j-1] + 1)``
    unrolls to ``min_{i<=j} cand[i] + (j - i)``, i.e. a running minimum
    of ``cand - j`` (``np.minimum.accumulate``) plus ``j``.  Exact
    parity with the per-cell loop is locked by a test."""
    ref, hyp = np.asarray(list(ref)), np.asarray(list(hyp))
    m, n = len(ref), len(hyp)
    if m == 0 or n == 0:
        return int(m or n)
    dp = np.arange(n + 1)
    j = np.arange(n + 1)
    cand = np.empty(n + 1, dp.dtype)
    for i in range(1, m + 1):
        cand[0] = i
        np.minimum(dp[:-1] + (ref[i - 1] != hyp),    # substitution
                   dp[1:] + 1,                       # deletion
                   out=cand[1:])
        dp = np.minimum.accumulate(cand - j) + j     # insertion chain
    return int(dp[n])


def token_error_rate(refs, hyps) -> float:
    """sum(edit distances) / sum(ref lengths) — i.e. WER over tokens."""
    num = sum(edit_distance(r, h) for r, h in zip(refs, hyps))
    den = sum(max(len(r), 1) for r in refs)
    return num / den


def frame_error_rate(logits, labels, lengths=None) -> float:
    """Framewise classification error of the DNN-HMM (CE-trained) model.
    logits: (B,T,V) array-like; labels: (B,T); ``lengths`` (B,) excludes
    padded frames (t >= lengths[b]) from both numerator and denominator
    per the ``data/pipeline.py`` batch contract."""
    pred = np.asarray(logits).argmax(-1)
    labels = np.asarray(labels)
    err = pred != labels
    if lengths is None:
        return float(err.mean())
    T = labels.shape[1]
    mask = np.arange(T)[None, :] < np.asarray(lengths)[:, None]
    return float(err[mask].sum() / max(mask.sum(), 1))


def greedy_ctc_decode(logits, lengths=None, *, blank: int = 0):
    """Best-path CTC decoding: argmax per frame, merge repeats, drop
    blanks.  logits: (B,T,V); ``lengths`` (B,) truncates each row to its
    valid frames.  Returns list of int lists."""
    pred = np.asarray(logits).argmax(-1)
    out = []
    for i, row in enumerate(pred):
        if lengths is not None:
            row = row[:int(lengths[i])]
        seq, prev = [], None
        for c in row:
            c = int(c)
            if c != prev and c != blank:
                seq.append(c)
            prev = c
        out.append(seq)
    return out


def collapse_labels(labels, lengths=None, *, blank: int = 0):
    """Frame labels -> reference token sequences for TER: merge repeats,
    drop the ``blank`` class, truncate to ``lengths``.  The evaluation
    convention (docs/decoding.md): class 0 — the most frequent CD state
    under the Zipf priors of the synthetic data — plays the
    blank/silence role on both the reference and hypothesis side, so
    TER is meaningful for CE- and CTC-trained checkpoints alike."""
    labels = np.asarray(labels)
    out = []
    for i, row in enumerate(labels):
        n = int(lengths[i]) if lengths is not None else len(row)
        row = row[:n]
        if n == 0:
            out.append([])
            continue
        keep = np.ones(n, bool)
        keep[1:] = row[1:] != row[:-1]
        out.append([int(c) for c in row[keep] if c != blank])
    return out
