"""Recognition-quality metrics (the paper reports WER on Hub5'00; with
synthetic data the analogues are frame error rate for the CE-trained
DNN-HMM and token error rate — the same Levenshtein WER formula over
synthetic token sequences — for CTC/seq2seq models)."""
from __future__ import annotations

import numpy as np


def edit_distance(ref, hyp) -> int:
    """Levenshtein distance between two sequences (the WER numerator)."""
    ref, hyp = list(ref), list(hyp)
    m, n = len(ref), len(hyp)
    dp = np.arange(n + 1)
    for i in range(1, m + 1):
        prev_diag = dp[0]
        dp[0] = i
        for j in range(1, n + 1):
            cur = dp[j]
            dp[j] = min(dp[j] + 1,          # deletion
                        dp[j - 1] + 1,      # insertion
                        prev_diag + (ref[i - 1] != hyp[j - 1]))
            prev_diag = cur
    return int(dp[n])


def token_error_rate(refs, hyps) -> float:
    """sum(edit distances) / sum(ref lengths) — i.e. WER over tokens."""
    num = sum(edit_distance(r, h) for r, h in zip(refs, hyps))
    den = sum(max(len(r), 1) for r in refs)
    return num / den


def frame_error_rate(logits, labels) -> float:
    """Framewise classification error of the DNN-HMM (CE-trained) model.
    logits: (B,T,V) array-like; labels: (B,T)."""
    pred = np.asarray(logits).argmax(-1)
    labels = np.asarray(labels)
    return float((pred != labels).mean())


def greedy_ctc_decode(logits, *, blank: int = 0):
    """Best-path CTC decoding: argmax per frame, merge repeats, drop
    blanks.  logits: (B,T,V).  Returns list of int lists."""
    pred = np.asarray(logits).argmax(-1)
    out = []
    for row in pred:
        seq, prev = [], None
        for c in row:
            c = int(c)
            if c != prev and c != blank:
                seq.append(c)
            prev = c
        out.append(seq)
    return out
