from repro.eval.metrics import (  # noqa: F401
    collapse_labels,
    edit_distance,
    frame_error_rate,
    greedy_ctc_decode,
    token_error_rate,
)
