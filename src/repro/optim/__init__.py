from repro.optim.optimizers import adam, momentum, sgd, Optimizer  # noqa: F401
from repro.optim.schedules import (  # noqa: F401
    constant,
    paper_recipe,
    warmup_then_anneal,
)
