"""Minimal functional optimizers.

The paper's recipe is plain mini-batch SGD (Eq. 5) — no momentum state —
which is also what keeps per-learner replica memory at 1× params for the
decentralized strategies.  Momentum and Adam are provided for the
beyond-paper experiments.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable            # params -> opt_state
    update: Callable          # (grads, opt_state, params, lr) -> (new_params, opt_state)


def sgd() -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params, lr):
        new = jax.tree.map(
            lambda w, g: (w.astype(jnp.float32)
                          - lr * g.astype(jnp.float32)).astype(w.dtype),
            params, grads)
        return new, state

    return Optimizer("sgd", init, update)


def momentum(beta: float = 0.9, nesterov: bool = False) -> Optimizer:
    def init(params):
        return jax.tree.map(lambda w: jnp.zeros(w.shape, jnp.float32), params)

    def update(grads, state, params, lr):
        state = jax.tree.map(
            lambda m, g: beta * m + g.astype(jnp.float32), state, grads)
        if nesterov:
            step_dir = jax.tree.map(
                lambda m, g: beta * m + g.astype(jnp.float32), state, grads)
        else:
            step_dir = state
        new = jax.tree.map(
            lambda w, d: (w.astype(jnp.float32) - lr * d).astype(w.dtype),
            params, step_dir)
        return new, state

    return Optimizer("momentum", init, update)


def adam(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8) -> Optimizer:
    def init(params):
        z = lambda w: jnp.zeros(w.shape, jnp.float32)
        return {"m": jax.tree.map(z, params),
                "v": jax.tree.map(z, params),
                "t": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        t = state["t"] + 1
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(
            lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["v"], grads)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)
        new = jax.tree.map(
            lambda w, m_, v_: (w.astype(jnp.float32)
                               - lr * (m_ / bc1)
                               / (jnp.sqrt(v_ / bc2) + eps)).astype(w.dtype),
            params, m, v)
        return new, {"m": m, "v": v, "t": t}

    return Optimizer("adam", init, update)


def get_optimizer(name: str) -> Optimizer:
    return {"sgd": sgd, "momentum": momentum, "adam": adam}[name]()
