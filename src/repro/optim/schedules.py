"""Learning-rate schedules.

``paper_recipe`` reproduces §V of the paper: distributed runs start at the
single-GPU base LR (0.1) and *linearly warm up* to the large-batch LR over
the first 10 epochs, then anneal by 1/sqrt(2) every epoch — the standard
large-batch warm-up the paper credits for convergence at batch 2560-8192.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def constant(lr: float):
    return lambda step: jnp.float32(lr)


def warmup_then_anneal(base_lr: float, peak_lr: float, warmup_steps: int,
                       anneal_every: int, anneal_factor: float):
    def sched(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr + (peak_lr - base_lr) * jnp.minimum(
            step / max(warmup_steps, 1), 1.0)
        n_anneals = jnp.floor(
            jnp.maximum(step - warmup_steps, 0.0) / max(anneal_every, 1))
        return warm * jnp.power(anneal_factor, n_anneals)

    return sched


def paper_recipe(steps_per_epoch: int, base_lr: float = 0.1,
                 peak_lr: float = 1.0):
    """§V: warm up linearly from 0.1 to 1.0 over 10 epochs, then multiply by
    1/sqrt(2) each epoch."""
    return warmup_then_anneal(
        base_lr, peak_lr,
        warmup_steps=10 * steps_per_epoch,
        anneal_every=steps_per_epoch,
        anneal_factor=float(1.0 / np.sqrt(2.0)),
    )
