"""Closed-loop capacity search: bisect the max sustained QPS meeting a
p99 first-token SLO target (docs/serving.md §Capacity report).

``sustained_capacity`` replays the *same* seeded workload shape at
candidate arrival rates (``Workload.with_qps`` keeps every other knob —
seed, lengths, tiers, diurnal phase — fixed) through a real server in
virtual time, and bisects the largest rate whose run satisfies

* p99 first-token latency <= ``p99_target_s``,
* abandonment fraction   <= ``max_abandon_frac``,
* at least one completion (an empty trace is vacuously feasible).

Everything is deterministic: the trace is a pure function of
``(workload, qps)``, the loop runs on a :class:`VirtualClock`, and the
bisection itself touches only exact float midpoints — so re-running the
same seed reproduces the identical max-QPS row and latency percentiles.
One server instance is reused across probe levels via ``reset()`` so
the jitted prefill/decode executables compile once.
"""
from __future__ import annotations

import math

from repro.serving.loop import CostModel, ServingLoop, VirtualClock
from repro.serving.workload import Workload, generate_trace


def run_level(server, workload: Workload, payload_fn, *,
              cost: CostModel, preempt: bool = True):
    """One probe: reset the server, replay the workload's trace in
    virtual time, return the SLO summary dict."""
    server.reset()
    trace = generate_trace(workload)
    loop = ServingLoop(server, trace, payload_fn,
                       n_tiers=len(workload.tier_probs),
                       clock=VirtualClock(), cost=cost, preempt=preempt)
    loop.run()
    s = loop.summary()
    s["qps"] = workload.qps
    s["waves"] = loop.n_waves
    s["virtual_s"] = loop.clock.now()
    return s


def feasible(summary: dict, *, p99_target_s: float,
             max_abandon_frac: float = 0.05) -> bool:
    if summary["offered"] == 0:
        return True
    if summary["done"] == 0:
        return False
    p99 = summary["first_token"]["p99"]
    if math.isnan(p99) or p99 > p99_target_s:
        return False
    return summary["abandoned"] <= max_abandon_frac * summary["offered"]


def sustained_capacity(server, workload: Workload, payload_fn, *,
                       p99_target_s: float, qps_lo: float = 0.25,
                       qps_hi: float = 32.0, iters: int = 5,
                       cost: CostModel = None, preempt: bool = True,
                       max_abandon_frac: float = 0.05):
    """Bisect the max sustained QPS meeting the p99 first-token target.

    Returns ``(max_qps, summary_at_max)`` — ``max_qps`` is 0.0 (with the
    infeasible low-probe summary) when even ``qps_lo`` misses the SLO,
    and ``qps_hi`` when the whole bracket is feasible.
    """
    cost = cost if cost is not None else CostModel()
    probe = lambda q: run_level(server, workload.with_qps(q), payload_fn,
                                cost=cost, preempt=preempt)
    ok = lambda s: feasible(s, p99_target_s=p99_target_s,
                            max_abandon_frac=max_abandon_frac)
    s_lo = probe(qps_lo)
    if not ok(s_lo):
        return 0.0, s_lo
    s_hi = probe(qps_hi)
    if ok(s_hi):
        return qps_hi, s_hi
    lo, best = qps_lo, s_lo
    hi = qps_hi
    for _ in range(iters):
        mid = 0.5 * (lo + hi)
        s = probe(mid)
        if ok(s):
            lo, best = mid, s
        else:
            hi = mid
    return lo, best
