"""Continuous-batching admission control: typed admission results,
priority tiers, and slot preemption (docs/serving.md §Admission).

The controller is server-agnostic — it drives any object implementing
the slot-pool duck contract of ``repro.launch.serve``:

* ``submit(req, payload) -> AdmitResult`` — claim a free slot (typed
  rejection otherwise),
* ``preempt(rid) -> snapshot`` — evict a running request, returning an
  opaque snapshot that fully captures its decode state (LM: the cache
  row + position/budget; ASR: the ``BeamState`` row + posteriors),
* ``restore(snapshot) -> AdmitResult`` — resume a preempted request in
  any free slot, bit-for-bit (preempt-then-resume equals the
  uninterrupted decode — tested),
* ``emits_on_admit`` — True when admission itself produces the first
  token (LM prefill does; ASR streams its first progress on the first
  wave after admission).

**Tiers.**  Tier 0 is the highest priority.  Queued requests admit
high-tier-first, FIFO within a tier; a queued request may *preempt* a
running one of strictly lower priority when the pool is full (victim =
the lowest-priority running request, most recently admitted among
equals).  Preempted jobs re-enter at the *front* of their tier's queue
holding their snapshot, so they resume before anything newer of the
same tier.  The no-priority-inversion invariant (with preemption on):
after a ``pump``, no queued job has strictly higher priority than any
running job (``check_inversion`` — asserted over whole virtual-time
runs in tests/test_serving.py).

**Abandonment.**  A request that has never been admitted abandons the
queue once it has waited past its ``patience`` (the workload model's
user walking away).  Preempted requests already started and never
abandon.

Everything here is deterministic given the offered trace: queues are
plain FIFOs, the victim choice is a total order, and all timestamps
come from the loop's clock.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Optional

from repro.serving.slo import Recorder
from repro.serving.workload import Request

# typed admission outcomes (docs/serving.md §Admission)
OK = "ok"                          # admitted into a slot
POOL_FULL = "pool_full"            # every slot busy (retryable)
PROMPT_TOO_LONG = "prompt_too_long"  # payload exceeds the slot capacity
NO_BUDGET = "no_budget"            # nothing to decode (max_new/frames <= 0)

RETRYABLE = (POOL_FULL,)
TERMINAL = (PROMPT_TOO_LONG, NO_BUDGET)


@dataclass(frozen=True)
class AdmitResult:
    """Typed admission outcome; truthy iff admitted (so existing
    ``while pending and server.admit(...)`` loops keep working)."""

    reason: str
    slot: int = -1

    def __bool__(self) -> bool:
        return self.reason == OK


ADMITTED = AdmitResult(OK)


def prompt_capacity(max_len: int, mode: str) -> int:
    """The documented LM/ASR payload-capacity contract, hoisted from the
    two former call-site magic numbers (serve.py's clamp and the
    servers' admit validation must agree or a clamped payload is
    terminally rejected):

    * ``lm``  — a slot holds ``max_len`` cache positions but ONE is
      reserved for the first generated token the prefill emits, so the
      prompt may fill at most ``max_len - 1``.
    * ``asr`` — the whole posterior buffer is decodable: an utterance
      may fill all ``max_len`` frames (nothing is generated into the
      buffer).
    """
    if mode == "lm":
        return max_len - 1
    if mode == "asr":
        return max_len
    raise ValueError(f"unknown payload mode {mode!r}")


@dataclass(eq=False)
class Job:
    """One request's life in the controller: queued -> running
    (-> preempted -> queued -> running)* -> done, or abandoned/rejected
    before ever running."""

    req: Request
    payload: object
    state: str = "queued"    # queued|running|preempted|done|...
    snapshot: object = None  # set while preempted

    @property
    def rid(self) -> int:
        return self.req.rid

    @property
    def tier(self) -> int:
        return self.req.tier


class AdmissionController:
    """Priority-tiered admission with optional preemption over one
    slot-pool server (module docstring for semantics)."""

    def __init__(self, server, *, n_tiers: int, preempt: bool = True,
                 recorder: Optional[Recorder] = None):
        if n_tiers < 1:
            raise ValueError(f"n_tiers must be >= 1, got {n_tiers}")
        self.server = server
        self.queues = [deque() for _ in range(n_tiers)]
        self.running: dict[int, Job] = {}
        self.preempt_enabled = preempt
        self.recorder = recorder if recorder is not None else Recorder()

    # ------------------------------------------------------------- intake
    def offer(self, req: Request, payload) -> None:
        if not 0 <= req.tier < len(self.queues):
            raise ValueError(
                f"request {req.rid} tier {req.tier} outside the "
                f"{len(self.queues)}-tier controller")
        self.queues[req.tier].append(Job(req, payload))
        self.recorder.offered(req.rid, req.tier, req.arrival,
                              deadline=req.arrival + req.deadline)

    def backlog(self) -> int:
        return sum(len(q) for q in self.queues)

    # ------------------------------------------------------------ pumping
    def pump(self, now: float, advance=None) -> int:
        """Drop abandoned waiters, then admit as much of the queue as the
        pool (plus preemption) allows.  Returns the number of slots
        filled this pump (admissions + restores).  ``advance``, when
        given, is called once per successful admission and returns the
        post-admission clock — so the admit service time (prefill /
        BLSTM forward) is charged *before* the request's admission and
        first-token stamps."""
        self._abandon(now)
        n_admitted = 0
        for tier, q in enumerate(self.queues):
            while q:
                job = q[0]
                res = self._try_admit(job)
                if res:
                    q.popleft()
                    if advance is not None:
                        now = advance()
                    self._mark_running(job, now)
                    n_admitted += 1
                elif res.reason == POOL_FULL:
                    victim = self._pick_victim(tier)
                    if victim is None:
                        # nothing of lower priority runs, so neither this
                        # tier nor any lower one can make progress
                        return n_admitted
                    self._do_preempt(victim)
                else:                      # terminal typed rejection
                    q.popleft()
                    job.state = "rejected"
                    self.recorder.rejected(job.rid, now, res.reason)
        return n_admitted

    def _try_admit(self, job: Job) -> AdmitResult:
        if job.snapshot is not None:
            res = self.server.restore(job.snapshot)
            if res:
                job.snapshot = None
            return res
        return self.server.submit(job.req, job.payload)

    def _mark_running(self, job: Job, now: float) -> None:
        first = job.state == "queued"
        job.state = "running"
        self.running[job.rid] = job
        self.recorder.admitted(job.rid, now)
        if first and getattr(self.server, "emits_on_admit", False):
            self.recorder.first_token(job.rid, now)

    def _abandon(self, now: float) -> None:
        for q in self.queues:
            kept, gone = [], []
            for j in q:
                started = j.snapshot is not None or j.state == "preempted"
                if started or now - j.req.arrival <= j.req.patience:
                    kept.append(j)
                else:
                    gone.append(j)
            if gone:
                for j in gone:
                    j.state = "abandoned"
                    self.recorder.abandoned(j.rid, now)
                q.clear()
                q.extend(kept)

    def _pick_victim(self, tier: int) -> Optional[Job]:
        """Lowest-priority running job strictly below ``tier``'s
        priority; the most recently admitted breaks ties (it has the
        least sunk work).  Deterministic: dict preserves insertion
        (= admission) order."""
        if not self.preempt_enabled:
            return None
        victim = None
        for job in self.running.values():        # admission order
            if job.tier <= tier:
                continue
            if victim is None or job.tier > victim.tier:
                victim = job
            elif job.tier == victim.tier:
                victim = job                     # later admission wins
        return victim

    def _do_preempt(self, victim: Job) -> None:
        victim.snapshot = self.server.preempt(victim.rid)
        victim.state = "preempted"
        del self.running[victim.rid]
        self.queues[victim.tier].appendleft(victim)
        self.recorder.preempted(victim.rid)

    # ---------------------------------------------------------- wave side
    def on_wave(self, completed, progressed, now: float) -> None:
        """Stamp one decode wave: ``progressed`` request ids advanced
        this wave (first progress = first token for streaming servers),
        ``completed`` is ``[(rid, tokens), ...]``."""
        for rid in progressed:
            self.recorder.first_token(rid, now)
        for rid, tokens in completed:
            job = self.running.pop(rid, None)
            if job is not None:
                job.state = "done"
            self.recorder.done(rid, now, n_tokens=len(tokens))

    # --------------------------------------------------------- invariants
    def check_inversion(self):
        """Priority-inversion witnesses: (queued_tier, running_tier)
        pairs with a queued job of strictly higher priority than a
        running one.  Empty after every pump when preemption is on."""
        if not self.preempt_enabled:
            return []
        queued = [t for t, q in enumerate(self.queues) if q]
        if not queued:
            return []
        lowest_queued = min(queued)
        return [(lowest_queued, job.tier) for job in self.running.values()
                if job.tier > lowest_queued]
