"""The serving event loop: arrivals -> admission -> decode waves ->
SLO accounting, in *virtual time* by default (docs/serving.md
§Virtual time).

**Virtual-time contract.**  A :class:`VirtualClock` only moves when the
loop tells it to: each admission costs ``CostModel.admit_s`` (the
prefill / BLSTM-forward service time) and each decode wave costs
``CostModel.wave_s(work)`` (a base wave cost plus a per-token/per-frame
term).  No wall-clock sleeps ever happen, so a whole overload scenario
runs in milliseconds of real time, the timeline is a pure function of
``(trace, cost model, scheduler)``, and re-running the same seed
reproduces every timestamp exactly — which is what makes the capacity
report of ``benchmarks/run.py --only load`` reproducible row-for-row.
:class:`WallClock` swaps in for benches: ``now`` is real elapsed time,
``advance`` is a no-op (the real compute provides the delay) and idle
gaps actually sleep until the next arrival.

The loop drives any server implementing the slot-pool duck contract
(``submit`` / ``step_wave`` / ``preempt`` / ``restore`` / ``reset`` —
see ``repro.serving.admission``), with the
:class:`~repro.serving.admission.AdmissionController` deciding who
occupies slots.  One iteration: deliver due arrivals, pump admissions
(abandonment, priority, preemption), then advance every active slot one
wave and stamp first-token/completion events at the post-wave clock.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Optional

from repro.serving.admission import AdmissionController
from repro.serving.slo import Recorder, summarize


class VirtualClock:
    """Deterministic loop-driven clock (virtual seconds from 0)."""

    def __init__(self, start: float = 0.0):
        self._t = float(start)

    def now(self) -> float:
        return self._t

    def advance(self, dt: float) -> None:
        self._t += dt

    def sleep_until(self, t: float) -> None:
        self._t = max(self._t, t)


class WallClock:
    """Real elapsed time; ``advance`` is a no-op (the measured compute
    itself provides the delay), idle gaps really sleep."""

    def __init__(self):
        self._t0 = time.perf_counter()

    def now(self) -> float:
        return time.perf_counter() - self._t0

    def advance(self, dt: float) -> None:
        pass

    def sleep_until(self, t: float) -> None:
        dt = t - self.now()
        if dt > 0:
            time.sleep(dt)


@dataclass(frozen=True)
class CostModel:
    """Virtual service times (seconds) of the slot-pool operations.

    These are *nominal* constants pinned per (mode × kernel-impl) cell
    in the capacity bench — deterministic by construction; real-hardware
    truth is a ROADMAP item, wall-clock runs use :class:`WallClock`
    where the cost model is ignored.
    """

    admit_s: float = 0.020       # prefill / BLSTM forward per admission
    wave_base_s: float = 0.010   # fixed cost of one decode wave
    per_work_s: float = 0.0      # per token decoded / frame consumed

    def wave_s(self, work: int) -> float:
        return self.wave_base_s + self.per_work_s * work


class ServingLoop:
    """Drive one server through one offered trace (module docstring)."""

    def __init__(self, server, trace, payload_fn: Callable, *,
                 n_tiers: int, clock=None, cost: CostModel = None,
                 preempt: bool = True, check_inversion: bool = False,
                 max_waves: int = 200_000,
                 on_event: Optional[Callable] = None):
        self.server = server
        self.trace = sorted(trace, key=lambda r: (r.arrival, r.rid))
        self.payload_fn = payload_fn
        self.clock = clock if clock is not None else VirtualClock()
        self.cost = cost if cost is not None else CostModel()
        self.controller = AdmissionController(server, n_tiers=n_tiers,
                                              preempt=preempt)
        self.check_inversion = check_inversion
        self.max_waves = max_waves
        self.on_event = on_event
        self.n_waves = 0
        self.inversions = []

    # ---------------------------------------------------------------- run
    def run(self) -> Recorder:
        i, ctl, clock = 0, self.controller, self.clock
        while True:
            now = clock.now()
            while i < len(self.trace) and self.trace[i].arrival <= now:
                req = self.trace[i]
                ctl.offer(req, self.payload_fn(req))
                self._emit("offer", req.rid, tier=req.tier)
                i += 1
            ctl.pump(now, advance=self._admit_tick)
            if self.check_inversion:
                self.inversions += ctl.check_inversion()
            if ctl.running:
                completed, progressed, work = self.server.step_wave()
                clock.advance(self.cost.wave_s(work))
                ctl.on_wave(completed, progressed, clock.now())
                for rid, tokens in completed:
                    self._emit("done", rid, n_tokens=len(tokens))
                self.n_waves += 1
                if self.n_waves > self.max_waves:
                    raise RuntimeError(
                        f"serving loop exceeded {self.max_waves} waves")
            elif i < len(self.trace):
                clock.sleep_until(self.trace[i].arrival)
            elif ctl.backlog():
                # idle pool + non-empty queue: only queued waiters whose
                # patience has not expired can be left (the pump admits
                # otherwise); jump to the next abandonment horizon
                clock.sleep_until(min(
                    j.req.arrival + j.req.patience
                    for q in ctl.queues for j in q) + 1e-9)
            else:
                break
        return ctl.recorder

    def summary(self) -> dict:
        return summarize(self.controller.recorder,
                         n_tiers=len(self.controller.queues))

    def _admit_tick(self) -> float:
        """Charge one admission's service time; the controller stamps
        the admitted request at the returned (post-prefill) clock."""
        self.clock.advance(self.cost.admit_s)
        return self.clock.now()

    def _emit(self, kind, rid, **kw) -> None:
        if self.on_event is not None:
            self.on_event(kind, rid, self.clock.now(), kw)
