"""Per-request latency accounting and the shared stats CSV schema.

Every serving-side surface reports through the same two primitives:

* :class:`RequestEvents` — one record per offered request, holding the
  raw event timestamps (arrival, first admit, first token, final
  result) plus the preemption count and the terminal outcome.  The
  :class:`Recorder` owns the table; the admission controller and the
  serving loop stamp it as events happen.  Timestamps are whatever the
  loop's clock says — *virtual* seconds in tests/benches, wall seconds
  in ``--wall`` mode — so the same accounting code covers both.
* ``name,value,derived`` CSV rows — the schema ``benchmarks/run.py``
  and ``launch/evaluate.py`` already print; the formatting source now
  lives in :mod:`repro.obs` (:func:`repro.obs.csv_row` /
  :func:`repro.obs.print_csv_rows`); this module re-exports them as
  deprecation shims (docs/serving.md §Report schema).

The :class:`Recorder` is a *view* over the shared observability event
schema (docs/observability.md): every stamping method emits a
``request/*`` event through ``repro.obs`` (a no-op unless a launcher
enabled tracing) and applies it to the live table via the same
:func:`_apply` fold that :func:`fold_request_events` uses to rebuild a
table from a recorded JSONL — so the flight recorder and the in-memory
table can never disagree (property-tested in tests/test_obs.py).

SLO definitions (docs/serving.md §SLOs):

* **queue wait**   = first admit − arrival (admitted requests only),
* **first token**  = first emitted token/progress − arrival,
* **final result** = completion − arrival,
* percentiles use the **nearest-rank** convention: ``p_q`` of ``n``
  sorted samples is element ``ceil(q/100 · n) − 1`` — deterministic,
  no interpolation, so hand-built traces have exactly computable
  p50/p95/p99 (property-tested in tests/test_serving.py).
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from repro import obs
from repro.obs import CSV_HEADER, csv_row, print_csv_rows  # noqa: F401
# ^ moved to repro.obs (single formatting source); re-exported here as
#   deprecation shims for existing importers.

NAN = float("nan")


@dataclass
class RequestEvents:
    """Raw per-request event timestamps (clock units of the loop)."""

    rid: int
    tier: int
    arrival: float
    deadline: float = math.inf   # final-result SLO bound (accounting only)
    t_admit: float = NAN         # first admission
    t_first: float = NAN         # first token / first decode progress
    t_done: float = NAN          # final result
    n_preempt: int = 0
    n_tokens: int = 0
    outcome: str = "offered"     # offered|running|done|abandoned|rejected
    reject_reason: str = ""

    # latencies (NaN while the event has not happened)
    @property
    def queue_wait(self) -> float:
        return self.t_admit - self.arrival

    @property
    def first_token(self) -> float:
        return self.t_first - self.arrival

    @property
    def final(self) -> float:
        return self.t_done - self.arrival


def _apply(rec: "Recorder", name: str, attrs: dict) -> None:
    """Fold one ``request/*`` schema event into a recorder's table.
    The single transition function shared by the live :class:`Recorder`
    (stamping methods route through it) and the offline
    :func:`fold_request_events` rebuild — unknown rids raise KeyError,
    matching the historical stamping semantics."""
    a = attrs
    table = rec.events
    if name == "request/offered":
        table[a["rid"]] = RequestEvents(
            a["rid"], a["tier"], a["arrival"],
            deadline=a.get("deadline", math.inf))
    elif name == "request/admitted":
        ev = table[a["rid"]]
        if math.isnan(ev.t_admit):          # first admission only
            ev.t_admit = a["now"]
        ev.outcome = "running"
    elif name == "request/first_token":
        ev = table[a["rid"]]
        if math.isnan(ev.t_first):
            ev.t_first = a["now"]
    elif name == "request/preempted":
        table[a["rid"]].n_preempt += 1
        rec.n_preemptions += 1
    elif name == "request/done":
        ev = table[a["rid"]]
        ev.t_done = a["now"]
        ev.n_tokens = a.get("n_tokens", 0)
        ev.outcome = "done"
    elif name == "request/abandoned":
        ev = table[a["rid"]]
        ev.t_done = a["now"]
        ev.outcome = "abandoned"
    elif name == "request/rejected":
        ev = table[a["rid"]]
        ev.t_done = a["now"]
        ev.outcome = "rejected"
        ev.reject_reason = a["reason"]
    else:
        raise ValueError(f"unknown request event {name!r}")


class Recorder:
    """The per-request event table: a live view over the shared
    ``request/*`` event schema.  Each stamping method tees the event to
    ``repro.obs`` (free while tracing is off) and folds it into the
    table via :func:`_apply`; summarized by :func:`summarize`."""

    def __init__(self, emit: bool = True):
        self.events: dict[int, RequestEvents] = {}
        self.n_preemptions = 0
        self._emit = emit

    def _stamp(self, name, **attrs):
        if self._emit:
            obs.event(name, **attrs)
        _apply(self, name, attrs)

    def offered(self, rid, tier, arrival, deadline=math.inf):
        self._stamp("request/offered", rid=rid, tier=tier,
                    arrival=arrival, deadline=deadline)

    def admitted(self, rid, now):
        self._stamp("request/admitted", rid=rid, now=now)

    def first_token(self, rid, now):
        self._stamp("request/first_token", rid=rid, now=now)

    def preempted(self, rid):
        self._stamp("request/preempted", rid=rid)

    def done(self, rid, now, n_tokens=0):
        self._stamp("request/done", rid=rid, now=now, n_tokens=n_tokens)

    def abandoned(self, rid, now):
        self._stamp("request/abandoned", rid=rid, now=now)

    def rejected(self, rid, now, reason):
        self._stamp("request/rejected", rid=rid, now=now, reason=reason)


def fold_request_events(events) -> Recorder:
    """Rebuild a request table from recorded schema events (the
    ``request/*`` instants of a JSONL trace).  By construction
    ``fold(trace).events == live.events`` for the run that emitted the
    trace — the view-consistency property tests/test_obs.py asserts."""
    rec = Recorder(emit=False)
    for ev in events:
        name = ev.get("name", "")
        if ev.get("kind") == "event" and name.startswith("request/"):
            _apply(rec, name, ev.get("attrs", {}))
    return rec


def percentile(values, q: float) -> float:
    """Nearest-rank percentile: element ``ceil(q/100 * n) - 1`` of the
    sorted sample (q in (0, 100]); NaN on an empty sample."""
    vals = sorted(v for v in values if not math.isnan(v))
    if not vals:
        return NAN
    rank = max(int(math.ceil(q / 100.0 * len(vals))), 1)
    return vals[min(rank, len(vals)) - 1]


_QS = (50, 95, 99)


def _pcts(values):
    return {f"p{q}": percentile(values, q) for q in _QS}


def summarize(recorder: Recorder, n_tiers: int = None) -> dict:
    """Aggregate the event table into the SLO summary dict: outcome
    counts (overall and per tier), nearest-rank p50/p95/p99 of queue
    wait / first-token / final-result latency, and the deadline-miss
    fraction of completed requests."""
    evs = list(recorder.events.values())
    if n_tiers is None:
        n_tiers = max((e.tier for e in evs), default=-1) + 1
    done = [e for e in evs if e.outcome == "done"]
    admitted = [e for e in evs if not math.isnan(e.t_admit)]
    out = {
        "offered": len(evs),
        "done": len(done),
        "abandoned": sum(e.outcome == "abandoned" for e in evs),
        "rejected": sum(e.outcome == "rejected" for e in evs),
        "preemptions": recorder.n_preemptions,
        "tokens": sum(e.n_tokens for e in done),
        "queue_wait": _pcts([e.queue_wait for e in admitted]),
        "first_token": _pcts([e.first_token for e in evs]),
        "final": _pcts([e.final for e in done]),
        "deadline_miss_frac": (
            sum(e.t_done > e.deadline for e in done) / len(done)
            if done else 0.0),
        "per_tier": {},
    }
    for t in range(n_tiers):
        te = [e for e in evs if e.tier == t]
        td = [e for e in te if e.outcome == "done"]
        out["per_tier"][t] = {
            "offered": len(te),
            "done": len(td),
            "abandoned": sum(e.outcome == "abandoned" for e in te),
            "first_token_p99": percentile([e.first_token for e in te], 99),
            "final_p99": percentile([e.final for e in td], 99),
        }
    return out


def summary_rows(summary: dict, prefix: str, derived: str = ""):
    """Flatten a :func:`summarize` dict into ``(name, value, derived)``
    rows of the shared CSV schema (the capacity-report cell layout —
    docs/serving.md §Report schema)."""
    rows = [(f"{prefix}/{k}", float(summary[k]), derived)
            for k in ("offered", "done", "abandoned", "rejected",
                      "preemptions", "tokens", "deadline_miss_frac")]
    for metric in ("queue_wait", "first_token", "final"):
        for q, v in summary[metric].items():
            rows.append((f"{prefix}/{metric}_{q}", v,
                         f"{derived} ({metric} {q}, s)".strip()))
    for t, tv in summary["per_tier"].items():
        rows.append((f"{prefix}/done/tier{t}", float(tv["done"]),
                     f"of {tv['offered']} offered in tier {t}"))
        rows.append((f"{prefix}/first_token_p99/tier{t}",
                     tv["first_token_p99"], f"tier {t} first-token p99, s"))
    return rows


# NOTE: CSV_HEADER / csv_row / print_csv_rows moved to repro.obs (the
# single formatting source); imported above and re-exported for
# backward compatibility.
