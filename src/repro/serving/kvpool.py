"""Refcounted KV-cache page pool with prompt-prefix sharing and COW.

The dense LM server pins one ``(L, max_len, KV, E)`` cache row per slot,
so a 16-token request holds the same HBM as an 8192-token one.  This
module is the host-side bookkeeping that fixes that: the physical cache
becomes a fixed pool of **pages** (``page_size`` cache positions each)
and every request owns a small *page table* mapping its logical pages to
physical ones (docs/serving.md §KV paging).

Three mechanisms, all pure host-side Python (no device state — the
server owns the device page arrays and applies the copy/scatter actions
this module returns):

* **Refcounted allocation.**  ``alloc_request`` reserves
  ``ceil(total_positions / page_size)`` pages up front (eager: a request
  that admits can never OOM mid-decode).  ``free_request`` drops one
  refcount per table entry; a page returns to the free list when its
  refcount hits zero.  The free list is LIFO and deterministically
  seeded, so allocation order is reproducible.
* **Prefix sharing.**  A chained-hash trie maps ``digest(tokens[:n])``
  to the physical page holding positions ``[(n-1)//P * P, n)``.  At
  admission the pool probes the trie page by page; every hit shares the
  existing physical page (refcount += 1) instead of allocating a fresh
  one.  Digests are registered for *every* prefix length covered by an
  owned prompt page, so a shorter prompt can share the partial tail
  page of a longer identical prefix.
* **Copy-on-write.**  Before the server writes position ``pos`` it calls
  ``ensure_writable``; if the page holding ``pos`` is shared
  (refcount > 1) the pool moves the request onto a fresh page and
  returns ``(old, new)`` so the server copies the device page.  A
  shared *partial* page is guaranteed a COW page at admission time
  (``reserved`` pages), so admission is still all-or-nothing.  A sole
  owner writing into its own registered prompt page instead *trims* the
  trie so no later request can share beyond the overwritten prefix.

Safety of partial-page sharing: a sharer with prompt length ``p`` only
ever attends positions ``< pos`` with ``pos`` starting at ``p``, i.e.
entirely inside the verified-identical prefix; the original owner's
writes land at positions ``>= its own p' >= p`` and trigger COW/trim
first.  Digest collisions (blake2b-128 chained per token) are assumed
impossible, as in vLLM's block-hash sharing.

Telemetry: ``pages_in_use``, ``sharing_ratio`` (fraction of logical
pages backed by a shared physical page), ``n_cow``, ``n_shared_hits``.
``check()`` asserts the pool invariants (refcounts sum to table refs,
free + in-use partitions the pool, reservations are backed by free
pages) and is hammered by a hypothesis property test.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field

import numpy as np


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def _digest_chain(prev: bytes, token: int) -> bytes:
    """Chained 128-bit prefix digest: h_n = H(h_{n-1} || token_n)."""
    return hashlib.blake2b(
        prev + int(token).to_bytes(8, "little", signed=True),
        digest_size=16).digest()


def prefix_digests(tokens, lo: int = 0, prev: bytes = b""):
    """Digests ``h_{lo+1} .. h_{len(tokens)}`` of the token chain,
    starting from ``prev = h_lo``.  ``h_n`` covers ``tokens[:n]``."""
    out = []
    h = prev
    for t in tokens[lo:]:
        h = _digest_chain(h, t)
        out.append(h)
    return out


@dataclass
class PageAlloc:
    """Result of a successful :meth:`PagePool.alloc_request`."""

    table: list          # physical page id per logical page
    owned: list          # bool per logical page; False = trie-shared
    n_shared: int = 0    # logical pages backed by a shared physical page

    @property
    def n_pages(self) -> int:
        return len(self.table)


@dataclass
class _Request:
    prompt: tuple
    total: int           # total cache positions reserved (incl. decode)
    table: list = field(default_factory=list)
    owned: list = field(default_factory=list)
    reserved: int = 0    # free pages held back for a pending COW
    reserved_for: int = -1   # physical page the reservation is tied to


class PagePool:
    """Fixed pool of ``n_pages`` physical KV pages of ``page_size``
    positions each; see module docstring for the contract."""

    def __init__(self, n_pages: int, page_size: int, *, seed: int = 0,
                 share: bool = True):
        if n_pages <= 0 or page_size <= 0:
            raise ValueError("n_pages and page_size must be positive")
        self.n_pages = int(n_pages)
        self.page_size = int(page_size)
        self.share = bool(share)
        self.seed = int(seed)
        self.n_cow = 0
        self.n_shared_hits = 0
        self.refcount = np.zeros(self.n_pages, dtype=np.int64)
        order = np.arange(self.n_pages)
        if seed:
            order = np.random.default_rng(seed).permutation(order)
        # LIFO free list: pop() from the tail → page order[ -1 ] first.
        self._free = [int(p) for p in order[::-1]]
        self._reqs: dict[int, _Request] = {}
        # digest -> physical page;  page -> [(prefix_len, digest), ...]
        self._trie: dict[bytes, int] = {}
        self._registered: dict[int, list] = {}

    # -- telemetry ---------------------------------------------------------
    @property
    def pages_in_use(self) -> int:
        return int((self.refcount > 0).sum())

    @property
    def total_refs(self) -> int:
        return int(self.refcount.sum())

    @property
    def reserved_pages(self) -> int:
        return sum(r.reserved for r in self._reqs.values())

    @property
    def free_pages(self) -> int:
        """Pages available to *new* admissions (excludes COW reserves)."""
        return len(self._free) - self.reserved_pages

    @property
    def sharing_ratio(self) -> float:
        """Fraction of logical page references served by a shared
        physical page: ``1 - pages_in_use / total_refs`` (0 when idle)."""
        refs = self.total_refs
        return 0.0 if refs == 0 else 1.0 - self.pages_in_use / refs

    def table_of(self, rid: int):
        return list(self._reqs[rid].table)

    def owned_of(self, rid: int):
        return list(self._reqs[rid].owned)

    # -- alloc / share -----------------------------------------------------
    def pages_for(self, total_positions: int) -> int:
        """Worst-case (no-sharing) page demand of a request reserving
        ``total_positions`` cache positions."""
        return cdiv(int(total_positions), self.page_size)

    def alloc_request(self, rid: int, prompt, total_positions: int, *,
                      written_upto: int = None):
        """Reserve pages for ``total_positions`` cache positions, sharing
        prompt-prefix pages against the trie.  Returns a
        :class:`PageAlloc` or ``None`` when the pool lacks free pages
        (retryable — the typed ``pool_full``).  ``written_upto`` (restore
        path) marks positions ``[0, written_upto)`` as already holding
        data; pages containing *decode* output are never shared."""
        if rid in self._reqs:
            raise KeyError(f"rid {rid} already allocated")
        P = self.page_size
        prompt = tuple(int(t) for t in prompt)
        plen = len(prompt)
        total = int(total_positions)
        if not plen or total < plen:
            raise ValueError("need total_positions >= len(prompt) >= 1")
        pos = plen if written_upto is None else int(written_upto)
        # Only verified prompt content is shareable; a partial page that
        # already holds decode output (pos > plen) is not.
        share_upto = plen if pos <= plen else P * (plen // P)
        n_total = cdiv(total, P)

        table, owned = [], []
        shared_partial = 0
        if self.share:
            h = b""
            for j in range(n_total):
                e = min((j + 1) * P, share_upto)
                if e <= j * P:
                    break
                h = prefix_digests(prompt, lo=j * P, prev=h)[e - j*P - 1]
                hit = self._trie.get(h)
                if hit is None:
                    break
                table.append(hit)
                owned.append(False)
                if e < (j + 1) * P:      # partial page ⇒ COW guaranteed
                    shared_partial = 1
        n_shared = len(table)
        need = (n_total - n_shared) + shared_partial
        if need > self.free_pages:
            return None                   # pool_full (retryable)
        for p in table:
            self.refcount[p] += 1
        fresh = [self._free.pop() for _ in range(n_total - n_shared)]
        for p in fresh:
            self.refcount[p] = 1
            table.append(p)
            owned.append(True)
        self.n_shared_hits += n_shared
        req = _Request(prompt=prompt, total=total, table=table,
                       owned=owned, reserved=shared_partial,
                       reserved_for=table[n_shared - 1]
                       if shared_partial else -1)
        self._reqs[rid] = req
        # Register prefix digests for *owned* prompt pages so later
        # identical prefixes can share them.
        if self.share:
            for j in range(n_shared, n_total):
                e = min((j + 1) * P, share_upto)
                if e <= j * P:
                    break
                self._register(table[j], prompt, j * P, e)
        return PageAlloc(table=list(table), owned=list(owned),
                         n_shared=n_shared)

    def _register(self, page: int, prompt, lo: int, hi: int):
        prev = b""
        if lo:
            prev = prefix_digests(prompt[:lo])[-1]
        regs = self._registered.setdefault(page, [])
        for n, h in enumerate(prefix_digests(prompt[:hi], lo=lo, prev=prev),
                              start=lo + 1):
            if h not in self._trie:        # first writer wins
                self._trie[h] = page
                regs.append((n, h))

    def _unregister(self, page: int, keep_upto: int = -1):
        """Drop this page's trie entries with prefix_len > keep_upto."""
        regs = self._registered.get(page, [])
        kept = []
        for n, h in regs:
            if n <= keep_upto:
                kept.append((n, h))
            elif self._trie.get(h) == page:
                del self._trie[h]
        if kept:
            self._registered[page] = kept
        else:
            self._registered.pop(page, None)

    # -- write / COW -------------------------------------------------------
    def ensure_writable(self, rid: int, pos: int):
        """Called before the server writes cache position ``pos``.
        Returns ``(old_page, new_page)`` when a copy-on-write happened
        (the caller must copy the device page old → new), else ``None``.
        A sole owner writing inside a registered prompt page trims the
        trie so stale prefixes can no longer be shared."""
        req = self._reqs[rid]
        P = self.page_size
        pos = int(pos)
        if not (0 <= pos < req.total):
            raise IndexError(f"pos {pos} outside reserved [0, {req.total})")
        j = pos // P
        phys = req.table[j]
        if self.refcount[phys] > 1:
            # Consume a COW reservation TIED TO THIS PHYSICAL PAGE.  The
            # writer may be the page's original owner (which never
            # reserves) while a partial sharer holds the reservation —
            # any reservation on ``phys`` is interchangeable: each COW
            # drops the refcount by one, so refcount-1 pending writes
            # are covered by the refcount-1 sharer reservations.
            donor = req if (req.reserved and req.reserved_for == phys) \
                else next((r for r in self._reqs.values()
                           if r.reserved and r.reserved_for == phys),
                          None)
            if donor is not None:
                donor.reserved = 0
                donor.reserved_for = -1
            elif self.free_pages <= 0:
                raise RuntimeError("COW with no unreserved free page — "
                                   "shared partial pages must reserve one "
                                   "at admission")
            new = self._free.pop()
            self.refcount[phys] -= 1
            self.refcount[new] = 1
            req.table[j] = new
            req.owned[j] = True
            self.n_cow += 1
            return (phys, new)
        # Sole owner: an in-place write at ``pos`` invalidates every
        # registered prefix longer than ``pos`` on this page.  A now-
        # unneeded reservation (every other sharer already left or
        # COWed away) is released back to the admittable budget.
        if req.reserved and req.reserved_for == phys:
            req.reserved = 0
            req.reserved_for = -1
        self._unregister(phys, keep_upto=pos)
        return None

    # -- free --------------------------------------------------------------
    def free_request(self, rid: int):
        """Release the request's table: one refcount each; pages return
        to the free list (and leave the trie) at refcount zero."""
        req = self._reqs.pop(rid)
        for phys in req.table:
            self.refcount[phys] -= 1
            if self.refcount[phys] == 0:
                self._unregister(phys)
                self._free.append(phys)

    def reset(self):
        """Drain the pool: every request freed, free list re-seeded."""
        for rid in list(self._reqs):
            self.free_request(rid)
        assert self.pages_in_use == 0 and not self._trie
        order = np.arange(self.n_pages)
        if self.seed:
            order = np.random.default_rng(self.seed).permutation(order)
        self._free = [int(p) for p in order[::-1]]

    # -- invariants --------------------------------------------------------
    def check(self):
        """Assert pool invariants; returns self (chainable in tests)."""
        assert (self.refcount >= 0).all(), "negative refcount"
        in_use = {p for p in range(self.n_pages) if self.refcount[p] > 0}
        free = set(self._free)
        assert len(self._free) == len(free), "duplicate page in free list"
        assert not (in_use & free), "page both free and referenced"
        assert len(in_use) + len(free) == self.n_pages, "leaked page"
        refs = sum(len(r.table) for r in self._reqs.values())
        assert refs == self.total_refs, "refcounts != sum of table refs"
        assert self.reserved_pages <= len(self._free), \
            "COW reservation not backed by a free page"
        for r in self._reqs.values():
            assert r.reserved in (0, 1), "at most one COW reserve/request"
            assert not r.reserved or r.reserved_for in r.table, \
                "reservation tied to a page outside the request's table"
        for h, p in self._trie.items():
            assert self.refcount[p] > 0, "trie entry on a free page"
            assert any(hh == h for _, hh in self._registered.get(p, [])), \
                "trie entry missing from page registry"
        return self
