"""Seeded, fully deterministic load generator for the serving layer.

The traffic model mirrors what a fleet front-end sees (docs/serving.md):

* **Arrivals** — a Poisson process at ``qps``, optionally modulated by a
  diurnal cycle: the instantaneous rate is ``rate_at(w, t) = qps * (1 +
  amp * sin(2*pi*t / period))``.  Modulated arrivals are drawn by
  *thinning* a homogeneous process at the peak rate ``qps * (1 + amp)``,
  so the trace is exact for any amplitude in [0, 1).
* **Lengths** — lognormal utterance frames / prompt tokens (the same
  family ``repro.data.pipeline`` uses for the ``lengths`` batch
  contract), clipped to ``[len_min, len_max]``.
* **Tiers** — each request draws a priority tier from ``tier_probs``
  (tier 0 is the highest priority; the admission controller may preempt
  lower tiers for it).
* **Deadline + abandonment** — ``patience`` bounds how long a request
  waits in the queue before its user walks away (it abandons *unstarted*
  only); ``deadline`` is the final-result SLO used for accounting.

Everything is a pure function of ``(Workload, seed)``: the same config
produces the identical arrival/length/tier trace, which is what makes
the capacity report of ``benchmarks/run.py --only load`` reproducible
row-for-row.  Draw order is fixed (gap, thinning coin, then length and
tier for accepted arrivals) so the trace is stable under refactors that
do not change the model.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import Tuple

import numpy as np


@dataclass(frozen=True)
class Request:
    """One offered request of the trace (virtual-seconds timestamps)."""

    rid: int
    arrival: float        # virtual s from trace start
    length: int           # prompt tokens (LM) / utterance frames (ASR)
    tier: int             # 0 = highest priority
    max_new: int          # LM decode budget (ASR ignores it)
    patience: float       # abandon if not admitted within this wait
    deadline: float       # final-result SLO bound (accounting only)


@dataclass(frozen=True)
class Workload:
    """Deterministic traffic model; see the module docstring."""

    qps: float
    horizon: float                 # generate arrivals in [0, horizon)
    seed: int = 0
    tier_probs: Tuple[float, ...] = (0.25, 0.75)
    len_median: float = 12.0       # lognormal median length
    len_sigma: float = 0.5         # lognormal log-std
    len_min: int = 1
    len_max: int = 48
    diurnal_amp: float = 0.0       # 0 = homogeneous Poisson
    diurnal_period: float = 60.0   # virtual s per diurnal cycle
    patience: float = 30.0
    deadline: float = 60.0
    max_new: int = 8

    def with_qps(self, qps: float) -> "Workload":
        return replace(self, qps=qps)


def rate_at(w: Workload, t: float) -> float:
    """Instantaneous arrival rate at virtual time ``t`` (requests/s).

    Monotone in ``diurnal_amp``: increasing at phases where
    ``sin(2*pi*t/period) > 0``, decreasing where it is negative, and the
    peak/trough rates are ``qps * (1 +- amp)`` exactly.
    """
    if w.diurnal_amp == 0.0:
        return w.qps
    return w.qps * (1.0 + w.diurnal_amp
                    * math.sin(2.0 * math.pi * t / w.diurnal_period))


def generate_trace(w: Workload) -> list:
    """The full request trace as a list of :class:`Request`, sorted by
    arrival.  Same ``Workload`` (incl. seed) => identical trace."""
    if not 0.0 <= w.diurnal_amp < 1.0:
        raise ValueError(f"diurnal_amp must be in [0, 1), got {w.diurnal_amp}")
    if w.qps <= 0.0 or w.horizon <= 0.0:
        raise ValueError("qps and horizon must be positive")
    probs = np.asarray(w.tier_probs, np.float64)
    if probs.ndim != 1 or len(probs) == 0 or (probs < 0).any():
        raise ValueError(f"bad tier_probs {w.tier_probs}")
    probs = probs / probs.sum()
    cum = np.cumsum(probs)

    rng = np.random.default_rng(w.seed)
    lam_max = w.qps * (1.0 + w.diurnal_amp)
    out, t = [], 0.0
    while True:
        t += rng.exponential(1.0 / lam_max)
        if t >= w.horizon:
            break
        # thinning: keep the point with prob rate(t) / lam_max
        if rng.random() * lam_max > rate_at(w, t):
            continue
        length = int(np.clip(
            round(float(rng.lognormal(math.log(w.len_median), w.len_sigma))),
            w.len_min, w.len_max))
        tier = int(np.searchsorted(cum, rng.random(), side="right"))
        tier = min(tier, len(cum) - 1)
        out.append(Request(rid=len(out), arrival=float(t), length=length,
                           tier=tier, max_new=w.max_new,
                           patience=w.patience, deadline=w.deadline))
    return out


def make_payload(req: Request, *, mode: str, vocab: int = 0,
                 input_dim: int = 0, seed: int = 0) -> np.ndarray:
    """Deterministic request payload: LM prompt tokens or ASR features.

    Seeded per ``(seed, rid)`` so a preempted-and-resumed request and an
    uninterrupted replay of the same trace see identical bytes.
    """
    rng = np.random.default_rng((seed, req.rid))
    if mode == "lm":
        if vocab <= 0:
            raise ValueError("lm payloads need vocab > 0")
        return rng.integers(0, vocab, size=req.length).astype(np.int32)
    if mode == "asr":
        if input_dim <= 0:
            raise ValueError("asr payloads need input_dim > 0")
        return rng.normal(size=(req.length, input_dim)).astype(np.float32)
    raise ValueError(f"mode must be 'lm' or 'asr', got {mode!r}")
