"""Multi-tenant serving layer: deterministic load generation, SLO-
accounted continuous batching with priority preemption, and a
closed-loop capacity search (docs/serving.md).

The package is server-agnostic: it drives the slot-pool servers of
``repro.launch.serve`` (LM and streaming ASR) through a shared duck
contract — ``submit`` / ``step_wave`` / ``preempt`` / ``restore`` /
``reset`` — so queueing, preemption and latency accounting are written
once.  Everything runs in *virtual time* by default (no wall-clock
sleeps; reproducible in tests), with a wall-clock mode for benches.
"""
from repro.serving.admission import (NO_BUDGET, OK, POOL_FULL,   # noqa: F401
                                     PROMPT_TOO_LONG, AdmissionController,
                                     AdmitResult, Job, prompt_capacity)
from repro.serving.capacity import (run_level,                   # noqa: F401
                                    sustained_capacity)
from repro.serving.kvpool import PageAlloc, PagePool             # noqa: F401
from repro.serving.loop import (CostModel, ServingLoop,          # noqa: F401
                                VirtualClock, WallClock)
from repro.serving.slo import (Recorder, RequestEvents,          # noqa: F401
                               csv_row, percentile, print_csv_rows,
                               summarize, summary_rows)
from repro.serving.workload import (Request, Workload,           # noqa: F401
                                    generate_trace, make_payload, rate_at)
