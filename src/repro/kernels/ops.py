"""Jit'd public wrappers around the Pallas kernels.

Model code selects these with ``kernel_impl='pallas'``; on non-TPU
backends the kernels execute in interpret mode (Python evaluation of the
kernel body — correct, slow), which is how CI validates them against the
``ref.py`` oracles.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention import flash_attention
from repro.kernels.lstm_cell import blstm_sequence as _blstm_sequence
from repro.kernels.lstm_cell import \
    blstm_stack_sequence as _blstm_stack_sequence
from repro.kernels.lstm_cell import lstm_sequence as _lstm_sequence
from repro.kernels.moe_dense import moe_dense as _moe_dense
from repro.kernels.ssd_scan import ssd as _ssd


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "q_offset"))
def attention(q, k, v, *, causal: bool = True, window: int = 0,
              block_q: int = 512, block_k: int = 512, q_offset: int = 0):
    return flash_attention(q, k, v, causal=causal, window=window,
                           block_q=block_q, block_k=block_k,
                           q_offset=q_offset)


@functools.partial(jax.jit, static_argnames=("reverse", "block_b",
                                             "vmem_budget", "stash_dtype",
                                             "seq_chunk"))
def lstm_sequence(wx, wh, b, x, lengths=None, *, reverse: bool = False,
                  block_b: int = None, vmem_budget: int = None,
                  stash_dtype: str = None, seq_chunk: int = 0):
    return _lstm_sequence(wx, wh, b, x, lengths, reverse=reverse,
                          block_b=block_b, vmem_budget=vmem_budget,
                          stash_dtype=stash_dtype, seq_chunk=seq_chunk)


@functools.partial(jax.jit, static_argnames=("block_b", "vmem_budget",
                                             "stash_dtype", "seq_chunk"))
def blstm_sequence(wx_fwd, wh_fwd, b_fwd, wx_bwd, wh_bwd, b_bwd, x,
                   lengths=None, *, block_b: int = None,
                   vmem_budget: int = None, stash_dtype: str = None,
                   seq_chunk: int = 0):
    return _blstm_sequence(wx_fwd, wh_fwd, b_fwd, wx_bwd, wh_bwd, b_bwd, x,
                           lengths, block_b=block_b,
                           vmem_budget=vmem_budget,
                           stash_dtype=stash_dtype, seq_chunk=seq_chunk)


@functools.partial(jax.jit, static_argnames=("block_b", "vmem_budget",
                                             "stash_dtype", "seq_chunk"))
def blstm_stack(params, x, lengths=None, *, block_b: int = None,
                vmem_budget: int = None, stash_dtype: str = None,
                seq_chunk: int = 0):
    """Fused multi-layer BLSTM stack (see lstm_cell.blstm_stack_sequence):
    ``params`` is a tuple of per-layer (wxf, whf, bf, wxb, whb, bb)
    tuples; inference keeps inter-layer activations in VMEM, training
    falls back to the per-layer stashing custom VJP."""
    return _blstm_stack_sequence(params, x, lengths, block_b=block_b,
                                 vmem_budget=vmem_budget,
                                 stash_dtype=stash_dtype,
                                 seq_chunk=seq_chunk)


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd(x, dt, A, Bm, Cm, *, chunk: int = 256):
    return _ssd(x, dt, A, Bm, Cm, chunk=chunk)


@functools.partial(jax.jit, static_argnames=("act", "tile_t"))
def moe_dense(x, router_w, wi, wg, wo, *, act: str = "swiglu",
              tile_t: int = 1024):
    return _moe_dense(x, router_w, wi, wg, wo, act=act, tile_t=tile_t)
