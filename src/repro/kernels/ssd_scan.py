"""Pallas TPU chunked SSD (mamba-2) kernel.

TPU adaptation of the SSD algorithm [arXiv:2405.21060 §6]: the per-chunk
quadratic part is a pair of MXU matmuls over (chunk x chunk) tiles held in
VMEM; the inter-chunk state recurrence rides the sequential grid axis in a
VMEM scratch accumulator (h: heads x state x head_dim, f32), so the state
never round-trips to HBM between chunks.

  grid = (B, n_chunks)   (chunk axis sequential, state carried in scratch)
  VMEM blocks per program: x (Q,H,P), dt (Q,H), B/C (Q,H,N)

Chunk length Q=256 and P=64, N<=128 keep every matmul tile MXU-shaped
(>=128 contracting / 128-lane) for the assigned ssm/hybrid configs.

Oracle: ``repro.kernels.ref.ssd_ref`` (exact sequential recurrence);
``repro.models.ssm.ssd_chunked`` is the pure-jnp chunked equivalent.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, hlast_ref,
                h_ref):
    """One (batch, chunk) program.

    x_ref: (Q,H,P), dt_ref: (Q,H), a_ref: (H,), b_ref/c_ref: (Q,H,N)
    y_ref: (Q,H,P) out; hlast_ref: (H,N,P) out (final state);
    h_ref: (H,N,P) f32 scratch carrying the running state across chunks.
    """
    chunk_idx = pl.program_id(1)
    n_chunks = pl.num_programs(1)

    @pl.when(chunk_idx == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[...].astype(jnp.float32)           # (Q,H,P)
    dt = dt_ref[...].astype(jnp.float32)         # (Q,H)
    A = a_ref[...].astype(jnp.float32)           # (H,)
    Bm = b_ref[...].astype(jnp.float32)          # (Q,H,N)
    Cm = c_ref[...].astype(jnp.float32)          # (Q,H,N)

    Q = x.shape[0]
    dtA = dt * A[None, :]                        # (Q,H), negative
    cum = jnp.cumsum(dtA, axis=0)                # (Q,H)

    # ---- intra-chunk quadratic part (MXU): scores (H,Q,K)
    scores = jax.lax.dot_general(
        jnp.moveaxis(Cm, 1, 0), jnp.moveaxis(Bm, 1, 0),
        (((2,), (2,)), ((0,), (0,))), preferred_element_type=jnp.float32)
    diff = cum[:, None, :] - cum[None, :, :]                  # (Q,K,H)
    mask = (jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
            >= jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1))
    decay = jnp.exp(jnp.where(mask[:, :, None], diff, -1e30))  # overflow-safe
    w = scores * jnp.moveaxis(decay, 2, 0)
    wdt = w * jnp.moveaxis(dt, 1, 0)[:, None, :]              # (H,Q,K)*dt_k
    y = jax.lax.dot_general(
        wdt, jnp.moveaxis(x, 1, 0),
        (((2,), (1,)), ((0,), (0,))), preferred_element_type=jnp.float32)
    y = jnp.moveaxis(y, 0, 1)                                  # (Q,H,P)

    # ---- inter-chunk: contribution of the carried state
    h = h_ref[...]                                             # (H,N,P)
    out_decay = jnp.exp(cum)                                   # (Q,H)
    y_inter = jax.lax.dot_general(
        jnp.moveaxis(Cm, 1, 0), h,
        (((2,), (1,)), ((0,), (0,))), preferred_element_type=jnp.float32)
    y = y + jnp.moveaxis(y_inter, 0, 1) * out_decay[:, :, None]

    # ---- state update
    last = cum[-1:, :]                                         # (1,H)
    in_decay = jnp.exp(last - cum) * dt                        # (Q,H)
    S_c = jax.lax.dot_general(
        jnp.moveaxis(Bm * in_decay[:, :, None], 1, 0),
        jnp.moveaxis(x, 1, 0),
        (((1,), (1,)), ((0,), (0,))), preferred_element_type=jnp.float32)
    h_ref[...] = jnp.exp(last[0])[:, None, None] * h + S_c

    y_ref[...] = y.astype(y_ref.dtype)

    @pl.when(chunk_idx == n_chunks - 1)
    def _emit_state():
        hlast_ref[...] = h_ref[...].astype(hlast_ref.dtype)


def ssd(x, dt, A, Bm, Cm, *, chunk: int = 256, interpret: bool = None):
    """x: (B,S,H,P), dt: (B,S,H) f32, A: (H,), Bm/Cm: (B,S,H,N).
    Returns (y (B,S,H,P), final_state (B,H,N,P) f32)."""
    B, S, H, Pd = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    grid = (B, S // Q)
    y, hlast = pl.pallas_call(
        _ssd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, Q, H, Pd), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((None, Q, H), lambda b, c: (b, c, 0)),
            pl.BlockSpec((H,), lambda b, c: (0,)),
            pl.BlockSpec((None, Q, H, N), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((None, Q, H, N), lambda b, c: (b, c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, Q, H, Pd), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((None, H, N, Pd), lambda b, c: (b, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, Pd), x.dtype),
            jax.ShapeDtypeStruct((B, H, N, Pd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((H, N, Pd), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, Bm, Cm)
    return y, hlast
