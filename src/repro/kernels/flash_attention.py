"""Pallas TPU flash attention (causal / sliding-window GQA).

TPU adaptation of the memory-bound hot-spot the §Roofline analysis flags in
every attention-bearing architecture: the pure-JAX path materializes the
(S x S) score tensor per q-chunk in HBM; this kernel keeps the running
softmax statistics in VMEM and never writes probabilities back.

Grid: (batch, kv_group, q_blocks).  Each program owns one q block of
``block_q`` rows for one (batch, kv-head-group) and streams kv blocks of
``block_k`` through VMEM with the standard online-softmax recurrence
(m: running max, l: running normalizer, acc: f32 accumulator).

Blocks are MXU-aligned (block_q x head_dim and block_k x head_dim tiles,
multiples of 128 on the contracting dim where head_dim allows).  The
kv loop is ``lax.fori_loop`` over kv blocks with a causal upper bound —
blocks fully above the diagonal (or fully outside the sliding window) are
skipped, which is where the sub-quadratic win for windowed layers comes
from.

Validated in interpret mode against ``repro.kernels.ref.attention_ref``
(tests/test_kernels.py sweeps shapes, dtypes, windows, GQA ratios).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, seq_k: int,
                 causal: bool, window: int, q_offset: int, scale: float):
    """One (batch, group, q-block) program.

    q_ref: (block_q, heads_per_group, head_dim) VMEM
    k_ref/v_ref: (seq_k, head_dim) VMEM (one kv head)
    o_ref: (block_q, heads_per_group, head_dim)
    """
    block_q, m_per_g, head_dim = q_ref.shape
    q_block_idx = pl.program_id(2)
    q_start = q_block_idx * block_q + q_offset

    q = q_ref[...].astype(jnp.float32).reshape(block_q * m_per_g, head_dim)

    n_kv = seq_k // block_k
    if causal:
        # last kv block that intersects [q_start, q_start+block_q)
        hi = jnp.minimum((q_start + block_q - 1) // block_k + 1, n_kv)
    else:
        hi = n_kv
    if causal and window > 0:
        lo = jnp.maximum(q_start - window + 1, 0) // block_k
    else:
        lo = 0

    def body(kb, carry):
        acc, m_i, l_i = carry
        k = pl.load(k_ref, (pl.dslice(kb * block_k, block_k),
                            slice(None))).astype(jnp.float32)
        v = pl.load(v_ref, (pl.dslice(kb * block_k, block_k),
                            slice(None))).astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale      # (bq*m, bk)
        if causal:
            q_pos = q_start + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, m_per_g), 0).reshape(block_q * m_per_g)
            k_pos = kb * block_k + jax.lax.iota(jnp.int32, block_k)
            ok = q_pos[:, None] >= k_pos[None, :]
            if window > 0:
                ok &= (q_pos[:, None] - k_pos[None, :]) < window
            s = jnp.where(ok, s, NEG_INF)
        m_new = jnp.maximum(m_i, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_i - m_new)
        l_new = alpha * l_i + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return acc, m_new, l_new

    acc0 = jnp.zeros((block_q * m_per_g, head_dim), jnp.float32)
    m0 = jnp.full((block_q * m_per_g,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q * m_per_g,), jnp.float32)
    acc, m_i, l_i = jax.lax.fori_loop(lo, hi, body, (acc0, m0, l0))
    out = acc / jnp.maximum(l_i, 1e-30)[:, None]
    o_ref[...] = out.reshape(block_q, m_per_g, head_dim).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 512, block_k: int = 512,
                    q_offset: int = 0, interpret: bool = None):
    """q: (B, Sq, H, E); k/v: (B, Sk, KV, E) -> (B, Sq, H, E).

    GQA: each kv head serves H//KV query heads; grid axis 1 walks kv heads
    and the q block carries its group's query heads together (better MXU
    utilization than one head at a time when H//KV > 1).
    """
    B, Sq, H, E = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    M = H // KV
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, block_q, Sk, block_k)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    qg = q.reshape(B, Sq, KV, M, E)
    grid = (B, KV, Sq // block_q)
    kernel = functools.partial(
        _attn_kernel, block_k=block_k, seq_k=Sk, causal=causal,
        window=int(window), q_offset=int(q_offset),
        scale=float(1.0 / np.sqrt(E)))

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, None, M, E),
                         lambda b, g, i: (b, i, g, 0, 0)),
            pl.BlockSpec((None, Sk, None, E), lambda b, g, i: (b, 0, g, 0)),
            pl.BlockSpec((None, Sk, None, E), lambda b, g, i: (b, 0, g, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, None, M, E),
                               lambda b, g, i: (b, i, g, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sq, KV, M, E), q.dtype),
        interpret=interpret,
    )(qg, k, v)
    return out.reshape(B, Sq, H, E)
