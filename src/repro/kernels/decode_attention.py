"""Pallas TPU decode-shaped attention — the serving hot path.

The per-token decode step is the inverse of the flash kernel's regime:
q is a single row per head while the KV cache is (B, S, KV, E) with S in
the thousands, so the step is HBM-bound on the cache read and the only
job of a kernel is to stream that read once at full bandwidth.  The
layout keeps the tiny (M, E) q block and the f32 online-softmax carry
(acc (M, E), m/l (M, 1)) resident in VMEM while the cache walks through
in ``block_s`` tiles on the inner sequential grid axis:

  grid = (B, KV, S // block_s);  VMEM per program:
      q (M, E), k/v tiles 2 * (block_s, E), out (M, E)
      + f32 scratch acc (M, E) + m, l (M, 1).

S-tile count never changes the resident set, so arbitrarily long caches
stream through a fixed VMEM budget (``auto_block_s_decode`` picks the
largest power-of-two tile that fits; ``decode_attn_vmem_bytes`` is the
single source of the accounting, quoted in docs/kernels.md).

GQA grouping mirrors ``repro.models.attention.attn_decode``: the H query
heads are reshaped to (KV, M = H // KV) groups so each grid point serves
one kv-head's M queries against one cache stripe — the cache tile is
read once for all M queries of its group.

Masking matches the jax reference exactly: position t is attended iff
``t <= pos`` (canonical) or ``t < pos`` (delta variant, old cache only)
and ``pos - t < window``.  Both ``pos`` and ``window`` are TRACED
scalars — the per-layer window rides through the layer scan as data
(models/attention.py module docstring) — so they enter the kernel as
(1, 1) SMEM blocks, never as static params.  Tiles entirely above
``pos`` are skipped; the ragged last tile is handled by masking scores
at ``t >= S`` AND zeroing out-of-bounds v rows (the block is padded with
garbage that may be non-finite, and 0 * nan = nan would otherwise leak
through the p @ v product).

The ``delta`` variant fuses ``attn_decode_delta``: the new token's K/V
column is folded into the online-softmax INIT (m = s_new, l = 1,
acc = v_new) before the cache streams through, so the concat-and-resoftmax
of the jax path disappears and the cache is still read exactly once.

Numerics: scores, softmax and the accumulator are f32 regardless of
cache dtype (matching the jax path's f32 softmax); the output is cast
back to q.dtype.  Parity with ``attn_decode``/``attn_decode_delta`` is
~1e-7 normalized in f32 (tests/test_decode_attention.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.lstm_cell import DEFAULT_VMEM_BUDGET, _resolve_interpret

NEG_INF = -1e30
_NO_WINDOW = 2 ** 30  # window >= S is full attention (cf. GLOBAL_WINDOW)


def decode_attn_vmem_bytes(block_s: int, M: int, E: int,
                           itemsize: int = 4) -> int:
    """Resident VMEM bytes per grid program — independent of S."""
    qo = 2 * M * E * itemsize              # q block + out block
    kv = 2 * 2 * block_s * E * itemsize    # k + v tiles, double-buffered
    carry = (M * E + 2 * M) * 4            # f32 acc + m + l scratch
    return qo + kv + carry


def paged_attn_vmem_bytes(page_size: int, M: int, E: int, table_elems: int,
                          itemsize: int = 4) -> int:
    """Paged-mode resident bytes per grid program: the dense accounting
    at ``block_s = page_size`` plus the scalar-prefetched page table and
    (pos, window) meta in SMEM (``table_elems = B * table_width`` i32)."""
    return (decode_attn_vmem_bytes(page_size, M, E, itemsize)
            + 4 * (table_elems + 2))


def auto_block_s_decode(S: int, M: int, E: int, itemsize: int = 4,
                        vmem_budget=None, page_size: int = None) -> int:
    """Largest power-of-two S-tile (<= S, >= 8) within the VMEM budget.

    With ``page_size`` set (paged cache) the tile is PINNED to one page —
    the physical pages are not contiguous so a tile cannot span them —
    and this only validates that a page-sized tile fits the budget."""
    budget = vmem_budget or DEFAULT_VMEM_BUDGET
    if page_size is not None:
        if decode_attn_vmem_bytes(page_size, M, E, itemsize) > budget:
            raise ValueError(
                f"page_size={page_size} tile exceeds the VMEM budget "
                f"({decode_attn_vmem_bytes(page_size, M, E, itemsize)} "
                f"> {budget}); shrink the page")
        return int(page_size)
    bs = min(512, 1 << max(int(S) - 1, 0).bit_length())
    while bs > 8 and decode_attn_vmem_bytes(bs, M, E, itemsize) > budget:
        bs //= 2
    return max(8, min(bs, S))


def _attend_tile(pos, win, q_ref, k_ref, v_ref, o_ref,
                 acc_ref, m_ref, l_ref, *, block_s, seq_len, n_tiles,
                 scale, delta, kn_ref=None, vn_ref=None):
    """One grid step of the online-softmax walk — shared verbatim by the
    dense and paged kernels (``pos``/``win`` arrive as traced scalars;
    only the BlockSpec index maps differ), so contiguous-page paged
    output is bit-exact vs dense at ``block_s == page_size``."""
    s_idx = pl.program_id(2)
    q = q_ref[...].astype(jnp.float32)                       # (M, E)
    M, E = q.shape

    @pl.when(s_idx == 0)
    def _init():
        if delta:
            # fold the new-token column into the carry: p_new = 1 at init
            k1 = kn_ref[...].astype(jnp.float32)             # (1, E)
            v1 = vn_ref[...].astype(jnp.float32)
            s_new = jax.lax.dot_general(
                q, k1, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32) * scale  # (M, 1)
            m_ref[...] = s_new
            l_ref[...] = jnp.ones((M, 1), jnp.float32)
            acc_ref[...] = jnp.broadcast_to(v1, (M, E))
        else:
            m_ref[...] = jnp.full((M, 1), NEG_INF, jnp.float32)
            l_ref[...] = jnp.zeros((M, 1), jnp.float32)
            acc_ref[...] = jnp.zeros((M, E), jnp.float32)

    @pl.when(s_idx * block_s <= pos)  # tiles above pos contribute nothing
    def _tile():
        k = k_ref[...].astype(jnp.float32)                   # (block_s, E)
        v = v_ref[...].astype(jnp.float32)
        t = s_idx * block_s + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_s), 1)
        # ragged tail: garbage rows may be non-finite and 0 * nan = nan,
        # so v must be zeroed — masking the scores alone is not enough
        v = jnp.where(t.reshape(block_s, 1) < seq_len, v, 0.0)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale      # (M, block_s)
        ok = (t < pos + (0 if delta else 1)) & (pos - t < win) \
            & (t < seq_len)
        s = jnp.where(ok, s, NEG_INF)
        m_i, l_i, acc = m_ref[...], l_ref[...], acc_ref[...]
        m_new = jnp.maximum(m_i, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_i - m_new)
        l_ref[...] = alpha * l_i + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(s_idx == n_tiles - 1)
    def _flush():
        o_ref[...] = (acc_ref[...]
                      / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def _decode_kernel(pos_ref, win_ref, q_ref, k_ref, v_ref, o_ref,
                   acc_ref, m_ref, l_ref, *, block_s, seq_len, n_tiles,
                   scale, delta, kn_ref=None, vn_ref=None):
    _attend_tile(pos_ref[0, 0], win_ref[0, 0], q_ref, k_ref, v_ref, o_ref,
                 acc_ref, m_ref, l_ref, block_s=block_s, seq_len=seq_len,
                 n_tiles=n_tiles, scale=scale, delta=delta,
                 kn_ref=kn_ref, vn_ref=vn_ref)


def decode_attention(q, k_cache, v_cache, pos, *, window=None, k_new=None,
                     v_new=None, block_s=None, vmem_budget=None,
                     interpret=None):
    """Pallas decode attention.  q (B, 1, H, E) vs cache (B, S, KV, E).

    ``k_new``/``v_new`` None selects the canonical mask (t <= pos; cache
    already holds the new token — ``attn_decode``); passing both (B, 1,
    KV, E) selects the fused delta variant (old cache strictly t < pos
    plus the new column — ``attn_decode_delta``).  ``pos`` and ``window``
    may be traced scalars; window None means full attention.
    """
    B, _, H, E = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    M = H // KV
    delta = k_new is not None
    interpret = _resolve_interpret(interpret)
    if block_s is None:
        block_s = auto_block_s_decode(S, M, E, k_cache.dtype.itemsize,
                                      vmem_budget)
    block_s = max(1, min(block_s, S))
    n_tiles = pl.cdiv(S, block_s)
    pos_arr = jnp.asarray(pos, jnp.int32).reshape(1, 1)
    win_arr = jnp.asarray(_NO_WINDOW if window is None else window,
                          jnp.int32).reshape(1, 1)
    qg = q.reshape(B, KV, M, E)
    smem = pl.BlockSpec((1, 1), lambda b, g, s: (0, 0),
                        memory_space=pltpu.SMEM)
    cache_spec = pl.BlockSpec((None, block_s, None, E),
                              lambda b, g, s: (b, s, g, 0))
    q_spec = pl.BlockSpec((None, None, M, E), lambda b, g, s: (b, g, 0, 0))
    in_specs = [smem, smem, q_spec, cache_spec, cache_spec]
    args = [pos_arr, win_arr, qg, k_cache, v_cache]
    kern = functools.partial(
        _decode_kernel, block_s=block_s, seq_len=S, n_tiles=n_tiles,
        scale=float(1.0 / np.sqrt(E)), delta=delta)
    if delta:
        new_spec = pl.BlockSpec((None, 1, None, E),
                                lambda b, g, s: (b, 0, g, 0))
        in_specs += [new_spec, new_spec]
        args += [k_new, v_new]

        def body(pos_ref, win_ref, q_ref, k_ref, v_ref, kn_ref, vn_ref,
                 o_ref, acc_ref, m_ref, l_ref):
            kern(pos_ref, win_ref, q_ref, k_ref, v_ref, o_ref,
                 acc_ref, m_ref, l_ref, kn_ref=kn_ref, vn_ref=vn_ref)
    else:
        body = kern
    out = pl.pallas_call(
        body,
        grid=(B, KV, n_tiles),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((None, None, M, E),
                               lambda b, g, s: (b, g, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, M, E), q.dtype),
        scratch_shapes=[pltpu.VMEM((M, E), jnp.float32),
                        pltpu.VMEM((M, 1), jnp.float32),
                        pltpu.VMEM((M, 1), jnp.float32)],
        interpret=interpret,
    )(*args)
    return out.reshape(B, 1, H, E)


def paged_decode_attention(q, k_pages, v_pages, page_table, pos, *,
                           window=None, k_new=None, v_new=None,
                           vmem_budget=None, interpret=None):
    """Paged decode attention: q (B, 1, H, E) vs a page pool
    (n_pages, P, KV, E) walked through ``page_table`` (B, W) i32.

    The grid's inner axis is the LOGICAL page index s; the page table is
    scalar-prefetched (SMEM) so the k/v BlockSpec index maps resolve
    ``table[b, s]`` to a physical page before the DMA issues — the tile
    is pinned to one page (``block_s = P``), everything else (online-
    softmax carry, GQA grouping, windowing, the fused ``k_new``/``v_new``
    delta init, masking at ``t <= pos`` with t = s·P + i) is the dense
    kernel's ``_attend_tile`` unchanged.  Table rows may be padded with
    any valid physical page id beyond the request's allocated pages —
    those tiles start above ``pos`` and are skipped.
    """
    B, _, H, E = q.shape
    n_pages, P, KV = k_pages.shape[0], k_pages.shape[1], k_pages.shape[2]
    W = page_table.shape[-1]
    M = H // KV
    S = W * P                               # logical sequence length
    delta = k_new is not None
    interpret = _resolve_interpret(interpret)
    auto_block_s_decode(S, M, E, k_pages.dtype.itemsize, vmem_budget,
                        page_size=P)        # budget check only
    meta = jnp.stack([jnp.asarray(pos, jnp.int32).reshape(()),
                      jnp.asarray(_NO_WINDOW if window is None else window,
                                  jnp.int32).reshape(())])
    tbl = jnp.asarray(page_table, jnp.int32).reshape(B, W)
    qg = q.reshape(B, KV, M, E)
    page_spec = pl.BlockSpec((None, P, None, E),
                             lambda b, g, s, meta_ref, tbl_ref:
                             (tbl_ref[b, s], 0, g, 0))
    q_spec = pl.BlockSpec((None, None, M, E),
                          lambda b, g, s, meta_ref, tbl_ref: (b, g, 0, 0))
    in_specs = [q_spec, page_spec, page_spec]
    args = [qg, k_pages, v_pages]
    kern = functools.partial(
        _paged_kernel, seq_len=S, n_tiles=W,
        scale=float(1.0 / np.sqrt(E)), delta=delta)
    if delta:
        new_spec = pl.BlockSpec((None, 1, None, E),
                                lambda b, g, s, meta_ref, tbl_ref:
                                (b, 0, g, 0))
        in_specs += [new_spec, new_spec]
        args += [k_new, v_new]

        def body(meta_ref, tbl_ref, q_ref, k_ref, v_ref, kn_ref, vn_ref,
                 o_ref, acc_ref, m_ref, l_ref):
            kern(meta_ref, tbl_ref, q_ref, k_ref, v_ref, o_ref,
                 acc_ref, m_ref, l_ref, kn_ref=kn_ref, vn_ref=vn_ref)
    else:
        body = kern
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KV, W),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((None, None, M, E),
                               lambda b, g, s, meta_ref, tbl_ref:
                               (b, g, 0, 0)),
        scratch_shapes=[pltpu.VMEM((M, E), jnp.float32),
                        pltpu.VMEM((M, 1), jnp.float32),
                        pltpu.VMEM((M, 1), jnp.float32)])
    out = pl.pallas_call(
        body,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, M, E), q.dtype),
        interpret=interpret,
    )(meta, tbl, *args)
    return out.reshape(B, 1, H, E)


def _paged_kernel(meta_ref, tbl_ref, q_ref, k_ref, v_ref, o_ref,
                  acc_ref, m_ref, l_ref, *, seq_len, n_tiles, scale,
                  delta, kn_ref=None, vn_ref=None):
    # tbl_ref is consumed by the BlockSpec index maps; the tile math
    # sees logical positions only.
    block_s = k_ref.shape[0]                # one page per tile
    _attend_tile(meta_ref[0], meta_ref[1], q_ref, k_ref, v_ref, o_ref,
                 acc_ref, m_ref, l_ref, block_s=block_s, seq_len=seq_len,
                 n_tiles=n_tiles, scale=scale, delta=delta,
                 kn_ref=kn_ref, vn_ref=vn_ref)
