"""Pallas TPU fused (B)LSTM sequence kernels — the training hot path.

The paper's acoustic model spends essentially all of its compute in 6
bi-LSTM layers (Table I: 165MB model, 0.07 s/batch); every distributed
strategy in §IV only pays off if this per-learner step is fast.  A
time-step of LSTM is two skinny matmuls plus elementwise gates —
dominated by weight re-reads from HBM if each step round-trips.  The TPU
adaptation keeps the weight matrices and the recurrent (h, c) state
resident in VMEM across the whole unroll and walks time on the inner
sequential grid axis, so HBM traffic per step is just x_t in / h_t out:

  grid = (B//bB, T);  VMEM blocks per direction:
      x_t (bB, D), Wx (D, 4H), Wh (H, 4H), b (4H,); scratch h, c (bB, H).

The batch axis is tiled with ``block_b`` (``bB``): the time axis is the
*inner* (fastest-varying) grid axis so each batch tile walks the whole
recurrence with its own resident (h, c) carry before the grid moves to
the next tile — an outer-batch grid would need every tile's state live
at once and defeat the tiling.  Batches that are not a multiple of
``block_b`` are zero-padded up front and sliced after; padded rows never
pollute weight gradients because their output cotangents are zero.

Gate layout (i|f|g|o) matches ``repro.models.lstm.lstm_cell_step``, which
is the oracle via ``repro.kernels.ref.lstm_ref`` (forget-gate bias +1).

Variable-length masking (``lengths``)
-------------------------------------
Passing a per-row ``lengths`` (B,) int32 vector (the batch contract of
``repro.data.pipeline``) selects the masked kernels: a (bb,) lengths
block rides along the batch grid axis, and on padded steps
(time >= lengths[row]) the (h, c) VMEM carry is FROZEN and the emitted
h_t is zero, so padded frames can never leak into weight gradients.  The
reverse direction thereby reverses *within* each utterance's valid span:
its leading invalid segment (right-padding) carries the zero initial
state untouched until the last valid frame.  The backward kernel mirrors
this — on invalid steps dgates are zeroed and the (dh, dc) carries pass
through unchanged.  Rows added by batch-tile padding get length 0, which
subsumes the zero-cotangent argument above.  Oracle:
``repro.kernels.ref.lstm_ref(..., lengths=...)`` (masked scan).

Three kernel variants share one body (``_make_fwd_kernel``):

* inference forward (``stash=False``) — emits h_t only;
* training forward (``stash=True``) — additionally stashes the
  post-activation gates (bB, 4H) and cell states (bB, H) per step, f32;
* bidirectional fusion (``n_dir=2``) — both directions advance in one
  grid pass (forward direction at time t, reverse direction at T-1-t),
  with both weight sets resident in VMEM and x handed to the kernel
  once; per-direction math is op-for-op identical to the ``n_dir=1``
  kernel, so the fused output is bit-identical to two separate calls.

Backward pass (``_make_bwd_kernel``)
------------------------------------
Wired via ``jax.custom_vjp`` so ``jax.value_and_grad`` through
``models/lstm.loss_train(kernel_impl="pallas")`` works end-to-end.  The
backward kernel walks the time grid in *reverse recurrence order*,
carrying (dh, dc) in VMEM scratch and accumulating dWx (D, 4H),
dWh (H, 4H) and db (4H,) in f32 VMEM-resident output blocks (constant
index maps — the block is zeroed at the first grid program and flushed
once at the end), while emitting dx_t per step.  h_{t-1} is re-read from
the stashed forward output y (the value that actually entered the
recurrent matmul, post bf16 rounding), c_{t-1}/c_t from the stashed cell
states, and the gate nonlinearities come from the stashed activations —
only tanh(c_t) is recomputed.

Residual stashing vs recompute
------------------------------
We stash post-activation gates + cell states, by default in f32:
4H + H = 5H floats per (row, step) — for the paper shape
(B=256, T=21, H=512) that is 256*21*5*512*4B ≈ 55MB HBM per direction,
written once in the forward and read once in the backward.
``stash_dtype="bfloat16"`` halves that stash (gates are in [-1, 1] so
bf16's 8 relative bits cost ~1e-2 normalized grad error — the relaxed
tolerance of the parity test); the backward upcasts to f32 on read and
its dW accumulators stay f32 either way.  The
alternative — recomputing gates in the backward — saves that HBM
traffic but re-runs both matmuls (2/3 of the step FLOPs) and still has
to stash or recompute the cell-state sequence for df/dc; on TPU the
matmul units are the scarce resource for this skinny shape, so we trade
HBM capacity for MXU time (same choice cuDNN makes) *at the paper's
T=21*.  For long utterances that trade flips — see next section.

Sequence-chunked recompute (``seq_chunk``)
------------------------------------------
Conversational utterances run to thousands of frames; an O(T) residual
stash caps sequence length well below that operating point.  With
``seq_chunk=K`` (> 0, frames per chunk; -1 lets :func:`auto_tile` pick
``(block_b, K)`` jointly from the VMEM budget) the training forward
stashes only the (h, c) carries at each chunk *entry* — 2H floats per
(row, chunk) instead of 5H per (row, step), an O(T) -> O(T/K)
reduction — and the backward kernel walks a ``(B//bB, T/K)`` grid in
reverse chunk order: each grid step re-runs the forward for its K-frame
chunk entirely in VMEM (rebuilding the gate/cell residuals in scratch),
then runs the K reverse-recurrence steps against them, carrying
(dh, dc) across chunks in scratch exactly like the per-step kernel.
Cost: one extra forward pass worth of matmuls, independent of K; K only
trades VMEM (the chunk residual scratch is ``bB*K*6H`` f32) against the
boundary-stash size.  T that doesn't divide by K is zero-padded to the
next multiple and the padded steps masked off via a synthesized
``lengths`` vector, so the chunked path always runs the masked kernels
(lengths = T everywhere reproduces the dense recurrence exactly).
:func:`stash_bytes` is the accounting single-source (benchmarks and the
stash-size tests read it).

Fused multi-layer stack (``blstm_stack_sequence``)
--------------------------------------------------
The stacked BLSTM's inter-layer h traffic round-trips HBM once per
layer.  :func:`blstm_stack_sequence` runs the whole L-layer stack as ONE
kernel on a ``(B//bB, L, T)`` grid: layer l writes its (bB, T, 2H)
output into a VMEM ping-pong buffer that layer l+1 reads directly, so
only layer 0's input and layer L-1's output touch HBM.  (A *streaming*
cross-layer fusion is impossible for bidirectional layers — layer l+1
at time 0 needs layer l's reverse output at time 0, computed at the
last grid step — hence the buffer holds the full T.)  Per-direction
math is op-for-op the single-layer kernel, so the fused stack is
bit-identical to the per-layer loop.  Under ``jax.vjp`` the custom-VJP
rules fall back to the per-layer stashing forwards/backwards (each
layer's output is a residual the backward needs anyway), composing with
``seq_chunk`` and ``lengths``; the fused kernel serves the primal
(inference) call.  See docs/kernels.md for the full contracts.

VMEM budget and ``block_b`` auto-tuning
---------------------------------------
``auto_block_b`` picks the largest power-of-two batch tile whose
resident set fits ``vmem_budget`` (default 12MB of a 16MB v5e core),
estimating the worse of the two training kernels:

  stashing fwd:  n_dir * (D*4H + H*4H + 4H) * itemsize   (weights)
                 + 2 * n_dir * bB * (D + H) * itemsize   (x/y streams)
                 + n_dir * 2 * bB * H * 4                (h, c carries)
                 + 2 * n_dir * bB * 5H * 4               (stash blocks)
  backward (one direction at a time):
                 (D*4H + H*4H + 4H) * (itemsize + 4)     (weights +
                                                          f32 dW accum)
                 + streamed dy/stash/x/dx blocks + (dh, dc) carries

For the paper shape (D=260, H=512, bf16) one direction's weights plus
its f32 gradient accumulators already cost ~9.5MB, so training at
B=256 auto-tiles to bB=64 at the 12MB default (bB=8 floor under 10MB);
pure inference holds both directions' weights in 6.3MB and fits
bB=256 outright.  A single tile never pads past the 8-row sublane
multiple (B=96 runs as one 96-row tile, not a padded 128-row one).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_VMEM_BUDGET = 12 * 2 ** 20


def _resolve_interpret(interpret):
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def _round_up(n: int, m: int) -> int:
    return -(-n // m) * m


def _fit_block_b(B: int, usage, budget: int) -> int:
    """The shared batch-tile search of every tuner: start from the
    power-of-two cover of B, halve while ``usage(bb)`` overruns the
    budget, floor at 8 rows (the f32 sublane tile — below that the
    weights themselves are the problem, not the tile), and never pad a
    single tile past the 8-row sublane multiple."""
    bb = max(8, 1 << (max(B, 1) - 1).bit_length())
    while bb > 8 and usage(bb) > budget:
        bb //= 2
    if bb >= B:
        bb = max(8, _round_up(B, 8))
    return bb


def auto_block_b(B: int, D: int, H: int, itemsize: int, *, n_dir: int = 1,
                 training: bool = False, vmem_budget: int = None,
                 stash_itemsize: int = 4) -> int:
    """Largest power-of-two batch tile whose resident set fits the VMEM
    budget (see module docstring for the byte math).  Floors at 8 rows
    (the f32 sublane tile) even when the budget is overrun — at that
    point the weights themselves are the problem, not the tile.
    ``stash_itemsize`` reflects the gate/cell residual stash dtype (2 for
    the bf16 stash option)."""
    budget = vmem_budget or DEFAULT_VMEM_BUDGET
    wparams = D * 4 * H + H * 4 * H + 4 * H

    def usage(bb):
        weights = n_dir * wparams * itemsize
        streamed = 2 * n_dir * bb * (D + H) * itemsize
        carries = n_dir * 2 * bb * H * 4
        if not training:
            return weights + streamed + carries
        # worst single-kernel resident set of the training pair:
        # (a) stashing forward — all directions' weights + gate/cell
        #     stash blocks;  (b) backward — runs ONE direction at a time:
        #     that direction's weights + its f32 dWx/dWh/db accumulators
        #     + the streamed dy/stash/x/dx blocks + (dh, dc) carries.
        fwd = (weights + streamed + carries
               + 2 * n_dir * bb * 5 * H * stash_itemsize)
        bwd = (wparams * (itemsize + 4)
               + 2 * bb * (D + H) * itemsize
               + 2 * bb * 5 * H * stash_itemsize
               + 2 * bb * H * 4
               + 2 * bb * H * 4)
        return max(fwd, bwd)

    return _fit_block_b(B, usage, budget)


def stash_bytes(B: int, T: int, H: int, *, n_dir: int = 1,
                stash_itemsize: int = 4, seq_chunk: int = 0) -> int:
    """Residual-stash HBM bytes of the training forward (the accounting
    single-source for benchmarks/run.py --only longseq and the stash-size
    tests).  Unchunked: post-activation gates (4H) + cell states (H) per
    (row, step).  Chunked: only the (h, c) chunk-entry carries — 2H per
    (row, chunk), ceil(T / seq_chunk) chunks after time padding."""
    if seq_chunk and seq_chunk > 0:
        n_chunks = -(-T // seq_chunk)
        return n_dir * B * n_chunks * 2 * H * stash_itemsize
    return n_dir * B * T * 5 * H * stash_itemsize


def _chunked_usage(bb, K, D, H, itemsize, n_dir, stash_itemsize):
    """Worst single-kernel VMEM resident set of the chunked training pair
    (chunk-stash forward vs chunked-recompute backward) — the byte math
    behind :func:`auto_tile`; docs/kernels.md walks through it."""
    wparams = D * 4 * H + H * 4 * H + 4 * H
    fwd = (n_dir * wparams * itemsize            # weights, all directions
           + 2 * n_dir * bb * (D + H) * itemsize  # x/y streams
           + n_dir * 2 * bb * H * 4               # (h, c) carries
           + 2 * n_dir * bb * H * stash_itemsize)  # boundary-carry blocks
    bwd = (wparams * (itemsize + 4)              # one direction + f32 dW
           + bb * K * (2 * D + H) * itemsize     # x/dx/dy chunk blocks
           + bb * K * 6 * H * 4                  # gate/h/c chunk scratch
           + 2 * bb * H * 4                      # (dh, dc) carries
           + 2 * bb * H * stash_itemsize)        # boundary-carry blocks
    return max(fwd, bwd)


def auto_tile(B: int, T: int, D: int, H: int, itemsize: int, *,
              n_dir: int = 1, vmem_budget: int = None,
              stash_itemsize: int = 4, seq_chunk: int = -1,
              block_b: int = None):
    """Jointly pick ``(block_b, seq_chunk)`` for the chunked TRAINING
    kernels so the worse of (chunk-stash forward, chunked-recompute
    backward) fits the VMEM budget.

    ``seq_chunk > 0`` fixes the chunk length (clamped to T) and only
    ``block_b`` is tuned; ``seq_chunk = -1`` starts from
    min(256, next_pow2(T)) and halves the chunk first (chunk length only
    trades VMEM — the recompute cost is one extra forward pass regardless
    of K), then the batch tile, flooring at K=16 frames and bb=8 rows;
    finally K is halved further while the time padding it induces
    (round_up(T, K) - T) exceeds T/8, so an unlucky T cannot waste a
    large fraction of every chunked pass on masked-off steps.  An
    explicit ``block_b`` is respected and only K is tuned."""
    if not seq_chunk:
        return (block_b or auto_block_b(
            B, D, H, itemsize, n_dir=n_dir, training=True,
            vmem_budget=vmem_budget, stash_itemsize=stash_itemsize)), 0
    budget = vmem_budget or DEFAULT_VMEM_BUDGET
    T = max(T, 1)
    fixed_k = seq_chunk > 0
    K = min(seq_chunk, T) if fixed_k else min(
        256, 1 << (T - 1).bit_length())
    bb = block_b or max(8, 1 << (max(B, 1) - 1).bit_length())

    def usage(bb, K):
        return _chunked_usage(bb, K, D, H, itemsize, n_dir, stash_itemsize)

    while usage(bb, K) > budget:
        if not fixed_k and K > 16:
            K //= 2
        elif block_b is None and bb > 8:
            bb //= 2
        else:
            break   # floor: the weights themselves overrun the budget
    while not fixed_k and K > 16 and (_round_up(T, K) - T) * 8 > T:
        K //= 2                        # bound the masked-padding waste
    if block_b is None and bb >= B:
        bb = max(8, _round_up(B, 8))   # single tile: sublane multiple only
    return bb, K


def _pad_rows(a, Bp):
    B = a.shape[0]
    if B == Bp:
        return a
    return jnp.pad(a, ((0, Bp - B),) + ((0, 0),) * (a.ndim - 1))


def _pad_time(a, Tp):
    T = a.shape[1]
    if T == Tp:
        return a
    return jnp.pad(a, ((0, 0), (0, Tp - T)) + ((0, 0),) * (a.ndim - 2))


def _stash_dtype(stash_dtype):
    return jnp.dtype(stash_dtype or "float32")


def _tile(x, n_dir: int, H: int, block_b, vmem_budget, *, training: bool,
          stash_itemsize: int = 4):
    """The single source of the (block_b, padded_B) pair.  The stashing
    forward and the backward wrapper both derive the tile through here
    with ``training=True`` and identical arguments, so the backward's
    grid covers exactly the rows the forward padded (``_run_bwd``
    asserts the invariant)."""
    if block_b is not None and block_b < 0:
        raise ValueError(f"block_b must be positive or 0/None (auto), "
                         f"got {block_b}")
    B, _, D = x.shape
    bb = block_b or auto_block_b(B, D, H, jnp.dtype(x.dtype).itemsize,
                                 n_dir=n_dir, training=training,
                                 vmem_budget=vmem_budget,
                                 stash_itemsize=stash_itemsize)
    return bb, _round_up(B, bb)


# ---------------------------------------------------------------------------
# forward kernels (inference / training-with-stash, uni- or bidirectional)
# ---------------------------------------------------------------------------

def _cell_math(x_t, hx, c_prev, wx, wh, b):
    """The one LSTM cell step shared by every kernel body (single-layer
    forward, chunked-recompute backward phase 1, fused stack): gate order
    i|f|g|o, forget bias +1, f32 accumulation.  ``hx`` is the recurrent
    input already rounded to the matmul dtype.  Returns the
    post-activation gates and the updated (c, h).  Keep this the single
    source — drift between kernel bodies would silently break the
    bit-identity and grad-parity contracts rather than crash."""
    gates = (
        jax.lax.dot_general(x_t, wx, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
        + jax.lax.dot_general(hx, wh, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
        + b[None, :]
    )
    H = wh.shape[-1] // 4
    i = jax.nn.sigmoid(gates[:, 0 * H:1 * H])
    f = jax.nn.sigmoid(gates[:, 1 * H:2 * H] + 1.0)
    g = jnp.tanh(gates[:, 2 * H:3 * H])
    o = jax.nn.sigmoid(gates[:, 3 * H:4 * H])
    c = f * c_prev + i * g
    return i, f, g, o, c, o * jnp.tanh(c)

def _make_fwd_kernel(n_dir: int, stash: bool, revs=None, chunk: int = 0):
    """Kernel body over refs laid out as:

    inputs:  x * n_dir, then (wx, wh, b) * n_dir, then lengths if masked
    outputs: y * n_dir, then (acts, cseq) * n_dir if ``stash``
             (with ``chunk`` > 0 the per-step (acts, cseq) pair becomes
             the per-chunk (h_bound, c_bound) entry-carry pair, written
             once per chunk on its first grid step)
    scratch: (h, c) * n_dir

    ``revs`` enables masking: it carries each direction's reverse flag so
    the body can recover the real time index of grid step t and freeze
    the (h, c) carry / zero the output on padded steps.
    """
    masked = revs is not None
    n_in = 4 * n_dir + (1 if masked else 0)
    n_out = n_dir * (3 if stash else 1)

    def kernel(*refs):
        x_refs = refs[:n_dir]
        w_refs = refs[n_dir:4 * n_dir]
        out_refs = refs[n_in:n_in + n_out]
        scr_refs = refs[n_in + n_out:]
        t = pl.program_id(1)
        if masked:
            lens = refs[4 * n_dir][...]                     # (bb,) int32
            T = pl.num_programs(1)

        for d in range(n_dir):
            wx_ref, wh_ref, b_ref = w_refs[3 * d:3 * d + 3]
            h_ref, c_ref = scr_refs[2 * d:2 * d + 2]

            @pl.when(t == 0)
            def _init(h_ref=h_ref, c_ref=c_ref):
                h_ref[...] = jnp.zeros_like(h_ref)
                c_ref[...] = jnp.zeros_like(c_ref)

            x = x_refs[d][...]
            h = h_ref[...]
            c_prev = c_ref[...]
            if stash and chunk:
                # stash the chunk-ENTRY carry on the chunk's first step;
                # the output block's index map (t // chunk) keeps it
                # resident for the remaining chunk-1 visits
                hb_ref = out_refs[n_dir + 2 * d]
                cb_ref = out_refs[n_dir + 2 * d + 1]

                @pl.when(t % chunk == 0)
                def _bound(hb_ref=hb_ref, cb_ref=cb_ref, h=h, c=c_prev):
                    hb_ref[...] = h.astype(hb_ref.dtype)
                    cb_ref[...] = c.astype(cb_ref.dtype)
            i, f, g, o, c, h_new = _cell_math(
                x, h.astype(x.dtype), c_prev, wx_ref[...], wh_ref[...],
                b_ref[...])
            if masked:
                time_idx = (T - 1 - t) if revs[d] else t
                vm = (time_idx < lens)[:, None]
                c = jnp.where(vm, c, c_prev)                # freeze carry
                y = jnp.where(vm, h_new, jnp.zeros_like(h_new))
                h_new = jnp.where(vm, h_new, h)
            else:
                y = h_new
            c_ref[...] = c
            h_ref[...] = h_new
            out_refs[d][...] = y.astype(out_refs[d].dtype)
            if stash and not chunk:
                acts_ref = out_refs[n_dir + 2 * d]
                cseq_ref = out_refs[n_dir + 2 * d + 1]
                acts_ref[...] = jnp.concatenate(
                    [i, f, g, o], axis=-1).astype(acts_ref.dtype)
                cseq_ref[...] = c.astype(cseq_ref.dtype)

    return kernel


def _xmap(T: int, reverse: bool):
    if reverse:
        return lambda ib, t: (ib, T - 1 - t, 0)
    return lambda ib, t: (ib, t, 0)


def _run_fwd(ws, x, revs, *, stash: bool, block_b, vmem_budget, interpret,
             lengths=None, stash_dtype=None, seq_chunk: int = 0):
    """Run the forward kernel for one or two directions in one grid pass.

    ws: ((wx, wh, b), ...) per direction; revs: matching reverse flags.
    ``lengths`` (B,) int32 selects the masked kernel (padded rows of the
    batch tile get length 0).  Returns (outs, bb): outs is the flat
    pallas output list over the *padded* batch (y per direction, then
    (acts, cseq) pairs if stash, in ``stash_dtype``).

    ``seq_chunk`` (resolved chunk length K > 0, stash only) switches the
    per-step residual stash to per-chunk (h_bound, c_bound) entry
    carries; the caller must have padded T to a multiple of K and passed
    ``lengths`` (the chunked path is always masked).
    """
    B, T, D = x.shape
    H = ws[0][1].shape[0]
    n_dir = len(ws)
    sdt = _stash_dtype(stash_dtype)
    if seq_chunk:
        assert stash and lengths is not None and T % seq_chunk == 0, \
            (stash, lengths is None, T, seq_chunk)
    bb, Bp = _tile(x, n_dir, H, block_b, vmem_budget, training=stash,
                   stash_itemsize=sdt.itemsize)
    xp = _pad_rows(x, Bp)
    grid = (Bp // bb, T)

    operands, in_specs = [], []
    for rev in revs:
        operands.append(xp)
        in_specs.append(pl.BlockSpec((bb, None, D), _xmap(T, rev)))
    for wx, wh, b in ws:
        operands += [wx, wh, b]
        in_specs += [
            pl.BlockSpec((D, 4 * H), lambda ib, t: (0, 0)),
            pl.BlockSpec((H, 4 * H), lambda ib, t: (0, 0)),
            pl.BlockSpec((4 * H,), lambda ib, t: (0,)),
        ]
    if lengths is not None:
        operands.append(_pad_rows(lengths.astype(jnp.int32), Bp))
        in_specs.append(pl.BlockSpec((bb,), lambda ib, t: (ib,)))

    out_specs = [pl.BlockSpec((bb, None, H), _xmap(T, rev)) for rev in revs]
    out_shape = [jax.ShapeDtypeStruct((Bp, T, H), x.dtype) for _ in revs]
    if stash and seq_chunk:
        K = seq_chunk
        for _ in revs:
            # chunk-entry (h, c) carries; grid step t writes chunk t // K
            out_specs += [pl.BlockSpec((bb, None, H),
                                       lambda ib, t: (ib, t // K, 0))] * 2
            out_shape += [jax.ShapeDtypeStruct((Bp, T // K, H), sdt)] * 2
    elif stash:
        for rev in revs:
            out_specs += [pl.BlockSpec((bb, None, 4 * H), _xmap(T, rev)),
                          pl.BlockSpec((bb, None, H), _xmap(T, rev))]
            out_shape += [jax.ShapeDtypeStruct((Bp, T, 4 * H), sdt),
                          jax.ShapeDtypeStruct((Bp, T, H), sdt)]

    scratch = []
    for _ in revs:
        scratch += [pltpu.VMEM((bb, H), jnp.float32),
                    pltpu.VMEM((bb, H), jnp.float32)]

    outs = pl.pallas_call(
        _make_fwd_kernel(n_dir, stash,
                         revs if lengths is not None else None,
                         chunk=seq_chunk),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=scratch,
        interpret=_resolve_interpret(interpret),
    )(*operands)
    return list(outs), bb


# ---------------------------------------------------------------------------
# backward kernel (one direction; the BLSTM VJP runs it once per direction)
# ---------------------------------------------------------------------------

def _make_bwd_kernel(reverse: bool, masked: bool):
    """One reverse-recurrence step.  Grid (B//bB, T); grid axis 1 walks
    the recurrence backwards (index maps reverse time), carrying (dh, dc)
    in scratch and accumulating dWx/dWh/db into constant-mapped f32
    output blocks that stay VMEM-resident for the whole grid.

    ``masked`` adds a trailing lengths input: on padded steps dgates are
    zeroed (so dx and the dW accumulators see nothing) and the (dh, dc)
    carries pass through unchanged — the exact VJP of the frozen-carry
    forward.  ``reverse`` is only consulted when masked (to recover the
    real time index of grid step r)."""

    def kernel(*refs):
        (dy_ref, acts_ref, c_ref, cprev_ref, hprev_ref, x_ref,
         wx_ref, wh_ref) = refs[:8]
        len_ref = refs[8] if masked else None
        (dx_ref, dwx_ref, dwh_ref, db_ref,
         dh_ref, dc_ref) = refs[8 + (1 if masked else 0):]
        ib = pl.program_id(0)
        r = pl.program_id(1)

        @pl.when(r == 0)
        def _init_carry():
            dh_ref[...] = jnp.zeros_like(dh_ref)
            dc_ref[...] = jnp.zeros_like(dc_ref)

        @pl.when((r == 0) & (ib == 0))
        def _init_accum():
            dwx_ref[...] = jnp.zeros_like(dwx_ref)
            dwh_ref[...] = jnp.zeros_like(dwh_ref)
            db_ref[...] = jnp.zeros_like(db_ref)

        # the last grid step is the *first* step of the original
        # recurrence: its h_{t-1}/c_{t-1} are the zero initial state,
        # not array values
        boundary = r == pl.num_programs(1) - 1
        H = dh_ref.shape[-1]
        acts = acts_ref[...].astype(jnp.float32)
        i = acts[:, 0 * H:1 * H]
        f = acts[:, 1 * H:2 * H]
        g = acts[:, 2 * H:3 * H]
        o = acts[:, 3 * H:4 * H]
        c = c_ref[...].astype(jnp.float32)
        zero = jnp.zeros_like(c)
        c_prev = jnp.where(boundary, zero,
                           cprev_ref[...].astype(jnp.float32))
        h_prev = jnp.where(boundary, zero,
                           hprev_ref[...].astype(jnp.float32))

        dh_carry = dh_ref[...]
        dc_carry = dc_ref[...]
        dh = dy_ref[...].astype(jnp.float32) + dh_carry
        tc = jnp.tanh(c)
        dc = dh * o * (1.0 - tc * tc) + dc_carry
        if masked:
            T = pl.num_programs(1)
            time_idx = r if reverse else T - 1 - r
            vm = (time_idx < len_ref[...])[:, None]
            dh = jnp.where(vm, dh, zero)
            dc = jnp.where(vm, dc, zero)
        dgates = jnp.concatenate([
            dc * g * i * (1.0 - i),          # d pre-act input gate
            dc * c_prev * f * (1.0 - f),     # d pre-act forget gate
            dc * i * (1.0 - g * g),          # d pre-act cell candidate
            dh * tc * o * (1.0 - o),         # d pre-act output gate
        ], axis=-1)

        wx = wx_ref[...].astype(jnp.float32)
        wh = wh_ref[...].astype(jnp.float32)
        dx_ref[...] = jax.lax.dot_general(
            dgates, wx, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32).astype(dx_ref.dtype)
        dh_new = jax.lax.dot_general(
            dgates, wh, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        dc_new = dc * f
        if masked:
            # padded step: h_t = h_{t-1}, c_t = c_{t-1} — the carries
            # pass straight through
            dh_new = jnp.where(vm, dh_new, dh_carry)
            dc_new = jnp.where(vm, dc_new, dc_carry)
        dh_ref[...] = dh_new
        dc_ref[...] = dc_new

        x = x_ref[...].astype(jnp.float32)
        dwx_ref[...] += jax.lax.dot_general(
            x, dgates, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        dwh_ref[...] += jax.lax.dot_general(
            h_prev, dgates, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        db_ref[...] += jnp.sum(dgates, axis=0)

    return kernel


def _bwd_tmap(T: int, reverse: bool):
    """Time index of the step grid position r processes (reverse
    recurrence order: the forward direction walks T-1..0)."""
    if reverse:
        return lambda ib, r: (ib, r, 0)
    return lambda ib, r: (ib, T - 1 - r, 0)


def _bwd_pmap(T: int, reverse: bool):
    """Time index of the *previous* recurrence step (clamped at the
    boundary; the kernel zeroes the value there)."""
    if reverse:
        return lambda ib, r: (ib, jnp.minimum(r + 1, T - 1), 0)
    return lambda ib, r: (ib, jnp.maximum(T - 2 - r, 0), 0)


def _run_bwd(wx, wh, xp, yp, acts, cseq, dyp, *, reverse: bool, bb: int,
             interpret, lengths_p=None):
    """Backward kernel over padded arrays -> (dxp, dwx, dwh, db), f32
    weight grads (caller casts to param dtypes).  ``lengths_p`` is the
    row-padded (Bp,) lengths vector for the masked VJP (None = dense)."""
    Bp, T, D = xp.shape
    H = wh.shape[0]
    assert Bp % bb == 0, (Bp, bb)   # forward/backward tile lockstep
    grid = (Bp // bb, T)
    tmap = _bwd_tmap(T, reverse)
    pmap = _bwd_pmap(T, reverse)
    masked = lengths_p is not None

    in_specs = [
        pl.BlockSpec((bb, None, H), tmap),          # dy_t
        pl.BlockSpec((bb, None, 4 * H), tmap),      # stashed gates_t
        pl.BlockSpec((bb, None, H), tmap),          # c_t
        pl.BlockSpec((bb, None, H), pmap),          # c_{t-1}
        pl.BlockSpec((bb, None, H), pmap),          # h_{t-1} (= y)
        pl.BlockSpec((bb, None, D), tmap),          # x_t
        pl.BlockSpec((D, 4 * H), lambda ib, r: (0, 0)),
        pl.BlockSpec((H, 4 * H), lambda ib, r: (0, 0)),
    ]
    operands = [dyp, acts, cseq, cseq, yp, xp, wx, wh]
    if masked:
        in_specs.append(pl.BlockSpec((bb,), lambda ib, r: (ib,)))
        operands.append(lengths_p)

    return pl.pallas_call(
        _make_bwd_kernel(reverse, masked),
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((bb, None, D), tmap),
            pl.BlockSpec((D, 4 * H), lambda ib, r: (0, 0)),
            pl.BlockSpec((H, 4 * H), lambda ib, r: (0, 0)),
            pl.BlockSpec((4 * H,), lambda ib, r: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bp, T, D), xp.dtype),
            jax.ShapeDtypeStruct((D, 4 * H), jnp.float32),
            jax.ShapeDtypeStruct((H, 4 * H), jnp.float32),
            jax.ShapeDtypeStruct((4 * H,), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bb, H), jnp.float32),
            pltpu.VMEM((bb, H), jnp.float32),
        ],
        interpret=_resolve_interpret(interpret),
    )(*operands)


# ---------------------------------------------------------------------------
# chunked-recompute backward (one direction; grid walks chunks in reverse)
# ---------------------------------------------------------------------------

def _make_bwd_chunked_kernel(reverse: bool, K: int):
    """One grid step = one K-frame chunk, processed in reverse recurrence
    order (grid axis 1 index maps reverse the chunk axis).  Phase 1
    re-runs the forward for the chunk from its stashed entry carry,
    rebuilding the gate/cell residuals in VMEM scratch; phase 2 runs the
    K reverse-recurrence steps against them, carrying (dh, dc) across
    chunks in scratch and accumulating dWx/dWh/db into constant-mapped
    f32 output blocks.  Always masked — the chunked wrapper synthesizes
    ``lengths`` (= T) for dense inputs so time padding to a K multiple
    stays exact."""

    def kernel(dy_ref, x_ref, hb_ref, cb_ref, wx_ref, wh_ref, b_ref,
               len_ref, dx_ref, dwx_ref, dwh_ref, db_ref,
               g_scr, hp_scr, cp_scr, dh_ref, dc_ref):
        ib = pl.program_id(0)
        r = pl.program_id(1)
        n = pl.num_programs(1)
        H = dh_ref.shape[-1]

        @pl.when(r == 0)
        def _init_carry():
            dh_ref[...] = jnp.zeros_like(dh_ref)
            dc_ref[...] = jnp.zeros_like(dc_ref)

        @pl.when((r == 0) & (ib == 0))
        def _init_accum():
            dwx_ref[...] = jnp.zeros_like(dwx_ref)
            dwh_ref[...] = jnp.zeros_like(dwh_ref)
            db_ref[...] = jnp.zeros_like(db_ref)

        # real-time base of this grid step's x/dy/dx blocks (= block
        # index * K; the recurrence chunk is n-1-r in both directions)
        base = (r if reverse else n - 1 - r) * K
        lens = len_ref[...]
        b = b_ref[...]
        xdt = x_ref.dtype
        zero = jnp.zeros((dh_ref.shape[0], H), jnp.float32)

        def _vm(lt):
            return ((base + lt) < lens)[:, None]

        # ---- phase 1: recompute the chunk's forward in VMEM ----------
        # u walks the chunk in recurrence order; lt is the real-time
        # position inside the block (the reverse direction's recurrence
        # walks real time descending)
        def fwd_body(u, hc):
            h, c = hc
            lt = (K - 1 - u) if reverse else u
            x_t = x_ref[:, pl.ds(lt, 1), :][:, 0, :]
            hx = h.astype(xdt)
            hp_scr[:, pl.ds(lt, 1), :] = hx.astype(
                jnp.float32)[:, None, :]
            cp_scr[:, pl.ds(lt, 1), :] = c[:, None, :]
            i, f, g, o, c_new, h_new = _cell_math(
                x_t, hx, c, wx_ref[...], wh_ref[...], b)
            g_scr[:, pl.ds(lt, 1), :] = jnp.concatenate(
                [i, f, g, o], axis=-1)[:, None, :]
            vm = _vm(lt)
            return (jnp.where(vm, h_new, h), jnp.where(vm, c_new, c))

        h0 = hb_ref[...].astype(jnp.float32)
        c0 = cb_ref[...].astype(jnp.float32)
        jax.lax.fori_loop(0, K, fwd_body, (h0, c0))

        # ---- phase 2: reverse-recurrence backward over the chunk -----
        wx = wx_ref[...].astype(jnp.float32)
        wh = wh_ref[...].astype(jnp.float32)

        def bwd_body(u, carry):
            dh_c, dc_c = carry
            s = K - 1 - u                       # recurrence-local step
            lt = (K - 1 - s) if reverse else s
            acts = g_scr[:, pl.ds(lt, 1), :][:, 0, :]
            i = acts[:, 0 * H:1 * H]
            f = acts[:, 1 * H:2 * H]
            g = acts[:, 2 * H:3 * H]
            o = acts[:, 3 * H:4 * H]
            c_prev = cp_scr[:, pl.ds(lt, 1), :][:, 0, :]
            vm = _vm(lt)
            c = jnp.where(vm, f * c_prev + i * g, c_prev)
            dh = dy_ref[:, pl.ds(lt, 1), :][:, 0, :].astype(
                jnp.float32) + dh_c
            tc = jnp.tanh(c)
            dc = dh * o * (1.0 - tc * tc) + dc_c
            dh = jnp.where(vm, dh, zero)
            dc = jnp.where(vm, dc, zero)
            dgates = jnp.concatenate([
                dc * g * i * (1.0 - i),
                dc * c_prev * f * (1.0 - f),
                dc * i * (1.0 - g * g),
                dh * tc * o * (1.0 - o),
            ], axis=-1)
            dx_ref[:, pl.ds(lt, 1), :] = jax.lax.dot_general(
                dgates, wx, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32).astype(
                    dx_ref.dtype)[:, None, :]
            dh_new = jax.lax.dot_general(
                dgates, wh, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32)
            dc_new = dc * f
            x_t = x_ref[:, pl.ds(lt, 1), :][:, 0, :].astype(jnp.float32)
            h_prev = hp_scr[:, pl.ds(lt, 1), :][:, 0, :]
            dwx_ref[...] += jax.lax.dot_general(
                x_t, dgates, (((0,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            dwh_ref[...] += jax.lax.dot_general(
                h_prev, dgates, (((0,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)
            db_ref[...] += jnp.sum(dgates, axis=0)
            return (jnp.where(vm, dh_new, dh_c),
                    jnp.where(vm, dc_new, dc_c))

        dh_c, dc_c = jax.lax.fori_loop(
            0, K, bwd_body, (dh_ref[...], dc_ref[...]))
        dh_ref[...] = dh_c
        dc_ref[...] = dc_c

    return kernel


def _run_bwd_chunked(wx, wh, b, xp, hbound, cbound, dyp, lengths_p, *,
                     reverse: bool, bb: int, interpret):
    """Chunked backward over padded arrays -> (dxp, dwx, dwh, db), f32
    weight grads.  ``xp``/``dyp`` are row- and time-padded (T multiple of
    the chunk length); ``hbound``/``cbound`` are the (Bp, n_chunks, H)
    chunk-entry carries of the chunk-stash forward; ``lengths_p`` the
    row-padded lengths (always present on the chunked path)."""
    Bp, T, D = xp.shape
    H = wh.shape[0]
    n = hbound.shape[1]
    K = T // n
    assert Bp % bb == 0 and T % n == 0, (Bp, bb, T, n)

    def cmap(ib, r):              # x/dy/dx chunk block, real-time order
        return (ib, r, 0) if reverse else (ib, n - 1 - r, 0)

    def bmap(ib, r):              # entry carries, recurrence-chunk order
        return (ib, n - 1 - r, 0)

    return pl.pallas_call(
        _make_bwd_chunked_kernel(reverse, K),
        grid=(Bp // bb, n),
        in_specs=[
            pl.BlockSpec((bb, K, H), cmap),           # dy chunk
            pl.BlockSpec((bb, K, D), cmap),           # x chunk
            pl.BlockSpec((bb, None, H), bmap),        # h entry carry
            pl.BlockSpec((bb, None, H), bmap),        # c entry carry
            pl.BlockSpec((D, 4 * H), lambda ib, r: (0, 0)),
            pl.BlockSpec((H, 4 * H), lambda ib, r: (0, 0)),
            pl.BlockSpec((4 * H,), lambda ib, r: (0,)),
            pl.BlockSpec((bb,), lambda ib, r: (ib,)),
        ],
        out_specs=[
            pl.BlockSpec((bb, K, D), cmap),
            pl.BlockSpec((D, 4 * H), lambda ib, r: (0, 0)),
            pl.BlockSpec((H, 4 * H), lambda ib, r: (0, 0)),
            pl.BlockSpec((4 * H,), lambda ib, r: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bp, T, D), xp.dtype),
            jax.ShapeDtypeStruct((D, 4 * H), jnp.float32),
            jax.ShapeDtypeStruct((H, 4 * H), jnp.float32),
            jax.ShapeDtypeStruct((4 * H,), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bb, K, 4 * H), jnp.float32),   # gate residuals
            pltpu.VMEM((bb, K, H), jnp.float32),       # h_{t-1} (rounded)
            pltpu.VMEM((bb, K, H), jnp.float32),       # c_{t-1}
            pltpu.VMEM((bb, H), jnp.float32),          # dh carry
            pltpu.VMEM((bb, H), jnp.float32),          # dc carry
        ],
        interpret=_resolve_interpret(interpret),
    )(dyp, xp, hbound, cbound, wx, wh, b, lengths_p)


# ---------------------------------------------------------------------------
# custom-VJP wiring: unidirectional
# ---------------------------------------------------------------------------

def _len_cotangent(lengths):
    """Cotangent for the integer lengths input (float0 per JAX's rule for
    non-differentiable primal dtypes; None when lengths wasn't passed)."""
    if lengths is None:
        return None
    return np.zeros(lengths.shape, jax.dtypes.float0)


def _run_fwd_train(ws, x, revs, lengths, *, interpret, block_b,
                   vmem_budget, stash_dtype, seq_chunk):
    """Stashing training forward shared by every custom-VJP fwd rule.

    Returns (ys, res): ys are per-direction (B, T, H) outputs (trimmed),
    res the residual tuple :func:`_run_bwd_train` consumes.  On the
    chunked path (``seq_chunk`` != 0) x is zero-padded to a chunk
    multiple of T, a full-T ``lengths`` is synthesized for dense inputs,
    and the residuals are the (h, c) chunk-entry carries instead of the
    per-step gate/cell stash."""
    B, T, D = x.shape
    H = ws[0][1].shape[0]
    n_dir = len(ws)
    sdt = _stash_dtype(stash_dtype)
    if seq_chunk:
        bb, K = auto_tile(B, T, D, H, jnp.dtype(x.dtype).itemsize,
                          n_dir=n_dir, vmem_budget=vmem_budget,
                          stash_itemsize=sdt.itemsize,
                          seq_chunk=seq_chunk, block_b=block_b)
        lens = (jnp.full((B,), T, jnp.int32) if lengths is None
                else jnp.minimum(lengths.astype(jnp.int32), T))
        outs, _ = _run_fwd(ws, _pad_time(x, _round_up(T, K)), revs,
                           stash=True, block_b=bb,
                           vmem_budget=vmem_budget, interpret=interpret,
                           lengths=lens, stash_dtype=stash_dtype,
                           seq_chunk=K)
        ys = [outs[d][:B, :T] for d in range(n_dir)]
        return ys, (x, lens, tuple(outs[n_dir:]))
    outs, _ = _run_fwd(ws, x, revs, stash=True, block_b=block_b,
                       vmem_budget=vmem_budget, interpret=interpret,
                       lengths=lengths, stash_dtype=stash_dtype)
    ys = [outs[d][:B] for d in range(n_dir)]
    return ys, (x, lengths, tuple(outs))


def _run_bwd_train(ws, res, dys, revs, *, interpret, block_b,
                   vmem_budget, stash_dtype, seq_chunk):
    """Backward shared by every custom-VJP bwd rule: one `_run_bwd` /
    `_run_bwd_chunked` call per direction against the residuals of
    :func:`_run_fwd_train`.  Returns (per-direction (dwx, dwh, db) f32,
    dx summed over directions, trimmed, f32)."""
    x, lengths, stash = res
    B, T, D = x.shape
    H = ws[0][1].shape[0]
    n_dir = len(ws)
    sdt = _stash_dtype(stash_dtype)
    grads, dx = [], 0
    if seq_chunk:
        bb, K = auto_tile(B, T, D, H, jnp.dtype(x.dtype).itemsize,
                          n_dir=n_dir, vmem_budget=vmem_budget,
                          stash_itemsize=sdt.itemsize,
                          seq_chunk=seq_chunk, block_b=block_b)
        Bp = stash[0].shape[0]
        assert Bp == _round_up(B, bb), (Bp, B, bb)
        Tp = _round_up(T, K)
        xp = _pad_rows(_pad_time(x, Tp), Bp)
        lp = _pad_rows(lengths, Bp)
        for d, ((wx, wh, b), rev) in enumerate(zip(ws, revs)):
            dyp = _pad_rows(_pad_time(dys[d], Tp), Bp)
            dxp, dwx, dwh, db = _run_bwd_chunked(
                wx, wh, b, xp, stash[2 * d], stash[2 * d + 1], dyp, lp,
                reverse=rev, bb=bb, interpret=interpret)
            grads.append((dwx, dwh, db))
            dx = dx + dxp[:B, :T].astype(jnp.float32)
        return grads, dx
    bb, Bp = _tile(x, n_dir, H, block_b, vmem_budget, training=True,
                   stash_itemsize=sdt.itemsize)
    assert Bp == stash[0].shape[0], (Bp, stash[0].shape)
    xp = _pad_rows(x, Bp)
    lp = (None if lengths is None
          else _pad_rows(lengths.astype(jnp.int32), Bp))
    for d, ((wx, wh, b), rev) in enumerate(zip(ws, revs)):
        yp = stash[d]
        acts, cseq = stash[n_dir + 2 * d], stash[n_dir + 2 * d + 1]
        dxp, dwx, dwh, db = _run_bwd(
            wx, wh, xp, yp, acts, cseq, _pad_rows(dys[d], Bp),
            reverse=rev, bb=bb, interpret=interpret, lengths_p=lp)
        grads.append((dwx, dwh, db))
        dx = dx + dxp[:B].astype(jnp.float32)
    return grads, dx


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _lstm_vjp(static, wx, wh, b, x, lengths):
    reverse, interpret, block_b, vmem_budget = static[:4]
    outs, _ = _run_fwd(((wx, wh, b),), x, (reverse,), stash=False,
                       block_b=block_b, vmem_budget=vmem_budget,
                       interpret=interpret, lengths=lengths)
    return outs[0][:x.shape[0]]


def _lstm_vjp_fwd(static, wx, wh, b, x, lengths):
    reverse, interpret, block_b, vmem_budget, stash_dtype, seq_chunk = \
        static
    ys, res = _run_fwd_train(((wx, wh, b),), x, (reverse,), lengths,
                             interpret=interpret, block_b=block_b,
                             vmem_budget=vmem_budget,
                             stash_dtype=stash_dtype,
                             seq_chunk=seq_chunk)
    return ys[0], (wx, wh, b, lengths, res)


def _lstm_vjp_bwd(static, fullres, dy):
    reverse, interpret, block_b, vmem_budget, stash_dtype, seq_chunk = \
        static
    wx, wh, b, lengths, res = fullres
    grads, dx = _run_bwd_train(((wx, wh, b),), res, (dy,), (reverse,),
                               interpret=interpret, block_b=block_b,
                               vmem_budget=vmem_budget,
                               stash_dtype=stash_dtype,
                               seq_chunk=seq_chunk)
    (dwx, dwh, db), = grads
    return (dwx.astype(wx.dtype), dwh.astype(wh.dtype),
            db.astype(b.dtype), dx.astype(res[0].dtype),
            _len_cotangent(lengths))


_lstm_vjp.defvjp(_lstm_vjp_fwd, _lstm_vjp_bwd)


def lstm_sequence(wx, wh, b, x, lengths=None, *, reverse: bool = False,
                  interpret: bool = None, block_b: int = None,
                  vmem_budget: int = None, stash_dtype: str = None,
                  seq_chunk: int = 0):
    """x: (B, T, D) -> (B, T, H); weights wx (D,4H), wh (H,4H), b (4H,).

    Differentiable (custom VJP; see module docstring).  ``block_b``
    tiles the batch (None -> :func:`auto_block_b`).  ``lengths`` (B,)
    int selects the masked recurrence (frozen carry + zeroed output on
    padded steps); ``stash_dtype`` ('float32' | 'bfloat16') sets the
    training-forward residual stash precision; ``seq_chunk`` (K > 0
    frames, or -1 for auto) switches training to the sequence-chunked
    recompute backward (O(T/K) residual stash)."""
    return _lstm_vjp((bool(reverse), interpret, block_b, vmem_budget,
                      stash_dtype, seq_chunk or 0), wx, wh, b, x, lengths)


# ---------------------------------------------------------------------------
# custom-VJP wiring: fused bidirectional
# ---------------------------------------------------------------------------

_BLSTM_REVS = (False, True)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _blstm_vjp(static, wxf, whf, bf, wxb, whb, bb_, x, lengths):
    interpret, block_b, vmem_budget = static[:3]
    outs, _ = _run_fwd(((wxf, whf, bf), (wxb, whb, bb_)), x, _BLSTM_REVS,
                       stash=False, block_b=block_b,
                       vmem_budget=vmem_budget, interpret=interpret,
                       lengths=lengths)
    B = x.shape[0]
    return jnp.concatenate([outs[0][:B], outs[1][:B]], axis=-1)


def _blstm_vjp_fwd(static, wxf, whf, bf, wxb, whb, bb_, x, lengths):
    interpret, block_b, vmem_budget, stash_dtype, seq_chunk = static
    ys, res = _run_fwd_train(((wxf, whf, bf), (wxb, whb, bb_)), x,
                             _BLSTM_REVS, lengths, interpret=interpret,
                             block_b=block_b, vmem_budget=vmem_budget,
                             stash_dtype=stash_dtype,
                             seq_chunk=seq_chunk)
    y = jnp.concatenate(ys, axis=-1)
    return y, (wxf, whf, bf, wxb, whb, bb_, lengths, res)


def _blstm_vjp_bwd(static, fullres, dy):
    interpret, block_b, vmem_budget, stash_dtype, seq_chunk = static
    wxf, whf, bf, wxb, whb, bb_, lengths, res = fullres
    H = whf.shape[0]
    grads, dx = _run_bwd_train(
        ((wxf, whf, bf), (wxb, whb, bb_)), res,
        (dy[..., :H], dy[..., H:]), _BLSTM_REVS, interpret=interpret,
        block_b=block_b, vmem_budget=vmem_budget,
        stash_dtype=stash_dtype, seq_chunk=seq_chunk)
    (dwxf, dwhf, dbf), (dwxb, dwhb, dbb) = grads
    return (dwxf.astype(wxf.dtype), dwhf.astype(whf.dtype),
            dbf.astype(bf.dtype), dwxb.astype(wxb.dtype),
            dwhb.astype(whb.dtype), dbb.astype(bb_.dtype),
            dx.astype(res[0].dtype), _len_cotangent(lengths))


_blstm_vjp.defvjp(_blstm_vjp_fwd, _blstm_vjp_bwd)


def blstm_sequence(wx_fwd, wh_fwd, b_fwd, wx_bwd, wh_bwd, b_bwd, x,
                   lengths=None, *, interpret: bool = None,
                   block_b: int = None, vmem_budget: int = None,
                   stash_dtype: str = None, seq_chunk: int = 0):
    """Fused bidirectional layer: x (B, T, D) -> (B, T, 2H) with the
    forward-direction output in [..., :H] and the time-reversed
    direction in [..., H:] — one kernel invocation, both weight sets
    resident, bit-identical to two :func:`lstm_sequence` calls.

    ``lengths`` (B,) int masks padded steps (the reverse direction then
    reverses within each row's valid span); ``stash_dtype`` sets the
    training-forward residual stash precision; ``seq_chunk`` (K > 0
    frames, or -1 for auto) selects the sequence-chunked recompute
    backward (O(T/K) residual stash)."""
    return _blstm_vjp((interpret, block_b, vmem_budget, stash_dtype,
                       seq_chunk or 0),
                      wx_fwd, wh_fwd, b_fwd, wx_bwd, wh_bwd, b_bwd, x,
                      lengths)


# ---------------------------------------------------------------------------
# fused multi-layer stack (inter-layer h stays VMEM-resident)
# ---------------------------------------------------------------------------

def _stack_usage(bb: int, T: int, D: int, H: int, itemsize: int) -> int:
    """VMEM resident set of the fused-stack kernel at batch tile bb (the
    two (bB, T, 2H) inter-layer ping-pong buffers dominate; see
    docs/kernels.md for the walk-through)."""
    Dm = max(D, 2 * H)
    return (2 * (Dm * 4 * H + H * 4 * H + 4 * H) * itemsize  # one layer
            + 2 * bb * T * 2 * H * itemsize        # ping-pong buffers
            + 2 * bb * (D + 2 * H) * itemsize      # x/y blocks
            + 4 * bb * H * 4)                      # (h, c) x 2 dirs


def auto_stack_block_b(B: int, T: int, D: int, H: int, itemsize: int,
                       vmem_budget: int = None) -> int:
    """Batch tile for the fused-stack kernel: the ping-pong buffers scale
    with T, so the tile shrinks as sequences grow (floor 8 rows; if even
    the floor overruns the budget, `blstm_stack_sequence` falls back to
    the per-layer loop instead of overcommitting VMEM)."""
    return _fit_block_b(
        B, lambda bb: _stack_usage(bb, T, D, H, itemsize),
        vmem_budget or DEFAULT_VMEM_BUDGET)


def _make_stack_kernel(L: int, T: int, D0: int, Dm: int, H: int,
                       masked: bool):
    """Whole-stack body on the (B//bB, L, T) grid (L and T sequential,
    T innermost).  Per-direction math is op-for-op `_make_fwd_kernel`
    (shared via `_cell_math`); the only new moving part is the layer
    input: layer 0 reads the x block (D0 wide, zero-extended to Dm
    in-register — exact, and avoids materializing a Dm-wide x copy in
    HBM), layer l>0 reads layer l-1's output from the VMEM ping-pong
    buffer at its direction's real time index (the x index maps collapse
    to a constant block for l > 0, so x stays resident instead of being
    re-fetched every step).  Outputs are written only by the last
    layer."""

    def kernel(*refs):
        (xf_ref, xb_ref, wxs_ref, whs_ref, bs_ref) = refs[:5]
        len_ref = refs[5] if masked else None
        yf_ref, yb_ref = refs[5 + (1 if masked else 0):][:2]
        (ybuf0, ybuf1, h0_ref, c0_ref, h1_ref, c1_ref) = refs[-6:]
        l = pl.program_id(1)
        t = pl.program_id(2)
        even = l % 2 == 0
        if masked:
            lens = len_ref[...]

        for d in range(2):
            x_ref = (xf_ref, xb_ref)[d]
            h_ref, c_ref = ((h0_ref, c0_ref), (h1_ref, c1_ref))[d]
            out_ref = (yf_ref, yb_ref)[d]
            tr = t if d == 0 else T - 1 - t       # real time this step

            @pl.when(t == 0)
            def _init(h_ref=h_ref, c_ref=c_ref):
                h_ref[...] = jnp.zeros_like(h_ref)
                c_ref[...] = jnp.zeros_like(c_ref)

            # layer input: x block for l == 0, else the previous layer's
            # buffer (ping-pong: even layers write ybuf0, odd ybuf1)
            x_in = x_ref[...]
            if Dm > D0:
                x_in = jnp.pad(x_in, ((0, 0), (0, Dm - D0)))
            p0 = ybuf0[:, pl.ds(tr, 1), :][:, 0, :]
            p1 = ybuf1[:, pl.ds(tr, 1), :][:, 0, :]
            prev = jnp.where(even, p1, p0)
            if Dm > 2 * H:
                prev = jnp.pad(prev, ((0, 0), (0, Dm - 2 * H)))
            inp = jnp.where(l == 0, x_in, prev.astype(x_in.dtype))

            h = h_ref[...]
            c_prev = c_ref[...]
            i, f, g, o, c, h_new = _cell_math(
                inp, h.astype(inp.dtype), c_prev, wxs_ref[d],
                whs_ref[d], bs_ref[d])
            if masked:
                vm = (tr < lens)[:, None]
                c = jnp.where(vm, c, c_prev)
                y = jnp.where(vm, h_new, jnp.zeros_like(h_new))
                h_new = jnp.where(vm, h_new, h)
            else:
                y = h_new
            c_ref[...] = c
            h_ref[...] = h_new
            yb_val = y.astype(ybuf0.dtype)[:, None, :]

            @pl.when(even)
            def _w0(yb_val=yb_val, tr=tr, d=d):
                ybuf0[:, pl.ds(tr, 1), d * H:(d + 1) * H] = yb_val

            @pl.when(jnp.logical_not(even))
            def _w1(yb_val=yb_val, tr=tr, d=d):
                ybuf1[:, pl.ds(tr, 1), d * H:(d + 1) * H] = yb_val

            @pl.when(l == L - 1)
            def _out(out_ref=out_ref, y=y):
                out_ref[...] = y.astype(out_ref.dtype)

    return kernel


def _stack_layers(params):
    """Normalize the per-layer parameter pytree to a tuple of 6-tuples
    ((wxf, whf, bf, wxb, whb, bb), ...)."""
    return tuple(tuple(layer) for layer in params)


def _stack_primal(params, x, lengths, *, interpret, block_b, vmem_budget):
    layers = _stack_layers(params)
    L = len(layers)
    B, T, D0 = x.shape
    H = layers[0][1].shape[0]
    Dm = max(D0, 2 * H)
    itemsize = jnp.dtype(x.dtype).itemsize
    bb = block_b or auto_stack_block_b(B, T, D0, H, itemsize, vmem_budget)
    if (block_b is None and _stack_usage(bb, T, D0, H, itemsize)
            > (vmem_budget or DEFAULT_VMEM_BUDGET)):
        # very long T: even the 8-row floor cannot hold the (bB, T, 2H)
        # ping-pong buffers — run the per-layer fused-BLSTM loop
        # (T-independent VMEM) instead of overcommitting/failing compile
        for (wxf, whf, bf, wxb, whb, bb_) in layers:
            outs, _ = _run_fwd(((wxf, whf, bf), (wxb, whb, bb_)), x,
                               _BLSTM_REVS, stash=False, block_b=None,
                               vmem_budget=vmem_budget,
                               interpret=interpret, lengths=lengths)
            x = jnp.concatenate([outs[0][:B], outs[1][:B]], axis=-1)
        return x
    Bp = _round_up(B, bb)

    def padw(w):
        return jnp.pad(w, ((0, Dm - w.shape[0]), (0, 0)))

    wxs = jnp.stack([jnp.stack([padw(lw[0]), padw(lw[3])])
                     for lw in layers])                  # (L, 2, Dm, 4H)
    whs = jnp.stack([jnp.stack([lw[1], lw[4]]) for lw in layers])
    bs = jnp.stack([jnp.stack([lw[2], lw[5]]) for lw in layers])
    xp = _pad_rows(x, Bp)
    masked = lengths is not None

    # x is only consumed by layer 0; for l > 0 the maps collapse to a
    # constant block so it stays resident instead of re-streaming
    def xmap_f(ib, l, t):
        return (ib, jnp.where(l == 0, t, 0), 0)

    def xmap_b(ib, l, t):
        return (ib, jnp.where(l == 0, T - 1 - t, 0), 0)

    in_specs = [
        pl.BlockSpec((bb, None, D0), xmap_f),
        pl.BlockSpec((bb, None, D0), xmap_b),
        pl.BlockSpec((None, 2, Dm, 4 * H), lambda ib, l, t: (l, 0, 0, 0)),
        pl.BlockSpec((None, 2, H, 4 * H), lambda ib, l, t: (l, 0, 0, 0)),
        pl.BlockSpec((None, 2, 4 * H), lambda ib, l, t: (l, 0, 0)),
    ]
    operands = [xp, xp, wxs, whs, bs]
    if masked:
        in_specs.append(pl.BlockSpec((bb,), lambda ib, l, t: (ib,)))
        operands.append(_pad_rows(lengths.astype(jnp.int32), Bp))

    yf, yb = pl.pallas_call(
        _make_stack_kernel(L, T, D0, Dm, H, masked),
        grid=(Bp // bb, L, T),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((bb, None, H), lambda ib, l, t: (ib, t, 0)),
            pl.BlockSpec((bb, None, H),
                         lambda ib, l, t: (ib, T - 1 - t, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((Bp, T, H), x.dtype)] * 2,
        scratch_shapes=[
            pltpu.VMEM((bb, T, 2 * H), x.dtype),    # ping-pong buffer 0
            pltpu.VMEM((bb, T, 2 * H), x.dtype),    # ping-pong buffer 1
            pltpu.VMEM((bb, H), jnp.float32),       # fwd-dir h
            pltpu.VMEM((bb, H), jnp.float32),       # fwd-dir c
            pltpu.VMEM((bb, H), jnp.float32),       # rev-dir h
            pltpu.VMEM((bb, H), jnp.float32),       # rev-dir c
        ],
        interpret=_resolve_interpret(interpret),
    )(*operands)
    return jnp.concatenate([yf[:B], yb[:B]], axis=-1)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _stack_vjp(static, params, x, lengths):
    interpret, block_b, vmem_budget = static[:3]
    return _stack_primal(params, x, lengths, interpret=interpret,
                         block_b=block_b, vmem_budget=vmem_budget)


def _stack_vjp_fwd(static, params, x, lengths):
    interpret, block_b, vmem_budget, stash_dtype, seq_chunk = static
    layers = _stack_layers(params)
    xl, reses = x, []
    for (wxf, whf, bf, wxb, whb, bb_) in layers:
        ys, res = _run_fwd_train(((wxf, whf, bf), (wxb, whb, bb_)), xl,
                                 _BLSTM_REVS, lengths,
                                 interpret=interpret, block_b=block_b,
                                 vmem_budget=vmem_budget,
                                 stash_dtype=stash_dtype,
                                 seq_chunk=seq_chunk)
        reses.append(res)
        xl = jnp.concatenate(ys, axis=-1)
    return xl, (params, lengths, tuple(reses))


def _stack_vjp_bwd(static, fullres, dy):
    interpret, block_b, vmem_budget, stash_dtype, seq_chunk = static
    params, lengths, reses = fullres
    layers = _stack_layers(params)
    H = layers[0][1].shape[0]
    dparams = [None] * len(layers)
    for li in reversed(range(len(layers))):
        (wxf, whf, bf, wxb, whb, bb_) = layers[li]
        grads, dx = _run_bwd_train(
            ((wxf, whf, bf), (wxb, whb, bb_)), reses[li],
            (dy[..., :H], dy[..., H:]), _BLSTM_REVS,
            interpret=interpret, block_b=block_b,
            vmem_budget=vmem_budget, stash_dtype=stash_dtype,
            seq_chunk=seq_chunk)
        (dwxf, dwhf, dbf), (dwxb, dwhb, dbb) = grads
        dparams[li] = (dwxf.astype(wxf.dtype), dwhf.astype(whf.dtype),
                       dbf.astype(bf.dtype), dwxb.astype(wxb.dtype),
                       dwhb.astype(whb.dtype), dbb.astype(bb_.dtype))
        dy = dx.astype(reses[li][0].dtype)   # next layer down's cotangent
    return tuple(dparams), dy, _len_cotangent(lengths)


_stack_vjp.defvjp(_stack_vjp_fwd, _stack_vjp_bwd)


def blstm_stack_sequence(params, x, lengths=None, *,
                         interpret: bool = None, block_b: int = None,
                         vmem_budget: int = None, stash_dtype: str = None,
                         seq_chunk: int = 0):
    """The whole stacked BLSTM as one fused kernel: ``params`` is a
    sequence of per-layer ``(wx_fwd, wh_fwd, b_fwd, wx_bwd, wh_bwd,
    b_bwd)`` tuples (layer 0 consumes x's D features, deeper layers the
    previous layer's 2H); returns (B, T, 2H_last).

    The primal (inference) call keeps the inter-layer activations in
    VMEM — bit-identical to the per-layer :func:`blstm_sequence` loop —
    while under ``jax.vjp`` the custom rules run the per-layer stashing
    forwards/backwards (every layer's output is a residual the backward
    needs anyway), composing with ``lengths``, ``stash_dtype`` and
    ``seq_chunk`` exactly like the single-layer entry points."""
    return _stack_vjp((interpret, block_b, vmem_budget, stash_dtype,
                       seq_chunk or 0), _stack_layers(params), x, lengths)
