"""Pallas TPU fused (B)LSTM sequence kernels — the training hot path.

The paper's acoustic model spends essentially all of its compute in 6
bi-LSTM layers (Table I: 165MB model, 0.07 s/batch); every distributed
strategy in §IV only pays off if this per-learner step is fast.  A
time-step of LSTM is two skinny matmuls plus elementwise gates —
dominated by weight re-reads from HBM if each step round-trips.  The TPU
adaptation keeps the weight matrices and the recurrent (h, c) state
resident in VMEM across the whole unroll and walks time on the inner
sequential grid axis, so HBM traffic per step is just x_t in / h_t out:

  grid = (B//bB, T);  VMEM blocks per direction:
      x_t (bB, D), Wx (D, 4H), Wh (H, 4H), b (4H,); scratch h, c (bB, H).

The batch axis is tiled with ``block_b`` (``bB``): the time axis is the
*inner* (fastest-varying) grid axis so each batch tile walks the whole
recurrence with its own resident (h, c) carry before the grid moves to
the next tile — an outer-batch grid would need every tile's state live
at once and defeat the tiling.  Batches that are not a multiple of
``block_b`` are zero-padded up front and sliced after; padded rows never
pollute weight gradients because their output cotangents are zero.

Gate layout (i|f|g|o) matches ``repro.models.lstm.lstm_cell_step``, which
is the oracle via ``repro.kernels.ref.lstm_ref`` (forget-gate bias +1).

Variable-length masking (``lengths``)
-------------------------------------
Passing a per-row ``lengths`` (B,) int32 vector (the batch contract of
``repro.data.pipeline``) selects the masked kernels: a (bb,) lengths
block rides along the batch grid axis, and on padded steps
(time >= lengths[row]) the (h, c) VMEM carry is FROZEN and the emitted
h_t is zero, so padded frames can never leak into weight gradients.  The
reverse direction thereby reverses *within* each utterance's valid span:
its leading invalid segment (right-padding) carries the zero initial
state untouched until the last valid frame.  The backward kernel mirrors
this — on invalid steps dgates are zeroed and the (dh, dc) carries pass
through unchanged.  Rows added by batch-tile padding get length 0, which
subsumes the zero-cotangent argument above.  Oracle:
``repro.kernels.ref.lstm_ref(..., lengths=...)`` (masked scan).

Three kernel variants share one body (``_make_fwd_kernel``):

* inference forward (``stash=False``) — emits h_t only;
* training forward (``stash=True``) — additionally stashes the
  post-activation gates (bB, 4H) and cell states (bB, H) per step, f32;
* bidirectional fusion (``n_dir=2``) — both directions advance in one
  grid pass (forward direction at time t, reverse direction at T-1-t),
  with both weight sets resident in VMEM and x handed to the kernel
  once; per-direction math is op-for-op identical to the ``n_dir=1``
  kernel, so the fused output is bit-identical to two separate calls.

Backward pass (``_make_bwd_kernel``)
------------------------------------
Wired via ``jax.custom_vjp`` so ``jax.value_and_grad`` through
``models/lstm.loss_train(kernel_impl="pallas")`` works end-to-end.  The
backward kernel walks the time grid in *reverse recurrence order*,
carrying (dh, dc) in VMEM scratch and accumulating dWx (D, 4H),
dWh (H, 4H) and db (4H,) in f32 VMEM-resident output blocks (constant
index maps — the block is zeroed at the first grid program and flushed
once at the end), while emitting dx_t per step.  h_{t-1} is re-read from
the stashed forward output y (the value that actually entered the
recurrent matmul, post bf16 rounding), c_{t-1}/c_t from the stashed cell
states, and the gate nonlinearities come from the stashed activations —
only tanh(c_t) is recomputed.

Residual stashing vs recompute
------------------------------
We stash post-activation gates + cell states, by default in f32:
4H + H = 5H floats per (row, step) — for the paper shape
(B=256, T=21, H=512) that is 256*21*5*512*4B ≈ 55MB HBM per direction,
written once in the forward and read once in the backward.
``stash_dtype="bfloat16"`` halves that stash (gates are in [-1, 1] so
bf16's 8 relative bits cost ~1e-2 normalized grad error — the relaxed
tolerance of the parity test); the backward upcasts to f32 on read and
its dW accumulators stay f32 either way.  The
alternative — recomputing gates in the backward — saves that HBM
traffic but re-runs both matmuls (2/3 of the step FLOPs) and still has
to stash or recompute the cell-state sequence for df/dc; on TPU the
matmul units are the scarce resource for this skinny shape, so we trade
HBM capacity for MXU time (same choice cuDNN makes).  Revisit if T
grows beyond a few hundred frames (then a seq-chunked recompute —
stash c every K steps, recompute gates within a chunk — wins).

VMEM budget and ``block_b`` auto-tuning
---------------------------------------
``auto_block_b`` picks the largest power-of-two batch tile whose
resident set fits ``vmem_budget`` (default 12MB of a 16MB v5e core),
estimating the worse of the two training kernels:

  stashing fwd:  n_dir * (D*4H + H*4H + 4H) * itemsize   (weights)
                 + 2 * n_dir * bB * (D + H) * itemsize   (x/y streams)
                 + n_dir * 2 * bB * H * 4                (h, c carries)
                 + 2 * n_dir * bB * 5H * 4               (stash blocks)
  backward (one direction at a time):
                 (D*4H + H*4H + 4H) * (itemsize + 4)     (weights +
                                                          f32 dW accum)
                 + streamed dy/stash/x/dx blocks + (dh, dc) carries

For the paper shape (D=260, H=512, bf16) one direction's weights plus
its f32 gradient accumulators already cost ~9.5MB, so training at
B=256 auto-tiles to bB=64 at the 12MB default (bB=8 floor under 10MB);
pure inference holds both directions' weights in 6.3MB and fits
bB=256 outright.  A single tile never pads past the 8-row sublane
multiple (B=96 runs as one 96-row tile, not a padded 128-row one).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_VMEM_BUDGET = 12 * 2 ** 20


def _resolve_interpret(interpret):
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def _round_up(n: int, m: int) -> int:
    return -(-n // m) * m


def auto_block_b(B: int, D: int, H: int, itemsize: int, *, n_dir: int = 1,
                 training: bool = False, vmem_budget: int = None,
                 stash_itemsize: int = 4) -> int:
    """Largest power-of-two batch tile whose resident set fits the VMEM
    budget (see module docstring for the byte math).  Floors at 8 rows
    (the f32 sublane tile) even when the budget is overrun — at that
    point the weights themselves are the problem, not the tile.
    ``stash_itemsize`` reflects the gate/cell residual stash dtype (2 for
    the bf16 stash option)."""
    budget = vmem_budget or DEFAULT_VMEM_BUDGET
    wparams = D * 4 * H + H * 4 * H + 4 * H

    def usage(bb):
        weights = n_dir * wparams * itemsize
        streamed = 2 * n_dir * bb * (D + H) * itemsize
        carries = n_dir * 2 * bb * H * 4
        if not training:
            return weights + streamed + carries
        # worst single-kernel resident set of the training pair:
        # (a) stashing forward — all directions' weights + gate/cell
        #     stash blocks;  (b) backward — runs ONE direction at a time:
        #     that direction's weights + its f32 dWx/dWh/db accumulators
        #     + the streamed dy/stash/x/dx blocks + (dh, dc) carries.
        fwd = (weights + streamed + carries
               + 2 * n_dir * bb * 5 * H * stash_itemsize)
        bwd = (wparams * (itemsize + 4)
               + 2 * bb * (D + H) * itemsize
               + 2 * bb * 5 * H * stash_itemsize
               + 2 * bb * H * 4
               + 2 * bb * H * 4)
        return max(fwd, bwd)

    bb = max(8, 1 << (max(B, 1) - 1).bit_length())
    while bb > 8 and usage(bb) > budget:
        bb //= 2
    if bb >= B:
        # single tile: don't pad past the sublane multiple (B=96 should
        # run as one 96-row tile, not a zero-padded 128-row one)
        bb = max(8, _round_up(B, 8))
    return bb


def _pad_rows(a, Bp):
    B = a.shape[0]
    if B == Bp:
        return a
    return jnp.pad(a, ((0, Bp - B),) + ((0, 0),) * (a.ndim - 1))


def _stash_dtype(stash_dtype):
    return jnp.dtype(stash_dtype or "float32")


def _tile(x, n_dir: int, H: int, block_b, vmem_budget, *, training: bool,
          stash_itemsize: int = 4):
    """The single source of the (block_b, padded_B) pair.  The stashing
    forward and the backward wrapper both derive the tile through here
    with ``training=True`` and identical arguments, so the backward's
    grid covers exactly the rows the forward padded (``_run_bwd``
    asserts the invariant)."""
    if block_b is not None and block_b < 0:
        raise ValueError(f"block_b must be positive or 0/None (auto), "
                         f"got {block_b}")
    B, _, D = x.shape
    bb = block_b or auto_block_b(B, D, H, jnp.dtype(x.dtype).itemsize,
                                 n_dir=n_dir, training=training,
                                 vmem_budget=vmem_budget,
                                 stash_itemsize=stash_itemsize)
    return bb, _round_up(B, bb)


# ---------------------------------------------------------------------------
# forward kernels (inference / training-with-stash, uni- or bidirectional)
# ---------------------------------------------------------------------------

def _make_fwd_kernel(n_dir: int, stash: bool, revs=None):
    """Kernel body over refs laid out as:

    inputs:  x * n_dir, then (wx, wh, b) * n_dir, then lengths if masked
    outputs: y * n_dir, then (acts, cseq) * n_dir if ``stash``
    scratch: (h, c) * n_dir

    ``revs`` enables masking: it carries each direction's reverse flag so
    the body can recover the real time index of grid step t and freeze
    the (h, c) carry / zero the output on padded steps.
    """
    masked = revs is not None
    n_in = 4 * n_dir + (1 if masked else 0)
    n_out = n_dir * (3 if stash else 1)

    def kernel(*refs):
        x_refs = refs[:n_dir]
        w_refs = refs[n_dir:4 * n_dir]
        out_refs = refs[n_in:n_in + n_out]
        scr_refs = refs[n_in + n_out:]
        t = pl.program_id(1)
        if masked:
            lens = refs[4 * n_dir][...]                     # (bb,) int32
            T = pl.num_programs(1)

        for d in range(n_dir):
            wx_ref, wh_ref, b_ref = w_refs[3 * d:3 * d + 3]
            h_ref, c_ref = scr_refs[2 * d:2 * d + 2]

            @pl.when(t == 0)
            def _init(h_ref=h_ref, c_ref=c_ref):
                h_ref[...] = jnp.zeros_like(h_ref)
                c_ref[...] = jnp.zeros_like(c_ref)

            x = x_refs[d][...]
            h = h_ref[...]
            c_prev = c_ref[...]
            gates = (
                jax.lax.dot_general(x, wx_ref[...], (((1,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)
                + jax.lax.dot_general(h.astype(x.dtype), wh_ref[...],
                                      (((1,), (0,)), ((), ())),
                                      preferred_element_type=jnp.float32)
                + b_ref[...][None, :]
            )
            H = h_ref.shape[-1]
            i = jax.nn.sigmoid(gates[:, 0 * H:1 * H])
            f = jax.nn.sigmoid(gates[:, 1 * H:2 * H] + 1.0)
            g = jnp.tanh(gates[:, 2 * H:3 * H])
            o = jax.nn.sigmoid(gates[:, 3 * H:4 * H])
            c = f * c_prev + i * g
            h_new = o * jnp.tanh(c)
            if masked:
                time_idx = (T - 1 - t) if revs[d] else t
                vm = (time_idx < lens)[:, None]
                c = jnp.where(vm, c, c_prev)                # freeze carry
                y = jnp.where(vm, h_new, jnp.zeros_like(h_new))
                h_new = jnp.where(vm, h_new, h)
            else:
                y = h_new
            c_ref[...] = c
            h_ref[...] = h_new
            out_refs[d][...] = y.astype(out_refs[d].dtype)
            if stash:
                acts_ref = out_refs[n_dir + 2 * d]
                cseq_ref = out_refs[n_dir + 2 * d + 1]
                acts_ref[...] = jnp.concatenate(
                    [i, f, g, o], axis=-1).astype(acts_ref.dtype)
                cseq_ref[...] = c.astype(cseq_ref.dtype)

    return kernel


def _xmap(T: int, reverse: bool):
    if reverse:
        return lambda ib, t: (ib, T - 1 - t, 0)
    return lambda ib, t: (ib, t, 0)


def _run_fwd(ws, x, revs, *, stash: bool, block_b, vmem_budget, interpret,
             lengths=None, stash_dtype=None):
    """Run the forward kernel for one or two directions in one grid pass.

    ws: ((wx, wh, b), ...) per direction; revs: matching reverse flags.
    ``lengths`` (B,) int32 selects the masked kernel (padded rows of the
    batch tile get length 0).  Returns (outs, bb): outs is the flat
    pallas output list over the *padded* batch (y per direction, then
    (acts, cseq) pairs if stash, in ``stash_dtype``).
    """
    B, T, D = x.shape
    H = ws[0][1].shape[0]
    n_dir = len(ws)
    sdt = _stash_dtype(stash_dtype)
    bb, Bp = _tile(x, n_dir, H, block_b, vmem_budget, training=stash,
                   stash_itemsize=sdt.itemsize)
    xp = _pad_rows(x, Bp)
    grid = (Bp // bb, T)

    operands, in_specs = [], []
    for rev in revs:
        operands.append(xp)
        in_specs.append(pl.BlockSpec((bb, None, D), _xmap(T, rev)))
    for wx, wh, b in ws:
        operands += [wx, wh, b]
        in_specs += [
            pl.BlockSpec((D, 4 * H), lambda ib, t: (0, 0)),
            pl.BlockSpec((H, 4 * H), lambda ib, t: (0, 0)),
            pl.BlockSpec((4 * H,), lambda ib, t: (0,)),
        ]
    if lengths is not None:
        operands.append(_pad_rows(lengths.astype(jnp.int32), Bp))
        in_specs.append(pl.BlockSpec((bb,), lambda ib, t: (ib,)))

    out_specs = [pl.BlockSpec((bb, None, H), _xmap(T, rev)) for rev in revs]
    out_shape = [jax.ShapeDtypeStruct((Bp, T, H), x.dtype) for _ in revs]
    if stash:
        for rev in revs:
            out_specs += [pl.BlockSpec((bb, None, 4 * H), _xmap(T, rev)),
                          pl.BlockSpec((bb, None, H), _xmap(T, rev))]
            out_shape += [jax.ShapeDtypeStruct((Bp, T, 4 * H), sdt),
                          jax.ShapeDtypeStruct((Bp, T, H), sdt)]

    scratch = []
    for _ in revs:
        scratch += [pltpu.VMEM((bb, H), jnp.float32),
                    pltpu.VMEM((bb, H), jnp.float32)]

    outs = pl.pallas_call(
        _make_fwd_kernel(n_dir, stash,
                         revs if lengths is not None else None),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=scratch,
        interpret=_resolve_interpret(interpret),
    )(*operands)
    return list(outs), bb


# ---------------------------------------------------------------------------
# backward kernel (one direction; the BLSTM VJP runs it once per direction)
# ---------------------------------------------------------------------------

def _make_bwd_kernel(reverse: bool, masked: bool):
    """One reverse-recurrence step.  Grid (B//bB, T); grid axis 1 walks
    the recurrence backwards (index maps reverse time), carrying (dh, dc)
    in scratch and accumulating dWx/dWh/db into constant-mapped f32
    output blocks that stay VMEM-resident for the whole grid.

    ``masked`` adds a trailing lengths input: on padded steps dgates are
    zeroed (so dx and the dW accumulators see nothing) and the (dh, dc)
    carries pass through unchanged — the exact VJP of the frozen-carry
    forward.  ``reverse`` is only consulted when masked (to recover the
    real time index of grid step r)."""

    def kernel(*refs):
        (dy_ref, acts_ref, c_ref, cprev_ref, hprev_ref, x_ref,
         wx_ref, wh_ref) = refs[:8]
        len_ref = refs[8] if masked else None
        (dx_ref, dwx_ref, dwh_ref, db_ref,
         dh_ref, dc_ref) = refs[8 + (1 if masked else 0):]
        ib = pl.program_id(0)
        r = pl.program_id(1)

        @pl.when(r == 0)
        def _init_carry():
            dh_ref[...] = jnp.zeros_like(dh_ref)
            dc_ref[...] = jnp.zeros_like(dc_ref)

        @pl.when((r == 0) & (ib == 0))
        def _init_accum():
            dwx_ref[...] = jnp.zeros_like(dwx_ref)
            dwh_ref[...] = jnp.zeros_like(dwh_ref)
            db_ref[...] = jnp.zeros_like(db_ref)

        # the last grid step is the *first* step of the original
        # recurrence: its h_{t-1}/c_{t-1} are the zero initial state,
        # not array values
        boundary = r == pl.num_programs(1) - 1
        H = dh_ref.shape[-1]
        acts = acts_ref[...].astype(jnp.float32)
        i = acts[:, 0 * H:1 * H]
        f = acts[:, 1 * H:2 * H]
        g = acts[:, 2 * H:3 * H]
        o = acts[:, 3 * H:4 * H]
        c = c_ref[...].astype(jnp.float32)
        zero = jnp.zeros_like(c)
        c_prev = jnp.where(boundary, zero,
                           cprev_ref[...].astype(jnp.float32))
        h_prev = jnp.where(boundary, zero,
                           hprev_ref[...].astype(jnp.float32))

        dh_carry = dh_ref[...]
        dc_carry = dc_ref[...]
        dh = dy_ref[...].astype(jnp.float32) + dh_carry
        tc = jnp.tanh(c)
        dc = dh * o * (1.0 - tc * tc) + dc_carry
        if masked:
            T = pl.num_programs(1)
            time_idx = r if reverse else T - 1 - r
            vm = (time_idx < len_ref[...])[:, None]
            dh = jnp.where(vm, dh, zero)
            dc = jnp.where(vm, dc, zero)
        dgates = jnp.concatenate([
            dc * g * i * (1.0 - i),          # d pre-act input gate
            dc * c_prev * f * (1.0 - f),     # d pre-act forget gate
            dc * i * (1.0 - g * g),          # d pre-act cell candidate
            dh * tc * o * (1.0 - o),         # d pre-act output gate
        ], axis=-1)

        wx = wx_ref[...].astype(jnp.float32)
        wh = wh_ref[...].astype(jnp.float32)
        dx_ref[...] = jax.lax.dot_general(
            dgates, wx, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32).astype(dx_ref.dtype)
        dh_new = jax.lax.dot_general(
            dgates, wh, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        dc_new = dc * f
        if masked:
            # padded step: h_t = h_{t-1}, c_t = c_{t-1} — the carries
            # pass straight through
            dh_new = jnp.where(vm, dh_new, dh_carry)
            dc_new = jnp.where(vm, dc_new, dc_carry)
        dh_ref[...] = dh_new
        dc_ref[...] = dc_new

        x = x_ref[...].astype(jnp.float32)
        dwx_ref[...] += jax.lax.dot_general(
            x, dgates, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        dwh_ref[...] += jax.lax.dot_general(
            h_prev, dgates, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        db_ref[...] += jnp.sum(dgates, axis=0)

    return kernel


def _bwd_tmap(T: int, reverse: bool):
    """Time index of the step grid position r processes (reverse
    recurrence order: the forward direction walks T-1..0)."""
    if reverse:
        return lambda ib, r: (ib, r, 0)
    return lambda ib, r: (ib, T - 1 - r, 0)


def _bwd_pmap(T: int, reverse: bool):
    """Time index of the *previous* recurrence step (clamped at the
    boundary; the kernel zeroes the value there)."""
    if reverse:
        return lambda ib, r: (ib, jnp.minimum(r + 1, T - 1), 0)
    return lambda ib, r: (ib, jnp.maximum(T - 2 - r, 0), 0)


def _run_bwd(wx, wh, xp, yp, acts, cseq, dyp, *, reverse: bool, bb: int,
             interpret, lengths_p=None):
    """Backward kernel over padded arrays -> (dxp, dwx, dwh, db), f32
    weight grads (caller casts to param dtypes).  ``lengths_p`` is the
    row-padded (Bp,) lengths vector for the masked VJP (None = dense)."""
    Bp, T, D = xp.shape
    H = wh.shape[0]
    assert Bp % bb == 0, (Bp, bb)   # forward/backward tile lockstep
    grid = (Bp // bb, T)
    tmap = _bwd_tmap(T, reverse)
    pmap = _bwd_pmap(T, reverse)
    masked = lengths_p is not None

    in_specs = [
        pl.BlockSpec((bb, None, H), tmap),          # dy_t
        pl.BlockSpec((bb, None, 4 * H), tmap),      # stashed gates_t
        pl.BlockSpec((bb, None, H), tmap),          # c_t
        pl.BlockSpec((bb, None, H), pmap),          # c_{t-1}
        pl.BlockSpec((bb, None, H), pmap),          # h_{t-1} (= y)
        pl.BlockSpec((bb, None, D), tmap),          # x_t
        pl.BlockSpec((D, 4 * H), lambda ib, r: (0, 0)),
        pl.BlockSpec((H, 4 * H), lambda ib, r: (0, 0)),
    ]
    operands = [dyp, acts, cseq, cseq, yp, xp, wx, wh]
    if masked:
        in_specs.append(pl.BlockSpec((bb,), lambda ib, r: (ib,)))
        operands.append(lengths_p)

    return pl.pallas_call(
        _make_bwd_kernel(reverse, masked),
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((bb, None, D), tmap),
            pl.BlockSpec((D, 4 * H), lambda ib, r: (0, 0)),
            pl.BlockSpec((H, 4 * H), lambda ib, r: (0, 0)),
            pl.BlockSpec((4 * H,), lambda ib, r: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bp, T, D), xp.dtype),
            jax.ShapeDtypeStruct((D, 4 * H), jnp.float32),
            jax.ShapeDtypeStruct((H, 4 * H), jnp.float32),
            jax.ShapeDtypeStruct((4 * H,), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bb, H), jnp.float32),
            pltpu.VMEM((bb, H), jnp.float32),
        ],
        interpret=_resolve_interpret(interpret),
    )(*operands)


# ---------------------------------------------------------------------------
# custom-VJP wiring: unidirectional
# ---------------------------------------------------------------------------

def _len_cotangent(lengths):
    """Cotangent for the integer lengths input (float0 per JAX's rule for
    non-differentiable primal dtypes; None when lengths wasn't passed)."""
    if lengths is None:
        return None
    return np.zeros(lengths.shape, jax.dtypes.float0)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _lstm_vjp(static, wx, wh, b, x, lengths):
    reverse, interpret, block_b, vmem_budget, stash_dtype = static
    outs, _ = _run_fwd(((wx, wh, b),), x, (reverse,), stash=False,
                       block_b=block_b, vmem_budget=vmem_budget,
                       interpret=interpret, lengths=lengths)
    return outs[0][:x.shape[0]]


def _lstm_vjp_fwd(static, wx, wh, b, x, lengths):
    reverse, interpret, block_b, vmem_budget, stash_dtype = static
    outs, _ = _run_fwd(((wx, wh, b),), x, (reverse,), stash=True,
                       block_b=block_b, vmem_budget=vmem_budget,
                       interpret=interpret, lengths=lengths,
                       stash_dtype=stash_dtype)
    yp, acts, cseq = outs
    return yp[:x.shape[0]], (wx, wh, b, x, lengths, yp, acts, cseq)


def _lstm_vjp_bwd(static, res, dy):
    reverse, interpret, block_b, vmem_budget, stash_dtype = static
    wx, wh, b, x, lengths, yp, acts, cseq = res
    B = x.shape[0]
    bb, Bp = _tile(x, 1, wh.shape[0], block_b, vmem_budget, training=True,
                   stash_itemsize=_stash_dtype(stash_dtype).itemsize)
    assert Bp == yp.shape[0], (Bp, yp.shape)
    lp = (None if lengths is None
          else _pad_rows(lengths.astype(jnp.int32), Bp))
    dxp, dwx, dwh, db = _run_bwd(
        wx, wh, _pad_rows(x, Bp), yp, acts, cseq, _pad_rows(dy, Bp),
        reverse=reverse, bb=bb, interpret=interpret, lengths_p=lp)
    return (dwx.astype(wx.dtype), dwh.astype(wh.dtype),
            db.astype(b.dtype), dxp[:B].astype(x.dtype),
            _len_cotangent(lengths))


_lstm_vjp.defvjp(_lstm_vjp_fwd, _lstm_vjp_bwd)


def lstm_sequence(wx, wh, b, x, lengths=None, *, reverse: bool = False,
                  interpret: bool = None, block_b: int = None,
                  vmem_budget: int = None, stash_dtype: str = None):
    """x: (B, T, D) -> (B, T, H); weights wx (D,4H), wh (H,4H), b (4H,).

    Differentiable (custom VJP; see module docstring).  ``block_b``
    tiles the batch (None -> :func:`auto_block_b`).  ``lengths`` (B,)
    int selects the masked recurrence (frozen carry + zeroed output on
    padded steps); ``stash_dtype`` ('float32' | 'bfloat16') sets the
    training-forward residual stash precision."""
    return _lstm_vjp((bool(reverse), interpret, block_b, vmem_budget,
                      stash_dtype), wx, wh, b, x, lengths)


# ---------------------------------------------------------------------------
# custom-VJP wiring: fused bidirectional
# ---------------------------------------------------------------------------

_BLSTM_REVS = (False, True)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _blstm_vjp(static, wxf, whf, bf, wxb, whb, bb_, x, lengths):
    interpret, block_b, vmem_budget, stash_dtype = static
    outs, _ = _run_fwd(((wxf, whf, bf), (wxb, whb, bb_)), x, _BLSTM_REVS,
                       stash=False, block_b=block_b,
                       vmem_budget=vmem_budget, interpret=interpret,
                       lengths=lengths)
    B = x.shape[0]
    return jnp.concatenate([outs[0][:B], outs[1][:B]], axis=-1)


def _blstm_vjp_fwd(static, wxf, whf, bf, wxb, whb, bb_, x, lengths):
    interpret, block_b, vmem_budget, stash_dtype = static
    outs, _ = _run_fwd(((wxf, whf, bf), (wxb, whb, bb_)), x, _BLSTM_REVS,
                       stash=True, block_b=block_b,
                       vmem_budget=vmem_budget, interpret=interpret,
                       lengths=lengths, stash_dtype=stash_dtype)
    yf, yb, acts_f, cseq_f, acts_b, cseq_b = outs
    B = x.shape[0]
    y = jnp.concatenate([yf[:B], yb[:B]], axis=-1)
    return y, (wxf, whf, bf, wxb, whb, bb_, x, lengths,
               yf, acts_f, cseq_f, yb, acts_b, cseq_b)


def _blstm_vjp_bwd(static, res, dy):
    interpret, block_b, vmem_budget, stash_dtype = static
    (wxf, whf, bf, wxb, whb, bb_, x, lengths,
     yf, acts_f, cseq_f, yb, acts_b, cseq_b) = res
    B = x.shape[0]
    H = whf.shape[0]
    bb, Bp = _tile(x, 2, H, block_b, vmem_budget, training=True,
                   stash_itemsize=_stash_dtype(stash_dtype).itemsize)
    assert Bp == yf.shape[0], (Bp, yf.shape)
    xp = _pad_rows(x, Bp)
    lp = (None if lengths is None
          else _pad_rows(lengths.astype(jnp.int32), Bp))
    dyf = _pad_rows(dy[..., :H], Bp)
    dyb = _pad_rows(dy[..., H:], Bp)
    dxf, dwxf, dwhf, dbf = _run_bwd(wxf, whf, xp, yf, acts_f, cseq_f, dyf,
                                    reverse=False, bb=bb,
                                    interpret=interpret, lengths_p=lp)
    dxb, dwxb, dwhb, dbb = _run_bwd(wxb, whb, xp, yb, acts_b, cseq_b, dyb,
                                    reverse=True, bb=bb,
                                    interpret=interpret, lengths_p=lp)
    dx = (dxf.astype(jnp.float32) + dxb.astype(jnp.float32))[:B]
    return (dwxf.astype(wxf.dtype), dwhf.astype(whf.dtype),
            dbf.astype(bf.dtype), dwxb.astype(wxb.dtype),
            dwhb.astype(whb.dtype), dbb.astype(bb_.dtype),
            dx.astype(x.dtype), _len_cotangent(lengths))


_blstm_vjp.defvjp(_blstm_vjp_fwd, _blstm_vjp_bwd)


def blstm_sequence(wx_fwd, wh_fwd, b_fwd, wx_bwd, wh_bwd, b_bwd, x,
                   lengths=None, *, interpret: bool = None,
                   block_b: int = None, vmem_budget: int = None,
                   stash_dtype: str = None):
    """Fused bidirectional layer: x (B, T, D) -> (B, T, 2H) with the
    forward-direction output in [..., :H] and the time-reversed
    direction in [..., H:] — one kernel invocation, both weight sets
    resident, bit-identical to two :func:`lstm_sequence` calls.

    ``lengths`` (B,) int masks padded steps (the reverse direction then
    reverses within each row's valid span); ``stash_dtype`` sets the
    training-forward residual stash precision."""
    return _blstm_vjp((interpret, block_b, vmem_budget, stash_dtype),
                      wx_fwd, wh_fwd, b_fwd, wx_bwd, wh_bwd, b_bwd, x,
                      lengths)
