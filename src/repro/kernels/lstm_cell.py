"""Pallas TPU fused LSTM sequence kernel (the paper's compute hot-spot).

The paper's acoustic model spends its time in 6 bi-LSTM layers (Table I:
165MB model, 0.07 s/batch on a P100).  A time-step of LSTM is two skinny
matmuls plus elementwise gates — dominated by weight re-reads from HBM if
each step round-trips.  The TPU adaptation keeps BOTH weight matrices and
the recurrent (h, c) state resident in VMEM across the whole unroll and
walks time on the sequential grid axis, so HBM traffic per step is just
x_t in / h_t out:

  grid = (T,);  VMEM blocks: x_t (B,D), Wx (D,4H), Wh (H,4H); scratch h,c.

Gate layout (i|f|g|o) matches ``repro.models.lstm.lstm_cell_step``, which
is the oracle via ``repro.kernels.ref.lstm_ref`` (forget-gate bias +1).

For the paper's shape (D=260, H=512, 4H=2048) everything fits easily:
Wx+Wh ≈ 0.8M params ≈ 1.6MB bf16, per-step state B×H×8B ≈ 1MB at B=256.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _lstm_kernel(x_ref, wx_ref, wh_ref, b_ref, o_ref, h_ref, c_ref):
    """One time step.  x_ref: (B, D); o_ref: (B, H); scratch h/c: (B, H)."""
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)
        c_ref[...] = jnp.zeros_like(c_ref)

    x = x_ref[...]
    h = h_ref[...]
    gates = (
        jax.lax.dot_general(x, wx_ref[...], (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
        + jax.lax.dot_general(h.astype(x.dtype), wh_ref[...],
                              (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
        + b_ref[...][None, :]
    )
    H = h_ref.shape[-1]
    i = gates[:, 0 * H:1 * H]
    f = gates[:, 1 * H:2 * H]
    g = gates[:, 2 * H:3 * H]
    o = gates[:, 3 * H:4 * H]
    c = (jax.nn.sigmoid(f + 1.0) * c_ref[...]
         + jax.nn.sigmoid(i) * jnp.tanh(g))
    h_new = jax.nn.sigmoid(o) * jnp.tanh(c)
    c_ref[...] = c
    h_ref[...] = h_new
    o_ref[...] = h_new.astype(o_ref.dtype)


def lstm_sequence(wx, wh, b, x, *, reverse: bool = False,
                  interpret: bool = None):
    """x: (B, T, D) -> (B, T, H); weights wx (D,4H), wh (H,4H), b (4H,)."""
    B, T, D = x.shape
    H = wh.shape[0]
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    def x_map(t):
        return (0, (T - 1 - t) if reverse else t, 0)

    return pl.pallas_call(
        _lstm_kernel,
        grid=(T,),
        in_specs=[
            pl.BlockSpec((B, None, D), x_map),
            pl.BlockSpec((D, 4 * H), lambda t: (0, 0)),
            pl.BlockSpec((H, 4 * H), lambda t: (0, 0)),
            pl.BlockSpec((4 * H,), lambda t: (0,)),
        ],
        out_specs=pl.BlockSpec((B, None, H), x_map),
        out_shape=jax.ShapeDtypeStruct((B, T, H), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((B, H), jnp.float32),
            pltpu.VMEM((B, H), jnp.float32),
        ],
        interpret=interpret,
    )(x, wx, wh, b)
