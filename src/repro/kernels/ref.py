"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

Each reference is written for clarity/exactness, not speed:

* ``attention_ref``  — full softmax attention with causal/window masks.
* ``lstm_ref``       — step-by-step LSTM via ``repro.models.lstm``.
* ``ssd_ref``        — the exact sequential SSM recurrence (no chunking),
                       which also oracles ``repro.models.ssm.ssd_chunked``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  q_offset: int = 0):
    """q: (B,Sq,H,E); k/v: (B,Sk,KV,E) -> (B,Sq,H,E), f32 math."""
    B, Sq, H, E = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    M = H // KV
    qg = q.reshape(B, Sq, KV, M, E).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bsgme,btge->bgmst", qg, kf) / np.sqrt(E)
    if causal:
        q_pos = q_offset + jnp.arange(Sq)
        k_pos = jnp.arange(Sk)
        ok = q_pos[:, None] >= k_pos[None, :]
        if window > 0:
            ok &= (q_pos[:, None] - k_pos[None, :]) < window
        s = jnp.where(ok[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgmst,btge->bsgme", p, vf)
    return o.reshape(B, Sq, H, E).astype(q.dtype)


def lstm_ref(wx, wh, b, x, *, reverse: bool = False, lengths=None):
    """Matches kernels.lstm_cell.lstm_sequence; gate order i|f|g|o,
    forget bias +1.  With ``lengths`` (B,) this is the masked scan
    oracle: the (h, c) carry is frozen and the output zeroed on padded
    steps (t >= lengths[b]), so the reverse direction reverses within
    each row's valid span."""
    from repro.models.lstm import lstm_cell_step

    B, T, D = x.shape
    H = wh.shape[0]
    h = jnp.zeros((B, H), x.dtype)
    c = jnp.zeros((B, H), jnp.float32)

    if lengths is None:
        def step(carry, x_t):
            h, c = carry
            h, c = lstm_cell_step(wx, wh, b, x_t, h, c)
            return (h, c), h

        xs = jnp.moveaxis(x, 1, 0)
        _, hs = jax.lax.scan(step, (h, c), xs, reverse=reverse)
        return jnp.moveaxis(hs, 0, 1)

    def step(carry, inp):
        x_t, t = inp
        h, c = carry
        h2, c2 = lstm_cell_step(wx, wh, b, x_t, h, c)
        v = (t < lengths)[:, None]
        h = jnp.where(v, h2, h)
        c = jnp.where(v, c2, c)
        return (h, c), jnp.where(v, h2, jnp.zeros_like(h2))

    xs = jnp.moveaxis(x, 1, 0)
    _, hs = jax.lax.scan(step, (h, c), (xs, jnp.arange(T)), reverse=reverse)
    return jnp.moveaxis(hs, 0, 1)


def blstm_ref(wx_fwd, wh_fwd, b_fwd, wx_bwd, wh_bwd, b_bwd, x,
              lengths=None):
    """Oracle for kernels.lstm_cell.blstm_sequence: the two directions run
    separately and concatenate on the feature axis."""
    return jnp.concatenate(
        [lstm_ref(wx_fwd, wh_fwd, b_fwd, x, lengths=lengths),
         lstm_ref(wx_bwd, wh_bwd, b_bwd, x, reverse=True,
                  lengths=lengths)], axis=-1)


def blstm_stack_ref(layers, x, lengths=None):
    """Oracle for kernels.lstm_cell.blstm_stack_sequence: the per-layer
    loop of :func:`blstm_ref` (each layer consumes the previous layer's
    (B, T, 2H) output)."""
    for (wxf, whf, bf, wxb, whb, bb) in layers:
        x = blstm_ref(wxf, whf, bf, wxb, whb, bb, x, lengths=lengths)
    return x


def ssd_ref(x, dt, A, Bm, Cm):
    """Exact token-by-token SSM recurrence.

    x: (B,S,H,P), dt: (B,S,H), A: (H,), Bm/Cm: (B,S,H,N).
    h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t^T ;  y_t = C_t . h_t
    Returns (y (B,S,H,P) like x.dtype, h_final (B,H,N,P) f32).
    """
    B, S, H, Pd = x.shape
    N = Bm.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = Bm.astype(jnp.float32)
    Cf = Cm.astype(jnp.float32)

    def step(h, inp):
        x_t, dt_t, B_t, C_t = inp
        dA = jnp.exp(dt_t * A)                       # (B,H)
        h = (dA[:, :, None, None] * h
             + jnp.einsum("bhn,bh,bhp->bhnp", B_t, dt_t, x_t))
        y = jnp.einsum("bhn,bhnp->bhp", C_t, h)
        return h, y

    h0 = jnp.zeros((B, H, N, Pd), jnp.float32)
    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (xf, dtf, Bf, Cf))
    h_final, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), h_final


def moe_dense_ref(x, router_w, wi, wg, wo, *, act: str = "swiglu"):
    """Oracle for kernels.moe_dense: y = sum_e w[:,e] * ffn_e(x)."""
    h = jnp.einsum("td,edf->tef", x, wi)
    if act == "swiglu":
        g = jnp.einsum("td,edf->tef", x, wg)
        h = jax.nn.silu(g.astype(jnp.float32)) * h.astype(jnp.float32)
    else:
        h = jax.nn.gelu(h.astype(jnp.float32))
    ye = jnp.einsum("tef,efd->ted", h.astype(x.dtype), wo)
    return jnp.einsum("ted,te->td", ye.astype(jnp.float32),
                      router_w.astype(jnp.float32)).astype(x.dtype)
