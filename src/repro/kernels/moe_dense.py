"""Pallas TPU fused dense-MoE kernel (granite-style tiny experts).

Motivated directly by the §Perf attention-ablation measurement
(benchmarks/flash_projection.py): after the combine fusion, granite's
residual memory term is the HBM round-trip of every expert's hidden
activations — (tokens, E, d_ff) at d_ff=512, E=40 is a 5× inflation of the
active work and none of it needs to leave VMEM.

This kernel keeps one token tile (x: tile_t × d) resident, walks experts on
the inner grid axis streaming each expert's (wi, wg, wo) through VMEM once
per tile, and accumulates the router-weighted output in a VMEM scratch:

  grid = (n_token_tiles, E)
  y[tile] = sum_e router_w[tile, e] * swiglu(x wi_e, x wg_e) wo_e

HBM traffic per layer ≈ x + y + n_tiles × expert weights — the hidden
(tokens, E, d_ff) tensor never materializes.  Oracle:
``repro.kernels.ref.moe_dense_ref`` (== models/moe.py dense path).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _moe_kernel(x_ref, w_ref, wi_ref, wg_ref, wo_ref, y_ref, acc_ref, *,
                act: str):
    """x_ref: (T, d); w_ref: (T, E_block=1) router weights for this expert;
    wi/wg: (d, f); wo: (f, d); y_ref: (T, d); acc: (T, d) f32 scratch."""
    e = pl.program_id(1)
    n_e = pl.num_programs(1)

    @pl.when(e == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    h = jax.lax.dot_general(x, wi_ref[...], (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    if act == "swiglu":
        g = jax.lax.dot_general(x, wg_ref[...], (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    ye = jax.lax.dot_general(h.astype(x.dtype), wo_ref[...],
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] += ye * w_ref[...].astype(jnp.float32)

    @pl.when(e == n_e - 1)
    def _emit():
        y_ref[...] = acc_ref[...].astype(y_ref.dtype)


def moe_dense(x, router_w, wi, wg, wo, *, act: str = "swiglu",
              tile_t: int = 1024, interpret: bool = None):
    """x: (T, d); router_w: (T, E) combine weights (0 for unselected);
    wi/wg: (E, d, f); wo: (E, f, d).  Returns (T, d)."""
    T, d = x.shape
    E, _, f = wi.shape
    tile_t = min(tile_t, T)
    assert T % tile_t == 0, (T, tile_t)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    kernel = functools.partial(_moe_kernel, act=act)
    return pl.pallas_call(
        kernel,
        grid=(T // tile_t, E),
        in_specs=[
            pl.BlockSpec((tile_t, d), lambda t, e: (t, 0)),
            pl.BlockSpec((tile_t, 1), lambda t, e: (t, e)),
            pl.BlockSpec((None, d, f), lambda t, e: (e, 0, 0)),
            pl.BlockSpec((None, d, f), lambda t, e: (e, 0, 0)),
            pl.BlockSpec((None, f, d), lambda t, e: (e, 0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_t, d), lambda t, e: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((T, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((tile_t, d), jnp.float32)],
        interpret=interpret,
    )(x, router_w, wi, wg, wo)
