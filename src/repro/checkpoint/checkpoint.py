"""Msgpack+npz checkpointing for arbitrary train-state pytrees.

Layout:  <dir>/step_<n>/tree.msgpack (structure + small leaves metadata)
         <dir>/step_<n>/arrays.npz   (tensor payloads)

This module is the durability half of the crash-recovery contract
(docs/fault_tolerance.md):

* **Atomic saves** — payloads are written to a temp directory, fsynced
  (files AND directories, so the rename itself is durable), then
  renamed into place.  A crash mid-save can never leave a corrupt
  ``step_<n>``: either the old state survives or the new one is
  complete.  Old steps beyond ``keep`` are pruned only AFTER the new
  one is durable.
* **Validated restores** — :func:`restore` checks the saved tree
  structure, every leaf's shape, and every leaf's dtype against
  ``state_like`` and raises a :class:`ValueError` naming the mismatched
  leaf path (``jax.tree_util.keystr``), instead of silently
  mis-restoring into the wrong slot.
* **Bit-exact round-trips** — leaves are stored as raw numpy (bf16
  viewed as uint16, since npz cannot hold bfloat16), so a save→restore
  of optimizer state, ``state['comm']`` error-feedback residuals, and
  bf16 params reproduces every bit; together with the data pipeline
  being a pure function of (seed, step), a killed-and-resumed run
  matches an uninterrupted one step-for-step.
"""
from __future__ import annotations

import os
import shutil

import jax
import msgpack
import numpy as np


def _flatten(state):
    leaves, treedef = jax.tree.flatten(state)
    return leaves, treedef


def _fsync_file(path: str):
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _fsync_dir(path: str):
    # directory fsync makes the contained names durable; not every
    # filesystem supports opening a directory O_RDONLY for fsync —
    # degrade gracefully rather than fail the save
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def save(directory: str, step: int, state, *, keep: int = 3) -> str:
    """Atomically persist ``state`` as ``<directory>/step_<step>``.

    Write order (the crash-safety argument): temp dir → payload files →
    fsync payload files → fsync temp dir → rename → fsync parent dir →
    prune.  At no point does an incomplete ``step_<n>`` exist under its
    final name, and pruning of the ``keep`` newest-but-N steps only
    happens after the new step is durable on disk."""
    os.makedirs(directory, exist_ok=True)
    leaves, treedef = _flatten(state)
    arrays = {f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)}
    meta = {
        "step": int(step),
        "n_leaves": len(leaves),
        "treedef": str(treedef),
        "dtypes": [str(a.dtype) for a in arrays.values()],
        "shapes": [list(a.shape) for a in arrays.values()],
    }
    tmp = os.path.join(directory, f".tmp_step_{step}")
    final = os.path.join(directory, f"step_{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    tree_path = os.path.join(tmp, "tree.msgpack")
    with open(tree_path, "wb") as f:
        f.write(msgpack.packb(meta))
        f.flush()
        os.fsync(f.fileno())
    # npz can't hold bfloat16 — view as uint16 and restore from dtype meta
    packed = {
        k: (a.view(np.uint16) if a.dtype.name == "bfloat16" else a)
        for k, a in arrays.items()
    }
    arrays_path = os.path.join(tmp, "arrays.npz")
    np.savez(arrays_path, **packed)
    _fsync_file(arrays_path)
    _fsync_dir(tmp)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _fsync_dir(directory)

    # the new step is durable — only now retire the oldest beyond `keep`
    steps = sorted(latest_steps(directory))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s}"), ignore_errors=True)
    return final


def latest_steps(directory: str):
    if not os.path.isdir(directory):
        return []
    return [int(d.split("_", 1)[1]) for d in os.listdir(directory)
            if d.startswith("step_")]


def latest_step(directory: str):
    steps = latest_steps(directory)
    return max(steps) if steps else None


def restore(directory: str, state_like, step: int = None):
    """Restore into the structure of ``state_like``.

    The saved tree structure and every leaf's shape/dtype are validated
    against ``state_like``; a mismatch raises a ValueError naming the
    offending leaf path, the expected and the found shape/dtype — a
    checkpoint from a different strategy/config/learner count fails
    loudly instead of silently mis-restoring."""
    import jax.numpy as jnp

    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step}")
    with open(os.path.join(path, "tree.msgpack"), "rb") as f:
        meta = msgpack.unpackb(f.read())
    data = np.load(os.path.join(path, "arrays.npz"))
    paths_and_leaves, treedef = jax.tree_util.tree_flatten_with_path(
        state_like)
    if meta["treedef"] != str(treedef):
        raise ValueError(
            f"checkpoint {path} tree structure mismatch:\n"
            f"  saved:    {meta['treedef']}\n"
            f"  expected: {treedef}\n"
            f"(different strategy/optimizer/transport than the saved "
            f"run? state keys like 'prev_params'/'anchor'/'comm' are "
            f"strategy-dependent)")
    if meta["n_leaves"] != len(paths_and_leaves):
        raise ValueError(
            f"checkpoint {path} has {meta['n_leaves']} leaves, state "
            f"expects {len(paths_and_leaves)}")
    out = []
    for i, (leaf_path, ref) in enumerate(paths_and_leaves):
        a = data[f"leaf_{i}"]
        dt = meta["dtypes"][i]
        if dt == "bfloat16":
            a = a.view(jnp.bfloat16)
        name = jax.tree_util.keystr(leaf_path)
        expect_shape = tuple(np.shape(ref))
        if tuple(a.shape) != expect_shape:
            raise ValueError(
                f"checkpoint {path} leaf {name!r}: saved shape "
                f"{tuple(a.shape)} != expected {expect_shape} "
                f"(learner count or architecture changed since the "
                f"save?)")
        expect_dtype = str(jnp.asarray(ref).dtype) \
            if not hasattr(ref, "dtype") else str(ref.dtype)
        if str(a.dtype) != expect_dtype:
            raise ValueError(
                f"checkpoint {path} leaf {name!r}: saved dtype "
                f"{a.dtype} != expected {expect_dtype}")
        out.append(jnp.asarray(a))
    return jax.tree.unflatten(treedef, out), step
