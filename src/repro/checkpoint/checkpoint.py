"""Msgpack+npz checkpointing for arbitrary train-state pytrees.

Layout:  <dir>/step_<n>/tree.msgpack (structure + small leaves metadata)
         <dir>/step_<n>/arrays.npz   (tensor payloads)
Writes are atomic (tmp dir + rename); ``keep`` bounds retained steps.
"""
from __future__ import annotations

import os
import shutil

import jax
import msgpack
import numpy as np


def _flatten(state):
    leaves, treedef = jax.tree.flatten(state)
    return leaves, treedef


def save(directory: str, step: int, state, *, keep: int = 3) -> str:
    os.makedirs(directory, exist_ok=True)
    leaves, treedef = _flatten(state)
    arrays = {f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)}
    meta = {
        "step": int(step),
        "n_leaves": len(leaves),
        "treedef": str(treedef),
        "dtypes": [str(a.dtype) for a in arrays.values()],
        "shapes": [list(a.shape) for a in arrays.values()],
    }
    tmp = os.path.join(directory, f".tmp_step_{step}")
    final = os.path.join(directory, f"step_{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    with open(os.path.join(tmp, "tree.msgpack"), "wb") as f:
        f.write(msgpack.packb(meta))
    # npz can't hold bfloat16 — view as uint16 and restore from dtype meta
    packed = {
        k: (a.view(np.uint16) if a.dtype.name == "bfloat16" else a)
        for k, a in arrays.items()
    }
    np.savez(os.path.join(tmp, "arrays.npz"), **packed)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)

    steps = sorted(latest_steps(directory))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s}"), ignore_errors=True)
    return final


def latest_steps(directory: str):
    if not os.path.isdir(directory):
        return []
    return [int(d.split("_", 1)[1]) for d in os.listdir(directory)
            if d.startswith("step_")]


def latest_step(directory: str):
    steps = latest_steps(directory)
    return max(steps) if steps else None


def restore(directory: str, state_like, step: int = None):
    """Restore into the structure of ``state_like`` (shape/dtype checked)."""
    import jax.numpy as jnp

    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step}")
    with open(os.path.join(path, "tree.msgpack"), "rb") as f:
        meta = msgpack.unpackb(f.read())
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves, treedef = _flatten(state_like)
    assert meta["n_leaves"] == len(leaves), "tree structure mismatch"
    out = []
    for i, ref in enumerate(leaves):
        a = data[f"leaf_{i}"]
        dt = meta["dtypes"][i]
        if dt == "bfloat16":
            a = a.view(jnp.bfloat16)
        expect = tuple(np.shape(ref))
        assert tuple(a.shape) == expect, (i, a.shape, expect)
        out.append(jnp.asarray(a))
    return jax.tree.unflatten(treedef, out), step
