"""Synthetic-but-learnable data pipelines.

The paper trains on SWB2000 (1,975 h of telephone speech).  That corpus is
licensed and not available offline, so each family gets a deterministic
synthetic generator with real structure to learn — enough for the
convergence comparisons of §V (heldout-loss curves across strategies are
about optimizer dynamics, not acoustics):

* ASR frames  — features drawn from per-class Gaussian clusters with label
  context (emulating CD-HMM state targets with phone-class imbalance: class
  priors are Zipf-distributed like CD-state occupancy).
* LM tokens   — a fixed random first-order Markov chain (low-entropy rows)
  so next-token prediction is learnable well below uniform entropy.
* seq2seq     — target tokens derived from pooled input-frame statistics.

Batches are generated on the fly from the step index (infinite, resumable,
no storage I/O); a host-side prefetch thread emulates the paper's
overlapped data-loading workers (§IV-D).
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass

import numpy as np


def _rng(seed, step):
    return np.random.default_rng(np.uint64(seed * 1_000_003 + step))


@dataclass
class SyntheticASRDataset:
    """Frame-classification data for the paper's BLSTM acoustic model."""

    input_dim: int
    n_classes: int
    seq_len: int
    batch: int
    seed: int = 0
    n_effective_classes: int = 64   # rank of the learnable structure

    def __post_init__(self):
        r = np.random.default_rng(self.seed)
        k = min(self.n_effective_classes, self.n_classes)
        self.centroids = r.normal(size=(k, self.input_dim)).astype(np.float32)
        # Zipf-like priors: CD-state occupancy is hugely uneven (paper §IV-A)
        pri = 1.0 / np.arange(1, k + 1)
        self.priors = pri / pri.sum()
        self.k = k

    def batch_at(self, step: int):
        r = _rng(self.seed, step)
        cls = r.choice(self.k, size=(self.batch, self.seq_len), p=self.priors)
        feats = (self.centroids[cls]
                 + 0.5 * r.normal(size=(self.batch, self.seq_len,
                                        self.input_dim))).astype(np.float32)
        return {"features": feats, "labels": cls.astype(np.int32)}


@dataclass
class SyntheticLMDataset:
    """First-order Markov token streams (learnable next-token structure)."""

    vocab: int
    seq_len: int
    batch: int
    seed: int = 0
    effective_vocab: int = 256
    temperature: float = 0.3

    def __post_init__(self):
        r = np.random.default_rng(self.seed)
        k = min(self.effective_vocab, self.vocab)
        logits = r.normal(size=(k, k)) / self.temperature
        e = np.exp(logits - logits.max(-1, keepdims=True))
        self.trans = (e / e.sum(-1, keepdims=True)).astype(np.float64)
        self.k = k

    def batch_at(self, step: int):
        r = _rng(self.seed, step)
        B, S = self.batch, self.seq_len
        toks = np.zeros((B, S + 1), np.int32)
        toks[:, 0] = r.integers(0, self.k, size=B)
        # vectorized Markov sampling via inverse-CDF
        cdf = np.cumsum(self.trans, axis=-1)
        u = r.random((B, S))
        for t in range(S):
            toks[:, t + 1] = (cdf[toks[:, t]] > u[:, t:t + 1]).argmax(-1)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


@dataclass
class SyntheticSeq2SeqDataset:
    """Frame embeddings -> token transcripts (whisper-style backbone)."""

    d_model: int
    vocab: int
    enc_len: int
    dec_len: int
    batch: int
    seed: int = 0
    effective_vocab: int = 128

    def __post_init__(self):
        r = np.random.default_rng(self.seed)
        k = min(self.effective_vocab, self.vocab)
        self.readout = r.normal(size=(self.d_model, k)).astype(np.float32)
        self.k = k

    def batch_at(self, step: int):
        r = _rng(self.seed, step)
        frames = r.normal(size=(self.batch, self.enc_len,
                                self.d_model)).astype(np.float32)
        # pooled frame windows determine target tokens (learnable alignment)
        pool = self.enc_len // self.dec_len if self.enc_len >= self.dec_len else 1
        trimmed = frames[:, :pool * self.dec_len].reshape(
            self.batch, self.dec_len, pool, self.d_model).mean(2)
        scores = trimmed @ self.readout
        labels = scores.argmax(-1).astype(np.int32)
        tokens = np.concatenate(
            [np.zeros((self.batch, 1), np.int32), labels[:, :-1]], axis=1)
        return {"frames": frames, "tokens": tokens, "labels": labels}


@dataclass
class SyntheticVLMDataset:
    """Patch-embedding prefix + Markov text (internvl-style early fusion)."""

    d_model: int
    vocab: int
    n_patches: int
    text_len: int
    batch: int
    seed: int = 0

    def __post_init__(self):
        self.lm = SyntheticLMDataset(self.vocab, self.text_len, self.batch,
                                     seed=self.seed)

    def batch_at(self, step: int):
        r = _rng(self.seed, step)
        out = self.lm.batch_at(step)
        out["patches"] = r.normal(
            size=(self.batch, self.n_patches, self.d_model)
        ).astype(np.float32)
        return out


def make_dataset(cfg, *, seq_len: int, batch: int, seed: int = 0):
    """Family-appropriate synthetic dataset for an ArchConfig."""
    fam = cfg.family
    if fam == "lstm":
        return SyntheticASRDataset(cfg.input_dim, cfg.vocab, seq_len, batch,
                                   seed=seed)
    if fam == "encdec":
        half = seq_len // 2
        return SyntheticSeq2SeqDataset(cfg.d_model, cfg.vocab, half, half,
                                       batch, seed=seed)
    if fam == "vlm":
        sp = int(seq_len * cfg.vlm_patch_frac)
        return SyntheticVLMDataset(cfg.d_model, cfg.vocab, sp, seq_len - sp,
                                   batch, seed=seed)
    return SyntheticLMDataset(cfg.vocab, seq_len, batch, seed=seed)


class Prefetcher:
    """Host-side prefetch thread: overlaps batch synthesis with the device
    step, the way the paper overlaps data loading with gradient compute
    (§IV-D 'run data loaders in multiple processes')."""

    def __init__(self, dataset, start_step: int = 0, depth: int = 2):
        self.dataset = dataset
        self.q = queue.Queue(maxsize=depth)
        self.step = start_step
        self.stop = threading.Event()
        self.thread = threading.Thread(target=self._work, daemon=True)
        self.thread.start()

    def _work(self):
        s = self.step
        while not self.stop.is_set():
            try:
                self.q.put(self.dataset.batch_at(s), timeout=0.5)
                s += 1
            except queue.Full:
                continue

    def next(self):
        return self.q.get()

    def close(self):
        self.stop.set()
