"""Synthetic-but-learnable data pipelines.

The paper trains on SWB2000 (1,975 h of telephone speech).  That corpus is
licensed and not available offline, so each family gets a deterministic
synthetic generator with real structure to learn — enough for the
convergence comparisons of §V (heldout-loss curves across strategies are
about optimizer dynamics, not acoustics):

* ASR frames  — features drawn from per-class Gaussian clusters with label
  context (emulating CD-HMM state targets with phone-class imbalance: class
  priors are Zipf-distributed like CD-state occupancy).
* LM tokens   — a fixed random first-order Markov chain (low-entropy rows)
  so next-token prediction is learnable well below uniform entropy.
* seq2seq     — target tokens derived from pooled input-frame statistics.

Batches are generated on the fly from the step index (infinite, resumable,
no storage I/O); a host-side prefetch thread emulates the paper's
overlapped data-loading workers (§IV-D).

The ``lengths`` batch contract (variable-length utterances)
-----------------------------------------------------------
With ``var_len=True`` the ASR dataset emits *utterances* instead of
rectangular frame blocks: per-sequence valid lengths are drawn from a
clipped lognormal (SWB-like heavy spread), and every batch carries a
``lengths`` key:

* ``features``: (B, Tpad, D) f32 — zero beyond each row's length;
* ``labels``:   (B, Tpad)   i32 — 0 beyond each row's length;
* ``lengths``:  (B,)        i32 — valid frame count per row, >= 1.

Downstream consumers (``models/lstm.py``, ``models/common.cross_entropy``,
``core/strategies.py``) treat frames at t >= lengths[b] as padding: they
are masked out of the loss, frozen out of the BLSTM recurrence, and
excluded from gradient aggregation.  Fixed-length batches simply omit the
key — the absence of ``lengths`` *is* the rectangular contract.  The
normative statement of the contract (and the frame-weighted aggregation
it implies) is docs/data.md; this docstring is the emitter's view.

Length-bucketed batch construction (``bucket=True``) mirrors the paper's
loader (§IV-D) and Zhang et al. 1907.05701: utterances are generated in a
shuffle window of ``bucket_window`` batches, sorted by length within the
window, and carved into batches of near-equal length; each batch is padded
only to its own max length rounded up to ``pad_multiple`` (bounding the
number of distinct XLA compilations).  Utterance content is a pure
function of (seed, window) regardless of bucketing, so fixed-pad and
bucketed runs see the same workload — only the padding waste differs.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass

import numpy as np


def _rng(seed, step):
    return np.random.default_rng(np.uint64(seed * 1_000_003 + step))


def _round_up(n: int, m: int) -> int:
    return -(-n // m) * m


@dataclass
class SyntheticASRDataset:
    """Frame-classification data for the paper's BLSTM acoustic model.

    ``var_len=True`` switches to variable-length utterances carrying a
    ``lengths`` key; ``bucket=True`` additionally sorts utterances by
    length inside a ``bucket_window``-batch shuffle window so batches pad
    to their own (rounded) max length instead of ``seq_len`` — see the
    module docstring for the full batch contract.
    """

    input_dim: int
    n_classes: int
    seq_len: int
    batch: int
    seed: int = 0
    n_effective_classes: int = 64   # rank of the learnable structure
    # --- variable-length utterances (module docstring: batch contract) ---
    var_len: bool = False
    min_len: int = 4
    len_sigma: float = 0.6          # lognormal spread of utterance lengths
    bucket: bool = False            # sort-within-shuffle-window batching
    bucket_window: int = 16         # shuffle window, in batches
    pad_multiple: int = 8           # bucketed Tpad rounds up to this

    def __post_init__(self):
        r = np.random.default_rng(self.seed)
        k = min(self.n_effective_classes, self.n_classes)
        self.centroids = r.normal(size=(k, self.input_dim)).astype(np.float32)
        # Zipf-like priors: CD-state occupancy is hugely uneven (paper §IV-A)
        pri = 1.0 / np.arange(1, k + 1)
        self.priors = pri / pri.sum()
        self.k = k
        self._wcache = None          # (window_idx, lens, feats, cls)

    def _window(self, w: int):
        """All utterances of shuffle window ``w`` (vectorized, cached).

        Utterance content is a pure function of (seed, w): fixed-pad and
        bucketed batching carve the same utterance stream differently."""
        if self._wcache is not None and self._wcache[0] == w:
            return self._wcache[1:]
        N = self.bucket_window * self.batch
        r = np.random.default_rng((np.uint64(self.seed), np.uint64(w), 2))
        med = max(self.min_len, int(0.6 * self.seq_len))
        lens = np.clip(
            np.rint(r.lognormal(np.log(med), self.len_sigma, size=N)),
            self.min_len, self.seq_len).astype(np.int32)
        cls = r.choice(self.k, size=(N, self.seq_len), p=self.priors)
        feats = (self.centroids[cls]
                 + 0.5 * r.normal(size=(N, self.seq_len,
                                        self.input_dim))).astype(np.float32)
        valid = np.arange(self.seq_len)[None, :] < lens[:, None]
        feats *= valid[..., None]
        cls = np.where(valid, cls, 0).astype(np.int32)
        self._wcache = (w, lens, feats, cls)
        return lens, feats, cls

    def batch_at(self, step: int):
        if not self.var_len:
            r = _rng(self.seed, step)
            cls = r.choice(self.k, size=(self.batch, self.seq_len),
                           p=self.priors)
            feats = (self.centroids[cls]
                     + 0.5 * r.normal(size=(self.batch, self.seq_len,
                                            self.input_dim))
                     ).astype(np.float32)
            return {"features": feats, "labels": cls.astype(np.int32)}

        w, j = divmod(step, self.bucket_window)
        lens, feats, cls = self._window(w)
        order = (np.argsort(lens, kind="stable") if self.bucket
                 else np.arange(len(lens)))
        rows = order[j * self.batch:(j + 1) * self.batch]
        blens = lens[rows]
        tpad = (min(self.seq_len,
                    _round_up(int(blens.max()), self.pad_multiple))
                if self.bucket else self.seq_len)
        return {"features": feats[rows, :tpad],
                "labels": cls[rows, :tpad],
                "lengths": blens}


@dataclass
class SyntheticLMDataset:
    """First-order Markov token streams (learnable next-token structure)."""

    vocab: int
    seq_len: int
    batch: int
    seed: int = 0
    effective_vocab: int = 256
    temperature: float = 0.3

    def __post_init__(self):
        r = np.random.default_rng(self.seed)
        k = min(self.effective_vocab, self.vocab)
        logits = r.normal(size=(k, k)) / self.temperature
        e = np.exp(logits - logits.max(-1, keepdims=True))
        self.trans = (e / e.sum(-1, keepdims=True)).astype(np.float64)
        self.k = k

    def batch_at(self, step: int):
        r = _rng(self.seed, step)
        B, S = self.batch, self.seq_len
        toks = np.zeros((B, S + 1), np.int32)
        toks[:, 0] = r.integers(0, self.k, size=B)
        # vectorized Markov sampling via inverse-CDF
        cdf = np.cumsum(self.trans, axis=-1)
        u = r.random((B, S))
        for t in range(S):
            toks[:, t + 1] = (cdf[toks[:, t]] > u[:, t:t + 1]).argmax(-1)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


@dataclass
class SyntheticSeq2SeqDataset:
    """Frame embeddings -> token transcripts (whisper-style backbone)."""

    d_model: int
    vocab: int
    enc_len: int
    dec_len: int
    batch: int
    seed: int = 0
    effective_vocab: int = 128

    def __post_init__(self):
        r = np.random.default_rng(self.seed)
        k = min(self.effective_vocab, self.vocab)
        self.readout = r.normal(size=(self.d_model, k)).astype(np.float32)
        self.k = k

    def batch_at(self, step: int):
        r = _rng(self.seed, step)
        frames = r.normal(size=(self.batch, self.enc_len,
                                self.d_model)).astype(np.float32)
        # pooled frame windows determine target tokens (learnable alignment)
        pool = self.enc_len // self.dec_len if self.enc_len >= self.dec_len else 1
        trimmed = frames[:, :pool * self.dec_len].reshape(
            self.batch, self.dec_len, pool, self.d_model).mean(2)
        scores = trimmed @ self.readout
        labels = scores.argmax(-1).astype(np.int32)
        tokens = np.concatenate(
            [np.zeros((self.batch, 1), np.int32), labels[:, :-1]], axis=1)
        return {"frames": frames, "tokens": tokens, "labels": labels}


@dataclass
class SyntheticVLMDataset:
    """Patch-embedding prefix + Markov text (internvl-style early fusion)."""

    d_model: int
    vocab: int
    n_patches: int
    text_len: int
    batch: int
    seed: int = 0

    def __post_init__(self):
        self.lm = SyntheticLMDataset(self.vocab, self.text_len, self.batch,
                                     seed=self.seed)

    def batch_at(self, step: int):
        r = _rng(self.seed, step)
        out = self.lm.batch_at(step)
        out["patches"] = r.normal(
            size=(self.batch, self.n_patches, self.d_model)
        ).astype(np.float32)
        return out


def make_dataset(cfg, *, seq_len: int, batch: int, seed: int = 0,
                 var_len: bool = False, bucket: bool = False):
    """Family-appropriate synthetic dataset for an ArchConfig.

    ``var_len``/``bucket`` select variable-length utterances with optional
    length-bucketed batching (lstm family only; see module docstring)."""
    fam = cfg.family
    if (var_len or bucket) and fam != "lstm":
        raise ValueError(f"var_len/bucket batching is only defined for the "
                         f"lstm (utterance) family, not {fam!r}")
    if fam == "lstm":
        return SyntheticASRDataset(cfg.input_dim, cfg.vocab, seq_len, batch,
                                   seed=seed, var_len=var_len or bucket,
                                   bucket=bucket)
    if fam == "encdec":
        half = seq_len // 2
        return SyntheticSeq2SeqDataset(cfg.d_model, cfg.vocab, half, half,
                                       batch, seed=seed)
    if fam == "vlm":
        sp = int(seq_len * cfg.vlm_patch_frac)
        return SyntheticVLMDataset(cfg.d_model, cfg.vocab, sp, seq_len - sp,
                                   batch, seed=seed)
    return SyntheticLMDataset(cfg.vocab, seq_len, batch, seed=seed)


class Prefetcher:
    """Host-side prefetch thread: overlaps batch synthesis with the device
    step, the way the paper overlaps data loading with gradient compute
    (§IV-D 'run data loaders in multiple processes').

    Lifecycle: exceptions raised inside the worker are captured and
    re-raised from :meth:`next` (after any already-synthesized batches
    drain), so a consumer never blocks forever on a dead worker; and
    :meth:`close` joins the worker thread (bounded by ``join_timeout``)
    instead of abandoning it."""

    def __init__(self, dataset, start_step: int = 0, depth: int = 2,
                 join_timeout: float = 5.0):
        self.dataset = dataset
        self.q = queue.Queue(maxsize=depth)
        self.step = start_step
        self.join_timeout = join_timeout
        self.stop = threading.Event()
        self.error = None
        self.thread = threading.Thread(target=self._work, daemon=True)
        self.thread.start()

    def _work(self):
        s = self.step
        while not self.stop.is_set():
            try:
                batch = self.dataset.batch_at(s)
            except BaseException as e:       # re-raised on the consumer side
                self.error = e
                return
            while not self.stop.is_set():
                try:
                    self.q.put(batch, timeout=0.5)
                    s += 1
                    break
                except queue.Full:
                    continue

    def next(self):
        while True:
            try:
                return self.q.get(timeout=0.5)
            except queue.Empty:
                if self.error is not None:
                    raise RuntimeError(
                        "prefetch worker failed") from self.error
                if not self.thread.is_alive():
                    raise RuntimeError("prefetch worker exited unexpectedly")

    def close(self):
        self.stop.set()
        self.thread.join(timeout=self.join_timeout)
