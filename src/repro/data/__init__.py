from repro.data.pipeline import (  # noqa: F401
    SyntheticASRDataset,
    SyntheticLMDataset,
    SyntheticSeq2SeqDataset,
    SyntheticVLMDataset,
    make_dataset,
)
