"""swb2000-blstm — the paper's own acoustic model (§V Experiments).

6 bi-directional LSTM layers with 1,024 cells each (512 per direction), a
256-unit linear bottleneck, and a 32,000-way softmax over CD-HMM states.
Input is a 260-dim acoustic feature vector (PLP 40 + i-vector 100 +
logMel/delta/double-delta 120), unrolled 21 frames, batch 256, trained
with frame-level cross-entropy.  [Cui et al., IEEE SPM 2020, §V]
"""
from repro.configs.base import ArchConfig, register

SWB2000_BLSTM = register(
    ArchConfig(
        name="swb2000-blstm",
        family="lstm",
        n_layers=6,
        d_model=1024,          # LSTM cells per layer (512 per direction)
        n_heads=0,
        n_kv_heads=0,
        d_ff=0,
        vocab=32000,           # CD-HMM state targets
        citation="Cui et al., IEEE Signal Processing Magazine 2020, §V",
        norm="none",
        tie_embeddings=False,
        lstm_hidden=512,       # per direction
        lstm_bottleneck=256,
        input_dim=260,
        # Pallas BLSTM kernel: one direction's weights + f32 gradient
        # accumulators are ~9.5MB resident in the backward, so the
        # training batch tile auto-tunes to bB=64 at the 12MB budget
        # (see kernels/lstm_cell.py docstring for the byte math).
        lstm_block_b=0,        # 0 -> auto from the VMEM budget
        lstm_vmem_budget_mb=12,
        # at the paper's T=21 the per-step residual stash is cheap; for
        # long-utterance runs set lstm_seq_chunk (--seq-chunk) to trade
        # one recompute forward for an O(T/K) stash (docs/kernels.md)
        lstm_seq_chunk=0,
        # recognition scoring (launch/evaluate.py, docs/decoding.md):
        # Viterbi prefix beam over the CD-state posteriors; width 8 is
        # the quality/latency knee at the synthetic vocab scale
        beam_width=8,
        beam_semiring="max",
        # frame classifier: no autoregressive decode step
        skip_shapes=("prefill_32k", "decode_32k", "long_500k"),
        train_strategy="ad_psgd",
        n_learners=16,
        microbatches=1,
    )
)
