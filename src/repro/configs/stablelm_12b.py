"""stablelm-12b [dense] — [hf:stabilityai/stablelm-2-1_6b family]."""
from repro.configs.base import ArchConfig, register

STABLELM_12B = register(
    ArchConfig(
        name="stablelm-12b",
        family="dense",
        n_layers=40,
        d_model=5120,
        n_heads=32,
        n_kv_heads=8,
        d_ff=13824,
        vocab=100352,
        head_dim=160,
        rope_theta=10_000.0,
        norm="layernorm",
        act="swiglu",
        use_bias=False,
        tie_embeddings=False,
        citation="hf:stabilityai/stablelm-2-12b model card",
        window_for_long=8192,
        train_strategy="sd_psgd",
        n_learners=16,
        microbatches=8,
    )
)
