"""Assigned-architecture registry.

Importing this package registers every ``--arch`` id.  Each module carries
the exact assigned configuration with its source citation.
"""
from repro.configs.base import (  # noqa: F401
    ARCH_REGISTRY,
    SHAPE_REGISTRY,
    ArchConfig,
    MoEConfig,
    ShapeConfig,
    SSMConfig,
    get_arch,
    get_shape,
)

# one module per assigned architecture (+ the paper's own model)
from repro.configs import (  # noqa: F401
    command_r_35b,
    granite_moe_3b_a800m,
    hymba_1_5b,
    internvl2_2b,
    llama4_scout_17b_a16e,
    mamba2_370m,
    phi3_medium_14b,
    smollm_360m,
    stablelm_12b,
    swb2000_blstm,
    whisper_large_v3,
)

ALL_ARCHS = tuple(sorted(ARCH_REGISTRY))
ASSIGNED_ARCHS = tuple(a for a in ALL_ARCHS if a != "swb2000-blstm")
