"""whisper-large-v3 [audio] — encoder-decoder with conv frontend (stub)
[arXiv:2212.04356].

Only the transformer backbone is implemented; the mel-spectrogram + conv
feature extractor is a STUB — ``input_specs`` provides precomputed frame
embeddings (B, S_enc, d_model) per the assignment carve-out.

The assigned ``seq_len`` of a shape is split evenly between encoder frames
and decoder tokens (DESIGN.md §Shapes).  ``long_500k`` is skipped: both
encoder and decoder use full attention and a 262k-token transcript decode
is outside the model's design envelope (DESIGN.md §Arch-applicability).
"""
from repro.configs.base import ArchConfig, register

WHISPER_LARGE_V3 = register(
    ArchConfig(
        name="whisper-large-v3",
        family="encdec",
        n_layers=32,           # decoder layers
        n_enc_layers=32,       # encoder layers
        d_model=1280,
        n_heads=20,
        n_kv_heads=20,         # MHA (GQA kv=20 == n_heads)
        d_ff=5120,
        vocab=51866,
        head_dim=64,
        rope_theta=0.0,        # whisper uses learned/sinusoidal positions
        norm="layernorm",
        act="gelu",
        use_bias=True,
        tie_embeddings=True,
        citation="arXiv:2212.04356 (Whisper); large-v3 model card",
        frontend="audio",
        skip_shapes=("long_500k",),
        train_strategy="sd_psgd",
        n_learners=16,
        microbatches=4,
    )
)
