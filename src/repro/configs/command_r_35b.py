"""command-r-35b [dense] — GQA, no-bias [hf:CohereForAI/c4ai-command-r-v01]."""
from repro.configs.base import ArchConfig, register

COMMAND_R_35B = register(
    ArchConfig(
        name="command-r-35b",
        family="dense",
        n_layers=40,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=22528,
        vocab=256000,
        head_dim=128,
        rope_theta=10_000.0,
        norm="layernorm",
        act="swiglu",
        use_bias=False,
        tie_embeddings=True,
        citation="hf:CohereForAI/c4ai-command-r-v01 model card",
        window_for_long=8192,
        # 35B replicated per learner does not leave room for an extra stale
        # copy; SD-PSGD needs no AD-PSGD staleness buffer (DESIGN.md).
        train_strategy="sd_psgd",
        n_learners=16,
        microbatches=8,
    )
)
