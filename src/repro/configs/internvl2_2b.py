"""internvl2-2b [vlm] — InternViT + InternLM2 [arXiv:2404.16821].

Only the language/decoder backbone (InternLM2-1.8B shape) is implemented;
the InternViT vision encoder + MLP projector are a STUB whose output patch
embeddings are provided by ``input_specs`` (per the assignment carve-out).
"""
from repro.configs.base import ArchConfig, register

INTERNVL2_2B = register(
    ArchConfig(
        name="internvl2-2b",
        family="vlm",
        n_layers=24,
        d_model=2048,
        n_heads=16,
        n_kv_heads=8,
        d_ff=8192,
        vocab=92553,
        head_dim=128,
        rope_theta=10_000.0,
        norm="rmsnorm",
        act="swiglu",
        tie_embeddings=True,
        citation="arXiv:2404.16821 (InternVL2); LM backbone InternLM2-1.8B",
        frontend="vision",
        vlm_patch_frac=0.25,
        window_for_long=8192,
        train_strategy="ad_psgd",
        n_learners=16,
        microbatches=4,
    )
)
