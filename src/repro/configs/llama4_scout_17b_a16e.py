"""llama4-scout-17b-a16e [moe] — MoE 16e top-1, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E].

~109B total / ~17B active parameters.  A full per-learner replica does not
fit 16 chips of HBM, so training uses the paper's allreduce-equivalent
SC-PSGD (Eq.13 of the paper) with expert sharding over the data axis and
FSDP for the dense trunk — see DESIGN.md §Arch-applicability.

Llama-4 interleaves chunked (local) attention with a few global-attention
layers (iRoPE); we model that with window=8192 and periodic global layers,
which also makes ``long_500k`` natively sub-quadratic for this arch.
"""
from repro.configs.base import ArchConfig, MoEConfig, register

LLAMA4_SCOUT = register(
    ArchConfig(
        name="llama4-scout-17b-a16e",
        family="moe",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        d_ff=8192,
        vocab=202048,
        head_dim=128,
        rope_theta=500_000.0,
        norm="rmsnorm",
        act="swiglu",
        tie_embeddings=True,
        citation="hf:meta-llama/Llama-4-Scout-17B-16E model card",
        moe=MoEConfig(
            num_experts=16,
            top_k=1,
            d_ff_expert=8192,
            shared_expert=True,
            shared_d_ff=8192,
            capacity_factor=1.25,
            router_impl="dispatch",
            router_group=4096,
        ),
        window=8192,
        global_attn_layers=(0, 12, 24, 36),
        train_strategy="sc_psgd",
        n_learners=1,
        fsdp=True,
        expert_axis="data",
        microbatches=8,
    )
)
