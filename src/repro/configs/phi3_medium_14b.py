"""phi3-medium-14b [dense] — RoPE SwiGLU GQA [arXiv:2404.14219]."""
from repro.configs.base import ArchConfig, register

PHI3_MEDIUM_14B = register(
    ArchConfig(
        name="phi3-medium-14b",
        family="dense",
        n_layers=40,
        d_model=5120,
        n_heads=40,
        n_kv_heads=10,
        d_ff=17920,
        vocab=100352,
        head_dim=128,
        rope_theta=10_000.0,
        norm="rmsnorm",
        act="swiglu",
        tie_embeddings=True,
        citation="arXiv:2404.14219 (Phi-3 technical report)",
        # full attention -> long_500k runs as the documented sliding-window
        # variant (window_for_long), see DESIGN.md §Arch-applicability.
        window=0,
        window_for_long=8192,
        train_strategy="ad_psgd",
        n_learners=16,
        microbatches=8,
    )
)
