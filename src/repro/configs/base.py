"""Architecture and input-shape configuration system.

Every assigned architecture is expressed as an :class:`ArchConfig` and
registered in :data:`ARCH_REGISTRY` under its public ``--arch`` id.  The
four assigned input shapes live in :data:`SHAPE_REGISTRY`.

Configs are frozen dataclasses so they can be hashed into jit static
arguments and compared in tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts block configuration."""

    num_experts: int
    top_k: int
    d_ff_expert: int          # hidden dim of each expert FFN
    shared_expert: bool = False
    shared_d_ff: int = 0
    capacity_factor: float = 1.25
    router_impl: str = "dispatch"   # "dispatch" (capacity one-hot) | "dense"
    aux_loss_weight: float = 0.01
    router_group: int = 4096        # tokens per routing group for dispatch


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 style SSD (state space duality) block configuration."""

    state_dim: int            # N, per-head SSM state size
    head_dim: int = 64        # P, channels per SSM head
    expand: int = 2           # d_inner = expand * d_model
    n_groups: int = 1         # B/C groups (like GQA for SSM)
    conv_width: int = 4       # depthwise causal conv width
    chunk: int = 256          # SSD chunk length


@dataclass(frozen=True)
class ArchConfig:
    """One selectable architecture (``--arch <name>``)."""

    name: str
    family: str               # dense | moe | ssm | hybrid | encdec | vlm | lstm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    citation: str = ""

    head_dim: int = 0         # 0 -> derived as d_model // n_heads
    rope_theta: float = 10_000.0
    norm: str = "rmsnorm"     # rmsnorm | layernorm
    act: str = "swiglu"       # swiglu | gelu
    use_bias: bool = False
    tie_embeddings: bool = True

    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None

    # Sliding-window attention. 0 = full attention.  For pure full-attention
    # architectures the ``long_500k`` shape is run with
    # ``window_for_long`` > 0 as a documented variant (DESIGN.md).
    window: int = 0
    window_for_long: int = 8192
    # layers (by index mod pattern) that keep global attention when a window
    # is active; e.g. hymba keeps first/middle/last global.
    global_attn_layers: tuple = ()

    # encoder-decoder (whisper): number of encoder layers; seq_len of a
    # shape is split evenly between encoder frames and decoder tokens.
    n_enc_layers: int = 0

    # vlm: number of prefix patch-embedding positions for a given seq_len is
    # seq_len // vlm_patch_fraction_denom.
    vlm_patch_frac: float = 0.25

    # modality frontend stub: 'none' | 'audio' (frame embeddings) |
    # 'vision' (patch embeddings).
    frontend: str = "none"

    # lstm acoustic model (the paper's own architecture)
    lstm_hidden: int = 0      # per-direction hidden size
    lstm_bottleneck: int = 0
    input_dim: int = 0        # acoustic feature dim (paper: 260)
    # Pallas LSTM kernel knobs (repro.kernels.lstm_cell): batch tile of
    # the (B//bB, T) grid; 0 -> auto-picked from the VMEM budget.
    lstm_block_b: int = 0
    lstm_vmem_budget_mb: int = 12
    # training-forward residual stash precision ('float32' | 'bfloat16'):
    # bf16 halves the ~55MB/direction gate/cell stash at ~1e-2 normalized
    # gradient error (see kernels/lstm_cell.py 'Residual stashing').
    lstm_stash_dtype: str = "float32"
    # sequence-chunked recompute for long utterances: 0 = per-step stash,
    # K > 0 = stash only (h, c) chunk-entry carries every K frames and
    # rebuild gate residuals in VMEM in the backward (O(T/K) stash HBM at
    # the cost of one extra forward pass), -1 = auto-tune (block_b, K)
    # jointly from the VMEM budget (kernels/lstm_cell.py 'Sequence-chunked
    # recompute', docs/kernels.md).
    lstm_seq_chunk: int = 0

    # distribution defaults (see repro/core/strategies.py and DESIGN.md)
    train_strategy: str = "sd_psgd"   # sc_psgd | sd_psgd | ad_psgd | bmuf | hring
    n_learners: int = 16
    fsdp: bool = False        # shard params over the data axis (SC-PSGD only)
    expert_axis: str = ""     # mesh axis for expert parallelism ("data" or "")

    # ---- communication substrate (repro/core/transport.py; the full
    # strategy × topology × wire matrix is in docs/strategies.md) ----
    # mixing topology override; "" = the strategy's default
    # (uniform | ring | hierarchical | exp | none)
    comm_topology: str = ""
    # wire codec for payloads that cross the wire; "" = strategy default
    # (f32 | bf16 | int8 | topk)
    comm_wire: str = ""
    # hierarchical only: codec of the intra-pod allreduce ("" = f32;
    # f32 | bf16 | int8 — topk is gossip-only); the inter-pod ring uses
    # comm_wire — e.g. bf16 intra + topk inter
    comm_intra_wire: str = ""
    # chunked collectives: split payloads into buckets of this many MB so
    # XLA can interleave mixing with backward compute (0 = fused payload)
    comm_bucket_mb: int = 0
    # hierarchical topology: learners per pod (must divide n_learners)
    comm_pod_size: int = 1
    # topk wire: fraction of entries shipped per bucket
    comm_topk_frac: float = 0.01
    # elastic (fault-tolerant) mixing only: staleness damping λ — a
    # learner whose params are s steps behind mixes with confidence
    # 1/(1 + λ·s) (mixing.staleness_damped; docs/fault_tolerance.md).
    # 0 disables damping; ignored outside --fault-* runs.
    comm_staleness_lambda: float = 0.0

    # ---- CTC decode / recognition quality (repro/decode;
    # docs/decoding.md; --beam-* flags of evaluate.py and serve.py) ----
    # prefix-beam width of the eval/serve decoder (1 = greedy best-path)
    beam_width: int = 8
    # prefix-score merge: 'max' (Viterbi — beam=1 provably equals greedy
    # best-path) | 'sum' (classic log-semiring prefix beam search)
    beam_semiring: str = "max"
    # length-normalization alpha for the final hypothesis ranking
    # (score / max(len, 1)**alpha; 0 = raw log-prob)
    beam_len_norm: float = 0.0
    # per-frame top-C vocab pruning of the beam candidate grid (0 = off:
    # full beam x V).  Exact whenever C covers the frame's extend support
    # (docs/decoding.md §Top-C); candidate VMEM scales with C, not V
    beam_topc: int = 0
    # decode-step attention: '' (follow the launcher's --kernel-impl) |
    # 'jax' | 'pallas' (repro.kernels.decode_attention streaming kernel)
    attn_decode_impl: str = ""
    # ---- serving KV-cache layout (serve.py --cache; docs/serving.md
    # §KV paging) ----
    # 'dense' (per-slot max_len rows) | 'paged' (shared page pool with
    # prompt-prefix sharing + COW; attention-only decoder families)
    cache_mode: str = "dense"
    # cache positions per physical KV page under cache_mode='paged'
    # (serve.py --page-size overrides; must divide the serve max_len)
    page_size: int = 16

    # which shapes this arch supports (see DESIGN.md skip notes)
    skip_shapes: tuple = ()

    # numerics
    param_dtype: str = "bfloat16"
    remat: bool = True
    microbatches: int = 4     # gradient-accumulation microbatches for train

    # ---- beyond-paper performance knobs (EXPERIMENTS.md §Perf) ----
    # 'replicated' (baseline: attention weights+compute replicated over the
    # model axis) | 'seq' (sequence-parallel attention: head_dim-sharded
    # projections, q-chunk positions sharded over 'model')
    attn_sharding: str = "replicated"
    # fuse the dense-MoE combine into one (experts, ff) contraction instead
    # of materializing per-expert outputs (kills the giant psum)
    moe_dense_fused: bool = False

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # ------------------------------------------------------------------
    @property
    def is_subquadratic(self) -> bool:
        return self.family in ("ssm", "hybrid") or self.window > 0

    @property
    def supports_decode(self) -> bool:
        return self.family != "lstm"   # frame classifier has no decode loop

    def supports_shape(self, shape_name: str) -> bool:
        return shape_name not in self.skip_shapes

    # ------------------------------------------------------------------
    def optimized(self) -> "ArchConfig":
        """§Perf overlay: the beyond-paper optimized variant of this arch
        (sequence-parallel attention, fused dense-MoE combine, smaller
        routing groups, fewer grad-accumulation round-trips)."""
        changes = dict(attn_sharding="seq", moe_dense_fused=True,
                       microbatches=max(2, self.microbatches // 4))
        if self.moe is not None and self.moe.router_impl == "dispatch":
            changes["moe"] = replace(self.moe, router_group=1024)
        return replace(self, **changes)

    def reduced(self) -> "ArchConfig":
        """A smoke-test variant of the same family: <=2 layers, d_model<=256,
        <=4 experts, small vocab.  Used by per-arch CPU smoke tests."""
        d = min(self.d_model, 256)
        heads = min(self.n_heads, 4) or self.n_heads
        kv = min(self.n_kv_heads, 2) or self.n_kv_heads
        hd = max(d // max(heads, 1), 8) if heads else 0
        changes = dict(
            n_layers=2,
            d_model=d,
            n_heads=heads,
            n_kv_heads=kv,
            head_dim=hd,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab=min(self.vocab, 512),
            n_learners=2,
            microbatches=1,
            window=min(self.window, 64) if self.window else 0,
        )
        if self.moe is not None:
            changes["moe"] = replace(
                self.moe,
                num_experts=min(self.moe.num_experts, 4),
                top_k=min(self.moe.top_k, 2),
                d_ff_expert=min(self.moe.d_ff_expert, 128),
                shared_d_ff=min(self.moe.shared_d_ff, 128),
                router_group=64,
            )
        if self.ssm is not None:
            changes["ssm"] = replace(
                self.ssm,
                state_dim=min(self.ssm.state_dim, 16),
                head_dim=16,
                chunk=16,
            )
        if self.n_enc_layers:
            changes["n_enc_layers"] = 1
        if self.lstm_hidden:
            changes["lstm_hidden"] = 64
            changes["lstm_bottleneck"] = 32
        return replace(self, **changes)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned (seq_len, global_batch) workload shape."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                 # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPE_REGISTRY = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

# populated by repro.configs (one module per assigned architecture)
ARCH_REGISTRY: dict = {}


def register(cfg: ArchConfig) -> ArchConfig:
    ARCH_REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ArchConfig:
    import repro.configs  # noqa: F401  (ensures registry is populated)

    try:
        return ARCH_REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown arch {name!r}; available: {sorted(ARCH_REGISTRY)}"
        ) from None


def get_shape(name: str) -> ShapeConfig:
    try:
        return SHAPE_REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown shape {name!r}; available: {sorted(SHAPE_REGISTRY)}"
        ) from None
