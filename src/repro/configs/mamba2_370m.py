"""mamba2-370m [ssm] — SSD (state-space duality), attention-free
[arXiv:2405.21060]."""
from repro.configs.base import ArchConfig, SSMConfig, register

MAMBA2_370M = register(
    ArchConfig(
        name="mamba2-370m",
        family="ssm",
        n_layers=48,
        d_model=1024,
        n_heads=0,            # attention-free
        n_kv_heads=0,
        d_ff=0,               # no separate FFN; mamba2 block carries the MLP
        vocab=50280,
        head_dim=0,
        norm="rmsnorm",
        tie_embeddings=True,
        citation="arXiv:2405.21060 (Mamba-2 / SSD)",
        ssm=SSMConfig(
            state_dim=128,
            head_dim=64,
            expand=2,          # d_inner = 2048, n_ssm_heads = 32
            n_groups=1,
            conv_width=4,
            chunk=256,
        ),
        train_strategy="ad_psgd",
        n_learners=16,
        microbatches=2,
    )
)
