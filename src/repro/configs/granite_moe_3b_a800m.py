"""granite-moe-3b-a800m [moe] — 40 experts top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base family].

Tiny experts (d_ff=512) with a wide top-k: the dispatch overhead of
capacity routing dwarfs the expert matmuls, so the default router_impl is
"dense" (compute all 40 experts, mask to top-8) which is exact and
MXU-friendly at this size — see DESIGN.md §MoE.
"""
from repro.configs.base import ArchConfig, MoEConfig, register

GRANITE_MOE_3B = register(
    ArchConfig(
        name="granite-moe-3b-a800m",
        family="moe",
        n_layers=32,
        d_model=1536,
        n_heads=24,
        n_kv_heads=8,
        d_ff=512,
        vocab=49155,
        head_dim=64,
        rope_theta=10_000.0,
        norm="rmsnorm",
        act="swiglu",
        tie_embeddings=True,
        citation="hf:ibm-granite/granite-3.0-3b-a800m-base model card",
        moe=MoEConfig(
            num_experts=40,
            top_k=8,
            d_ff_expert=512,
            capacity_factor=1.25,
            router_impl="dense",
            router_group=2048,
        ),
        window_for_long=8192,
        train_strategy="ad_psgd",
        n_learners=16,
        microbatches=4,
    )
)
