"""smollm-360m [dense] — llama-arch small [hf:HuggingFaceTB/SmolLM-135M]."""
from repro.configs.base import ArchConfig, register

SMOLLM_360M = register(
    ArchConfig(
        name="smollm-360m",
        family="dense",
        n_layers=32,
        d_model=960,
        n_heads=15,
        n_kv_heads=5,
        d_ff=2560,
        vocab=49152,
        head_dim=64,
        rope_theta=10_000.0,
        norm="rmsnorm",
        act="swiglu",
        tie_embeddings=True,
        citation="hf:HuggingFaceTB/SmolLM-135M (llama architecture family)",
        window_for_long=8192,
        train_strategy="ad_psgd",
        n_learners=16,
        microbatches=2,
    )
)
