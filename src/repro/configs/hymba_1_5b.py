"""hymba-1.5b [hybrid] — parallel attention + mamba heads in every layer
[arXiv:2411.13676].

Hymba fuses attention heads and SSM heads inside one block (outputs are
independently normalized and averaged).  Most layers use sliding-window
attention; first/middle/last keep global attention — which is what makes
``long_500k`` feasible natively.
"""
from repro.configs.base import ArchConfig, SSMConfig, register

HYMBA_1_5B = register(
    ArchConfig(
        name="hymba-1.5b",
        family="hybrid",
        n_layers=32,
        d_model=1600,
        n_heads=25,
        n_kv_heads=5,
        d_ff=5504,
        vocab=32001,
        head_dim=64,
        rope_theta=10_000.0,
        norm="rmsnorm",
        act="swiglu",
        tie_embeddings=True,
        citation="arXiv:2411.13676 (Hymba)",
        ssm=SSMConfig(
            state_dim=16,
            head_dim=64,
            expand=2,
            n_groups=1,
            conv_width=4,
            chunk=256,
        ),
        window=1024,
        global_attn_layers=(0, 15, 31),
        train_strategy="ad_psgd",
        n_learners=16,
        microbatches=2,
    )
)
