"""Logical-axis sharding rules (t5x/MaxText style), with divisibility-aware
fallback chains so that one rule set covers all 10 assigned architectures.

Every parameter/activation dimension carries a *logical* axis name
('heads', 'mlp', 'batch', ...).  :class:`MeshRules` maps logical axes to
mesh axes; each logical axis has an ordered candidate list and the first
unused mesh axis that evenly divides the dimension wins.  This is what lets
e.g. phi-3 (40 heads, not divisible by the 16-way model axis) fall through
to sharding ``head_dim`` instead, while command-r (64 heads) shards heads
directly.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# ---------------------------------------------------------------------------
# Rule sets
# ---------------------------------------------------------------------------

def default_rules(*, fsdp: bool = False, expert_axis: str = "",
                  learner_axis: str = "data") -> dict:
    """Logical-axis -> ordered mesh-axis candidates.

    ``learner_axis`` is where decentralized learner replicas live: the
    'data' axis on a single pod, the 'pod' axis for the H-ring multi-pod
    configuration (paper §V HPC setting).
    """
    rules = {
        # the decentralized-SGD learner-replica dimension (paper Eq. 14)
        "learner": (learner_axis,),
        # parameters
        "vocab": ("model",),
        "embed": ("data",) if fsdp else (),
        "mlp": ("model",),
        # Attention weights replicate over 'model': none of the assigned
        # GQA configs has heads (or per-group heads) divisible by the 16-way
        # model axis, and sharding the contracting head_dim turns every
        # score matmul into a giant partial-sum all-reduce (observed in the
        # prototype HLO).  Attention COMPUTE is model-sharded on the decode
        # path via cache_seq below, and via sequence-parallel constraints in
        # the perf pass (EXPERIMENTS.md §Perf).
        "heads": (),
        "kv_heads": (),
        "head_dim": (),
        "qkv": (),
        "experts": (expert_axis,) if expert_axis else (),
        "expert_mlp": ("model",),
        "ssm_heads": ("model",),
        "ssm_inner": ("model",),
        "ssm_state": (),
        "conv_dim": (),
        "layers": (),
        "lstm_hidden": ("model",),
        "lstm_gates": ("model",),
        "feature": (),
        "bottleneck": (),
        # activations
        "batch": ("data",),
        "seq": (),
        # decode KV caches shard their time axis over 'model' (flash-decode
        # style partial softmax), and over model×data for the B=1 long
        # context shape.
        "cache_seq": (("model", "data"), "model", "data"),
        "frames": (),
        None: (),
    }
    return rules


def multipod_rules(*, fsdp: bool = False, expert_axis: str = "") -> dict:
    """Multi-pod mesh ('pod','data','model'): learners ride the pod axis
    (H-ring super-learners), batch shards over pod×data, FSDP over data."""
    rules = default_rules(fsdp=fsdp, expert_axis=expert_axis,
                          learner_axis="pod")
    rules["batch"] = ("data",)
    return rules


# ---------------------------------------------------------------------------
# MeshRules
# ---------------------------------------------------------------------------

@dataclass
class MeshRules:
    mesh: Mesh
    rules: dict

    def axis_size(self, name: str) -> int:
        return self.mesh.shape[name]

    def spec(self, shape: Sequence[int], axes: Sequence[Optional[str]]) -> P:
        """Greedy left-to-right assignment: each mesh axis used at most once
        per spec; a candidate must evenly divide the dimension."""
        assert len(shape) == len(axes), (shape, axes)
        out = [None] * len(shape)
        used = set()
        for i, (n, ax) in enumerate(zip(shape, axes)):
            for cand in self.rules.get(ax, ()):
                if not cand:
                    continue
                group = cand if isinstance(cand, tuple) else (cand,)
                size = 1
                for a in group:
                    size *= self.axis_size(a)
                if used.isdisjoint(group) and n % size == 0:
                    out[i] = cand
                    used.update(group)
                    break
        return P(*out)

    def sharding(self, shape, axes) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(shape, axes))

    def sds(self, shape, dtype, axes) -> jax.ShapeDtypeStruct:
        """ShapeDtypeStruct stand-in for the dry-run (no allocation)."""
        return jax.ShapeDtypeStruct(
            tuple(shape), dtype, sharding=self.sharding(shape, axes)
        )


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ParamSpec:
    """Shape + dtype + logical axes + init recipe for one parameter."""

    shape: tuple
    dtype: str = "bfloat16"
    axes: tuple = ()
    init: str = "normal"      # normal | zeros | ones | lecun | small_a_log
    init_scale: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def spec_tree_to_sds(spec_tree, mesh_rules: MeshRules,
                     extra_leading: tuple = ()):
    """Map a tree of ParamSpec to ShapeDtypeStructs.

    ``extra_leading`` prepends (size, logical_axis) dims — used to add the
    learner-replica dimension of decentralized strategies.
    """
    def one(ps: ParamSpec):
        shape = tuple(s for s, _ in extra_leading) + ps.shape
        axes = tuple(a for _, a in extra_leading) + ps.axes
        return mesh_rules.sds(shape, ps.dtype, axes)

    return jax.tree.map(one, spec_tree,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def spec_tree_shardings(spec_tree, mesh_rules: MeshRules,
                        extra_leading: tuple = ()):
    def one(ps: ParamSpec):
        shape = tuple(s for s, _ in extra_leading) + ps.shape
        axes = tuple(a for _, a in extra_leading) + ps.axes
        return mesh_rules.sharding(shape, axes)

    return jax.tree.map(one, spec_tree,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def init_param(ps: ParamSpec, key) -> jax.Array:
    import jax.numpy as jnp

    dtype = jnp.dtype(ps.dtype)
    if ps.init == "zeros":
        return jnp.zeros(ps.shape, dtype)
    if ps.init == "ones":
        return jnp.ones(ps.shape, dtype)
    if ps.init == "small_a_log":
        # mamba2 A_log init: A in [1, 16) -> log
        u = jax.random.uniform(key, ps.shape, jnp.float32, 1.0, 16.0)
        return jnp.log(u).astype(dtype)
    if ps.init == "lecun":
        fan_in = ps.shape[0] if len(ps.shape) >= 1 else 1
        scale = 1.0 / np.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, ps.shape, jnp.float32) * scale).astype(dtype)
    return (jax.random.normal(key, ps.shape, jnp.float32) * ps.init_scale).astype(dtype)


def init_spec_tree(spec_tree, key):
    """Materialize a ParamSpec tree into real arrays (smoke tests/examples)."""
    leaves, treedef = jax.tree.flatten(
        spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec)
    )
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(
        treedef, [init_param(ps, k) for ps, k in zip(leaves, keys)]
    )
