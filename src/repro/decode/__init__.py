"""Recognition-quality decode subsystem: batched CTC prefix beam search
(jnp + Pallas kernel, optional top-C vocab pruning), streaming
beam-state carry, and the serving argmax kernel.  Contracts in
docs/decoding.md."""
from repro.decode.beam import (  # noqa: F401
    BeamState,
    beam_decode,
    beam_occupancy,
    beam_search,
    decode_chunk,
    finalize,
    gather_rows,
    init_state,
    reset_rows,
    scatter_rows,
    topc_scores,
)
from repro.decode.kernel import (  # noqa: F401
    argmax_tokens,
    beam_cand_bytes,
)
