"""Recognition-quality decode subsystem: batched CTC prefix beam search
(jnp + Pallas kernel), streaming beam-state carry, and the serving
argmax kernel.  Contracts in docs/decoding.md."""
from repro.decode.beam import (  # noqa: F401
    BeamState,
    beam_decode,
    beam_occupancy,
    beam_search,
    decode_chunk,
    finalize,
    init_state,
    reset_rows,
)
from repro.decode.kernel import argmax_tokens  # noqa: F401
