"""Exact numpy oracle for the vectorized prefix beam search.

Dict-of-real-prefixes reference (no fixed beam slots, no rolling hash):
the classic Hannun et al. 2014 algorithm written for clarity, against
which ``decode/beam.py`` and the Pallas kernel are allclose/bit-equal in
tests (ties excepted — the vectorized impl breaks score ties by
candidate index, the oracle by dict/sort order, so parity tests use
continuous random logits where ties have measure zero).
"""
from __future__ import annotations

import numpy as np

NEG = -1e30


def _merge(semiring):
    if semiring == "max":
        return max
    if semiring == "sum":
        return np.logaddexp
    raise ValueError(f"semiring must be 'max' or 'sum', got {semiring!r}")


def _log_softmax(x):
    x = np.asarray(x, np.float32)
    m = x.max(-1, keepdims=True)
    e = np.exp(x - m)
    return x - m - np.log(e.sum(-1, keepdims=True))


def prefix_beam_ref(logits, lengths=None, *, beam: int = 8, blank: int = 0,
                    semiring: str = "max", len_norm: float = 0.0,
                    max_len: int = None):
    """(B, T, V) logits -> (hyps: list of int lists, scores: list of
    float).  Same contract as ``beam.beam_search`` (U cap, lengths
    freeze, length-normalized final ranking)."""
    logp = _log_softmax(logits)
    B, T, V = logp.shape
    U = max_len if max_len is not None else T
    merge = _merge(semiring)
    hyps, scores = [], []
    for b in range(B):
        Tb = int(lengths[b]) if lengths is not None else T
        beams = {(): (0.0, NEG)}                      # prefix -> (p_b, p_nb)
        for t in range(min(Tb, T)):
            lp = logp[b, t]
            new = {}

            def bump(prefix, i, val):
                e = new.setdefault(prefix, [NEG, NEG])
                e[i] = float(merge(e[i], val))

            for prefix, (pb, pnb) in beams.items():
                tot = float(merge(pb, pnb))
                bump(prefix, 0, tot + lp[blank])
                if prefix:
                    bump(prefix, 1, pnb + lp[prefix[-1]])
                if len(prefix) < U:
                    for c in range(V):
                        if c == blank:
                            continue
                        base = pb if (prefix and c == prefix[-1]) else tot
                        bump(prefix + (c,), 1, base + lp[c])
            ranked = sorted(new.items(),
                            key=lambda kv: -float(merge(*kv[1])))
            beams = {p: tuple(s) for p, s in ranked[:beam]}

        def final_score(prefix, pb, pnb):
            tot = float(merge(pb, pnb))
            if len_norm:
                tot = tot / max(len(prefix), 1) ** len_norm
            return tot

        best, (pb, pnb) = max(beams.items(),
                              key=lambda kv: final_score(kv[0], *kv[1]))
        hyps.append(list(best))
        scores.append(final_score(best, pb, pnb))
    return hyps, scores
