"""Batched CTC prefix beam search in JAX — the recognition-quality
subsystem behind ``launch/evaluate.py`` and the ASR serving mode of
``launch/serve.py``.

The paper's third evaluation axis is recognition performance (WER on
Hub5'00; the companion 1904.04956 reports its headline results as WER
deltas between (A)D-PSGD and sync SGD).  This module scores checkpoints
the same way at synthetic scale: it turns per-frame CTC posteriors
(B, T, V) into token sequences with a *prefix* beam search (Hannun et
al. 2014), vectorized over both the batch and the beam so the whole
decode is one ``lax.scan`` over frames.

Semirings
---------
Per prefix we carry two log scores — ``p_b`` (alignments ending in
blank) and ``p_nb`` (ending in the prefix's last token) — and combine
contributions with a *semiring merge*:

* ``semiring='max'`` (default): Viterbi scoring — a prefix's score is
  its single best alignment.  With ``beam=1`` this is **provably
  identical to greedy best-path decoding**: the surviving prefix is the
  collapse of the running frame-argmax path, because every candidate's
  frame increment is bounded by ``max_c logp[c]`` and the candidate that
  achieves the bound is exactly the collapse of (greedy path + argmax
  token) — appending the argmax token extends the prefix iff greedy's
  collapse does (repeat tokens route through ``p_nb`` when the best
  alignment ends non-blank, through ``p_b`` after a blank).  The
  equivalence is locked by a test against ``eval.metrics
  .greedy_ctc_decode``.
* ``semiring='sum'``: the classic log-semiring prefix beam search —
  scores sum (``logaddexp``) over all alignments of a prefix, which is
  what makes beam > 1 *better* than best-path: probability mass spread
  over several alignments of one prefix can beat the single best raw
  path (the blank-dominated-frames case).

Beam state and the merge
------------------------
:class:`BeamState` is a pytree of fixed-shape arrays — tokens
(B, K, U), lengths, last token, a rolling prefix hash, the (p_b, p_nb)
scores and a per-row frame counter — so it can be carried through
``lax.scan``, donated, or held across calls (the streaming mode).  The
per-frame step (:func:`frame_step_scores`, shared verbatim by the
Pallas kernel in ``decode/kernel.py``) expands K stays + K·(V-1)
extends, merges duplicate prefixes, and selects the top K:

* an extend of prefix k by token c collides with an in-beam prefix j
  iff ``len[j] == len[k] + 1`` and ``hash[j] == hash[k]*P + c`` — and
  the only token that can make prefix j is ``c == last[j]``, so the
  merge is a (K × K) check rather than (K × V × K);
* prefix identity uses a rolling polynomial hash (``P = 1_000_003``,
  int32 wraparound) plus the length check; distinct same-length
  prefixes with equal hashes are astronomically unlikely (the numpy
  oracle in ``decode/ref.py`` compares real prefixes and the parity
  tests pass bit-for-bit);
* top-K is K iterative argmax passes (first-occurrence tie break), the
  same procedure in the jnp and Pallas paths so they match bit-for-bit.

Streaming / chunked decode
--------------------------
``state = init_state(B, beam, max_len)`` then repeated
``state = decode_chunk(state, logits_chunk, lengths)`` — the carry *is*
the beam state — then ``finalize(state)``.  ``state.t`` counts consumed
frames per row; rows with ``t >= lengths[b]`` are frozen (the decode
analogue of the ``lengths`` batch contract in ``repro.data.pipeline``),
so feeding one T-frame call or T/C chunked calls is bit-identical.
``reset_rows`` re-arms individual rows for continuous-batching servers
(``launch/serve.py`` carries one BeamState across its slot pool).

Length-normalized scoring: ``finalize(..., len_norm=a)`` ranks final
beams by ``score / max(len, 1)**a`` (Wu et al. style), countering the
short-hypothesis bias of raw log-probabilities.  See docs/decoding.md
for the full contract and the kernel VMEM math.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

NEG = -1e30
HASH_P = 1_000_003        # rolling-hash multiplier (int32 wraparound)


def _merge_fn(semiring: str):
    if semiring == "max":
        return jnp.maximum
    if semiring == "sum":
        return jnp.logaddexp
    raise ValueError(f"semiring must be 'max' or 'sum', got {semiring!r}")


def _reduce_fn(semiring: str):
    if semiring == "max":
        return lambda x, axis: jnp.max(x, axis=axis)
    if semiring == "sum":
        return lambda x, axis: jax.nn.logsumexp(x, axis=axis)
    raise ValueError(f"semiring must be 'max' or 'sum', got {semiring!r}")


class BeamState(NamedTuple):
    """Carry of the streaming decode (all arrays, scan/jit friendly)."""

    tokens: jax.Array        # (B, K, U) i32, -1 padded
    lens: jax.Array          # (B, K) i32 prefix lengths
    last: jax.Array          # (B, K) i32 last token (-1 = empty prefix)
    phash: jax.Array         # (B, K) i32 rolling prefix hash
    p_b: jax.Array           # (B, K) f32 log score, alignments ending blank
    p_nb: jax.Array          # (B, K) f32 log score, ending non-blank
    t: jax.Array             # (B,) i32 frames consumed (freeze counter)


def init_state(batch: int, beam: int, max_len: int) -> BeamState:
    """Fresh beams: slot 0 holds the empty prefix (p_b = 0), the rest are
    NEG placeholders that real candidates displace on the first frame."""
    p_b = jnp.where(jnp.arange(beam)[None, :] == 0, 0.0, NEG)
    return BeamState(
        tokens=jnp.full((batch, beam, max_len), -1, jnp.int32),
        lens=jnp.zeros((batch, beam), jnp.int32),
        last=jnp.full((batch, beam), -1, jnp.int32),
        phash=jnp.zeros((batch, beam), jnp.int32),
        p_b=jnp.broadcast_to(p_b, (batch, beam)).astype(jnp.float32),
        p_nb=jnp.full((batch, beam), NEG, jnp.float32),
        t=jnp.zeros((batch,), jnp.int32),
    )


def gather_rows(state: BeamState, idx) -> BeamState:
    """Snapshot beam rows ``idx`` (N,) as a BeamState with batch N — the
    serving preemption snapshot (``launch/serve.py`` parks a preempted
    slot's beams host-side and :func:`scatter_rows` re-arms them in
    whatever slot the request resumes in, bit-for-bit)."""
    idx = jnp.asarray(idx, jnp.int32)
    return BeamState(*(arr[idx] for arr in state))


def scatter_rows(state: BeamState, rows: BeamState, idx) -> BeamState:
    """Write snapshot ``rows`` (batch N) back into rows ``idx`` (N,) of
    ``state`` — the inverse of :func:`gather_rows`: gather-then-scatter
    through the same indices is the identity."""
    idx = jnp.asarray(idx, jnp.int32)
    return BeamState(*(arr.at[idx].set(jnp.asarray(src, arr.dtype))
                       for arr, src in zip(state, rows)))


def reset_rows(state: BeamState, mask) -> BeamState:
    """Re-arm rows where ``mask`` (B,) is True (serving slot admission)."""
    B, K, U = state.tokens.shape
    fresh = init_state(B, K, U)
    pick2 = mask[:, None]
    return BeamState(
        tokens=jnp.where(mask[:, None, None], fresh.tokens, state.tokens),
        lens=jnp.where(pick2, fresh.lens, state.lens),
        last=jnp.where(pick2, fresh.last, state.last),
        phash=jnp.where(pick2, fresh.phash, state.phash),
        p_b=jnp.where(pick2, fresh.p_b, state.p_b),
        p_nb=jnp.where(pick2, fresh.p_nb, state.p_nb),
        t=jnp.where(mask, fresh.t, state.t),
    )


# ---------------------------------------------------------------------------
# per-frame step: candidate expansion + duplicate merge + top-K
# ---------------------------------------------------------------------------

def frame_step_scores(logp, p_b, p_nb, last, phash, plen, *, blank: int,
                      max_len: int, semiring: str):
    """One frame of prefix beam search, batched.

    Pure array math shared bit-for-bit by the jnp path and the Pallas
    kernel body (``decode/kernel.py`` calls exactly this function on
    VMEM-resident blocks).

    logp: (B, V) f32 log-softmax of the frame; p_b/p_nb: (B, K) f32;
    last/phash/plen: (B, K) i32.  Returns ``(sel, new_pb, new_pnb)``
    where ``sel`` (B, K) i32 indexes the flattened (K*V,) candidate grid
    — candidate ``k*V + c`` is "extend prefix k with c", except
    ``c == blank`` which is "prefix k stays" — ranked best-first.
    """
    B, V = logp.shape
    K = p_b.shape[1]
    merge = _merge_fn(semiring)
    reduce_ = _reduce_fn(semiring)

    tot = merge(p_b, p_nb)                                       # (B, K)
    stay_pb = tot + logp[:, blank][:, None]
    lp_last = jnp.take_along_axis(logp, jnp.maximum(last, 0), axis=1)
    stay_pnb = jnp.where(last >= 0, p_nb + lp_last, NEG)

    c_ids = jax.lax.broadcasted_iota(jnp.int32, (1, 1, V), 2)
    base = jnp.where(c_ids == last[:, :, None], p_b[:, :, None],
                     tot[:, :, None])
    ext = base + logp[:, None, :]                                # (B, K, V)
    ext = jnp.where(c_ids == blank, NEG, ext)
    ext = jnp.where(plen[:, :, None] >= max_len, NEG, ext)       # U cap

    # Duplicate merge: extend(k, c) equals in-beam prefix j iff
    # len[j] == len[k]+1 and hash[j] == hash[k]*P + c; the only viable
    # token is c == last[j].  match[b, k, j]: parent k's extend-by-
    # last[j] collides with stay j.
    match = ((plen[:, None, :] == plen[:, :, None] + 1)
             & (phash[:, None, :]
                == phash[:, :, None] * HASH_P + last[:, None, :])
             & (last[:, None, :] >= 0))                          # (B, K, K)
    idx = jnp.broadcast_to(jnp.maximum(last, 0)[:, None, :], (B, K, K))
    e = jnp.take_along_axis(ext, idx, axis=2)    # e[b,k,j]=ext[b,k,last[j]]
    contrib = reduce_(jnp.where(match, e, NEG), 1)               # (B, K)
    stay_pnb = merge(stay_pnb, contrib)
    for j in range(K):                           # kill the merged extends
        cj = jnp.maximum(last[:, j], 0)
        hit = match[:, :, j][:, :, None] & (c_ids == cj[:, None, None])
        ext = jnp.where(hit, NEG, ext)

    # Candidate grid: blank column carries the stay total.
    stay_tot = merge(stay_pb, stay_pnb)
    cand = jnp.where(c_ids == blank, stay_tot[:, :, None], ext)
    cand = cand.reshape(B, K * V)
    ext_flat = ext.reshape(B, K * V)

    # Top-K by K iterative argmax passes (first-occurrence tie break —
    # identical in jnp and Pallas, so the two impls match bit-for-bit).
    col_ids = jax.lax.broadcasted_iota(jnp.int32, (B, K * V), 1)
    sels = []
    work = cand
    for _ in range(K):
        best = jnp.argmax(work, axis=1).astype(jnp.int32)        # (B,)
        sels.append(best)
        work = jnp.where(col_ids == best[:, None], NEG, work)
    sel = jnp.stack(sels, axis=1)                                # (B, K)

    parent = sel // V
    is_stay = (sel % V) == blank
    new_pb = jnp.where(is_stay, jnp.take_along_axis(stay_pb, parent, 1),
                       NEG)
    new_pnb = jnp.where(is_stay, jnp.take_along_axis(stay_pnb, parent, 1),
                        jnp.take_along_axis(ext_flat, sel, 1))
    return sel, new_pb, new_pnb


def topc_scores(logp, C: int):
    """Per-row top-C of (B, V) log-probs by C iterative argmax passes
    (first-occurrence tie break) — the same selection procedure in the
    jnp and Pallas paths, so the two impls match bit-for-bit; on distinct
    values it equals ``jax.lax.top_k``.  Values are gathered from the
    ORIGINAL row (the sweep stamps a workspace only), so downstream
    arithmetic sees the exact same floats as the unpruned path.

    Returns ``(vals (B, C) f32 descending, idx (B, C) i32)``."""
    B, V = logp.shape
    col_ids = jax.lax.broadcasted_iota(jnp.int32, (B, V), 1)
    work = logp
    vals, idxs = [], []
    for _ in range(C):
        best = jnp.argmax(work, axis=1).astype(jnp.int32)        # (B,)
        vals.append(jnp.take_along_axis(logp, best[:, None], 1)[:, 0])
        idxs.append(best)
        work = jnp.where(col_ids == best[:, None], NEG, work)
    return jnp.stack(vals, axis=1), jnp.stack(idxs, axis=1)


def frame_step_scores_topc(logp, p_b, p_nb, last, phash, plen, *,
                           blank: int, max_len: int, semiring: str,
                           topc: int):
    """Top-C vocab-pruned frame step: identical contract to
    :func:`frame_step_scores` (``sel`` still indexes the K*V grid, so
    :func:`apply_selection` is shared verbatim), but the extend grid is
    (K, C) over the frame's top-C tokens instead of (K, V).

    Exact-mass corrections keep every non-extend term un-pruned: the
    stay scores gather ``logp[blank]`` and ``logp[last[k]]`` directly,
    and the duplicate-merge contribution ``ext[b, k, last[j]]`` is
    recomputed from scalars (``base(k, last[j]) + logp[last[j]]`` — the
    same floats the unpruned path gathers from the (K, V) grid), so
    pruning only ever drops *extension* candidates.  Hence the exactness
    condition (docs/decoding.md §Top-C): the pruned search is
    bit-identical to the unpruned one whenever every extend selected by
    the unpruned top-K uses a token inside the frame's top-C.  C = V is
    unconditionally identical (ties aside — both paths break ties
    first-occurrence, but in different candidate layouts).
    """
    B, V = logp.shape
    K = p_b.shape[1]
    C = topc
    merge = _merge_fn(semiring)
    reduce_ = _reduce_fn(semiring)

    vals, idx = topc_scores(logp, C)                             # (B, C)

    tot = merge(p_b, p_nb)                                       # (B, K)
    stay_pb = tot + logp[:, blank][:, None]
    lp_last = jnp.take_along_axis(logp, jnp.maximum(last, 0), axis=1)
    stay_pnb = jnp.where(last >= 0, p_nb + lp_last, NEG)

    idx3 = idx[:, None, :]                                       # (B, 1, C)
    base = jnp.where(idx3 == last[:, :, None], p_b[:, :, None],
                     tot[:, :, None])
    ext = base + vals[:, None, :]                                # (B, K, C)
    ext = jnp.where(idx3 == blank, NEG, ext)
    ext = jnp.where(plen[:, :, None] >= max_len, NEG, ext)       # U cap

    # Duplicate merge — same (K, K) check as the unpruned path; the
    # gathered e[b,k,j] = ext[b,k,last[j]] is rebuilt from scalars with
    # the same masks the unpruned path applied (U cap; last[j] is never
    # blank), so the merged mass is exact even when last[j] is pruned.
    match = ((plen[:, None, :] == plen[:, :, None] + 1)
             & (phash[:, None, :]
                == phash[:, :, None] * HASH_P + last[:, None, :])
             & (last[:, None, :] >= 0))                          # (B, K, K)
    base_kj = jnp.where(last[:, None, :] == last[:, :, None],
                        p_b[:, :, None], tot[:, :, None])        # (B, K, K)
    e = base_kj + lp_last[:, None, :]
    e = jnp.where(plen[:, :, None] >= max_len, NEG, e)
    contrib = reduce_(jnp.where(match, e, NEG), 1)               # (B, K)
    stay_pnb = merge(stay_pnb, contrib)
    for j in range(K):                           # kill the merged extends
        hit = match[:, :, j][:, :, None] & (idx3 == last[:, j][:, None, None])
        ext = jnp.where(hit, NEG, ext)

    # Candidate grid (B, K*(C+1)): column 0 of each parent is its stay.
    stay_tot = merge(stay_pb, stay_pnb)
    cand = jnp.concatenate([stay_tot[:, :, None], ext], axis=2)
    cand = cand.reshape(B, K * (C + 1))
    ext_flat = ext.reshape(B, K * C)

    col_ids = jax.lax.broadcasted_iota(jnp.int32, (B, K * (C + 1)), 1)
    sels = []
    work = cand
    for _ in range(K):
        best = jnp.argmax(work, axis=1).astype(jnp.int32)        # (B,)
        sels.append(best)
        work = jnp.where(col_ids == best[:, None], NEG, work)
    sel_c = jnp.stack(sels, axis=1)                              # (B, K)

    # Map back to the K*V convention so apply_selection is shared.
    parent = sel_c // (C + 1)
    within = sel_c % (C + 1)
    is_stay = within == 0
    tok = jnp.take_along_axis(
        idx, jnp.clip(within - 1, 0, C - 1).reshape(B, K), axis=1)
    c = jnp.where(is_stay, blank, tok)
    sel = parent * V + c
    new_pb = jnp.where(is_stay, jnp.take_along_axis(stay_pb, parent, 1),
                       NEG)
    new_pnb = jnp.where(is_stay, jnp.take_along_axis(stay_pnb, parent, 1),
                        jnp.take_along_axis(
                            ext_flat,
                            parent * C + jnp.clip(within - 1, 0, C - 1), 1))
    return sel, new_pb, new_pnb


def apply_selection(state: BeamState, sel, new_pb, new_pnb, *, blank: int,
                    vocab: int) -> BeamState:
    """Materialize the selected candidates into the next beam state
    (token gather/append, hash/length bookkeeping — jnp on both impls;
    the kernel only computes ``sel`` and the scores)."""
    B, K, U = state.tokens.shape
    parent = sel // vocab
    c = (sel % vocab).astype(jnp.int32)
    is_stay = c == blank

    tokens = jnp.take_along_axis(state.tokens, parent[:, :, None], axis=1)
    plen = jnp.take_along_axis(state.lens, parent, 1)
    phash = jnp.take_along_axis(state.phash, parent, 1)
    plast = jnp.take_along_axis(state.last, parent, 1)

    u_ids = jnp.arange(U)[None, None, :]
    put = (~is_stay)[:, :, None] & (u_ids == plen[:, :, None])
    tokens = jnp.where(put, c[:, :, None], tokens)
    return state._replace(
        tokens=tokens,
        lens=plen + (~is_stay).astype(jnp.int32),
        last=jnp.where(is_stay, plast, c),
        phash=jnp.where(is_stay, phash, phash * HASH_P + c),
        p_b=new_pb,
        p_nb=new_pnb,
    )


# ---------------------------------------------------------------------------
# chunked decode (the streaming carry) and one-shot search
# ---------------------------------------------------------------------------

def decode_chunk(state: BeamState, logits, lengths=None, *, blank: int = 0,
                 semiring: str = "max", impl: str = "jax",
                 interpret=None, block_b: int = None,
                 topc: int = 0) -> BeamState:
    """Advance the beams over a chunk of frames.

    logits: (B, Tc, V) raw (pre-softmax); ``lengths`` (B,) i32 counts
    TOTAL valid frames from stream start — rows whose ``state.t`` has
    reached their length are frozen (state and counter), so chunked and
    one-shot decodes of the same stream are bit-identical.
    ``impl='pallas'`` routes the per-frame step through the Pallas
    kernel (``decode/kernel.py``); interpret/block_b as there.
    ``topc`` > 0 prunes the extend grid to the frame's top-C tokens
    (:func:`frame_step_scores_topc`; exact when C covers the per-frame
    support — docs/decoding.md §Top-C); 0 or >= V runs unpruned.
    """
    B, Tc, V = logits.shape
    K = state.p_b.shape[1]
    U = state.tokens.shape[2]
    if K > V:
        raise ValueError(f"beam width {K} exceeds vocab {V}")
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    topc = 0 if topc >= V else topc

    if impl == "pallas":
        from repro.decode.kernel import beam_frame_step

        def step_fn(lp, st):
            return beam_frame_step(
                lp, st.p_b, st.p_nb, st.last, st.phash, st.lens,
                blank=blank, max_len=U, semiring=semiring,
                block_b=block_b, interpret=interpret, topc=topc)
    elif topc:
        def step_fn(lp, st):
            return frame_step_scores_topc(
                lp, st.p_b, st.p_nb, st.last, st.phash, st.lens,
                blank=blank, max_len=U, semiring=semiring, topc=topc)
    else:
        def step_fn(lp, st):
            return frame_step_scores(
                lp, st.p_b, st.p_nb, st.last, st.phash, st.lens,
                blank=blank, max_len=U, semiring=semiring)

    def body(st, lp_t):
        sel, npb, npnb = step_fn(lp_t, st)
        new = apply_selection(st, sel, npb, npnb, blank=blank, vocab=V)
        if lengths is None:
            return new._replace(t=st.t + 1), None
        valid = st.t < lengths                                   # (B,)
        v2, v3 = valid[:, None], valid[:, None, None]
        frozen = BeamState(
            tokens=jnp.where(v3, new.tokens, st.tokens),
            lens=jnp.where(v2, new.lens, st.lens),
            last=jnp.where(v2, new.last, st.last),
            phash=jnp.where(v2, new.phash, st.phash),
            p_b=jnp.where(v2, new.p_b, st.p_b),
            p_nb=jnp.where(v2, new.p_nb, st.p_nb),
            t=jnp.where(valid, st.t + 1, st.t),
        )
        return frozen, None

    state, _ = jax.lax.scan(body, state, jnp.moveaxis(logp, 1, 0))
    return state


def beam_occupancy(state: BeamState):
    """(B,) fraction of beam slots holding a live prefix (finite score)
    — the serving/evaluate utilization telemetry (docs/decoding.md)."""
    tot = jnp.maximum(state.p_b, state.p_nb)
    return jnp.mean((tot > NEG / 2).astype(jnp.float32), axis=1)


def finalize(state: BeamState, *, len_norm: float = 0.0,
             semiring: str = "max"):
    """Best hypothesis per row: ``(tokens (B, U) i32 -1-padded,
    lens (B,), scores (B,))``; ``len_norm`` = a ranks by
    ``score / max(len, 1)**a``."""
    U = state.tokens.shape[2]
    tot = _merge_fn(semiring)(state.p_b, state.p_nb)
    score = tot
    if len_norm:
        score = tot / jnp.maximum(state.lens, 1) ** len_norm
    best = jnp.argmax(score, axis=1)
    tokens = jnp.take_along_axis(
        state.tokens, best[:, None, None], axis=1)[:, 0]
    lens = jnp.take_along_axis(state.lens, best[:, None], 1)[:, 0]
    sc = jnp.take_along_axis(score, best[:, None], 1)[:, 0]
    tokens = jnp.where(jnp.arange(U)[None, :] < lens[:, None], tokens, -1)
    return tokens, lens, sc


def beam_search(logits, lengths=None, *, beam: int = 8, blank: int = 0,
                semiring: str = "max", len_norm: float = 0.0,
                max_len: int = None, impl: str = "jax", interpret=None,
                block_b: int = None, topc: int = 0):
    """One-shot batched prefix beam search over (B, T, V) logits.

    Returns ``(tokens (B, U) i32 -1-padded, lens (B,), scores (B,))``.
    ``beam=1`` with the default max semiring reproduces
    ``eval.metrics.greedy_ctc_decode`` exactly (module docstring)."""
    B, T, V = logits.shape
    U = max_len if max_len is not None else T
    state = init_state(B, beam, U)
    state = decode_chunk(state, logits, lengths, blank=blank,
                         semiring=semiring, impl=impl, interpret=interpret,
                         block_b=block_b, topc=topc)
    return finalize(state, len_norm=len_norm, semiring=semiring)


def beam_decode(logits, lengths=None, **kw):
    """:func:`beam_search` with list-of-int-lists output, mirroring
    ``eval.metrics.greedy_ctc_decode`` for drop-in TER scoring."""
    import numpy as np

    tokens, lens, _ = beam_search(logits, lengths, **kw)
    tokens, lens = np.asarray(tokens), np.asarray(lens)
    return [list(map(int, row[:n])) for row, n in zip(tokens, lens)]
