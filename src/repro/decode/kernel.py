"""Pallas TPU kernel for the prefix-beam inner step (+ decode argmax).

The hot loop of CTC beam decoding is the per-frame candidate expansion,
duplicate merge and top-K over the ``beam x vocab`` candidate grid —
O(K·V) scores plus K argmax passes per frame, latency-bound at serving
batch sizes.  :func:`beam_frame_step` runs that step as one Pallas
kernel: the (bB, V) frame log-probs and the six (bB, K) beam-state
vectors are VMEM-resident blocks on a ``(B // bB,)`` batch grid, and
every intermediate (the (bB, K, V) extend scores, the (bB, K, K) merge
match, the (bB, K*V) candidate grid the K argmax passes sweep) lives in
VMEM for the whole step — nothing round-trips HBM between expansion and
selection.

The kernel body calls ``repro.decode.beam.frame_step_scores`` — the
*same* array math as the jnp path — so pallas-vs-jax parity is
bit-for-bit by construction (the tests still assert it, in interpret
mode, like every other kernel in this repo).  The state *update* (token
append, hash/length bookkeeping) stays in jnp outside the kernel: it is
O(K·U) gathers with no V-sized intermediates.

VMEM math (docs/decoding.md, single source :func:`beam_cand_bytes`):
the unpruned resident set per grid step is about ``bB*V*4`` (logp)
+ ``3 * bB*K*V*4`` (base/ext/candidate grids) + small (bB, K) vectors —
for (bB=8, K=8, V=512) about 0.5 MB — and the default ``block_b`` is
picked by :func:`auto_block_b_decode` so the set fits the same 12 MB
default budget the LSTM kernels use.  ``topc=C`` swaps the body for
``frame_step_scores_topc``: the K-scaled grids shrink from (K, V) to
(K, C+1) and vocab survives only in the logp block + top-C sweep
workspace, so the VMEM ceiling (and hence ``block_b``) stops scaling
with vocab — the hard ceiling the unpruned kernel put on V.  Off-TPU the
kernel executes in interpret mode (CI parity path); the gathers inside
``frame_step_scores`` are interpret-validated, compiled-TPU lowering is
tracked with the other real-TPU items in ROADMAP.md.

:func:`argmax_tokens` is the degenerate beam=1 selector — a one-pass
VMEM argmax over (bB, V) logits.  ``launch/serve.py`` routes its
one-token LM decode loop through it under ``--kernel-impl pallas``
(bit-identical to ``jnp.argmax``), so the flag finally covers the whole
request loop, not just prefill.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.decode.beam import (NEG, frame_step_scores,
                               frame_step_scores_topc)
from repro.kernels.lstm_cell import (DEFAULT_VMEM_BUDGET,
                                     _resolve_interpret)


def beam_cand_bytes(beam: int, vocab: int, topc: int = 0) -> int:
    """f32 bytes per batch row of the beam-step candidate working set —
    the single source of the VMEM accounting (docs/decoding.md, the
    ``--only serve`` bench).  Unpruned: ~4 live (K, V) grids
    (base/ext/candidate/argmax sweep) + the (V,) logp block.  With
    top-C pruning the K-scaled grids shrink to (K, C+1) — vocab only
    enters through the logp block and its top-C sweep workspace, so the
    candidate memory scales with C, not V."""
    if topc and topc < vocab:
        return (4 * beam * (topc + 1) + 2 * vocab + 2 * topc) * 4
    return (4 * beam * vocab + vocab) * 4


def auto_block_b_decode(B: int, beam: int, vocab: int,
                        vmem_budget: int = None, topc: int = 0) -> int:
    """Largest batch tile whose beam-step resident set
    (:func:`beam_cand_bytes`) fits the budget."""
    budget = vmem_budget or DEFAULT_VMEM_BUDGET
    per_row = beam_cand_bytes(beam, vocab, topc)
    bb = max(1, budget // max(per_row, 1))
    return int(min(bb, B))


def beam_frame_step(logp, p_b, p_nb, last, phash, plen, *, blank: int,
                    max_len: int, semiring: str, block_b: int = None,
                    interpret=None, topc: int = 0):
    """Pallas-resident ``beam.frame_step_scores``: same signature and
    bit-identical outputs ``(sel, new_pb, new_pnb)``.  ``topc`` > 0
    runs the fused top-C pruned step (``frame_step_scores_topc``): the
    top-C sweep AND the pruned candidate grid live in one kernel, so
    the (bB, K, V) grids never materialize."""
    B, V = logp.shape
    K = p_b.shape[1]
    interpret = _resolve_interpret(interpret)
    topc = 0 if topc >= V else topc
    bb = block_b or auto_block_b_decode(B, K, V, topc=topc)
    bb = max(1, min(bb, B))

    pad = (-B) % bb
    if pad:
        logp = jnp.pad(logp, ((0, pad), (0, 0)))
        p_b = jnp.pad(p_b, ((0, pad), (0, 0)), constant_values=NEG)
        p_nb = jnp.pad(p_nb, ((0, pad), (0, 0)), constant_values=NEG)
        last = jnp.pad(last, ((0, pad), (0, 0)), constant_values=-1)
        phash = jnp.pad(phash, ((0, pad), (0, 0)))
        plen = jnp.pad(plen, ((0, pad), (0, 0)))
    Bp = B + pad

    def kernel(logp_ref, pb_ref, pnb_ref, last_ref, hash_ref, len_ref,
               sel_ref, npb_ref, npnb_ref):
        if topc:
            sel, npb, npnb = frame_step_scores_topc(
                logp_ref[:], pb_ref[:], pnb_ref[:], last_ref[:],
                hash_ref[:], len_ref[:], blank=blank, max_len=max_len,
                semiring=semiring, topc=topc)
        else:
            sel, npb, npnb = frame_step_scores(
                logp_ref[:], pb_ref[:], pnb_ref[:], last_ref[:],
                hash_ref[:], len_ref[:], blank=blank, max_len=max_len,
                semiring=semiring)
        sel_ref[:] = sel
        npb_ref[:] = npb
        npnb_ref[:] = npnb

    row = lambda i: (i, 0)
    spec_v = pl.BlockSpec((bb, V), row, memory_space=pltpu.VMEM)
    spec_k = pl.BlockSpec((bb, K), row, memory_space=pltpu.VMEM)
    sel, npb, npnb = pl.pallas_call(
        kernel,
        grid=(Bp // bb,),
        in_specs=[spec_v, spec_k, spec_k, spec_k, spec_k, spec_k],
        out_specs=(spec_k, spec_k, spec_k),
        out_shape=(
            jax.ShapeDtypeStruct((Bp, K), jnp.int32),
            jax.ShapeDtypeStruct((Bp, K), jnp.float32),
            jax.ShapeDtypeStruct((Bp, K), jnp.float32),
        ),
        interpret=interpret,
    )(logp, p_b, p_nb, last, phash, plen)
    if pad:
        sel, npb, npnb = sel[:B], npb[:B], npnb[:B]
    return sel, npb, npnb


@functools.partial(jax.jit, static_argnames=("interpret", "block_b"))
def argmax_tokens(logits, *, interpret=None, block_b: int = None):
    """(B, V) logits -> (B,) i32 argmax via a VMEM kernel — the beam=1
    token selector of the serving decode loop (bit-matches
    ``jnp.argmax(logits, -1)``)."""
    B, V = logits.shape
    interpret = _resolve_interpret(interpret)
    bb = max(1, min(block_b or B, B))
    pad = (-B) % bb
    if pad:
        logits = jnp.pad(logits, ((0, pad), (0, 0)), constant_values=NEG)
    Bp = B + pad

    def kernel(x_ref, out_ref):
        out_ref[:] = jnp.argmax(
            x_ref[:].astype(jnp.float32), axis=1, keepdims=True
        ).astype(jnp.int32)

    out = pl.pallas_call(
        kernel,
        grid=(Bp // bb,),
        in_specs=[pl.BlockSpec((bb, V), lambda i: (i, 0),
                               memory_space=pltpu.VMEM)],
        out_specs=pl.BlockSpec((bb, 1), lambda i: (i, 0),
                               memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct((Bp, 1), jnp.int32),
        interpret=interpret,
    )(logits)
    return out[:B, 0]
