"""CTC loss (Graves et al. 2006) — the paper's §III names CTC as the
emerging end-to-end ASR criterion alongside frame-CE; provided so the
acoustic-model substrate covers both.

Standard alpha (forward) recursion over the blank-extended label sequence,
in log space, time steps via ``lax.scan``.  Supports per-sequence label
lengths (padded with -1) and per-sequence INPUT lengths (right-padded
frames, the ``lengths`` batch contract of ``repro.data.pipeline``): the
alpha recursion freezes beyond each sequence's last valid frame, which is
exactly the NLL of the truncated unpadded sequence.  Oracle: brute-force
alignment enumeration in tests/test_ctc.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import sequence_mask

NEG = -1e30


def _logsumexp3(a, b, c):
    m = jnp.maximum(jnp.maximum(a, b), c)
    m = jnp.maximum(m, NEG)
    return m + jnp.log(jnp.exp(a - m) + jnp.exp(b - m) + jnp.exp(c - m))


def ctc_loss(logits, labels, label_lengths=None, *, blank: int = 0,
             input_lengths=None):
    """logits: (B, T, V); labels: (B, U) int32 (pad with -1 beyond length);
    label_lengths: (B,) int32 (default: count of non-negative labels);
    input_lengths: (B,) int32 valid frame count per row (default: all T
    frames) — frames at t >= input_lengths[b] are excluded from the
    recursion, matching the unpadded per-sequence NLL.
    Returns mean negative log likelihood over the batch."""
    B, T, V = logits.shape
    U = labels.shape[1]
    if label_lengths is None:
        label_lengths = jnp.sum(labels >= 0, axis=1)
    labels = jnp.maximum(labels, 0)
    frame_ok = (None if input_lengths is None
                else sequence_mask(input_lengths, T))       # (B, T)

    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)

    # blank-extended sequence z: (B, S=2U+1): [b, l1, b, l2, ..., lU, b]
    S = 2 * U + 1
    z = jnp.full((B, S), blank, jnp.int32)
    z = z.at[:, 1::2].set(labels)
    s_idx = jnp.arange(S)
    valid = s_idx[None, :] < (2 * label_lengths + 1)[:, None]     # (B,S)
    # skip-transition allowed where z_s is a label and != z_{s-2}
    z_m2 = jnp.pad(z, ((0, 0), (2, 0)), constant_values=-1)[:, :S]
    can_skip = (s_idx[None, :] % 2 == 1) & (z != z_m2)

    def emit(t):
        return jnp.take_along_axis(logp[:, t], z, axis=1)          # (B,S)

    alpha0 = jnp.full((B, S), NEG)
    alpha0 = alpha0.at[:, 0].set(logp[:, 0, blank])
    alpha0 = alpha0.at[:, 1].set(
        jnp.where(label_lengths > 0,
                  jnp.take_along_axis(logp[:, 0], z[:, 1:2], 1)[:, 0], NEG))

    def step(alpha, t):
        prev1 = jnp.pad(alpha, ((0, 0), (1, 0)), constant_values=NEG)[:, :S]
        prev2 = jnp.pad(alpha, ((0, 0), (2, 0)), constant_values=NEG)[:, :S]
        prev2 = jnp.where(can_skip, prev2, NEG)
        new = _logsumexp3(alpha, prev1, prev2) + emit(t)
        new = jnp.where(valid, new, NEG)
        if frame_ok is not None:
            # padded frame: freeze alpha, so the final read equals the
            # recursion stopped at the row's last valid frame
            new = jnp.where(frame_ok[:, t][:, None], new, alpha)
        return new, None

    alpha, _ = jax.lax.scan(step, alpha0, jnp.arange(1, T))

    last = 2 * label_lengths            # index of final blank
    a_last = jnp.take_along_axis(alpha, last[:, None], 1)[:, 0]
    a_prev = jnp.take_along_axis(
        alpha, jnp.maximum(last - 1, 0)[:, None], 1)[:, 0]
    a_prev = jnp.where(label_lengths > 0, a_prev, NEG)
    m = jnp.maximum(a_last, a_prev)
    nll = -(m + jnp.log(jnp.exp(a_last - m) + jnp.exp(a_prev - m)))
    return jnp.mean(nll)


def collapse_frame_labels(frame_labels, max_len: int, *, blank: int = 0):
    """Frame-wise targets -> collapsed CTC label sequences (numpy, host
    side): remove repeats, shift classes by +1 (0 reserved for blank),
    pad with -1."""
    import numpy as np

    B, T = frame_labels.shape
    out = np.full((B, max_len), -1, np.int32)
    lens = np.zeros((B,), np.int32)
    for b in range(B):
        prev, j = None, 0
        for t in range(T):
            c = int(frame_labels[b, t])
            if c != prev and j < max_len:
                out[b, j] = c + 1
                j += 1
            prev = c
        lens[b] = j
    return out, lens
