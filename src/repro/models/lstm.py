"""The paper's acoustic model: 6-layer bi-directional LSTM DNN-HMM with a
linear bottleneck and a 32,000-way CD-HMM-state softmax (Cui et al. §V).

The LSTM cell is the compute hot-spot the Pallas kernel in
``repro.kernels.lstm_cell`` fuses (gate matmuls + elementwise); this module
doubles as its pure-jnp oracle through ``repro.kernels.ref``.

Variable-length utterances (the ``lengths`` batch contract)
-----------------------------------------------------------
``forward``/``loss_train`` accept right-padded batches with a per-row
valid-length vector ``lengths`` (B,) — the contract emitted by
``repro.data.pipeline`` with ``var_len=True``.  Masking semantics, shared
bit-for-bit by the jax scan and the Pallas kernels:

* on padded steps (t >= lengths[b]) the recurrent (h, c) carry is FROZEN
  (not updated), so padded frames cannot enter any weight gradient;
* the layer output at padded frames is 0, so the next layer sees zeroed
  padding exactly like the input layer did;
* the backward direction therefore reverses *within* each utterance's
  valid span: right-padding means its leading invalid segment carries the
  zero initial state untouched until the last valid frame;
* the loss is normalized by the number of valid frames, not B*T.

When ``lengths`` is None every path reduces to the rectangular behavior.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import cross_entropy, sequence_mask
from repro.sharding import ParamSpec


def lstm_cell_step(wx, wh, b, x_t, h, c):
    """One LSTM step.  x_t: (B,D_in); h/c: (B,H).  Gate order: i,f,g,o."""
    gates = (jnp.einsum("bd,dg->bg", x_t, wx)
             + jnp.einsum("bh,hg->bg", h, wh)).astype(jnp.float32) + b
    i, f, g, o = jnp.split(gates, 4, axis=-1)
    c = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h = jax.nn.sigmoid(o) * jnp.tanh(c)
    return h.astype(x_t.dtype), c


def _kernel_knobs(cfg):
    """(block_b, vmem_budget, stash_dtype, seq_chunk) for the Pallas LSTM
    kernels (seq_chunk: 0 = per-step stash, -1 = auto-tuned chunk length,
    K > 0 = K-frame chunked recompute; docs/kernels.md)."""
    block_b = getattr(cfg, "lstm_block_b", 0) or None
    budget_mb = getattr(cfg, "lstm_vmem_budget_mb", 0)
    stash = getattr(cfg, "lstm_stash_dtype", "float32") or "float32"
    seq_chunk = getattr(cfg, "lstm_seq_chunk", 0) or 0
    return (block_b, (budget_mb * 2 ** 20 if budget_mb else None), stash,
            seq_chunk)


def lstm_layer(p, x, *, lengths=None, reverse: bool = False,
               kernel_impl: str = "jax", block_b: int = None,
               vmem_budget: int = None, stash_dtype: str = None,
               seq_chunk: int = 0):
    """x: (B,T,D_in) -> (B,T,H).

    ``lengths`` (B,) int enables the masked recurrence (carry frozen and
    output zeroed at t >= lengths[b]; see module docstring)."""
    B, T, _ = x.shape
    H = p["wh"].shape[0]
    h0 = jnp.zeros((B, H), x.dtype)
    c0 = jnp.zeros((B, H), jnp.float32)

    if kernel_impl == "pallas":
        from repro.kernels.ops import lstm_sequence
        return lstm_sequence(p["wx"], p["wh"], p["b"], x, lengths,
                             reverse=reverse, block_b=block_b,
                             vmem_budget=vmem_budget,
                             stash_dtype=stash_dtype,
                             seq_chunk=seq_chunk)

    if lengths is None:
        def step(carry, x_t):
            h, c = carry
            h, c = lstm_cell_step(p["wx"], p["wh"], p["b"], x_t, h, c)
            return (h, c), h

        xs = jnp.moveaxis(x, 1, 0)
        (_, _), hs = jax.lax.scan(step, (h0, c0), xs, reverse=reverse)
        return jnp.moveaxis(hs, 0, 1)

    def step(carry, inp):
        x_t, t = inp
        h, c = carry
        h2, c2 = lstm_cell_step(p["wx"], p["wh"], p["b"], x_t, h, c)
        v = (t < lengths)[:, None]
        h = jnp.where(v, h2, h)                       # freeze the carry
        c = jnp.where(v, c2, c)
        return (h, c), jnp.where(v, h2, jnp.zeros_like(h2))

    xs = jnp.moveaxis(x, 1, 0)
    (_, _), hs = jax.lax.scan(step, (h0, c0), (xs, jnp.arange(T)),
                              reverse=reverse)
    return jnp.moveaxis(hs, 0, 1)


def layer_specs(d_in: int, hidden: int, dtype: str):
    return {
        "fwd": {
            "wx": ParamSpec((d_in, 4 * hidden), dtype,
                            ("feature", "lstm_gates"), "lecun"),
            "wh": ParamSpec((hidden, 4 * hidden), dtype,
                            ("lstm_hidden", "lstm_gates"), "lecun"),
            "b": ParamSpec((4 * hidden,), "float32", ("lstm_gates",), "zeros"),
        },
        "bwd": {
            "wx": ParamSpec((d_in, 4 * hidden), dtype,
                            ("feature", "lstm_gates"), "lecun"),
            "wh": ParamSpec((hidden, 4 * hidden), dtype,
                            ("lstm_hidden", "lstm_gates"), "lecun"),
            "b": ParamSpec((4 * hidden,), "float32", ("lstm_gates",), "zeros"),
        },
    }


def param_specs(cfg):
    H = cfg.lstm_hidden
    dt = cfg.param_dtype
    layers = {}
    d_in = cfg.input_dim
    for i in range(cfg.n_layers):
        layers[f"layer_{i}"] = layer_specs(d_in, H, dt)
        d_in = 2 * H
    return {
        "layers": layers,
        "bottleneck": ParamSpec((2 * H, cfg.lstm_bottleneck), dt,
                                ("lstm_hidden", "bottleneck"), "lecun"),
        "softmax_w": ParamSpec((cfg.lstm_bottleneck, cfg.vocab), dt,
                               ("bottleneck", "vocab"), "normal", 0.02),
        "softmax_b": ParamSpec((cfg.vocab,), "float32", ("vocab",), "zeros"),
    }


def forward(cfg, params, features, lengths=None, *,
            kernel_impl: str = "jax"):
    """features: (B, T, input_dim) -> logits (B, T, vocab).

    The pallas path runs the WHOLE bi-LSTM stack as one fused kernel
    invocation (``repro.kernels.ops.blstm_stack``): inter-layer
    activations stay VMEM-resident on the inference call, and under
    ``jax.value_and_grad`` its custom VJP falls back to the per-layer
    stashing forward/backward (honoring the ``lstm_stash_dtype`` /
    ``lstm_seq_chunk`` config knobs).

    ``lengths`` (B,) int threads the masked recurrence through every
    layer (frozen carries + zeroed padded outputs; module docstring)."""
    x = features.astype(jnp.bfloat16)
    block_b, vmem_budget, stash_dtype, seq_chunk = _kernel_knobs(cfg)
    if kernel_impl == "pallas":
        from repro.kernels.ops import blstm_stack
        layers = tuple(
            (p["fwd"]["wx"], p["fwd"]["wh"], p["fwd"]["b"],
             p["bwd"]["wx"], p["bwd"]["wh"], p["bwd"]["b"])
            for p in (params["layers"][f"layer_{i}"]
                      for i in range(cfg.n_layers)))
        x = blstm_stack(layers, x, lengths, block_b=block_b,
                        vmem_budget=vmem_budget, stash_dtype=stash_dtype,
                        seq_chunk=seq_chunk)
    else:
        for i in range(cfg.n_layers):
            p = params["layers"][f"layer_{i}"]
            fwd = lstm_layer(p["fwd"], x, lengths=lengths,
                             kernel_impl=kernel_impl)
            bwd = lstm_layer(p["bwd"], x, lengths=lengths, reverse=True,
                             kernel_impl=kernel_impl)
            x = jnp.concatenate([fwd, bwd], axis=-1)
    x = jnp.einsum("btd,dk->btk", x, params["bottleneck"])
    logits = (jnp.einsum("btk,kv->btv", x, params["softmax_w"])
              .astype(jnp.float32) + params["softmax_b"])
    return logits


def loss_train(cfg, params, batch, *, kernel_impl: str = "jax"):
    """Frame-level CE.  If the batch carries ``lengths``, padded frames are
    excluded and the loss normalizes by the valid-frame count (the masked
    contract of ``repro.data.pipeline``)."""
    lengths = batch.get("lengths")
    logits = forward(cfg, params, batch["features"], lengths,
                     kernel_impl=kernel_impl)
    mask = (None if lengths is None
            else sequence_mask(lengths, logits.shape[1]))
    return cross_entropy(logits, batch["labels"], mask=mask)
