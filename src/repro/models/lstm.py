"""The paper's acoustic model: 6-layer bi-directional LSTM DNN-HMM with a
linear bottleneck and a 32,000-way CD-HMM-state softmax (Cui et al. §V).

The LSTM cell is the compute hot-spot the Pallas kernel in
``repro.kernels.lstm_cell`` fuses (gate matmuls + elementwise); this module
doubles as its pure-jnp oracle through ``repro.kernels.ref``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import cross_entropy
from repro.sharding import ParamSpec


def lstm_cell_step(wx, wh, b, x_t, h, c):
    """One LSTM step.  x_t: (B,D_in); h/c: (B,H).  Gate order: i,f,g,o."""
    gates = (jnp.einsum("bd,dg->bg", x_t, wx)
             + jnp.einsum("bh,hg->bg", h, wh)).astype(jnp.float32) + b
    i, f, g, o = jnp.split(gates, 4, axis=-1)
    c = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h = jax.nn.sigmoid(o) * jnp.tanh(c)
    return h.astype(x_t.dtype), c


def _kernel_knobs(cfg):
    """(block_b, vmem_budget) for the Pallas LSTM kernels from cfg."""
    block_b = getattr(cfg, "lstm_block_b", 0) or None
    budget_mb = getattr(cfg, "lstm_vmem_budget_mb", 0)
    return block_b, (budget_mb * 2 ** 20 if budget_mb else None)


def lstm_layer(p, x, *, reverse: bool = False, kernel_impl: str = "jax",
               block_b: int = None, vmem_budget: int = None):
    """x: (B,T,D_in) -> (B,T,H)."""
    B, T, _ = x.shape
    H = p["wh"].shape[0]
    h0 = jnp.zeros((B, H), x.dtype)
    c0 = jnp.zeros((B, H), jnp.float32)

    if kernel_impl == "pallas":
        from repro.kernels.ops import lstm_sequence
        return lstm_sequence(p["wx"], p["wh"], p["b"], x, reverse=reverse,
                             block_b=block_b, vmem_budget=vmem_budget)

    def step(carry, x_t):
        h, c = carry
        h, c = lstm_cell_step(p["wx"], p["wh"], p["b"], x_t, h, c)
        return (h, c), h

    xs = jnp.moveaxis(x, 1, 0)
    (_, _), hs = jax.lax.scan(step, (h0, c0), xs, reverse=reverse)
    return jnp.moveaxis(hs, 0, 1)


def layer_specs(d_in: int, hidden: int, dtype: str):
    return {
        "fwd": {
            "wx": ParamSpec((d_in, 4 * hidden), dtype,
                            ("feature", "lstm_gates"), "lecun"),
            "wh": ParamSpec((hidden, 4 * hidden), dtype,
                            ("lstm_hidden", "lstm_gates"), "lecun"),
            "b": ParamSpec((4 * hidden,), "float32", ("lstm_gates",), "zeros"),
        },
        "bwd": {
            "wx": ParamSpec((d_in, 4 * hidden), dtype,
                            ("feature", "lstm_gates"), "lecun"),
            "wh": ParamSpec((hidden, 4 * hidden), dtype,
                            ("lstm_hidden", "lstm_gates"), "lecun"),
            "b": ParamSpec((4 * hidden,), "float32", ("lstm_gates",), "zeros"),
        },
    }


def param_specs(cfg):
    H = cfg.lstm_hidden
    dt = cfg.param_dtype
    layers = {}
    d_in = cfg.input_dim
    for i in range(cfg.n_layers):
        layers[f"layer_{i}"] = layer_specs(d_in, H, dt)
        d_in = 2 * H
    return {
        "layers": layers,
        "bottleneck": ParamSpec((2 * H, cfg.lstm_bottleneck), dt,
                                ("lstm_hidden", "bottleneck"), "lecun"),
        "softmax_w": ParamSpec((cfg.lstm_bottleneck, cfg.vocab), dt,
                               ("bottleneck", "vocab"), "normal", 0.02),
        "softmax_b": ParamSpec((cfg.vocab,), "float32", ("vocab",), "zeros"),
    }


def forward(cfg, params, features, *, kernel_impl: str = "jax"):
    """features: (B, T, input_dim) -> logits (B, T, vocab).

    The pallas path runs each bi-LSTM layer as ONE fused kernel
    invocation (both directions' weights resident in VMEM, x handed to
    the kernel once) instead of two sequential direction passes."""
    x = features.astype(jnp.bfloat16)
    block_b, vmem_budget = _kernel_knobs(cfg)
    for i in range(cfg.n_layers):
        p = params["layers"][f"layer_{i}"]
        if kernel_impl == "pallas":
            from repro.kernels.ops import blstm_sequence
            x = blstm_sequence(p["fwd"]["wx"], p["fwd"]["wh"], p["fwd"]["b"],
                               p["bwd"]["wx"], p["bwd"]["wh"], p["bwd"]["b"],
                               x, block_b=block_b, vmem_budget=vmem_budget)
            continue
        fwd = lstm_layer(p["fwd"], x, kernel_impl=kernel_impl)
        bwd = lstm_layer(p["bwd"], x, reverse=True, kernel_impl=kernel_impl)
        x = jnp.concatenate([fwd, bwd], axis=-1)
    x = jnp.einsum("btd,dk->btk", x, params["bottleneck"])
    logits = (jnp.einsum("btk,kv->btv", x, params["softmax_w"])
              .astype(jnp.float32) + params["softmax_b"])
    return logits


def loss_train(cfg, params, batch, *, kernel_impl: str = "jax"):
    logits = forward(cfg, params, batch["features"], kernel_impl=kernel_impl)
    return cross_entropy(logits, batch["labels"])
