"""Model facade: one uniform interface over all architecture families.

``build_model(cfg)`` returns a :class:`Model` exposing

* ``param_specs()``            — ParamSpec tree (shapes/axes/init)
* ``loss_fn(params, batch)``   — scalar training loss
* ``prefill_fn / decode_fn``   — serving steps (KV/SSM caches)
* ``cache_specs(shape)``       — decode-state ParamSpec tree
* ``input_specs(shape, mode)`` — ParamSpec tree describing batch inputs

Everything is ParamSpec-based so the same definition drives (a) real
initialization for smoke tests/examples and (b) ShapeDtypeStruct stand-ins
for the multi-pod dry-run.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import encdec as ED
from repro.models import lstm as LS
from repro.models import transformer as TF
from repro.sharding import ParamSpec


def _i32(shape, axes):
    return ParamSpec(shape, "int32", axes, "zeros")


def _emb(shape, axes):
    return ParamSpec(shape, "bfloat16", axes, "normal", 1.0)


@dataclass(frozen=True)
class Model:
    cfg: ArchConfig

    # ------------------------------------------------------------- params
    def param_specs(self):
        fam = self.cfg.family
        if fam == "encdec":
            return ED.param_specs(self.cfg)
        if fam == "lstm":
            return LS.param_specs(self.cfg)
        return TF.param_specs(self.cfg)

    # --------------------------------------------------------------- train
    def loss_fn(self, params, batch, *, kernel_impl: str = "jax",
                batch_axis: str = ""):
        fam = self.cfg.family
        if fam == "encdec":
            return ED.loss_train(self.cfg, params, batch,
                                 batch_axis=batch_axis)
        if fam == "lstm":
            return LS.loss_train(self.cfg, params, batch,
                                 kernel_impl=kernel_impl)
        return TF.loss_train(self.cfg, params, batch,
                             kernel_impl=kernel_impl, batch_axis=batch_axis)

    # --------------------------------------------------------------- serve
    def prefill_fn(self, params, batch, *, cache_len: int = 0,
                   long_context: bool = False, kernel_impl: str = "jax"):
        fam = self.cfg.family
        if fam == "encdec":
            return ED.prefill(self.cfg, params, batch["frames"],
                              batch["tokens"], cache_len=cache_len)
        patches = batch.get("patches")
        return TF.prefill(self.cfg, params, batch["tokens"],
                          cache_len=cache_len, patches=patches,
                          long_context=long_context,
                          kernel_impl=kernel_impl)

    def decode_fn(self, params, cache, tokens, pos, *,
                  long_context: bool = False, kernel_impl: str = "jax",
                  page_table=None, page_size: int = 0):
        fam = self.cfg.family
        if fam == "encdec":
            if page_table is not None:
                raise ValueError("paged KV cache: decoder-only families")
            return ED.decode_step(self.cfg, params, cache, tokens, pos)
        return TF.decode_step(self.cfg, params, cache, tokens, pos,
                              long_context=long_context,
                              kernel_impl=kernel_impl,
                              page_table=page_table, page_size=page_size)

    # --------------------------------------------------------------- specs
    def cache_specs(self, shape: ShapeConfig):
        cfg = self.cfg
        B = shape.global_batch
        if cfg.family == "encdec":
            half = shape.seq_len // 2
            return ED.cache_specs(cfg, B, half, half)
        return TF.cache_specs(cfg, B, shape.seq_len)

    def page_specs(self, n_pages: int, page_size: int):
        """Paged decode-state specs (one shared page pool; serve.py
        ``--cache paged``)."""
        return TF.page_specs(self.cfg, n_pages, page_size)

    def input_specs(self, shape: ShapeConfig, mode: str = None):
        """ParamSpec tree of the model inputs for one assigned shape.

        mode: 'train' | 'prefill' | 'decode' (default: shape.kind).
        """
        cfg = self.cfg
        mode = mode or shape.kind
        B, S = shape.global_batch, shape.seq_len
        fam = cfg.family

        if fam == "lstm":
            assert mode == "train", "frame classifier has no decode/prefill"
            return {
                "features": _emb((B, S, cfg.input_dim),
                                 ("batch", "seq", "feature")),
                "labels": _i32((B, S), ("batch", "seq")),
            }

        if fam == "encdec":
            half = S // 2
            if mode == "train":
                return {
                    "frames": _emb((B, half, cfg.d_model),
                                   ("batch", "frames", "embed")),
                    "tokens": _i32((B, half), ("batch", "seq")),
                    "labels": _i32((B, half), ("batch", "seq")),
                }
            if mode == "prefill":
                return {
                    "frames": _emb((B, half, cfg.d_model),
                                   ("batch", "frames", "embed")),
                    "tokens": _i32((B, half), ("batch", "seq")),
                }
            return {"tokens": _i32((B, 1), ("batch", None)),
                    "pos": _i32((), ())}

        if fam == "vlm" and mode in ("train", "prefill"):
            sp = int(S * cfg.vlm_patch_frac)
            st = S - sp
            d = {
                "patches": _emb((B, sp, cfg.d_model),
                                ("batch", "seq", "embed")),
                "tokens": _i32((B, st), ("batch", "seq")),
            }
            if mode == "train":
                d["labels"] = _i32((B, st), ("batch", "seq"))
            return d

        if mode in ("train", "prefill"):
            d = {"tokens": _i32((B, S), ("batch", "seq"))}
            if mode == "train":
                d["labels"] = _i32((B, S), ("batch", "seq"))
            return d

        # decode: one new token against a seq_len cache
        return {"tokens": _i32((B, 1), ("batch", None)),
                "pos": _i32((), ())}


def build_model(cfg: ArchConfig) -> Model:
    return Model(cfg)
