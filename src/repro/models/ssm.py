"""Mamba-2 SSD (state-space duality) block, chunked for TPU.

The sequence path uses the SSD chunked algorithm [arXiv:2405.21060 §6]:
within-chunk interactions are a small quadratic "attention-like" matmul
(MXU-friendly), across-chunk state is a first-order recurrence carried by
``lax.scan``.  The chunk loop is the unit the Pallas kernel in
``repro.kernels.ssd_scan`` tiles into VMEM; this module is also its oracle
via ``repro.kernels.ref``.

Decode keeps (conv window, SSM state) per layer: O(1) per token, which is
what makes ``long_500k`` native for ssm/hybrid architectures.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import rmsnorm
from repro.sharding import ParamSpec


def ssm_dims(cfg):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    return d_inner, n_heads


def ssm_param_specs(cfg):
    s = cfg.ssm
    d = cfg.d_model
    d_inner, H = ssm_dims(cfg)
    GN = s.n_groups * s.state_dim
    dt = cfg.param_dtype
    W = s.conv_width
    return {
        "wz": ParamSpec((d, d_inner), dt, ("embed", "ssm_inner"), "lecun"),
        "wx": ParamSpec((d, d_inner), dt, ("embed", "ssm_inner"), "lecun"),
        "wB": ParamSpec((d, GN), dt, ("embed", "ssm_state"), "lecun"),
        "wC": ParamSpec((d, GN), dt, ("embed", "ssm_state"), "lecun"),
        "wdt": ParamSpec((d, H), dt, ("embed", "ssm_heads"), "lecun"),
        "conv_x": ParamSpec((W, d_inner), "float32", (None, "ssm_inner"), "lecun"),
        "conv_B": ParamSpec((W, GN), "float32", (None, "ssm_state"), "lecun"),
        "conv_C": ParamSpec((W, GN), "float32", (None, "ssm_state"), "lecun"),
        "dt_bias": ParamSpec((H,), "float32", ("ssm_heads",), "zeros"),
        "A_log": ParamSpec((H,), "float32", ("ssm_heads",), "small_a_log"),
        "D": ParamSpec((H,), "float32", ("ssm_heads",), "ones"),
        "norm_scale": ParamSpec((d_inner,), "float32", ("ssm_inner",), "ones"),
        "out": ParamSpec((d_inner, d), dt, ("ssm_inner", "embed"), "lecun"),
    }


# ---------------------------------------------------------------------------
# causal depthwise conv
# ---------------------------------------------------------------------------

def causal_conv_seq(x, kernel):
    """x: (B,S,C); kernel: (W,C) depthwise; causal (left) padding."""
    W = kernel.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    # sum of shifted slices — W is tiny (4), unrolled adds beat conv lowering
    S = x.shape[1]
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(W):
        out = out + xp[:, i:i + S, :].astype(jnp.float32) * kernel[i]
    return out.astype(x.dtype)


def causal_conv_step(buf, xt, kernel):
    """buf: (B,W-1,C) previous inputs; xt: (B,C).  Returns (new_buf, yt)."""
    W = kernel.shape[0]
    window = jnp.concatenate([buf, xt[:, None, :]], axis=1)          # (B,W,C)
    yt = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32), kernel)
    return window[:, 1:, :], yt.astype(xt.dtype)


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------

def ssd_chunked(x, dt, A, Bm, Cm, chunk: int, h0=None):
    """SSD over a full sequence.

    x:  (B,S,H,P)   inputs per SSM head
    dt: (B,S,H)     discretization steps (softplus'ed, f32)
    A:  (H,)        negative continuous-time decay
    Bm: (B,S,H,N)   input matrix (groups already broadcast to heads)
    Cm: (B,S,H,N)   output matrix
    Returns (y (B,S,H,P), final_state (B,H,N,P) f32).
    """
    B, S, H, Pd = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    S_orig = S
    pad = (-S) % Q
    if pad:
        # zero-dt padding is exact: dt=0 tokens contribute nothing to the
        # state (dtA=0 -> decay 1, input weight 0); padded y is sliced off
        zp = lambda a: jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))
        x, dt, Bm, Cm = zp(x), zp(dt), zp(Bm), zp(Cm)
        S = S + pad
    nc = S // Q

    def to_chunks(a):
        return jnp.moveaxis(a.reshape(B, nc, Q, *a.shape[2:]), 1, 0)

    xc, dtc, Bc, Cc = map(to_chunks, (x, dt, Bm, Cm))
    if h0 is None:
        h0 = jnp.zeros((B, H, N, Pd), jnp.float32)

    @jax.checkpoint
    def body(h, inp):
        x_, dt_, B_, C_ = inp                       # (B,Q,...)
        dtA = dt_ * A                               # (B,Q,H) f32, negative
        cum = jnp.cumsum(dtA, axis=1)               # (B,Q,H)
        # ---- intra-chunk (quadratic within the chunk)
        scores = jnp.einsum("bqhn,bkhn->bhqk",
                            C_.astype(jnp.bfloat16), B_.astype(jnp.bfloat16))
        # mask the EXPONENT (not the exp) — exp(cum_q - cum_k) overflows to
        # inf for masked q<k entries and NaN-poisons the backward pass
        diff = cum[:, :, None, :] - cum[:, None, :, :]               # (B,q,k,H)
        mask = jnp.tril(jnp.ones((Q, Q), bool))
        decay = jnp.exp(jnp.where(mask[None, :, :, None], diff, -1e30))
        w = scores.astype(jnp.float32) * jnp.moveaxis(decay, 3, 1)   # (B,H,q,k)
        y = jnp.einsum("bhqk,bkh,bkhp->bqhp",
                       w.astype(jnp.bfloat16),
                       dt_.astype(jnp.bfloat16),
                       x_.astype(jnp.bfloat16))
        # ---- inter-chunk (state from previous chunks)
        out_decay = jnp.exp(cum)                                     # (B,Q,H)
        y = y + jnp.einsum("bqhn,bhnp,bqh->bqhp",
                           C_.astype(jnp.float32), h, out_decay
                           ).astype(y.dtype)
        # ---- state update
        last = cum[:, -1:, :]                                        # (B,1,H)
        in_decay = jnp.exp(last - cum) * dt_                         # (B,Q,H)
        S_c = jnp.einsum("bkhn,bkh,bkhp->bhnp",
                         B_.astype(jnp.float32), in_decay,
                         x_.astype(jnp.float32))
        h = jnp.exp(last[:, 0, :])[:, :, None, None] * h + S_c
        return h, y

    h_final, ys = jax.lax.scan(body, h0, (xc, dtc, Bc, Cc))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, H, Pd)
    return y[:, :S_orig], h_final


def ssd_step(h, xt, dtt, A, Bt, Ct):
    """One decode step.  h: (B,H,N,P) f32; xt: (B,H,P); dtt: (B,H);
    Bt/Ct: (B,H,N).  Returns (h', yt)."""
    dA = jnp.exp(dtt * A)                                            # (B,H)
    dBx = jnp.einsum("bhn,bh,bhp->bhnp", Bt.astype(jnp.float32),
                     dtt, xt.astype(jnp.float32))
    h = dA[:, :, None, None] * h + dBx
    yt = jnp.einsum("bhn,bhnp->bhp", Ct.astype(jnp.float32), h)
    return h, yt.astype(xt.dtype)


# ---------------------------------------------------------------------------
# Full mamba2 block
# ---------------------------------------------------------------------------

def _projections(cfg, p, x):
    s = cfg.ssm
    z = jnp.einsum("bsd,di->bsi", x, p["wz"])
    xi = jnp.einsum("bsd,di->bsi", x, p["wx"])
    Bp = jnp.einsum("bsd,dn->bsn", x, p["wB"])
    Cp = jnp.einsum("bsd,dn->bsn", x, p["wC"])
    dt_raw = jnp.einsum("bsd,dh->bsh", x.astype(jnp.float32), p["wdt"])
    return z, xi, Bp, Cp, dt_raw


def _broadcast_groups(cfg, a, H):
    """(B,S,G*N) -> (B,S,H,N) by repeating groups across their heads."""
    s = cfg.ssm
    B_, S_ = a.shape[:2]
    a = a.reshape(B_, S_, s.n_groups, s.state_dim)
    reps = H // s.n_groups
    return jnp.repeat(a, reps, axis=2)


def mamba2_seq(cfg, p, x, *, kernel_impl: str = "jax"):
    """Full-sequence mamba2 block.  x: (B,S,d) -> (y, (conv_state, ssm_state))."""
    s = cfg.ssm
    d_inner, H = ssm_dims(cfg)
    B_, S_, _ = x.shape
    z, xi, Bp, Cp, dt_raw = _projections(cfg, p, x)
    xi_c = jax.nn.silu(causal_conv_seq(xi, p["conv_x"]))
    Bp_c = jax.nn.silu(causal_conv_seq(Bp, p["conv_B"]))
    Cp_c = jax.nn.silu(causal_conv_seq(Cp, p["conv_C"]))
    dt = jax.nn.softplus(dt_raw + p["dt_bias"])                      # f32
    A = -jnp.exp(p["A_log"])
    xh = xi_c.reshape(B_, S_, H, s.head_dim)
    Bh = _broadcast_groups(cfg, Bp_c, H)
    Ch = _broadcast_groups(cfg, Cp_c, H)
    if kernel_impl == "pallas":
        from repro.kernels.ops import ssd as ssd_op
        y, h_final = ssd_op(xh, dt, A, Bh, Ch, chunk=s.chunk)
    else:
        y, h_final = ssd_chunked(xh, dt, A, Bh, Ch, s.chunk)
    y = y + (p["D"][:, None] * xh.astype(jnp.float32)).astype(y.dtype)
    y = y.reshape(B_, S_, d_inner)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                p["norm_scale"])
    out = jnp.einsum("bsi,id->bsd", y, p["out"])
    # decode-ready states: last conv_width-1 pre-activation conv inputs
    W = s.conv_width
    conv_state = {
        "x": xi[:, S_ - (W - 1):, :],
        "B": Bp[:, S_ - (W - 1):, :],
        "C": Cp[:, S_ - (W - 1):, :],
    }
    return out, (conv_state, h_final)


def mamba2_step(cfg, p, xt, conv_state, h):
    """One-token decode.  xt: (B,1,d) -> (y (B,1,d), new states)."""
    s = cfg.ssm
    d_inner, H = ssm_dims(cfg)
    z, xi, Bp, Cp, dt_raw = _projections(cfg, p, xt)
    sq = lambda a: a[:, 0, :]
    cs_x, xi_t = causal_conv_step(conv_state["x"], sq(xi), p["conv_x"])
    cs_B, Bp_t = causal_conv_step(conv_state["B"], sq(Bp), p["conv_B"])
    cs_C, Cp_t = causal_conv_step(conv_state["C"], sq(Cp), p["conv_C"])
    xi_t, Bp_t, Cp_t = map(jax.nn.silu, (xi_t, Bp_t, Cp_t))
    dt = jax.nn.softplus(sq(dt_raw) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    B_ = xt.shape[0]
    xh = xi_t.reshape(B_, H, s.head_dim)
    Bh = _broadcast_groups(cfg, Bp_t[:, None, :], H)[:, 0]
    Ch = _broadcast_groups(cfg, Cp_t[:, None, :], H)[:, 0]
    h, yt = ssd_step(h, xh, dt, A, Bh, Ch)
    yt = yt + (p["D"][:, None] * xh.astype(jnp.float32)).astype(yt.dtype)
    yt = yt.reshape(B_, 1, d_inner)
    yt = rmsnorm(yt * jax.nn.silu(z.astype(jnp.float32)).astype(yt.dtype),
                 p["norm_scale"])
    out = jnp.einsum("bsi,id->bsd", yt, p["out"])
    return out, ({"x": cs_x, "B": cs_B, "C": cs_C}, h)


def ssm_cache_specs(cfg, batch: int):
    """ParamSpec-shaped description of per-layer decode state."""
    s = cfg.ssm
    d_inner, H = ssm_dims(cfg)
    GN = s.n_groups * s.state_dim
    W = s.conv_width
    mk = lambda shape, axes, dtype="bfloat16": ParamSpec(shape, dtype, axes)
    return {
        "conv": {
            "x": mk((batch, W - 1, d_inner), ("batch", None, "ssm_inner")),
            "B": mk((batch, W - 1, GN), ("batch", None, "ssm_state")),
            "C": mk((batch, W - 1, GN), ("batch", None, "ssm_state")),
        },
        "h": mk((batch, H, s.state_dim, s.head_dim),
                ("batch", "ssm_heads", "ssm_state", None), "float32"),
    }
