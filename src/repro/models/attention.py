"""Grouped-query attention with three execution paths:

* ``attn_seq``    — full-sequence (train / prefill): q-chunked streaming
  softmax so the score tensor never materializes at (S, S); causal and
  sliding-window masks are applied per chunk.  This is the pure-JAX
  counterpart of the Pallas flash kernel in ``repro.kernels.flash_attention``
  (selected via ``impl='pallas'``).
* ``attn_decode`` — single-token step against a KV cache (serve path).
  ``impl='pallas'`` selects the decode-shaped streaming kernel in
  ``repro.kernels.decode_attention`` (same masks, online softmax over
  S-tiles); ``attn_decode_delta(impl='pallas')`` uses its fused variant
  that folds the new-token column in without re-reading the cache.
* cross-attention (encoder-decoder) reuses ``attn_seq`` without a mask.

Sliding windows are mask-based: the per-layer window rides through the
layer ``scan`` as data, which lets heterogeneous stacks (hymba/llama4
global+local layers) share one compiled body.  See DESIGN.md §Attention.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import apply_rope
from repro.sharding import ParamSpec

NEG_INF = -1e30


def _constrain_dims(x, assignments):
    """Constrain selected dims of x to mesh axes, leaving the others
    UNCONSTRAINED (a bare None would force replication — which silently
    un-shards a data-sharded batch dim, §Perf pair-C iter 3).  Entries with
    axes missing from the mesh are dropped (tests/examples run meshless).
    assignments: {dim: axis_or_None}; None means force-replicate that dim."""
    from jax.sharding import PartitionSpec as P

    mesh = jax.sharding.get_abstract_mesh()
    spec = [P.UNCONSTRAINED] * x.ndim
    any_set = False
    for dim, axis in assignments.items():
        if axis is None:
            spec[dim] = None
            any_set = True
        elif axis in mesh.axis_names:
            spec[dim] = axis
            any_set = True
    if not any_set or not mesh.axis_names:
        return x
    return jax.lax.with_sharding_constraint(x, P(*spec))


def _gather_last(x):
    """Force the last dim (head_dim) to full size, other dims untouched."""
    from jax.sharding import PartitionSpec as P

    mesh = jax.sharding.get_abstract_mesh()
    if not mesh.axis_names:
        return x
    spec = [P.UNCONSTRAINED] * (x.ndim - 1) + [None]
    return jax.lax.with_sharding_constraint(x, P(*spec))


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def attn_param_specs(cfg, *, dtype=None):
    dt = dtype or cfg.param_dtype
    d, H, KV, E = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p = {
        "wq": ParamSpec((d, H, E), dt, ("embed", "heads", "head_dim"), "lecun"),
        "wk": ParamSpec((d, KV, E), dt, ("embed", "kv_heads", "head_dim"), "lecun"),
        "wv": ParamSpec((d, KV, E), dt, ("embed", "kv_heads", "head_dim"), "lecun"),
        "wo": ParamSpec((H, E, d), dt, ("heads", "head_dim", "embed"), "lecun"),
    }
    if cfg.use_bias:
        p["bq"] = ParamSpec((H, E), "float32", ("heads", "head_dim"), "zeros")
        p["bk"] = ParamSpec((KV, E), "float32", ("kv_heads", "head_dim"), "zeros")
        p["bv"] = ParamSpec((KV, E), "float32", ("kv_heads", "head_dim"), "zeros")
        p["bo"] = ParamSpec((d,), "float32", ("embed",), "zeros")
    return p


def qkv_project(cfg, p, xq, xkv, positions_q=None, positions_kv=None):
    q = jnp.einsum("bsd,dhe->bshe", xq, p["wq"])
    k = jnp.einsum("bsd,dhe->bshe", xkv, p["wk"])
    v = jnp.einsum("bsd,dhe->bshe", xkv, p["wv"])
    if "bq" in p:
        q = (q.astype(jnp.float32) + p["bq"]).astype(q.dtype)
        k = (k.astype(jnp.float32) + p["bk"]).astype(k.dtype)
        v = (v.astype(jnp.float32) + p["bv"]).astype(v.dtype)
    if positions_q is not None:
        q = apply_rope(q, positions_q, cfg.rope_theta)
    if positions_kv is not None:
        k = apply_rope(k, positions_kv, cfg.rope_theta)
    return q, k, v


def out_project(p, o):
    y = jnp.einsum("bshe,hed->bsd", o, p["wo"])
    if "bo" in p:
        y = (y.astype(jnp.float32) + p["bo"]).astype(y.dtype)
    return y


# ---------------------------------------------------------------------------
# Full-sequence attention (train / prefill / cross)
# ---------------------------------------------------------------------------

def attn_seq(q, k, v, *, causal: bool, window=None, q_chunk: int = 512,
             pos_offset=0, seq_shard: bool = False,
             seq_shard_chunked: bool = False, batch_axis="", stub: bool = False):
    """q: (B,Sq,H,E), k/v: (B,Sk,KV,E).  window: scalar (traced ok); a
    window >= Sk is full attention.  Returns (B,Sq,H,E).

    seq_shard=True is the sequence-parallel mode (EXPERIMENTS.md §Perf):
    K/V are gathered to full head_dim (cheap: one (B,Sk,KV,E) gather per
    layer) and each q chunk's position dim is sharded over the 'model'
    axis, dividing attention FLOPs *and* score traffic by the model-axis
    size instead of replicating them."""
    B, Sq, H, E = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G, M = KV, H // KV
    scale = 1.0 / np.sqrt(E)
    k_pos = jnp.arange(Sk)
    from jax.sharding import PartitionSpec as P

    if stub:
        # attention-ablated stand-in (o = q): zero score traffic/compute.
        # Used ONLY by benchmarks/flash_projection.py to measure the
        # non-attention traffic floor that bounds the Pallas flash kernel's
        # projected roofline (never a model path).
        return q

    if seq_shard and seq_shard_chunked:
        # forward-only paths (prefill): q-chunk scan ON TOP of the sequence
        # sharding bounds the materialized score block to
        # (q_chunk/16, Sk) per device — the per-chunk reshard is cheap when
        # there is no backward pass to mirror it (§Perf pair-B iter 3).
        k, v = _gather_last(k), _gather_last(v)
        qg = q.reshape(B, Sq, G, M, E)
        q_chunk = min(q_chunk, Sq)
        n_chunks = Sq // q_chunk
        scale_ = 1.0 / np.sqrt(E)

        def one_chunk(i):
            qs = jax.lax.dynamic_slice_in_dim(qg, i * q_chunk, q_chunk, 1)
            asg = {1: "model", 4: None}
            if batch_axis:
                asg[0] = batch_axis
            qs = _constrain_dims(qs, asg)
            s = jnp.einsum("bcgme,btge->bgmct", qs, k) * scale_
            s = s.astype(jnp.float32)
            if causal:
                q_pos = pos_offset + i * q_chunk + jnp.arange(q_chunk)
                ok = q_pos[:, None] >= k_pos[None, :]
                if window is not None:
                    ok = ok & (q_pos[:, None] - k_pos[None, :] < window)
                s = jnp.where(ok[None, None, None], s, NEG_INF)
            p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
            return jnp.einsum("bgmct,btge->bcgme", p, v)

        if n_chunks == 1:
            o = one_chunk(jnp.int32(0))
        else:
            _, os_ = jax.lax.scan(lambda c, i: (c, one_chunk(i)), 0,
                                  jnp.arange(n_chunks))
            o = jnp.moveaxis(os_, 0, 1).reshape(B, Sq, G, M, E)
        return o.reshape(B, Sq, G * M, E)

    if seq_shard:
        # Megatron-SP-style: ONE reshard per layer — gather K/V to full
        # head_dim, shard q's position dim over 'model'.  No chunk scan:
        # the per-device score block is already 1/model_size of (Sq, Sk).
        # (§Perf iter 5 — REFUTED: a hand-rolled bf16-materialized softmax
        # added more fusion boundaries than it saved; f32 softmax fuses
        # better. Kept the single-reshard structure from iter 2.)
        k, v = _gather_last(k), _gather_last(v)
        asg = {1: "model", 4: None}
        if batch_axis:
            asg[0] = batch_axis
        qg = _constrain_dims(q.reshape(B, Sq, G, M, E), asg)
        # (§Perf iter 6 — REFUTED: a q-major 'bsgmt' layout was tried to
        # remove a transpose+copy of the scores; it measured 5% WORSE —
        # the partitioner preferred the head-major layout.)
        s = jnp.einsum("bsgme,btge->bgmst", qg, k) * scale
        s = s.astype(jnp.float32)
        if causal:
            q_pos = pos_offset + jnp.arange(Sq)
            ok = q_pos[:, None] >= k_pos[None, :]
            if window is not None:
                ok = ok & (q_pos[:, None] - k_pos[None, :] < window)
            s = jnp.where(ok[None, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
        o = jnp.einsum("bgmst,btge->bsgme", p, v)
        return o.reshape(B, Sq, G * M, E)

    qg = q.reshape(B, Sq, G, M, E)
    q_chunk = min(q_chunk, Sq)
    n_chunks = Sq // q_chunk
    assert n_chunks * q_chunk == Sq, (Sq, q_chunk)

    @jax.checkpoint
    def one_chunk(i):
        qs = jax.lax.dynamic_slice_in_dim(qg, i * q_chunk, q_chunk, 1)
        s = jnp.einsum("bcgme,btge->bgmct", qs, k) * scale
        s = s.astype(jnp.float32)
        if causal:
            q_pos = pos_offset + i * q_chunk + jnp.arange(q_chunk)
            ok = q_pos[:, None] >= k_pos[None, :]
            if window is not None:
                ok = ok & (q_pos[:, None] - k_pos[None, :] < window)
            s = jnp.where(ok[None, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
        return jnp.einsum("bgmct,btge->bcgme", p, v)

    if n_chunks == 1:
        o = one_chunk(jnp.int32(0))
    else:
        _, os_ = jax.lax.scan(
            lambda c, i: (c, one_chunk(i)), 0, jnp.arange(n_chunks)
        )
        o = jnp.moveaxis(os_, 0, 1).reshape(B, n_chunks * q_chunk, G, M, E)
        o = o.reshape(B, Sq, G * M, E)
        return o
    return o.reshape(B, Sq, G * M, E)


# ---------------------------------------------------------------------------
# Decode step
# ---------------------------------------------------------------------------

_PALLAS_ANNOUNCED = set()


def _announce_pallas(tag):
    """Trace-time marker that the pallas decode-attn branch was actually
    taken inside the jitted decode — the CI serve smoke greps for it."""
    if tag not in _PALLAS_ANNOUNCED:
        _PALLAS_ANNOUNCED.add(tag)
        print(f"[attn] decode-attn path: pallas ({tag})", flush=True)


def _gather_pages(pages, table):
    """Physical pages (n_pages, P, KV, E) + table (B, W) → the logical
    dense cache (B, W·P, KV, E).  The gathered array is value-identical
    to a dense cache holding the same content, so the jax paged path is
    bit-exact vs dense — the einsums see the same operands."""
    n_pages, P, KV, E = pages.shape
    B, W = table.shape
    return pages[table].reshape(B, W * P, KV, E)


def attn_decode(q, k_cache, v_cache, pos, *, window=None,
                seq_shard: bool = False, impl: str = "jax",
                interpret=None, page_table=None, page_size: int = 0):
    """q: (B,1,H,E); caches: (B,S,KV,E) already containing the new token at
    index ``pos``.  Masks out positions > pos and outside the window.

    impl='pallas' streams the cache through the Pallas decode kernel
    (seq_shard stays on the jax path: the sharding constraints live
    outside the kernel grid).

    ``page_table`` (B, W) selects the PAGED cache layout: k/v are
    physical page pools (n_pages, page_size, KV, E) and position t lives
    at ``pages[table[b, t // P], t % P]``.  impl='pallas' walks the
    table inside the kernel (scalar-prefetched index maps); the jax path
    gathers the pages into the logical dense cache and reuses the dense
    math unchanged."""
    if page_table is not None:
        if impl == "pallas" and not seq_shard:
            from repro.kernels.decode_attention import paged_decode_attention

            _announce_pallas("paged")
            return paged_decode_attention(q, k_cache, v_cache, page_table,
                                          pos, window=window,
                                          interpret=interpret)
        k_cache = _gather_pages(k_cache, page_table)
        v_cache = _gather_pages(v_cache, page_table)
    if impl == "pallas" and not seq_shard:
        from repro.kernels.decode_attention import decode_attention

        _announce_pallas("canonical")
        return decode_attention(q, k_cache, v_cache, pos, window=window,
                                interpret=interpret)
    if seq_shard:
        q = _gather_last(q)  # head_dim-sharded projections -> gather tiny q
    B, _, H, E = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    G, M = KV, H // KV
    qg = q.reshape(B, G, M, E)
    s = jnp.einsum("bgme,btge->bgmt", qg, k_cache) / np.sqrt(E)
    s = s.astype(jnp.float32)
    t = jnp.arange(S)
    ok = t <= pos
    if window is not None:
        ok = ok & (pos - t < window)
    s = jnp.where(ok[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(v_cache.dtype)
    o = jnp.einsum("bgmt,btge->bgme", p, v_cache)
    return o.reshape(B, 1, H, E)


def attn_decode_delta(q, k_cache, v_cache, k_new, v_new, pos, *,
                      window=None, seq_shard: bool = False,
                      impl: str = "jax", interpret=None,
                      page_table=None, page_size: int = 0):
    """Decode WITHOUT writing the cache first: attend over the old cache
    (positions < pos) plus an explicit extra column for the new token.

    Mathematically identical to update-then-attend, but the full per-layer
    cache never flows through the layer scan — the new K/V rows are emitted
    as scan outputs and written back with ONE stacked dynamic-update-slice
    per step (§Perf pair-D): decode stops depending on XLA's while-loop
    buffer aliasing for ~TB-scale cache copies.

    impl='pallas' uses the fused kernel variant: the new-token column is
    folded into the online-softmax init, so the cache is read exactly once
    and the concat-and-resoftmax disappears.

    ``page_table`` selects the paged cache layout exactly as in
    :func:`attn_decode` (the decode hot path under ``--cache paged``:
    the cache write happens OUTSIDE attention, so pages are read-only
    here and prefix-shared pages need no special casing).
    """
    if page_table is not None:
        if impl == "pallas" and not seq_shard:
            from repro.kernels.decode_attention import paged_decode_attention

            _announce_pallas("paged-delta")
            return paged_decode_attention(q, k_cache, v_cache, page_table,
                                          pos, window=window,
                                          k_new=k_new, v_new=v_new,
                                          interpret=interpret)
        k_cache = _gather_pages(k_cache, page_table)
        v_cache = _gather_pages(v_cache, page_table)
    if impl == "pallas" and not seq_shard:
        from repro.kernels.decode_attention import decode_attention

        _announce_pallas("delta")
        return decode_attention(q, k_cache, v_cache, pos, window=window,
                                k_new=k_new, v_new=v_new,
                                interpret=interpret)
    if seq_shard:
        q = _gather_last(q)
        k_new = _gather_last(k_new)
        v_new = _gather_last(v_new)
    B, _, H, E = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    G, M = KV, H // KV
    qg = q.reshape(B, G, M, E)
    s_old = jnp.einsum("bgme,btge->bgmt", qg, k_cache) / np.sqrt(E)
    s_old = s_old.astype(jnp.float32)
    t = jnp.arange(S)
    ok = t < pos                      # strictly old positions
    if window is not None:
        ok = ok & (pos - t < window)
    s_old = jnp.where(ok[None, None, None], s_old, NEG_INF)
    s_new = (jnp.einsum("bgme,bge->bgm", qg, k_new[:, 0])
             / np.sqrt(E)).astype(jnp.float32)[..., None]
    s = jnp.concatenate([s_old, s_new], axis=-1)
    p = jax.nn.softmax(s, axis=-1).astype(v_cache.dtype)
    o = (jnp.einsum("bgmt,btge->bgme", p[..., :S], v_cache)
         + p[..., S:] * v_new[:, 0][:, :, None, :])
    return o.reshape(B, 1, H, E)


def write_new_token(cache, new, pos, *, layer_stacked: bool = True):
    """cache (L,B,S,KV,E) [or (B,S,KV,E)]; new (L,B,1,KV,E) [or (B,1,..)];
    single write of the new token column at dynamic index pos."""
    axis = 2 if layer_stacked else 1
    return jax.lax.dynamic_update_slice_in_dim(
        cache, new.astype(cache.dtype), pos, axis=axis)


def write_new_token_paged(cache, new, page_table, pos, page_size: int):
    """Paged counterpart of :func:`write_new_token`: cache is the page
    pool (L, n_pages, P, KV, E), new (L, B, 1, KV, E); request b's new
    column lands at physical ``(page_table[b, pos // P], pos % P)``.
    One scatter per step, same as the dense single dynamic-update-slice.
    COW happens host-side BEFORE this write (serving/kvpool.py), so the
    target page is always exclusively owned."""
    j = pos // page_size
    off = pos % page_size
    page_ids = jnp.take(page_table, j, axis=1)        # (B,)
    return cache.at[:, page_ids, off].set(new[:, :, 0].astype(cache.dtype))


def update_cache(cache, new, pos):
    """cache (B,S,KV,E); new (B,1,KV,E); write at dynamic index pos."""
    return jax.lax.dynamic_update_slice_in_dim(cache, new.astype(cache.dtype),
                                               pos, axis=1)
