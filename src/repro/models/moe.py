"""Mixture-of-experts FFN with two TPU-idiomatic router implementations.

``dispatch`` — GShard/Switch-style capacity routing: tokens are grouped,
top-k experts chosen per token, and a one-hot dispatch/combine einsum moves
token activations to experts.  With experts sharded over the 'data' mesh
axis this lowers to the classic all-to-all expert-parallel pattern
(llama4-scout: 16 experts over the 16-way data axis).

``dense`` — compute every expert for every token and mask to the top-k.
Exact (no token dropping) and MXU-friendly when experts are tiny
(granite-moe: d_ff_expert=512, 40 experts); the dispatch one-hot overhead
would dominate there.  See DESIGN.md §MoE.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import gelu
from repro.sharding import ParamSpec


def moe_param_specs(cfg):
    m = cfg.moe
    d, ff, E = cfg.d_model, m.d_ff_expert, m.num_experts
    dt = cfg.param_dtype
    p = {
        "router": ParamSpec((d, E), "float32", ("embed", "experts"), "lecun"),
        "wi": ParamSpec((E, d, ff), dt, ("experts", "embed", "expert_mlp"), "lecun"),
        "wg": ParamSpec((E, d, ff), dt, ("experts", "embed", "expert_mlp"), "lecun"),
        "wo": ParamSpec((E, ff, d), dt, ("experts", "expert_mlp", "embed"), "lecun"),
    }
    if m.shared_expert:
        sff = m.shared_d_ff
        p["shared_wi"] = ParamSpec((d, sff), dt, ("embed", "mlp"), "lecun")
        p["shared_wg"] = ParamSpec((d, sff), dt, ("embed", "mlp"), "lecun")
        p["shared_wo"] = ParamSpec((sff, d), dt, ("mlp", "embed"), "lecun")
    return p


def _expert_ffn(p, xe, act: str):
    """xe: (E, g, cap, d) -> (E, g, cap, d); per-expert SwiGLU/GELU."""
    h = jnp.einsum("egcd,edf->egcf", xe, p["wi"])
    if act == "swiglu":
        g = jnp.einsum("egcd,edf->egcf", xe, p["wg"])
        h = jax.nn.silu(g) * h
    else:
        h = gelu(h)
    return jnp.einsum("egcf,efd->egcd", h, p["wo"])


def _aux_loss(probs, expert_mask, num_experts):
    """Switch-transformer load-balance loss, per group then averaged.
    probs: (g, s, E); expert_mask: (g, s, E) in {0,1} (any-k membership)."""
    density = jnp.mean(expert_mask.astype(jnp.float32), axis=1)     # (g, E)
    density_proxy = jnp.mean(probs, axis=1)                          # (g, E)
    return jnp.mean(density * density_proxy) * (num_experts ** 2)


def moe_apply(cfg, p, x):
    """x: (B, S, d).  Returns (y, aux_loss)."""
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    # largest divisor of T that fits the configured routing group
    g_sz = min(m.router_group, T)
    while T % g_sz:
        g_sz -= 1
    n_g = T // g_sz
    xg = x.reshape(n_g, g_sz, d)

    logits = jnp.einsum("gsd,de->gse", xg.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_idx = jax.lax.top_k(probs, m.top_k)                  # (g,s,k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)

    onehot = jax.nn.one_hot(top_idx, m.num_experts, dtype=jnp.float32)
    aux = _aux_loss(probs, jnp.max(onehot, axis=2), m.num_experts)

    if m.router_impl == "dense":
        # weight per (token, expert): sum of top-k weights where selected
        w_te = jnp.einsum("gsk,gske->gse", top_w, onehot)            # (g,s,E)

        fused = getattr(cfg, "moe_dense_fused", False)

        def group(xs):
            xg_, wg_ = xs
            h = jnp.einsum("sd,edf->esf", xg_, p["wi"])
            if cfg.act == "swiglu":
                gate = jnp.einsum("sd,edf->esf", xg_, p["wg"])
                h = jax.nn.silu(gate) * h
            else:
                h = gelu(h)
            if fused:
                # §Perf: weight the hidden activations by the router and
                # contract (experts, ff) jointly — the partial sum under an
                # ff-sharded wo is then only (s, d) instead of (E, s, d).
                hw = h * wg_.T[:, :, None].astype(h.dtype)
                return jnp.einsum("esf,efd->sd", hw, p["wo"])
            ye = jnp.einsum("esf,efd->esd", h, p["wo"])
            return jnp.einsum("esd,se->sd", ye, wg_.astype(ye.dtype))

        y = jax.lax.map(jax.checkpoint(group), (xg, w_te))
    else:
        cap = int(g_sz * m.top_k * m.capacity_factor / m.num_experts)
        cap = max(cap, 1)
        # position of each (token, k) slot inside its expert's buffer,
        # priority by (token, k) order within the group (GShard).
        flat = onehot.reshape(n_g, g_sz * m.top_k, m.num_experts)
        pos = jnp.cumsum(flat, axis=1) - flat                        # (g,s*k,E)
        pos = pos.reshape(n_g, g_sz, m.top_k, m.num_experts)
        in_cap = (pos < cap).astype(jnp.float32) * onehot            # keep mask
        pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), cap, dtype=jnp.float32)
        # dispatch: (g, s, E, cap); combine adds router weights
        dispatch = jnp.einsum("gske,gskec->gsec", in_cap, pos_oh)
        combine = jnp.einsum("gsk,gske,gskec->gsec", top_w, in_cap, pos_oh)

        xe = jnp.einsum("gsec,gsd->egcd", dispatch.astype(x.dtype), xg)
        ye = _expert_ffn(p, xe, cfg.act)
        y = jnp.einsum("gsec,egcd->gsd", combine.astype(ye.dtype), ye)

    y = y.reshape(B, S, d)
    if m.shared_expert:
        h = jnp.einsum("bsd,df->bsf", x, p["shared_wi"])
        if cfg.act == "swiglu":
            h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["shared_wg"])) * h
        else:
            h = gelu(h)
        y = y + jnp.einsum("bsf,fd->bsd", h, p["shared_wo"])
    return y, aux
