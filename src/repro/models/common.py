"""Shared building blocks: norms, RoPE, losses, small numerics helpers.

All model code is functional pure-JAX: parameters are pytrees of arrays
described by :class:`repro.sharding.ParamSpec` trees.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding import ParamSpec

DEFAULT_DTYPE = jnp.bfloat16


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def norm_spec(cfg, dim=None, axes=("embed",)):
    dim = dim if dim is not None else cfg.d_model
    if cfg.norm == "layernorm":
        return {
            "scale": ParamSpec((dim,), "float32", axes, "ones"),
            "bias": ParamSpec((dim,), "float32", axes, "zeros"),
        }
    return {"scale": ParamSpec((dim,), "float32", axes, "ones")}


def apply_norm(p, x, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    if "bias" in p:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    else:
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"]
    return y.astype(dt)


def rmsnorm(x, scale=None, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(ms + eps)
    if scale is not None:
        y = y * scale
    return y.astype(dt)


# ---------------------------------------------------------------------------
# Rotary / sinusoidal positions
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, E); positions: broadcastable to (..., S)."""
    if theta <= 0:
        return x
    E = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(E, theta), jnp.float32)
    ang = positions[..., None].astype(jnp.float32) * freqs      # (..., S, E/2)
    ang = ang[..., None, :]                                      # (..., S, 1, E/2)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(positions, d_model: int):
    """Whisper-style fixed sinusoidal embeddings; positions (..., S)."""
    half = d_model // 2
    freqs = jnp.exp(-np.log(10_000.0) * jnp.arange(half) / max(half - 1, 1))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def sequence_mask(lengths, max_len: int):
    """(B,) int lengths -> (B, max_len) bool validity mask.

    True at frames t < lengths[b] — the shared definition of "valid frame"
    used by the masked loss, the length-aware BLSTM, and CTC input
    masking (see the ``lengths`` batch contract in ``repro.data.pipeline``).
    """
    return jnp.arange(max_len)[None, :] < lengths[:, None]


def cross_entropy(logits, labels, z_loss: float = 0.0, mask=None):
    """Token-level CE; logits (..., V) any float dtype, labels (...) int.

    With ``mask`` (bool, same shape as labels) the loss is the sum over
    valid positions divided by the valid count — NOT the padded B*T mean —
    so padded frames neither dilute the loss nor leak into gradients."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    loss = lse - ll
    if z_loss:
        loss = loss + z_loss * jnp.square(lse)
    if mask is None:
        return jnp.mean(loss)
    m = mask.astype(jnp.float32)
    return jnp.sum(loss * m) / jnp.maximum(jnp.sum(m), 1.0)


# ---------------------------------------------------------------------------
# Misc
# ---------------------------------------------------------------------------

def dense_spec(d_in, d_out, axes, *, dtype="bfloat16", use_bias=False,
               out_axes=None, init="lecun"):
    p = {"w": ParamSpec((d_in, d_out), dtype, axes, init)}
    if use_bias:
        p["b"] = ParamSpec((d_out,), "float32", (axes[-1],), "zeros")
    return p


def dense(p, x):
    y = jnp.einsum("...d,df->...f", x, p["w"])
    if "b" in p:
        y = (y.astype(jnp.float32) + p["b"]).astype(y.dtype)
    return y


def gelu(x):
    return jax.nn.gelu(x, approximate=True)
