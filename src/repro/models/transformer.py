"""Unified decoder-only stack covering the dense / moe / ssm / hybrid / vlm
families.  Layers are stacked into one scanned pytree (small HLO, bounded
compile time at 40+ layers); per-layer heterogeneity (sliding-window vs
global attention in hymba / llama4-scout) rides through the scan as data.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention as A
from repro.models import ffn as F
from repro.models import moe as M
from repro.models import ssm as S
from repro.models.common import apply_norm, cross_entropy, norm_spec, rmsnorm
from repro.sharding import ParamSpec

GLOBAL_WINDOW = np.int32(2**30)   # "window" meaning full attention


# ---------------------------------------------------------------------------
# Param specs
# ---------------------------------------------------------------------------

def layer_param_specs(cfg):
    fam = cfg.family
    p = {"ln1": norm_spec(cfg)}
    if fam in ("dense", "moe", "hybrid", "vlm"):
        p["attn"] = A.attn_param_specs(cfg)
    if fam in ("ssm", "hybrid"):
        p["ssm"] = S.ssm_param_specs(cfg)
    if fam in ("dense", "vlm", "hybrid"):
        p["ln2"] = norm_spec(cfg)
        p["mlp"] = F.ffn_param_specs(cfg)
    if fam == "moe":
        p["ln2"] = norm_spec(cfg)
        p["moe"] = M.moe_param_specs(cfg)
    return p


def _stack(spec_tree, n):
    def one(ps: ParamSpec):
        return ParamSpec((n,) + ps.shape, ps.dtype, ("layers",) + ps.axes,
                         ps.init, ps.init_scale)
    return jax.tree.map(one, spec_tree,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def param_specs(cfg):
    d, V = cfg.d_model, cfg.vocab
    p = {
        "embed": ParamSpec((V, d), cfg.param_dtype, ("vocab", "embed"),
                           "normal", 0.02),
        "layers": _stack(layer_param_specs(cfg), cfg.n_layers),
        "final_norm": norm_spec(cfg),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = ParamSpec((d, V), cfg.param_dtype,
                                 ("embed", "vocab"), "normal", 0.02)
    return p


def layer_windows(cfg, seq_len: int, *, long_context: bool = False):
    """Per-layer attention window array (n_layers,) int32."""
    w = cfg.window
    if long_context and w == 0:
        w = cfg.window_for_long   # documented sliding-window variant
    if w == 0:
        return np.full((cfg.n_layers,), GLOBAL_WINDOW, np.int32)
    ws = np.full((cfg.n_layers,), w, np.int32)
    for i in cfg.global_attn_layers:
        if i < cfg.n_layers:
            ws[i] = GLOBAL_WINDOW
    return ws


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _attn_block(cfg, p, x, positions, window, *, cache=None, pos=None,
                fwd_only: bool = False, batch_axis="", stub: bool = False):
    """Returns (out, (k,v)) — k/v are the new cache when decoding, else the
    full-seq K/V for cache construction."""
    seq_shard = cfg.attn_sharding == "seq"
    if cache is None:
        q, k, v = A.qkv_project(cfg, p, x, x, positions, positions)
        o = A.attn_seq(q, k, v, causal=True, window=window,
                       seq_shard=seq_shard,
                       seq_shard_chunked=seq_shard and fwd_only,
                       batch_axis=batch_axis, stub=stub)
        return A.out_project(p, o), (k, v)
    k_cache, v_cache = cache
    q, k, v = A.qkv_project(cfg, p, x, x, positions, positions)
    k_cache = A.update_cache(k_cache, k, pos)
    v_cache = A.update_cache(v_cache, v, pos)
    o = A.attn_decode(q, k_cache, v_cache, pos, window=window,
                      seq_shard=seq_shard)
    return A.out_project(p, o), (k_cache, v_cache)


def _hybrid_combine(attn_out, ssm_out):
    # Hymba: per-branch output normalization then mean fusion
    return 0.5 * (rmsnorm(attn_out) + rmsnorm(ssm_out))


# ---------------------------------------------------------------------------
# Sequence forward (train / prefill)
# ---------------------------------------------------------------------------

def forward_seq(cfg, params, x, *, long_context: bool = False,
                collect_cache: bool = False, cache_len: int = 0,
                kernel_impl: str = "jax", batch_axis=""):
    """x: (B,S,d) embedded inputs.  Returns (hidden, aux_loss, cache)."""
    Bsz, Ssz, _ = x.shape
    windows = jnp.asarray(layer_windows(cfg, Ssz, long_context=long_context))
    positions = jnp.arange(Ssz)[None, :]
    fam = cfg.family

    def layer(x, scanned):
        p, window = scanned
        aux = jnp.float32(0.0)
        h = apply_norm(p["ln1"], x)
        cache_out = ()
        if fam in ("dense", "moe", "vlm"):
            o, (k, v) = _attn_block(cfg, p["attn"], h, positions, window,
                                    fwd_only=collect_cache,
                                    batch_axis=batch_axis,
                                    stub=kernel_impl == "ablate_attn")
            x = x + o
            if collect_cache:
                cache_out = _pad_cache(k, v, cache_len)
        elif fam == "ssm":
            o, (conv_state, h_ssm) = S.mamba2_seq(cfg, p["ssm"], h,
                                                  kernel_impl=kernel_impl)
            x = x + o
            if collect_cache:
                cache_out = (conv_state, h_ssm)
        elif fam == "hybrid":
            oa, (k, v) = _attn_block(cfg, p["attn"], h, positions, window,
                                     fwd_only=collect_cache,
                                     batch_axis=batch_axis,
                                     stub=kernel_impl == "ablate_attn")
            os_, (conv_state, h_ssm) = S.mamba2_seq(cfg, p["ssm"], h,
                                                    kernel_impl=kernel_impl)
            x = x + _hybrid_combine(oa, os_).astype(x.dtype)
            if collect_cache:
                cache_out = (_pad_cache(k, v, cache_len), conv_state, h_ssm)
        if fam in ("dense", "vlm", "hybrid"):
            x = x + F.ffn_apply(cfg, p["mlp"], apply_norm(p["ln2"], x))
        elif fam == "moe":
            mo, aux = M.moe_apply(cfg, p["moe"], apply_norm(p["ln2"], x))
            x = x + mo
        return x.astype(jnp.bfloat16), (aux, cache_out)

    body = jax.checkpoint(layer) if cfg.remat else layer

    def scan_body(x, scanned):
        return body(x, scanned)

    x, (auxes, caches) = jax.lax.scan(scan_body, x.astype(jnp.bfloat16),
                                      (params["layers"], windows))
    return x, jnp.sum(auxes), caches


def _pad_cache(k, v, cache_len):
    """Grow prefill K/V to the serving cache length (zero-padded tail)."""
    if cache_len and cache_len > k.shape[1]:
        pad = ((0, 0), (0, cache_len - k.shape[1]), (0, 0), (0, 0))
        k, v = jnp.pad(k, pad), jnp.pad(v, pad)
    return (k, v)


# ---------------------------------------------------------------------------
# Embedding / logits
# ---------------------------------------------------------------------------

def embed_tokens(cfg, params, tokens):
    return params["embed"][tokens].astype(jnp.bfloat16)


def logits_fn(cfg, params, x):
    if cfg.tie_embeddings:
        return jnp.einsum("bsd,vd->bsv", x, params["embed"])
    return jnp.einsum("bsd,dv->bsv", x, params["lm_head"])


def embed_with_prefix(cfg, params, tokens, patches):
    """VLM early fusion: prefix patch embeddings then text tokens."""
    xt = embed_tokens(cfg, params, tokens)
    if patches is not None:
        xp = patches.astype(jnp.bfloat16)
        return jnp.concatenate([xp, xt], axis=1)
    return xt


# ---------------------------------------------------------------------------
# Train loss
# ---------------------------------------------------------------------------

def loss_train(cfg, params, batch, *, kernel_impl: str = "jax",
               batch_axis=""):
    """batch: {'tokens','labels'} (+ 'patches' for vlm)."""
    tokens, labels = batch["tokens"], batch["labels"]
    patches = batch.get("patches")
    x = embed_with_prefix(cfg, params, tokens, patches)
    x, aux, _ = forward_seq(cfg, params, x, kernel_impl=kernel_impl,
                            batch_axis=batch_axis)
    x = apply_norm(params["final_norm"], x)
    if patches is not None:   # loss only over text positions
        x = x[:, patches.shape[1]:, :]
    logits = logits_fn(cfg, params, x)
    loss = cross_entropy(logits, labels)
    if cfg.moe is not None:
        loss = loss + cfg.moe.aux_loss_weight * aux
    return loss


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------

def cache_specs(cfg, batch: int, cache_len: int):
    """Stacked per-layer decode-state specs for this family."""
    fam = cfg.family
    kv = lambda: {
        "k": ParamSpec((cfg.n_layers, batch, cache_len, cfg.n_kv_heads,
                        cfg.head_dim),
                       "bfloat16",
                       ("layers", "batch", "cache_seq", "kv_heads",
                        "head_dim")),
        "v": ParamSpec((cfg.n_layers, batch, cache_len, cfg.n_kv_heads,
                        cfg.head_dim),
                       "bfloat16",
                       ("layers", "batch", "cache_seq", "kv_heads",
                        "head_dim")),
    }
    ssm = lambda: jax.tree.map(
        lambda ps: ParamSpec((cfg.n_layers,) + ps.shape, ps.dtype,
                             ("layers",) + ps.axes),
        S.ssm_cache_specs(cfg, batch),
        is_leaf=lambda x: isinstance(x, ParamSpec))
    if fam in ("dense", "moe", "vlm"):
        return {"attn": kv()}
    if fam == "ssm":
        return {"ssm": ssm()}
    if fam == "hybrid":
        return {"attn": kv(), "ssm": ssm()}
    raise ValueError(fam)


def page_specs(cfg, n_pages: int, page_size: int):
    """Paged decode-state specs: ONE pool of physical KV pages shared by
    every in-flight request (serve.py ``--cache paged``) instead of a
    per-slot (batch, cache_len) row.  Attention-only families."""
    if cfg.family not in ("dense", "moe", "vlm"):
        raise ValueError(f"paged KV cache needs an attention-only family, "
                         f"got {cfg.family}")
    shape = (cfg.n_layers, n_pages, page_size, cfg.n_kv_heads, cfg.head_dim)
    axes = ("layers", "pages", "page_pos", "kv_heads", "head_dim")
    return {"attn": {"k": ParamSpec(shape, "bfloat16", axes),
                     "v": ParamSpec(shape, "bfloat16", axes)}}


def decode_step(cfg, params, cache, tokens, pos, *, long_context: bool = False,
                kernel_impl: str = "jax", page_table=None, page_size: int = 0):
    """One-token decode.  tokens: (B,1) int32, pos: scalar int32 position of
    the new token.  Returns (logits (B,1,V), new cache).

    kernel_impl='pallas' routes the per-layer attention through the fused
    Pallas decode kernel (cfg.attn_decode_impl overrides when set).

    ``page_table`` (B, W) selects the PAGED cache layout (serve.py
    ``--cache paged``): cache['attn'] k/v are page pools
    (L, n_pages, page_size, KV, E) shared across requests, the attention
    walks the table, and the new-token column scatters into the table's
    page for ``pos`` (the page is exclusively owned — COW runs host-side
    first).  Attention-only families; SSM/hybrid state is per-slot O(1)
    and has nothing to page."""
    fam = cfg.family
    paged = page_table is not None
    if paged and fam not in ("dense", "moe", "vlm"):
        raise ValueError(f"paged KV cache needs an attention-only family, "
                         f"got {fam}")
    if paged:
        S_cache = page_table.shape[-1] * page_size   # logical length
    else:
        S_cache = (cache["attn"]["k"].shape[2] if "attn" in cache
                   else (1 << 30))
    windows = jnp.asarray(layer_windows(cfg, S_cache,
                                        long_context=long_context))
    x = embed_tokens(cfg, params, tokens)
    positions = jnp.full((tokens.shape[0], 1), pos)
    seq_shard = cfg.attn_sharding == "seq"
    attn_impl = cfg.attn_decode_impl or kernel_impl

    def attn_delta(p, h, cache_l, window):
        q, k, v = A.qkv_project(cfg, p, h, h, positions, positions)
        o = A.attn_decode_delta(q, cache_l["attn"]["k"],
                                cache_l["attn"]["v"], k, v, pos,
                                window=window, seq_shard=seq_shard,
                                impl=attn_impl, page_table=page_table,
                                page_size=page_size)
        return A.out_project(p, o), {"k": k, "v": v}   # new-token rows only

    def layer(x, scanned):
        p, window, cache_l = scanned
        h = apply_norm(p["ln1"], x)
        new_cache = {}
        if fam in ("dense", "moe", "vlm"):
            o, kv_new = attn_delta(p["attn"], h, cache_l, window)
            x = x + o
            new_cache["attn"] = kv_new
        elif fam == "ssm":
            o, (conv_state, h_ssm) = S.mamba2_step(
                cfg, p["ssm"], h, cache_l["ssm"]["conv"], cache_l["ssm"]["h"])
            x = x + o
            new_cache["ssm"] = {"conv": conv_state, "h": h_ssm}
        elif fam == "hybrid":
            oa, kv_new = attn_delta(p["attn"], h, cache_l, window)
            os_, (conv_state, h_ssm) = S.mamba2_step(
                cfg, p["ssm"], h, cache_l["ssm"]["conv"], cache_l["ssm"]["h"])
            x = x + _hybrid_combine(oa, os_).astype(x.dtype)
            new_cache["attn"] = kv_new
            new_cache["ssm"] = {"conv": conv_state, "h": h_ssm}
        if fam in ("dense", "vlm", "hybrid"):
            x = x + F.ffn_apply(cfg, p["mlp"], apply_norm(p["ln2"], x))
        elif fam == "moe":
            mo, _ = M.moe_apply(cfg, p["moe"], apply_norm(p["ln2"], x))
            x = x + mo
        return x.astype(jnp.bfloat16), new_cache

    x, deltas = jax.lax.scan(layer, x.astype(jnp.bfloat16),
                             (params["layers"], windows, cache))
    # ONE stacked write of the new token column per step (§Perf pair-D):
    # the full caches never flow through the layer scan as outputs.
    new_cache = dict(cache)
    if "attn" in deltas:
        if paged:
            new_cache["attn"] = {
                "k": A.write_new_token_paged(cache["attn"]["k"],
                                             deltas["attn"]["k"],
                                             page_table, pos, page_size),
                "v": A.write_new_token_paged(cache["attn"]["v"],
                                             deltas["attn"]["v"],
                                             page_table, pos, page_size),
            }
        else:
            new_cache["attn"] = {
                "k": A.write_new_token(cache["attn"]["k"],
                                       deltas["attn"]["k"], pos),
                "v": A.write_new_token(cache["attn"]["v"],
                                       deltas["attn"]["v"], pos),
            }
    if "ssm" in deltas:
        new_cache["ssm"] = deltas["ssm"]   # O(1)-size states, stacked by scan
    x = apply_norm(params["final_norm"], x)
    return logits_fn(cfg, params, x), new_cache


def prefill(cfg, params, tokens, *, cache_len: int = 0, patches=None,
            long_context: bool = False, kernel_impl: str = "jax",
            batch_axis="data"):
    """Full-context forward emitting the decode cache + last-token logits."""
    fam = cfg.family
    x = embed_with_prefix(cfg, params, tokens, patches)
    cache_len = cache_len or x.shape[1]
    x, _, caches = forward_seq(cfg, params, x, collect_cache=True,
                               cache_len=cache_len,
                               long_context=long_context,
                               kernel_impl=kernel_impl,
                               batch_axis=batch_axis)
    x = apply_norm(params["final_norm"], x)
    logits = logits_fn(cfg, params, x[:, -1:, :])
    if fam in ("dense", "moe", "vlm"):
        k, v = caches
        cache = {"attn": {"k": k, "v": v}}
    elif fam == "ssm":
        conv_state, h_ssm = caches
        cache = {"ssm": {"conv": conv_state, "h": h_ssm}}
    elif fam == "hybrid":
        (k, v), conv_state, h_ssm = caches
        cache = {"attn": {"k": k, "v": v},
                 "ssm": {"conv": conv_state, "h": h_ssm}}
    else:
        raise ValueError(fam)
    return logits, cache
