"""Dense (non-MoE) feed-forward blocks: SwiGLU / GELU."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import gelu
from repro.sharding import ParamSpec


def ffn_param_specs(cfg, d_ff=None):
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    dt = cfg.param_dtype
    p = {
        "wi": ParamSpec((d, ff), dt, ("embed", "mlp"), "lecun"),
        "wo": ParamSpec((ff, d), dt, ("mlp", "embed"), "lecun"),
    }
    if cfg.act == "swiglu":
        p["wg"] = ParamSpec((d, ff), dt, ("embed", "mlp"), "lecun")
    if cfg.use_bias:
        p["bi"] = ParamSpec((ff,), "float32", ("mlp",), "zeros")
        p["bo"] = ParamSpec((d,), "float32", ("embed",), "zeros")
    return p


def ffn_apply(cfg, p, x):
    h = jnp.einsum("bsd,df->bsf", x, p["wi"])
    if "bi" in p:
        h = (h.astype(jnp.float32) + p["bi"]).astype(h.dtype)
    if cfg.act == "swiglu":
        h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["wg"])) * h
    else:
        h = gelu(h)
    y = jnp.einsum("bsf,fd->bsd", h, p["wo"])
    if "bo" in p:
        y = (y.astype(jnp.float32) + p["bo"]).astype(y.dtype)
    return y
