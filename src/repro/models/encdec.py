"""Whisper-style encoder-decoder transformer backbone.

The mel-spectrogram + conv feature extractor is a STUB: the encoder
consumes precomputed frame embeddings (B, S_enc, d_model) supplied by
``input_specs`` (assignment carve-out).  Positions are sinusoidal for both
stacks (adaptation from whisper's learned decoder positions — DESIGN.md).

The assigned ``seq_len`` is split evenly: S_enc = S_dec = seq_len // 2.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models import ffn as F
from repro.models.common import (apply_norm, cross_entropy, norm_spec,
                                 sinusoidal_positions)
from repro.models.transformer import _pad_cache, _stack
from repro.sharding import ParamSpec


def enc_layer_specs(cfg):
    return {
        "ln1": norm_spec(cfg),
        "attn": A.attn_param_specs(cfg),
        "ln2": norm_spec(cfg),
        "mlp": F.ffn_param_specs(cfg),
    }


def dec_layer_specs(cfg):
    return {
        "ln1": norm_spec(cfg),
        "self_attn": A.attn_param_specs(cfg),
        "lnx": norm_spec(cfg),
        "cross_attn": A.attn_param_specs(cfg),
        "ln2": norm_spec(cfg),
        "mlp": F.ffn_param_specs(cfg),
    }


def param_specs(cfg):
    return {
        "embed": ParamSpec((cfg.vocab, cfg.d_model), cfg.param_dtype,
                           ("vocab", "embed"), "normal", 0.02),
        "enc_layers": _stack(enc_layer_specs(cfg), cfg.n_enc_layers),
        "enc_norm": norm_spec(cfg),
        "dec_layers": _stack(dec_layer_specs(cfg), cfg.n_layers),
        "dec_norm": norm_spec(cfg),
    }


def _add_positions(x):
    B, S_, d = x.shape
    pos = sinusoidal_positions(jnp.arange(S_), d).astype(x.dtype)
    return x + pos[None]


def encode(cfg, params, frames, *, batch_axis="", fwd_only=False):
    """frames: (B, S_enc, d) stub frame embeddings."""
    x = _add_positions(frames.astype(jnp.bfloat16))
    seq_shard = cfg.attn_sharding == "seq"

    @jax.checkpoint
    def layer(x, p):
        h = apply_norm(p["ln1"], x)
        q, k, v = A.qkv_project(cfg, p["attn"], h, h)
        o = A.attn_seq(q, k, v, causal=False, seq_shard=seq_shard,
                       seq_shard_chunked=seq_shard and fwd_only,
                       batch_axis=batch_axis)
        x = x + A.out_project(p["attn"], o)
        x = x + F.ffn_apply(cfg, p["mlp"], apply_norm(p["ln2"], x))
        return x.astype(jnp.bfloat16), None

    x, _ = jax.lax.scan(layer, x, params["enc_layers"])
    return apply_norm(params["enc_norm"], x)


def decode_seq(cfg, params, tokens, enc_out, *, collect_cache=False,
               cache_len=0, batch_axis=""):
    """Teacher-forced decoder over a full token sequence."""
    x = params["embed"][tokens].astype(jnp.bfloat16)
    x = _add_positions(x)
    seq_shard = cfg.attn_sharding == "seq"
    chunked = seq_shard and collect_cache

    def layer(x, p):
        h = apply_norm(p["ln1"], x)
        q, k, v = A.qkv_project(cfg, p["self_attn"], h, h)
        o = A.attn_seq(q, k, v, causal=True, seq_shard=seq_shard,
                       seq_shard_chunked=chunked, batch_axis=batch_axis)
        x = x + A.out_project(p["self_attn"], o)
        h = apply_norm(p["lnx"], x)
        q, ck, cv = A.qkv_project(cfg, p["cross_attn"], h, enc_out)
        o = A.attn_seq(q, ck, cv, causal=False, seq_shard=seq_shard,
                       seq_shard_chunked=chunked, batch_axis=batch_axis)
        x = x + A.out_project(p["cross_attn"], o)
        x = x + F.ffn_apply(cfg, p["mlp"], apply_norm(p["ln2"], x))
        cache = (_pad_cache(k, v, cache_len), (ck, cv)) if collect_cache else ()
        return x.astype(jnp.bfloat16), cache

    body = jax.checkpoint(layer) if cfg.remat else layer
    x, caches = jax.lax.scan(body, x, params["dec_layers"])
    return apply_norm(params["dec_norm"], x), caches


def loss_train(cfg, params, batch, *, batch_axis="", **_):
    enc_out = encode(cfg, params, batch["frames"], batch_axis=batch_axis)
    x, _ = decode_seq(cfg, params, batch["tokens"], enc_out,
                      batch_axis=batch_axis)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    return cross_entropy(logits, batch["labels"])


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def cache_specs(cfg, batch: int, cache_len: int, enc_len: int):
    kv = lambda s: {
        "k": ParamSpec((cfg.n_layers, batch, s, cfg.n_kv_heads, cfg.head_dim),
                       "bfloat16",
                       ("layers", "batch", "cache_seq", "kv_heads",
                        "head_dim")),
        "v": ParamSpec((cfg.n_layers, batch, s, cfg.n_kv_heads, cfg.head_dim),
                       "bfloat16",
                       ("layers", "batch", "cache_seq", "kv_heads",
                        "head_dim")),
    }
    return {"self": kv(cache_len), "cross": kv(enc_len)}


def prefill(cfg, params, frames, tokens, *, cache_len: int = 0,
            batch_axis="data"):
    enc_out = encode(cfg, params, frames, batch_axis=batch_axis,
                     fwd_only=True)
    cache_len = cache_len or tokens.shape[1]
    x, caches = decode_seq(cfg, params, tokens, enc_out,
                           collect_cache=True, cache_len=cache_len,
                           batch_axis=batch_axis)
    (k, v), (ck, cv) = caches
    logits = jnp.einsum("bsd,vd->bsv", x[:, -1:, :], params["embed"])
    return logits, {"self": {"k": k, "v": v}, "cross": {"k": ck, "v": cv}}


def decode_step(cfg, params, cache, tokens, pos):
    """One decoder token against self+cross caches."""
    x = params["embed"][tokens].astype(jnp.bfloat16)
    d = cfg.d_model
    posemb = sinusoidal_positions(jnp.full((tokens.shape[0], 1), pos),
                                  d).astype(x.dtype)
    x = x + posemb

    def layer(x, scanned):
        p, cache_l = scanned
        h = apply_norm(p["ln1"], x)
        q, k, v = A.qkv_project(cfg, p["self_attn"], h, h)
        kc = A.update_cache(cache_l["self"]["k"], k, pos)
        vc = A.update_cache(cache_l["self"]["v"], v, pos)
        o = A.attn_decode(q, kc, vc, pos)
        x = x + A.out_project(p["self_attn"], o)
        h = apply_norm(p["lnx"], x)
        q = jnp.einsum("bsd,dhe->bshe", h, p["cross_attn"]["wq"])
        if "bq" in p["cross_attn"]:
            q = (q.astype(jnp.float32) + p["cross_attn"]["bq"]).astype(q.dtype)
        ck, cv = cache_l["cross"]["k"], cache_l["cross"]["v"]
        o = A.attn_decode(q, ck, cv, jnp.int32(ck.shape[1] - 1))
        x = x + A.out_project(p["cross_attn"], o)
        x = x + F.ffn_apply(cfg, p["mlp"], apply_norm(p["ln2"], x))
        return x.astype(jnp.bfloat16), {"self": {"k": kc, "v": vc},
                                        "cross": {"k": ck, "v": cv}}

    x, new_cache = jax.lax.scan(layer, x, (params["dec_layers"], cache))
    x = apply_norm(params["dec_norm"], x)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    return logits, new_cache
