"""Deterministic, seeded fault injection for distributed training.

The async/decentralized strategies this repo reproduces exist to tolerate
real clusters — learners that straggle, slow down heterogeneously, stall
on heavy-tailed pauses, drop gossip payloads, and die mid-run
(1904.04956's AD-PSGD experiments, 2110.11199's asynchronous decentralized
acoustic-model training).  This module is the single source of those
conditions: a :class:`FaultPlan` is a *pure function of its seed* that
schedules every fault, so a run under a plan is exactly reproducible and
two strategies compared under the same plan see the same cluster weather.

The plan is consumed at two boundaries:

* **The step loop** (``repro.core.strategies.make_elastic_train_step`` /
  ``repro.launch.train --fault-*``): :meth:`FaultPlan.step_inputs` yields
  per-step numpy masks — which learners are alive, which contribute a
  gradient this step (stragglers/stalls), who rejoins, which gossip edges
  deliver, whose payloads are corrupted — that are fed to the jitted
  elastic step as plain arrays (constant shapes, one compile).
* **The perfsim boundary** (``benchmarks/perfsim``): the same plan's
  :meth:`speed_factors` / :meth:`stall_extra` / departure schedule drive
  the discrete-event wall-clock simulator at pod-scale learner counts,
  so convergence (real training) and throughput (simulated cluster) are
  reported under ONE fault description.

Faults modeled (all per-learner, all deterministic from ``seed``):

* **stragglers** — heterogeneous speed: a learner with factor ``m``
  computes a gradient only every ``m``-th step (step-loop view) / takes
  ``m×`` the base per-batch time (perfsim view).
* **heavy-tailed stalls** — with ``stall_prob`` per step a learner
  freezes for a Pareto(``stall_shape``)-distributed number of steps
  (GC pauses, network hiccups, preemptions).
* **departures** — a learner crashes at ``step`` and optionally rejoins
  at ``rejoin``; rejoiners are re-seeded from the survivors' consensus
  (elastic membership; docs/fault_tolerance.md).
* **dropped gossip** — with ``drop_prob`` an undirected mixing edge
  fails for the step (both endpoints fall back to themselves; the
  mixing matrix stays doubly stochastic).
* **corrupted gossip** — with ``corrupt_prob`` a learner's *outgoing*
  payload picks up Gaussian noise of relative scale ``corrupt_scale``
  for one step (receivers only; the local replica stays clean).

The plan REFUSES to leave the cluster empty: a schedule under which no
learner is alive at some step raises at construction — the step loop
would otherwise divide by a zero frame count (see
``strategies.check_active``).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class Straggler:
    """Learner ``learner`` runs ``factor``× slower than the base rate:
    it contributes a gradient only on steps where
    ``(step + phase) % factor == 0``."""

    learner: int
    factor: int
    phase: int = 0


@dataclass(frozen=True)
class Departure:
    """Learner ``learner`` crashes at the start of ``step``; with
    ``rejoin >= 0`` it re-enters at that step (re-seeded from the
    survivors' consensus), otherwise it is gone for good."""

    learner: int
    step: int
    rejoin: int = -1


@dataclass
class FaultPlan:
    """One deterministic cluster-weather schedule (module docstring)."""

    n_learners: int
    seed: int = 0
    stragglers: Tuple[Straggler, ...] = ()
    departures: Tuple[Departure, ...] = ()
    drop_prob: float = 0.0
    stall_prob: float = 0.0
    stall_shape: float = 1.5     # Pareto tail index of stall lengths
    stall_max: int = 64          # cap on a single stall, in steps
    corrupt_prob: float = 0.0
    corrupt_scale: float = 0.0   # noise RMS relative to the payload RMS

    def __post_init__(self):
        L = self.n_learners
        if L < 1:
            raise ValueError(f"fault plan needs n_learners >= 1, got {L}")
        for s in self.stragglers:
            if not 0 <= s.learner < L:
                raise ValueError(f"straggler learner {s.learner} out of "
                                 f"range for n_learners={L}")
            if s.factor < 1:
                raise ValueError(f"straggler factor must be >= 1, "
                                 f"got {s.factor} (learner {s.learner})")
        for d in self.departures:
            if not 0 <= d.learner < L:
                raise ValueError(f"departure learner {d.learner} out of "
                                 f"range for n_learners={L}")
            if d.rejoin >= 0 and d.rejoin <= d.step:
                raise ValueError(
                    f"learner {d.learner} rejoin step {d.rejoin} must be "
                    f"after its crash step {d.step}")
        if not 0.0 <= self.drop_prob <= 1.0:
            raise ValueError(f"drop_prob must be in [0, 1], "
                             f"got {self.drop_prob}")
        if not 0.0 <= self.stall_prob <= 1.0:
            raise ValueError(f"stall_prob must be in [0, 1], "
                             f"got {self.stall_prob}")
        if not 0.0 <= self.corrupt_prob <= 1.0:
            raise ValueError(f"corrupt_prob must be in [0, 1], "
                             f"got {self.corrupt_prob}")
        self._validate_membership()
        # lazily-grown stall bitmap cache: (horizon, bool (L, horizon))
        self._stalls = None

    # -- membership ------------------------------------------------------
    def _validate_membership(self):
        """No step may leave zero learners alive — the all-inactive edge
        would turn frame-weighted aggregation into 0/0 downstream, so it
        is rejected HERE, with the offending step named."""
        events = sorted({0}
                        | {d.step for d in self.departures}
                        | {d.rejoin for d in self.departures if d.rejoin >= 0})
        for step in events:
            n = int(self.active_at(step).sum())
            if n == 0:
                raise ValueError(
                    f"fault plan leaves ZERO active learners at step {step} "
                    f"(of {self.n_learners}); every step needs at least one "
                    f"survivor — stagger the departures or add rejoins")

    def active_at(self, step: int) -> np.ndarray:
        """bool (L,): alive at ``step`` (crashed learners are inactive in
        [step, rejoin); rejoin < 0 means gone forever)."""
        active = np.ones(self.n_learners, bool)
        for d in self.departures:
            if d.step <= step and (d.rejoin < 0 or step < d.rejoin):
                active[d.learner] = False
        return active

    def rejoin_at(self, step: int) -> np.ndarray:
        """bool (L,): re-enters the cluster exactly at ``step`` (its
        params are re-seeded from the survivors' consensus)."""
        out = np.zeros(self.n_learners, bool)
        for d in self.departures:
            if d.rejoin == step:
                out[d.learner] = True
        return out

    # -- stragglers / stalls --------------------------------------------
    def speed_factors(self) -> np.ndarray:
        """f64 (L,): per-learner slowdown multipliers (1.0 = nominal) —
        the perfsim view of the straggler schedule."""
        f = np.ones(self.n_learners)
        for s in self.stragglers:
            f[s.learner] = max(f[s.learner], float(s.factor))
        return f

    def _straggler_contrib(self, step: int) -> np.ndarray:
        c = np.ones(self.n_learners, bool)
        for s in self.stragglers:
            c[s.learner] &= ((step + s.phase) % s.factor) == 0
        return c

    def _stall_bitmap(self, horizon: int) -> np.ndarray:
        """bool (L, horizon): stalled-at-step, built deterministically by
        walking each learner's seeded stall process (cached, regrown by
        doubling so step_inputs(k) is O(1) amortized)."""
        if self._stalls is not None and self._stalls.shape[1] > horizon:
            return self._stalls
        h = 256
        while h <= horizon:
            h *= 2
        L = self.n_learners
        out = np.zeros((L, h), bool)
        if self.stall_prob > 0:
            for i in range(L):
                r = np.random.default_rng(
                    (np.uint64(self.seed), np.uint64(i), np.uint64(11)))
                s = 0
                while s < h:
                    if r.random() < self.stall_prob:
                        n = int(min(self.stall_max,
                                    np.ceil(r.pareto(self.stall_shape) + 1)))
                        out[i, s:s + n] = True
                        s += n
                    else:
                        s += 1
        self._stalls = out
        return out

    def stalled_at(self, step: int) -> np.ndarray:
        if self.stall_prob <= 0:
            return np.zeros(self.n_learners, bool)
        return self._stall_bitmap(step)[:, step]

    def stall_extra(self, learner: int, k: int) -> float:
        """Extra stall time (in units of the base per-batch time) charged
        to learner ``learner``'s ``k``-th batch — the perfsim view of the
        same heavy-tailed stall process."""
        if self.stall_prob <= 0:
            return 0.0
        r = np.random.default_rng((np.uint64(self.seed), np.uint64(learner),
                                   np.uint64(k), np.uint64(13)))
        if r.random() >= self.stall_prob:
            return 0.0
        return float(min(self.stall_max,
                         np.ceil(r.pareto(self.stall_shape) + 1)))

    # -- gossip faults ---------------------------------------------------
    def edge_ok_at(self, step: int) -> np.ndarray:
        """f32 (L, L): 1 where the undirected mixing edge (i, j) delivers
        this step, 0 where it is dropped (symmetric, diag always 1)."""
        L = self.n_learners
        if self.drop_prob <= 0:
            return np.ones((L, L), np.float32)
        r = np.random.default_rng(
            (np.uint64(self.seed), np.uint64(step), np.uint64(17)))
        up = (r.random((L, L)) >= self.drop_prob)
        ok = np.triu(up, 1)
        ok = (ok + ok.T).astype(np.float32)
        np.fill_diagonal(ok, 1.0)
        return ok

    def corrupt_at(self, step: int) -> np.ndarray:
        """f32 (L,): relative noise scale applied to each learner's
        OUTGOING payload this step (0 = clean)."""
        L = self.n_learners
        if self.corrupt_prob <= 0 or self.corrupt_scale <= 0:
            return np.zeros(L, np.float32)
        r = np.random.default_rng(
            (np.uint64(self.seed), np.uint64(step), np.uint64(19)))
        hit = r.random(L) < self.corrupt_prob
        return (hit * self.corrupt_scale).astype(np.float32)

    # -- the step-loop contract -----------------------------------------
    def step_inputs(self, step: int) -> dict:
        """Everything the elastic train step needs for one step, as
        constant-shape numpy arrays (one jit compile for the whole run):

        ========== ========= =============================================
        key        shape     meaning
        ========== ========= =============================================
        active     (L,) f32  1 = alive this step
        contrib    (L,) f32  1 = computes a gradient this step (alive,
                             straggler-phase hit, not stalled)
        rejoin     (L,) f32  1 = re-enters THIS step (consensus re-seed)
        edge_ok    (L,L) f32 1 = the undirected gossip edge delivers
        corrupt    (L,) f32  outgoing-payload noise scale (0 = clean)
        ========== ========= =============================================
        """
        active = self.active_at(step)
        contrib = active & self._straggler_contrib(step) \
            & ~self.stalled_at(step)
        return {
            "active": active.astype(np.float32),
            "contrib": contrib.astype(np.float32),
            "rejoin": self.rejoin_at(step).astype(np.float32),
            "edge_ok": self.edge_ok_at(step),
            "corrupt": self.corrupt_at(step),
        }

    def no_fault_inputs(self) -> dict:
        """The trivial (fault-free) step inputs — what a plan-less elastic
        step sees."""
        L = self.n_learners
        ones = np.ones(L, np.float32)
        return {"active": ones, "contrib": ones.copy(),
                "rejoin": np.zeros(L, np.float32),
                "edge_ok": np.ones((L, L), np.float32),
                "corrupt": np.zeros(L, np.float32)}

    # -- (de)serialization ----------------------------------------------
    def to_dict(self) -> dict:
        """JSON-able plan description (the schema documented in
        docs/fault_tolerance.md)."""
        return {
            "n_learners": self.n_learners, "seed": self.seed,
            "stragglers": [[s.learner, s.factor, s.phase]
                           for s in self.stragglers],
            "departures": [[d.learner, d.step, d.rejoin]
                           for d in self.departures],
            "drop_prob": self.drop_prob, "stall_prob": self.stall_prob,
            "stall_shape": self.stall_shape, "stall_max": self.stall_max,
            "corrupt_prob": self.corrupt_prob,
            "corrupt_scale": self.corrupt_scale,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "FaultPlan":
        return cls(
            n_learners=d["n_learners"], seed=d.get("seed", 0),
            stragglers=tuple(Straggler(*s) for s in d.get("stragglers", ())),
            departures=tuple(Departure(*x) for x in d.get("departures", ())),
            drop_prob=d.get("drop_prob", 0.0),
            stall_prob=d.get("stall_prob", 0.0),
            stall_shape=d.get("stall_shape", 1.5),
            stall_max=d.get("stall_max", 64),
            corrupt_prob=d.get("corrupt_prob", 0.0),
            corrupt_scale=d.get("corrupt_scale", 0.0),
        )

    def describe(self) -> str:
        bits = [f"L={self.n_learners}", f"seed={self.seed}"]
        if self.stragglers:
            bits.append("stragglers=" + ",".join(
                f"{s.learner}:{s.factor}x" for s in self.stragglers))
        if self.departures:
            bits.append("departures=" + ",".join(
                f"{d.learner}@{d.step}"
                + (f"->{d.rejoin}" if d.rejoin >= 0 else "->never")
                for d in self.departures))
        for k in ("drop_prob", "stall_prob", "corrupt_prob"):
            v = getattr(self, k)
            if v > 0:
                bits.append(f"{k}={v}")
        return "FaultPlan(" + ", ".join(bits) + ")"


# ---------------------------------------------------------------------------
# CLI spec parsing (the --fault-* train flags)
# ---------------------------------------------------------------------------

def parse_stragglers(spec: str) -> Tuple[Straggler, ...]:
    """``"0:4,3:2"`` -> learner 0 at 4x, learner 3 at 2x."""
    out = []
    for part in filter(None, (p.strip() for p in spec.split(","))):
        fields = part.split(":")
        if len(fields) != 2:
            raise ValueError(
                f"bad straggler spec {part!r}: want 'learner:factor' "
                f"(e.g. '0:4' = learner 0 runs 4x slower)")
        out.append(Straggler(int(fields[0]), int(fields[1])))
    return tuple(out)


def parse_departures(spec: str) -> Tuple[Departure, ...]:
    """``"1:30:60,2:50"`` -> learner 1 crashes at step 30 and rejoins at
    60; learner 2 crashes at step 50 and never comes back."""
    out = []
    for part in filter(None, (p.strip() for p in spec.split(","))):
        fields = part.split(":")
        if len(fields) not in (2, 3):
            raise ValueError(
                f"bad departure spec {part!r}: want 'learner:step' or "
                f"'learner:step:rejoin' (e.g. '1:30:60')")
        rejoin = int(fields[2]) if len(fields) == 3 else -1
        out.append(Departure(int(fields[0]), int(fields[1]), rejoin))
    return tuple(out)
