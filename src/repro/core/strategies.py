"""Distributed training strategies (the paper's contribution, §IV-V).

Implemented strategies, all expressed in the decentralized formalism of
paper Eq. 14  (W_{k+1} = W_k·T − α·g(Φ_k, ξ_k)):

==========  =====================  =========  =============================
name        T (mixing)             Φ_k        paper reference
==========  =====================  =========  =============================
sc_psgd     T_u (allreduce)        W_k        §IV-B1 sync centralized; with
                                              L=1 replicas this is plain
                                              data-parallel SGD + psum
                                              (Eq. 13 equivalence)
sd_psgd     T_1 (ring permute)     W_k        §IV-C sync decentralized
ad_psgd     T_1 (ring permute)     W_{k-1}    §IV-C async decentralized:
                                              one-step-stale gradients let
                                              XLA overlap the mixing
                                              collective with compute
bmuf        block-level T_u        W_k local  §IV-B1 (Chen & Huo): local SGD
                                              for a block, then blockwise
                                              model-update filtering with
                                              block momentum
downpour    PS (simulated)         W_{k-1}    §IV-B2 async centralized
hring       T_1 over pods +        W_{k-1}    §V second experiment: NCCL
            T_u within pod                    allreduce inside a node
                                              (super-learner), AD-PSGD ring
                                              across nodes -> 'pod' axis
==========  =====================  =========  =============================

TPU/SPMD adaptation (DESIGN.md §Asynchrony): true wall-clock asynchrony
does not exist in a single SPMD program, so AD-PSGD's asynchrony is modeled
*deterministically* as bounded staleness — gradients are evaluated at the
previous iterate while the mixing of the current iterate proceeds in
parallel.  This is exactly the communication/computation overlap the paper
credits for AD-PSGD's speedup, and it preserves the algorithm's convergence
analysis (staleness tau=1..tau_max).  Wall-clock effects (stragglers, load
balancing, Table II/III) are studied with the discrete-event simulator in
``benchmarks/perfsim.py``.

Learner replicas are a stacked leading axis sharded over the mesh
('data' axis on one pod; 'pod' axis for hring), so each chip only ever
holds its own learner's shard — replication costs no extra HBM per chip.

Communication is factored out into the unified substrate of
``repro.core.transport``: every strategy takes a :class:`Transport`
(topology × wire codec × bucketing) and only contributes its *defaults*
(``Strategy.topology``/``Strategy.wire``).  Previously-inexpressible
combinations — BMUF with int8 block sync, hring with bf16 intra-pod +
topk inter-pod, allreduce with sparsified payloads — are one config away
(``comm_topology``/``comm_wire``/... knobs in configs/base.py, ``--comm-*``
train flags; matrix in docs/strategies.md).  With the default f32 wire the
substrate delegates to the exact mixers in ``repro.core.mixing`` and the
update trajectories are bit-identical to the pre-substrate step.  Each
replicated step also emits ``wire_bytes`` telemetry (analytic bytes sent
per learner per round, from ``Transport.wire_bytes``).

Variable-length batches (the ``lengths`` key of repro.data.pipeline) are
aggregated with *frame weights*: each learner's/microbatch's masked-mean
gradient is scaled by its valid-frame share so uniform mixing equals the
global masked gradient — the normative contract lives in docs/data.md.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.transport import Transport
from repro.optim.optimizers import Optimizer


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def split_learner_batch(batch, n_learners: int):
    """(B, ...) -> (L, B/L, ...) on every input leaf.

    Raises a ValueError (not a silent misshape) when the global batch is
    not divisible by the learner count, or when the learner count itself
    is empty (the all-inactive edge — see :func:`check_active`)."""
    if n_learners < 1:
        raise ValueError(
            f"n_learners={n_learners}: cannot split a batch over an "
            f"empty learner set — at least one learner must be active "
            f"(see check_active / FaultPlan membership validation)")

    def one(path, x):
        B = x.shape[0]
        if B % n_learners != 0:
            key = jax.tree_util.keystr(path)
            raise ValueError(
                f"global batch size B={B} (batch key {key!r}) is not "
                f"divisible by n_learners={n_learners}; every batch leaf "
                f"needs leading dim a multiple of the learner count so "
                f"each learner gets an equal shard (got remainder "
                f"{B % n_learners})")
        return x.reshape(n_learners, B // n_learners, *x.shape[1:])

    return jax.tree_util.tree_map_with_path(one, batch)


def check_active(active) -> int:
    """Host-side guard for the all-inactive-learner edge: frame-weighted
    aggregation over an empty learner set is 0/0, and the jitted step
    only *clamps* the denominator (traced values cannot raise).  Call
    this on the step's activity mask before invoking the elastic step;
    returns the live count.  ``repro.core.faults.FaultPlan`` applies the
    same rule to every membership event at plan construction."""
    n = int(np.asarray(active).sum())
    if n <= 0:
        raise ValueError(
            "no active learners this step: frame-weighted aggregation "
            "over an empty learner set is 0/0 and mixing has no "
            "survivor to freeze toward — fix the fault plan so at least "
            "one learner stays alive (FaultPlan raises the same error "
            "at construction)")
    return n


def _valid_frames(batch):
    """Per-example valid-frame counts summed over the batch, or None for
    rectangular batches (the ``lengths`` contract of repro.data.pipeline)."""
    if isinstance(batch, dict) and "lengths" in batch:
        return jnp.sum(batch["lengths"].astype(jnp.float32))
    return None


def _accumulated_grad(loss_fn, params, batch, n_micro: int):
    """Gradient with optional microbatch accumulation (memory knob).

    When the batch carries ``lengths``, microbatches are combined with
    frame weights (each microbatch's masked-mean loss/grad scaled by its
    valid-frame count) so the result equals the masked mean over the
    whole batch, not the mean-of-means."""
    if n_micro <= 1:
        loss, g = jax.value_and_grad(loss_fn)(params, batch)
        return loss, g

    def slice_micro(x):
        # split on the MINOR position of the batch dim (strided microbatches)
        # so a data/pod-sharded batch axis stays GSPMD-representable after
        # the reshape; (n_micro, B, ...) major-split is not when the shard
        # size doesn't divide B/n_micro contiguously.
        B = x.shape[0]
        x = x.reshape(B // n_micro, n_micro, *x.shape[1:])
        return jnp.moveaxis(x, 1, 0)

    mb = jax.tree.map(slice_micro, batch)
    weighted = _valid_frames(batch) is not None

    def body(carry, mbatch):
        acc, loss_acc, wsum = carry
        loss, g = jax.value_and_grad(loss_fn)(params, mbatch)
        w = _valid_frames(mbatch) if weighted else jnp.float32(1.0)
        acc = jax.tree.map(lambda a, b: a + w * b.astype(a.dtype), acc, g)
        return (acc, loss_acc + w * loss, wsum + w), None

    g0 = jax.tree.map(lambda w: jnp.zeros(w.shape, jnp.float32), params)
    (g, loss, wsum), _ = jax.lax.scan(
        body, (g0, jnp.float32(0.0), jnp.float32(0.0)), mb)
    scale = 1.0 / jnp.maximum(wsum, 1e-6)
    return loss * scale, jax.tree.map(lambda x: x * scale, g)


def consensus_distance(params):
    """Mean L2 distance of learner replicas from their average — the
    consensus diagnostic for decentralized SGD (paper §IV-C)."""
    def one(w):
        if w.ndim == 0 or w.shape[0] == 1:
            return jnp.float32(0.0), jnp.float32(1.0)
        wf = w.astype(jnp.float32)
        mu = jnp.mean(wf, axis=0, keepdims=True)
        return jnp.sum(jnp.square(wf - mu)), jnp.float32(wf.size)

    parts = [one(w) for w in jax.tree.leaves(params)]
    num = sum(p[0] for p in parts)
    den = sum(p[1] for p in parts)
    return jnp.sqrt(num / den)


# ---------------------------------------------------------------------------
# Strategy definitions
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Strategy:
    """A distributed training strategy built around paper Eq. 14.

    ``topology``/``wire`` are only the DEFAULT Transport of the strategy
    (what you get when no explicit transport/config override is passed);
    any strategy runs over any substrate configuration."""

    name: str
    topology: str               # default Transport topology
    wire: str = "f32"           # default Transport wire codec
    stale: bool = False         # gradients at W_{k-1} (async modeling)
    replicated: bool = True     # params carry a leading learner axis
    block_size: int = 0         # >0: BMUF block length (in steps)
    block_momentum: float = 0.9
    block_lr: float = 1.0

    @property
    def mixer(self) -> str:     # pre-substrate name, kept for callers
        return self.topology


STRATEGIES = {
    "sc_psgd": Strategy("sc_psgd", topology="uniform", replicated=False),
    "sc_psgd_replicated": Strategy("sc_psgd_replicated", topology="uniform"),
    "sd_psgd": Strategy("sd_psgd", topology="ring"),
    "ad_psgd": Strategy("ad_psgd", topology="ring", stale=True),
    "downpour": Strategy("downpour", topology="uniform", stale=True),
    # BMUF mixes only at block boundaries; 'uniform' is the block-sync
    # topology (overridable like any other via the transport)
    "bmuf": Strategy("bmuf", topology="uniform", block_size=16),
    "hring": Strategy("hring", topology="hierarchical", stale=True),
    # beyond-paper (anchored in §IV-D comm-reduction survey), now plain
    # substrate configurations rather than bespoke mixers:
    "ad_psgd_q8": Strategy("ad_psgd_q8", topology="ring", wire="int8",
                           stale=True),
    "ad_psgd_exp": Strategy("ad_psgd_exp", topology="exp", stale=True),
}


def get_strategy(name: str) -> Strategy:
    return STRATEGIES[name]


def default_transport(strategy: Strategy) -> Transport:
    """The strategy's native substrate configuration (f32 wire, fused
    payloads) — bit-identical to the pre-substrate mixers."""
    return Transport(topology=strategy.topology, wire=strategy.wire)


def transport_from_cfg(cfg, strategy: Strategy) -> Transport:
    """Resolve the ``comm_*`` knobs of an ArchConfig against the
    strategy defaults (empty string = keep the strategy default)."""
    return Transport(
        topology=getattr(cfg, "comm_topology", "") or strategy.topology,
        wire=getattr(cfg, "comm_wire", "") or strategy.wire,
        intra_wire=getattr(cfg, "comm_intra_wire", "") or "f32",
        bucket_bytes=int(getattr(cfg, "comm_bucket_mb", 0) * 2 ** 20),
        pod_size=getattr(cfg, "comm_pod_size", 1) or 1,
        topk_frac=getattr(cfg, "comm_topk_frac", 0.01),
        staleness_lambda=getattr(cfg, "comm_staleness_lambda", 0.0),
    )


# ---------------------------------------------------------------------------
# Train state / step builder
# ---------------------------------------------------------------------------

def init_state(strategy: Strategy, params, optimizer: Optimizer,
               transport: Optional[Transport] = None):
    """params: already stacked with the learner dim if strategy.replicated.

    Pass the SAME ``transport`` given to :func:`make_train_step`: wires
    with error feedback (topk) carry their residuals in ``state['comm']``
    (f32 regardless of the parameter dtype)."""
    transport = transport if transport is not None \
        else default_transport(strategy)
    state = {
        "params": params,
        "opt": (jax.vmap(optimizer.init)(params)
                if strategy.replicated and _learner_dim(params) > 1
                else optimizer.init(params)),
        "step": jnp.zeros((), jnp.int32),
    }
    # distinct buffers (not aliases of params) so the whole state is donatable
    copy = lambda t: jax.tree.map(jnp.copy, t)
    if strategy.stale:
        state["prev_params"] = copy(params)
    if strategy.block_size:
        state["anchor"] = copy(params)
        state["block_mom"] = jax.tree.map(
            lambda w: jnp.zeros(w.shape, jnp.float32), params)
    if strategy.replicated and transport.needs_state:
        state["comm"] = transport.init_comm(params)
    return state


def _learner_dim(params) -> int:
    return jax.tree.leaves(params)[0].shape[0]


def _grad_norm(g):
    """Global L2 norm of a gradient tree (f32 accumulation)."""
    sq = sum(jnp.sum(jnp.square(w.astype(jnp.float32)))
             for w in jax.tree.leaves(g))
    return jnp.sqrt(sq)


def _grad_norm_stacked(g_l):
    """(L,) per-learner L2 norms of a stacked gradient tree."""
    sq = sum(jnp.sum(jnp.square(w.astype(jnp.float32)),
                     axis=tuple(range(1, w.ndim)))
             for w in jax.tree.leaves(g_l))
    return jnp.sqrt(sq)


def make_train_step(strategy: Strategy, loss_fn: Callable,
                    optimizer: Optimizer, lr_schedule: Callable,
                    *, n_learners: int = 1, microbatches: int = 1,
                    with_consensus: bool = False, pre_split: bool = False,
                    transport: Optional[Transport] = None,
                    with_grad_norm: bool = False):
    """Build the jittable train step.

    loss_fn(params, batch) -> scalar, over UNstacked params/batch.
    Batches carrying a ``lengths`` key (variable-length utterances; see
    repro.data.pipeline) get frame-weighted aggregation: learner
    gradients are scaled by their valid-frame share before mixing, and
    the reported loss is the frame-weighted mean.
    For replicated strategies the step expects state['params'] stacked
    (L, ...) and the global batch either pre-split to (L, B/L, ...) with an
    explicit ('learner','batch',...) sharding (``pre_split=True`` — required
    when the learner axis is 'pod': an in-step reshape of a data-sharded
    batch dim into (pod, data) is not GSPMD-representable and silently
    replicates the learner work), or flat (B, ...) to be reshaped here.

    ``transport`` configures the communication substrate (topology ×
    wire × bucketing; default: the strategy's native f32 configuration,
    bit-identical to the pre-substrate step).  Replicated steps emit
    ``metrics['wire_bytes']`` — analytic bytes sent per learner this
    step (0 on non-sync BMUF steps).  Non-replicated sc_psgd averages
    gradients through GSPMD, not the substrate, so it carries no
    wire-byte telemetry (see docs/strategies.md).

    ``with_grad_norm`` adds ``metrics['grad_norm']`` — the L2 norm of
    the applied gradient (mean of the per-learner norms on replicated
    strategies).  Off by default: the extra reduction changes the jit
    graph, and the observability layer's zero-overhead contract is
    that uninstrumented runs stay bit-identical.
    """
    transport = transport if transport is not None \
        else default_transport(strategy)
    mix = (transport.make_mixer(n_learners) if strategy.replicated
           else None)

    def grad_one(params, batch):
        return _accumulated_grad(loss_fn, params, batch, microbatches)

    def step(state, batch):
        lr = lr_schedule(state["step"])
        metrics = {}

        if not strategy.replicated:
            # plain data-parallel SGD: gradient averaging over the data axis
            # happens through GSPMD (batch sharded, params replicated/FSDP) —
            # the allreduce realization of the PS (paper Eq. 13).
            loss, g = grad_one(state["params"], batch)
            new_params, opt = optimizer.update(g, state["opt"],
                                               state["params"], lr)
            out = {"params": new_params, "opt": opt,
                   "step": state["step"] + 1}
            metrics["loss"] = loss
            if with_grad_norm:
                metrics["grad_norm"] = _grad_norm(g)
            return out, metrics

        lbatch = batch if pre_split else split_learner_batch(batch, n_learners)
        grad_at = state["prev_params"] if strategy.stale else state["params"]
        loss_l, g_l = jax.vmap(grad_one)(grad_at, lbatch)
        if isinstance(lbatch, dict) and "lengths" in lbatch:
            # frame-weighted aggregation: each learner's masked-mean
            # gradient is scaled by its valid-frame share, so the uniform
            # 1/L combination (sc_psgd mixing) — and proportionally the
            # sd/ad_psgd ring updates — equals the gradient of the GLOBAL
            # masked loss:  sum_l f_l g_l / sum_l f_l.
            frames = jnp.sum(lbatch["lengths"].astype(jnp.float32),
                             axis=tuple(range(1, lbatch["lengths"].ndim)))
            w = frames / jnp.maximum(jnp.mean(frames), 1e-6)
            g_l = jax.tree.map(
                lambda g: (g.astype(jnp.float32)
                           * w.reshape((-1,) + (1,) * (g.ndim - 1))
                           ).astype(g.dtype), g_l)
            metrics["loss"] = (jnp.sum(loss_l * frames)
                               / jnp.maximum(jnp.sum(frames), 1e-6))
        else:
            metrics["loss"] = jnp.mean(loss_l)
        if with_grad_norm:
            metrics["grad_norm"] = jnp.mean(_grad_norm_stacked(g_l))

        comm = state.get("comm", {})
        wire_bytes = jnp.float32(transport.wire_bytes(state["params"]))
        if strategy.block_size:
            # BMUF: local SGD inside a block; blockwise model-update
            # filtering at block boundaries.  The block sync goes through
            # the substrate, so e.g. int8 block sync is one config away.
            upd_params, opt = jax.vmap(
                optimizer.update, in_axes=(0, 0, 0, None)
            )(g_l, state["opt"], state["params"], lr)
            step_no = state["step"] + 1
            is_sync = (step_no % strategy.block_size) == 0

            def do_sync(args):
                params, anchor, mom, comm = args
                avg, comm = mix(params, step_no, comm)
                delta = jax.tree.map(
                    lambda a, b: (a.astype(jnp.float32)
                                  - b.astype(jnp.float32)), avg, anchor)
                mom = jax.tree.map(
                    lambda m, d: strategy.block_momentum * m
                    + strategy.block_lr * d, mom, delta)
                new = jax.tree.map(
                    lambda b, m: (b.astype(jnp.float32) + m).astype(b.dtype),
                    anchor, mom)
                return new, new, mom, comm

            def no_sync(args):
                params, anchor, mom, comm = args
                return params, anchor, mom, comm

            new_params, anchor, mom, comm = jax.lax.cond(
                is_sync, do_sync, no_sync,
                (upd_params, state["anchor"], state["block_mom"], comm))
            out = {"params": new_params, "opt": opt, "step": step_no,
                   "anchor": anchor, "block_mom": mom}
            metrics["wire_bytes"] = jnp.where(is_sync, wire_bytes, 0.0)
        else:
            # Eq. 14: mixing of the current iterate is data-independent of
            # the gradient (evaluated at prev iterate when stale) -> XLA can
            # schedule the collective concurrently with compute; chunked
            # buckets (transport.bucket_bytes) deepen that interleaving.
            mixed, comm = mix(state["params"], state["step"], comm)
            new_params, opt = jax.vmap(
                optimizer.update, in_axes=(0, 0, 0, None)
            )(g_l, state["opt"], mixed, lr)
            out = {"params": new_params, "opt": opt,
                   "step": state["step"] + 1}
            metrics["wire_bytes"] = wire_bytes

        if "comm" in state:
            out["comm"] = comm
        if strategy.stale:
            out["prev_params"] = state["params"]
        if with_consensus:
            metrics["consensus"] = consensus_distance(out["params"])
        return out, metrics

    return step


# ---------------------------------------------------------------------------
# Elastic (fault-tolerant) train step
# ---------------------------------------------------------------------------

def _sel(mask, a, b):
    """Per-learner select over stacked trees: leaf rows where the (L,)
    ``mask`` is set come from ``a``, the rest from ``b``."""
    def one(x, y):
        m = (mask > 0).reshape((-1,) + (1,) * (x.ndim - 1))
        return jnp.where(m, x, y)
    return jax.tree.map(one, a, b)


def _reseed_rejoiners(params, rejoin, incumbent):
    """Rejoining learners re-enter at the incumbents' consensus mean —
    elastic membership never resurrects a crashed learner's dead weights
    (docs/fault_tolerance.md)."""
    n_inc = jnp.maximum(jnp.sum(incumbent), 1.0)

    def one(w):
        wf = w.astype(jnp.float32)
        inc = incumbent.reshape((-1,) + (1,) * (w.ndim - 1))
        mu = jnp.sum(wf * inc, axis=0, keepdims=True) / n_inc
        rj = (rejoin > 0).reshape((-1,) + (1,) * (w.ndim - 1))
        return jnp.where(rj, mu, wf).astype(w.dtype)

    return jax.tree.map(one, params)


def _masked_consensus(params, active):
    """Consensus distance over the ACTIVE learners only (a crashed
    learner's frozen replica is cluster weather, not disagreement)."""
    n_act = jnp.maximum(jnp.sum(active), 1.0)

    def one(w):
        if w.ndim == 0 or w.shape[0] == 1:
            return jnp.float32(0.0), jnp.float32(1.0)
        wf = w.astype(jnp.float32)
        a = active.reshape((-1,) + (1,) * (w.ndim - 1))
        mu = jnp.sum(wf * a, axis=0, keepdims=True) / n_act
        per = jnp.float32(wf.size) / wf.shape[0]
        return jnp.sum(jnp.square(wf - mu) * a), n_act * per

    parts = [one(w) for w in jax.tree.leaves(params)]
    num = sum(p[0] for p in parts)
    den = sum(p[1] for p in parts)
    return jnp.sqrt(num / den)


def init_elastic_state(strategy: Strategy, params, optimizer: Optimizer,
                       transport: Optional[Transport] = None):
    """:func:`init_state` plus the per-learner staleness counters (steps
    since the learner last contributed a gradient) that drive
    staleness-aware mixing weights."""
    state = init_state(strategy, params, optimizer, transport)
    state["staleness"] = jnp.zeros((_learner_dim(params),), jnp.int32)
    return state


def make_elastic_train_step(strategy: Strategy, loss_fn: Callable,
                            optimizer: Optimizer, lr_schedule: Callable,
                            *, n_learners: int, microbatches: int = 1,
                            with_consensus: bool = False,
                            pre_split: bool = False,
                            transport: Optional[Transport] = None,
                            fault_seed: int = 0,
                            with_corruption: bool = False,
                            with_grad_norm: bool = False):
    """Build the fault-tolerant variant of :func:`make_train_step`:

        ``step(state, batch, faults) -> (state', metrics)``

    where ``faults`` is one :meth:`repro.core.faults.FaultPlan.
    step_inputs` dict (active/contrib/rejoin/edge_ok/corrupt arrays, all
    traced — ONE jit compile covers any fault schedule).  Semantics
    (normative text in docs/fault_tolerance.md):

    * **membership** — mixing runs over the live set via the elastic
      matrices (dead learners frozen bit-for-bit as identity rows);
      rejoiners re-enter at the incumbents' consensus mean with a fresh
      optimizer state and zero staleness.
    * **stragglers/stalls** — a learner that is alive but not
      contributing (``contrib`` = 0) still participates in mixing but
      applies no gradient and keeps its optimizer state; its staleness
      counter grows, and with ``transport.staleness_lambda`` > 0 its
      mixing influence is damped by 1/(1 + λ·staleness).
    * **aggregation** — frame weights renormalize over the contributing
      learners: w_l = n_active·f_l/Σ_contrib f, so the mean applied
      gradient equals the global masked gradient over contributors, and
      the reported loss is the contributor frame-weighted mean.  The
      all-inactive edge is clamped in-graph and rejected host-side
      (:func:`check_active`, FaultPlan validation).
    * **wire faults** — dropped edges return their mixing mass to the
      diagonal; corrupted payloads (``with_corruption``) only poison
      the peer view, never the local replica.

    With the trivial mask (everyone active and contributing, no drops)
    the trajectory matches :func:`make_train_step` to f32 matmul
    tolerance — the elastic path mixes via an explicit matrix
    contraction where the plain path uses rolls/means.

    Only replicated strategies can be elastic (non-replicated sc_psgd
    has no learner axis to mask — use ``sc_psgd_replicated``).
    Difference-coded wires (topk) are rejected by
    :meth:`Transport.make_elastic_mixer`.
    """
    if not strategy.replicated:
        raise ValueError(
            f"strategy {strategy.name!r} is not replicated: elastic "
            f"membership needs a stacked learner axis to mask — use "
            f"'sc_psgd_replicated' for an elastic allreduce baseline")
    transport = transport if transport is not None \
        else default_transport(strategy)
    mix = transport.make_elastic_mixer(
        n_learners, fault_seed=fault_seed, with_corruption=with_corruption)

    def grad_one(params, batch):
        return _accumulated_grad(loss_fn, params, batch, microbatches)

    def step(state, batch, faults):
        lr = lr_schedule(state["step"])
        metrics = {}
        active = faults["active"]
        rejoin = faults["rejoin"]
        gmask = active * faults["contrib"]
        n_act = jnp.maximum(jnp.sum(active), 1.0)
        incumbent = active * (1.0 - rejoin)

        # membership first: rejoiners re-enter at the incumbents' mean
        params = _reseed_rejoiners(state["params"], rejoin, incumbent)
        fresh_opt = jax.vmap(optimizer.init)(params)
        opt = _sel(rejoin, fresh_opt, state["opt"])
        staleness = jnp.where(rejoin > 0, 0, state["staleness"])

        lbatch = batch if pre_split else split_learner_batch(batch, n_learners)
        grad_at = params
        prev = None
        if strategy.stale:
            prev = _reseed_rejoiners(state["prev_params"], rejoin, incumbent)
            grad_at = prev
        loss_l, g_l = jax.vmap(grad_one)(grad_at, lbatch)

        if isinstance(lbatch, dict) and "lengths" in lbatch:
            frames = jnp.sum(lbatch["lengths"].astype(jnp.float32),
                             axis=tuple(range(1, lbatch["lengths"].ndim)))
        else:
            frames = jnp.ones((n_learners,), jnp.float32)
        cframes = gmask * frames
        csum = jnp.maximum(jnp.sum(cframes), 1e-6)
        # mean-over-active of the applied gradients == the global masked
        # gradient over the contributors (all-contributing rectangular
        # batches give w == 1, the plain-path convention)
        w = n_act * cframes / csum
        g_l = jax.tree.map(
            lambda g: (g.astype(jnp.float32)
                       * w.reshape((-1,) + (1,) * (g.ndim - 1))
                       ).astype(g.dtype), g_l)
        metrics["loss"] = jnp.sum(loss_l * cframes) / csum
        if with_grad_norm:
            # mean applied-gradient norm over the contributors
            norms = _grad_norm_stacked(g_l)
            metrics["grad_norm"] = (jnp.sum(norms * gmask)
                                    / jnp.maximum(jnp.sum(gmask), 1.0))

        wire_bytes = (jnp.float32(transport.wire_bytes(params))
                      * n_act / n_learners)

        def elastic_mix(p, step_no):
            return mix(p, step_no, active, staleness,
                       faults["edge_ok"], faults["corrupt"])

        if strategy.block_size:
            # elastic BMUF: gated local SGD inside the block; at block
            # boundaries the survivors sync through the elastic matrix
            # while the dead keep params/anchor/momentum frozen
            anchor = _reseed_rejoiners(state["anchor"], rejoin, incumbent)
            mom = _sel(rejoin,
                       jax.tree.map(lambda m: jnp.zeros_like(m),
                                    state["block_mom"]),
                       state["block_mom"])
            upd_params, new_opt = jax.vmap(
                optimizer.update, in_axes=(0, 0, 0, None)
            )(g_l, opt, params, lr)
            upd_params = _sel(gmask, upd_params, params)
            new_opt = _sel(gmask, new_opt, opt)
            step_no = state["step"] + 1
            is_sync = (step_no % strategy.block_size) == 0

            def do_sync(args):
                p, anchor, mom = args
                avg = elastic_mix(p, step_no)
                delta = jax.tree.map(
                    lambda a, b: (a.astype(jnp.float32)
                                  - b.astype(jnp.float32)), avg, anchor)
                new_mom = jax.tree.map(
                    lambda m, d: strategy.block_momentum * m
                    + strategy.block_lr * d, mom, delta)
                new = jax.tree.map(
                    lambda b, m: (b.astype(jnp.float32) + m).astype(b.dtype),
                    anchor, new_mom)
                return (_sel(active, new, p), _sel(active, new, anchor),
                        _sel(active, new_mom, mom))

            new_params, anchor, mom = jax.lax.cond(
                is_sync, do_sync, lambda args: args,
                (upd_params, anchor, mom))
            out = {"params": new_params, "opt": new_opt, "step": step_no,
                   "anchor": anchor, "block_mom": mom}
            metrics["wire_bytes"] = jnp.where(is_sync, wire_bytes, 0.0)
        else:
            mixed = elastic_mix(params, state["step"])
            upd_params, new_opt = jax.vmap(
                optimizer.update, in_axes=(0, 0, 0, None)
            )(g_l, opt, mixed, lr)
            # contributors step from the mixed iterate; alive
            # non-contributors keep the mixed iterate (they gossiped but
            # computed nothing); the dead stay exactly where they were
            new_params = _sel(active, _sel(gmask, upd_params, mixed), params)
            new_opt = _sel(gmask, new_opt, opt)
            out = {"params": new_params, "opt": new_opt,
                   "step": state["step"] + 1}
            metrics["wire_bytes"] = wire_bytes

        if strategy.stale:
            out["prev_params"] = params
        if "comm" in state:            # unreachable for topk (mixer raises)
            out["comm"] = state["comm"]
        out["staleness"] = jnp.where(gmask > 0, 0, staleness + 1
                                     ).astype(jnp.int32)
        metrics["n_active"] = n_act
        metrics["n_contrib"] = jnp.sum(gmask)
        metrics["staleness_max"] = jnp.max(out["staleness"] * (active > 0))
        if with_consensus:
            metrics["consensus"] = _masked_consensus(out["params"], active)
        return out, metrics

    return step


def stack_for_learners(params, n_learners: int):
    """Replicate freshly-initialized params into the stacked learner axis."""
    return jax.tree.map(
        lambda w: jnp.broadcast_to(w[None], (n_learners,) + w.shape), params)


def average_learners(params):
    """Collapse replicas to the consensus model (for eval/checkpoint)."""
    return jax.tree.map(
        lambda w: jnp.mean(w.astype(jnp.float32), axis=0).astype(w.dtype),
        params)
