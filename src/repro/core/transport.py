"""Unified communication substrate: topology × wire × overlap (paper §IV-D).

The paper's thesis is that distributed ASR training is won by "striking
the balance between communication and computation" (§IV-D, §V), and the
winning configurations in practice are *combinations* — hierarchical
topology + compressed payloads + overlapped collectives.  This module
factors communication out of the strategies into one composable
:class:`Transport` that every mixing/aggregation site goes through:

* ``topology`` — who exchanges with whom, all expressed as doubly-
  stochastic mixing matrices over the stacked learner axis (Eq. 14):

  =============  ========================================================
  ``uniform``    T_u global averaging — the allreduce realization of a
                 parameter server (Eq. 13); used by SC-PSGD / downpour
                 and BMUF block sync.
  ``ring``       T_1 neighbor averaging — a pair of collective-permutes;
                 SD/AD-PSGD.
  ``hierarchical``  T_u inside each pod of ``pod_size`` learners, T_1
                 ring across pods (the paper's §V H-ring as a topology,
                 no longer a bespoke strategy); as a matrix this is
                 kron(ring(L/p), uniform(p)) — see
                 ``mixing.hierarchical_matrix``.
  ``exp``        one-peer exponential graph [Assran'19]: hypercube
                 gossip, exact consensus every log2(L) rounds.
  ``none``       identity (local SGD; BMUF between block boundaries).
  =============  ========================================================

* ``wire`` — the codec applied to every payload that crosses the wire
  (neighbor permutes, allreduce contributions, inter-pod exchanges).
  On the flat topologies the local replica stays full precision — only
  what a *peer* receives is coded.  The one exception is the
  hierarchical INTRA-pod stage: it models an allreduce, where every
  member's contribution is reduced remotely, so the pod mean is taken
  over coded payloads (own included):

  =========  =========================================================
  ``f32``    4 B/elem, exact (default; bit-identical to the
             pre-substrate mixers).
  ``bf16``   2 B/elem truncation.
  ``int8``   1 B/elem symmetric linear quantization, one f32 scale per
             sender per bucket (per-tensor when unbucketed).  Rounding
             error is <= scale/2 per round and is re-averaged by the
             mixing contraction, so no residual state is needed.
  ``topk``   magnitude sparsification: each sender ships the largest
             ``topk_frac`` fraction of entries (8 B per kept entry:
             value + index).  Sparsifying raw weights would shrink
             peers toward zero, so topk uses DIFFERENCE CODING against
             a shared public estimate [CHOCO-SGD, Koloskova'19]: every
             node tracks each sender's estimate ŵ (reconstructible
             from the payload stream alone), the sender ships
             C(w − ŵ), all trackers apply ŵ ← ŵ + C(·), and mixing
             becomes the damped gossip  w += γ·(T·ŵ − ŵ)  with
             consensus step ``gossip_gamma``.  The un-shipped mass
             r = (w − ŵ) − C(w − ŵ) is the ERROR-FEEDBACK residual:
             it stays inside w − ŵ (the estimate only advances by what
             was sent) and is re-offered every round [Seide'14,
             Aji'17]; it is also materialized in ``state['comm']`` so
             tests/telemetry can assert the EF contract.  ŵ and r
             accumulate in f32 regardless of the parameter dtype.
             Because T is doubly stochastic, γ-damped gossip preserves
             the replica mean exactly — compression error never leaks
             into the consensus average.
  =========  =========================================================

* ``bucket_bytes`` — chunked collectives: payloads larger than this are
  split into buckets that are coded/exchanged independently, giving XLA
  a stream of small independent collectives it can interleave with
  backward compute instead of one monolithic transfer (0 = one fused
  payload per tensor).  f32 bucketing is bit-exact; int8/topk code each
  bucket independently (per-bucket scales/top-k, the standard bucketed
  formulation).

``Transport.wire_bytes`` is the single source for wire-byte telemetry:
analytic bytes SENT per learner per mixing round, from the leaf shapes
and the codec — emitted into train metrics as ``wire_bytes`` and
accounted per (strategy × wire) by ``benchmarks/run.py --only comm``.
The accounting conventions (per-topology multipliers, codec overheads)
are documented in docs/strategies.md.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mixing

TOPOLOGIES = ("none", "uniform", "ring", "hierarchical", "exp")
WIRES = ("f32", "bf16", "int8", "topk")

# wires that carry an error-feedback residual in strategy state
_EF_WIRES = ("topk",)


def _needs_ef(wire: str) -> bool:
    return wire in _EF_WIRES


# ---------------------------------------------------------------------------
# Wire codecs (per-sender; operate on (G, n) f32 payload buckets)
# ---------------------------------------------------------------------------

def decode_payload(wire: str, x, topk_frac: float = 0.01):
    """What the receivers see of the (G, n) f32 payload ``x``: each of the
    G senders' rows is coded independently (per-sender scales/top-k)."""
    if wire == "f32":
        return x
    if wire == "bf16":
        return x.astype(jnp.bfloat16).astype(jnp.float32)
    if wire == "int8":
        amax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
        scale = jnp.where(amax > 0, amax / 127.0, 1.0)
        q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
        return q.astype(jnp.float32) * scale
    if wire == "topk":
        n = x.shape[1]
        k = _topk_k(n, topk_frac)
        if k >= n:
            return x
        kth = jax.lax.top_k(jnp.abs(x), k)[0][:, -1:]
        # >= keeps ties (may ship slightly more than k on degenerate
        # inputs); the wire accounting uses the nominal k
        return jnp.where(jnp.abs(x) >= kth, x, 0.0)
    raise ValueError(f"unknown wire {wire!r}; expected one of {WIRES}")


def _topk_k(n: int, frac: float) -> int:
    return min(n, max(1, int(np.ceil(frac * n))))


def _ring_sends(G: int) -> float:
    """Payloads each member sends per T_1 round: both neighbors (2), the
    single neighbor when G==2, nothing when alone."""
    return 0.0 if G <= 1 else (1.0 if G == 2 else 2.0)


# ---------------------------------------------------------------------------
# Topology combines: local replica w (full precision) + decoded peers d
# ---------------------------------------------------------------------------

def _combine_ring(w, d):
    G = w.shape[0]
    if G == 1:
        return w
    if G == 2:
        return (2.0 * w + jnp.roll(d, 1, axis=0)) / 3.0
    return (w + jnp.roll(d, 1, axis=0) + jnp.roll(d, -1, axis=0)) / 3.0


def _combine_uniform(w, d):
    G = w.shape[0]
    if G == 1:
        return w
    # own contribution stays exact; peers' arrive decoded
    return (w - d + jnp.sum(d, axis=0, keepdims=True)) / G


def _combine_exp(w, d, step, G):
    if G == 1:
        return w
    m = int(np.log2(G))
    branches = [
        (lambda s: lambda: (w + jnp.roll(d, s, axis=0)) / 2.0)(2 ** i)
        for i in range(m)
    ]
    return jax.lax.switch(step % m, branches)


# ---------------------------------------------------------------------------
# Transport
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Transport:
    """One composable communication configuration (see module docstring)."""

    topology: str = "ring"
    wire: str = "f32"
    # hierarchical only: codec of the intra-pod averaging stage (the
    # inter-pod ring uses ``wire``) — e.g. bf16 intra-pod + topk inter-pod
    intra_wire: str = "f32"
    bucket_bytes: int = 0        # 0 = one fused payload per tensor
    pod_size: int = 1            # hierarchical: learners per pod
    topk_frac: float = 0.01      # topk wire: fraction of entries shipped
    # consensus step of the difference-coded (topk) gossip.  0 = auto:
    # min(0.5, topk_frac) — CHOCO theory wants gamma = O(compression
    # quality), and empirically gamma ≲ 2·topk_frac is the stable region
    # (pure-gossip divergence beyond it); 1.0 (plain mixing) is only safe
    # for near-exact wires.
    gossip_gamma: float = 0.0
    # elastic mixing only: staleness damping λ — a learner whose params
    # are s steps behind gets confidence 1/(1 + λ·s) in the mixing
    # matrix (mixing.staleness_damped; docs/fault_tolerance.md).  0
    # disables damping.  Ignored by the non-elastic make_mixer path.
    staleness_lambda: float = 0.0

    def __post_init__(self):
        if self.topology not in TOPOLOGIES:
            raise ValueError(f"unknown topology {self.topology!r}; "
                             f"expected one of {TOPOLOGIES}")
        for w in (self.wire, self.intra_wire):
            if w not in WIRES:
                raise ValueError(f"unknown wire {w!r}; "
                                 f"expected one of {WIRES}")
        if self.intra_wire in _EF_WIRES:
            raise ValueError(
                f"intra_wire {self.intra_wire!r} is not supported: "
                f"difference-coded wires are gossip-only (they need the "
                f"γ-damped update against a tracked estimate) and cannot "
                f"realize the intra-pod allreduce — use f32/bf16/int8 "
                f"intra-pod and save topk for the inter-pod ring")
        if self.pod_size < 1:
            raise ValueError(f"pod_size must be >= 1, got {self.pod_size}")
        if not 0.0 < self.topk_frac <= 1.0:
            raise ValueError(f"topk_frac must be in (0, 1], "
                             f"got {self.topk_frac}")
        if not 0.0 <= self.gossip_gamma <= 1.0:
            raise ValueError(f"gossip_gamma must be in [0, 1] (0 = auto), "
                             f"got {self.gossip_gamma}")
        if self.staleness_lambda < 0.0:
            raise ValueError(f"staleness_lambda must be >= 0, "
                             f"got {self.staleness_lambda}")

    @property
    def resolved_gamma(self) -> float:
        return self.gossip_gamma or min(0.5, self.topk_frac)

    # -- state ----------------------------------------------------------
    @property
    def needs_state(self) -> bool:
        """True when the wire carries an error-feedback residual that must
        live in the strategy state (threaded through the train step)."""
        return _needs_ef(self.wire)

    def init_comm(self, params) -> dict:
        """Error-feedback state: per-sender residual + shared public
        estimate (difference coding), ALWAYS f32 zeros regardless of the
        parameter dtype (bf16 accumulation of tiny per-round errors
        stalls: the residual magnitude quickly falls below the bf16 ulp
        of the running sum and silently stops accumulating)."""
        comm = {}
        if _needs_ef(self.wire):
            def main_shape(w):
                s = tuple(w.shape)
                if self.topology == "hierarchical":
                    s = (s[0] // self.pod_size,) + s[1:]
                return jnp.zeros(s, jnp.float32)
            comm["residual"] = jax.tree.map(main_shape, params)
            comm["estimate"] = jax.tree.map(main_shape, params)
        return comm

    # -- mixing ---------------------------------------------------------
    def make_mixer(self, n_learners: int):
        """Returns ``mix(params, step, comm) -> (mixed, comm)`` over the
        stacked learner axis.  With ``wire='f32'`` and no bucketing the
        fast path delegates to the pure-topology mixers in
        ``repro.core.mixing`` and is bit-identical to them."""
        t = self
        if t.topology == "hierarchical" and n_learners % t.pod_size:
            raise ValueError(
                f"hierarchical topology needs pod_size ({t.pod_size}) to "
                f"divide n_learners ({n_learners})")
        if t.topology == "exp":
            m = max(int(np.log2(max(n_learners, 1))), 1)
            if 2 ** m != n_learners and n_learners != 1:
                raise ValueError("exp topology wants power-of-2 learners, "
                                 f"got {n_learners}")

        # the fast path must also rule out a lossy INTRA-pod codec, which
        # only bites when the hierarchical intra stage actually exists
        plain_intra = (t.topology != "hierarchical" or t.pod_size == 1
                       or t.intra_wire == "f32")
        plain_wire = (t.wire == "f32" and t.bucket_bytes == 0
                      and plain_intra)
        if plain_wire and not t.needs_state:
            if t.topology == "none":
                return lambda p, step, comm: (p, comm)
            if t.topology == "uniform":
                return lambda p, step, comm: (mixing.mix_uniform(p), comm)
            if t.topology == "ring" or (t.topology == "hierarchical"
                                        and t.pod_size == 1):
                return lambda p, step, comm: (mixing.mix_ring(p), comm)
            if t.topology == "hierarchical" and t.pod_size == n_learners:
                return lambda p, step, comm: (mixing.mix_uniform(p), comm)
            if t.topology == "hierarchical":
                mix_h = functools.partial(mixing.mix_hierarchical,
                                          pod_size=t.pod_size)
                return lambda p, step, comm: (mix_h(p), comm)
            if t.topology == "exp":
                exp = mixing.make_exp_mixer(n_learners)
                return lambda p, step, comm: (exp(p, step), comm)

        return functools.partial(_general_mix, t, n_learners)

    def make_elastic_mixer(self, n_learners: int, *, fault_seed: int = 0,
                           with_corruption: bool = False):
        """Elastic-membership mixing (docs/fault_tolerance.md): returns

            ``mix(params, step, active, staleness, edge_ok, corrupt)
              -> mixed``

        where the masks come from ``repro.core.faults.FaultPlan.
        step_inputs`` plus the per-learner staleness counters carried in
        strategy state.  The topology is rebuilt every step over the
        live set (``mixing.elastic_matrix``): dead learners are identity
        rows (their replicas frozen bit-for-bit), dropped gossip edges
        return their mass to the diagonal, and with ``staleness_lambda``
        > 0 learners s steps behind are down-weighted by 1/(1 + λ·s).
        All inputs may be traced — one jit compile covers the whole run.

        Differences from :meth:`make_mixer`:

        * single-stage matrix contraction — ``intra_wire`` does not
          apply (the hierarchical intra/inter stages collapse into one
          doubly-stochastic matrix, coded uniformly with ``wire``);
        * no comm state — difference-coded wires (topk) are REJECTED:
          their shared public estimate assumes every tracker sees every
          payload, which elastic membership breaks (a rejoiner's
          estimate is stale-by-unknown), so there is no correct EF
          residual to carry.  Use f32/bf16/int8 wires under faults.
        * the local replica always stays exact: only the peer view is
          wire-coded, and (``with_corruption``) only the peer view picks
          up the fault plan's payload noise — deterministic per
          (fault_seed, step, leaf).
        """
        t = self
        if t.needs_state:
            raise ValueError(
                f"wire {t.wire!r} is difference-coded (error-feedback "
                f"state) and cannot run under elastic membership: the "
                f"shared public estimate desynchronizes when learners "
                f"crash or rejoin — use an f32/bf16/int8 wire with "
                f"--fault-* runs")
        if t.topology == "hierarchical" and n_learners % t.pod_size:
            raise ValueError(
                f"hierarchical topology needs pod_size ({t.pod_size}) to "
                f"divide n_learners ({n_learners})")

        def mix(params, step, active, staleness, edge_ok, corrupt):
            if t.topology == "none":
                return params
            T = mixing.elastic_matrix(
                active, t.topology, step=step, pod_size=t.pod_size,
                staleness=staleness, staleness_lambda=t.staleness_lambda,
                edge_ok=edge_ok)
            diag = jnp.diag(T)
            off = T - jnp.diag(diag)

            def one(i, w):
                wf = w.astype(jnp.float32).reshape(n_learners, -1)
                d = _coded(t, t.wire, wf)
                if with_corruption:
                    key = jax.random.fold_in(
                        jax.random.fold_in(
                            jax.random.PRNGKey(fault_seed), step), i)
                    rms = jnp.sqrt(jnp.mean(d * d, axis=1, keepdims=True))
                    noise = jax.random.normal(key, d.shape, jnp.float32)
                    d = d + corrupt[:, None] * rms * noise
                # peers' views arrive through the (coded, possibly
                # corrupted) wire; the local replica contributes exactly
                out = off @ d + diag[:, None] * wf
                return out.reshape(w.shape).astype(w.dtype)

            leaves, treedef = jax.tree.flatten(params)
            return jax.tree.unflatten(
                treedef, [one(i, w) for i, w in enumerate(leaves)])

        return mix

    # -- telemetry ------------------------------------------------------
    def wire_bytes(self, params) -> float:
        """Analytic bytes SENT per learner per mixing round, from leaf
        shapes only (works on ShapeDtypeStructs).  Conventions in
        docs/strategies.md: ring = 2 payloads (1 when L==2), uniform =
        2(L-1)/L (ring-allreduce schedule regardless of codec),
        exp = 1, hierarchical = intra uniform over the pod + the pod
        ring amortized over its members."""
        total = 0.0
        for leaf in jax.tree.leaves(params):
            L = int(leaf.shape[0])
            n = int(np.prod(leaf.shape[1:])) if len(leaf.shape) > 1 else 1
            if self.topology == "hierarchical":
                p = self.pod_size
                pods = L // p
                intra = (0.0 if p == 1 else
                         2.0 * (p - 1) / p
                         * self._payload_bytes(self.intra_wire, n))
                inter = (0.0 if pods == 1 else
                         _ring_sends(pods)
                         * self._payload_bytes(self.wire, n) / p)
                total += intra + inter
            else:
                mult = {
                    "none": 0.0,
                    "ring": _ring_sends(L),
                    "uniform": 2.0 * (L - 1) / L,
                    "exp": 1.0 if L > 1 else 0.0,
                }[self.topology]
                total += mult * self._payload_bytes(self.wire, n)
        return total

    def _payload_bytes(self, wire: str, n: int) -> float:
        """Coded size of one sender's n-element tensor, incl. per-bucket
        codec overheads (int8 scale, topk value+index pairs)."""
        sizes = _bucket_sizes(n, self.bucket_bytes)
        if wire == "f32":
            return 4.0 * n
        if wire == "bf16":
            return 2.0 * n
        if wire == "int8":
            return float(n + 4 * len(sizes))
        if wire == "topk":
            return float(sum(8 * _topk_k(s, self.topk_frac) for s in sizes))
        raise ValueError(wire)


# ---------------------------------------------------------------------------
# General (coded / bucketed) mixing path
# ---------------------------------------------------------------------------

def _bucket_sizes(n: int, bucket_bytes: int) -> list:
    """Column-bucket sizes of an n-element f32 payload — the single
    source of the bucketing rule, shared by the codec splitter and the
    wire-byte accounting so the two cannot drift apart."""
    if bucket_bytes <= 0 or n * 4 <= bucket_bytes:
        return [n]
    per = max(1, bucket_bytes // 4)
    return [min(per, n - i) for i in range(0, n, per)]


def _split_cols(x, bucket_bytes: int):
    """Split (G, n) into column buckets of <= bucket_bytes f32 payload."""
    sizes = _bucket_sizes(x.shape[1], bucket_bytes)
    if len(sizes) == 1:
        return [x]
    return jnp.split(x, list(np.cumsum(sizes[:-1])), axis=1)


def _coded(t: Transport, wire: str, x):
    """Bucket-wise decode; returns the decoded full (G, n) tensor."""
    parts = [decode_payload(wire, c, t.topk_frac)
             for c in _split_cols(x, t.bucket_bytes)]
    return parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)


def _wire_stage(t: Transport, wire: str, x, ef):
    """One coded exchange of the (G, n) payload ``x``.

    Returns ``(peer_view, ef')`` — what the receivers hold for each
    sender afterwards, plus the updated error-feedback state.  Without
    error feedback the peer view is simply the decoded payload.  With it
    (topk), difference coding against the shared estimate [CHOCO-SGD]:
    payload = C(x − ŵ); every tracker applies ŵ ← ŵ + payload; the
    dropped mass (x − ŵ') − the f32 residual — stays inside the next
    round's difference and is re-offered automatically."""
    if not _needs_ef(wire):
        return _coded(t, wire, x), ef
    if ef is None:
        raise ValueError(
            f"wire {wire!r} carries error-feedback state: pass the same "
            f"Transport to init_state(...) so state['comm'] holds the "
            f"residual/estimate trees")
    _, est = ef
    delta = x - est
    d = _coded(t, wire, delta)
    est = est + d
    return est, (delta - d, est)


def _general_mix(t: Transport, n_learners: int, params, step, comm):
    comm = comm or {}

    def leaves_or_none(key):
        tree = comm.get(key)
        return (jax.tree.leaves(tree) if tree is not None else None)

    leaves, treedef = jax.tree.flatten(params)
    n = len(leaves)
    ef_main = _zip_ef(leaves_or_none("residual"),
                      leaves_or_none("estimate"), n)

    outs = [_mix_leaf(t, w, step, a) for w, a in zip(leaves, ef_main)]

    mixed = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_comm = dict(comm)
    for key, idx in (("residual", 1), ("estimate", 2)):
        if key in comm:
            new_comm[key] = jax.tree.unflatten(
                treedef, [o[idx] for o in outs])
    return mixed, new_comm


def _zip_ef(residuals, estimates, n):
    if residuals is None:
        return [None] * n
    return list(zip(residuals, estimates))


def _flat_ef(ef, G):
    """Error-feedback pair reshaped to the (G, n) payload domain."""
    if ef is None:
        return None
    return tuple(a.astype(jnp.float32).reshape(G, -1) for a in ef)


def _shaped_ef(ef_new, ef_orig):
    """Back to the stored leaf shapes (passthrough when no EF state)."""
    if ef_orig is None:
        return None, None
    if ef_new is None:
        return ef_orig
    return tuple(a.reshape(o.shape) for a, o in zip(ef_new, ef_orig))


def _combine(t: Transport, topology: str, ef_wire: bool, local, d, step):
    """Topology combine of the local (full-precision) value with the
    peer view ``d``.  Exact wires substitute peers' decoded payloads
    directly; difference-coded wires use the γ-damped CHOCO gossip
    ``local + γ·(T·ŵ − ŵ)``, which preserves the replica mean exactly
    (T doubly stochastic) and is stable under aggressive sparsity."""
    G = local.shape[0]
    if ef_wire:
        if topology == "ring":
            gossip = _combine_ring(d, d) - d
        elif topology == "uniform":
            gossip = jnp.mean(d, axis=0, keepdims=True) - d
        elif topology == "exp":
            gossip = _combine_exp(d, d, step, G) - d
        else:
            raise ValueError(topology)
        return local + t.resolved_gamma * gossip
    if topology == "ring":
        return _combine_ring(local, d)
    if topology == "uniform":
        return _combine_uniform(local, d)
    if topology == "exp":
        return _combine_exp(local, d, step, G)
    raise ValueError(topology)


def _mix_leaf(t: Transport, w, step, ef_main):
    """One leaf through the coded substrate.  Returns
    (mixed, r_main', est_main')."""
    L = w.shape[0]
    dtype = w.dtype
    new_main = None

    if L == 1 or t.topology == "none":
        mixed = w
    elif t.topology == "hierarchical":
        wf = w.astype(jnp.float32).reshape(L, -1)
        p = t.pod_size
        pods = L // p
        # intra-pod allreduce: contributions are reduced remotely, so the
        # pod mean is over coded payloads, own included (unlike the flat
        # uniform topology's gossip model, which keeps the local replica
        # exact); difference-coded intra wires are rejected at
        # construction (docs/strategies.md)
        if p == 1:
            pm = wf
        else:
            di = _coded(t, t.intra_wire, wf)
            pm = jnp.mean(di.reshape(pods, p, -1), axis=1)
        # inter-pod ring on the pod means
        if pods == 1:
            mixed_pm = pm
        else:
            d2, new_main = _wire_stage(t, t.wire, pm,
                                       _flat_ef(ef_main, pods))
            mixed_pm = _combine(t, "ring", _needs_ef(t.wire), pm, d2,
                                step)
        out = jnp.broadcast_to(mixed_pm[:, None, :],
                               (pods, p, mixed_pm.shape[-1]))
        mixed = out.reshape(w.shape).astype(dtype)
    else:
        wf = w.astype(jnp.float32).reshape(L, -1)
        d, new_main = _wire_stage(t, t.wire, wf, _flat_ef(ef_main, L))
        mixed = _combine(t, t.topology, _needs_ef(t.wire), wf, d, step)
        mixed = mixed.reshape(w.shape).astype(dtype)

    rm, em = _shaped_ef(new_main, ef_main)
    return mixed, rm, em
