"""The paper's primary contribution: decentralized/asynchronous data-parallel
SGD strategies expressed in the mixing-matrix formalism of Eq. 14, over a
composable communication substrate (topology × wire × bucketing)."""
from repro.core.mixing import (  # noqa: F401
    get_mixer,
    hierarchical_matrix,
    is_doubly_stochastic,
    mix_hierarchical,
    mix_matrix,
    mix_ring,
    mix_uniform,
    ring_matrix,
    uniform_matrix,
)
from repro.core.strategies import (  # noqa: F401
    STRATEGIES,
    Strategy,
    average_learners,
    consensus_distance,
    default_transport,
    get_strategy,
    init_state,
    make_train_step,
    split_learner_batch,
    stack_for_learners,
    transport_from_cfg,
)
from repro.core.transport import (  # noqa: F401
    TOPOLOGIES,
    WIRES,
    Transport,
)
