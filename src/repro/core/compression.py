"""DEPRECATED compatibility surface for communication compression.

The quantizers that used to live here are now WIRE CODECS of the unified
communication substrate (``repro.core.transport``): what was the bespoke
``mix_ring_q8`` mixer is exactly ``Transport(topology='ring',
wire='int8')``, and the int8/topk codecs now compose with EVERY topology
(uniform allreduce, hierarchical pods, exponential graph) and every
strategy (sc/sd/ad_psgd, BMUF block sync, hring) instead of only the
ring.  See docs/strategies.md for the full strategy × topology × wire
matrix.

Kept here, still anchored in the paper's §IV-D survey of 1-bit SGD
[Seide'14] / QSGD [Alistarh'17] / sparsification [Aji'17]:

* ``quantize_int8``/``dequantize_int8`` — the per-tensor symmetric
  linear quantizer (the transport's int8 codec applies it per sender).
* ``mix_ring_q8`` — thin shim over the substrate, for existing callers.
* ``make_exp_mixer`` — re-exported from ``repro.core.mixing`` (it is
  pure topology, not compression).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.mixing import make_exp_mixer  # noqa: F401  (compat)


def quantize_int8(x):
    """x (any float) -> (int8 payload, f32 scale). Symmetric, per-tensor."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def mix_ring_q8(params):
    """DEPRECATED: ring (T_1) mixing with int8 neighbor payloads — now a
    shim over ``Transport(topology='ring', wire='int8')``, which applies
    per-sender scales (a strictly tighter error bound than the old shared
    per-tensor scale).  Each learner sends q8(w_l) to both ring neighbors;
    the local replica stays full precision."""
    from repro.core.transport import Transport

    leaves = jax.tree.leaves(params)
    L = leaves[0].shape[0] if leaves else 1
    mixed, _ = Transport(topology="ring", wire="int8").make_mixer(L)(
        params, jnp.int32(0), {})
    return mixed
