"""Communication compression for decentralized mixing (beyond-paper,
anchored in the paper's §IV-D survey of 1-bit SGD [Seide'14] / QSGD
[Alistarh'17] / sparsification [Aji'17]).

``quantize_int8`` is a per-tensor symmetric linear quantizer with an f32
scale; applied to the *neighbor payloads* of ring mixing it halves the
collective-permute wire bytes vs bf16 (4x vs the f32 baseline wire) at the
cost of <=1/254 relative rounding error per round.  Because mixing is a
CONTRACTION toward consensus, the quantization noise stays bounded (it is
re-averaged every round) — validated in tests/test_compression.py, and the
end-to-end convergence test shows no measurable loss-curve difference at
int8 on the toy problem.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x):
    """x (any float) -> (int8 payload, f32 scale). Symmetric, per-tensor."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def mix_ring_q8(params):
    """Ring (T_1) mixing with int8 neighbor payloads.

    Each learner sends q8(w_l) to both ring neighbors; the local replica
    stays full precision: w' = (w + deq(left) + deq(right)) / 3.
    The permute moves int8 + one f32 scalar — 2x less wire than bf16.
    """
    def one(w):
        L = w.shape[0]
        if L == 1:
            return w
        q, scale = quantize_int8(w)
        # scales are per-learner-tensor: roll them alongside the payload
        def neighbor(shift):
            qn = jnp.roll(q, shift, axis=0)
            return dequantize_int8(qn, scale)  # per-tensor scale shared

        wf = w.astype(jnp.float32)
        if L == 2:
            mixed = (2 * wf + neighbor(1)) / 3.0
        else:
            mixed = (wf + neighbor(1) + neighbor(-1)) / 3.0
        return mixed.astype(w.dtype)

    return jax.tree.map(one, params)


def make_exp_mixer(n_learners: int):
    """One-peer exponential-graph gossip [Assran'19/Ying'21]: at step k each
    learner averages with the peer 2^(k mod log2 L) hops away.

    For L = 2^m this reaches EXACT consensus every m rounds (hypercube
    gossip) — strictly faster mixing than the paper's T_1 ring at the same
    per-step wire cost (ONE permute instead of two).  Time-varying T_k are
    each doubly stochastic, so the Eq. 14 analysis still applies.
    """
    import numpy as np

    L = n_learners
    m = max(int(np.log2(L)), 1)
    assert 2 ** m == L or L == 1, "exponential graph wants power-of-2 learners"

    def mix(params, step):
        if L == 1:
            return params
        k = step % m

        def one(w):
            wf = w.astype(jnp.float32)
            branches = [
                (lambda shift: lambda ww=wf, s=shift:
                 (ww + jnp.roll(ww, s, axis=0)) / 2.0)(2 ** i)
                for i in range(m)
            ]
            return jax.lax.switch(k, branches).astype(w.dtype)

        return jax.tree.map(one, params)

    return mix
