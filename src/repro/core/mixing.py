"""Mixing matrices for decentralized parallel SGD (paper §IV-C, Eq. 14).

The paper models one decentralized update as

    W_{k+1} = W_k · T  −  α_k · g(Φ_k, ξ_k)

where the columns of ``W_k`` are per-learner model replicas and ``T`` is a
doubly-stochastic mixing matrix.  Two canonical choices from the paper:

* ``T_1`` (ring): each learner averages with its immediate left/right
  neighbors — 1/3 on the tridiagonal (wrap-around).  On the TPU mesh this
  lowers to a pair of ``collective-permute`` ops over the learner axis.
* ``T_u`` (uniform): global model averaging — the allreduce realization of
  a parameter server (paper Eq. 13).

``apply_mixing`` is the collective-form implementation used by the training
step (learner replicas stacked on a sharded leading axis); the explicit
matrix constructors exist for analysis and the hypothesis/property tests
(doubly-stochasticity, T^n → T_u consensus).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Explicit matrices (analysis / tests)
# ---------------------------------------------------------------------------

def ring_matrix(L: int) -> np.ndarray:
    """T_1: tridiagonal-with-wraparound, 1/3 each (paper's example)."""
    if L == 1:
        return np.ones((1, 1))
    if L == 2:
        # degenerate ring: self + the single neighbor (counted twice in the
        # tridiagonal pattern) -> [2/3, 1/3]
        return np.array([[2 / 3, 1 / 3], [1 / 3, 2 / 3]])
    T = np.zeros((L, L))
    for i in range(L):
        T[i, i] = 1 / 3
        T[i, (i - 1) % L] = 1 / 3
        T[i, (i + 1) % L] = 1 / 3
    return T


def uniform_matrix(L: int) -> np.ndarray:
    """T_u: global model averaging."""
    return np.full((L, L), 1.0 / L)


def identity_matrix(L: int) -> np.ndarray:
    return np.eye(L)


def is_doubly_stochastic(T: np.ndarray, atol: float = 1e-6) -> bool:
    return (
        bool(np.all(T >= -atol))
        and np.allclose(T.sum(0), 1.0, atol=atol)
        and np.allclose(T.sum(1), 1.0, atol=atol)
    )


# ---------------------------------------------------------------------------
# Collective-form application (training step)
# ---------------------------------------------------------------------------

def mix_ring(params):
    """(w[l-1] + w[l] + w[l+1]) / 3 along the stacked learner axis 0.

    ``jnp.roll`` along a mesh-sharded axis lowers to collective-permute —
    the decentralized communication pattern of SD/AD-PSGD, with cost
    independent of the learner count (paper §IV-C).
    """
    def one(w):
        if w.shape[0] == 1:
            return w
        # roll FIRST (collective-permute moves the native — usually bf16 —
        # payload; upcasting before the roll doubles wire bytes for free,
        # see EXPERIMENTS.md §Perf iter 3), then average in f32.  The
        # optimization_barrier stops XLA from commuting the convert back
        # across the permute.
        def roll_native(shift):
            return jax.lax.optimization_barrier(
                jnp.roll(w, shift, axis=0)).astype(jnp.float32)

        wf = w.astype(jnp.float32)
        if w.shape[0] == 2:
            mixed = (2 * wf + roll_native(1)) / 3.0
        else:
            mixed = (wf + roll_native(1) + roll_native(-1)) / 3.0
        return mixed.astype(w.dtype)

    return jax.tree.map(one, params)


def mix_uniform(params):
    """Global model averaging (T_u) — the allreduce PS realization."""
    def one(w):
        wf = w.astype(jnp.float32)
        return jnp.broadcast_to(
            jnp.mean(wf, axis=0, keepdims=True), wf.shape).astype(w.dtype)

    return jax.tree.map(one, params)


def mix_matrix(params, T):
    """General doubly-stochastic mixing (research/analysis path)."""
    Tj = jnp.asarray(T, jnp.float32)

    def one(w):
        wf = w.astype(jnp.float32)
        return jnp.einsum("l...,ml->m...", wf, Tj).astype(w.dtype)

    return jax.tree.map(one, params)


MIXERS = {
    "ring": mix_ring,
    "uniform": mix_uniform,
    "none": lambda p: p,
}


def get_mixer(kind: str, n_learners: int = 0):
    """Returns mixer(params, step) -> params.  'ring_q8' (int8 payloads)
    and 'exp' (one-peer exponential graph) are the beyond-paper mixers from
    repro.core.compression."""
    if kind == "ring_q8":
        from repro.core.compression import mix_ring_q8
        return lambda p, step=None: mix_ring_q8(p)
    if kind == "exp":
        from repro.core.compression import make_exp_mixer
        assert n_learners, "exp mixer needs the learner count"
        mixer = make_exp_mixer(n_learners)
        return lambda p, step=None: mixer(p, step)
    f = MIXERS[kind]
    return lambda p, step=None: f(p)
