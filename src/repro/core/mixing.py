"""Mixing matrices for decentralized parallel SGD (paper §IV-C, Eq. 14).

This module is PURE TOPOLOGY MATH: who averages with whom, always as a
doubly-stochastic matrix over the stacked learner axis.  Wire formats,
bucketing and error feedback live in ``repro.core.transport`` — every
mixer here is the exact-arithmetic (f32-wire) special case that the
substrate delegates to on its fast path.

The paper models one decentralized update as

    W_{k+1} = W_k · T  −  α_k · g(Φ_k, ξ_k)

where the columns of ``W_k`` are per-learner model replicas and ``T`` is a
doubly-stochastic mixing matrix.  Canonical choices:

* ``T_1`` (ring): each learner averages with its immediate left/right
  neighbors — 1/3 on the tridiagonal (wrap-around).  On the TPU mesh this
  lowers to a pair of ``collective-permute`` ops over the learner axis.
* ``T_u`` (uniform): global model averaging — the allreduce realization of
  a parameter server (paper Eq. 13).
* hierarchical (paper §V H-ring): T_u inside each pod of ``pod_size``
  learners, T_1 across pods — as a matrix, kron(T_1(L/p), T_u(p)).
* exponential graph [Assran'19]: time-varying one-peer gossip; for
  L = 2^m learners, exact consensus every m rounds.

The collective-form functions are used by the training step (learner
replicas stacked on a sharded leading axis); the explicit matrix
constructors exist for analysis and the hypothesis/property tests
(doubly-stochasticity, T^n → T_u consensus).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Explicit matrices (analysis / tests)
# ---------------------------------------------------------------------------

def ring_matrix(L: int) -> np.ndarray:
    """T_1: tridiagonal-with-wraparound, 1/3 each (paper's example)."""
    if L == 1:
        return np.ones((1, 1))
    if L == 2:
        # degenerate ring: self + the single neighbor (counted twice in the
        # tridiagonal pattern) -> [2/3, 1/3]
        return np.array([[2 / 3, 1 / 3], [1 / 3, 2 / 3]])
    T = np.zeros((L, L))
    for i in range(L):
        T[i, i] = 1 / 3
        T[i, (i - 1) % L] = 1 / 3
        T[i, (i + 1) % L] = 1 / 3
    return T


def uniform_matrix(L: int) -> np.ndarray:
    """T_u: global model averaging."""
    return np.full((L, L), 1.0 / L)


def identity_matrix(L: int) -> np.ndarray:
    return np.eye(L)


def hierarchical_matrix(L: int, pod_size: int) -> np.ndarray:
    """kron(T_1 over pods, T_u within pod): uniform averaging inside each
    pod of ``pod_size`` learners, ring mixing across the pod means (the
    paper's §V hierarchical-ring as one doubly-stochastic matrix)."""
    if L % pod_size:
        raise ValueError(f"pod_size {pod_size} must divide L={L}")
    return np.kron(ring_matrix(L // pod_size),
                   uniform_matrix(pod_size))


def is_doubly_stochastic(T: np.ndarray, atol: float = 1e-6) -> bool:
    return (
        bool(np.all(T >= -atol))
        and np.allclose(T.sum(0), 1.0, atol=atol)
        and np.allclose(T.sum(1), 1.0, atol=atol)
    )


# ---------------------------------------------------------------------------
# Collective-form application (training step)
# ---------------------------------------------------------------------------

def mix_ring(params):
    """(w[l-1] + w[l] + w[l+1]) / 3 along the stacked learner axis 0.

    ``jnp.roll`` along a mesh-sharded axis lowers to collective-permute —
    the decentralized communication pattern of SD/AD-PSGD, with cost
    independent of the learner count (paper §IV-C).
    """
    def one(w):
        if w.shape[0] == 1:
            return w
        # roll FIRST (collective-permute moves the native — usually bf16 —
        # payload; upcasting before the roll doubles wire bytes for free,
        # see EXPERIMENTS.md §Perf iter 3), then average in f32.  The
        # optimization_barrier stops XLA from commuting the convert back
        # across the permute.
        def roll_native(shift):
            return jax.lax.optimization_barrier(
                jnp.roll(w, shift, axis=0)).astype(jnp.float32)

        wf = w.astype(jnp.float32)
        if w.shape[0] == 2:
            mixed = (2 * wf + roll_native(1)) / 3.0
        else:
            mixed = (wf + roll_native(1) + roll_native(-1)) / 3.0
        return mixed.astype(w.dtype)

    return jax.tree.map(one, params)


def mix_uniform(params):
    """Global model averaging (T_u) — the allreduce PS realization."""
    def one(w):
        wf = w.astype(jnp.float32)
        return jnp.broadcast_to(
            jnp.mean(wf, axis=0, keepdims=True), wf.shape).astype(w.dtype)

    return jax.tree.map(one, params)


def mix_hierarchical(params, *, pod_size: int):
    """Collective form of :func:`hierarchical_matrix`: pod-mean, ring-mix
    the pod means, broadcast back to the pod's members."""
    def one(w):
        L = w.shape[0]
        if L % pod_size:
            raise ValueError(f"pod_size {pod_size} must divide L={L}")
        pods = L // pod_size
        if pod_size == 1:
            return mix_ring({"w": w})["w"]
        wf = w.astype(jnp.float32).reshape(pods, pod_size, -1)
        pm = jnp.mean(wf, axis=1)
        if pods == 1:
            mixed = pm
        elif pods == 2:
            mixed = (2.0 * pm + jnp.roll(pm, 1, axis=0)) / 3.0
        else:
            mixed = (pm + jnp.roll(pm, 1, axis=0)
                     + jnp.roll(pm, -1, axis=0)) / 3.0
        out = jnp.broadcast_to(mixed[:, None, :], wf.shape)
        return out.reshape(w.shape).astype(w.dtype)

    return jax.tree.map(one, params)


def make_exp_mixer(n_learners: int):
    """One-peer exponential-graph gossip [Assran'19/Ying'21]: at step k each
    learner averages with the peer 2^(k mod log2 L) hops away.

    For L = 2^m this reaches EXACT consensus every m rounds (hypercube
    gossip) — strictly faster mixing than the paper's T_1 ring at the same
    per-step wire cost (ONE permute instead of two).  Time-varying T_k are
    each doubly stochastic, so the Eq. 14 analysis still applies.
    """
    L = n_learners
    m = max(int(np.log2(L)), 1)
    assert 2 ** m == L or L == 1, "exponential graph wants power-of-2 learners"

    def mix(params, step):
        if L == 1:
            return params
        k = step % m

        def one(w):
            wf = w.astype(jnp.float32)
            branches = [
                (lambda shift: lambda ww=wf, s=shift:
                 (ww + jnp.roll(ww, s, axis=0)) / 2.0)(2 ** i)
                for i in range(m)
            ]
            return jax.lax.switch(k, branches).astype(w.dtype)

        return jax.tree.map(one, params)

    return mix


def mix_matrix(params, T):
    """General doubly-stochastic mixing (research/analysis path)."""
    Tj = jnp.asarray(T, jnp.float32)

    def one(w):
        wf = w.astype(jnp.float32)
        return jnp.einsum("l...,ml->m...", wf, Tj).astype(w.dtype)

    return jax.tree.map(one, params)


MIXERS = {
    "ring": mix_ring,
    "uniform": mix_uniform,
    "none": lambda p: p,
}


def get_mixer(kind: str, n_learners: int = 0):
    """DEPRECATED shim (kept for analysis scripts/tests): returns
    mixer(params, step) -> params.  New code should build a
    ``repro.core.transport.Transport`` instead — 'ring_q8' is
    Transport(topology='ring', wire='int8') and 'exp' is
    Transport(topology='exp')."""
    if kind == "ring_q8":
        from repro.core.compression import mix_ring_q8
        return lambda p, step=None: mix_ring_q8(p)
    if kind == "exp":
        assert n_learners, "exp mixer needs the learner count"
        mixer = make_exp_mixer(n_learners)
        return lambda p, step=None: mixer(p, step)
    f = MIXERS[kind]
    return lambda p, step=None: f(p)
