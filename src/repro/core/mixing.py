"""Mixing matrices for decentralized parallel SGD (paper §IV-C, Eq. 14).

This module is PURE TOPOLOGY MATH: who averages with whom, always as a
doubly-stochastic matrix over the stacked learner axis.  Wire formats,
bucketing and error feedback live in ``repro.core.transport`` — every
mixer here is the exact-arithmetic (f32-wire) special case that the
substrate delegates to on its fast path.

The paper models one decentralized update as

    W_{k+1} = W_k · T  −  α_k · g(Φ_k, ξ_k)

where the columns of ``W_k`` are per-learner model replicas and ``T`` is a
doubly-stochastic mixing matrix.  Canonical choices:

* ``T_1`` (ring): each learner averages with its immediate left/right
  neighbors — 1/3 on the tridiagonal (wrap-around).  On the TPU mesh this
  lowers to a pair of ``collective-permute`` ops over the learner axis.
* ``T_u`` (uniform): global model averaging — the allreduce realization of
  a parameter server (paper Eq. 13).
* hierarchical (paper §V H-ring): T_u inside each pod of ``pod_size``
  learners, T_1 across pods — as a matrix, kron(T_1(L/p), T_u(p)).
* exponential graph [Assran'19]: time-varying one-peer gossip; for
  L = 2^m learners, exact consensus every m rounds.

The collective-form functions are used by the training step (learner
replicas stacked on a sharded leading axis); the explicit matrix
constructors exist for analysis and the hypothesis/property tests
(doubly-stochasticity, T^n → T_u consensus).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Explicit matrices (analysis / tests)
# ---------------------------------------------------------------------------

def ring_matrix(L: int) -> np.ndarray:
    """T_1: tridiagonal-with-wraparound, 1/3 each (paper's example)."""
    if L == 1:
        return np.ones((1, 1))
    if L == 2:
        # degenerate ring: self + the single neighbor (counted twice in the
        # tridiagonal pattern) -> [2/3, 1/3]
        return np.array([[2 / 3, 1 / 3], [1 / 3, 2 / 3]])
    T = np.zeros((L, L))
    for i in range(L):
        T[i, i] = 1 / 3
        T[i, (i - 1) % L] = 1 / 3
        T[i, (i + 1) % L] = 1 / 3
    return T


def uniform_matrix(L: int) -> np.ndarray:
    """T_u: global model averaging."""
    return np.full((L, L), 1.0 / L)


def identity_matrix(L: int) -> np.ndarray:
    return np.eye(L)


def hierarchical_matrix(L: int, pod_size: int) -> np.ndarray:
    """kron(T_1 over pods, T_u within pod): uniform averaging inside each
    pod of ``pod_size`` learners, ring mixing across the pod means (the
    paper's §V hierarchical-ring as one doubly-stochastic matrix)."""
    if L % pod_size:
        raise ValueError(f"pod_size {pod_size} must divide L={L}")
    return np.kron(ring_matrix(L // pod_size),
                   uniform_matrix(pod_size))


def is_doubly_stochastic(T: np.ndarray, atol: float = 1e-6) -> bool:
    return (
        bool(np.all(T >= -atol))
        and np.allclose(T.sum(0), 1.0, atol=atol)
        and np.allclose(T.sum(1), 1.0, atol=atol)
    )


# ---------------------------------------------------------------------------
# Collective-form application (training step)
# ---------------------------------------------------------------------------

def mix_ring(params):
    """(w[l-1] + w[l] + w[l+1]) / 3 along the stacked learner axis 0.

    ``jnp.roll`` along a mesh-sharded axis lowers to collective-permute —
    the decentralized communication pattern of SD/AD-PSGD, with cost
    independent of the learner count (paper §IV-C).
    """
    def one(w):
        if w.shape[0] == 1:
            return w
        # roll FIRST (collective-permute moves the native — usually bf16 —
        # payload; upcasting before the roll doubles wire bytes for free,
        # see EXPERIMENTS.md §Perf iter 3), then average in f32.  The
        # optimization_barrier stops XLA from commuting the convert back
        # across the permute.
        def roll_native(shift):
            return jax.lax.optimization_barrier(
                jnp.roll(w, shift, axis=0)).astype(jnp.float32)

        wf = w.astype(jnp.float32)
        if w.shape[0] == 2:
            mixed = (2 * wf + roll_native(1)) / 3.0
        else:
            mixed = (wf + roll_native(1) + roll_native(-1)) / 3.0
        return mixed.astype(w.dtype)

    return jax.tree.map(one, params)


def mix_uniform(params):
    """Global model averaging (T_u) — the allreduce PS realization."""
    def one(w):
        wf = w.astype(jnp.float32)
        return jnp.broadcast_to(
            jnp.mean(wf, axis=0, keepdims=True), wf.shape).astype(w.dtype)

    return jax.tree.map(one, params)


def mix_hierarchical(params, *, pod_size: int):
    """Collective form of :func:`hierarchical_matrix`: pod-mean, ring-mix
    the pod means, broadcast back to the pod's members."""
    def one(w):
        L = w.shape[0]
        if L % pod_size:
            raise ValueError(f"pod_size {pod_size} must divide L={L}")
        pods = L // pod_size
        if pod_size == 1:
            return mix_ring({"w": w})["w"]
        wf = w.astype(jnp.float32).reshape(pods, pod_size, -1)
        pm = jnp.mean(wf, axis=1)
        if pods == 1:
            mixed = pm
        elif pods == 2:
            mixed = (2.0 * pm + jnp.roll(pm, 1, axis=0)) / 3.0
        else:
            mixed = (pm + jnp.roll(pm, 1, axis=0)
                     + jnp.roll(pm, -1, axis=0)) / 3.0
        out = jnp.broadcast_to(mixed[:, None, :], wf.shape)
        return out.reshape(w.shape).astype(w.dtype)

    return jax.tree.map(one, params)


def make_exp_mixer(n_learners: int):
    """One-peer exponential-graph gossip [Assran'19/Ying'21]: at step k each
    learner averages with the peer 2^(k mod log2 L) hops away.

    For L = 2^m this reaches EXACT consensus every m rounds (hypercube
    gossip) — strictly faster mixing than the paper's T_1 ring at the same
    per-step wire cost (ONE permute instead of two).  Time-varying T_k are
    each doubly stochastic, so the Eq. 14 analysis still applies.
    """
    L = n_learners
    m = max(int(np.log2(L)), 1)
    assert 2 ** m == L or L == 1, "exponential graph wants power-of-2 learners"

    def mix(params, step):
        if L == 1:
            return params
        k = step % m

        def one(w):
            wf = w.astype(jnp.float32)
            branches = [
                (lambda shift: lambda ww=wf, s=shift:
                 (ww + jnp.roll(ww, s, axis=0)) / 2.0)(2 ** i)
                for i in range(m)
            ]
            return jax.lax.switch(k, branches).astype(w.dtype)

        return jax.tree.map(one, params)

    return mix


# ---------------------------------------------------------------------------
# Elastic matrices: the same topologies over a live subset of learners
# ---------------------------------------------------------------------------
#
# Under elastic membership (learners crash, rejoin, straggle — see
# ``repro.core.faults`` and docs/fault_tolerance.md) the mixing matrix is
# rebuilt every step for the ACTIVE set: dead learners become identity
# rows (their replica is frozen bit-for-bit until they rejoin) and the
# survivors re-form the topology among themselves by consecutive rank.
# Everything below is jnp on a traced (L,) activity mask, so the jitted
# elastic train step compiles ONCE for the whole run regardless of the
# fault schedule.
#
# All constructors return symmetric doubly-stochastic matrices (the
# hierarchical one to a documented tolerance under ragged pod survivor
# counts), so the Eq. 14 analysis — and exact consensus-mean
# preservation — carries over unchanged.


def _elastic_hop_matrix(active, hop, *, exp_weights: bool = False):
    """Gossip-at-hop-``hop`` over the active learners, by consecutive
    rank: active learner of rank i exchanges with ranks i±hop (mod the
    live count).  ``exp_weights=False`` gives ring thirds (matches
    :func:`ring_matrix` exactly for every live count, including the
    L=2 [2/3, 1/3] degenerate case); ``exp_weights=True`` gives the
    one-peer exponential-graph weights (1/2 self, 1/4 each direction,
    collapsing to exact pairwise averaging when hop = n/2)."""
    a = jnp.asarray(active, jnp.float32)
    L = a.shape[0]
    n = jnp.maximum(jnp.sum(a), 1.0)
    rank = jnp.cumsum(a) - 1.0
    d = jnp.mod(rank[:, None] - rank[None, :], n)
    hop = jnp.asarray(hop, jnp.float32)
    hit_f = (d == jnp.mod(hop, n)).astype(jnp.float32)
    hit_b = (d == jnp.mod(n - hop, n)).astype(jnp.float32)
    pair = a[:, None] * a[None, :] * (1.0 - jnp.eye(L))
    if exp_weights:
        off = pair * 0.25 * (hit_f + hit_b)
    else:
        off = pair * (1.0 / 3.0) * jnp.maximum(hit_f, hit_b)
    diag = a * (1.0 - jnp.sum(off, axis=1)) + (1.0 - a)
    return off + jnp.diag(diag)


def elastic_ring_matrix(active):
    """T_1 over the live set: ring thirds among survivors by consecutive
    rank, identity for the dead.  All-active reproduces
    :func:`ring_matrix` exactly."""
    return _elastic_hop_matrix(active, 1.0)


def elastic_exp_matrix(active, step):
    """Time-varying exponential-graph gossip over the live set: at step k
    each survivor exchanges at hop 2^(k mod ceil(log2 n)).  Symmetrized
    (both directions at 1/4) so staleness damping and edge drops keep it
    doubly stochastic; a power-of-2 live count still reaches exact
    consensus every log2(n) rounds (each round with hop n/2 is exact
    pairwise averaging)."""
    a = jnp.asarray(active, jnp.float32)
    n = jnp.maximum(jnp.sum(a), 1.0)
    m = jnp.maximum(jnp.ceil(jnp.log2(n)), 1.0)
    hop = jnp.round(2.0 ** jnp.mod(jnp.asarray(step, jnp.float32), m))
    return _elastic_hop_matrix(active, hop, exp_weights=True)


def elastic_uniform_matrix(active):
    """T_u over the live set: global averaging among survivors, identity
    for the dead."""
    a = jnp.asarray(active, jnp.float32)
    n = jnp.maximum(jnp.sum(a), 1.0)
    return a[:, None] * a[None, :] / n + jnp.diag(1.0 - a)


def elastic_hierarchical_matrix(active, pod_size: int, *, sinkhorn: int = 30):
    """Hierarchical mixing over the live set: uniform averaging among
    each pod's survivors, ring mixing across pods that still have any,
    identity for the dead (and for fully-dead pods).

    With ragged survivor counts the raw intra∘inter composition is only
    row-stochastic (a small pod's members weigh more in the pod mean than
    a large pod's), so the matrix is symmetrized and re-balanced with a
    few symmetric Sinkhorn sweeps — doubly stochastic to ~1e-6 in
    practice, and EXACTLY kron(ring, uniform) when every pod has the
    same survivor count (in particular the all-active case)."""
    a = jnp.asarray(active, jnp.float32)
    L = a.shape[0]
    if L % pod_size:
        raise ValueError(f"pod_size {pod_size} must divide L={L}")
    pods = L // pod_size
    ap = a.reshape(pods, pod_size)
    pod_n = jnp.sum(ap, axis=1)                      # survivors per pod
    pod_alive = (pod_n > 0).astype(jnp.float32)
    Tp = _elastic_hop_matrix(pod_alive, 1.0)         # ring over live pods
    # lift to learners: i in pod P, j in pod Q gets Tp[P,Q] * a_j/n_Q
    share = a / jnp.maximum(jnp.repeat(pod_n, pod_size), 1.0)
    lift = jnp.repeat(jnp.repeat(Tp, pod_size, 0), pod_size, 1)
    R = a[:, None] * lift * share[None, :] \
        + jnp.diag(1.0 - a)
    S = 0.5 * (R + R.T)
    for _ in range(sinkhorn):
        s = jnp.sum(S, axis=1)
        inv = jax.lax.rsqrt(jnp.maximum(s, 1e-12))
        S = S * inv[:, None] * inv[None, :]
    return S


def staleness_damped(T, staleness, lam):
    """Down-weight stale learners' cross influence: with per-learner
    staleness s (steps since the learner last contributed a gradient)
    and damping λ, each learner gets confidence c_i = 1/(1 + λ·s_i) and
    the off-diagonal becomes T_ij·c_i·c_j, the freed mass returning to
    the diagonal.  Symmetric elementwise rescaling of a symmetric T
    keeps it doubly stochastic — a fresh learner neither absorbs a stale
    peer's lagged params nor leaks weight through it, while λ = 0 (or a
    fully-fresh cluster) is the identity transform."""
    T = jnp.asarray(T, jnp.float32)
    c = 1.0 / (1.0 + lam * jnp.asarray(staleness, jnp.float32))
    off = T * c[:, None] * c[None, :]
    off = off - jnp.diag(jnp.diag(off))
    diag = 1.0 - jnp.sum(off, axis=1)
    return off + jnp.diag(diag)


def edge_masked(T, edge_ok):
    """Drop gossip edges: zero the masked off-diagonal entries (the mask
    is symmetric — an undirected link either delivers or doesn't) and
    return the freed mass to the diagonal, preserving double
    stochasticity.  Both endpoints of a dropped edge fall back toward
    themselves, exactly like a timed-out peer exchange."""
    T = jnp.asarray(T, jnp.float32)
    off = T * jnp.asarray(edge_ok, jnp.float32)
    off = off - jnp.diag(jnp.diag(off))
    diag = 1.0 - jnp.sum(off, axis=1)
    return off + jnp.diag(diag)


def elastic_matrix(active, topology: str, *, step=0, pod_size: int = 1,
                   staleness=None, staleness_lambda: float = 0.0,
                   edge_ok=None):
    """One elastic mixing matrix: ``topology`` over the live set, then
    dropped-edge masking, then staleness damping (docs/fault_tolerance.md
    has the full semantics).  ``active``/``staleness``/``edge_ok``/
    ``step`` may all be traced — the result is jit-stable."""
    if topology == "none":
        T = jnp.eye(jnp.asarray(active).shape[0], dtype=jnp.float32)
    elif topology == "ring":
        T = elastic_ring_matrix(active)
    elif topology == "uniform":
        T = elastic_uniform_matrix(active)
    elif topology == "exp":
        T = elastic_exp_matrix(active, step)
    elif topology == "hierarchical":
        T = elastic_hierarchical_matrix(active, pod_size)
    else:
        raise ValueError(f"unknown topology {topology!r} for elastic "
                         f"mixing")
    if edge_ok is not None:
        T = edge_masked(T, edge_ok)
    if staleness is not None and staleness_lambda > 0.0:
        T = staleness_damped(T, staleness, staleness_lambda)
    return T


def mix_matrix(params, T):
    """General doubly-stochastic mixing (research/analysis path)."""
    Tj = jnp.asarray(T, jnp.float32)

    def one(w):
        wf = w.astype(jnp.float32)
        return jnp.einsum("l...,ml->m...", wf, Tj).astype(w.dtype)

    return jax.tree.map(one, params)


MIXERS = {
    "ring": mix_ring,
    "uniform": mix_uniform,
    "none": lambda p: p,
}


def get_mixer(kind: str, n_learners: int = 0):
    """DEPRECATED shim (kept for analysis scripts/tests): returns
    mixer(params, step) -> params.  New code should build a
    ``repro.core.transport.Transport`` instead — 'ring_q8' is
    Transport(topology='ring', wire='int8') and 'exp' is
    Transport(topology='exp')."""
    if kind == "ring_q8":
        from repro.core.compression import mix_ring_q8
        return lambda p, step=None: mix_ring_q8(p)
    if kind == "exp":
        assert n_learners, "exp mixer needs the learner count"
        mixer = make_exp_mixer(n_learners)
        return lambda p, step=None: mixer(p, step)
    f = MIXERS[kind]
    return lambda p, step=None: f(p)
