"""repro.obs — the unified observability layer (docs/observability.md).

One process-wide pair of sinks that every surface emits through:

* a :class:`~repro.obs.metrics.MetricsRegistry` of tagged counters /
  gauges / histograms (per-step training scalars, serving service
  times, kernel VMEM accounting, bytes on wire), and
* a :class:`~repro.obs.trace.FlightRecorder` — a bounded ring of
  schema events (spans, instants, metric snapshots) exportable as
  JSONL and as Chrome ``trace_event`` JSON.

The default is the **no-op pair**: until :func:`configure` is called
(the launchers call it when ``--trace-out`` is passed) every
instrument and span is a shared do-nothing object, so uninstrumented
runs pay one method call per site and stay bit-identical — the
property the recovery / transport-golden / paged≡dense exactness
tests rely on (gated by ``benchmarks/run.py --only obs`` at ≤ 3%
step overhead).

Module-level helpers (:func:`event`, :func:`span`, :func:`metric_*`)
always dispatch through the *current* sinks, so call sites never cache
a stale registry across :func:`configure`/:func:`reset`.

This module is also the single source of the ``name,value,derived``
stats CSV schema (:func:`csv_row` / :func:`print_csv_rows`), formerly
in ``repro.serving.slo`` (which keeps deprecation shims).
"""
from __future__ import annotations

import json

from .metrics import (  # noqa: F401  (re-exports)
    MAX_SAMPLES,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    NOOP,
    NULL_METRICS,
    NullRegistry,
    nearest_rank,
)
from .trace import (  # noqa: F401
    DEFAULT_MAXLEN,
    FlightRecorder,
    KINDS,
    NULL_RECORDER,
    NullRecorder,
    chrome_trace,
    read_jsonl,
    validate_events,
    write_jsonl,
)
from .profile import (  # noqa: F401
    ProfiledFn,
    fit_cost_model,
    profiled,
)

# ---------------------------------------------------------------------------
# process-global sinks (no-op until configure())
# ---------------------------------------------------------------------------

_metrics: MetricsRegistry = NULL_METRICS
_recorder: FlightRecorder = NULL_RECORDER


def configure(maxlen: int = DEFAULT_MAXLEN):
    """Turn observability on: install a live registry + recorder pair
    (replacing the no-op defaults) and return ``(metrics, recorder)``."""
    global _metrics, _recorder
    _metrics = MetricsRegistry()
    _recorder = FlightRecorder(maxlen=maxlen)
    return _metrics, _recorder


def reset() -> None:
    """Back to the zero-overhead no-op defaults (tests; end of a run)."""
    global _metrics, _recorder
    _metrics = NULL_METRICS
    _recorder = NULL_RECORDER


def enabled() -> bool:
    return _metrics is not NULL_METRICS


def get_metrics() -> MetricsRegistry:
    return _metrics


def get_recorder() -> FlightRecorder:
    return _recorder


# thin always-current dispatchers (never cache the sink at a call site)

def event(name: str, **attrs) -> None:
    _recorder.event(name, **attrs)


def span(name: str, **attrs):
    return _recorder.span(name, **attrs)


def add_span(name: str, t0: float, dur: float, **attrs) -> None:
    _recorder.add_span(name, t0, dur, **attrs)


def counter(name: str, **tags):
    return _metrics.counter(name, **tags)


def gauge(name: str, **tags):
    return _metrics.gauge(name, **tags)


def histogram(name: str, wall: bool = False, **tags):
    return _metrics.histogram(name, wall=wall, **tags)


def flush_metrics() -> int:
    """Append the registry snapshot to the flight recorder as
    ``metric`` events (deterministic order); returns records written."""
    recs = _metrics.snapshot()
    for rec in recs:
        _recorder.metric(rec)
    return len(recs)


def dump(path: str, deterministic: bool = False,
         chrome: str = None) -> int:
    """Flush the metrics snapshot and write the recorder to ``path`` as
    JSONL (optionally also ``chrome`` as trace_event JSON); returns
    JSONL lines written.  No-op (returns 0) while disabled."""
    if not enabled():
        return 0
    flush_metrics()
    events_ = _recorder.events
    n = write_jsonl(events_, path, deterministic=deterministic)
    if chrome:
        with open(chrome, "w", encoding="utf-8") as f:
            json.dump(chrome_trace(events_), f)
    return n


# ---------------------------------------------------------------------------
# the shared ``name,value,derived`` stats CSV schema
# (moved here from repro.serving.slo — single formatting source)
# ---------------------------------------------------------------------------

CSV_HEADER = "name,value,derived"


def csv_row(name, value, derived="") -> str:
    """One row of the shared stats schema (evaluate/benchmarks/load)."""
    try:
        value = f"{float(value):.6g}"
    except (TypeError, ValueError):
        value = str(value)
    return f"{name},{value},{derived}"


def print_csv_rows(rows, header: bool = False) -> None:
    """Print ``(name, value, derived)`` rows in the shared schema."""
    if header:
        print(CSV_HEADER)
    for name, value, derived in rows:
        print(csv_row(name, value, derived), flush=True)
