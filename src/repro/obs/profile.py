"""Profiling hooks: compile-vs-steady wall-time wrappers for jitted
entry points, and the CostModel calibration fit fed by them.

``jax.jit`` hides a bimodal cost: the first call per input shape traces
and compiles (seconds), every later call just dispatches (micro- to
milliseconds).  A single ``N steps in Xs`` line therefore conflates two
regimes the paper's §IV cost accounting keeps separate.
:class:`ProfiledFn` wraps a jitted callable, blocks on the result
(``jax.block_until_ready``) and classifies each call:

* **compile** — first call for a given *shape key* (by default the
  shapes/dtypes of array arguments; bucketed batching thus counts one
  compile per bucket, matching XLA's retrace behaviour),
* **steady** — every subsequent call with a known key.

Timings land in the process metrics registry as ``wall=True``
histograms tagged ``fn=<name> phase=compile|steady`` and, optionally,
as flight-recorder spans — so ``launch/obsreport.py`` renders the
split and the deterministic JSONL export can drop them.

:func:`fit_cost_model` closes the ROADMAP loop "calibrate CostModel
from ``--wall`` runs": a least-squares line through measured
(work, wave seconds) pairs gives ``per_work_s``/``wave_base_s``, and
mean admit time gives ``admit_s`` — printable as CSV and pastable back
into ``launch/load.py`` flags.
"""
from __future__ import annotations

import time

from .metrics import NULL_METRICS
from .trace import NULL_RECORDER

try:  # array-result blocking; obs must import without jax (obsreport)
    import jax

    def _block(x):
        return jax.block_until_ready(x)
except Exception:  # pragma: no cover - exercised only without jax
    def _block(x):
        return x


def _shape_key(args, kwargs):
    """Default shape key: the (shape, dtype) of every array-like
    argument — a new batch shape means XLA retraces, so the call is a
    compile."""
    parts = []
    for a in list(args) + [kwargs[k] for k in sorted(kwargs)]:
        shape = getattr(a, "shape", None)
        if shape is not None:
            parts.append((tuple(shape), str(getattr(a, "dtype", ""))))
    return tuple(parts)


class ProfiledFn:
    """Wall-time wrapper separating first-call (compile) from
    steady-state time per jitted entry point.

    >>> step = ProfiledFn(jitted_step, "train/step")
    >>> out = step(state, batch)        # blocked; timed as compile
    >>> out = step(state, batch)        # timed as steady
    >>> step.compile_s, step.steady_s, step.n_compiles
    """

    def __init__(self, fn, name: str, *, metrics=None, recorder=None,
                 key=None, block=True):
        self.fn = fn
        self.name = name
        self.metrics = NULL_METRICS if metrics is None else metrics
        self.recorder = NULL_RECORDER if recorder is None else recorder
        self._key = _shape_key if key is None else key
        self._block = block
        self._seen: set = set()
        self.n_calls = 0
        self.n_compiles = 0
        self.compile_s = 0.0
        self.steady_s = 0.0

    def __call__(self, *args, **kwargs):
        k = self._key(args, kwargs)
        compile_call = k not in self._seen
        t0 = time.perf_counter()
        out = self.fn(*args, **kwargs)
        if self._block:
            out = _block(out)
        dt = time.perf_counter() - t0
        self._seen.add(k)
        self.n_calls += 1
        phase = "compile" if compile_call else "steady"
        if compile_call:
            self.n_compiles += 1
            self.compile_s += dt
        else:
            self.steady_s += dt
        self.metrics.histogram("profile/call_s", wall=True,
                               fn=self.name, phase=phase).observe(dt)
        self.recorder.add_span(self.name, t0, dt, phase=phase, wall=True)
        return out

    @property
    def steady_mean_s(self) -> float:
        n = self.n_calls - self.n_compiles
        return self.steady_s / n if n else float("nan")

    def summary(self) -> dict:
        return {"fn": self.name, "n_calls": self.n_calls,
                "n_compiles": self.n_compiles,
                "compile_s": self.compile_s, "steady_s": self.steady_s,
                "steady_mean_s": self.steady_mean_s}


def profiled(fn, name: str, **kw) -> ProfiledFn:
    """Wrap ``fn`` unless it already is a :class:`ProfiledFn`."""
    if isinstance(fn, ProfiledFn):
        return fn
    return ProfiledFn(fn, name, **kw)


# ---------------------------------------------------------------------------
# CostModel calibration from measured service times
# ---------------------------------------------------------------------------

def fit_cost_model(wave_obs, admit_obs=()) -> dict:
    """Least-squares CostModel parameters from ``--wall`` measurements.

    ``wave_obs`` — iterable of ``(work, seconds)`` pairs, one per
    measured ``step_wave`` (work = active decode slots, the CostModel's
    unit); ``admit_obs`` — measured per-admission seconds.  Returns a
    plain dict (NOT a CostModel — keeps obs import-free of serving)::

        {"wave_base_s", "per_work_s", "admit_s", "n_waves", "resid_s"}

    With a single distinct work level the slope is unidentifiable; we
    pin ``per_work_s = 0`` and fit the intercept alone.
    """
    pairs = [(float(w), float(s)) for w, s in wave_obs]
    n = len(pairs)
    if n == 0:
        return {"wave_base_s": float("nan"), "per_work_s": float("nan"),
                "admit_s": _mean(admit_obs), "n_waves": 0,
                "resid_s": float("nan")}
    xs = [w for w, _ in pairs]
    ys = [s for _, s in pairs]
    mx = sum(xs) / n
    my = sum(ys) / n
    sxx = sum((x - mx) ** 2 for x in xs)
    if sxx > 0.0:
        slope = sum((x - mx) * (y - my) for x, y in pairs) / sxx
        slope = max(slope, 0.0)  # negative per-work cost is noise
    else:
        slope = 0.0
    base = max(my - slope * mx, 0.0)
    resid = (sum((y - (base + slope * x)) ** 2
                 for x, y in pairs) / n) ** 0.5
    return {"wave_base_s": base, "per_work_s": slope,
            "admit_s": _mean(admit_obs), "n_waves": n, "resid_s": resid}


def _mean(vals) -> float:
    vals = [float(v) for v in vals]
    return sum(vals) / len(vals) if vals else float("nan")
