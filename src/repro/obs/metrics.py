"""Metrics registry: counters, gauges and histograms with tag support.

One process-wide registry (held by ``repro.obs``) collects every
per-step scalar the launchers used to print ad-hoc — train loss /
wire bytes / pad efficiency / fault telemetry, serving service times,
kernel VMEM accounting — so one snapshot carries the whole run
(docs/observability.md).

Design contract:

* **Zero-overhead no-op default** — until ``repro.obs.configure()`` is
  called, every instrument handed out is the shared :data:`NOOP`
  object whose methods do nothing; uninstrumented runs stay
  bit-identical and pay only a method-call per site.
* **Deterministic snapshot order** — :meth:`MetricsRegistry.snapshot`
  sorts by ``(name, sorted(tags))`` regardless of registration order,
  so two runs that record the same values emit byte-identical
  snapshots (property-tested in tests/test_obs.py).
* **Wall marking** — instruments created with ``wall=True`` hold
  wall-clock measurements (service times, step durations); the
  deterministic JSONL export (``repro.obs.trace.write_jsonl``) drops
  them so seeded runs stay bit-equal across re-runs.
"""
from __future__ import annotations

import math

# histogram sample reservoir cap: enough for percentile fidelity on
# smoke-scale runs without unbounded memory on long ones
MAX_SAMPLES = 4096

_QS = (50, 95, 99)


def nearest_rank(values, q: float) -> float:
    """Nearest-rank percentile (the repo-wide convention of
    repro.serving.slo): element ``ceil(q/100 * n) - 1`` of the sorted
    sample; NaN on an empty one."""
    vals = sorted(values)
    if not vals:
        return float("nan")
    rank = max(int(math.ceil(q / 100.0 * len(vals))), 1)
    return vals[min(rank, len(vals)) - 1]


class Counter:
    """Monotone accumulator (bytes on wire, tokens decoded, ...)."""

    __slots__ = ("value",)
    kind = "counter"

    def __init__(self):
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        self.value += float(v)

    def fields(self) -> dict:
        return {"value": self.value}


class Gauge:
    """Last-written value (occupancy, VMEM accounting, pad efficiency)."""

    __slots__ = ("value",)
    kind = "gauge"

    def __init__(self):
        self.value = float("nan")

    def set(self, v: float) -> None:
        self.value = float(v)

    def fields(self) -> dict:
        return {"value": self.value}


class Histogram:
    """Streaming distribution: count/total/min/max plus a bounded
    sample reservoir (first :data:`MAX_SAMPLES` observations) for
    nearest-rank percentiles and the CostModel least-squares fit."""

    __slots__ = ("count", "total", "min", "max", "samples", "wall")
    kind = "histogram"

    def __init__(self, wall: bool = False):
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf
        self.samples: list = []
        self.wall = wall

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        if len(self.samples) < MAX_SAMPLES:
            self.samples.append(v)

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else float("nan")

    def fields(self) -> dict:
        out = {"count": self.count, "total": self.total, "mean": self.mean,
               "min": self.min if self.count else float("nan"),
               "max": self.max if self.count else float("nan")}
        for q in _QS:
            out[f"p{q}"] = nearest_rank(self.samples, q)
        return out


class _Noop:
    """The shared do-nothing instrument of the disabled registry: every
    method of every instrument kind, as a pass."""

    __slots__ = ()
    kind = "noop"
    value = 0.0
    count = 0
    total = 0.0
    mean = float("nan")
    samples: tuple = ()
    wall = False

    def inc(self, v: float = 1.0) -> None:
        pass

    def set(self, v: float) -> None:
        pass

    def observe(self, v: float) -> None:
        pass

    def fields(self) -> dict:
        return {}


NOOP = _Noop()


def _key(name: str, tags: dict):
    return (name, tuple(sorted(tags.items())))


class MetricsRegistry:
    """Tagged instrument registry with deterministic snapshots.

    ``counter/gauge/histogram(name, **tags)`` get-or-create the
    instrument for ``(name, tags)``; asking for an existing name with a
    different kind is a :class:`TypeError` (one name, one meaning)."""

    def __init__(self):
        self._items: dict = {}

    def _get(self, cls, name: str, tags: dict, **kw):
        key = _key(name, tags)
        inst = self._items.get(key)
        if inst is None:
            inst = self._items[key] = cls(**kw)
        elif not isinstance(inst, cls):
            raise TypeError(
                f"metric {name!r} {dict(tags)} already registered as "
                f"{inst.kind}, not {cls.kind}")
        return inst

    def counter(self, name: str, **tags) -> Counter:
        return self._get(Counter, name, tags)

    def gauge(self, name: str, **tags) -> Gauge:
        return self._get(Gauge, name, tags)

    def histogram(self, name: str, wall: bool = False, **tags) -> Histogram:
        h = self._get(Histogram, name, tags, wall=wall)
        if wall:
            h.wall = True
        return h

    def __len__(self) -> int:
        return len(self._items)

    def snapshot(self) -> list:
        """Deterministically-ordered list of metric records:
        ``{"name", "tags", "kind", "wall", **fields}`` sorted by
        ``(name, sorted(tags))`` — independent of registration order."""
        out = []
        for key in sorted(self._items):
            name, tags = key
            inst = self._items[key]
            rec = {"name": name, "tags": dict(tags), "kind": inst.kind,
                   "wall": bool(getattr(inst, "wall", False))}
            rec.update(inst.fields())
            out.append(rec)
        return out


class NullRegistry(MetricsRegistry):
    """The disabled default: hands out :data:`NOOP` for everything and
    snapshots empty — instrumentation sites cost one no-op call."""

    def counter(self, name: str, **tags):
        return NOOP

    def gauge(self, name: str, **tags):
        return NOOP

    def histogram(self, name: str, wall: bool = False, **tags):
        return NOOP

    def snapshot(self) -> list:
        return []


NULL_METRICS = NullRegistry()
