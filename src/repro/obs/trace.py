"""Span tracing, the JSONL flight recorder, and the Chrome trace
exporter (docs/observability.md §Event schema).

The :class:`FlightRecorder` is a bounded ring buffer of event dicts —
one shared schema for spans (timed regions), instant events (the
structured per-request / per-step records every launcher used to print
ad-hoc) and metric snapshots:

    {"seq": int, "kind": "span" | "event" | "metric", "name": str,
     "ts": float s, "dur": float s (spans), "id"/"parent": int (spans),
     "attrs": {str: scalar}, ...}

* ``seq`` is a per-recorder monotone id assigned at *entry* — it is a
  pure function of the call sequence, so seeded runs produce identical
  seqs (the run-twice bit-equality gate).
* ``ts``/``dur`` are wall-clock (``time.perf_counter``) and the ONLY
  nondeterministic fields; :func:`write_jsonl` with
  ``deterministic=True`` strips them (and drops whole events marked
  ``wall``) so two seeded runs emit byte-identical JSONL.
* Spans nest: ``with recorder.span("mix", learner=3):`` records its
  parent span's id, so the exporter and ``launch/obsreport.py`` can
  attribute child time correctly.
* Memory is bounded: ``maxlen`` caps the ring (oldest events drop;
  ``n_dropped`` counts them), so a long run cannot OOM the recorder.

:func:`chrome_trace` converts an event list to the Chrome
``trace_event`` JSON (``chrome://tracing`` / https://ui.perfetto.dev):
spans become complete ("X") events, instants "i", metric snapshots
counter ("C") series.
"""
from __future__ import annotations

import json
import time
from collections import deque
from contextlib import contextmanager

KINDS = ("span", "event", "metric")

# JSON-scalar attr values only: the schema stays greppable and every
# line round-trips through json without custom encoders
_SCALARS = (str, int, float, bool, type(None))

DEFAULT_MAXLEN = 65536


class FlightRecorder:
    """Bounded in-memory ring of schema events (module docstring)."""

    def __init__(self, maxlen: int = DEFAULT_MAXLEN,
                 clock=time.perf_counter):
        self._events = deque(maxlen=maxlen)
        self._clock = clock
        self._seq = 0
        self._stack: list = []          # open-span ids (launchers are
        self.maxlen = maxlen            # single-threaded)

    # ------------------------------------------------------------- state
    @property
    def events(self) -> list:
        return list(self._events)

    def __len__(self) -> int:
        return len(self._events)

    @property
    def n_dropped(self) -> int:
        """Events evicted by the ring bound."""
        return self._seq - len(self._events)

    def clear(self) -> None:
        self._events.clear()
        self._seq = 0
        self._stack.clear()

    # ----------------------------------------------------------- records
    def event(self, name: str, **attrs) -> None:
        """One instant event (a step record, a request transition)."""
        self._seq += 1
        self._events.append({"seq": self._seq, "kind": "event",
                             "name": name, "ts": self._clock(),
                             "attrs": attrs})

    def metric(self, rec: dict) -> None:
        """One metric-snapshot record (see MetricsRegistry.snapshot);
        the instrument's own ``kind`` lands as ``instrument`` (the
        event ``kind`` stays ``metric``); ``wall`` metrics are dropped
        by the deterministic export."""
        self._seq += 1
        ev = {"seq": self._seq, "kind": "metric", "ts": self._clock()}
        for k, v in rec.items():
            ev["instrument" if k == "kind" else k] = v
        self._events.append(ev)

    def add_span(self, name: str, t0: float, dur: float,
                 wall: bool = False, **attrs) -> None:
        """Append an already-timed span (the ProfiledFn path: the
        caller measured ``dur`` itself, e.g. around a blocked jit
        call).  ``wall=True`` marks it wall-clock-derived, so the
        deterministic export drops the whole event."""
        self._seq += 1
        ev = {"seq": self._seq, "kind": "span", "name": name,
              "ts": t0, "dur": dur, "id": self._seq,
              "parent": self._stack[-1] if self._stack else 0,
              "attrs": attrs}
        if wall:
            ev["wall"] = True
        self._events.append(ev)

    @contextmanager
    def span(self, name: str, **attrs):
        """Timed region: ``with recorder.span("mix", learner=i): ...``
        The record lands at exit (children therefore precede parents in
        the stream); ``id``/``parent`` reconstruct the nesting."""
        self._seq += 1
        sid = self._seq
        parent = self._stack[-1] if self._stack else 0
        self._stack.append(sid)
        t0 = self._clock()
        try:
            yield
        finally:
            dur = self._clock() - t0
            self._stack.pop()
            self._events.append({"seq": sid, "kind": "span", "name": name,
                                 "ts": t0, "dur": dur, "id": sid,
                                 "parent": parent, "attrs": attrs})


class NullRecorder(FlightRecorder):
    """The disabled default: every record is a pass, ``span`` is a
    shared no-op context — instrumentation sites cost one call."""

    def __init__(self):
        super().__init__(maxlen=1)

    def event(self, name: str, **attrs) -> None:
        pass

    def metric(self, rec: dict) -> None:
        pass

    def add_span(self, name: str, t0: float, dur: float,
                 wall: bool = False, **attrs) -> None:
        pass

    @contextmanager
    def span(self, name: str, **attrs):
        yield


NULL_RECORDER = NullRecorder()


# ---------------------------------------------------------------------------
# JSONL export / import
# ---------------------------------------------------------------------------

# fields carrying wall-clock time, stripped by the deterministic export
_WALL_FIELDS = ("ts", "dur")


def event_to_line(ev: dict, deterministic: bool = False):
    """One JSONL line (sorted keys, so byte-stable), or None when the
    deterministic export drops the event entirely (wall-marked)."""
    if deterministic:
        if ev.get("wall"):
            return None
        ev = {k: v for k, v in ev.items() if k not in _WALL_FIELDS}
    return json.dumps(ev, sort_keys=True)


def write_jsonl(events, path: str, deterministic: bool = False) -> int:
    """Write the flight-recorder events as JSONL; returns lines
    written.  ``deterministic=True`` strips wall-clock fields and drops
    wall-marked events so seeded re-runs are byte-identical."""
    n = 0
    with open(path, "w", encoding="utf-8") as f:
        for ev in events:
            line = event_to_line(ev, deterministic)
            if line is not None:
                f.write(line + "\n")
                n += 1
    return n


def read_jsonl(path: str) -> list:
    with open(path, encoding="utf-8") as f:
        return [json.loads(line) for line in f if line.strip()]


def validate_events(events) -> list:
    """Schema problems as strings (empty = valid).  The contract every
    emitted JSONL must satisfy (the CI obs smoke gates on it)."""
    problems = []
    seen = set()
    for i, ev in enumerate(events):
        where = f"event[{i}]"
        if not isinstance(ev, dict):
            problems.append(f"{where}: not an object")
            continue
        seq = ev.get("seq")
        if not isinstance(seq, int):
            problems.append(f"{where}: missing/non-int seq")
        elif seq in seen:
            problems.append(f"{where}: duplicate seq {seq}")
        else:
            seen.add(seq)
        if ev.get("kind") not in KINDS:
            problems.append(f"{where}: kind {ev.get('kind')!r} not in "
                            f"{KINDS}")
        if not isinstance(ev.get("name"), str) or not ev.get("name"):
            problems.append(f"{where}: missing name")
        for fld in _WALL_FIELDS:
            if fld in ev and not isinstance(ev[fld], (int, float)):
                problems.append(f"{where}: {fld} not numeric")
        if ev.get("kind") == "span":
            if "dur" in ev and ev["dur"] < 0:
                problems.append(f"{where}: negative span dur")
            for fld in ("id", "parent"):
                if fld in ev and not isinstance(ev[fld], int):
                    problems.append(f"{where}: span {fld} not int")
        attrs = ev.get("attrs", {})
        if not isinstance(attrs, dict):
            problems.append(f"{where}: attrs not an object")
        else:
            for k, v in attrs.items():
                if not isinstance(k, str):
                    problems.append(f"{where}: non-str attr key {k!r}")
                if not isinstance(v, _SCALARS):
                    problems.append(f"{where}: attr {k}={type(v).__name__}"
                                    f" not a JSON scalar")
    return problems


# ---------------------------------------------------------------------------
# Chrome trace_event exporter
# ---------------------------------------------------------------------------

def chrome_trace(events) -> dict:
    """Chrome ``trace_event`` JSON (the dict; ``json.dump`` it and open
    in chrome://tracing or ui.perfetto.dev).  Spans -> complete "X"
    events, instants -> "i", metric records -> counter "C" series."""
    out = []
    for ev in events:
        ts_us = float(ev.get("ts", 0.0)) * 1e6
        attrs = dict(ev.get("attrs", {}))
        kind = ev.get("kind")
        if kind == "span":
            out.append({"name": ev["name"], "ph": "X", "ts": ts_us,
                        "dur": float(ev.get("dur", 0.0)) * 1e6,
                        "pid": 0, "tid": int(attrs.pop("tid", 0)),
                        "args": attrs})
        elif kind == "metric":
            val = ev.get("value", ev.get("mean"))
            if isinstance(val, (int, float)) and val == val:
                out.append({"name": ev["name"], "ph": "C", "ts": ts_us,
                            "pid": 0, "args": {"value": float(val)}})
        else:
            out.append({"name": ev["name"], "ph": "i", "ts": ts_us,
                        "s": "t", "pid": 0, "tid": 0, "args": attrs})
    out.sort(key=lambda e: (e["ts"], e["name"]))
    return {"traceEvents": out, "displayTimeUnit": "ms"}
