"""Production mesh construction.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax initialization).

Mesh geometry (TPU v5e):
* single pod:  (16, 16) = 256 chips, axes ('data', 'model')
* multi-pod:   (2, 16, 16) = 512 chips, axes ('pod', 'data', 'model')

Mapping of the paper's HPC topology (§V): a 'super learner' (one server's
GPUs under NCCL allreduce) becomes one model-parallel group; the learner
ring of AD-PSGD runs over the 'data' axis on one pod and over the 'pod'
axis in the H-ring multi-pod configuration.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5: explicit axis types on the mesh
    from jax.sharding import AxisType
except ImportError:  # jax 0.4.x: every mesh axis is implicitly 'auto'
    AxisType = None

from repro.sharding import MeshRules, default_rules, multipod_rules


def _make_mesh(shape, axes):
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def use_mesh(mesh):
    """Context manager activating ``mesh``: ``jax.set_mesh`` on new jax,
    the Mesh object's own context manager on 0.4.x."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over the locally available devices (CPU tests/examples)."""
    n = len(jax.devices())
    data = min(data, n)
    return _make_mesh((data, max(n // data, 1))[:2], ("data", "model"))


def rules_for(cfg, mesh, *, multi_pod: bool = False) -> MeshRules:
    """MeshRules for one architecture on one mesh (FSDP / expert axis per
    the arch's distribution defaults)."""
    mk = multipod_rules if multi_pod else default_rules
    rules = mk(fsdp=cfg.fsdp, expert_axis=cfg.expert_axis)
    if getattr(cfg, "attn_sharding", "replicated") == "seq":
        # sequence-parallel attention (§Perf): projections sharded on the
        # contracting head_dim (always 16-divisible across the zoo); the
        # attention compute itself is resharded per q-chunk in attn_seq.
        rules["head_dim"] = ("model",)
    return MeshRules(mesh, rules)
