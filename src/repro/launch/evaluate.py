"""Recognition-quality evaluation launcher: checkpoint -> TER/FER table.

The paper's third axis (alongside convergence and speedup) is
recognition performance — WER on Hub5'00; the companion 1904.04956
reports (A)D-PSGD vs sync SGD as WER deltas.  This CLI is that table's
synthetic analogue: it restores a training checkpoint written by
``repro.launch.train`` (same strategy/learners/optimizer so the state
pytree matches), averages the learner replicas to the consensus model,
runs the BLSTM forward over a held-out synthetic set (respecting the
``lengths`` batch contract), and scores it with

* **FER** — masked frame error rate (padding excluded),
* **TER** — token error rate (the WER formula) of greedy best-path vs
  CTC prefix beam search (``repro.decode``; ``--beam-*`` knobs),
* throughput — valid frames/s through forward+decode and decoded
  tokens/s + beam occupancy, the same conventions ``launch/serve.py``
  prints.

Output is the ``name,value,derived`` CSV of benchmarks/run.py so rows
drop straight into the paper-tables flow.

  PYTHONPATH=src python -m repro.launch.train --arch swb2000-blstm \
      --reduced --learners 2 --strategy ad_psgd --steps 40 \
      --ckpt-dir /tmp/ck --ckpt-every 20
  PYTHONPATH=src python -m repro.launch.evaluate --arch swb2000-blstm \
      --reduced --learners 2 --strategy ad_psgd --ckpt-dir /tmp/ck \
      --beam-width 8
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import decode as DC
from repro import obs
from repro.checkpoint import restore
from repro.configs import get_arch
from repro.core import strategies as ST
from repro.data import make_dataset
from repro.eval.metrics import (collapse_labels, frame_error_rate,
                                greedy_ctc_decode, token_error_rate)
from repro.launch.mesh import make_local_mesh, use_mesh
from repro.launch.train import setup_training
from repro.models import lstm as LS

HELDOUT_OFFSET = 1_000_000      # batch_at() index space disjoint from train


def restore_consensus(cfg, *, ckpt_dir: str, strategy_name: str = None,
                      n_learners: int = None, optimizer_name: str = "sgd",
                      step: int = None, kernel_impl: str = "jax"):
    """Rebuild the exact train-state pytree (strategy x learners x
    optimizer must match the training run), restore the checkpoint into
    it, and collapse learner replicas to the consensus params."""
    mesh = make_local_mesh()
    with use_mesh(mesh):
        state, _, meta = setup_training(
            cfg, mesh, strategy_name=strategy_name, n_learners=n_learners,
            optimizer_name=optimizer_name, kernel_impl=kernel_impl)
    state, step = restore(ckpt_dir, state, step=step)
    params = state["params"]
    if meta["strategy"].replicated:
        params = ST.average_learners(params)
    return params, step, meta


def evaluate_params(cfg, params, *, batches: int = 4, batch: int = 8,
                    seq_len: int = None, var_len: bool = False,
                    bucket: bool = False, seed: int = 0,
                    kernel_impl: str = "jax", beam: int = None,
                    semiring: str = None, len_norm: float = None,
                    blank: int = 0, decode_chunk: int = 0,
                    topc: int = None):
    """Decode a held-out synthetic set and return the metrics dict.

    ``decode_chunk`` > 0 streams each batch through the chunked decode
    (carry = beam state) in windows of that many frames — bit-identical
    to the one-shot decode, exercised here so evaluate and the serving
    loop share one code path."""
    beam = beam or getattr(cfg, "beam_width", 8)
    semiring = semiring or getattr(cfg, "beam_semiring", "max")
    len_norm = (getattr(cfg, "beam_len_norm", 0.0)
                if len_norm is None else len_norm)
    topc = getattr(cfg, "beam_topc", 0) if topc is None else topc
    seq_len = seq_len or 21
    impl = "pallas" if kernel_impl == "pallas" else "jax"

    ds = make_dataset(cfg, seq_len=seq_len, batch=batch, seed=seed,
                      var_len=var_len or bucket, bucket=bucket)

    @jax.jit
    def fwd(p, feats, lengths=None):
        return LS.forward(cfg, p, feats, lengths, kernel_impl=kernel_impl)

    @jax.jit
    def decode_batch(logits, lengths):
        """Jitted chunked decode of one batch (lengths always supplied:
        full-T lengths reproduce the rectangular decode exactly)."""
        B, T, _ = logits.shape
        chunk = decode_chunk if decode_chunk > 0 else T
        st = DC.init_state(B, beam, T)
        for t in range(0, T, chunk):
            st = DC.decode_chunk(st, logits[:, t:t + chunk], lengths,
                                 blank=blank, semiring=semiring, impl=impl,
                                 topc=topc)
        toks, lens, _ = DC.finalize(st, len_norm=len_norm,
                                    semiring=semiring)
        return toks, lens, DC.beam_occupancy(st)

    def run_batch(b):
        lengths = b.get("lengths")
        lens_j = (jnp.full(b["features"].shape[0], b["features"].shape[1],
                           jnp.int32) if lengths is None
                  else jnp.asarray(lengths))
        t0 = time.perf_counter()
        logits = jax.block_until_ready(
            fwd(params, jnp.asarray(b["features"]),
                None if lengths is None else lens_j))
        dt_fwd = time.perf_counter() - t0
        t1 = time.perf_counter()
        toks, lens, occ = jax.tree.map(
            jax.block_until_ready, decode_batch(logits, lens_j))
        dt_dec = time.perf_counter() - t1
        obs.add_span("eval/fwd", t0, dt_fwd, wall=True)
        obs.add_span("eval/decode", t1, dt_dec, wall=True)
        obs.histogram("eval/fwd_s", wall=True).observe(dt_fwd)
        obs.histogram("eval/decode_s", wall=True).observe(dt_dec)
        return logits, lengths, toks, lens, occ, dt_fwd, dt_dec

    # warm-up compile on every distinct padded shape (bucketed batches
    # pad to their own rounded max T) so the throughput rows measure
    # forward+decode, not XLA compilation
    batch_list = [ds.batch_at(HELDOUT_OFFSET + i) for i in range(batches)]
    for shape in {b["features"].shape for b in batch_list}:
        run_batch(next(b for b in batch_list
                       if b["features"].shape == shape))

    fer_n = fer_d = 0.0
    refs, hyps_g, hyps_b = [], [], []
    valid_frames = 0
    occupancy = []
    t_fwd = t_dec = 0.0
    for b in batch_list:
        logits, lengths, toks, lens, occ, dt_fwd, dt_dec = run_batch(b)
        t_fwd += dt_fwd
        t_dec += dt_dec
        logits_np = np.asarray(logits, np.float32)
        B, T, _ = logits_np.shape
        n_valid = int(lengths.sum()) if lengths is not None else B * T
        valid_frames += n_valid

        fer = frame_error_rate(logits_np, b["labels"], lengths)
        fer_n += fer * n_valid
        fer_d += n_valid
        refs += collapse_labels(b["labels"], lengths, blank=blank)
        hyps_g += greedy_ctc_decode(logits_np, lengths, blank=blank)

        occupancy.append(float(np.mean(np.asarray(occ))))
        toks, lens = np.asarray(toks), np.asarray(lens)
        hyps_b += [list(map(int, r[:n])) for r, n in zip(toks, lens)]

    decoded = sum(len(h) for h in hyps_b)
    return {
        "fer": fer_n / max(fer_d, 1),
        "ter_greedy": token_error_rate(refs, hyps_g),
        "ter_beam": token_error_rate(refs, hyps_b),
        "beam": beam,
        "semiring": semiring,
        "valid_frames": valid_frames,
        "frames_per_s": valid_frames / max(t_fwd + t_dec, 1e-9),
        "decoded_tok_per_s": decoded / max(t_dec, 1e-9),
        "beam_occupancy": float(np.mean(occupancy)) if occupancy else 0.0,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--ckpt-dir", required=True,
                    help="checkpoint directory written by repro.launch."
                         "train (state restores only when --strategy/"
                         "--learners/--optimizer match the training run)")
    ap.add_argument("--step", type=int, default=0,
                    help="checkpoint step to restore (0 = latest)")
    ap.add_argument("--strategy", default=None,
                    choices=[None] + sorted(ST.STRATEGIES))
    ap.add_argument("--learners", type=int, default=None)
    ap.add_argument("--optimizer", default="sgd")
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale variant of the arch (CPU-friendly)")
    ap.add_argument("--batches", type=int, default=4,
                    help="held-out batches to decode")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=21)
    ap.add_argument("--var-len", action="store_true",
                    help="held-out set carries per-utterance lengths "
                         "(masked FER + length-aware decode)")
    ap.add_argument("--bucket", action="store_true",
                    help="length-bucketed held-out batches (implies "
                         "--var-len)")
    ap.add_argument("--kernel-impl", default="jax",
                    choices=["jax", "pallas"],
                    help="BLSTM forward AND beam inner-step kernels")
    ap.add_argument("--beam-width", type=int, default=0,
                    help="CTC prefix-beam width (0 = cfg beam_width)")
    ap.add_argument("--beam-semiring", default="",
                    choices=["", "max", "sum"],
                    help="prefix-score merge: 'max' (Viterbi; beam=1 == "
                         "greedy) or 'sum' (log-semiring) ('' = cfg)")
    ap.add_argument("--beam-len-norm", type=float, default=-1.0,
                    help="length-normalization alpha for final ranking "
                         "(-1 = cfg beam_len_norm)")
    ap.add_argument("--beam-topc", type=int, default=-1,
                    help="per-frame top-C vocab pruning of the beam "
                         "candidate grid (0 = off, -1 = cfg beam_topc); "
                         "exact when C covers the frame support "
                         "(docs/decoding.md)")
    ap.add_argument("--decode-chunk", type=int, default=0,
                    help="stream the decode in chunks of this many "
                         "frames, carry = beam state (0 = one shot)")
    ap.add_argument("--blank", type=int, default=0,
                    help="blank/silence class id of the TER convention")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace-out", default="",
                    help="enable observability and write the run's "
                         "flight-recorder JSONL here (per-batch "
                         "forward/decode timing spans; "
                         "docs/observability.md)")
    ap.add_argument("--trace-deterministic", action="store_true",
                    help="strip wall-clock fields from the JSONL so "
                         "two seeded runs emit byte-identical traces")
    args = ap.parse_args(argv)

    if args.trace_out:
        obs.configure()
    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.family != "lstm":
        raise SystemExit("evaluate covers the acoustic (lstm) family; "
                         f"--arch {args.arch} is {cfg.family!r}")
    changes = {}
    if args.beam_width:
        changes["beam_width"] = args.beam_width
    if args.beam_semiring:
        changes["beam_semiring"] = args.beam_semiring
    if args.beam_len_norm >= 0:
        changes["beam_len_norm"] = args.beam_len_norm
    if args.beam_topc >= 0:
        changes["beam_topc"] = args.beam_topc
    if changes:
        cfg = dataclasses.replace(cfg, **changes)

    strategy = ST.get_strategy(args.strategy or cfg.train_strategy)
    params, step, meta = restore_consensus(
        cfg, ckpt_dir=args.ckpt_dir, strategy_name=strategy.name,
        n_learners=args.learners, optimizer_name=args.optimizer,
        step=args.step or None, kernel_impl=args.kernel_impl)
    print(f"restored {strategy.name} checkpoint at step {step} "
          f"(L={meta['n_learners']}, consensus params)")

    m = evaluate_params(
        cfg, params, batches=args.batches, batch=args.batch,
        seq_len=args.seq_len, var_len=args.var_len, bucket=args.bucket,
        seed=args.seed, kernel_impl=args.kernel_impl,
        blank=args.blank, decode_chunk=args.decode_chunk)

    from repro.obs import print_csv_rows

    tag = f"evaluate/{strategy.name}"
    rows = [
        (f"{tag}/fer", m["fer"], f"masked frame error rate, step {step}"),
        (f"{tag}/ter_greedy", m["ter_greedy"],
         "token error rate, best-path decode"),
        (f"{tag}/ter_beam{m['beam']}", m["ter_beam"],
         f"prefix beam, {m['semiring']} semiring"),
        (f"{tag}/frames_per_s", m["frames_per_s"],
         f"{m['valid_frames']} valid frames, forward+decode"),
        (f"{tag}/decoded_tok_per_s", m["decoded_tok_per_s"],
         "serve.py throughput convention"),
        (f"{tag}/beam_occupancy", m["beam_occupancy"],
         "live beam slots / beam width"),
    ]
    # the shared name,value,derived schema (repro.obs)
    print_csv_rows(rows, header=True)
    if args.trace_out:
        n = obs.dump(args.trace_out,
                     deterministic=args.trace_deterministic)
        print(f"trace: {n} events -> {args.trace_out}")
        obs.reset()


if __name__ == "__main__":
    main()
