"""Batched serving launcher: continuous-batching decode loops.

Two request families share the slot-pool pattern (admit into free slots,
advance all active slots together, free and refill on completion):

* **LM** (decoder-only families): single-request prefill scatters cache
  rows into a stacked KV/SSM cache, then the jitted one-token
  ``decode_step`` advances every active slot.  Under ``--kernel-impl
  pallas`` the flag covers the whole request loop: prefill (flash
  attention), the decode step's per-layer attention (the streaming
  cache kernel in ``repro.kernels.decode_attention``, fused delta
  variant) and the next-token selection
  (``repro.decode.kernel.argmax_tokens``, bit-identical to
  ``jnp.argmax``).
* **ASR** (the paper's lstm family): requests are variable-length
  utterances; admission runs the BLSTM forward once (``--kernel-impl``
  selects the fused Pallas stack), and the decode loop streams the
  CD-state posteriors through the chunked CTC prefix beam search of
  ``repro.decode`` — one :class:`repro.decode.BeamState` batched over
  the slot pool IS the decode carry, advanced ``--chunk-frames`` frames
  per wave (docs/decoding.md).

Both servers implement the multi-tenant slot-pool duck contract of
``repro.serving`` (docs/serving.md): ``admit``/``submit`` return a
*typed* :class:`~repro.serving.admission.AdmitResult` (``pool_full`` is
retryable; ``prompt_too_long``/``no_budget`` are terminal),
``preempt``/``restore`` snapshot a running request's full decode state
(LM: the cache row; ASR: the :class:`~repro.decode.BeamState` row via
``gather_rows``/``scatter_rows``) so a preempted-then-resumed request
decodes bit-for-bit identically to an uninterrupted one, ``step_wave``
reports per-wave progress for SLO accounting, and every slot
transition lands in ``server.events`` as a structured per-request
event instead of an ad-hoc stats line.  ``repro.launch.load`` drives
these servers through seeded traffic with SLO accounting.

PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --reduced \
    --requests 6 --slots 2 --max-new 16
PYTHONPATH=src python -m repro.launch.serve --arch swb2000-blstm \
    --reduced --requests 6 --slots 2 --chunk-frames 8 --beam-width 4
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import decode as DC
from repro import obs
from repro.configs import get_arch
from repro.configs.base import ShapeConfig
from repro.launch.mesh import make_local_mesh, rules_for
from repro.models import build_model
from repro.serving.admission import (NO_BUDGET, OK, POOL_FULL,
                                     PROMPT_TOO_LONG, AdmitResult,
                                     prompt_capacity)
from repro.serving.kvpool import PagePool, cdiv
from repro.sharding import ParamSpec, init_spec_tree


def _profile_jits(server, names):
    """Wrap the server's jitted entry points in compile/steady
    :class:`~repro.obs.ProfiledFn` wall-time wrappers (only while
    observability is on — the wrapper blocks on results, which the
    uninstrumented hot path must not pay)."""
    server._profiled = []
    if not obs.enabled():
        return
    for attr in names:
        p = obs.profiled(getattr(server, attr),
                         f"serve/{attr.removeprefix('_jit_')}",
                         metrics=obs.get_metrics(),
                         recorder=obs.get_recorder())
        setattr(server, attr, p)
        server._profiled.append(p)


def zeros_from_specs(spec_tree):
    return jax.tree.map(
        lambda ps: jnp.zeros(ps.shape, ps.dtype),
        spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec))


def scatter_slot(pool, row, slot):
    """Write a single-request cache row (batch dim 1) into pool slot."""
    def one(dst, src):
        # batch is axis 1 (layer-stacked caches: (L, B, ...))
        return jax.lax.dynamic_update_slice_in_dim(
            dst, src.astype(dst.dtype), slot, axis=1)
    return jax.tree.map(one, pool, row)


def scatter_slots(pool, rows, slots):
    """Write gathered cache rows (batch = len(slots)) back into the
    (possibly non-contiguous) pool slots — the batched-wave counterpart
    of :func:`scatter_slot`."""
    idx = jnp.asarray(slots, jnp.int32)
    return jax.tree.map(
        lambda dst, src: dst.at[:, idx].set(src.astype(dst.dtype)),
        pool, rows)


class _SlotPool:
    """Shared slot-pool bookkeeping: typed admission helpers, the
    structured per-request event stream, and the rid -> slot map."""

    emits_on_admit = False

    def __init__(self, slots: int, verbose: bool = False):
        self.slots = slots
        self.active = np.zeros(slots, bool)
        self.req_ids = [-1] * slots
        self.events = []
        self.verbose = verbose

    def _event(self, kind: str, rid: int, **kw):
        self.events.append((kind, rid, kw))
        obs.event(f"serve/{kind}", rid=rid, **kw)
        if self.verbose:
            extra = "".join(f" {k}={v}" for k, v in kw.items())
            print(f"[req] {kind} rid={rid}{extra}", flush=True)

    def _free_slot(self):
        free = np.where(~self.active)[0]
        return int(free[0]) if len(free) else -1

    def _slot_of(self, rid: int) -> int:
        for slot in np.where(self.active)[0]:
            if self.req_ids[slot] == rid:
                return int(slot)
        raise KeyError(f"request {rid} is not active in the pool")

    def active_requests(self):
        return [self.req_ids[s] for s in np.where(self.active)[0]]


class Server(_SlotPool):
    """LM continuous batching over a stacked KV/SSM cache."""

    emits_on_admit = True      # prefill emits the first token at admission

    def __init__(self, cfg, *, slots: int, max_len: int, seed: int = 0,
                 kernel_impl: str = "jax", batched: bool = True,
                 verbose: bool = False):
        # kernel_impl covers the whole request loop: prefill, the decode
        # step's attention (repro.kernels.decode_attention via
        # models.api.decode_fn; cfg.attn_decode_impl overrides) and the
        # token selection (repro.decode.kernel.argmax_tokens)
        assert cfg.supports_decode and cfg.family != "encdec", \
            "demo server covers decoder-only families"
        super().__init__(slots, verbose)
        self.cfg = cfg
        self.model = build_model(cfg)
        self.max_len = max_len
        self.batched = batched
        self.params = init_spec_tree(self.model.param_specs(),
                                     jax.random.PRNGKey(seed))
        shape = ShapeConfig("serve", max_len, slots, "decode")
        self._cache_specs = self.model.cache_specs(shape)
        self.cache = zeros_from_specs(self._cache_specs)
        self.pos = np.zeros(slots, np.int32)          # next write position
        self.tokens = np.zeros((slots, 1), np.int32)  # last emitted token
        self.budget = np.zeros(slots, np.int32)
        self.outputs = [[] for _ in range(slots)]

        self._jit_prefill = jax.jit(
            lambda params, batch: self.model.prefill_fn(
                params, batch, cache_len=max_len,
                kernel_impl=kernel_impl))
        self._jit_decode = jax.jit(
            lambda params, cache, tok, pos: self.model.decode_fn(
                params, cache, tok, pos, kernel_impl=kernel_impl))
        if kernel_impl == "pallas":
            self._select = lambda row: int(DC.argmax_tokens(row[None])[0])
        else:
            self._select = lambda row: int(jnp.argmax(row))
        _profile_jits(self, ("_jit_prefill", "_jit_decode"))
        if obs.enabled() and cfg.family in ("dense", "moe", "vlm"):
            # runtime collection of the kernel's VMEM accounting
            # single-source (repro.kernels.decode_attention)
            from repro.kernels.decode_attention import (
                auto_block_s_decode, decode_attn_vmem_bytes)
            M, E = cfg.n_heads, cfg.head_dim
            bs = auto_block_s_decode(max_len, M, E)
            obs.gauge("kernel/decode_attn_vmem_bytes",
                      block_s=bs).set(decode_attn_vmem_bytes(bs, M, E))

    # ------------------------------------------------------------------
    def admit(self, req_id: int, prompt: np.ndarray,
              max_new: int) -> AdmitResult:
        """Claim a free slot, prefill, emit the first token.  Typed
        rejection: ``pool_full`` (retryable), ``prompt_too_long`` (the
        cache write position must stay inside the slot's max_len row,
        one position reserved for the first generated token) or
        ``no_budget`` (max_new <= 0) — each is a distinct cause, not a
        silent False."""
        prompt = np.asarray(prompt)
        if len(prompt) > prompt_capacity(self.max_len, "lm"):
            self._event("reject", req_id, reason=PROMPT_TOO_LONG,
                        prompt=len(prompt))
            return AdmitResult(PROMPT_TOO_LONG)
        if max_new <= 0:
            self._event("reject", req_id, reason=NO_BUDGET)
            return AdmitResult(NO_BUDGET)
        slot = self._free_slot()
        if slot < 0:
            return AdmitResult(POOL_FULL)
        logits, row_cache = self._jit_prefill(
            self.params, {"tokens": jnp.asarray(prompt[None, :])})
        self.cache = scatter_slot(self.cache, row_cache, slot)
        nxt = self._select(logits[0, -1])
        self.pos[slot] = len(prompt)
        self.tokens[slot, 0] = nxt
        self.active[slot] = True
        self.budget[slot] = max_new - 1
        self.outputs[slot] = [nxt]
        self.req_ids[slot] = req_id
        self._event("admit", req_id, slot=slot, prompt=len(prompt))
        return AdmitResult(OK, slot)

    # ----------------------------------------------------- duck contract
    def submit(self, req, payload) -> AdmitResult:
        return self.admit(req.rid, payload, req.max_new)

    def step_wave(self):
        """One decode wave: ``(completed, progressed_rids, work)`` —
        every active slot advances one token, so work = active count."""
        progressed = self.active_requests()
        done = self.step()
        return done, progressed, len(progressed)

    def preempt(self, rid: int):
        """Evict ``rid``: snapshot its cache row (host-side) plus the
        position/budget/output bookkeeping, free the slot."""
        slot = self._slot_of(rid)
        snap = {
            "rid": rid,
            "pos": int(self.pos[slot]),
            "token": int(self.tokens[slot, 0]),
            "budget": int(self.budget[slot]),
            "outputs": list(self.outputs[slot]),
            "row": jax.tree.map(lambda c: np.asarray(c[:, slot:slot + 1]),
                                self.cache),
        }
        self.active[slot] = False
        self.req_ids[slot] = -1
        self._event("preempt", rid, slot=slot, pos=snap["pos"])
        return snap

    def restore(self, snap) -> AdmitResult:
        """Resume a preempted request in any free slot — the cache row
        round-trips exactly, so the continued decode is bit-for-bit the
        uninterrupted one."""
        slot = self._free_slot()
        if slot < 0:
            return AdmitResult(POOL_FULL)
        row = jax.tree.map(jnp.asarray, snap["row"])
        self.cache = scatter_slot(self.cache, row, slot)
        self.pos[slot] = snap["pos"]
        self.tokens[slot, 0] = snap["token"]
        self.budget[slot] = snap["budget"]
        self.outputs[slot] = list(snap["outputs"])
        self.active[slot] = True
        self.req_ids[slot] = snap["rid"]
        self._event("restore", snap["rid"], slot=slot, pos=snap["pos"])
        return AdmitResult(OK, slot)

    def reset(self):
        """Clear every slot (jitted executables survive — the capacity
        search replays many traffic levels on one server)."""
        self.cache = jax.tree.map(jnp.zeros_like, self.cache)
        self.pos[:] = 0
        self.active[:] = False
        self.tokens[:] = 0
        self.budget[:] = 0
        self.outputs = [[] for _ in range(self.slots)]
        self.req_ids = [-1] * self.slots
        self.events.clear()

    # ------------------------------------------------------------------
    def step(self):
        """Advance every active slot by one token.

        Slots share one jitted decode at a common position frontier:
        the cache write position differs per slot, so slots are grouped
        by position and each group decodes as ONE batched call (gather
        rows -> decode -> scatter back) — bit-identical to the
        sequential per-slot decode (parity-tested), with
        ``batched=False`` keeping the reference loop."""
        if not self.batched:
            return self._step_sequential()
        done = []
        active = np.where(self.active)[0]
        for p in sorted({int(self.pos[s]) for s in active}):
            group = np.array([s for s in active if self.pos[s] == p],
                             np.int32)
            toks = jnp.asarray(self.tokens[group])
            rows = jax.tree.map(lambda c: c[:, group], self.cache)
            logits, rows = self._jit_decode(self.params, rows, toks,
                                            jnp.int32(p))
            self.cache = scatter_slots(self.cache, rows, group)
            for i, slot in enumerate(map(int, group)):
                self._advance_slot(slot, logits[i, -1], done)
        return done

    def _step_sequential(self):
        done = []
        for slot in np.where(self.active)[0]:
            slot = int(slot)
            tok = jnp.asarray(self.tokens[slot:slot + 1])
            row = jax.tree.map(lambda c: c[:, slot:slot + 1], self.cache)
            logits, row = self._jit_decode(self.params, row, tok,
                                           jnp.int32(int(self.pos[slot])))
            self.cache = scatter_slot(self.cache, row, slot)
            self._advance_slot(slot, logits[0, -1], done)
        return done

    def _advance_slot(self, slot: int, logit_row, done):
        nxt = self._select(logit_row)
        self.outputs[slot].append(nxt)
        self.tokens[slot, 0] = nxt
        self.pos[slot] += 1
        self.budget[slot] -= 1
        if self.budget[slot] <= 0 or self.pos[slot] >= self.max_len - 1:
            self.active[slot] = False
            rid = self.req_ids[slot]
            done.append((rid, list(self.outputs[slot])))
            self._event("done", rid, slot=slot,
                        tokens=len(self.outputs[slot]))


class PagedServer:
    """LM continuous batching over a PAGED KV cache (``--cache paged``).

    Same duck contract and decode loop as :class:`Server`, but the
    physical cache is one shared pool of ``pool_pages`` pages of
    ``page_size`` positions (models/transformer.py ``page_specs``) and
    capacity is the *page budget*, not a slot count: a short request
    pins ``ceil((plen + max_new) / P)`` pages instead of a full
    ``max_len`` row, so many more short requests fit the same HBM.
    Host-side bookkeeping (refcounts, the prompt-prefix trie, COW) lives
    in :class:`repro.serving.kvpool.PagePool`; this class owns the
    device page arrays and applies the pool's decisions:

    * **admit** — pages are reserved eagerly (all-or-nothing; admitted
      requests never OOM mid-decode).  Worst-case demand beyond the
      whole pool is the *terminal* ``no_budget``; insufficient free
      pages right now is the retryable ``pool_full``.  Prefill runs at
      page-rounded length and its cache rows scatter into the owned
      pages only — trie-shared prefix pages already hold the bytes.
    * **step** — equal-position groups decode as one batched call, the
      per-request page tables stacked into the (Bg, W) table the paged
      attention walks.  Before the wave's cache write,
      ``pool.ensure_writable`` COWs any shared page (device page copy
      here, refcount moves in the pool).
    * **preempt/restore** — the snapshot is the page *table* plus the
      owned pages' contents; restore re-allocates through the trie, so
      a resumed request may re-share prompt pages and is still
      bit-exact: shared pages are only read below the request's
      position, where content is verified-identical prompt.
    """

    emits_on_admit = True

    def __init__(self, cfg, *, pool_pages: int, page_size: int,
                 max_len: int, seed: int = 0, kernel_impl: str = "jax",
                 share: bool = True, verbose: bool = False):
        assert cfg.supports_decode and cfg.family in ("dense", "moe", "vlm"), \
            "paged KV cache covers attention-only decoder families"
        if max_len % page_size:
            raise ValueError(f"max_len {max_len} must be a multiple of "
                             f"page_size {page_size}")
        self.cfg = cfg
        self.model = build_model(cfg)
        self.max_len = max_len
        self.page_size = page_size
        self.table_w = cdiv(max_len, page_size)
        self.pool = PagePool(pool_pages, page_size, seed=seed, share=share)
        self.events = []
        self.verbose = verbose
        self.peak_sharing = 0.0
        self.params = init_spec_tree(self.model.param_specs(),
                                     jax.random.PRNGKey(seed))
        pages = zeros_from_specs(
            self.model.page_specs(pool_pages, page_size))
        self.k_pages = pages["attn"]["k"]
        self.v_pages = pages["attn"]["v"]
        self.reqs = {}    # rid -> {pos, token, budget, outputs, ...}

        self._jit_prefill = jax.jit(
            lambda params, batch, cl: self.model.prefill_fn(
                params, batch, cache_len=cl, kernel_impl=kernel_impl),
            static_argnums=2)
        self._jit_decode = jax.jit(
            lambda params, kp, vp, tbl, tok, pos: self.model.decode_fn(
                params, {"attn": {"k": kp, "v": vp}}, tok, pos,
                kernel_impl=kernel_impl, page_table=tbl,
                page_size=page_size))
        self._jit_write = jax.jit(
            lambda pool, rows, idx: pool.at[:, idx].set(
                rows.astype(pool.dtype)))
        self._jit_copy_page = jax.jit(
            lambda pool, src, dst: pool.at[:, dst].set(pool[:, src]))
        if kernel_impl == "pallas":
            self._select = lambda row: int(DC.argmax_tokens(row[None])[0])
        else:
            self._select = lambda row: int(jnp.argmax(row))
        _profile_jits(self, ("_jit_prefill", "_jit_decode",
                             "_jit_write", "_jit_copy_page"))
        if obs.enabled():
            from repro.kernels.decode_attention import paged_attn_vmem_bytes
            M, E = cfg.n_heads, cfg.head_dim
            obs.gauge("kernel/paged_attn_vmem_bytes",
                      page_size=page_size).set(
                paged_attn_vmem_bytes(page_size, M, E, self.table_w))

    # ------------------------------------------------------------------
    def _event(self, kind: str, rid: int, **kw):
        self.events.append((kind, rid, kw))
        obs.event(f"serve/{kind}", rid=rid, **kw)
        if self.verbose:
            extra = "".join(f" {k}={v}" for k, v in kw.items())
            print(f"[req] {kind} rid={rid}{extra}", flush=True)

    @property
    def active(self):
        """In-flight mask (duck compat with the slot servers' loops —
        one entry per live request, not per slot)."""
        return np.ones(len(self.reqs), bool)

    def active_requests(self):
        return list(self.reqs)

    def occupancy(self) -> float:
        return self.pool.pages_in_use / self.pool.n_pages

    # ------------------------------------------------------------------
    def admit(self, req_id: int, prompt: np.ndarray,
              max_new: int) -> AdmitResult:
        """Page-budget admission.  Typed rejection: ``prompt_too_long``
        (prompt exceeds the LM capacity contract), ``no_budget``
        (max_new <= 0, OR worst-case page demand exceeds the whole pool
        — the request can never fit, terminal), ``pool_full`` (not
        enough free pages right now, retryable)."""
        prompt = np.asarray(prompt)
        plen = len(prompt)
        if plen > prompt_capacity(self.max_len, "lm"):
            self._event("reject", req_id, reason=PROMPT_TOO_LONG,
                        prompt=plen)
            return AdmitResult(PROMPT_TOO_LONG)
        total = min(plen + max_new, self.max_len)
        if max_new <= 0 or self.pool.pages_for(total) > self.pool.n_pages:
            self._event("reject", req_id, reason=NO_BUDGET,
                        pages=self.pool.pages_for(max(total, 0)),
                        pool=self.pool.n_pages)
            return AdmitResult(NO_BUDGET)
        alloc = self.pool.alloc_request(req_id, prompt, total)
        if alloc is None:
            return AdmitResult(POOL_FULL)
        P = self.page_size
        pp = cdiv(plen, P) * P          # page-rounded prefill length
        logits, row_cache = self._jit_prefill(
            self.params, {"tokens": jnp.asarray(prompt[None, :])}, pp)
        self._write_owned(row_cache, alloc.table, alloc.owned,
                          n_pages=cdiv(plen, P))
        nxt = self._select(logits[0, -1])
        self.reqs[req_id] = {
            "pos": plen, "token": nxt, "budget": max_new - 1,
            "outputs": [nxt], "prompt": tuple(int(t) for t in prompt),
            "total": total,
        }
        self.peak_sharing = max(self.peak_sharing, self.pool.sharing_ratio)
        self._event("admit", req_id, prompt=plen,
                    pages=alloc.n_pages, shared=alloc.n_shared,
                    in_use=self.pool.pages_in_use)
        return AdmitResult(OK, 0)

    def _write_owned(self, row_cache, table, owned, n_pages):
        """Scatter an (L, 1, n_pages*P, KV, E) prefill row into the OWNED
        physical pages of the first ``n_pages`` table entries (shared
        pages already hold identical prompt bytes)."""
        own = [j for j in range(n_pages) if owned[j]]
        if not own:
            return
        phys = jnp.asarray([table[j] for j in own], jnp.int32)
        P = self.page_size

        def rows(arr):   # (L, 1, pp, KV, E) -> (L, n_own, P, KV, E)
            L, _, pp, KV, E = arr.shape
            return arr[:, 0].reshape(L, pp // P, P, KV, E)[:, own]

        self.k_pages = self._jit_write(self.k_pages,
                                       rows(row_cache["attn"]["k"]), phys)
        self.v_pages = self._jit_write(self.v_pages,
                                       rows(row_cache["attn"]["v"]), phys)

    # ----------------------------------------------------- duck contract
    def submit(self, req, payload) -> AdmitResult:
        return self.admit(req.rid, payload, req.max_new)

    def step_wave(self):
        progressed = self.active_requests()
        done = self.step()
        return done, progressed, len(progressed)

    def preempt(self, rid: int):
        """Evict ``rid``: snapshot its page table's OWNED pages (host)
        plus the bookkeeping, release the pages to the pool."""
        r = self.reqs.pop(rid)
        table = self.pool.table_of(rid)
        snap = {
            "rid": rid, "pos": r["pos"], "token": r["token"],
            "budget": r["budget"], "outputs": list(r["outputs"]),
            "prompt": r["prompt"], "total": r["total"],
            "pages_k": np.asarray(self.k_pages[:, jnp.asarray(table)]),
            "pages_v": np.asarray(self.v_pages[:, jnp.asarray(table)]),
        }
        self.pool.free_request(rid)
        self._event("preempt", rid, pos=r["pos"], pages=len(table))
        return snap

    def restore(self, snap) -> AdmitResult:
        """Resume a preempted request: re-allocate through the trie
        (prompt pages may re-share; pages holding decode output never
        do) and scatter the snapshot into the owned pages."""
        rid = snap["rid"]
        alloc = self.pool.alloc_request(rid, snap["prompt"], snap["total"],
                                        written_upto=snap["pos"])
        if alloc is None:
            return AdmitResult(POOL_FULL)
        own = [j for j in range(alloc.n_pages) if alloc.owned[j]]
        if own:
            phys = jnp.asarray([alloc.table[j] for j in own], jnp.int32)
            self.k_pages = self._jit_write(
                self.k_pages, jnp.asarray(snap["pages_k"][:, own]), phys)
            self.v_pages = self._jit_write(
                self.v_pages, jnp.asarray(snap["pages_v"][:, own]), phys)
        self.reqs[rid] = {k: snap[k] for k in
                          ("pos", "token", "budget", "prompt", "total")}
        self.reqs[rid]["outputs"] = list(snap["outputs"])
        self.peak_sharing = max(self.peak_sharing, self.pool.sharing_ratio)
        self._event("restore", rid, pos=snap["pos"],
                    shared=alloc.n_shared)
        return AdmitResult(OK, 0)

    def reset(self):
        self.pool.reset()
        self.k_pages = jnp.zeros_like(self.k_pages)
        self.v_pages = jnp.zeros_like(self.v_pages)
        self.reqs.clear()
        self.events.clear()
        self.peak_sharing = 0.0

    # ------------------------------------------------------------------
    def step(self):
        """Advance every in-flight request one token: equal-position
        groups share one batched decode (same grouping rule as the dense
        server, so outputs are bit-identical to it given equal logits);
        shared pages COW before the wave's cache write."""
        done = []
        for p in sorted({r["pos"] for r in self.reqs.values()}):
            group = [rid for rid, r in self.reqs.items()
                     if r["pos"] == p]
            for rid in group:    # COW before the device write at p
                moved = self.pool.ensure_writable(rid, p)
                if moved is not None:
                    src, dst = moved
                    self.k_pages = self._jit_copy_page(self.k_pages,
                                                       src, dst)
                    self.v_pages = self._jit_copy_page(self.v_pages,
                                                       src, dst)
                    self._event("cow", rid, pos=p, src=src, dst=dst)
            # Attend only the pages the group can reach: the logical
            # width is the widest request's page count, rounded up to a
            # power of two (bounded retraces).  Short requests stream
            # ceil(total/P) pages, not max_len positions — value-exact
            # because masked tiles contribute exact zeros.
            w_need = max(cdiv(self.reqs[rid]["total"], self.page_size)
                         for rid in group)
            w_use = min(self.table_w, 1 << max(w_need - 1, 0).bit_length())
            tbl = np.zeros((len(group), w_use), np.int32)
            for i, rid in enumerate(group):
                t = self.pool.table_of(rid)
                tbl[i, :len(t)] = t[:w_use]
            toks = jnp.asarray([[self.reqs[rid]["token"]]
                                for rid in group], jnp.int32)
            logits, cache = self._jit_decode(
                self.params, self.k_pages, self.v_pages,
                jnp.asarray(tbl), toks, jnp.int32(p))
            self.k_pages = cache["attn"]["k"]
            self.v_pages = cache["attn"]["v"]
            for i, rid in enumerate(group):
                self._advance(rid, logits[i, -1], done)
        return done

    def _advance(self, rid, logit_row, done):
        r = self.reqs[rid]
        nxt = self._select(logit_row)
        r["outputs"].append(nxt)
        r["token"] = nxt
        r["pos"] += 1
        r["budget"] -= 1
        # same finish rule as the dense Server -> bit-identical outputs
        if r["budget"] <= 0 or r["pos"] >= self.max_len - 1:
            done.append((rid, list(r["outputs"])))
            self._event("done", rid, tokens=len(r["outputs"]),
                        in_use=self.pool.pages_in_use)
            self.pool.free_request(rid)
            del self.reqs[rid]


class AsrServer(_SlotPool):
    """Streaming-ASR slot pool for the paper's acoustic model.

    Admission runs the BLSTM forward once over the utterance (masked to
    its valid frames; ``kernel_impl='pallas'`` selects the fused Pallas
    stack) and parks the CD-state posteriors host-side.  The decode loop
    then advances every active slot by ``chunk`` frames per wave through
    ONE batched :class:`repro.decode.BeamState` — the beam state is the
    streaming carry, per-slot frame counters freeze exhausted rows, and
    ``reset_rows`` re-arms a slot on admission.  Completion = all valid
    frames consumed; the hypothesis is the finalized best beam entry.
    Preemption snapshots the slot's beam row
    (``decode.gather_rows``/``scatter_rows``) plus its parked
    posteriors, so resume continues the identical beam trajectory.
    """

    def __init__(self, cfg, *, slots: int, max_frames: int, chunk: int,
                 beam: int = 0, seed: int = 0, kernel_impl: str = "jax",
                 topc: int = None, verbose: bool = False):
        from repro.models import lstm as LS

        super().__init__(slots, verbose)
        self.cfg = cfg
        self.max_frames = max_frames
        self.chunk = chunk
        self.beam = beam or getattr(cfg, "beam_width", 8)
        self.semiring = getattr(cfg, "beam_semiring", "max")
        self.len_norm = getattr(cfg, "beam_len_norm", 0.0)
        self.topc = (getattr(cfg, "beam_topc", 0) if topc is None
                     else topc)
        self.impl = "pallas" if kernel_impl == "pallas" else "jax"
        print(f"[decode] beam step: {self.impl} (beam {self.beam}, "
              f"topc {self.topc or 'off'})", flush=True)
        model = build_model(cfg)
        self.params = init_spec_tree(model.param_specs(),
                                     jax.random.PRNGKey(seed))
        self._jit_fwd = jax.jit(
            lambda p, feats, n: LS.forward(cfg, p, feats, n,
                                           kernel_impl=kernel_impl))
        self.logits = np.zeros((slots, max_frames, cfg.vocab), np.float32)
        self.lens = np.zeros(slots, np.int32)     # valid frames per slot
        self.pos = np.zeros(slots, np.int32)      # frames consumed
        self.state = DC.init_state(slots, self.beam, max_frames)
        # fixed (state, wave, lens) shapes -> jit once, no per-wave retrace
        self._jit_decode = jax.jit(
            lambda st, wave, lens: DC.decode_chunk(
                st, wave, lens, semiring=self.semiring, impl=self.impl,
                topc=self.topc))
        self._jit_finalize = jax.jit(
            lambda st: DC.finalize(st, len_norm=self.len_norm,
                                   semiring=self.semiring))
        self._jit_occ = jax.jit(DC.beam_occupancy)
        _profile_jits(self, ("_jit_fwd", "_jit_decode", "_jit_finalize"))
        if obs.enabled():
            obs.gauge("kernel/beam_cand_bytes", beam=self.beam,
                      topc=self.topc).set(
                DC.beam_cand_bytes(self.beam, cfg.vocab, self.topc))

    def admit(self, req_id: int, feats: np.ndarray) -> AdmitResult:
        """Typed admission: ``pool_full`` (retryable), ``prompt_too_long``
        (more frames than the slot's posterior buffer) or ``no_budget``
        (an empty utterance has nothing to decode)."""
        feats = np.asarray(feats, np.float32)
        n = len(feats)
        if n > prompt_capacity(self.max_frames, "asr"):
            self._event("reject", req_id, reason=PROMPT_TOO_LONG, frames=n)
            return AdmitResult(PROMPT_TOO_LONG)
        if n == 0:
            self._event("reject", req_id, reason=NO_BUDGET)
            return AdmitResult(NO_BUDGET)
        slot = self._free_slot()
        if slot < 0:
            return AdmitResult(POOL_FULL)
        padded = np.zeros((1, self.max_frames, feats.shape[-1]), np.float32)
        padded[0, :n] = feats
        logits = self._jit_fwd(self.params, jnp.asarray(padded),
                               jnp.asarray([n], jnp.int32))
        self.logits[slot] = np.asarray(logits[0], np.float32)
        self.lens[slot] = n
        self.pos[slot] = 0
        self.active[slot] = True
        self.req_ids[slot] = req_id
        mask = np.zeros(self.slots, bool)
        mask[slot] = True
        self.state = DC.reset_rows(self.state, jnp.asarray(mask))
        self._event("admit", req_id, slot=slot, frames=n)
        return AdmitResult(OK, slot)

    # ----------------------------------------------------- duck contract
    def submit(self, req, payload) -> AdmitResult:
        return self.admit(req.rid, payload)

    def step_wave(self):
        """One decode wave: ``(completed, progressed_rids, work)`` with
        work = valid frames consumed across the pool this wave."""
        active = np.where(self.active)[0]
        progressed = [self.req_ids[s] for s in active]
        work = int(np.minimum(
            self.chunk,
            np.maximum(self.lens[active] - self.pos[active], 0)).sum())
        done, _ = self.step()
        return done, progressed, work

    def preempt(self, rid: int):
        """Evict ``rid``: snapshot its beam row + parked posteriors,
        freeze the vacated row (lens = 0 so ``state.t >= lens``), free
        the slot."""
        slot = self._slot_of(rid)
        snap = {
            "rid": rid,
            "logits": self.logits[slot].copy(),
            "len": int(self.lens[slot]),
            "pos": int(self.pos[slot]),
            "beam": jax.tree.map(np.asarray,
                                 DC.gather_rows(self.state, [slot])),
        }
        self.active[slot] = False
        self.req_ids[slot] = -1
        self.lens[slot] = 0        # freezes the stale beam row
        self.pos[slot] = 0
        self._event("preempt", rid, slot=slot, pos=snap["pos"])
        return snap

    def restore(self, snap) -> AdmitResult:
        """Resume in any free slot: scatter the beam row back
        (``decode.scatter_rows``) — the continued chunked decode is
        bit-identical to the uninterrupted stream (BeamState contract,
        docs/decoding.md)."""
        slot = self._free_slot()
        if slot < 0:
            return AdmitResult(POOL_FULL)
        self.logits[slot] = snap["logits"]
        self.lens[slot] = snap["len"]
        self.pos[slot] = snap["pos"]
        self.state = DC.scatter_rows(self.state, snap["beam"], [slot])
        self.active[slot] = True
        self.req_ids[slot] = snap["rid"]
        self._event("restore", snap["rid"], slot=slot, pos=snap["pos"])
        return AdmitResult(OK, slot)

    def reset(self):
        self.logits[:] = 0.0
        self.lens[:] = 0
        self.pos[:] = 0
        self.active[:] = False
        self.req_ids = [-1] * self.slots
        self.state = DC.init_state(self.slots, self.beam, self.max_frames)
        self.events.clear()

    # ------------------------------------------------------------------
    def step(self):
        """Advance every active slot by one chunk of frames.  Returns
        ``[(req_id, tokens), ...]`` for slots that finished and
        the live-beam occupancy of this wave."""
        C = self.chunk
        idx = np.minimum(self.pos[:, None] + np.arange(C)[None, :],
                         self.max_frames - 1)
        wave = self.logits[np.arange(self.slots)[:, None], idx]
        # per-row freeze: state.t >= lens stops exhausted/empty rows
        self.state = self._jit_decode(self.state, jnp.asarray(wave),
                                      jnp.asarray(self.lens))
        occ = float(np.mean(np.asarray(
            self._jit_occ(self.state))[self.active])) \
            if self.active.any() else 0.0
        self.pos = np.where(self.active,
                            np.minimum(self.pos + C, self.lens), self.pos)
        done = []
        finished = np.where(self.active & (self.pos >= self.lens))[0]
        if len(finished):
            toks, lens, _ = self._jit_finalize(self.state)
            toks = np.asarray(toks)
            for slot in finished:
                hyp = list(map(int, toks[slot][:int(lens[slot])]))
                rid = self.req_ids[slot]
                done.append((rid, hyp))
                self.active[slot] = False
                self._event("done", rid, slot=int(slot), tokens=len(hyp))
        return done, occ


def _finish_trace(server, args):
    """End-of-run observability: per-entry-point compile/steady rows
    (the regimes a single wall-clock total conflates) and the JSONL
    flight-recorder dump."""
    for p in getattr(server, "_profiled", []):
        n = p.n_calls - p.n_compiles
        print(f"timing: {p.name} compile {p.compile_s:.2f}s "
              f"({p.n_compiles} compile(s)), steady {p.steady_s:.3f}s "
              f"over {n} calls", flush=True)
    if args.trace_out:
        n = obs.dump(args.trace_out,
                     deterministic=args.trace_deterministic)
        print(f"trace: {n} events -> {args.trace_out}")
        obs.reset()


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16,
                    help="prompt tokens (LM) / nominal utterance frames "
                         "(ASR) per request (clamped to --max-len)")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=64,
                    help="cache capacity (LM) / max utterance frames "
                         "(ASR) per slot")
    ap.add_argument("--cache", default="",
                    choices=["", "dense", "paged"],
                    help="LM KV-cache layout: dense per-slot rows or the "
                         "paged page-pool server with prompt-prefix "
                         "sharing (default: cfg.cache_mode)")
    ap.add_argument("--page-size", type=int, default=0,
                    help="cache positions per KV page in --cache paged "
                         "(0 = cfg.page_size; must divide --max-len)")
    ap.add_argument("--pool-pages", type=int, default=0,
                    help="physical pages in the paged pool (0 = the "
                         "dense-equivalent HBM: slots * max_len / "
                         "page_size)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="LM mode: length of a common prompt prefix "
                         "shared by all generated requests (exercises "
                         "prefix sharing under --cache paged; 0 = fully "
                         "random prompts)")
    ap.add_argument("--kernel-impl", default="jax",
                    choices=["jax", "pallas"],
                    help="kernels for prefill/the BLSTM forward AND the "
                         "decode loop (LM: decode-attention + argmax "
                         "selection kernels; ASR: the prefix-beam "
                         "inner-step kernel)")
    ap.add_argument("--sequential", action="store_true",
                    help="LM mode: decode active slots one at a time "
                         "instead of batching equal-position groups "
                         "(the bit-identical reference path)")
    ap.add_argument("--chunk-frames", type=int, default=8,
                    help="ASR mode: frames decoded per wave (the "
                         "streaming chunk of the beam-state carry)")
    ap.add_argument("--beam-width", type=int, default=0,
                    help="ASR mode: CTC prefix-beam width (0 = cfg "
                         "beam_width)")
    ap.add_argument("--beam-topc", type=int, default=-1,
                    help="ASR mode: per-frame top-C vocab pruning of the "
                         "beam candidate grid (0 = off, -1 = cfg "
                         "beam_topc); exact when C covers the frame "
                         "support (docs/decoding.md)")
    ap.add_argument("--trace-out", default="",
                    help="enable observability and write the run's "
                         "flight-recorder JSONL here (per-request "
                         "events, compile/steady kernel timings, VMEM "
                         "accounting gauges; docs/observability.md)")
    ap.add_argument("--trace-deterministic", action="store_true",
                    help="strip wall-clock fields from the JSONL so "
                         "two seeded runs emit byte-identical traces")
    args = ap.parse_args(argv)

    if args.trace_out:
        obs.configure()
    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.family == "lstm":
        return _main_asr(cfg, args)

    rng = np.random.default_rng(0)
    cache_mode = args.cache or cfg.cache_mode
    if cache_mode == "paged":
        page = args.page_size or cfg.page_size
        pool_pages = args.pool_pages or args.slots * cdiv(args.max_len,
                                                          page)
        server = PagedServer(cfg, pool_pages=pool_pages, page_size=page,
                             max_len=args.max_len,
                             kernel_impl=args.kernel_impl, verbose=True)
    else:
        server = Server(cfg, slots=args.slots, max_len=args.max_len,
                        kernel_impl=args.kernel_impl,
                        batched=not args.sequential, verbose=True)
    plen = min(args.prompt_len, prompt_capacity(args.max_len, "lm"))
    shared = min(args.shared_prefix, plen)
    prefix = rng.integers(0, cfg.vocab, size=shared)
    pending = [(i, np.concatenate([prefix,
                                   rng.integers(0, cfg.vocab,
                                                size=plen - shared)]))
               for i in range(args.requests)]
    finished, t0, steps, occ = [], time.time(), 0, 0.0
    while pending or server.active.any():
        while pending:
            res = server.admit(pending[0][0], pending[0][1], args.max_new)
            if res.reason == POOL_FULL:
                break
            pending.pop(0)      # admitted or terminally rejected (event
            # stream carries the per-request outcome either way)
        occ += (server.occupancy() if cache_mode == "paged"
                else server.active.mean())
        with obs.span("serve/wave", wave=steps):
            finished += server.step()
        steps += 1
    dt = time.time() - t0
    toks = sum(len(o) for _, o in finished)
    # decoded tokens/s + occupancy: the shared throughput convention of
    # launch/evaluate.py (occupancy = slot-pool utilization per wave;
    # paged mode reports page-pool utilization instead)
    print(f"served {len(finished)} requests, {toks} tokens, "
          f"{steps} decode waves in {dt:.1f}s ({toks/dt:.1f} tok/s, "
          f"occupancy {occ/max(steps, 1):.2f})")
    if cache_mode == "paged":
        print(f"[kv] pool={server.pool.n_pages} pages x "
              f"{server.page_size} positions, peak "
              f"sharing_ratio={server.peak_sharing:.3f}, "
              f"cow={server.pool.n_cow}, "
              f"shared_hits={server.pool.n_shared_hits}")
    for rid, out in finished:
        print(f"  req {rid}: {out[:8]}{'...' if len(out) > 8 else ''}")
    _finish_trace(server, args)


def _main_asr(cfg, args):
    """Streaming-ASR serving: variable-length synthetic utterances from
    the data pipeline's length distribution, chunked beam decode."""
    from repro.data import make_dataset

    seq_len = min(args.prompt_len, prompt_capacity(args.max_len, "asr"))
    ds = make_dataset(cfg, seq_len=seq_len, batch=max(args.requests, 1),
                      seed=0, var_len=True)
    batch = ds.batch_at(0)
    pending = [(i, batch["features"][i, :batch["lengths"][i]])
               for i in range(args.requests)]
    server = AsrServer(cfg, slots=args.slots, max_frames=args.max_len,
                       chunk=args.chunk_frames, beam=args.beam_width,
                       kernel_impl=args.kernel_impl,
                       topc=None if args.beam_topc < 0 else args.beam_topc,
                       verbose=True)
    finished, t0, steps, occ = [], time.time(), 0, 0.0
    frames = sum(len(f) for _, f in pending)
    while pending or server.active.any():
        while pending:
            res = server.admit(*pending[0])
            if res.reason == POOL_FULL:
                break
            pending.pop(0)
        with obs.span("serve/wave", wave=steps):
            done, wave_occ = server.step()
        finished += done
        occ += wave_occ
        steps += 1
    dt = time.time() - t0
    toks = sum(len(o) for _, o in finished)
    print(f"served {len(finished)} requests, {toks} tokens, "
          f"{steps} decode waves in {dt:.1f}s ({toks/dt:.1f} tok/s, "
          f"{frames/dt:.1f} frames/s, beam {server.beam} "
          f"occupancy {occ/max(steps, 1):.2f})")
    for rid, out in finished:
        print(f"  req {rid}: {out[:8]}{'...' if len(out) > 8 else ''}")
    _finish_trace(server, args)


if __name__ == "__main__":
    main()
