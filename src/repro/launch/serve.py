"""Batched serving launcher: continuous-batching decode loops.

Two request families share the slot-pool pattern (admit into free slots,
advance all active slots together, free and refill on completion):

* **LM** (decoder-only families): single-request prefill scatters cache
  rows into a stacked KV/SSM cache, then the jitted one-token
  ``decode_step`` advances every active slot.  Under ``--kernel-impl
  pallas`` the flag covers the whole request loop: prefill (flash
  attention), the decode step's per-layer attention (the streaming
  cache kernel in ``repro.kernels.decode_attention``, fused delta
  variant) and the next-token selection
  (``repro.decode.kernel.argmax_tokens``, bit-identical to
  ``jnp.argmax``).
* **ASR** (the paper's lstm family): requests are variable-length
  utterances; admission runs the BLSTM forward once (``--kernel-impl``
  selects the fused Pallas stack), and the decode loop streams the
  CD-state posteriors through the chunked CTC prefix beam search of
  ``repro.decode`` — one :class:`repro.decode.BeamState` batched over
  the slot pool IS the decode carry, advanced ``--chunk-frames`` frames
  per wave (docs/decoding.md).

Both servers implement the multi-tenant slot-pool duck contract of
``repro.serving`` (docs/serving.md): ``admit``/``submit`` return a
*typed* :class:`~repro.serving.admission.AdmitResult` (``pool_full`` is
retryable; ``prompt_too_long``/``no_budget`` are terminal),
``preempt``/``restore`` snapshot a running request's full decode state
(LM: the cache row; ASR: the :class:`~repro.decode.BeamState` row via
``gather_rows``/``scatter_rows``) so a preempted-then-resumed request
decodes bit-for-bit identically to an uninterrupted one, ``step_wave``
reports per-wave progress for SLO accounting, and every slot
transition lands in ``server.events`` as a structured per-request
event instead of an ad-hoc stats line.  ``repro.launch.load`` drives
these servers through seeded traffic with SLO accounting.

PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --reduced \
    --requests 6 --slots 2 --max-new 16
PYTHONPATH=src python -m repro.launch.serve --arch swb2000-blstm \
    --reduced --requests 6 --slots 2 --chunk-frames 8 --beam-width 4
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import decode as DC
from repro.configs import get_arch
from repro.configs.base import ShapeConfig
from repro.launch.mesh import make_local_mesh, rules_for
from repro.models import build_model
from repro.serving.admission import (NO_BUDGET, OK, POOL_FULL,
                                     PROMPT_TOO_LONG, AdmitResult)
from repro.sharding import ParamSpec, init_spec_tree


def zeros_from_specs(spec_tree):
    return jax.tree.map(
        lambda ps: jnp.zeros(ps.shape, ps.dtype),
        spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec))


def scatter_slot(pool, row, slot):
    """Write a single-request cache row (batch dim 1) into pool slot."""
    def one(dst, src):
        # batch is axis 1 (layer-stacked caches: (L, B, ...))
        return jax.lax.dynamic_update_slice_in_dim(
            dst, src.astype(dst.dtype), slot, axis=1)
    return jax.tree.map(one, pool, row)


def scatter_slots(pool, rows, slots):
    """Write gathered cache rows (batch = len(slots)) back into the
    (possibly non-contiguous) pool slots — the batched-wave counterpart
    of :func:`scatter_slot`."""
    idx = jnp.asarray(slots, jnp.int32)
    return jax.tree.map(
        lambda dst, src: dst.at[:, idx].set(src.astype(dst.dtype)),
        pool, rows)


class _SlotPool:
    """Shared slot-pool bookkeeping: typed admission helpers, the
    structured per-request event stream, and the rid -> slot map."""

    emits_on_admit = False

    def __init__(self, slots: int, verbose: bool = False):
        self.slots = slots
        self.active = np.zeros(slots, bool)
        self.req_ids = [-1] * slots
        self.events = []
        self.verbose = verbose

    def _event(self, kind: str, rid: int, **kw):
        self.events.append((kind, rid, kw))
        if self.verbose:
            extra = "".join(f" {k}={v}" for k, v in kw.items())
            print(f"[req] {kind} rid={rid}{extra}", flush=True)

    def _free_slot(self):
        free = np.where(~self.active)[0]
        return int(free[0]) if len(free) else -1

    def _slot_of(self, rid: int) -> int:
        for slot in np.where(self.active)[0]:
            if self.req_ids[slot] == rid:
                return int(slot)
        raise KeyError(f"request {rid} is not active in the pool")

    def active_requests(self):
        return [self.req_ids[s] for s in np.where(self.active)[0]]


class Server(_SlotPool):
    """LM continuous batching over a stacked KV/SSM cache."""

    emits_on_admit = True      # prefill emits the first token at admission

    def __init__(self, cfg, *, slots: int, max_len: int, seed: int = 0,
                 kernel_impl: str = "jax", batched: bool = True,
                 verbose: bool = False):
        # kernel_impl covers the whole request loop: prefill, the decode
        # step's attention (repro.kernels.decode_attention via
        # models.api.decode_fn; cfg.attn_decode_impl overrides) and the
        # token selection (repro.decode.kernel.argmax_tokens)
        assert cfg.supports_decode and cfg.family != "encdec", \
            "demo server covers decoder-only families"
        super().__init__(slots, verbose)
        self.cfg = cfg
        self.model = build_model(cfg)
        self.max_len = max_len
        self.batched = batched
        self.params = init_spec_tree(self.model.param_specs(),
                                     jax.random.PRNGKey(seed))
        shape = ShapeConfig("serve", max_len, slots, "decode")
        self._cache_specs = self.model.cache_specs(shape)
        self.cache = zeros_from_specs(self._cache_specs)
        self.pos = np.zeros(slots, np.int32)          # next write position
        self.tokens = np.zeros((slots, 1), np.int32)  # last emitted token
        self.budget = np.zeros(slots, np.int32)
        self.outputs = [[] for _ in range(slots)]

        self._jit_prefill = jax.jit(
            lambda params, batch: self.model.prefill_fn(
                params, batch, cache_len=max_len,
                kernel_impl=kernel_impl))
        self._jit_decode = jax.jit(
            lambda params, cache, tok, pos: self.model.decode_fn(
                params, cache, tok, pos, kernel_impl=kernel_impl))
        if kernel_impl == "pallas":
            self._select = lambda row: int(DC.argmax_tokens(row[None])[0])
        else:
            self._select = lambda row: int(jnp.argmax(row))

    # ------------------------------------------------------------------
    def admit(self, req_id: int, prompt: np.ndarray,
              max_new: int) -> AdmitResult:
        """Claim a free slot, prefill, emit the first token.  Typed
        rejection: ``pool_full`` (retryable), ``prompt_too_long`` (the
        cache write position must stay inside the slot's max_len row,
        one position reserved for the first generated token) or
        ``no_budget`` (max_new <= 0) — each is a distinct cause, not a
        silent False."""
        prompt = np.asarray(prompt)
        if len(prompt) > self.max_len - 1:
            self._event("reject", req_id, reason=PROMPT_TOO_LONG,
                        prompt=len(prompt))
            return AdmitResult(PROMPT_TOO_LONG)
        if max_new <= 0:
            self._event("reject", req_id, reason=NO_BUDGET)
            return AdmitResult(NO_BUDGET)
        slot = self._free_slot()
        if slot < 0:
            return AdmitResult(POOL_FULL)
        logits, row_cache = self._jit_prefill(
            self.params, {"tokens": jnp.asarray(prompt[None, :])})
        self.cache = scatter_slot(self.cache, row_cache, slot)
        nxt = self._select(logits[0, -1])
        self.pos[slot] = len(prompt)
        self.tokens[slot, 0] = nxt
        self.active[slot] = True
        self.budget[slot] = max_new - 1
        self.outputs[slot] = [nxt]
        self.req_ids[slot] = req_id
        self._event("admit", req_id, slot=slot, prompt=len(prompt))
        return AdmitResult(OK, slot)

    # ----------------------------------------------------- duck contract
    def submit(self, req, payload) -> AdmitResult:
        return self.admit(req.rid, payload, req.max_new)

    def step_wave(self):
        """One decode wave: ``(completed, progressed_rids, work)`` —
        every active slot advances one token, so work = active count."""
        progressed = self.active_requests()
        done = self.step()
        return done, progressed, len(progressed)

    def preempt(self, rid: int):
        """Evict ``rid``: snapshot its cache row (host-side) plus the
        position/budget/output bookkeeping, free the slot."""
        slot = self._slot_of(rid)
        snap = {
            "rid": rid,
            "pos": int(self.pos[slot]),
            "token": int(self.tokens[slot, 0]),
            "budget": int(self.budget[slot]),
            "outputs": list(self.outputs[slot]),
            "row": jax.tree.map(lambda c: np.asarray(c[:, slot:slot + 1]),
                                self.cache),
        }
        self.active[slot] = False
        self.req_ids[slot] = -1
        self._event("preempt", rid, slot=slot, pos=snap["pos"])
        return snap

    def restore(self, snap) -> AdmitResult:
        """Resume a preempted request in any free slot — the cache row
        round-trips exactly, so the continued decode is bit-for-bit the
        uninterrupted one."""
        slot = self._free_slot()
        if slot < 0:
            return AdmitResult(POOL_FULL)
        row = jax.tree.map(jnp.asarray, snap["row"])
        self.cache = scatter_slot(self.cache, row, slot)
        self.pos[slot] = snap["pos"]
        self.tokens[slot, 0] = snap["token"]
        self.budget[slot] = snap["budget"]
        self.outputs[slot] = list(snap["outputs"])
        self.active[slot] = True
        self.req_ids[slot] = snap["rid"]
        self._event("restore", snap["rid"], slot=slot, pos=snap["pos"])
        return AdmitResult(OK, slot)

    def reset(self):
        """Clear every slot (jitted executables survive — the capacity
        search replays many traffic levels on one server)."""
        self.cache = jax.tree.map(jnp.zeros_like, self.cache)
        self.pos[:] = 0
        self.active[:] = False
        self.tokens[:] = 0
        self.budget[:] = 0
        self.outputs = [[] for _ in range(self.slots)]
        self.req_ids = [-1] * self.slots
        self.events.clear()

    # ------------------------------------------------------------------
    def step(self):
        """Advance every active slot by one token.

        Slots share one jitted decode at a common position frontier:
        the cache write position differs per slot, so slots are grouped
        by position and each group decodes as ONE batched call (gather
        rows -> decode -> scatter back) — bit-identical to the
        sequential per-slot decode (parity-tested), with
        ``batched=False`` keeping the reference loop."""
        if not self.batched:
            return self._step_sequential()
        done = []
        active = np.where(self.active)[0]
        for p in sorted({int(self.pos[s]) for s in active}):
            group = np.array([s for s in active if self.pos[s] == p],
                             np.int32)
            toks = jnp.asarray(self.tokens[group])
            rows = jax.tree.map(lambda c: c[:, group], self.cache)
            logits, rows = self._jit_decode(self.params, rows, toks,
                                            jnp.int32(p))
            self.cache = scatter_slots(self.cache, rows, group)
            for i, slot in enumerate(map(int, group)):
                self._advance_slot(slot, logits[i, -1], done)
        return done

    def _step_sequential(self):
        done = []
        for slot in np.where(self.active)[0]:
            slot = int(slot)
            tok = jnp.asarray(self.tokens[slot:slot + 1])
            row = jax.tree.map(lambda c: c[:, slot:slot + 1], self.cache)
            logits, row = self._jit_decode(self.params, row, tok,
                                           jnp.int32(int(self.pos[slot])))
            self.cache = scatter_slot(self.cache, row, slot)
            self._advance_slot(slot, logits[0, -1], done)
        return done

    def _advance_slot(self, slot: int, logit_row, done):
        nxt = self._select(logit_row)
        self.outputs[slot].append(nxt)
        self.tokens[slot, 0] = nxt
        self.pos[slot] += 1
        self.budget[slot] -= 1
        if self.budget[slot] <= 0 or self.pos[slot] >= self.max_len - 1:
            self.active[slot] = False
            rid = self.req_ids[slot]
            done.append((rid, list(self.outputs[slot])))
            self._event("done", rid, slot=slot,
                        tokens=len(self.outputs[slot]))


class AsrServer(_SlotPool):
    """Streaming-ASR slot pool for the paper's acoustic model.

    Admission runs the BLSTM forward once over the utterance (masked to
    its valid frames; ``kernel_impl='pallas'`` selects the fused Pallas
    stack) and parks the CD-state posteriors host-side.  The decode loop
    then advances every active slot by ``chunk`` frames per wave through
    ONE batched :class:`repro.decode.BeamState` — the beam state is the
    streaming carry, per-slot frame counters freeze exhausted rows, and
    ``reset_rows`` re-arms a slot on admission.  Completion = all valid
    frames consumed; the hypothesis is the finalized best beam entry.
    Preemption snapshots the slot's beam row
    (``decode.gather_rows``/``scatter_rows``) plus its parked
    posteriors, so resume continues the identical beam trajectory.
    """

    def __init__(self, cfg, *, slots: int, max_frames: int, chunk: int,
                 beam: int = 0, seed: int = 0, kernel_impl: str = "jax",
                 topc: int = None, verbose: bool = False):
        from repro.models import lstm as LS

        super().__init__(slots, verbose)
        self.cfg = cfg
        self.max_frames = max_frames
        self.chunk = chunk
        self.beam = beam or getattr(cfg, "beam_width", 8)
        self.semiring = getattr(cfg, "beam_semiring", "max")
        self.len_norm = getattr(cfg, "beam_len_norm", 0.0)
        self.topc = (getattr(cfg, "beam_topc", 0) if topc is None
                     else topc)
        self.impl = "pallas" if kernel_impl == "pallas" else "jax"
        print(f"[decode] beam step: {self.impl} (beam {self.beam}, "
              f"topc {self.topc or 'off'})", flush=True)
        model = build_model(cfg)
        self.params = init_spec_tree(model.param_specs(),
                                     jax.random.PRNGKey(seed))
        self._jit_fwd = jax.jit(
            lambda p, feats, n: LS.forward(cfg, p, feats, n,
                                           kernel_impl=kernel_impl))
        self.logits = np.zeros((slots, max_frames, cfg.vocab), np.float32)
        self.lens = np.zeros(slots, np.int32)     # valid frames per slot
        self.pos = np.zeros(slots, np.int32)      # frames consumed
        self.state = DC.init_state(slots, self.beam, max_frames)
        # fixed (state, wave, lens) shapes -> jit once, no per-wave retrace
        self._jit_decode = jax.jit(
            lambda st, wave, lens: DC.decode_chunk(
                st, wave, lens, semiring=self.semiring, impl=self.impl,
                topc=self.topc))
        self._jit_finalize = jax.jit(
            lambda st: DC.finalize(st, len_norm=self.len_norm,
                                   semiring=self.semiring))
        self._jit_occ = jax.jit(DC.beam_occupancy)

    def admit(self, req_id: int, feats: np.ndarray) -> AdmitResult:
        """Typed admission: ``pool_full`` (retryable), ``prompt_too_long``
        (more frames than the slot's posterior buffer) or ``no_budget``
        (an empty utterance has nothing to decode)."""
        feats = np.asarray(feats, np.float32)
        n = len(feats)
        if n > self.max_frames:
            self._event("reject", req_id, reason=PROMPT_TOO_LONG, frames=n)
            return AdmitResult(PROMPT_TOO_LONG)
        if n == 0:
            self._event("reject", req_id, reason=NO_BUDGET)
            return AdmitResult(NO_BUDGET)
        slot = self._free_slot()
        if slot < 0:
            return AdmitResult(POOL_FULL)
        padded = np.zeros((1, self.max_frames, feats.shape[-1]), np.float32)
        padded[0, :n] = feats
        logits = self._jit_fwd(self.params, jnp.asarray(padded),
                               jnp.asarray([n], jnp.int32))
        self.logits[slot] = np.asarray(logits[0], np.float32)
        self.lens[slot] = n
        self.pos[slot] = 0
        self.active[slot] = True
        self.req_ids[slot] = req_id
        mask = np.zeros(self.slots, bool)
        mask[slot] = True
        self.state = DC.reset_rows(self.state, jnp.asarray(mask))
        self._event("admit", req_id, slot=slot, frames=n)
        return AdmitResult(OK, slot)

    # ----------------------------------------------------- duck contract
    def submit(self, req, payload) -> AdmitResult:
        return self.admit(req.rid, payload)

    def step_wave(self):
        """One decode wave: ``(completed, progressed_rids, work)`` with
        work = valid frames consumed across the pool this wave."""
        active = np.where(self.active)[0]
        progressed = [self.req_ids[s] for s in active]
        work = int(np.minimum(
            self.chunk,
            np.maximum(self.lens[active] - self.pos[active], 0)).sum())
        done, _ = self.step()
        return done, progressed, work

    def preempt(self, rid: int):
        """Evict ``rid``: snapshot its beam row + parked posteriors,
        freeze the vacated row (lens = 0 so ``state.t >= lens``), free
        the slot."""
        slot = self._slot_of(rid)
        snap = {
            "rid": rid,
            "logits": self.logits[slot].copy(),
            "len": int(self.lens[slot]),
            "pos": int(self.pos[slot]),
            "beam": jax.tree.map(np.asarray,
                                 DC.gather_rows(self.state, [slot])),
        }
        self.active[slot] = False
        self.req_ids[slot] = -1
        self.lens[slot] = 0        # freezes the stale beam row
        self.pos[slot] = 0
        self._event("preempt", rid, slot=slot, pos=snap["pos"])
        return snap

    def restore(self, snap) -> AdmitResult:
        """Resume in any free slot: scatter the beam row back
        (``decode.scatter_rows``) — the continued chunked decode is
        bit-identical to the uninterrupted stream (BeamState contract,
        docs/decoding.md)."""
        slot = self._free_slot()
        if slot < 0:
            return AdmitResult(POOL_FULL)
        self.logits[slot] = snap["logits"]
        self.lens[slot] = snap["len"]
        self.pos[slot] = snap["pos"]
        self.state = DC.scatter_rows(self.state, snap["beam"], [slot])
        self.active[slot] = True
        self.req_ids[slot] = snap["rid"]
        self._event("restore", snap["rid"], slot=slot, pos=snap["pos"])
        return AdmitResult(OK, slot)

    def reset(self):
        self.logits[:] = 0.0
        self.lens[:] = 0
        self.pos[:] = 0
        self.active[:] = False
        self.req_ids = [-1] * self.slots
        self.state = DC.init_state(self.slots, self.beam, self.max_frames)
        self.events.clear()

    # ------------------------------------------------------------------
    def step(self):
        """Advance every active slot by one chunk of frames.  Returns
        ``[(req_id, tokens), ...]`` for slots that finished and
        the live-beam occupancy of this wave."""
        C = self.chunk
        idx = np.minimum(self.pos[:, None] + np.arange(C)[None, :],
                         self.max_frames - 1)
        wave = self.logits[np.arange(self.slots)[:, None], idx]
        # per-row freeze: state.t >= lens stops exhausted/empty rows
        self.state = self._jit_decode(self.state, jnp.asarray(wave),
                                      jnp.asarray(self.lens))
        occ = float(np.mean(np.asarray(
            self._jit_occ(self.state))[self.active])) \
            if self.active.any() else 0.0
        self.pos = np.where(self.active,
                            np.minimum(self.pos + C, self.lens), self.pos)
        done = []
        finished = np.where(self.active & (self.pos >= self.lens))[0]
        if len(finished):
            toks, lens, _ = self._jit_finalize(self.state)
            toks = np.asarray(toks)
            for slot in finished:
                hyp = list(map(int, toks[slot][:int(lens[slot])]))
                rid = self.req_ids[slot]
                done.append((rid, hyp))
                self.active[slot] = False
                self._event("done", rid, slot=int(slot), tokens=len(hyp))
        return done, occ


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16,
                    help="prompt tokens (LM) / nominal utterance frames "
                         "(ASR) per request (clamped to --max-len)")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=64,
                    help="cache capacity (LM) / max utterance frames "
                         "(ASR) per slot")
    ap.add_argument("--kernel-impl", default="jax",
                    choices=["jax", "pallas"],
                    help="kernels for prefill/the BLSTM forward AND the "
                         "decode loop (LM: decode-attention + argmax "
                         "selection kernels; ASR: the prefix-beam "
                         "inner-step kernel)")
    ap.add_argument("--sequential", action="store_true",
                    help="LM mode: decode active slots one at a time "
                         "instead of batching equal-position groups "
                         "(the bit-identical reference path)")
    ap.add_argument("--chunk-frames", type=int, default=8,
                    help="ASR mode: frames decoded per wave (the "
                         "streaming chunk of the beam-state carry)")
    ap.add_argument("--beam-width", type=int, default=0,
                    help="ASR mode: CTC prefix-beam width (0 = cfg "
                         "beam_width)")
    ap.add_argument("--beam-topc", type=int, default=-1,
                    help="ASR mode: per-frame top-C vocab pruning of the "
                         "beam candidate grid (0 = off, -1 = cfg "
                         "beam_topc); exact when C covers the frame "
                         "support (docs/decoding.md)")
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.family == "lstm":
        return _main_asr(cfg, args)

    rng = np.random.default_rng(0)
    server = Server(cfg, slots=args.slots, max_len=args.max_len,
                    kernel_impl=args.kernel_impl,
                    batched=not args.sequential, verbose=True)
    plen = min(args.prompt_len, args.max_len - 1)
    pending = [(i, rng.integers(0, cfg.vocab, size=plen))
               for i in range(args.requests)]
    finished, t0, steps, occ = [], time.time(), 0, 0.0
    while pending or server.active.any():
        while pending:
            res = server.admit(pending[0][0], pending[0][1], args.max_new)
            if res.reason == POOL_FULL:
                break
            pending.pop(0)      # admitted or terminally rejected (event
            # stream carries the per-request outcome either way)
        occ += server.active.mean()
        finished += server.step()
        steps += 1
    dt = time.time() - t0
    toks = sum(len(o) for _, o in finished)
    # decoded tokens/s + occupancy: the shared throughput convention of
    # launch/evaluate.py (occupancy = slot-pool utilization per wave)
    print(f"served {len(finished)} requests, {toks} tokens, "
          f"{steps} decode waves in {dt:.1f}s ({toks/dt:.1f} tok/s, "
          f"occupancy {occ/max(steps, 1):.2f})")
    for rid, out in finished:
        print(f"  req {rid}: {out[:8]}{'...' if len(out) > 8 else ''}")


def _main_asr(cfg, args):
    """Streaming-ASR serving: variable-length synthetic utterances from
    the data pipeline's length distribution, chunked beam decode."""
    from repro.data import make_dataset

    seq_len = min(args.prompt_len, args.max_len)
    ds = make_dataset(cfg, seq_len=seq_len, batch=max(args.requests, 1),
                      seed=0, var_len=True)
    batch = ds.batch_at(0)
    pending = [(i, batch["features"][i, :batch["lengths"][i]])
               for i in range(args.requests)]
    server = AsrServer(cfg, slots=args.slots, max_frames=args.max_len,
                       chunk=args.chunk_frames, beam=args.beam_width,
                       kernel_impl=args.kernel_impl,
                       topc=None if args.beam_topc < 0 else args.beam_topc,
                       verbose=True)
    finished, t0, steps, occ = [], time.time(), 0, 0.0
    frames = sum(len(f) for _, f in pending)
    while pending or server.active.any():
        while pending:
            res = server.admit(*pending[0])
            if res.reason == POOL_FULL:
                break
            pending.pop(0)
        done, wave_occ = server.step()
        finished += done
        occ += wave_occ
        steps += 1
    dt = time.time() - t0
    toks = sum(len(o) for _, o in finished)
    print(f"served {len(finished)} requests, {toks} tokens, "
          f"{steps} decode waves in {dt:.1f}s ({toks/dt:.1f} tok/s, "
          f"{frames/dt:.1f} frames/s, beam {server.beam} "
          f"occupancy {occ/max(steps, 1):.2f})")
    for rid, out in finished:
        print(f"  req {rid}: {out[:8]}{'...' if len(out) > 8 else ''}")


if __name__ == "__main__":
    main()
