"""Batched serving launcher: continuous-batching decode loop.

A fixed pool of batch slots shares one stacked KV/SSM cache.  Requests are
admitted into free slots via single-request prefill (cache rows scattered
into the slot index), then all active slots advance together through the
jitted one-token ``decode_step``.  Completed slots are freed and refilled —
the standard continuous-batching pattern, CPU-runnable at reduced scale.

PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --reduced \
    --requests 6 --slots 2 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.configs.base import ShapeConfig
from repro.launch.mesh import make_local_mesh, rules_for
from repro.models import build_model
from repro.sharding import ParamSpec, init_spec_tree


def zeros_from_specs(spec_tree):
    return jax.tree.map(
        lambda ps: jnp.zeros(ps.shape, ps.dtype),
        spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec))


def scatter_slot(pool, row, slot):
    """Write a single-request cache row (batch dim 1) into pool slot."""
    def one(dst, src):
        # batch is axis 1 (layer-stacked caches: (L, B, ...))
        return jax.lax.dynamic_update_slice_in_dim(
            dst, src.astype(dst.dtype), slot, axis=1)
    return jax.tree.map(one, pool, row)


class Server:
    def __init__(self, cfg, *, slots: int, max_len: int, seed: int = 0,
                 kernel_impl: str = "jax"):
        # kernel_impl reaches prefill only: decode_fn is a one-token step
        # with no pallas variant (tracked in ROADMAP.md open items)
        assert cfg.supports_decode and cfg.family != "encdec", \
            "demo server covers decoder-only families"
        self.cfg = cfg
        self.model = build_model(cfg)
        self.slots = slots
        self.max_len = max_len
        self.params = init_spec_tree(self.model.param_specs(),
                                     jax.random.PRNGKey(seed))
        shape = ShapeConfig("serve", max_len, slots, "decode")
        self.cache = zeros_from_specs(self.model.cache_specs(shape))
        self.pos = np.zeros(slots, np.int32)          # next write position
        self.active = np.zeros(slots, bool)
        self.tokens = np.zeros((slots, 1), np.int32)  # last emitted token
        self.budget = np.zeros(slots, np.int32)
        self.outputs = [[] for _ in range(slots)]
        self.req_ids = [-1] * slots

        self._jit_prefill = jax.jit(
            lambda params, batch: self.model.prefill_fn(
                params, batch, cache_len=max_len,
                kernel_impl=kernel_impl))
        self._jit_decode = jax.jit(
            lambda params, cache, tok, pos: self.model.decode_fn(
                params, cache, tok, pos))

    # ------------------------------------------------------------------
    def admit(self, req_id: int, prompt: np.ndarray, max_new: int) -> bool:
        free = np.where(~self.active)[0]
        if len(free) == 0:
            return False
        slot = int(free[0])
        prompt = np.asarray(prompt)
        # clamp to the most recent max_len-1 tokens: the cache write
        # position must stay inside the slot's max_len cache row, and one
        # position is reserved for the first generated token (floor of 1
        # token — a -0 slice would keep the whole prompt)
        keep = max(self.max_len - 1, 1)
        if len(prompt) > keep:
            prompt = prompt[-keep:]
        logits, row_cache = self._jit_prefill(
            self.params, {"tokens": jnp.asarray(prompt[None, :])})
        self.cache = scatter_slot(self.cache, row_cache, slot)
        nxt = int(jnp.argmax(logits[0, -1]))
        self.pos[slot] = len(prompt)
        self.tokens[slot, 0] = nxt
        self.active[slot] = True
        self.budget[slot] = max_new - 1
        self.outputs[slot] = [nxt]
        self.req_ids[slot] = req_id
        return True

    def step(self):
        """Advance every active slot by one token.

        Slots share one jitted decode at a common position frontier: the
        cache write position differs per slot, so we decode sequentially per
        unique position group (at reduced scale groups are tiny; production
        serving aligns positions per wave).
        """
        done = []
        for slot in np.where(self.active)[0]:
            tok = jnp.asarray(self.tokens[slot:slot + 1])
            row = jax.tree.map(lambda c: c[:, slot:slot + 1], self.cache)
            logits, row = self._jit_decode(self.params, row, tok,
                                           jnp.int32(int(self.pos[slot])))
            self.cache = scatter_slot(self.cache, row, int(slot))
            nxt = int(jnp.argmax(logits[0, -1]))
            self.outputs[slot].append(nxt)
            self.tokens[slot, 0] = nxt
            self.pos[slot] += 1
            self.budget[slot] -= 1
            if self.budget[slot] <= 0 or self.pos[slot] >= self.max_len - 1:
                self.active[slot] = False
                done.append((self.req_ids[slot], list(self.outputs[slot])))
        return done


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--kernel-impl", default="jax",
                    choices=["jax", "pallas"],
                    help="kernel implementation for PREFILL only; the "
                         "one-token decode loop has no pallas path yet "
                         "and always runs the jax kernels")
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    rng = np.random.default_rng(0)
    server = Server(cfg, slots=args.slots, max_len=args.max_len,
                    kernel_impl=args.kernel_impl)
    pending = [(i, rng.integers(0, cfg.vocab, size=args.prompt_len))
               for i in range(args.requests)]
    finished, t0, steps = [], time.time(), 0
    while pending or server.active.any():
        while pending and server.admit(pending[0][0], pending[0][1],
                                       args.max_new):
            print(f"admitted request {pending[0][0]}")
            pending.pop(0)
        finished += server.step()
        steps += 1
    dt = time.time() - t0
    toks = sum(len(o) for _, o in finished)
    print(f"served {len(finished)} requests, {toks} tokens, "
          f"{steps} decode waves in {dt:.1f}s ({toks/dt:.1f} tok/s)")
    for rid, out in finished:
        print(f"  req {rid}: {out[:8]}{'...' if len(out) > 8 else ''}")


if __name__ == "__main__":
    main()
