"""Training launcher.

Library entry point: :func:`setup_training` builds (state, step_fn, meta)
for any (arch, strategy, mesh); the CLI runs the loop with prefetching,
logging and checkpointing.

Examples
--------
# paper's acoustic model, AD-PSGD, 4 simulated learners, reduced size:
PYTHONPATH=src python -m repro.launch.train --arch swb2000-blstm \
    --reduced --learners 4 --strategy ad_psgd --steps 200

# any assigned arch:
PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --reduced \
    --strategy sd_psgd --steps 50 --seq-len 128 --batch 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.checkpoint import restore, save
from repro.configs import get_arch
from repro.core import strategies as ST
from repro.data import make_dataset
from repro.data.pipeline import Prefetcher
from repro.launch.mesh import (make_local_mesh, make_production_mesh,
                               rules_for, use_mesh)
from repro.models import build_model
from repro.optim.optimizers import get_optimizer
from repro.optim.schedules import paper_recipe, warmup_then_anneal
from repro.sharding import init_spec_tree, spec_tree_shardings


def setup_training(cfg, mesh, *, strategy_name: str = None,
                   n_learners: int = None, optimizer_name: str = "sgd",
                   lr_schedule=None, seed: int = 0, multi_pod: bool = False,
                   with_consensus: bool = False, kernel_impl: str = "jax",
                   microbatches: int = None, transport=None,
                   elastic: bool = False, fault_seed: int = 0,
                   with_corruption: bool = False,
                   with_grad_norm: bool = False):
    """Build sharded train state + jitted step for one arch on one mesh.

    ``transport`` overrides the communication substrate (topology × wire
    × bucketing); default: the cfg's ``comm_*`` knobs resolved against
    the strategy (see repro.core.transport and docs/strategies.md).

    ``elastic=True`` builds the fault-tolerant step instead
    (``ST.make_elastic_train_step``): it takes a third ``faults``
    argument — one ``FaultPlan.step_inputs`` dict per step — and runs
    the strategy under elastic membership with staleness-aware mixing
    (docs/fault_tolerance.md).
    """
    strategy = ST.get_strategy(strategy_name or cfg.train_strategy)
    n_learners = n_learners if n_learners is not None else cfg.n_learners
    if not strategy.replicated:
        n_learners = 1
    microbatches = (microbatches if microbatches is not None
                    else cfg.microbatches)
    if transport is None:
        transport = ST.transport_from_cfg(cfg, strategy)
    model = build_model(cfg)
    rules = rules_for(cfg, mesh, multi_pod=multi_pod)
    opt = get_optimizer(optimizer_name)
    lr_schedule = lr_schedule or warmup_then_anneal(0.1, 0.5, 100, 10_000,
                                                    1 / np.sqrt(2))

    def loss_fn(params, batch):
        return model.loss_fn(params, batch, kernel_impl=kernel_impl)

    if elastic:
        step_fn = ST.make_elastic_train_step(
            strategy, loss_fn, opt, lr_schedule,
            n_learners=n_learners, microbatches=microbatches,
            with_consensus=with_consensus, transport=transport,
            fault_seed=fault_seed, with_corruption=with_corruption,
            with_grad_norm=with_grad_norm)
    else:
        step_fn = ST.make_train_step(
            strategy, loss_fn, opt, lr_schedule,
            n_learners=n_learners, microbatches=microbatches,
            with_consensus=with_consensus, transport=transport,
            with_grad_norm=with_grad_norm)

    pspecs = model.param_specs()
    lead = ((n_learners, "learner"),) if strategy.replicated else ()
    param_shardings = spec_tree_shardings(pspecs, rules, extra_leading=lead)

    with use_mesh(mesh):
        params = init_spec_tree(pspecs, jax.random.PRNGKey(seed))
        if strategy.replicated:
            params = ST.stack_for_learners(params, n_learners)
        params = jax.tree.map(jax.device_put, params, param_shardings)
        if elastic:
            state = ST.init_elastic_state(strategy, params, opt,
                                          transport=transport)
        else:
            state = ST.init_state(strategy, params, opt, transport=transport)
        jit_step = jax.jit(step_fn, donate_argnums=(0,))

    meta = dict(model=model, rules=rules, strategy=strategy,
                n_learners=n_learners, mesh=mesh, transport=transport)
    return state, jit_step, meta


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--strategy", default=None,
                    choices=[None] + sorted(ST.STRATEGIES))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=None)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--learners", type=int, default=None)
    ap.add_argument("--optimizer", default="sgd")
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale variant of the arch (CPU-friendly)")
    ap.add_argument("--mesh", default="local",
                    choices=["local", "pod", "multipod"])
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--consensus", action="store_true")
    ap.add_argument("--kernel-impl", default="jax",
                    choices=["jax", "pallas"])
    ap.add_argument("--block-b", type=int, default=0,
                    help="Pallas LSTM batch tile (0 = auto from VMEM)")
    ap.add_argument("--vmem-budget-mb", type=int, default=0,
                    help="VMEM budget for kernel auto-tiling (0 = cfg)")
    ap.add_argument("--stash-dtype", default="",
                    choices=["", "float32", "bfloat16"],
                    help="Pallas LSTM residual-stash dtype (bfloat16 "
                         "halves the gate/cell stash HBM)")
    ap.add_argument("--seq-chunk", type=int, default=0,
                    help="Pallas LSTM sequence-chunked recompute: stash "
                         "only (h, c) carries every K frames and rebuild "
                         "gate residuals in VMEM in the backward (0 = "
                         "off, -1 = auto from the VMEM budget); cuts the "
                         "O(T) residual stash to O(T/K) for long "
                         "utterances")
    ap.add_argument("--comm-topology", default="",
                    choices=["", "uniform", "ring", "hierarchical", "exp",
                             "none"],
                    help="mixing topology override (default: the "
                         "strategy's own; docs/strategies.md)")
    ap.add_argument("--comm-wire", default="",
                    choices=["", "f32", "bf16", "int8", "topk"],
                    help="wire codec for mixing payloads (default: the "
                         "strategy's own, f32 for all paper strategies)")
    ap.add_argument("--comm-intra-wire", default="",
                    choices=["", "f32", "bf16", "int8"],
                    help="hierarchical topology: codec of the intra-pod "
                         "allreduce (inter-pod uses --comm-wire; topk is "
                         "gossip-only and not valid here)")
    ap.add_argument("--comm-bucket-mb", type=int, default=0,
                    help="chunk mixing payloads into buckets of this many "
                         "MB so XLA can interleave them with backward "
                         "compute (0 = one fused payload per tensor)")
    ap.add_argument("--comm-pod-size", type=int, default=0,
                    help="hierarchical topology: learners per pod (0 = "
                         "cfg value)")
    ap.add_argument("--comm-topk-frac", type=float, default=0.0,
                    help="topk wire: fraction of entries shipped (0 = "
                         "cfg value, 0.01)")
    ap.add_argument("--comm-staleness-lambda", type=float, default=0.0,
                    help="elastic mixing: staleness damping λ — a "
                         "learner s steps behind mixes with confidence "
                         "1/(1 + λ·s); 0 = cfg value "
                         "(docs/fault_tolerance.md)")
    ap.add_argument("--resume", action="store_true",
                    help="require and restore the latest checkpoint in "
                         "--ckpt-dir: optimizer state, comm "
                         "error-feedback residuals and the data cursor "
                         "all resume bit-exactly (recovery contract in "
                         "docs/fault_tolerance.md); fails if nothing to "
                         "resume")
    ap.add_argument("--fault-stragglers", default="",
                    help="fault plan: 'learner:factor,...' — e.g. '0:4' "
                         "makes learner 0 contribute a gradient only "
                         "every 4th step (docs/fault_tolerance.md); any "
                         "--fault-* flag switches to the elastic "
                         "fault-tolerant step")
    ap.add_argument("--fault-departures", default="",
                    help="fault plan: 'learner:step[:rejoin],...' — "
                         "e.g. '1:30:60' crashes learner 1 at step 30 "
                         "and rejoins it (re-seeded from the survivors' "
                         "consensus) at step 60")
    ap.add_argument("--fault-drop-prob", type=float, default=0.0,
                    help="fault plan: per-step probability that an "
                         "undirected gossip edge drops (both endpoints "
                         "fall back to themselves)")
    ap.add_argument("--fault-stall-prob", type=float, default=0.0,
                    help="fault plan: per-step probability a learner "
                         "enters a heavy-tailed (Pareto) stall")
    ap.add_argument("--fault-corrupt-prob", type=float, default=0.0,
                    help="fault plan: per-step probability a learner's "
                         "outgoing payload picks up noise (receivers "
                         "only; needs --fault-corrupt-scale > 0)")
    ap.add_argument("--fault-corrupt-scale", type=float, default=0.0,
                    help="fault plan: corruption noise RMS relative to "
                         "the payload RMS")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="fault plan: seed of the deterministic fault "
                         "schedule (same seed = same cluster weather)")
    ap.add_argument("--var-len", action="store_true",
                    help="variable-length utterances: batches carry a "
                         "'lengths' key, loss/BLSTM/aggregation mask "
                         "padded frames (lstm family only)")
    ap.add_argument("--bucket", action="store_true",
                    help="length-bucketed batching (implies --var-len): "
                         "sort utterances within a shuffle window so each "
                         "batch pads to its own rounded max length; "
                         "distinct padded lengths each compile once")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace-out", default="",
                    help="enable observability and write the run's "
                         "flight-recorder JSONL here (schema in "
                         "docs/observability.md; render with "
                         "repro.launch.obsreport); also records "
                         "per-step grad-norm")
    ap.add_argument("--trace-deterministic", action="store_true",
                    help="strip wall-clock fields from the JSONL so "
                         "two seeded runs emit byte-identical traces")
    args = ap.parse_args(argv)

    if args.trace_out:
        obs.configure()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    import dataclasses
    changes = {}
    if args.block_b:
        changes["lstm_block_b"] = args.block_b
    if args.vmem_budget_mb:
        changes["lstm_vmem_budget_mb"] = args.vmem_budget_mb
    if args.stash_dtype:
        changes["lstm_stash_dtype"] = args.stash_dtype
    if args.seq_chunk:
        changes["lstm_seq_chunk"] = args.seq_chunk
    if args.comm_topology:
        changes["comm_topology"] = args.comm_topology
    if args.comm_wire:
        changes["comm_wire"] = args.comm_wire
    if args.comm_intra_wire:
        changes["comm_intra_wire"] = args.comm_intra_wire
    if args.comm_bucket_mb:
        changes["comm_bucket_mb"] = args.comm_bucket_mb
    if args.comm_pod_size:
        changes["comm_pod_size"] = args.comm_pod_size
    if args.comm_topk_frac:
        changes["comm_topk_frac"] = args.comm_topk_frac
    if args.comm_staleness_lambda:
        changes["comm_staleness_lambda"] = args.comm_staleness_lambda
    if changes:
        cfg = dataclasses.replace(cfg, **changes)
    seq_len = args.seq_len or (21 if cfg.family == "lstm" else 128)
    n_learners = args.learners if args.learners is not None else cfg.n_learners
    strategy = ST.get_strategy(args.strategy or cfg.train_strategy)
    if not strategy.replicated:
        n_learners = 1
    batch = args.batch or max(8, 2 * n_learners)

    # any --fault-* flag switches to the elastic fault-tolerant step,
    # driven by one deterministic FaultPlan (docs/fault_tolerance.md)
    from repro.core.faults import (FaultPlan, parse_departures,
                                   parse_stragglers)
    elastic = bool(args.fault_stragglers or args.fault_departures
                   or args.fault_drop_prob or args.fault_stall_prob
                   or args.fault_corrupt_prob)
    plan = None
    if elastic:
        plan = FaultPlan(
            n_learners, seed=args.fault_seed,
            stragglers=parse_stragglers(args.fault_stragglers),
            departures=parse_departures(args.fault_departures),
            drop_prob=args.fault_drop_prob,
            stall_prob=args.fault_stall_prob,
            corrupt_prob=args.fault_corrupt_prob,
            corrupt_scale=args.fault_corrupt_scale)
        print(plan.describe(), flush=True)

    if args.mesh == "local":
        mesh = make_local_mesh(data=len(jax.devices()))
    else:
        mesh = make_production_mesh(multi_pod=args.mesh == "multipod")

    state, jit_step, meta = setup_training(
        cfg, mesh, strategy_name=strategy.name, n_learners=n_learners,
        optimizer_name=args.optimizer, seed=args.seed,
        multi_pod=args.mesh == "multipod", with_consensus=args.consensus,
        kernel_impl=args.kernel_impl,
        lr_schedule=paper_recipe(steps_per_epoch=max(args.steps // 16, 1),
                                 base_lr=0.05, peak_lr=0.2),
        elastic=elastic, fault_seed=args.fault_seed,
        with_corruption=args.fault_corrupt_prob > 0,
        with_grad_norm=obs.enabled())

    if args.resume and not args.ckpt_dir:
        raise SystemExit("--resume needs --ckpt-dir")
    start = 0
    if args.ckpt_dir:
        try:
            state, start = restore(args.ckpt_dir, state)
            print(f"restored checkpoint at step {start}")
        except FileNotFoundError:
            if args.resume:
                raise SystemExit(
                    f"--resume: no checkpoint under {args.ckpt_dir}")

    if obs.enabled() and cfg.family == "lstm":
        # runtime collection of the BLSTM residual-stash HBM accounting
        # single-source (repro.kernels.lstm_cell.stash_bytes)
        from repro.kernels.lstm_cell import stash_bytes
        obs.gauge("kernel/stash_bytes", impl=args.kernel_impl).set(
            stash_bytes(max(batch // max(n_learners, 1), 1), seq_len,
                        cfg.d_model, n_dir=2,
                        stash_itemsize=(2 if cfg.lstm_stash_dtype
                                        == "bfloat16" else 4),
                        seq_chunk=max(cfg.lstm_seq_chunk, 0)))

    ds = make_dataset(cfg, seq_len=seq_len, batch=batch, seed=args.seed,
                      var_len=args.var_len or args.bucket,
                      bucket=args.bucket)
    pf = Prefetcher(ds, start_step=start)

    # compile/steady wall-time split per jit entry point: a new BATCH
    # shape (bucketed batching pads to distinct lengths) means an XLA
    # retrace, so key on the batch arg's array shapes (args[1])
    def _batch_key(a, kw):
        return tuple(sorted((k2, tuple(v.shape))
                            for k2, v in a[1].items()))

    prof = obs.ProfiledFn(jit_step, "train/step", key=_batch_key,
                          metrics=obs.get_metrics(),
                          recorder=obs.get_recorder())
    t0 = time.time()
    valid_frames = padded_frames = 0
    metrics = None
    with use_mesh(meta["mesh"]):
        for k in range(start, args.steps):
            with obs.span("train/fetch", step=k):
                batch_np = pf.next()
            if "lengths" in batch_np:
                valid_frames += int(batch_np["lengths"].sum())
                padded_frames += (batch_np["features"].shape[0]
                                  * batch_np["features"].shape[1])
            if plan is not None:
                faults = plan.step_inputs(k)
                ST.check_active(faults["active"])
                state, metrics = prof(state, batch_np, faults)
            else:
                state, metrics = prof(state, batch_np)
            if obs.enabled():
                scal = {k2: float(v) for k2, v in metrics.items()}
                obs.event("train/step", step=k, **scal)
                obs.histogram("train/loss").observe(scal["loss"])
                if "grad_norm" in scal:
                    obs.histogram("train/grad_norm").observe(
                        scal["grad_norm"])
                if "wire_bytes" in scal:
                    obs.counter("train/wire_bytes",
                                strategy=meta["strategy"].name
                                ).inc(scal["wire_bytes"])
                if "n_active" in scal:
                    obs.gauge("train/n_active").set(scal["n_active"])
                    obs.histogram("train/staleness_max").observe(
                        scal["staleness_max"])
                if padded_frames:
                    obs.gauge("train/pad_eff").set(
                        valid_frames / padded_frames)
            if k % args.log_every == 0:
                loss = float(metrics["loss"])
                line = (f"step {k:5d} loss {loss:.4f} "
                        f"({(time.time()-t0):.1f}s)")
                if padded_frames:
                    # padding efficiency: valid / (B * Tpad) frames —
                    # bucketing exists to push this toward 1.0
                    line += f" pad_eff {valid_frames/padded_frames:.2f}"
                if "wire_bytes" in metrics:
                    # analytic bytes sent per learner this step
                    # (Transport.wire_bytes; docs/strategies.md)
                    wb = float(metrics["wire_bytes"])
                    line += f" wire {wb/2**20:.2f}MB"
                if "n_active" in metrics:
                    line += (f" act {int(metrics['n_active'])}/"
                             f"{meta['n_learners']}"
                             f" stale {int(metrics['staleness_max'])}")
                if "consensus" in metrics:
                    line += f" consensus {float(metrics['consensus']):.3e}"
                print(line, flush=True)
            if args.ckpt_dir and args.ckpt_every and \
                    (k + 1) % args.ckpt_every == 0:
                save(args.ckpt_dir, k + 1, state)
    pf.close()
    if metrics is not None:
        # one parseable line for kill-and-resume / fault-smoke comparisons
        print(f"final loss {float(metrics['loss']):.6f}")
    # compile (first call per batch shape: trace + XLA compile) and
    # steady-state step time are different regimes — report both
    # instead of one conflated total (ProfiledFn split)
    n_steady = prof.n_calls - prof.n_compiles
    print(f"done: {args.steps - start} steps in {time.time()-t0:.1f}s "
          f"[{meta['strategy'].name}, L={meta['n_learners']}]")
    print(f"timing: compile {prof.compile_s:.1f}s "
          f"({prof.n_compiles} compile(s)), steady {prof.steady_s:.1f}s "
          f"over {n_steady} steps"
          + (f" ({1e3 * prof.steady_mean_s:.1f} ms/step)" if n_steady
             else ""), flush=True)
    if args.trace_out:
        n = obs.dump(args.trace_out,
                     deterministic=args.trace_deterministic)
        print(f"trace: {n} events -> {args.trace_out}")
        obs.reset()


if __name__ == "__main__":
    main()
